//! Pure-Rust executor for the AOT entry points.
//!
//! The paper's Layer-1/2 artifacts are Pallas kernels + a Llama-style
//! transformer, AOT-lowered to HLO and executed through PJRT. The PJRT
//! binding (`xla` crate) is not in the offline vendor set, so this module
//! supplies the same contract natively: every manifest entry is backed by
//! a deterministic Rust implementation of its golden model
//! (`python/compile/kernels/ref.py`), and the LLM entries run a real
//! (tiny) transformer — RMSNorm, RoPE, causal attention over a
//! fixed-capacity KV cache, SwiGLU MLP — with weights generated
//! deterministically from the in-crate PRNG.
//!
//! The serving semantics match `python/compile/model.py` exactly:
//! `llm_prefill` processes a `[1, prefill_len]` window and returns
//! `max_seq`-capacity caches; `llm_decode` writes the new token's K/V at
//! slot `pos` and attends slots `<= pos`, so padded prefill slots are
//! never read (the coordinator's cursor overwrites them first).

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::runtime::manifest::{EntrySpec, Manifest, ModelSpec, TensorSpec};
use crate::runtime::tensor::{DType, Tensor};
use crate::util::rng::Rng;

// Phong material constants + the RGB→YUV matrix come from
// `workloads::graphics` so the artifact golden models and the IR kernels
// cannot desynchronize.
use crate::workloads::graphics::{KA, KD, KS, RGB2YUV, SHININESS};

fn spec(shape: &[usize], dtype: DType) -> TensorSpec {
    TensorSpec::new(shape.to_vec(), dtype)
}

/// The manifest the simulated backend serves when no `artifacts/`
/// directory exists — same model configuration and entry catalogue as
/// `python/compile/aot.py` (TINY_CONFIG, PREFILL_LEN = 16, BATCH = 1).
pub(crate) fn default_manifest() -> Manifest {
    let model = ModelSpec {
        vocab: 256,
        dim: 64,
        n_layers: 2,
        n_heads: 4,
        head_dim: 16,
        hidden: 160,
        max_seq: 64,
        prefill_len: 16,
        batch: 1,
        // vocab*dim*2 (embed+unembed) + L*(4*dim² + 3*dim*hidden + 2*dim) + dim
        param_count: (256 * 64 * 2 + 2 * (4 * 64 * 64 + 3 * 64 * 160 + 2 * 64) + 64) as u64,
    };
    let (l, b, h, t, dh) =
        (model.n_layers, model.batch, model.n_heads, model.max_seq, model.head_dim);
    let kv = spec(&[l, b, h, t, dh], DType::F32);
    let f = DType::F32;
    let i = DType::I32;

    let mut entries = BTreeMap::new();
    let mut add = |name: &str, args: Vec<TensorSpec>, outputs: Vec<TensorSpec>| {
        entries.insert(
            name.to_string(),
            EntrySpec { file: format!("{name}.hlo.txt"), args, outputs },
        );
    };
    add(
        "llm_prefill",
        vec![spec(&[b, model.prefill_len], i)],
        vec![spec(&[b, model.prefill_len, model.vocab], f), kv.clone(), kv.clone()],
    );
    add(
        "llm_decode",
        vec![spec(&[b, 1], i), kv.clone(), kv.clone(), spec(&[1], i)],
        vec![spec(&[b, model.vocab], f), kv.clone(), kv],
    );
    add(
        "attention",
        vec![spec(&[1, 4, 64, 16], f); 3],
        vec![spec(&[1, 4, 64, 16], f)],
    );
    add("gf2mm", vec![spec(&[64, 64], i); 2], vec![spec(&[64, 64], i)]);
    add("vdecomp", vec![spec(&[16], i)], vec![spec(&[512], i)]);
    add("vdist3", vec![spec(&[256, 3], f); 2], vec![spec(&[256], f)]);
    add("mcov", vec![spec(&[256, 3], f); 2], vec![spec(&[3, 3], f)]);
    add("vfsmax", vec![spec(&[256], f)], vec![spec(&[], f), spec(&[], i)]);
    add(
        "vmadot",
        vec![spec(&[64, 64], f), spec(&[64], f)],
        vec![spec(&[64], f)],
    );
    add("phong", vec![spec(&[256, 3], f); 3], vec![spec(&[256], f)]);
    add("vrgb2yuv", vec![spec(&[256, 3], f)], vec![spec(&[256, 3], f)]);
    add(
        "vmvar",
        vec![spec(&[64, 16], f)],
        vec![spec(&[64], f), spec(&[64], f)],
    );
    Manifest { model, entries }
}

// ---------------------------------------------------------------------------
// Tiny Llama-style transformer (the llm_prefill / llm_decode backend)
// ---------------------------------------------------------------------------

const ROPE_THETA: f32 = 10000.0;
const NORM_EPS: f32 = 1e-5;

struct Layer {
    attn_norm: Vec<f32>,
    mlp_norm: Vec<f32>,
    /// `[dim, dim]`, row-major (input index major).
    wq: Vec<f32>,
    wk: Vec<f32>,
    wv: Vec<f32>,
    wo: Vec<f32>,
    /// `[dim, hidden]`.
    w1: Vec<f32>,
    /// `[hidden, dim]`.
    w2: Vec<f32>,
    /// `[dim, hidden]`.
    w3: Vec<f32>,
}

/// The deterministic tiny transformer driving the LLM serving entries.
pub(crate) struct TinyLlm {
    vocab: usize,
    dim: usize,
    n_heads: usize,
    head_dim: usize,
    hidden: usize,
    max_seq: usize,
    n_layers: usize,
    /// `[vocab, dim]`.
    embed: Vec<f32>,
    /// `[dim, vocab]`.
    unembed: Vec<f32>,
    final_norm: Vec<f32>,
    layers: Vec<Layer>,
}

fn dense(rng: &mut Rng, rows: usize, cols: usize) -> Vec<f32> {
    // Xavier-ish scale keeps activations and logits well-conditioned.
    let scale = 1.0 / (rows as f64).sqrt();
    (0..rows * cols).map(|_| (rng.normal() * scale) as f32).collect()
}

impl TinyLlm {
    /// Build weights deterministically from the model configuration.
    pub(crate) fn new(m: &ModelSpec) -> Self {
        let mut rng = Rng::new(0xA9_0A5);
        let layers = (0..m.n_layers)
            .map(|_| Layer {
                attn_norm: vec![1.0; m.dim],
                mlp_norm: vec![1.0; m.dim],
                wq: dense(&mut rng, m.dim, m.dim),
                wk: dense(&mut rng, m.dim, m.dim),
                wv: dense(&mut rng, m.dim, m.dim),
                wo: dense(&mut rng, m.dim, m.dim),
                w1: dense(&mut rng, m.dim, m.hidden),
                w2: dense(&mut rng, m.hidden, m.dim),
                w3: dense(&mut rng, m.dim, m.hidden),
            })
            .collect();
        Self {
            vocab: m.vocab,
            dim: m.dim,
            n_heads: m.n_heads,
            head_dim: m.head_dim,
            hidden: m.hidden,
            max_seq: m.max_seq,
            n_layers: m.n_layers,
            embed: dense(&mut rng, m.vocab, m.dim),
            unembed: dense(&mut rng, m.dim, m.vocab),
            final_norm: vec![1.0; m.dim],
            layers,
        }
    }

    fn kv_len(&self) -> usize {
        self.n_layers * self.n_heads * self.max_seq * self.head_dim
    }

    fn kv_index(&self, layer: usize, head: usize, slot: usize) -> usize {
        ((layer * self.n_heads + head) * self.max_seq + slot) * self.head_dim
    }

    /// Advance the model by one token at absolute position `pos`,
    /// writing its K/V into the caches and returning the logits row.
    /// Attention sees slots `0..=pos` (exact-causal for prefill replay,
    /// full-window for decode).
    fn step(&self, token: i32, pos: usize, kc: &mut [f32], vc: &mut [f32]) -> Vec<f32> {
        let d = self.dim;
        let dh = self.head_dim;
        let tok = token.rem_euclid(self.vocab as i32) as usize;
        let mut x: Vec<f32> = self.embed[tok * d..(tok + 1) * d].to_vec();

        for (li, layer) in self.layers.iter().enumerate() {
            // Attention sublayer.
            let h = rmsnorm(&x, &layer.attn_norm);
            let mut q = matvec(&h, &layer.wq, d, d);
            let mut k = matvec(&h, &layer.wk, d, d);
            let v = matvec(&h, &layer.wv, d, d);
            for head in 0..self.n_heads {
                rope(&mut q[head * dh..(head + 1) * dh], pos);
                rope(&mut k[head * dh..(head + 1) * dh], pos);
            }
            let mut attn = vec![0.0f32; d];
            for head in 0..self.n_heads {
                let base = self.kv_index(li, head, 0);
                let slot = self.kv_index(li, head, pos);
                kc[slot..slot + dh].copy_from_slice(&k[head * dh..(head + 1) * dh]);
                vc[slot..slot + dh].copy_from_slice(&v[head * dh..(head + 1) * dh]);
                let qh = &q[head * dh..(head + 1) * dh];
                let window = base..base + (pos + 1) * dh;
                attend(
                    qh,
                    &kc[window.clone()],
                    &vc[window],
                    &mut attn[head * dh..(head + 1) * dh],
                );
            }
            let proj = matvec(&attn, &layer.wo, d, d);
            for (xi, pi) in x.iter_mut().zip(&proj) {
                *xi += pi;
            }

            // SwiGLU MLP sublayer.
            let h = rmsnorm(&x, &layer.mlp_norm);
            let gate = matvec(&h, &layer.w1, d, self.hidden);
            let up = matvec(&h, &layer.w3, d, self.hidden);
            let inner: Vec<f32> =
                gate.iter().zip(&up).map(|(&g, &u)| silu(g) * u).collect();
            let down = matvec(&inner, &layer.w2, self.hidden, d);
            for (xi, di) in x.iter_mut().zip(&down) {
                *xi += di;
            }
        }

        let h = rmsnorm(&x, &self.final_norm);
        matvec(&h, &self.unembed, d, self.vocab)
    }

    pub(crate) fn max_seq(&self) -> usize {
        self.max_seq
    }

    /// Prefill: logits for every position + fresh max_seq-capacity caches.
    fn prefill(&self, ids: &[i32]) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut kc = vec![0.0f32; self.kv_len()];
        let mut vc = vec![0.0f32; self.kv_len()];
        let mut logits = Vec::with_capacity(ids.len() * self.vocab);
        for (pos, &id) in ids.iter().enumerate() {
            logits.extend(self.step(id, pos, &mut kc, &mut vc));
        }
        (logits, kc, vc)
    }
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

// ---------------------------------------------------------------------------
// Batched decode path (continuous-batching serving engine)
// ---------------------------------------------------------------------------

/// One sequence's slice of a batched decode tick.
///
/// `kc`/`vc` are the sequence's *gathered* `[L, H, max_seq, Dh]` working
/// sets (the paged-KV coordinator stages blocks into this layout, which is
/// exactly the artifact cache geometry minus the unit batch axis). The
/// step writes the new token's K/V at slot `pos` in place — no tensor
/// wrapping or cache cloning per token, unlike the `llm_decode` entry.
#[derive(Debug)]
pub struct DecodeSlot<'a> {
    /// Token to feed (the sequence's last emitted token).
    pub token: i32,
    /// Absolute position to write — must equal the context length.
    pub pos: usize,
    pub kc: &'a mut [f32],
    pub vc: &'a mut [f32],
}

/// Advance every slot by one token; returns one logits row per slot in
/// order. Numerically identical to running the `llm_decode` entry per
/// sequence (same `TinyLlm::step`), so batching can never perturb tokens.
pub(crate) fn decode_batch(model: &TinyLlm, slots: &mut [DecodeSlot<'_>]) -> Result<Vec<Vec<f32>>> {
    let mut out = Vec::with_capacity(slots.len());
    for (i, s) in slots.iter_mut().enumerate() {
        if s.kc.len() != model.kv_len() || s.vc.len() != model.kv_len() {
            return Err(Error::Runtime(format!(
                "decode_batch slot {i}: cache holds {} elements, model needs {}",
                s.kc.len(),
                model.kv_len()
            )));
        }
        if s.pos >= model.max_seq() {
            return Err(Error::Runtime(format!(
                "decode_batch slot {i}: position {} outside KV capacity {}",
                s.pos,
                model.max_seq()
            )));
        }
        out.push(model.step(s.token, s.pos, s.kc, s.vc));
    }
    Ok(out)
}

/// `softmax(q·Kᵀ / √dh) · V` over contiguous `[visible, dh]` key/value
/// slabs, accumulated into `out` (`out.len() == dh`). Shared by the
/// serving path and the standalone `attention` golden model so their
/// numerics cannot diverge. Two passes (max, exp/normalize) — exact and
/// fast enough for these tiny windows.
fn attend(qrow: &[f32], keys: &[f32], vals: &[f32], out: &mut [f32]) {
    let dh = qrow.len();
    let visible = keys.len() / dh;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut scores = Vec::with_capacity(visible);
    let mut mx = f32::NEG_INFINITY;
    for t in 0..visible {
        let s = dot(qrow, &keys[t * dh..(t + 1) * dh]) * scale;
        mx = mx.max(s);
        scores.push(s);
    }
    let mut denom = 0.0f32;
    for s in scores.iter_mut() {
        *s = (*s - mx).exp();
        denom += *s;
    }
    for (t, &p) in scores.iter().enumerate() {
        let w = p / denom;
        for (o, &vv) in out.iter_mut().zip(&vals[t * dh..(t + 1) * dh]) {
            *o += w * vv;
        }
    }
}

fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

/// `y[j] = Σ_i x[i] · w[i, j]` with `w` row-major `[rows, cols]`.
fn matvec(x: &[f32], w: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), rows);
    debug_assert_eq!(w.len(), rows * cols);
    let mut y = vec![0.0f32; cols];
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * cols..(i + 1) * cols];
        for (yj, &wij) in y.iter_mut().zip(row) {
            *yj += xi * wij;
        }
    }
    y
}

fn rmsnorm(x: &[f32], weight: &[f32]) -> Vec<f32> {
    let ms = x.iter().map(|&v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + NORM_EPS).sqrt();
    x.iter().zip(weight).map(|(&v, &w)| v * inv * w).collect()
}

/// Rotary embedding on one head vector (`model.py`'s rotate-half form).
fn rope(x: &mut [f32], pos: usize) {
    let half = x.len() / 2;
    for i in 0..half {
        let freq = 1.0 / ROPE_THETA.powf(i as f32 / half as f32);
        let ang = pos as f32 * freq;
        let (sin, cos) = ang.sin_cos();
        let (a, b) = (x[i], x[half + i]);
        x[i] = a * cos - b * sin;
        x[half + i] = a * sin + b * cos;
    }
}

// ---------------------------------------------------------------------------
// Entry dispatch
// ---------------------------------------------------------------------------

/// Execute one manifest entry. `args` have already been typechecked
/// against `entry` by the caller.
pub(crate) fn execute(
    model: &TinyLlm,
    name: &str,
    args: &[Tensor],
    entry: &EntrySpec,
) -> Result<Vec<Tensor>> {
    match name {
        "llm_prefill" => {
            let ids = args[0].as_i32()?;
            expect_rank(name, args, 0, 2)?;
            if args[0].shape()[0] != 1 {
                return Err(Error::Manifest(format!(
                    "llm_prefill: batch {} unsupported (simulated backend is batch-1)",
                    args[0].shape()[0]
                )));
            }
            let t = args[0].shape()[1];
            if t > model.max_seq {
                return Err(Error::Manifest(format!(
                    "llm_prefill: window {t} exceeds KV capacity {}",
                    model.max_seq
                )));
            }
            let (logits, kc, vc) = model.prefill(ids);
            let kv_shape =
                [model.n_layers, 1, model.n_heads, model.max_seq, model.head_dim];
            Ok(vec![
                Tensor::f32(logits, &[1, t, model.vocab])?,
                Tensor::f32(kc, &kv_shape)?,
                Tensor::f32(vc, &kv_shape)?,
            ])
        }
        "llm_decode" => {
            let id = args[0].as_i32()?[0];
            let mut kc = args[1].as_f32()?.to_vec();
            let mut vc = args[2].as_f32()?.to_vec();
            if kc.len() != model.kv_len() || vc.len() != model.kv_len() {
                return Err(Error::Manifest(format!(
                    "llm_decode: cache specs hold {} elements, model needs {}",
                    kc.len(),
                    model.kv_len()
                )));
            }
            let pos = args[3].as_i32()?[0];
            if pos < 0 || pos as usize >= model.max_seq {
                return Err(Error::Runtime(format!(
                    "decode position {pos} outside KV capacity {}",
                    model.max_seq
                )));
            }
            let logits = model.step(id, pos as usize, &mut kc, &mut vc);
            let kv_shape =
                [model.n_layers, 1, model.n_heads, model.max_seq, model.head_dim];
            Ok(vec![
                Tensor::f32(logits, &[1, model.vocab])?,
                Tensor::f32(kc, &kv_shape)?,
                Tensor::f32(vc, &kv_shape)?,
            ])
        }
        "attention" => attention(args),
        "gf2mm" => gf2mm(args),
        "vdecomp" => vdecomp(args, entry),
        "vdist3" => vdist3(args),
        "mcov" => mcov(args),
        "vfsmax" => vfsmax(args),
        "vmadot" => vmadot(args),
        "phong" => phong(args),
        "vrgb2yuv" => vrgb2yuv(args),
        "vmvar" => vmvar(args),
        other => Err(Error::Runtime(format!(
            "entry `{other}` has no simulated implementation"
        ))),
    }
}

/// Guard against manifests whose entry shapes deviate from the geometry
/// a simulated kernel implements: wrong ranks/inner dims become manifest
/// errors instead of index-out-of-bounds panics.
fn expect_rank(entry: &str, args: &[Tensor], idx: usize, rank: usize) -> Result<()> {
    if args[idx].shape().len() != rank {
        return Err(Error::Manifest(format!(
            "{entry}: arg {idx} must be rank {rank}, manifest declares shape {:?}",
            args[idx].shape()
        )));
    }
    Ok(())
}

/// Guard a fixed inner dimension (e.g. the `3` of `[N, 3]` point rows).
fn expect_dim(entry: &str, args: &[Tensor], idx: usize, dim: usize, want: usize) -> Result<()> {
    let shape = args[idx].shape();
    if shape.len() <= dim || shape[dim] != want {
        return Err(Error::Manifest(format!(
            "{entry}: arg {idx} dim {dim} must be {want}, manifest declares shape {shape:?}"
        )));
    }
    Ok(())
}

/// Causal multi-head attention, `[B, H, T, Dh]` → same shape (`ref.mha`).
fn attention(args: &[Tensor]) -> Result<Vec<Tensor>> {
    expect_rank("attention", args, 0, 4)?;
    let (q, k, v) = (args[0].as_f32()?, args[1].as_f32()?, args[2].as_f32()?);
    let shape = args[0].shape();
    if k.len() != q.len() || v.len() != q.len() {
        return Err(Error::Manifest(
            "attention: q/k/v entry specs disagree on element count".into(),
        ));
    }
    let (b, h, t, dh) = (shape[0], shape[1], shape[2], shape[3]);
    let mut out = vec![0.0f32; q.len()];
    for bh in 0..b * h {
        let base = bh * t * dh;
        for qi in 0..t {
            let qrow = &q[base + qi * dh..base + (qi + 1) * dh];
            let window = base..base + (qi + 1) * dh;
            attend(
                qrow,
                &k[window.clone()],
                &v[window],
                &mut out[base + qi * dh..base + (qi + 1) * dh],
            );
        }
    }
    Ok(vec![Tensor::f32(out, shape)?])
}

/// Matrix multiply over GF(2): `(a · b) & 1`.
fn gf2mm(args: &[Tensor]) -> Result<Vec<Tensor>> {
    expect_rank("gf2mm", args, 0, 2)?;
    expect_rank("gf2mm", args, 1, 2)?;
    let (a, b) = (args[0].as_i32()?, args[1].as_i32()?);
    let (m, k) = (args[0].shape()[0], args[0].shape()[1]);
    if args[1].shape()[0] != k {
        return Err(Error::Manifest(format!(
            "gf2mm: inner dims disagree ({k} vs {})",
            args[1].shape()[0]
        )));
    }
    let n = args[1].shape()[1];
    let mut out = vec![0i32; m * n];
    for r in 0..m {
        for kk in 0..k {
            let av = a[r * k + kk];
            if av == 0 {
                continue;
            }
            for c in 0..n {
                out[r * n + c] ^= av & b[kk * n + c] & 1;
            }
        }
    }
    Ok(vec![Tensor::i32(out, &[m, n])?])
}

/// Bitstream unpacking: packed little-endian 32-bit words → {0,1}.
fn vdecomp(args: &[Tensor], entry: &EntrySpec) -> Result<Vec<Tensor>> {
    let words = args[0].as_i32()?;
    let nbits = entry.outputs[0].numel();
    if nbits > words.len() * 32 {
        return Err(Error::Manifest(format!(
            "vdecomp: entry declares {nbits} output bits but only {} input words",
            words.len()
        )));
    }
    let bits: Vec<i32> = (0..nbits)
        .map(|i| (words[i / 32] >> (i % 32)) & 1)
        .collect();
    Ok(vec![Tensor::i32(bits, &entry.outputs[0].shape)?])
}

/// Squared Euclidean distance between 3-D point pairs: `[N,3]² → [N]`.
fn vdist3(args: &[Tensor]) -> Result<Vec<Tensor>> {
    expect_dim("vdist3", args, 0, 1, 3)?;
    expect_dim("vdist3", args, 1, 1, 3)?;
    let (p, q) = (args[0].as_f32()?, args[1].as_f32()?);
    let n = args[0].shape()[0].min(args[1].shape()[0]);
    let out: Vec<f32> = (0..n)
        .map(|i| {
            (0..3)
                .map(|d| {
                    let diff = p[i * 3 + d] - q[i * 3 + d];
                    diff * diff
                })
                .sum()
        })
        .collect();
    Ok(vec![Tensor::f32(out, &[n])?])
}

/// Cross-covariance of two centered point sets: `[N,3]² → [3,3]`.
fn mcov(args: &[Tensor]) -> Result<Vec<Tensor>> {
    expect_dim("mcov", args, 0, 1, 3)?;
    expect_dim("mcov", args, 1, 1, 3)?;
    let (p, q) = (args[0].as_f32()?, args[1].as_f32()?);
    let n = args[0].shape()[0].min(args[1].shape()[0]);
    let mut pm = [0.0f32; 3];
    let mut qm = [0.0f32; 3];
    for i in 0..n {
        for d in 0..3 {
            pm[d] += p[i * 3 + d];
            qm[d] += q[i * 3 + d];
        }
    }
    for d in 0..3 {
        pm[d] /= n as f32;
        qm[d] /= n as f32;
    }
    let mut cov = vec![0.0f32; 9];
    for i in 0..n {
        for r in 0..3 {
            for c in 0..3 {
                cov[r * 3 + c] += (p[i * 3 + r] - pm[r]) * (q[i * 3 + c] - qm[c]);
            }
        }
    }
    Ok(vec![Tensor::f32(cov, &[3, 3])?])
}

/// Max value + argmax of a float vector.
fn vfsmax(args: &[Tensor]) -> Result<Vec<Tensor>> {
    let x = args[0].as_f32()?;
    if x.is_empty() {
        return Err(Error::Manifest("vfsmax: entry declares an empty input".into()));
    }
    let mut best = 0usize;
    for (i, &v) in x.iter().enumerate() {
        if v > x[best] {
            best = i;
        }
    }
    Ok(vec![
        Tensor::f32(vec![x[best]], &[])?,
        Tensor::i32(vec![best as i32], &[])?,
    ])
}

/// Matrix–vector multiply: `[R,C] · [C] → [R]`.
fn vmadot(args: &[Tensor]) -> Result<Vec<Tensor>> {
    expect_rank("vmadot", args, 0, 2)?;
    expect_rank("vmadot", args, 1, 1)?;
    let (m, v) = (args[0].as_f32()?, args[1].as_f32()?);
    let (r, c) = (args[0].shape()[0], args[0].shape()[1]);
    if v.len() != c {
        return Err(Error::Manifest(format!(
            "vmadot: matrix has {c} columns but vector has {} elements",
            v.len()
        )));
    }
    let out: Vec<f32> = (0..r).map(|row| dot(&m[row * c..(row + 1) * c], v)).collect();
    Ok(vec![Tensor::f32(out, &[r])?])
}

/// Phong lighting per pixel over `[N,3]` unit vectors.
fn phong(args: &[Tensor]) -> Result<Vec<Tensor>> {
    for i in 0..3 {
        expect_dim("phong", args, i, 1, 3)?;
    }
    let (nrm, lgt, view) = (args[0].as_f32()?, args[1].as_f32()?, args[2].as_f32()?);
    let n = args.iter().map(|a| a.shape()[0]).min().unwrap_or(0);
    let out: Vec<f32> = (0..n)
        .map(|i| {
            let row = i * 3;
            let ndotl = dot(&nrm[row..row + 3], &lgt[row..row + 3]).max(0.0);
            let mut rdotv = 0.0f32;
            for d in 0..3 {
                let refl = 2.0 * ndotl * nrm[row + d] - lgt[row + d];
                rdotv += refl * view[row + d];
            }
            let rdotv = rdotv.max(0.0);
            let spec = if ndotl > 0.0 { rdotv.powi(SHININESS as i32) } else { 0.0 };
            KA as f32 + KD as f32 * ndotl + KS as f32 * spec
        })
        .collect();
    Ok(vec![Tensor::f32(out, &[n])?])
}

/// Color-space conversion `rgb · M'`, `[N,3] → [N,3]`.
fn vrgb2yuv(args: &[Tensor]) -> Result<Vec<Tensor>> {
    expect_dim("vrgb2yuv", args, 0, 1, 3)?;
    let rgb = args[0].as_f32()?;
    let n = args[0].shape()[0];
    let mut out = vec![0.0f32; n * 3];
    for i in 0..n {
        for (row, coeffs) in RGB2YUV.iter().enumerate() {
            out[i * 3 + row] = (0..3).map(|c| rgb[i * 3 + c] * coeffs[c] as f32).sum();
        }
    }
    Ok(vec![Tensor::f32(out, &[n, 3])?])
}

/// Row mean + variance: `[N,W] → ([N], [N])`.
fn vmvar(args: &[Tensor]) -> Result<Vec<Tensor>> {
    expect_rank("vmvar", args, 0, 2)?;
    let x = args[0].as_f32()?;
    let (n, w) = (args[0].shape()[0], args[0].shape()[1]);
    if w == 0 {
        return Err(Error::Manifest("vmvar: zero-width rows".into()));
    }
    let mut mean = vec![0.0f32; n];
    let mut var = vec![0.0f32; n];
    for r in 0..n {
        let row = &x[r * w..(r + 1) * w];
        let m = row.iter().sum::<f32>() / w as f32;
        let ex2 = row.iter().map(|&v| v * v).sum::<f32>() / w as f32;
        mean[r] = m;
        var[r] = ex2 - m * m;
    }
    Ok(vec![Tensor::f32(mean, &[n])?, Tensor::f32(var, &[n])?])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> TinyLlm {
        TinyLlm::new(&default_manifest().model)
    }

    #[test]
    fn default_manifest_lists_every_aot_entry() {
        let m = default_manifest();
        for name in [
            "attention", "gf2mm", "llm_decode", "llm_prefill", "mcov", "phong",
            "vdecomp", "vdist3", "vfsmax", "vmadot", "vmvar", "vrgb2yuv",
        ] {
            assert!(m.entries.contains_key(name), "missing {name}");
        }
        assert_eq!(m.model.prefill_len, 16);
        assert_eq!(m.model.max_seq, 64);
    }

    #[test]
    fn prefill_is_deterministic_and_finite() {
        let m = model();
        let (l1, k1, v1) = m.prefill(&[1, 2, 3, 4]);
        let (l2, k2, v2) = m.prefill(&[1, 2, 3, 4]);
        assert_eq!(l1, l2);
        assert_eq!(k1, k2);
        assert_eq!(v1, v2);
        assert!(l1.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn decode_continues_prefill_consistently() {
        // Teacher-forcing equivalence: prefill([a,b,c]) position-2 logits
        // must equal prefill([a,b]) followed by decode(c, pos=2).
        let m = model();
        let (full, _, _) = m.prefill(&[7, 8, 9]);
        let (_, mut kc, mut vc) = m.prefill(&[7, 8]);
        let step = m.step(9, 2, &mut kc, &mut vc);
        let want = &full[2 * m.vocab..3 * m.vocab];
        for (a, b) in step.iter().zip(want) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn padding_does_not_perturb_earlier_positions() {
        // Causality: logits at position i must not depend on later tokens
        // (the coordinator right-pads prompts relying on this).
        let m = model();
        let (a, _, _) = m.prefill(&[5, 6, 0, 0]);
        let (b, _, _) = m.prefill(&[5, 6, 9, 9]);
        assert_eq!(&a[..2 * m.vocab], &b[..2 * m.vocab]);
    }

    #[test]
    fn attention_matches_direct_softmax() {
        let mut rng = Rng::new(3);
        let (b, h, t, d) = (1usize, 2usize, 8usize, 4usize);
        let n = b * h * t * d;
        let q: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let shape = [b, h, t, d];
        let out = attention(&[
            Tensor::f32(q.clone(), &shape).unwrap(),
            Tensor::f32(k.clone(), &shape).unwrap(),
            Tensor::f32(v.clone(), &shape).unwrap(),
        ])
        .unwrap();
        let got = out[0].as_f32().unwrap();
        // Row 0 attends only itself: output == v row 0 per head.
        for head in 0..h {
            let base = head * t * d;
            for di in 0..d {
                assert!((got[base + di] - v[base + di]).abs() < 1e-5);
            }
        }
        assert!(got.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn gf2mm_identity() {
        let mut eye = vec![0i32; 16];
        for i in 0..4 {
            eye[i * 4 + i] = 1;
        }
        let a = vec![1, 0, 1, 1, 0, 1, 0, 1, 1, 1, 0, 0, 0, 0, 1, 1];
        let out = gf2mm(&[
            Tensor::i32(a.clone(), &[4, 4]).unwrap(),
            Tensor::i32(eye, &[4, 4]).unwrap(),
        ])
        .unwrap();
        assert_eq!(out[0].as_i32().unwrap(), a.as_slice());
    }

    #[test]
    fn vfsmax_scalar_outputs() {
        let out = vfsmax(&[Tensor::f32(vec![1.0, 9.0, 3.0], &[3]).unwrap()]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[9.0]);
        assert_eq!(out[1].as_i32().unwrap(), &[1]);
        assert_eq!(out[0].shape(), &[] as &[usize]);
    }

    #[test]
    fn phong_of_zero_vectors_is_ambient() {
        let z = Tensor::f32(vec![0.0; 6], &[2, 3]).unwrap();
        let out = phong(&[z.clone(), z.clone(), z]).unwrap();
        for &v in out[0].as_f32().unwrap() {
            assert!((v - KA as f32).abs() < 1e-6);
        }
    }
}
