//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. Records every AOT entry's argument/output shapes and the
//! serving model configuration so calls are typechecked before PJRT.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::runtime::tensor::{DType, Tensor};
use crate::util::json::Json;

/// Shape + dtype of one tensor argument or output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn new(shape: Vec<usize>, dtype: DType) -> Self {
        Self { shape, dtype }
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .get("shape")?
            .as_arr()?
            .iter()
            .map(Json::as_usize)
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(j.get("dtype")?.as_str()?)?;
        Ok(Self { shape, dtype })
    }
}

/// One AOT-lowered entry point.
#[derive(Debug, Clone)]
pub struct EntrySpec {
    /// File name within the artifact directory (e.g. `llm_decode.hlo.txt`).
    pub file: String,
    pub args: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl EntrySpec {
    /// Validate a call's tensors against this entry's signature.
    pub fn check_args(&self, name: &str, args: &[Tensor]) -> Result<()> {
        if args.len() != self.args.len() {
            return Err(Error::Runtime(format!(
                "entry `{name}`: expected {} args, got {}",
                self.args.len(),
                args.len()
            )));
        }
        for (i, (arg, spec)) in args.iter().zip(&self.args).enumerate() {
            if arg.dtype() != spec.dtype {
                return Err(Error::Runtime(format!(
                    "entry `{name}` arg {i}: dtype {:?} != manifest {:?}",
                    arg.dtype(),
                    spec.dtype
                )));
            }
            if arg.shape() != spec.shape.as_slice() {
                return Err(Error::Runtime(format!(
                    "entry `{name}` arg {i}: shape {:?} != manifest {:?}",
                    arg.shape(),
                    spec.shape
                )));
            }
        }
        Ok(())
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            file: j.get("file")?.as_str()?.to_string(),
            args: j
                .get("args")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<_>>()?,
            outputs: j
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<_>>()?,
        })
    }
}

/// Serving model configuration recorded by aot.py (tiny config for the
/// real PJRT run; the paper's 110M config is modelled by the cycle study).
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub vocab: usize,
    pub dim: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub hidden: usize,
    pub max_seq: usize,
    pub prefill_len: usize,
    pub batch: usize,
    pub param_count: u64,
}

impl ModelSpec {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            vocab: j.get("vocab")?.as_usize()?,
            dim: j.get("dim")?.as_usize()?,
            n_layers: j.get("n_layers")?.as_usize()?,
            n_heads: j.get("n_heads")?.as_usize()?,
            head_dim: j.get("head_dim")?.as_usize()?,
            hidden: j.get("hidden")?.as_usize()?,
            max_seq: j.get("max_seq")?.as_usize()?,
            prefill_len: j.get("prefill_len")?.as_usize()?,
            batch: j.get("batch")?.as_usize()?,
            param_count: j.get("param_count")?.as_u64()?,
        })
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelSpec,
    pub entries: BTreeMap<String, EntrySpec>,
}

impl Manifest {
    /// Load + parse the manifest file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            Error::Manifest(format!("cannot read {path:?}: {e}. Run `make artifacts` first."))
        })?;
        Self::parse(&text)
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let model = ModelSpec::from_json(j.get("model")?)?;
        let mut entries = BTreeMap::new();
        for (name, spec) in j.get("entries")?.as_obj()? {
            entries.insert(name.clone(), EntrySpec::from_json(spec)?);
        }
        Ok(Self { model, entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "model": {"vocab": 256, "dim": 64, "n_layers": 2, "n_heads": 4,
                "head_dim": 16, "hidden": 160, "max_seq": 64,
                "prefill_len": 16, "batch": 1, "param_count": 123456},
      "entries": {
        "gf2mm": {"file": "gf2mm.hlo.txt",
                   "args": [{"shape": [64, 64], "dtype": "int32"},
                            {"shape": [64, 64], "dtype": "int32"}],
                   "outputs": [{"shape": [64, 64], "dtype": "int32"}]}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.model.dim, 64);
        let e = &m.entries["gf2mm"];
        assert_eq!(e.args.len(), 2);
        assert_eq!(e.outputs[0].shape, vec![64, 64]);
        assert_eq!(e.outputs[0].dtype, DType::I32);
    }

    #[test]
    fn check_args_rejects_wrong_shape() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let e = &m.entries["gf2mm"];
        let bad = Tensor::i32(vec![0; 16], &[4, 4]).unwrap();
        let good = Tensor::i32(vec![0; 64 * 64], &[64, 64]).unwrap();
        assert!(e.check_args("gf2mm", &[bad, good.clone()]).is_err());
        assert!(e.check_args("gf2mm", &[good.clone(), good]).is_ok());
    }

    #[test]
    fn check_args_rejects_wrong_dtype() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let e = &m.entries["gf2mm"];
        let f = Tensor::f32(vec![0.0; 64 * 64], &[64, 64]).unwrap();
        let i = Tensor::i32(vec![0; 64 * 64], &[64, 64]).unwrap();
        assert!(e.check_args("gf2mm", &[f, i]).is_err());
    }
}
