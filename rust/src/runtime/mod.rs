//! Artifact runtime: load the AOT bundle (`artifacts/manifest.json`) and
//! execute its entries.
//!
//! The paper's pipeline executes HLO artifacts through PJRT; the PJRT
//! binding is not in the offline vendor set, so execution is backed by
//! [`sim`] — a pure-Rust implementation of every entry's golden model
//! (`python/compile/kernels/ref.py`), including a real tiny transformer
//! for the serving path. The manifest contract is unchanged: when
//! `artifacts/manifest.json` exists (produced by `make artifacts`) its
//! shapes drive typechecking; when it does not — a clean checkout, CI —
//! the runtime falls back to the built-in manifest mirroring
//! `python/compile/aot.py`'s entry catalogue, so `aquas serve` and the
//! runtime tests work with no Python step.
//!
//! Everything is deterministic: same entry + same inputs → bitwise-same
//! outputs, which is what the coordinator's greedy-decode tests rely on.

mod manifest;
mod sim;
mod tensor;

pub use manifest::{EntrySpec, Manifest, ModelSpec, TensorSpec};
pub use sim::DecodeSlot;
pub use tensor::{DType, Tensor};

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// An executor for the AOT artifact bundle.
///
/// Thread-safety: execution is pure (`&self`, no interior mutability), so
/// the coordinator's event loop can call [`Runtime::execute`] freely.
pub struct Runtime {
    manifest: Manifest,
    dir: PathBuf,
    model: sim::TinyLlm,
}

impl Runtime {
    /// Open the artifact directory: parse `manifest.json` if present,
    /// otherwise fall back to the built-in simulated manifest. The LLM
    /// weights are generated deterministically from the model config.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let manifest =
            if path.is_file() { Manifest::load(&path)? } else { sim::default_manifest() };
        let model = sim::TinyLlm::new(&manifest.model);
        Ok(Self { manifest, dir, model })
    }

    /// The artifact manifest (entry names, shapes, model config).
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execution platform name.
    pub fn platform(&self) -> String {
        "sim-cpu".to_string()
    }

    /// Validate that an entry exists (the PJRT backend compiled lazily
    /// here; the simulated backend only needs the manifest lookup).
    pub fn compile_entry(&self, name: &str) -> Result<()> {
        self.manifest
            .entries
            .get(name)
            .map(|_| ())
            .ok_or_else(|| Error::Manifest(format!("unknown entry `{name}`")))
    }

    /// Execute an entry with typed tensors; validates shapes/dtypes
    /// against the manifest before dispatch.
    pub fn execute(&self, name: &str, args: &[Tensor]) -> Result<Vec<Tensor>> {
        let spec = self
            .manifest
            .entries
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("unknown entry `{name}`")))?;
        spec.check_args(name, args)?;
        let outs = sim::execute(&self.model, name, args, spec)?;
        if outs.len() != spec.outputs.len() {
            return Err(Error::Runtime(format!(
                "entry `{name}`: expected {} outputs, got {}",
                spec.outputs.len(),
                outs.len()
            )));
        }
        Ok(outs)
    }

    /// Elements of one sequence's `[L, H, max_seq, Dh]` KV working set
    /// (the slice length [`Runtime::decode_batch`] expects per direction).
    pub fn kv_elems(&self) -> usize {
        let m = &self.manifest.model;
        m.n_layers * m.n_heads * m.max_seq * m.head_dim
    }

    /// Batched decode: advance N sequences one token each against their
    /// gathered KV working sets, in place. This is the serving engine's
    /// hot path — numerically identical to the `llm_decode` entry but
    /// without per-token tensor wrapping/cloning, and shaped for
    /// continuous batching (each slot carries its own position).
    pub fn decode_batch(&self, slots: &mut [DecodeSlot<'_>]) -> Result<Vec<Vec<f32>>> {
        sim::decode_batch(&self.model, slots)
    }

    /// Names of all available entries, sorted.
    pub fn entry_names(&self) -> Vec<String> {
        let mut v: Vec<_> = self.manifest.entries.keys().cloned().collect();
        v.sort();
        v
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("dir", &self.dir)
            .field("entries", &self.manifest.entries.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_without_artifacts_directory() {
        let rt = Runtime::load("definitely/not/a/real/dir").unwrap();
        assert!(rt.entry_names().iter().any(|n| n == "llm_prefill"));
        assert_eq!(rt.manifest().model.vocab, 256);
    }

    #[test]
    fn execute_typechecks_against_manifest() {
        let rt = Runtime::load("missing").unwrap();
        let bad = Tensor::i32(vec![0; 4], &[2, 2]).unwrap();
        assert!(rt.execute("gf2mm", &[bad.clone(), bad]).is_err());
        assert!(rt.execute("no_such_entry", &[]).is_err());
        assert!(rt.compile_entry("gf2mm").is_ok());
        assert!(rt.compile_entry("nope").is_err());
    }
}
