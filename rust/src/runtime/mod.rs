//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt`) and execute them.
//!
//! This is the only place the crate touches XLA. The interchange format is
//! HLO **text** (see `python/compile/aot.py`): jax ≥ 0.5 serializes
//! `HloModuleProto` with 64-bit instruction ids that xla_extension 0.5.1
//! rejects, while the text parser reassigns ids and round-trips cleanly.
//!
//! Everything is compiled once at startup ([`Runtime::load`]) or on first
//! use ([`Runtime::execute`] lazily compiles); the request path is pure
//! Rust + PJRT with no Python anywhere.

mod manifest;
mod tensor;

pub use manifest::{EntrySpec, Manifest, ModelSpec, TensorSpec};
pub use tensor::{DType, Tensor};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::error::{Error, Result};

/// A PJRT-backed executor for the AOT artifact bundle.
///
/// Thread-safety: the executable cache is guarded by a mutex; `execute`
/// takes `&self` and is safe to call from the coordinator's event loop.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    exes: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl Runtime {
    /// Open the artifact directory: parse `manifest.json`, create the PJRT
    /// CPU client. Executables compile lazily on first use.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, manifest, dir, exes: Mutex::new(HashMap::new()) })
    }

    /// The artifact manifest (entry names, shapes, model config).
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (always "cpu" on this image).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Eagerly compile one entry (otherwise compiled on first `execute`).
    pub fn compile_entry(&self, name: &str) -> Result<()> {
        let mut exes = self.exes.lock().expect("runtime mutex poisoned");
        if exes.contains_key(name) {
            return Ok(());
        }
        let spec = self
            .manifest
            .entries
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("unknown entry `{name}`")))?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Manifest(format!("non-utf8 path {path:?}")))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an entry with typed tensors; validates shapes/dtypes against
    /// the manifest and unwraps the output tuple.
    pub fn execute(&self, name: &str, args: &[Tensor]) -> Result<Vec<Tensor>> {
        let spec = self
            .manifest
            .entries
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("unknown entry `{name}`")))?;
        spec.check_args(name, args)?;
        self.compile_entry(name)?;

        let literals: Vec<xla::Literal> =
            args.iter().map(Tensor::to_literal).collect::<Result<_>>()?;
        let exes = self.exes.lock().expect("runtime mutex poisoned");
        let exe = exes.get(name).expect("compiled above");
        let result = exe.execute::<xla::Literal>(&literals)?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Xla(e.to_string()))?;
        drop(exes);
        // aot.py lowers everything with return_tuple=True.
        let parts = lit.to_tuple().map_err(|e| Error::Xla(e.to_string()))?;
        if parts.len() != spec.outputs.len() {
            return Err(Error::Runtime(format!(
                "entry `{name}`: expected {} outputs, got {}",
                spec.outputs.len(),
                parts.len()
            )));
        }
        parts
            .iter()
            .zip(&spec.outputs)
            .map(|(l, s)| Tensor::from_literal(l, s))
            .collect()
    }

    /// Names of all available entries, sorted.
    pub fn entry_names(&self) -> Vec<String> {
        let mut v: Vec<_> = self.manifest.entries.keys().cloned().collect();
        v.sort();
        v
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("dir", &self.dir)
            .field("entries", &self.manifest.entries.len())
            .finish()
    }
}
