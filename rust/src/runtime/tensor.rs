//! Typed host tensors: the runtime's argument/result currency.
//!
//! Only the dtypes the artifact bundle actually uses are supported (f32 and
//! i32); extending to more is mechanical.

use crate::error::{Error, Result};
use crate::runtime::manifest::TensorSpec;

/// Element dtype. Parsed from numpy names to match `aot.py`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    /// Parse the numpy dtype name used in the manifest.
    pub fn parse(name: &str) -> Result<Self> {
        match name {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => Err(Error::Manifest(format!("unsupported dtype `{other}`"))),
        }
    }

    /// The numpy dtype name.
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::I32 => "int32",
        }
    }
}

/// Raw storage for a [`Tensor`].
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A dense host tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: TensorData,
}

impl Tensor {
    /// Build an f32 tensor; checks element count against the shape.
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            return Err(Error::Runtime(format!(
                "tensor data len {} != shape {:?} ({n})",
                data.len(),
                shape
            )));
        }
        Ok(Self { shape: shape.to_vec(), data: TensorData::F32(data) })
    }

    /// Build an i32 tensor; checks element count against the shape.
    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            return Err(Error::Runtime(format!(
                "tensor data len {} != shape {:?} ({n})",
                data.len(),
                shape
            )));
        }
        Ok(Self { shape: shape.to_vec(), data: TensorData::I32(data) })
    }

    /// All-zeros tensor of the given spec.
    pub fn zeros(spec: &TensorSpec) -> Self {
        let n = spec.numel();
        let data = match spec.dtype {
            DType::F32 => TensorData::F32(vec![0.0; n]),
            DType::I32 => TensorData::I32(vec![0; n]),
        };
        Self { shape: spec.shape.clone(), data }
    }

    pub fn dtype(&self) -> DType {
        match &self.data {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Borrow as f32 slice (errors on dtype mismatch).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => Err(Error::Runtime("tensor is not f32".into())),
        }
    }

    /// Borrow as i32 slice (errors on dtype mismatch).
    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => Err(Error::Runtime("tensor is not i32".into())),
        }
    }

    /// Row-major linear index of a multi-dim coordinate.
    pub fn index(&self, coord: &[usize]) -> usize {
        debug_assert_eq!(coord.len(), self.shape.len());
        let mut idx = 0;
        for (c, d) in coord.iter().zip(&self.shape) {
            debug_assert!(c < d);
            idx = idx * d + c;
        }
        idx
    }

    /// Argmax over a flat f32 tensor (used for greedy decoding).
    pub fn argmax_f32(&self) -> Result<usize> {
        let v = self.as_f32()?;
        if v.is_empty() {
            return Err(Error::Runtime("argmax of empty tensor".into()));
        }
        let mut best = 0;
        for (i, &x) in v.iter().enumerate() {
            if x > v[best] {
                best = i;
            }
        }
        Ok(best)
    }
}
