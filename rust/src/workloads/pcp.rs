//! §6.3 — point-cloud processing: the ICP (Iterative Closest Point)
//! pipeline, accelerated by four ISAXs: `vdist3.vv` (squared Euclidean
//! distances), `mcov.vs` (cross-covariance), `vfsmax` (max + argmax) and
//! `vmadot` (matrix–vector product).
//!
//! This study runs with the widened 128-bit system bus
//! ([`InterfaceSet::rocket_wide_bus`]) to test whether the interface-aware
//! flow exploits the extra bandwidth. Point data is stored
//! structure-of-arrays-free: [N][3] f32 rows, with the "non-2ⁿ-length"
//! access pattern the paper calls out (3-element rows never align to
//! power-of-two transactions).

use crate::compiler::IsaxDef;
use crate::interface::cache::CacheHint;
use crate::interface::model::InterfaceSet;
use crate::ir::builder::FuncBuilder;
use crate::ir::interp::Memory;
use crate::ir::Func;
use crate::runtime::DType;
use crate::synthesis::SynthOptions;
use crate::util::rng::Rng;
use crate::workloads::Kernel;

/// Point count for the kernel studies.
pub const N: i64 = 32;
/// vmadot dims.
pub const MR: i64 = 16;
/// vmadot column count.
pub const MC: i64 = 16;

fn write_points(func: &Func, mem: &mut Memory, name: &str, seed: u64, n: i64) {
    let mut rng = Rng::new(seed);
    let pts: Vec<f32> = (0..n * 3).map(|_| rng.normal() as f32).collect();
    mem.write_f32(Kernel::buf(func, name), &pts);
}

// ---------------------------------------------------------------------------
// vdist3.vv — d[i] = ||p_i - q_i||²
// ---------------------------------------------------------------------------

fn build_vdist3(isax: bool) -> Func {
    let mut b = FuncBuilder::new(if isax { "vdist3" } else { "vdist3_sw" });
    let p = b.global("p", DType::F32, (N * 3) as usize, CacheHint::Warm);
    let q = b.global("q", DType::F32, (N * 3) as usize, CacheHint::Warm);
    let d = b.global("d", DType::F32, N as usize, CacheHint::Warm);
    let (sp, sq, sd) = if isax {
        (
            Some(b.scratchpad("s_p", DType::F32, (N * 3) as usize, 2)),
            Some(b.scratchpad("s_q", DType::F32, (N * 3) as usize, 2)),
            Some(b.scratchpad("s_d", DType::F32, N as usize, 1)),
        )
    } else {
        (None, None, None)
    };
    if isax {
        let zero = b.const_i(0);
        b.transfer(sp.unwrap(), zero, p, zero, (N * 3 * 4) as usize);
        b.transfer(sq.unwrap(), zero, q, zero, (N * 3 * 4) as usize);
    }
    b.for_range(0, N, 1, |b, i| {
        let three = b.const_i(3);
        let base = b.mul(i, three);
        let mut acc = b.const_f(0.0);
        for dim in 0..3 {
            let off = b.const_i(dim);
            let idx = b.add(base, off);
            let (pv, qv) = if isax {
                (b.read_smem(sp.unwrap(), idx), b.read_smem(sq.unwrap(), idx))
            } else {
                (b.load(p, idx), b.load(q, idx))
            };
            let diff = b.sub(pv, qv);
            let sq2 = b.mul(diff, diff);
            acc = b.add(acc, sq2);
        }
        if isax {
            b.write_smem(sd.unwrap(), i, acc);
        } else {
            b.store(d, i, acc);
        }
    });
    if isax {
        let zero = b.const_i(0);
        b.transfer(d, zero, sd.unwrap(), zero, (N * 4) as usize);
    }
    b.finish(&[])
}

fn init_vdist3(func: &Func, mem: &mut Memory) {
    write_points(func, mem, "p", 0xD157, N);
    write_points(func, mem, "q", 0xD158, N);
}

// ---------------------------------------------------------------------------
// mcov.vs — cov[3][3] += p_i q_iᵀ (inputs pre-centered by the host)
// ---------------------------------------------------------------------------

fn build_mcov(isax: bool) -> Func {
    let mut b = FuncBuilder::new(if isax { "mcov" } else { "mcov_sw" });
    let p = b.global("p", DType::F32, (N * 3) as usize, CacheHint::Warm);
    let q = b.global("q", DType::F32, (N * 3) as usize, CacheHint::Warm);
    let cov = b.global("cov", DType::F32, 9, CacheHint::Warm);
    let (sp, sq, sc) = if isax {
        (
            Some(b.scratchpad("s_p", DType::F32, (N * 3) as usize, 2)),
            Some(b.scratchpad("s_q", DType::F32, (N * 3) as usize, 2)),
            Some(b.scratchpad("s_c", DType::F32, 9, 1)),
        )
    } else {
        (None, None, None)
    };
    if isax {
        let zero = b.const_i(0);
        b.transfer(sp.unwrap(), zero, p, zero, (N * 3 * 4) as usize);
        b.transfer(sq.unwrap(), zero, q, zero, (N * 3 * 4) as usize);
    }
    b.for_range(0, N, 1, |b, i| {
        let three = b.const_i(3);
        let base = b.mul(i, three);
        b.for_range(0, 3, 1, |b, r| {
            b.for_range(0, 3, 1, |b, c| {
                let pr = b.add(base, r);
                let qc = b.add(base, c);
                let (pv, qv) = if isax {
                    (b.read_smem(sp.unwrap(), pr), b.read_smem(sq.unwrap(), qc))
                } else {
                    (b.load(p, pr), b.load(q, qc))
                };
                let prod = b.mul(pv, qv);
                let three2 = b.const_i(3);
                let rr = b.mul(r, three2);
                let cidx = b.add(rr, c);
                let old = if isax { b.read_smem(sc.unwrap(), cidx) } else { b.load(cov, cidx) };
                let acc = b.add(old, prod);
                if isax {
                    b.write_smem(sc.unwrap(), cidx, acc);
                } else {
                    b.store(cov, cidx, acc);
                }
            });
        });
    });
    if isax {
        let zero = b.const_i(0);
        b.transfer(cov, zero, sc.unwrap(), zero, 36);
    }
    b.finish(&[])
}

fn init_mcov(func: &Func, mem: &mut Memory) {
    write_points(func, mem, "p", 0xC0F1, N);
    write_points(func, mem, "q", 0xC0F2, N);
}

// ---------------------------------------------------------------------------
// vfsmax — running max + argmax kept in memory (ISAX-offloadable form)
// ---------------------------------------------------------------------------

fn build_vfsmax(isax: bool) -> Func {
    let mut b = FuncBuilder::new(if isax { "vfsmax" } else { "vfsmax_sw" });
    let x = b.global("x", DType::F32, N as usize, CacheHint::Warm);
    let mx = b.global("mx", DType::F32, 1, CacheHint::Warm);
    let am = b.global("am", DType::I32, 1, CacheHint::Warm);
    let sx = if isax {
        Some(b.scratchpad("s_x", DType::F32, N as usize, 2))
    } else {
        None
    };
    if isax {
        let zero = b.const_i(0);
        b.transfer(sx.unwrap(), zero, x, zero, (N * 4) as usize);
    }
    // mx[0] is pre-initialized by the host to x[0]; loop refines.
    b.for_range(0, N, 1, |b, i| {
        let v = if isax { b.read_smem(sx.unwrap(), i) } else { b.load(x, i) };
        let zero = b.const_i(0);
        let cur = b.load(mx, zero);
        let better = b.cmp(crate::ir::ops::CmpPred::Gt, v, cur);
        let newmax = b.select(better, v, cur);
        b.store(mx, zero, newmax);
        let curi = b.load(am, zero);
        let newi = b.select(better, i, curi);
        b.store(am, zero, newi);
    });
    b.finish(&[])
}

fn init_vfsmax(func: &Func, mem: &mut Memory) {
    let mut rng = Rng::new(0xF5);
    let xs: Vec<f32> = (0..N).map(|_| rng.normal() as f32).collect();
    mem.write_f32(Kernel::buf(func, "mx"), &[xs[0]]);
    mem.write_f32(Kernel::buf(func, "x"), &xs);
}

// ---------------------------------------------------------------------------
// vmadot — y = M·v
// ---------------------------------------------------------------------------

fn build_vmadot(isax: bool) -> Func {
    let mut b = FuncBuilder::new(if isax { "vmadot" } else { "vmadot_sw" });
    let m = b.global("m", DType::F32, (MR * MC) as usize, CacheHint::Warm);
    let v = b.global("v", DType::F32, MC as usize, CacheHint::Warm);
    let y = b.global("y", DType::F32, MR as usize, CacheHint::Warm);
    let (sm, sv, sy) = if isax {
        (
            Some(b.scratchpad("s_m", DType::F32, (MR * MC) as usize, 2)),
            Some(b.scratchpad("s_v", DType::F32, MC as usize, 1)),
            Some(b.scratchpad("s_y", DType::F32, MR as usize, 1)),
        )
    } else {
        (None, None, None)
    };
    if isax {
        let zero = b.const_i(0);
        b.transfer(sm.unwrap(), zero, m, zero, (MR * MC * 4) as usize);
        b.transfer(sv.unwrap(), zero, v, zero, (MC * 4) as usize);
    }
    b.for_range(0, MR, 1, |b, r| {
        b.for_range(0, MC, 1, |b, c| {
            let cc = b.const_i(MC);
            let rb = b.mul(r, cc);
            let midx = b.add(rb, c);
            let (mv, vv) = if isax {
                (b.read_smem(sm.unwrap(), midx), b.read_smem(sv.unwrap(), c))
            } else {
                (b.load(m, midx), b.load(v, c))
            };
            let prod = b.mul(mv, vv);
            let old = if isax { b.read_smem(sy.unwrap(), r) } else { b.load(y, r) };
            let acc = b.add(old, prod);
            if isax {
                b.write_smem(sy.unwrap(), r, acc);
            } else {
                b.store(y, r, acc);
            }
        });
    });
    if isax {
        let zero = b.const_i(0);
        b.transfer(y, zero, sy.unwrap(), zero, (MR * 4) as usize);
    }
    b.finish(&[])
}

fn init_vmadot(func: &Func, mem: &mut Memory) {
    let mut rng = Rng::new(0x3AD0);
    let m: Vec<f32> = (0..MR * MC).map(|_| rng.normal() as f32).collect();
    let v: Vec<f32> = (0..MC).map(|_| rng.normal() as f32).collect();
    mem.write_f32(Kernel::buf(func, "m"), &m);
    mem.write_f32(Kernel::buf(func, "v"), &v);
}

// ---------------------------------------------------------------------------

/// The four PCP kernels with Table-3 variants (wide 128-bit bus).
pub fn kernels() -> Vec<Kernel> {
    use crate::compiler::loop_passes::{apply, LoopPass};
    use crate::compiler::matcher::top_loops;

    let itfcs = InterfaceSet::rocket_wide_bus;

    let sw_vdist = build_vdist3(false);
    let vdist_tiled =
        apply(&sw_vdist, top_loops(&sw_vdist)[0], LoopPass::Tile(8)).expect("tile vdist3");
    let sw_mcov = build_mcov(false);
    let mcov_tiled =
        apply(&sw_mcov, top_loops(&sw_mcov)[0], LoopPass::Tile(4)).expect("tile mcov");
    let sw_vfsmax = build_vfsmax(false);
    let vfsmax_unrolled =
        apply(&sw_vfsmax, top_loops(&sw_vfsmax)[0], LoopPass::Unroll(2)).expect("unroll vfsmax");
    let sw_vmadot = build_vmadot(false);
    let vmadot_tiled =
        apply(&sw_vmadot, top_loops(&sw_vmadot)[0], LoopPass::Tile(4)).expect("tile vmadot");

    vec![
        Kernel {
            name: "vdist3.vv",
            software: sw_vdist,
            variants: vec![("Tiling(8)".into(), vdist_tiled)],
            isax: IsaxDef { name: "vdist3".into(), func: build_vdist3(true) },
            init: init_vdist3,
            outputs: vec!["d"],
            vector_profile: None,
            synth_opts: SynthOptions::default(),
            itfcs: itfcs(),
        },
        Kernel {
            name: "mcov.vs",
            software: sw_mcov,
            variants: vec![("Tiling(4)".into(), mcov_tiled)],
            isax: IsaxDef { name: "mcov".into(), func: build_mcov(true) },
            init: init_mcov,
            outputs: vec!["cov"],
            vector_profile: None,
            synth_opts: SynthOptions::default(),
            itfcs: itfcs(),
        },
        Kernel {
            name: "vfsmax",
            software: sw_vfsmax,
            variants: vec![("Unroll(2)".into(), vfsmax_unrolled)],
            isax: IsaxDef { name: "vfsmax".into(), func: build_vfsmax(true) },
            init: init_vfsmax,
            outputs: vec!["mx", "am"],
            vector_profile: None,
            synth_opts: SynthOptions::default(),
            itfcs: itfcs(),
        },
        Kernel {
            name: "vmadot",
            software: sw_vmadot,
            variants: vec![("Tiling(4)+Unroll".into(), vmadot_tiled)],
            isax: IsaxDef { name: "vmadot".into(), func: build_vmadot(true) },
            init: init_vmadot,
            outputs: vec!["y"],
            vector_profile: None,
            synth_opts: SynthOptions::default(),
            itfcs: itfcs(),
        },
    ]
}

/// The end-to-end PCP workload: one ICP-style iteration — distances,
/// best-match search, covariance, and a matrix–vector product — as one
/// program with four offloadable loops.
pub fn end_to_end_software() -> Func {
    let mut b = FuncBuilder::new("pcp_e2e");
    let p = b.global("p", DType::F32, (N * 3) as usize, CacheHint::Warm);
    let q = b.global("q", DType::F32, (N * 3) as usize, CacheHint::Warm);
    let d = b.global("d", DType::F32, N as usize, CacheHint::Warm);
    let mx = b.global("mx", DType::F32, 1, CacheHint::Warm);
    let am = b.global("am", DType::I32, 1, CacheHint::Warm);
    let cov = b.global("cov", DType::F32, 9, CacheHint::Warm);
    let m = b.global("m", DType::F32, (MR * MC) as usize, CacheHint::Warm);
    let v = b.global("v", DType::F32, MC as usize, CacheHint::Warm);
    let y = b.global("y", DType::F32, MR as usize, CacheHint::Warm);

    // vdist3
    b.for_range(0, N, 1, |b, i| {
        let three = b.const_i(3);
        let base = b.mul(i, three);
        let mut acc = b.const_f(0.0);
        for dim in 0..3 {
            let off = b.const_i(dim);
            let idx = b.add(base, off);
            let pv = b.load(p, idx);
            let qv = b.load(q, idx);
            let diff = b.sub(pv, qv);
            let sq = b.mul(diff, diff);
            acc = b.add(acc, sq);
        }
        b.store(d, i, acc);
    });
    // vfsmax over the distances
    b.for_range(0, N, 1, |b, i| {
        let val = b.load(d, i);
        let zero = b.const_i(0);
        let cur = b.load(mx, zero);
        let better = b.cmp(crate::ir::ops::CmpPred::Gt, val, cur);
        let newmax = b.select(better, val, cur);
        b.store(mx, zero, newmax);
        let curi = b.load(am, zero);
        let newi = b.select(better, i, curi);
        b.store(am, zero, newi);
    });
    // mcov
    b.for_range(0, N, 1, |b, i| {
        let three = b.const_i(3);
        let base = b.mul(i, three);
        b.for_range(0, 3, 1, |b, r| {
            b.for_range(0, 3, 1, |b, c| {
                let pr = b.add(base, r);
                let qc = b.add(base, c);
                let pv = b.load(p, pr);
                let qv = b.load(q, qc);
                let prod = b.mul(pv, qv);
                let three2 = b.const_i(3);
                let rr = b.mul(r, three2);
                let cidx = b.add(rr, c);
                let old = b.load(cov, cidx);
                let acc = b.add(old, prod);
                b.store(cov, cidx, acc);
            });
        });
    });
    // vmadot
    b.for_range(0, MR, 1, |b, r| {
        b.for_range(0, MC, 1, |b, c| {
            let cc = b.const_i(MC);
            let rb = b.mul(r, cc);
            let midx = b.add(rb, c);
            let mv = b.load(m, midx);
            let vv = b.load(v, c);
            let prod = b.mul(mv, vv);
            let old = b.load(y, r);
            let acc = b.add(old, prod);
            b.store(y, r, acc);
        });
    });
    b.finish(&[])
}

/// Initialize the e2e memory image.
pub fn init_end_to_end(func: &Func, mem: &mut Memory) {
    write_points(func, mem, "p", 0xE2E1, N);
    write_points(func, mem, "q", 0xE2E2, N);
    let mut rng = Rng::new(0xE2E3);
    let m: Vec<f32> = (0..MR * MC).map(|_| rng.normal() as f32).collect();
    let v: Vec<f32> = (0..MC).map(|_| rng.normal() as f32).collect();
    mem.write_f32(Kernel::buf(func, "m"), &m);
    mem.write_f32(Kernel::buf(func, "v"), &v);
    mem.write_f32(Kernel::buf(func, "mx"), &[-1e30]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};

    #[test]
    fn vdist3_computes_squared_distances() {
        let f = build_vdist3(false);
        let mut mem = Memory::for_func(&f);
        init_vdist3(&f, &mut mem);
        let p = mem.read_f32(Kernel::buf(&f, "p"));
        let q = mem.read_f32(Kernel::buf(&f, "q"));
        crate::ir::interp::run(&f, &[], &mut mem).unwrap();
        let d = mem.read_f32(Kernel::buf(&f, "d"));
        for i in 0..N as usize {
            let want: f32 = (0..3)
                .map(|k| {
                    let diff = p[i * 3 + k] - q[i * 3 + k];
                    diff * diff
                })
                .sum();
            assert!((d[i] - want).abs() < 1e-4, "i={i}: {} vs {want}", d[i]);
        }
    }

    #[test]
    fn vfsmax_finds_max_and_argmax() {
        let f = build_vfsmax(false);
        let mut mem = Memory::for_func(&f);
        init_vfsmax(&f, &mut mem);
        let xs = mem.read_f32(Kernel::buf(&f, "x"));
        crate::ir::interp::run(&f, &[], &mut mem).unwrap();
        let mx = mem.read_f32(Kernel::buf(&f, "mx"))[0];
        let am = mem.read_i32(Kernel::buf(&f, "am"))[0] as usize;
        let want = xs.iter().cloned().fold(f32::MIN, f32::max);
        assert!((mx - want).abs() < 1e-6);
        assert!((xs[am] - want).abs() < 1e-6);
    }

    #[test]
    fn all_pcp_kernels_match_their_isax() {
        for k in kernels() {
            let r = compile(&k.software, &[k.isax.clone()], &CompileOptions::default())
                .unwrap_or_else(|e| panic!("{}: {e}", k.name));
            assert_eq!(
                r.stats.matched,
                vec![k.isax.name.clone()],
                "{}: {:?}",
                k.name,
                r.stats
            );
        }
    }

    #[test]
    fn e2e_offloads_all_four_isaxes() {
        let sw = end_to_end_software();
        let isaxes: Vec<_> = kernels().iter().map(|k| k.isax.clone()).collect();
        let r = compile(&sw, &isaxes, &CompileOptions::default()).unwrap();
        for name in ["vdist3", "vfsmax", "mcov", "vmadot"] {
            assert!(
                r.stats.matched.iter().any(|m| m == name),
                "{name} not offloaded: {:?}",
                r.stats
            );
        }
    }

    #[test]
    fn all_pcp_variants_match() {
        for k in kernels() {
            for (desc, variant) in &k.variants {
                let r = compile(variant, &[k.isax.clone()], &CompileOptions::default())
                    .unwrap_or_else(|e| panic!("{} {desc}: {e}", k.name));
                assert_eq!(
                    r.stats.matched,
                    vec![k.isax.name.clone()],
                    "{} variant {desc}: {:?}",
                    k.name,
                    r.stats
                );
            }
        }
    }
}
