//! §6 case studies: PQC, point-cloud processing, graphics rendering, and
//! CPU LLM inference.
//!
//! Each kernel bundles (a) the canonical *software* implementation, (b)
//! deliberately divergent software variants (the robustness attacks of
//! Table 3: tiling, unrolling, representation changes, redundancy), (c)
//! the *ISAX description* at the functional Aquas-IR level, (d) data
//! initialization + the output buffer to check, and (e) a vector profile
//! for the Saturn comparison where applicable.
//!
//! Everything is deterministic (seeded [`crate::util::rng::Rng`]) so
//! benches reproduce run-to-run.
#![warn(missing_docs)]

pub mod graphics;
pub mod llm;
pub mod pcp;
pub mod pqc;

use crate::compiler::IsaxDef;
use crate::cores::saturn::VectorProfile;
use crate::interface::model::InterfaceSet;
use crate::ir::func::BufferId;
use crate::ir::interp::Memory;
use crate::ir::Func;
use crate::synthesis::SynthOptions;

/// A complete case-study kernel.
pub struct Kernel {
    /// Kernel name, as used in bench tables and error messages.
    pub name: &'static str,
    /// Canonical software implementation.
    pub software: Func,
    /// Divergent variants: (description, function). All must still match.
    pub variants: Vec<(String, Func)>,
    /// The ISAX description consumed by synthesis + the compiler.
    pub isax: IsaxDef,
    /// Memory initializer (applies to software and aligned-ISAX layouts,
    /// which share buffer order by construction).
    pub init: fn(&Func, &mut Memory),
    /// Buffers (by name) holding the kernel's outputs.
    pub outputs: Vec<&'static str>,
    /// Saturn mapping, when the kernel is vectorizable.
    pub vector_profile: Option<VectorProfile>,
    /// Synthesis knobs (body-cycle weight for elision etc.).
    pub synth_opts: SynthOptions,
    /// Interface configuration for this study.
    pub itfcs: InterfaceSet,
}

impl Kernel {
    /// Find a buffer id by name in a function (panics if missing —
    /// kernels own their naming).
    pub fn buf(func: &Func, name: &str) -> BufferId {
        func.buffer_by_name(name)
            .unwrap_or_else(|| panic!("kernel buffer `{name}` missing in {}", func.name))
    }

    /// Run the software version and return the named output contents
    /// (f32 lossy for i32 buffers — fine for equality on small ints).
    pub fn run_software(&self) -> crate::error::Result<Vec<Vec<f32>>> {
        let mut mem = Memory::for_func(&self.software);
        (self.init)(&self.software, &mut mem);
        crate::ir::interp::run(&self.software, &[], &mut mem)?;
        Ok(self
            .outputs
            .iter()
            .map(|n| mem.read_f32(Self::buf(&self.software, n)))
            .collect())
    }

    /// Run the ISAX description (functional level) and return outputs.
    pub fn run_isax(&self) -> crate::error::Result<Vec<Vec<f32>>> {
        let mut mem = Memory::for_func(&self.isax.func);
        (self.init)(&self.isax.func, &mut mem);
        crate::ir::interp::run(&self.isax.func, &[], &mut mem)?;
        Ok(self
            .outputs
            .iter()
            .map(|n| mem.read_f32(Self::buf(&self.isax.func, n)))
            .collect())
    }
}

/// All Table 2 kernels (PQC + PCP).
pub fn table2_kernels() -> Vec<Kernel> {
    let mut v = pqc::kernels();
    v.extend(pcp::kernels());
    v
}

/// All Figure 7 kernels (graphics).
pub fn graphics_kernels() -> Vec<Kernel> {
    graphics::kernels()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_kernel_software_matches_isax_semantics() {
        for k in table2_kernels().into_iter().chain(graphics_kernels()) {
            let sw = k.run_software().unwrap_or_else(|e| panic!("{}: sw {e}", k.name));
            let hw = k.run_isax().unwrap_or_else(|e| panic!("{}: isax {e}", k.name));
            assert_eq!(sw.len(), hw.len(), "{}", k.name);
            for (a, b) in sw.iter().zip(&hw) {
                assert_eq!(a.len(), b.len(), "{}", k.name);
                for (x, y) in a.iter().zip(b) {
                    assert!(
                        (x - y).abs() <= 1e-4 * x.abs().max(1.0),
                        "{}: {x} != {y}",
                        k.name
                    );
                }
            }
        }
    }

    #[test]
    fn every_kernel_verifies() {
        for k in table2_kernels().into_iter().chain(graphics_kernels()) {
            crate::ir::verifier::verify(&k.software)
                .unwrap_or_else(|e| panic!("{} software: {e}", k.name));
            crate::ir::verifier::verify(&k.isax.func)
                .unwrap_or_else(|e| panic!("{} isax: {e}", k.name));
            for (d, v) in &k.variants {
                crate::ir::verifier::verify(v)
                    .unwrap_or_else(|e| panic!("{} variant {d}: {e}", k.name));
            }
        }
    }
}
