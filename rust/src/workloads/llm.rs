//! §6.5 — CPU LLM inference: the attention-acceleration study.
//!
//! The paper prototypes Aquas on a Zynq XC7Z045 (both cores at 80 MHz,
//! 1 GB DDR3) running Llama-2-110M int8 and reports TTFT / ITL speedups
//! plus the SoC resource breakdown. This module provides the *cycle-level
//! model* of that study: analytic per-token cycles for (a) the scalar base
//! core and (b) the Aquas attention ISAX whose memory path follows the
//! §4.1 interface model. The *numeric* attention path runs for real
//! through the PJRT artifacts (see [`crate::coordinator`] and
//! `examples/llm_serve.rs`).

use crate::area::{FpgaModel, FpgaUsage};
use crate::interface::cache::CacheHint;
use crate::interface::dmasim;
use crate::interface::latency::{sequence_latency, TransactionKind};
use crate::interface::model::{InterfaceId, InterfaceSet, MemInterface};
use crate::ir::{Func, FuncBuilder};
use crate::runtime::DType;
use crate::synthesis::hwgen::{FuCount, MemEngineDesc, PipelineDesc, SramDesc, StageDesc};

/// Llama-2-110M-class architecture (matches `python/compile/model.py`'s
/// PAPER_CONFIG scaled to the paper's quoted 110M).
#[derive(Debug, Clone, Copy)]
pub struct LlmConfig {
    /// Model (embedding) dimension.
    pub dim: usize,
    /// Transformer layer count.
    pub n_layers: usize,
    /// Attention heads per layer.
    pub n_heads: usize,
    /// MLP hidden dimension.
    pub hidden: usize,
    /// Vocabulary size (drives the LM-head GEMV term).
    pub vocab: usize,
    /// Prompt length used for TTFT.
    pub prompt_len: usize,
    /// Bytes per weight (int8 quantization).
    pub weight_bytes: usize,
    /// SoC clock (both cores), Hz.
    pub clock_hz: f64,
}

impl Default for LlmConfig {
    fn default() -> Self {
        Self {
            dim: 768,
            n_layers: 12,
            n_heads: 12,
            hidden: 2048,
            vocab: 32000,
            prompt_len: 64,
            weight_bytes: 1,
            clock_hz: 80e6,
        }
    }
}

impl LlmConfig {
    /// Per-head dimension (`dim / n_heads`).
    pub fn head_dim(&self) -> usize {
        self.dim / self.n_heads
    }

    /// MACs in one attention block for one query token against `ctx` keys.
    pub fn attn_macs_per_token(&self, ctx: usize) -> u64 {
        // QKV projections + output projection + QK^T + PV.
        let proj = 4 * self.dim * self.dim;
        let scores = 2 * self.dim * ctx;
        (proj + scores) as u64
    }

    /// MACs in one MLP block per token.
    pub fn mlp_macs_per_token(&self) -> u64 {
        (3 * self.dim * self.hidden) as u64
    }

    /// Bytes of weights touched per token (decode streams all weights).
    pub fn weight_bytes_per_token(&self) -> u64 {
        let per_layer = 4 * self.dim * self.dim + 3 * self.dim * self.hidden;
        (self.n_layers * per_layer * self.weight_bytes + self.vocab * self.dim * self.weight_bytes)
            as u64
    }

    /// KV-cache bytes touched for one decode step at context length `ctx`.
    pub fn kv_bytes(&self, ctx: usize) -> u64 {
        (2 * self.n_layers * ctx * self.dim * self.weight_bytes) as u64
    }
}

/// Cycle model for the scalar base core (in-order, one MAC per ~4 cycles
/// — int8 multiply + accumulate + address math + load on a single-issue
/// pipeline with a 32-bit DDR3 front end).
#[derive(Debug, Clone, Copy)]
pub struct BaseCpuModel {
    /// Amortized cycles per int8 MAC on the scalar pipeline.
    pub cycles_per_mac: f64,
    /// Sustainable DRAM bytes/cycle through the cached 32-bit port.
    pub mem_bytes_per_cycle: f64,
}

impl Default for BaseCpuModel {
    fn default() -> Self {
        Self { cycles_per_mac: 1.25, mem_bytes_per_cycle: 1.6 }
    }
}

impl BaseCpuModel {
    /// Cycles for one token: compute-bound term vs weight-streaming term.
    pub fn token_cycles(&self, cfg: &LlmConfig, ctx: usize) -> f64 {
        let macs = cfg.n_layers as u64
            * (cfg.attn_macs_per_token(ctx) + cfg.mlp_macs_per_token())
            + (cfg.vocab * cfg.dim) as u64;
        let compute = macs as f64 * self.cycles_per_mac;
        let mem = (cfg.weight_bytes_per_token() + cfg.kv_bytes(ctx)) as f64
            / self.mem_bytes_per_cycle;
        compute.max(mem)
    }
}

/// Cycle model for the Aquas attention/GEMM ISAX: a 16-MAC int8 systolic
/// row fed by burst transfers over the 64-bit bus, with BRAM scratchpads
/// double-buffering tiles (the paper's "highly parallelized datapath" +
/// "highly efficient memory accesses").
#[derive(Debug, Clone, Copy)]
pub struct IsaxLlmModel {
    /// Sustained int8 MACs/cycle of a lone GEMV stream on the MAC row.
    pub macs_per_cycle: f64,
    /// Tile size staged per burst run (bytes).
    pub tile_bytes: usize,
}

impl Default for IsaxLlmModel {
    fn default() -> Self {
        Self { macs_per_cycle: 16.0, tile_bytes: 4096 }
    }
}

impl IsaxLlmModel {
    /// Effective DRAM bytes/cycle achieved by the bus engine for big
    /// bursts (from the §4.1 recurrences, not a free parameter).
    pub fn mem_bytes_per_cycle(&self, bus: &MemInterface) -> f64 {
        let n_txn = self.tile_bytes / bus.max_transaction();
        let sizes = vec![bus.max_transaction(); n_txn.max(1)];
        let cycles = sequence_latency(bus, TransactionKind::Load, &sizes);
        self.tile_bytes as f64 / cycles as f64
    }

    /// Cycles for one token with the attention+GEMM work offloaded.
    pub fn token_cycles(&self, cfg: &LlmConfig, ctx: usize, bus: &MemInterface) -> f64 {
        let macs = cfg.n_layers as u64
            * (cfg.attn_macs_per_token(ctx) + cfg.mlp_macs_per_token())
            + (cfg.vocab * cfg.dim) as u64;
        let compute = macs as f64 / self.macs_per_cycle;
        let mem = (cfg.weight_bytes_per_token() + cfg.kv_bytes(ctx)) as f64
            / self.mem_bytes_per_cycle(bus);
        // Double-buffered tiles overlap compute and memory; the slower
        // stream dominates with a small pipeline fill overhead.
        compute.max(mem) * 1.05
    }

    /// Sustained MACs/cycle when `n` token streams share one staged
    /// weight tile. The datapath is a 64-lane int8 MAC row of which a
    /// lone GEMV stream keeps ~16 lanes busy (`macs_per_cycle`);
    /// weight-stationary reuse across concurrent tokens turns the work
    /// into a skinny GEMM and fills the row, saturating at 4 streams.
    pub fn batch_macs_per_cycle(&self, n: usize) -> f64 {
        self.macs_per_cycle * n.clamp(1, 4) as f64
    }

    /// Cycles for one *batched* tick advancing sequences at context
    /// lengths `ctxs` by one token each. The weight stream is charged
    /// once for the whole batch (the amortization that single-stream
    /// serving cannot exploit); per-sequence KV traffic still scales with
    /// the batch. `batch_tick_cycles(cfg, &[ctx], bus)` equals
    /// [`IsaxLlmModel::token_cycles`] exactly, so a batch-1 engine *is*
    /// the single-stream baseline.
    pub fn batch_tick_cycles(&self, cfg: &LlmConfig, ctxs: &[usize], bus: &MemInterface) -> f64 {
        let (compute, mem) = self.batch_tick_parts(cfg, ctxs, bus);
        compute.max(mem) * 1.05
    }

    /// The `(compute, mem)` cycle demands of one batched tick *before*
    /// the double-buffering max and pipeline-fill factor are applied:
    /// `batch_tick_cycles == compute.max(mem) * 1.05` exactly. Exposed so
    /// the multi-core SoC layer can re-price the memory leg under shared-
    /// DDR contention (see [`IsaxLlmModel::shared_stream_slowdown`])
    /// without duplicating the demand model.
    pub fn batch_tick_parts(
        &self,
        cfg: &LlmConfig,
        ctxs: &[usize],
        bus: &MemInterface,
    ) -> (f64, f64) {
        if ctxs.is_empty() {
            return (0.0, 0.0);
        }
        let per_token_fixed = (cfg.vocab * cfg.dim) as u64;
        let macs: u64 = ctxs
            .iter()
            .map(|&c| {
                cfg.n_layers as u64 * (cfg.attn_macs_per_token(c) + cfg.mlp_macs_per_token())
                    + per_token_fixed
            })
            .sum();
        let compute = macs as f64 / self.batch_macs_per_cycle(ctxs.len());
        let kv: u64 = ctxs.iter().map(|&c| cfg.kv_bytes(c)).sum();
        let mem = (cfg.weight_bytes_per_token() + kv) as f64 / self.mem_bytes_per_cycle(bus);
        (compute, mem)
    }

    /// Cycles for one tiled prefill pass over a `prompt_len`-token
    /// prompt: all positions share one weight stream (prefill is a GEMM),
    /// each position pays its causal attention + KV traffic.
    pub fn prefill_cycles(&self, cfg: &LlmConfig, prompt_len: usize, bus: &MemInterface) -> f64 {
        let (compute, mem) = self.prefill_parts(cfg, prompt_len, bus);
        compute.max(mem) * 1.05
    }

    /// The `(compute, mem)` demands of one tiled prefill pass, split like
    /// [`IsaxLlmModel::batch_tick_parts`] (`prefill_cycles ==
    /// compute.max(mem) * 1.05` exactly).
    pub fn prefill_parts(
        &self,
        cfg: &LlmConfig,
        prompt_len: usize,
        bus: &MemInterface,
    ) -> (f64, f64) {
        let ctxs: Vec<usize> = (1..=prompt_len).collect();
        self.batch_tick_parts(cfg, &ctxs, bus)
    }

    /// DMA cycles to stage one paged KV block (K *and* V, every layer)
    /// through `bus`: each `(layer, direction)` slab of `block_slots`
    /// positions is one contiguous burst run, decomposed into legal
    /// transactions per §4.1 and priced by the exact latency recurrence.
    pub fn kv_block_dma_cycles(
        &self,
        cfg: &LlmConfig,
        bus: &MemInterface,
        block_slots: usize,
    ) -> f64 {
        let slab_bytes = block_slots * cfg.dim * cfg.weight_bytes;
        let burst =
            sequence_latency(bus, TransactionKind::Load, &bus.decompose(0, slab_bytes)) as f64;
        burst * (2 * cfg.n_layers) as f64
    }

    /// DMA cycles to stage `n_blocks` paged KV blocks back-to-back
    /// through `bus`, priced by the event-driven burst engine
    /// ([`crate::interface::dmasim`]) instead of per-block closed forms:
    /// one request per `(block, layer, direction)` slab, split into legal
    /// transactions and replayed through the per-interface queue with its
    /// `I_k` in-flight window. Single-stream and uncontended, so the
    /// result provably equals the exact §4.1 recurrence over the whole
    /// concatenated trace — slightly *below* `n_blocks ×`
    /// [`IsaxLlmModel::kv_block_dma_cycles`], because the in-flight
    /// window pipelines across slab boundaries that the per-block closed
    /// form must serialize. This is what the serving coordinator charges
    /// per decode tick, so batched gathers observe real queueing.
    pub fn kv_gather_dma_cycles(
        &self,
        cfg: &LlmConfig,
        bus: &MemInterface,
        block_slots: usize,
        n_blocks: usize,
    ) -> f64 {
        if n_blocks == 0 {
            return 0.0;
        }
        let slab_bytes = block_slots * cfg.dim * cfg.weight_bytes;
        // One §4.3-decomposed slab, streamed 2·n_layers times per block
        // through the allocation-free single-channel replay (identical to
        // the recorded event replay; this sits on the serving hot path).
        let slab = bus.decompose(0, slab_bytes);
        let n_slabs = n_blocks * 2 * cfg.n_layers;
        dmasim::stream_makespan(
            bus,
            TransactionKind::Load,
            (0..n_slabs).flat_map(|_| slab.iter().copied()),
        ) as f64
    }

    /// [`IsaxLlmModel::kv_gather_dma_cycles`] with a DMA fault injector
    /// in the datapath: the same slab stream, with ECC-style retry
    /// penalties billed per transaction
    /// ([`dmasim::stream_makespan_faulty`]). With an inactive injector
    /// the result is bitwise identical to the clean gather and the PRNG
    /// is never consulted — the chaos serving path calls this only when
    /// a fault plan arms DMA errors.
    pub fn kv_gather_dma_cycles_faulty(
        &self,
        cfg: &LlmConfig,
        bus: &MemInterface,
        block_slots: usize,
        n_blocks: usize,
        faults: &mut dmasim::DmaFaultInjector,
    ) -> f64 {
        if n_blocks == 0 {
            return 0.0;
        }
        let slab_bytes = block_slots * cfg.dim * cfg.weight_bytes;
        let slab = bus.decompose(0, slab_bytes);
        let n_slabs = n_blocks * 2 * cfg.n_layers;
        dmasim::stream_makespan_faulty(
            bus,
            TransactionKind::Load,
            (0..n_slabs).flat_map(|_| slab.iter().copied()),
            faults,
        ) as f64
    }

    /// Per-stream slowdown factors when `streams` cores' DMA engines pull
    /// concurrent weight/KV streams through a shared DDR controller that
    /// sustains `ddr_banks` beats per cycle across the whole SoC.
    ///
    /// Measured, not modelled: a steady-state calibration replay through
    /// the event-driven burst engine ([`crate::interface::dmasim`]) — one
    /// §4.1 queue per core's bus engine, beat-level arbitration at the
    /// shared port group (an [`dmasim::SramSpec`] with `ddr_banks` ports)
    /// — so the multi-core serving layer reuses the existing contention
    /// substrate instead of inventing a second timing model. Entry `i`
    /// applies to the i-th concurrently-streaming core; all entries are
    /// ≥ 1 and equal 1 exactly when the port group covers the aggregate
    /// demand (each engine sustains at most one beat per cycle, so
    /// `streams ≤ ddr_banks` never contends).
    pub fn shared_stream_slowdown(
        &self,
        bus: &MemInterface,
        streams: usize,
        ddr_banks: usize,
    ) -> Vec<f64> {
        if streams == 0 {
            return Vec::new();
        }
        if streams == 1 {
            // A lone stream has the controller to itself by construction.
            return vec![1.0];
        }
        // Enough back-to-back max-size transactions per stream to amortize
        // the lead-off and reach the steady-state service rate.
        const TXNS_PER_STREAM: usize = 192;
        let size = bus.max_transaction();
        let solo =
            dmasim::simulate_sizes(bus, TransactionKind::Load, &vec![size; TXNS_PER_STREAM]);
        let itfcs = InterfaceSet::new(vec![bus.clone(); streams]);
        let srams =
            [dmasim::SramSpec { name: "shared_ddr".into(), banks: ddr_banks.max(1) }];
        let mut txns = Vec::with_capacity(streams * TXNS_PER_STREAM);
        for k in 0..streams {
            for j in 0..TXNS_PER_STREAM {
                txns.push(dmasim::SimTxn {
                    op: k * TXNS_PER_STREAM + j,
                    itfc: InterfaceId(k),
                    kind: TransactionKind::Load,
                    addr: (j * size) as u64,
                    size,
                    sram: Some(0),
                });
            }
        }
        let out = dmasim::simulate_txns(&itfcs, &srams, &txns)
            .expect("calibration replay over a well-formed trace cannot fail");
        (0..streams)
            .map(|k| (out.itfc_cycles(InterfaceId(k)) as f64 / solo as f64).max(1.0))
            .collect()
    }
}

/// TTFT / ITL figures (§6.5 Figure 8(c)).
#[derive(Debug, Clone, Copy)]
pub struct LlmLatency {
    /// Time to first token, milliseconds.
    pub ttft_ms: f64,
    /// Inter-token latency, milliseconds.
    pub itl_ms: f64,
}

/// Run the study: returns (base, aquas, speedups).
pub fn figure8_latency(cfg: &LlmConfig) -> (LlmLatency, LlmLatency, f64, f64) {
    let bus = MemInterface::system_bus();
    let base = BaseCpuModel::default();
    let isax = IsaxLlmModel::default();

    // TTFT: prefill the prompt token-by-token (the scalar baseline cannot
    // batch; the ISAX tiles but still walks all positions).
    let mut base_ttft = 0.0;
    let mut isax_ttft = 0.0;
    for t in 0..cfg.prompt_len {
        base_ttft += base.token_cycles(cfg, t + 1);
        isax_ttft += isax.token_cycles(cfg, t + 1, &bus);
    }
    // ITL: one decode step at full prompt context.
    let base_itl = base.token_cycles(cfg, cfg.prompt_len);
    let isax_itl = isax.token_cycles(cfg, cfg.prompt_len, &bus);

    let to_ms = |cycles: f64| cycles / cfg.clock_hz * 1e3;
    let b = LlmLatency { ttft_ms: to_ms(base_ttft), itl_ms: to_ms(base_itl) };
    let a = LlmLatency { ttft_ms: to_ms(isax_ttft), itl_ms: to_ms(isax_itl) };
    (b, a, base_ttft / isax_ttft, base_itl / isax_itl)
}

/// The attention ISAX unit as a pipeline description (drives the Figure
/// 8(b) resource breakdown through [`crate::area::FpgaModel`]).
pub fn attention_pipeline() -> PipelineDesc {
    PipelineDesc {
        name: "llm_attn".into(),
        stages: vec![
            StageDesc { name: "decode".into(), fus: FuCount::default(), arbiters: 0 },
            StageDesc { name: "stage_in".into(), fus: FuCount::default(), arbiters: 2 },
            StageDesc {
                name: "compute".into(),
                // 16-lane int8 MAC row + softmax helpers.
                // 64-lane int8 MAC row (the cycle model's sustained 16
                // MACs/cycle allows for utilization losses) + softmax
                // helpers.
                fus: FuCount {
                    adders: 96,
                    multipliers: 64,
                    comparators: 16,
                    logic: 64,
                    fp_units: 4,
                    ..Default::default()
                },
                arbiters: 1,
            },
            StageDesc { name: "stage_out".into(), fus: FuCount::default(), arbiters: 1 },
            StageDesc { name: "writeback".into(), fus: FuCount::default(), arbiters: 0 },
        ],
        srams: vec![
            // Double-buffered weight/KV tiles + score rows: the BRAM-heavy
            // mix the paper reports (~25% of the device).
            SramDesc { name: "w_tile0".into(), bytes: 128 * 1024, banks: 4 },
            SramDesc { name: "w_tile1".into(), bytes: 128 * 1024, banks: 4 },
            SramDesc { name: "kv_tile".into(), bytes: 192 * 1024, banks: 4 },
            SramDesc { name: "score_rows".into(), bytes: 96 * 1024, banks: 2 },
        ],
        engines: vec![
            MemEngineDesc {
                itfc_name: "@cpuitfc".into(),
                width: 4,
                burst: false,
                tracker_depth: 1,
                misalign_fallback: true,
            },
            MemEngineDesc {
                itfc_name: "@busitfc".into(),
                width: 8,
                burst: true,
                tracker_depth: 2,
                misalign_fallback: true,
            },
        ],
        initiation_interval: 1,
        datapath_depth: 6,
    }
}

/// Figure 8(b): resource usage + utilization of the attention unit.
pub fn figure8_resources() -> (FpgaUsage, (f64, f64, f64, f64)) {
    let model = FpgaModel::default();
    let usage = model.usage(&attention_pipeline());
    let util = model.utilization(&usage);
    (usage, util)
}

/// The numeric attention kernel **fully in Aquas-IR** — including the
/// causal softmax, which needs the `exp` op. Layout matches the AOT
/// `attention` entry with the leading batch-1 axis dropped: `q`/`k`/`v`/
/// `o` are `[heads, seq, head_dim]` row-major f32 buffers; `srow` is a
/// one-row score scratch.
///
/// Per `(head, i)` query row the kernel runs the same two-pass stable
/// softmax as `runtime::sim::attend`: (1) scaled scores over the causal
/// window `j ≤ i` with a loop-carried running max, (2) `exp(s - max)`
/// with a carried denominator, (3) the probability-weighted value sum.
/// Before the `exp` op existed the softmax had to be staged on the host
/// between two interpreted GEMM stages (see `tests/golden_diff.rs`
/// history); this closes that ROADMAP item.
///
/// The kernel is written the way a naive frontend emits it: every flat
/// `[heads, seq, head_dim]` index is recomputed from scratch inside the
/// innermost loop that consumes it (constants included). Cleaning that
/// up is the mid-end's job — `ir::passes` hoists the invariant address
/// arithmetic and dedups the recomputed rows, which is exactly what
/// `BENCH_interp.json`'s `attention_dynop_reduction` gate measures. The
/// computed *values* are identical either way, so optimized and
/// unoptimized runs stay bit-equal.
pub fn ir_causal_attention(heads: i64, seq: i64, head_dim: i64) -> Func {
    let n = (heads * seq * head_dim) as usize;
    let mut b = FuncBuilder::new("attention_ir");
    let q = b.global("q", DType::F32, n, CacheHint::Warm);
    let k = b.global("k", DType::F32, n, CacheHint::Warm);
    let v = b.global("v", DType::F32, n, CacheHint::Warm);
    let o = b.global("o", DType::F32, n, CacheHint::Warm);
    let srow = b.global("srow", DType::F32, seq as usize, CacheHint::Warm);
    let scale = 1.0 / (head_dim as f64).sqrt();
    b.for_range(0, heads, 1, |b, h| {
        b.for_range(0, seq, 1, |b, i| {
            let one = b.const_i(1);
            let vis = b.add(i, one); // causal window: j in 0..=i
            let lb = b.const_i(0);
            let step = b.const_i(1);
            // Pass 1: scaled scores into srow, running max carried.
            let neg = b.const_f(-1e30);
            let m = b.for_loop(lb, vis, step, &[neg], |b, j, carried| {
                let zero_f = b.const_f(0.0);
                let lbd = b.const_i(0);
                let ubd = b.const_i(head_dim);
                let stepd = b.const_i(1);
                let dot = b.for_loop(lbd, ubd, stepd, &[zero_f], |b, d, acc| {
                    // q[h, i, d]: the full row base is rebuilt per lane.
                    let td = b.const_i(seq * head_dim);
                    let hbase = b.mul(h, td);
                    let dd = b.const_i(head_dim);
                    let irow = b.mul(i, dd);
                    let qrow = b.add(hbase, irow);
                    let qi = b.add(qrow, d);
                    let qv = b.load(q, qi);
                    // k[h, j, d]: likewise.
                    let jrow = b.mul(j, dd);
                    let krow = b.add(hbase, jrow);
                    let ki = b.add(krow, d);
                    let kv = b.load(k, ki);
                    let p = b.mul(qv, kv);
                    vec![b.add(acc[0], p)]
                });
                let sc = b.const_f(scale);
                let s = b.mul(dot[0], sc);
                b.store(srow, j, s);
                vec![b.max(carried[0], s)]
            });
            // Pass 2: exponentials + denominator.
            let lb2 = b.const_i(0);
            let step2 = b.const_i(1);
            let zero_f2 = b.const_f(0.0);
            let den = b.for_loop(lb2, vis, step2, &[zero_f2], |b, j, carried| {
                let s = b.load(srow, j);
                let sm = b.sub(s, m[0]);
                let e = b.exp(sm);
                b.store(srow, j, e);
                vec![b.add(carried[0], e)]
            });
            // Pass 3: probability-weighted value sum per output lane.
            b.for_range(0, head_dim, 1, |b, d| {
                let lb3 = b.const_i(0);
                let step3 = b.const_i(1);
                let zero_f3 = b.const_f(0.0);
                let acc = b.for_loop(lb3, vis, step3, &[zero_f3], |b, j, carried| {
                    let e = b.load(srow, j);
                    // v[h, j, d], row base again rebuilt from scratch.
                    let td3 = b.const_i(seq * head_dim);
                    let hbase3 = b.mul(h, td3);
                    let dd3 = b.const_i(head_dim);
                    let jrow = b.mul(j, dd3);
                    let vrow = b.add(hbase3, jrow);
                    let vi = b.add(vrow, d);
                    let vv = b.load(v, vi);
                    let p = b.mul(e, vv);
                    vec![b.add(carried[0], p)]
                });
                let out = b.div(acc[0], den[0]);
                let td4 = b.const_i(seq * head_dim);
                let hbase4 = b.mul(h, td4);
                let dd4 = b.const_i(head_dim);
                let ibase = b.mul(i, dd4);
                let orow = b.add(hbase4, ibase);
                let oi = b.add(orow, d);
                b.store(o, oi, out);
            });
        });
    });
    b.finish(&[])
}

/// One attention tile as a *synthesizable* ISAX description: the
/// unnormalized scores-times-values kernel for a `seq × head_dim` Q/K/V
/// tile, with the tiles staged into dual-banked scratchpads over the
/// interface model and the result staged back out.
///
/// [`ir_causal_attention`] is the interpreter-facing kernel: it works on
/// global buffers only, so it has no staging transfers and nothing for
/// the §4.3 flow to schedule. This variant is the memory-path view of
/// the same workload — the double-buffered weight/KV tile stream the
/// Figure-8 unit consumes — and exists so the design-space explorer
/// ([`crate::dse`]) can price an attention family through the identical
/// synthesize → hwgen → dmasim pipeline as the PQC/PCP kernels. The
/// softmax normalization stays on the host between tiles (the pre-`exp`
/// staging split described in [`ir_causal_attention`]'s docs), keeping
/// the offloaded datapath mul/add-only.
pub fn isax_attention_tile(seq: i64, head_dim: i64) -> Func {
    let n = (seq * head_dim) as usize;
    let scale = 1.0 / (head_dim as f64).sqrt();
    let mut b = FuncBuilder::new("attn_tile");
    let q = b.global("q", DType::F32, n, CacheHint::Warm);
    let k = b.global("k", DType::F32, n, CacheHint::Warm);
    let v = b.global("v", DType::F32, n, CacheHint::Warm);
    let o = b.global("o", DType::F32, n, CacheHint::Warm);
    let s_q = b.scratchpad("s_q", DType::F32, n, 2);
    let s_k = b.scratchpad("s_k", DType::F32, n, 2);
    let s_v = b.scratchpad("s_v", DType::F32, n, 2);
    let s_o = b.scratchpad("s_o", DType::F32, n, 2);
    let zero = b.const_i(0);
    b.transfer(s_q, zero, q, zero, n * 4);
    b.transfer(s_k, zero, k, zero, n * 4);
    b.transfer(s_v, zero, v, zero, n * 4);
    b.for_range(0, seq, 1, |b, i| {
        b.for_range(0, seq, 1, |b, j| {
            // score = scale · Σ_d q[i,d]·k[j,d]
            let zf = b.const_f(0.0);
            let lb = b.const_i(0);
            let ub = b.const_i(head_dim);
            let st = b.const_i(1);
            let dot = b.for_loop(lb, ub, st, &[zf], |b, d, acc| {
                let dd = b.const_i(head_dim);
                let irow = b.mul(i, dd);
                let qi = b.add(irow, d);
                let qv = b.read_smem(s_q, qi);
                let jrow = b.mul(j, dd);
                let ki = b.add(jrow, d);
                let kv = b.read_smem(s_k, ki);
                let p = b.mul(qv, kv);
                vec![b.add(acc[0], p)]
            });
            let sc = b.const_f(scale);
            let w = b.mul(dot[0], sc);
            // o[i,·] += score · v[j,·]
            b.for_range(0, head_dim, 1, |b, d| {
                let dd = b.const_i(head_dim);
                let jrow = b.mul(j, dd);
                let vi = b.add(jrow, d);
                let vv = b.read_smem(s_v, vi);
                let wv = b.mul(w, vv);
                let irow = b.mul(i, dd);
                let oi = b.add(irow, d);
                let ov = b.read_smem(s_o, oi);
                let nv = b.add(ov, wv);
                b.write_smem(s_o, oi, nv);
            });
        });
    });
    let zero2 = b.const_i(0);
    b.transfer(o, zero2, s_o, zero2, n * 4);
    b.finish(&[])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedups_match_paper_shape() {
        // Paper: 9.30× TTFT, 9.13× ITL. Shape requirement: both speedups
        // in the high single digits / low double digits, TTFT ≥ ITL-ish.
        let (_b, _a, ttft_x, itl_x) = figure8_latency(&LlmConfig::default());
        assert!(ttft_x > 6.0 && ttft_x < 14.0, "ttft speedup {ttft_x}");
        assert!(itl_x > 6.0 && itl_x < 14.0, "itl speedup {itl_x}");
    }

    #[test]
    fn latencies_are_edge_plausible() {
        let (b, a, _, _) = figure8_latency(&LlmConfig::default());
        // 110M int8 on an 80 MHz scalar core: seconds per token; the ISAX
        // brings it under a second.
        assert!(b.itl_ms > a.itl_ms);
        assert!(a.itl_ms > 1.0, "a.itl {} ms", a.itl_ms);
        assert!(b.ttft_ms > b.itl_ms, "prefill covers many tokens");
    }

    #[test]
    fn resource_breakdown_bram_heavy() {
        // Paper: 15% LUT, 10% FF, 25% BRAM.
        let (_usage, (lut, ff, bram, _dsp)) = figure8_resources();
        assert!((5.0..30.0).contains(&lut), "lut {lut}%");
        assert!((3.0..25.0).contains(&ff), "ff {ff}%");
        assert!((15.0..40.0).contains(&bram), "bram {bram}%");
        assert!(bram > lut && bram > ff, "BRAM must dominate: {lut}/{ff}/{bram}");
    }

    #[test]
    fn isax_mem_rate_follows_interface_model() {
        let bus = MemInterface::system_bus();
        let r = IsaxLlmModel::default().mem_bytes_per_cycle(&bus);
        // 64B bursts on an 8B-wide bus with lead 6, I=2: below peak 8 B/c,
        // above half of it.
        assert!(r > 3.0 && r < 8.0, "rate {r}");
    }

    #[test]
    fn batch_of_one_is_the_single_stream_model() {
        let cfg = LlmConfig::default();
        let bus = MemInterface::system_bus();
        let isax = IsaxLlmModel::default();
        for ctx in [1usize, 16, 64, 200] {
            let single = isax.token_cycles(&cfg, ctx, &bus);
            let batched = isax.batch_tick_cycles(&cfg, &[ctx], &bus);
            assert!(
                (single - batched).abs() < 1e-6 * single,
                "ctx {ctx}: {single} vs {batched}"
            );
        }
    }

    #[test]
    fn batched_ticks_amortize_the_weight_stream() {
        // The §6.5 single-stream decode is weight-bound: a batch-4 tick
        // must come out well over 2x cheaper per token (the serving
        // bench's acceptance bar), and throughput must be monotone in
        // batch width up to the lane saturation point.
        let cfg = LlmConfig::default();
        let bus = MemInterface::system_bus();
        let isax = IsaxLlmModel::default();
        let ctx = 64;
        let t1 = isax.batch_tick_cycles(&cfg, &[ctx], &bus);
        let t4 = isax.batch_tick_cycles(&cfg, &[ctx; 4], &bus) / 4.0;
        let t8 = isax.batch_tick_cycles(&cfg, &[ctx; 8], &bus) / 8.0;
        assert!(t1 / t4 >= 2.0, "batch-4 speedup {}", t1 / t4);
        assert!(t8 <= t4 * 1.001, "per-token cost must not grow: {t4} -> {t8}");
        // A batched tick can never beat the pure compute bound.
        let macs = cfg.n_layers as u64
            * (cfg.attn_macs_per_token(ctx) + cfg.mlp_macs_per_token())
            + (cfg.vocab * cfg.dim) as u64;
        let floor = macs as f64 / isax.batch_macs_per_cycle(8);
        assert!(t8 >= floor, "t8 {t8} below compute floor {floor}");
    }

    #[test]
    fn tiled_prefill_beats_token_by_token() {
        let cfg = LlmConfig::default();
        let bus = MemInterface::system_bus();
        let isax = IsaxLlmModel::default();
        let plen = 16;
        let tiled = isax.prefill_cycles(&cfg, plen, &bus);
        let mut walked = 0.0;
        for t in 0..plen {
            walked += isax.token_cycles(&cfg, t + 1, &bus);
        }
        assert!(tiled < walked, "tiled {tiled} vs walked {walked}");
        assert!(tiled > 0.0);
    }

    #[test]
    fn ir_attention_verifies_and_engines_agree() {
        use crate::ir::interp::{ExecStats, Memory};
        use crate::ir::{interp, verifier, vm};
        let f = ir_causal_attention(2, 8, 4);
        verifier::verify(&f).expect("attention IR verifies");
        assert!(f.count_ops(|k| matches!(k, crate::ir::OpKind::Exp)) > 0, "softmax is in-IR");

        let mut rng = crate::util::rng::Rng::new(0xA77E);
        let n = 2 * 8 * 4;
        let data: Vec<f32> = (0..3 * n).map(|_| rng.normal() as f32).collect();
        let mut m1 = Memory::for_func(&f);
        for (name, chunk) in ["q", "k", "v"].iter().zip(data.chunks(n)) {
            m1.write_f32(f.buffer_by_name(name).unwrap(), chunk);
        }
        let mut m2 = m1.clone();
        let mut s1 = ExecStats::default();
        let mut s2 = ExecStats::default();
        interp::run_with_stats(&f, &[], &mut m1, &mut s1).unwrap();
        vm::compile(&f).unwrap().run_with_stats(&[], &mut m2, &mut s2).unwrap();
        assert_eq!(s1, s2, "stats diverge");
        let o = f.buffer_by_name("o").unwrap();
        assert_eq!(m1.read_f32(o), m2.read_f32(o), "outputs diverge");

        // Row 0 attends only to itself: o[h, 0, :] == v[h, 0, :].
        let out = m1.read_f32(o);
        let vbuf = m1.read_f32(f.buffer_by_name("v").unwrap());
        for h in 0..2usize {
            for d in 0..4usize {
                let idx = h * 8 * 4 + d;
                assert!(
                    (out[idx] - vbuf[idx]).abs() < 1e-5,
                    "row 0 must pass v through: {} vs {}",
                    out[idx],
                    vbuf[idx]
                );
            }
        }
        // Probabilities sum to 1: uniform v ⇒ output equals v everywhere.
        let mut m3 = Memory::for_func(&f);
        m3.write_f32(f.buffer_by_name("q").unwrap(), &data[..n]);
        m3.write_f32(f.buffer_by_name("k").unwrap(), &data[n..2 * n]);
        m3.write_f32(f.buffer_by_name("v").unwrap(), &vec![0.5f32; n]);
        interp::run(&f, &[], &mut m3).unwrap();
        for x in m3.read_f32(o) {
            assert!((x - 0.5).abs() < 1e-5, "softmax rows must normalize: {x}");
        }
    }

    #[test]
    fn attention_tile_isax_verifies_and_synthesizes() {
        use crate::ir::verifier;
        use crate::synthesis::{synthesize, SynthOptions};
        let f = isax_attention_tile(8, 4);
        verifier::verify(&f).expect("attention tile verifies");
        let itfcs = InterfaceSet::rocket_default();
        let synth = synthesize(&f, &itfcs, &SynthOptions::default()).expect("attention tile synth");
        assert!(
            !synth.schedule.items.is_empty(),
            "staging transfers must reach the transaction schedule"
        );
    }

    #[test]
    fn tick_parts_compose_to_tick_cycles_exactly() {
        // The SoC contention layer re-prices the memory leg from the
        // parts; the composition must be bitwise-identical so a 1-core
        // SoC replay cannot drift from the single-engine clock.
        let cfg = LlmConfig::default();
        let bus = MemInterface::system_bus();
        let isax = IsaxLlmModel::default();
        for ctxs in [vec![], vec![7usize], vec![16, 32, 64], vec![64; 8]] {
            let (c, m) = isax.batch_tick_parts(&cfg, &ctxs, &bus);
            assert_eq!(c.max(m) * 1.05, isax.batch_tick_cycles(&cfg, &ctxs, &bus));
        }
        let (c, m) = isax.prefill_parts(&cfg, 16, &bus);
        assert_eq!(c.max(m) * 1.05, isax.prefill_cycles(&cfg, 16, &bus));
    }

    #[test]
    fn shared_stream_slowdown_tracks_the_port_group() {
        let bus = MemInterface::system_bus();
        let isax = IsaxLlmModel::default();
        assert_eq!(isax.shared_stream_slowdown(&bus, 0, 3), Vec::<f64>::new());
        assert_eq!(isax.shared_stream_slowdown(&bus, 1, 3), vec![1.0]);
        // Covered demand: each engine sustains at most one beat per
        // cycle, so `streams <= ddr_banks` never contends.
        for f in isax.shared_stream_slowdown(&bus, 2, 3) {
            assert!((f - 1.0).abs() < 0.02, "2 streams over 3 ports contended: {f}");
        }
        // Oversubscribed: 4 engines share 3 beat ports, so each sustains
        // ~3/4 of its solo rate.
        let f4 = isax.shared_stream_slowdown(&bus, 4, 3);
        assert_eq!(f4.len(), 4);
        for &f in &f4 {
            assert!(f > 1.1 && f < 1.7, "4-over-3 oversubscription factor {f}");
        }
        // Deeper oversubscription can only slow streams further.
        let f8 = isax.shared_stream_slowdown(&bus, 8, 3);
        let worst4 = f4.iter().cloned().fold(0.0f64, f64::max);
        let worst8 = f8.iter().cloned().fold(0.0f64, f64::max);
        assert!(worst8 > worst4, "8-over-3 must contend harder: {worst4} vs {worst8}");
    }

    #[test]
    fn simulated_gather_matches_recurrence_and_tracks_closed_form() {
        // The event-driven gather price must equal the exact §4.1
        // recurrence over the concatenated slab trace, and sit at or
        // just below the per-block closed form (cross-slab pipelining).
        let cfg = LlmConfig::default();
        let bus = MemInterface::system_bus();
        let isax = IsaxLlmModel::default();
        let block_slots = 8;
        let slab = block_slots * cfg.dim * cfg.weight_bytes;
        for n_blocks in [1usize, 2, 4] {
            let sim = isax.kv_gather_dma_cycles(&cfg, &bus, block_slots, n_blocks);
            let mut sizes = Vec::new();
            for _ in 0..n_blocks * 2 * cfg.n_layers {
                sizes.extend(bus.decompose(0, slab));
            }
            let exact = sequence_latency(&bus, TransactionKind::Load, &sizes) as f64;
            assert_eq!(sim, exact, "n_blocks {n_blocks}: sim != exact recurrence");
            let closed = isax.kv_block_dma_cycles(&cfg, &bus, block_slots) * n_blocks as f64;
            assert!(sim <= closed, "n_blocks {n_blocks}: sim {sim} above closed {closed}");
            assert!(
                sim > closed * 0.9,
                "n_blocks {n_blocks}: sim {sim} implausibly far below closed {closed}"
            );
        }
        assert_eq!(isax.kv_gather_dma_cycles(&cfg, &bus, block_slots, 0), 0.0);
    }

    #[test]
    fn faulty_gather_is_clean_at_zero_prob_and_dearer_under_faults() {
        let cfg = LlmConfig::default();
        let bus = MemInterface::system_bus();
        let isax = IsaxLlmModel::default();
        let block_slots = 8;
        for n_blocks in [1usize, 3] {
            let clean = isax.kv_gather_dma_cycles(&cfg, &bus, block_slots, n_blocks);
            let mut inert = dmasim::DmaFaultInjector::new(0.0, 7);
            let same = isax
                .kv_gather_dma_cycles_faulty(&cfg, &bus, block_slots, n_blocks, &mut inert);
            assert_eq!(same, clean, "inactive injector must be bitwise inert");
            let mut hot = dmasim::DmaFaultInjector::new(1.0, 7);
            let dear =
                isax.kv_gather_dma_cycles_faulty(&cfg, &bus, block_slots, n_blocks, &mut hot);
            assert!(dear > clean, "certain faults must cost cycles");
            assert!(hot.retries() > 0);
        }
    }

    #[test]
    fn paged_block_dma_costs_at_least_the_ideal_stream() {
        let cfg = LlmConfig::default();
        let bus = MemInterface::system_bus();
        let isax = IsaxLlmModel::default();
        let block_slots = 8;
        let per_block = isax.kv_block_dma_cycles(&cfg, &bus, block_slots);
        // One block holds block_slots positions of K+V across all layers.
        let block_bytes = (2 * cfg.n_layers * block_slots * cfg.dim * cfg.weight_bytes) as f64;
        let ideal = block_bytes / isax.mem_bytes_per_cycle(&bus);
        // Long bursts amortize lead-off, so a block lands within a few
        // percent of the ideal stream either way; anything far off means
        // the burst decomposition or the recurrence hookup broke.
        assert!(
            per_block > ideal * 0.95 && per_block < ideal * 1.5,
            "block DMA {per_block} implausible vs ideal stream {ideal}"
        );
    }
}
