//! §6.4 — graphics rendering: `vmvar` (vector moments), `mphong` (Phong
//! lighting) and `vrgb2yuv` (color-space conversion), pitted against the
//! Saturn vector unit (VLEN=128).

use crate::compiler::IsaxDef;
use crate::cores::saturn::VectorProfile;
use crate::interface::cache::CacheHint;
use crate::interface::model::InterfaceSet;
use crate::ir::builder::FuncBuilder;
use crate::ir::interp::Memory;
use crate::ir::Func;
use crate::runtime::DType;
use crate::synthesis::SynthOptions;
use crate::util::rng::Rng;
use crate::workloads::Kernel;

/// Pixels for phong / rgb2yuv.
pub const NPIX: i64 = 64;
/// vmvar: ROWS vectors of width W.
pub const ROWS: i64 = 16;
/// vmvar row width (elements per vector).
pub const W: i64 = 16;
/// Phong material constants (shininess kept small so `powi` stays cheap).
pub const KA: f64 = 0.1;
/// Phong diffuse coefficient.
pub const KD: f64 = 0.7;
/// Phong specular coefficient.
pub const KS: f64 = 0.4;
/// Phong specular exponent.
pub const SHININESS: u32 = 4;

fn write_unit_vectors(func: &Func, mem: &mut Memory, name: &str, seed: u64, n: i64) {
    let mut rng = Rng::new(seed);
    let mut data = Vec::with_capacity((n * 3) as usize);
    for _ in 0..n {
        let (x, y, z) = (rng.normal(), rng.normal(), rng.normal());
        let len = (x * x + y * y + z * z).sqrt().max(1e-9);
        data.extend([(x / len) as f32, (y / len) as f32, (z / len) as f32]);
    }
    mem.write_f32(Kernel::buf(func, name), &data);
}

// ---------------------------------------------------------------------------
// vmvar — per-row mean and variance
// ---------------------------------------------------------------------------

fn build_vmvar(isax: bool) -> Func {
    let mut b = FuncBuilder::new(if isax { "vmvar" } else { "vmvar_sw" });
    let x = b.global("x", DType::F32, (ROWS * W) as usize, CacheHint::Warm);
    let mean = b.global("mean", DType::F32, ROWS as usize, CacheHint::Warm);
    let var = b.global("var", DType::F32, ROWS as usize, CacheHint::Warm);
    let sx = if isax {
        Some(b.scratchpad("s_x", DType::F32, (ROWS * W) as usize, 2))
    } else {
        None
    };
    if isax {
        let zero = b.const_i(0);
        b.transfer(sx.unwrap(), zero, x, zero, (ROWS * W * 4) as usize);
    }
    b.for_range(0, ROWS, 1, |b, r| {
        let wc = b.const_i(W);
        let base = b.mul(r, wc);
        // accumulate sum and sum-of-squares in the output buffers
        b.for_range(0, W, 1, |b, i| {
            let idx = b.add(base, i);
            let v = if isax { b.read_smem(sx.unwrap(), idx) } else { b.load(x, idx) };
            let s = b.load(mean, r);
            let s2 = b.add(s, v);
            b.store(mean, r, s2);
            let sq = b.mul(v, v);
            let m2 = b.load(var, r);
            let m22 = b.add(m2, sq);
            b.store(var, r, m22);
        });
        // finalize: mean /= W; var = var/W - mean²
        let wf = b.const_f(W as f64);
        let s = b.load(mean, r);
        let m = b.div(s, wf);
        b.store(mean, r, m);
        let m2 = b.load(var, r);
        let ex2 = b.div(m2, wf);
        let msq = b.mul(m, m);
        let v = b.sub(ex2, msq);
        b.store(var, r, v);
    });
    b.finish(&[])
}

fn init_vmvar(func: &Func, mem: &mut Memory) {
    let mut rng = Rng::new(0x3A12);
    let xs: Vec<f32> = (0..ROWS * W).map(|_| rng.normal() as f32).collect();
    mem.write_f32(Kernel::buf(func, "x"), &xs);
}

// ---------------------------------------------------------------------------
// mphong — per-pixel Phong lighting over SoA [N*3] unit vectors
// ---------------------------------------------------------------------------

fn build_phong(isax: bool, redundant_loads: bool) -> Func {
    let name = if isax { "mphong" } else { "mphong_sw" };
    let mut b = FuncBuilder::new(name);
    let nrm = b.global("nrm", DType::F32, (NPIX * 3) as usize, CacheHint::Warm);
    let lgt = b.global("lgt", DType::F32, (NPIX * 3) as usize, CacheHint::Warm);
    let view = b.global("view", DType::F32, (NPIX * 3) as usize, CacheHint::Warm);
    let out = b.global("inten", DType::F32, NPIX as usize, CacheHint::Warm);
    let (sn, sl, sv, so) = if isax {
        (
            Some(b.scratchpad("s_n", DType::F32, (NPIX * 3) as usize, 2)),
            Some(b.scratchpad("s_l", DType::F32, (NPIX * 3) as usize, 2)),
            Some(b.scratchpad("s_v", DType::F32, (NPIX * 3) as usize, 2)),
            Some(b.scratchpad("s_o", DType::F32, NPIX as usize, 1)),
        )
    } else {
        (None, None, None, None)
    };
    if isax {
        let zero = b.const_i(0);
        b.transfer(sn.unwrap(), zero, nrm, zero, (NPIX * 3 * 4) as usize);
        b.transfer(sl.unwrap(), zero, lgt, zero, (NPIX * 3 * 4) as usize);
        b.transfer(sv.unwrap(), zero, view, zero, (NPIX * 3 * 4) as usize);
    }
    b.for_range(0, NPIX, 1, |b, i| {
        let three = b.const_i(3);
        let base = b.mul(i, three);
        let mut n = [None; 3];
        let mut l = [None; 3];
        let mut v = [None; 3];
        for d in 0..3usize {
            let off = b.const_i(d as i64);
            let idx = b.add(base, off);
            n[d] = Some(if isax { b.read_smem(sn.unwrap(), idx) } else { b.load(nrm, idx) });
            l[d] = Some(if isax { b.read_smem(sl.unwrap(), idx) } else { b.load(lgt, idx) });
            v[d] = Some(if isax { b.read_smem(sv.unwrap(), idx) } else { b.load(view, idx) });
        }
        // ndotl = max(0, n·l)
        let mut ndotl = b.const_f(0.0);
        for d in 0..3 {
            // "RE" robustness attack: spell the same load twice.
            let nd = if redundant_loads && d == 0 {
                let off = b.const_i(0);
                let idx = b.add(base, off);
                b.load(nrm, idx)
            } else {
                n[d].unwrap()
            };
            let p = b.mul(nd, l[d].unwrap());
            ndotl = b.add(ndotl, p);
        }
        let zero_f = b.const_f(0.0);
        let ndotl = b.max(ndotl, zero_f);
        // refl = 2*ndotl*n - l ; rdotv = max(0, refl·v)
        let two = b.const_f(2.0);
        let scale = b.mul(two, ndotl);
        let mut rdotv = b.const_f(0.0);
        for d in 0..3 {
            let rn = b.mul(scale, n[d].unwrap());
            let refl = b.sub(rn, l[d].unwrap());
            let p = b.mul(refl, v[d].unwrap());
            rdotv = b.add(rdotv, p);
        }
        let zero_f2 = b.const_f(0.0);
        let rdotv = b.max(rdotv, zero_f2);
        let spec_pow = b.powi(rdotv, SHININESS);
        // spec gated on front-facing normal
        let gate = b.cmp(crate::ir::ops::CmpPred::Gt, ndotl, zero_f2);
        let zero_f3 = b.const_f(0.0);
        let spec = b.select(gate, spec_pow, zero_f3);
        let ka = b.const_f(KA);
        let kd = b.const_f(KD);
        let ks = b.const_f(KS);
        let diff = b.mul(kd, ndotl);
        let sp = b.mul(ks, spec);
        let partial = b.add(ka, diff);
        let inten = b.add(partial, sp);
        if isax {
            b.write_smem(so.unwrap(), i, inten);
        } else {
            b.store(out, i, inten);
        }
    });
    if isax {
        let zero = b.const_i(0);
        b.transfer(out, zero, so.unwrap(), zero, (NPIX * 4) as usize);
    }
    b.finish(&[])
}

fn init_phong(func: &Func, mem: &mut Memory) {
    write_unit_vectors(func, mem, "nrm", 0x401, NPIX);
    write_unit_vectors(func, mem, "lgt", 0x402, NPIX);
    write_unit_vectors(func, mem, "view", 0x403, NPIX);
}

// ---------------------------------------------------------------------------
// vrgb2yuv — 3x3 color matrix per pixel
// ---------------------------------------------------------------------------

/// ITU-R BT.601-ish RGB→YUV matrix. Shared with the artifact golden
/// model (`runtime::sim`), which must agree numerically with the IR
/// kernel (same constants as `python/compile/kernels/ref.py`).
pub const RGB2YUV: [[f64; 3]; 3] = [
    [0.299, 0.587, 0.114],
    [-0.14713, -0.28886, 0.436],
    [0.615, -0.51499, -0.10001],
];

fn build_rgb2yuv(isax: bool, reassociated: bool) -> Func {
    let mut b = FuncBuilder::new(if isax { "vrgb2yuv" } else { "vrgb2yuv_sw" });
    let rgb = b.global("rgb", DType::F32, (NPIX * 3) as usize, CacheHint::Warm);
    let yuv = b.global("yuv", DType::F32, (NPIX * 3) as usize, CacheHint::Warm);
    let (si, so) = if isax {
        (
            Some(b.scratchpad("s_i", DType::F32, (NPIX * 3) as usize, 2)),
            Some(b.scratchpad("s_o", DType::F32, (NPIX * 3) as usize, 2)),
        )
    } else {
        (None, None)
    };
    if isax {
        let zero = b.const_i(0);
        b.transfer(si.unwrap(), zero, rgb, zero, (NPIX * 3 * 4) as usize);
    }
    b.for_range(0, NPIX, 1, |b, i| {
        let three = b.const_i(3);
        let base = b.mul(i, three);
        let mut chan = [None; 3];
        for c in 0..3usize {
            let off = b.const_i(c as i64);
            let idx = b.add(base, off);
            chan[c] = Some(if isax { b.read_smem(si.unwrap(), idx) } else { b.load(rgb, idx) });
        }
        for row in 0..3usize {
            let mut terms = Vec::new();
            for c in 0..3usize {
                let k = b.const_f(RGB2YUV[row][c]);
                terms.push(b.mul(chan[c].unwrap(), k));
            }
            // AF attack: reassociate the 3-term sum.
            let sum = if reassociated {
                let t12 = b.add(terms[1], terms[2]);
                b.add(terms[0], t12)
            } else {
                let t01 = b.add(terms[0], terms[1]);
                b.add(t01, terms[2])
            };
            let off = b.const_i(row as i64);
            let idx = b.add(base, off);
            if isax {
                b.write_smem(so.unwrap(), idx, sum);
            } else {
                b.store(yuv, idx, sum);
            }
        }
    });
    if isax {
        let zero = b.const_i(0);
        b.transfer(yuv, zero, so.unwrap(), zero, (NPIX * 3 * 4) as usize);
    }
    b.finish(&[])
}

fn init_rgb2yuv(func: &Func, mem: &mut Memory) {
    let mut rng = Rng::new(0x26B);
    let px: Vec<f32> = (0..NPIX * 3).map(|_| rng.f32()).collect();
    mem.write_f32(Kernel::buf(func, "rgb"), &px);
}

// ---------------------------------------------------------------------------

/// The three graphics kernels with variants + Saturn vector profiles.
pub fn kernels() -> Vec<Kernel> {
    use crate::compiler::loop_passes::{apply, LoopPass};
    use crate::compiler::matcher::top_loops;

    let sw_vmvar = build_vmvar(false);
    let vmvar_unrolled =
        apply(&sw_vmvar, top_loops(&sw_vmvar)[0], LoopPass::Unroll(2)).expect("unroll vmvar");

    vec![
        Kernel {
            name: "vmvar",
            software: sw_vmvar,
            variants: vec![("Unroll(2)".into(), vmvar_unrolled)],
            isax: IsaxDef { name: "vmvar".into(), func: build_vmvar(true) },
            init: init_vmvar,
            outputs: vec!["mean", "var"],
            vector_profile: Some(VectorProfile {
                elements: (ROWS * W) as u64,
                vector_ops_per_element: 2, // acc + square
                mem_ops_per_element: 1,
                reductions: 2 * ROWS as u64, // per-row sum + sumsq trees
                scalar_ops: 6 * ROWS as u64, // finalize divides
            }),
            synth_opts: SynthOptions::default(),
            itfcs: InterfaceSet::rocket_default(),
        },
        Kernel {
            name: "mphong",
            software: build_phong(false, false),
            variants: vec![("RE (redundant loads)".into(), build_phong(false, true))],
            isax: IsaxDef { name: "mphong".into(), func: build_phong(true, false) },
            init: init_phong,
            outputs: vec!["inten"],
            vector_profile: Some(VectorProfile {
                elements: NPIX as u64,
                vector_ops_per_element: 24,
                mem_ops_per_element: 10,
                reductions: 0,
                scalar_ops: 8,
            }),
            synth_opts: SynthOptions::default(),
            itfcs: InterfaceSet::rocket_default(),
        },
        Kernel {
            name: "vrgb2yuv",
            software: build_rgb2yuv(false, false),
            variants: vec![("AF (reassociated)".into(), build_rgb2yuv(false, true))],
            isax: IsaxDef { name: "vrgb2yuv".into(), func: build_rgb2yuv(true, false) },
            init: init_rgb2yuv,
            outputs: vec!["yuv"],
            vector_profile: Some(VectorProfile {
                elements: NPIX as u64,
                vector_ops_per_element: 15,
                mem_ops_per_element: 6,
                reductions: 0,
                scalar_ops: 4,
            }),
            synth_opts: SynthOptions::default(),
            itfcs: InterfaceSet::rocket_default(),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};

    #[test]
    fn vmvar_moments_correct() {
        let f = build_vmvar(false);
        let mut mem = Memory::for_func(&f);
        init_vmvar(&f, &mut mem);
        let xs = mem.read_f32(Kernel::buf(&f, "x"));
        crate::ir::interp::run(&f, &[], &mut mem).unwrap();
        let mean = mem.read_f32(Kernel::buf(&f, "mean"));
        let var = mem.read_f32(Kernel::buf(&f, "var"));
        for r in 0..ROWS as usize {
            let row = &xs[r * W as usize..(r + 1) * W as usize];
            let m: f32 = row.iter().sum::<f32>() / W as f32;
            let v: f32 = row.iter().map(|x| x * x).sum::<f32>() / W as f32 - m * m;
            assert!((mean[r] - m).abs() < 1e-4, "row {r}");
            assert!((var[r] - v).abs() < 1e-3, "row {r}");
        }
    }

    #[test]
    fn phong_in_plausible_range() {
        let f = build_phong(false, false);
        let mut mem = Memory::for_func(&f);
        init_phong(&f, &mut mem);
        crate::ir::interp::run(&f, &[], &mut mem).unwrap();
        let inten = mem.read_f32(Kernel::buf(&f, "inten"));
        for (i, x) in inten.iter().enumerate() {
            assert!(*x >= KA as f32 - 1e-6, "pixel {i}: {x}");
            assert!(*x <= (KA + KD + KS) as f32 + 1e-4, "pixel {i}: {x}");
        }
    }

    #[test]
    fn rgb2yuv_matches_matrix() {
        let f = build_rgb2yuv(false, false);
        let mut mem = Memory::for_func(&f);
        init_rgb2yuv(&f, &mut mem);
        let rgb = mem.read_f32(Kernel::buf(&f, "rgb"));
        crate::ir::interp::run(&f, &[], &mut mem).unwrap();
        let yuv = mem.read_f32(Kernel::buf(&f, "yuv"));
        for i in 0..NPIX as usize {
            for row in 0..3 {
                let want: f32 = (0..3)
                    .map(|c| rgb[i * 3 + c] * RGB2YUV[row][c] as f32)
                    .sum();
                let got = yuv[i * 3 + row];
                assert!((got - want).abs() < 1e-4, "pixel {i} row {row}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn all_graphics_kernels_match_their_isax() {
        for k in kernels() {
            let r = compile(&k.software, &[k.isax.clone()], &CompileOptions::default())
                .unwrap_or_else(|e| panic!("{}: {e}", k.name));
            assert_eq!(r.stats.matched, vec![k.isax.name.clone()], "{}: {:?}", k.name, r.stats);
        }
    }

    #[test]
    fn all_graphics_variants_match() {
        for k in kernels() {
            for (desc, variant) in &k.variants {
                let r = compile(variant, &[k.isax.clone()], &CompileOptions::default())
                    .unwrap_or_else(|e| panic!("{} {desc}: {e}", k.name));
                assert_eq!(
                    r.stats.matched,
                    vec![k.isax.name.clone()],
                    "{} variant {desc}: {:?}",
                    k.name,
                    r.stats
                );
            }
        }
    }
}
