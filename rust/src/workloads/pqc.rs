//! §6.2 — post-quantum cryptography: syndrome computation s = H·eᵀ over
//! GF(2).
//!
//! Two ISAXs, exactly as the paper designs them:
//! - **vdecomp** — bitstream unpacking: packed 32-bit words → {0,1} bytes;
//! - **mgf2mm** — matrix multiplication over GF(2) (requests from multiple
//!   users are packed into the columns of `E`).
//!
//! Dimensions (kept interpreter-friendly; the paper's H is much larger but
//! sparse — the dense core loop is the same): NBITS = 512 unpacked bits,
//! H: 16×32, E: 32×8, S: 16×8.

use crate::compiler::IsaxDef;
use crate::interface::cache::CacheHint;
use crate::interface::model::InterfaceSet;
use crate::ir::builder::FuncBuilder;
use crate::ir::interp::Memory;
use crate::ir::Func;
use crate::runtime::DType;
use crate::synthesis::SynthOptions;
use crate::util::rng::Rng;
use crate::workloads::Kernel;

/// vdecomp: number of unpacked bits produced.
pub const NBITS: i64 = 512;
/// vdecomp: number of packed 32-bit input words.
pub const NWORDS: i64 = NBITS / 32;
/// mgf2mm dims: S[R×C] = H[R×K] · E[K×C] over GF(2).
pub const R: i64 = 16;
/// mgf2mm inner (reduction) dimension.
pub const K: i64 = 32;
/// mgf2mm column count (packed user requests).
pub const C: i64 = 8;

// ---------------------------------------------------------------------------
// vdecomp
// ---------------------------------------------------------------------------

/// Canonical software: shift/mask spelling (`i >> 5`, `i & 31`) — the
/// idiomatic C form, deliberately *not* the ISAX's div/rem form. The
/// internal rules `shr-to-div` / `mask-to-rem` bridge the gap (Table 3
/// "RF" divergence).
pub fn software_vdecomp() -> Func {
    let mut b = FuncBuilder::new("vdecomp_sw");
    let e = b.global("e", DType::I32, NWORDS as usize, CacheHint::Warm);
    let out = b.global("out", DType::I32, NBITS as usize, CacheHint::Warm);
    b.for_range(0, NBITS, 1, |b, i| {
        let five = b.const_i(5);
        let word_idx = b.shr(i, five);
        let w = b.load(e, word_idx);
        let mask31 = b.const_i(31);
        let sh = b.and(i, mask31);
        let shifted = b.shr(w, sh);
        let one = b.const_i(1);
        let bit = b.and(shifted, one);
        b.store(out, i, bit);
    });
    b.finish(&[])
}

/// ISAX description: stages the packed words into a scratchpad over the
/// bus, unpacks with div/rem indexing, stages the unpacked bytes out.
pub fn isax_vdecomp() -> Func {
    let mut b = FuncBuilder::new("vdecomp");
    let e = b.global("e", DType::I32, NWORDS as usize, CacheHint::Warm);
    let out = b.global("out", DType::I32, NBITS as usize, CacheHint::Warm);
    let s_e = b.scratchpad("s_e", DType::I32, NWORDS as usize, 2);
    let s_out = b.scratchpad("s_out", DType::I32, NBITS as usize, 2);
    let zero = b.const_i(0);
    b.transfer(s_e, zero, e, zero, (NWORDS * 4) as usize);
    b.for_range(0, NBITS, 1, |b, i| {
        let c32 = b.const_i(32);
        let word_idx = b.div(i, c32);
        let w = b.read_smem(s_e, word_idx);
        let sh = b.rem(i, c32);
        let shifted = b.shr(w, sh);
        let one = b.const_i(1);
        let bit = b.and(shifted, one);
        b.write_smem(s_out, i, bit);
    });
    let zero2 = b.const_i(0);
    b.transfer(out, zero2, s_out, zero2, (NBITS * 4) as usize);
    b.finish(&[])
}

fn init_vdecomp(func: &Func, mem: &mut Memory) {
    let mut rng = Rng::new(0x50C5EED);
    let words: Vec<i32> = (0..NWORDS).map(|_| rng.next_u64() as i32).collect();
    mem.write_i32(Kernel::buf(func, "e"), &words);
}

// ---------------------------------------------------------------------------
// mgf2mm
// ---------------------------------------------------------------------------

/// Canonical software: xor-accumulate into S, then the loop structure the
/// paper's robustness study perturbs.
pub fn software_mgf2mm() -> Func {
    let mut b = FuncBuilder::new("mgf2mm_sw");
    let h = b.global("h", DType::I32, (R * K) as usize, CacheHint::Warm);
    let e = b.global("em", DType::I32, (K * C) as usize, CacheHint::Warm);
    let s = b.global("s", DType::I32, (R * C) as usize, CacheHint::Warm);
    b.for_range(0, R, 1, |b, r| {
        b.for_range(0, C, 1, |b, c| {
            b.for_range(0, K, 1, |b, k| {
                let kc = b.const_i(K);
                let rk = b.mul(r, kc);
                let hidx = b.add(rk, k);
                let hv = b.load(h, hidx);
                let cc = b.const_i(C);
                let kcidx = b.mul(k, cc);
                let eidx = b.add(kcidx, c);
                let ev = b.load(e, eidx);
                let prod = b.and(hv, ev);
                let rc = b.mul(r, cc);
                let sidx = b.add(rc, c);
                let sv = b.load(s, sidx);
                let acc = b.xor(sv, prod);
                b.store(s, sidx, acc);
            });
        });
    });
    b.finish(&[])
}

/// ISAX: all three operands staged; same xor/and datapath.
pub fn isax_mgf2mm() -> Func {
    let mut b = FuncBuilder::new("mgf2mm");
    let h = b.global("h", DType::I32, (R * K) as usize, CacheHint::Warm);
    let e = b.global("em", DType::I32, (K * C) as usize, CacheHint::Warm);
    let s = b.global("s", DType::I32, (R * C) as usize, CacheHint::Warm);
    let s_h = b.scratchpad("s_h", DType::I32, (R * K) as usize, 2);
    let s_e = b.scratchpad("s_e", DType::I32, (K * C) as usize, 2);
    let s_s = b.scratchpad("s_s", DType::I32, (R * C) as usize, 2);
    let zero = b.const_i(0);
    b.transfer(s_h, zero, h, zero, (R * K * 4) as usize);
    b.transfer(s_e, zero, e, zero, (K * C * 4) as usize);
    b.for_range(0, R, 1, |b, r| {
        b.for_range(0, C, 1, |b, c| {
            b.for_range(0, K, 1, |b, k| {
                let kc = b.const_i(K);
                let rk = b.mul(r, kc);
                let hidx = b.add(rk, k);
                let hv = b.read_smem(s_h, hidx);
                let cc = b.const_i(C);
                let kcidx = b.mul(k, cc);
                let eidx = b.add(kcidx, c);
                let ev = b.read_smem(s_e, eidx);
                let prod = b.and(hv, ev);
                let rc = b.mul(r, cc);
                let sidx = b.add(rc, c);
                let sv = b.read_smem(s_s, sidx);
                let acc = b.xor(sv, prod);
                b.write_smem(s_s, sidx, acc);
            });
        });
    });
    let zero2 = b.const_i(0);
    b.transfer(s, zero2, s_s, zero2, (R * C * 4) as usize);
    b.finish(&[])
}

fn init_mgf2mm(func: &Func, mem: &mut Memory) {
    let mut rng = Rng::new(0x46F2);
    let hbits: Vec<i32> = (0..R * K).map(|_| rng.below(2) as i32).collect();
    let ebits: Vec<i32> = (0..K * C).map(|_| rng.below(2) as i32).collect();
    mem.write_i32(Kernel::buf(func, "h"), &hbits);
    mem.write_i32(Kernel::buf(func, "em"), &ebits);
}

// ---------------------------------------------------------------------------
// Kernels + variants
// ---------------------------------------------------------------------------

/// Both PQC kernels with their Table-3 variants.
pub fn kernels() -> Vec<Kernel> {
    use crate::compiler::loop_passes::{apply, LoopPass};
    use crate::compiler::matcher::top_loops;

    let sw_vd = software_vdecomp();
    let vd_tiled = apply(&sw_vd, top_loops(&sw_vd)[0], LoopPass::Tile(4)).expect("tile vdecomp");
    let sw_mm = software_mgf2mm();
    // Unroll the innermost k-loop? Table 3 says Unroll(4) — unroll the
    // outer loop is what our guided engine inverts; use the top loop.
    let mm_unrolled =
        apply(&sw_mm, top_loops(&sw_mm)[0], LoopPass::Unroll(4)).expect("unroll mgf2mm");

    vec![
        Kernel {
            name: "vdecomp",
            software: sw_vd,
            variants: vec![("Tiling(4)".into(), vd_tiled)],
            isax: IsaxDef { name: "vdecomp".into(), func: isax_vdecomp() },
            init: init_vdecomp,
            outputs: vec!["out"],
            vector_profile: None,
            synth_opts: SynthOptions::default(),
            itfcs: InterfaceSet::rocket_default(),
        },
        Kernel {
            name: "mgf2mm",
            software: sw_mm,
            variants: vec![("Unroll(4)".into(), mm_unrolled)],
            isax: IsaxDef { name: "mgf2mm".into(), func: isax_mgf2mm() },
            init: init_mgf2mm,
            outputs: vec!["s"],
            vector_profile: None,
            synth_opts: SynthOptions::default(),
            itfcs: InterfaceSet::rocket_default(),
        },
    ]
}

/// The end-to-end PQC workload: unpack the error bitstream, then multiply
/// (software has both loops; the compiler should offload both ISAXs).
pub fn end_to_end_software() -> Func {
    let mut b = FuncBuilder::new("pqc_e2e");
    let e = b.global("e", DType::I32, NWORDS as usize, CacheHint::Warm);
    let out = b.global("out", DType::I32, NBITS as usize, CacheHint::Warm);
    let h = b.global("h", DType::I32, (R * K) as usize, CacheHint::Warm);
    let em = b.global("em", DType::I32, (K * C) as usize, CacheHint::Warm);
    let s = b.global("s", DType::I32, (R * C) as usize, CacheHint::Warm);

    // vdecomp loop (shift/mask spelling)
    b.for_range(0, NBITS, 1, |b, i| {
        let five = b.const_i(5);
        let word_idx = b.shr(i, five);
        let w = b.load(e, word_idx);
        let mask31 = b.const_i(31);
        let sh = b.and(i, mask31);
        let shifted = b.shr(w, sh);
        let one = b.const_i(1);
        let bit = b.and(shifted, one);
        b.store(out, i, bit);
    });
    // glue: pack the first K*C unpacked bits into the request matrix
    b.for_range(0, K * C, 1, |b, i| {
        let bit = b.load(out, i);
        b.store(em, i, bit);
    });
    // mgf2mm loop
    b.for_range(0, R, 1, |b, r| {
        b.for_range(0, C, 1, |b, c| {
            b.for_range(0, K, 1, |b, k| {
                let kc = b.const_i(K);
                let rk = b.mul(r, kc);
                let hidx = b.add(rk, k);
                let hv = b.load(h, hidx);
                let cc = b.const_i(C);
                let kcidx = b.mul(k, cc);
                let eidx = b.add(kcidx, c);
                let ev = b.load(em, eidx);
                let prod = b.and(hv, ev);
                let rc = b.mul(r, cc);
                let sidx = b.add(rc, c);
                let sv = b.load(s, sidx);
                let acc = b.xor(sv, prod);
                b.store(s, sidx, acc);
            });
        });
    });
    b.finish(&[])
}

/// Initialize the e2e memory image.
pub fn init_end_to_end(func: &Func, mem: &mut Memory) {
    let mut rng = Rng::new(0xE2E);
    let words: Vec<i32> = (0..NWORDS).map(|_| rng.next_u64() as i32).collect();
    let hbits: Vec<i32> = (0..R * K).map(|_| rng.below(2) as i32).collect();
    mem.write_i32(Kernel::buf(func, "e"), &words);
    mem.write_i32(Kernel::buf(func, "h"), &hbits);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::ir::ops::OpKind;

    #[test]
    fn vdecomp_unpacks_correctly() {
        let f = software_vdecomp();
        let mut mem = Memory::for_func(&f);
        let mut words = vec![0i32; NWORDS as usize];
        words[0] = 0b1011;
        words[1] = 1 << 31;
        mem.write_i32(Kernel::buf(&f, "e"), &words);
        crate::ir::interp::run(&f, &[], &mut mem).unwrap();
        let out = mem.read_i32(Kernel::buf(&f, "out"));
        assert_eq!(&out[..4], &[1, 1, 0, 1]);
        assert_eq!(out[63], 1); // bit 31 of word 1
        assert_eq!(out[62], 0);
    }

    #[test]
    fn mgf2mm_matches_reference_multiply() {
        let f = software_mgf2mm();
        let mut mem = Memory::for_func(&f);
        init_mgf2mm(&f, &mut mem);
        let h = mem.read_i32(Kernel::buf(&f, "h"));
        let e = mem.read_i32(Kernel::buf(&f, "em"));
        crate::ir::interp::run(&f, &[], &mut mem).unwrap();
        let s = mem.read_i32(Kernel::buf(&f, "s"));
        for r in 0..R as usize {
            for c in 0..C as usize {
                let mut acc = 0;
                for k in 0..K as usize {
                    acc ^= h[r * K as usize + k] & e[k * C as usize + c];
                }
                assert_eq!(s[r * C as usize + c], acc, "({r},{c})");
            }
        }
    }

    #[test]
    fn compiler_matches_canonical_vdecomp() {
        let ks = kernels();
        let vd = &ks[0];
        let r = compile(&vd.software, &[vd.isax.clone()], &CompileOptions::default()).unwrap();
        assert_eq!(r.stats.matched, vec!["vdecomp".to_string()], "{:?}", r.stats);
        assert_eq!(r.func.count_ops(|k| matches!(k, OpKind::Intrinsic(_))), 1);
    }

    #[test]
    fn compiler_matches_tiled_vdecomp_variant() {
        let ks = kernels();
        let vd = &ks[0];
        let (desc, variant) = &vd.variants[0];
        let r = compile(variant, &[vd.isax.clone()], &CompileOptions::default()).unwrap();
        assert_eq!(r.stats.matched, vec!["vdecomp".to_string()], "variant {desc}: {:?}", r.stats);
        assert!(r.stats.external_rewrites >= 1);
    }

    #[test]
    fn compiler_matches_unrolled_mgf2mm_variant() {
        let ks = kernels();
        let mm = &ks[1];
        let (desc, variant) = &mm.variants[0];
        let r = compile(variant, &[mm.isax.clone()], &CompileOptions::default()).unwrap();
        assert_eq!(r.stats.matched, vec!["mgf2mm".to_string()], "variant {desc}: {:?}", r.stats);
    }

    #[test]
    fn e2e_offloads_both_isaxes() {
        let ks = kernels();
        let sw = end_to_end_software();
        let isaxes: Vec<_> = ks.iter().map(|k| k.isax.clone()).collect();
        let r = compile(&sw, &isaxes, &CompileOptions::default()).unwrap();
        assert!(r.stats.matched.contains(&"vdecomp".to_string()), "{:?}", r.stats);
        assert!(r.stats.matched.contains(&"mgf2mm".to_string()), "{:?}", r.stats);
    }
}
