//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls: `thiserror` is not in the offline
//! vendor set (see DESIGN.md), and the variants are few enough that the
//! derive buys nothing.

/// Errors produced anywhere in the Aquas stack.
#[derive(Debug)]
pub enum Error {
    /// IR construction or verification failure.
    Ir(String),

    /// A memory transaction violates the microarchitectural constraints of
    /// its bound interface (§4.1: beat count, alignment, in-flight limit).
    Interface(String),

    /// Synthesis-time optimization failure (§4.3).
    Synthesis(String),

    /// E-graph or rewrite failure (§5.2–5.3).
    Egraph(String),

    /// Compiler matching/lowering failure (§5.4).
    Compiler(String),

    /// Cycle-level simulation failure.
    Sim(String),

    /// Runtime failure (artifact loading / entry execution).
    Runtime(String),

    /// Serving-coordinator failure.
    Coordinator(String),

    /// Artifact manifest problems.
    Manifest(String),

    /// Execution fuel exhausted: the program was stopped deterministically
    /// after `spent` charged fuel units, at the `at_op`-th billable event.
    /// Both IR engines (tree-walker and bytecode VM) raise this at the
    /// *identical* event for the same program and budget.
    Fuel {
        /// Fuel units charged before the budget ran out.
        spent: u64,
        /// Ordinal of the billable event that could not be afforded
        /// (equal to the count of successfully charged events).
        at_op: u64,
    },

    /// I/O failure (file system access).
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Ir(m) => write!(f, "ir error: {m}"),
            Error::Interface(m) => write!(f, "interface constraint violated: {m}"),
            Error::Synthesis(m) => write!(f, "synthesis error: {m}"),
            Error::Egraph(m) => write!(f, "egraph error: {m}"),
            Error::Compiler(m) => write!(f, "compiler error: {m}"),
            Error::Sim(m) => write!(f, "simulation error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Manifest(m) => write!(f, "manifest error: {m}"),
            Error::Fuel { spent, at_op } => {
                write!(f, "fuel exhausted: {spent} units spent, stopped at op {at_op}")
            }
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_by_layer() {
        assert_eq!(Error::Ir("x".into()).to_string(), "ir error: x");
        assert_eq!(Error::Manifest("y".into()).to_string(), "manifest error: y");
        assert!(Error::Interface("z".into()).to_string().contains("constraint"));
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(e.to_string().contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
