//! Crate-wide error type.

use thiserror::Error;

/// Errors produced anywhere in the Aquas stack.
#[derive(Debug, Error)]
pub enum Error {
    /// IR construction or verification failure.
    #[error("ir error: {0}")]
    Ir(String),

    /// A memory transaction violates the microarchitectural constraints of
    /// its bound interface (§4.1: beat count, alignment, in-flight limit).
    #[error("interface constraint violated: {0}")]
    Interface(String),

    /// Synthesis-time optimization failure (§4.3).
    #[error("synthesis error: {0}")]
    Synthesis(String),

    /// E-graph or rewrite failure (§5.2–5.3).
    #[error("egraph error: {0}")]
    Egraph(String),

    /// Compiler matching/lowering failure (§5.4).
    #[error("compiler error: {0}")]
    Compiler(String),

    /// Cycle-level simulation failure.
    #[error("simulation error: {0}")]
    Sim(String),

    /// PJRT runtime failure (artifact loading / execution).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Serving-coordinator failure.
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// Artifact manifest problems.
    #[error("manifest error: {0}")]
    Manifest(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),

    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
