//! # Aquas — holistic hardware–software co-optimization for ASIPs
//!
//! Reproduction of *"Aquas: Enhancing Domain Specialization through Holistic
//! Hardware-Software Co-Optimization based on MLIR"* (CS.AR 2025) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! - **Layer 3 (this crate)** — the Aquas framework: the multi-level
//!   [`ir`](crate::ir) (Aquas-IR), the [`interface`](crate::interface)
//!   memory-interface model (§4.1), the [`synthesis`](crate::synthesis)
//!   flow (§4.3), the [`egraph`](crate::egraph)-based
//!   [`compiler`](crate::compiler) (§5), cycle-level [`cores`](crate::cores)
//!   simulators, the [`area`](crate::area) model, the four case-study
//!   [`workloads`](crate::workloads) (§6), and the LLM serving
//!   [`coordinator`](crate::coordinator) that drives AOT artifacts through
//!   the [`runtime`](crate::runtime) (a pure-Rust executor standing in for
//!   PJRT on this offline image; see `runtime/sim.rs`).
//! - **Layer 2 (build-time)** — `python/compile/model.py`: a Llama-style
//!   transformer in JAX, lowered once to HLO text.
//! - **Layer 1 (build-time)** — `python/compile/kernels/`: Pallas kernels
//!   modelling each ISAX datapath, verified against pure-jnp oracles.
//!
//! Python never runs on the request path: `make artifacts` produces
//! `artifacts/*.hlo.txt`, and the Rust binary is self-contained after that
//! — or entirely without it, via the runtime's simulated fallback.
//!
//! # Worked example: build IR → synthesize → execute
//!
//! The shortest end-to-end path through the stack: author a
//! functional-level ISAX with [`ir::FuncBuilder`], run the §4.3
//! synthesis pipeline against an interface set, and execute the
//! resulting temporal-level program with the reference interpreter.
//!
//! ```
//! use aquas::interface::cache::CacheHint;
//! use aquas::interface::model::InterfaceSet;
//! use aquas::ir::interp::{run, Memory};
//! use aquas::ir::FuncBuilder;
//! use aquas::runtime::DType;
//! use aquas::synthesis::{scheduling, synthesize, SynthOptions};
//!
//! // Functional level: stage 32 cold floats into a scratchpad, double
//! // them in place, stream the result back out.
//! let mut b = FuncBuilder::new("doubler");
//! let src = b.global("src", DType::F32, 32, CacheHint::Cold);
//! let out = b.global("out", DType::F32, 32, CacheHint::Warm);
//! let tile = b.scratchpad("tile", DType::F32, 32, 1);
//! let zero = b.const_i(0);
//! b.transfer(tile, zero, src, zero, 128);
//! b.for_range(0, 32, 1, |b, i| {
//!     let x = b.read_smem(tile, i);
//!     let two = b.const_f(2.0);
//!     let y = b.mul(x, two);
//!     b.write_smem(tile, i, y);
//! });
//! b.transfer(out, zero, tile, zero, 128);
//! let func = b.finish(&[]);
//!
//! // §4.3 synthesis: elision → interface selection → transaction
//! // scheduling, against the default Rocket core-port + system-bus pair.
//! let itfcs = InterfaceSet::rocket_default();
//! let synth = synthesize(&func, &itfcs, &SynthOptions::default()).unwrap();
//! assert!(synth.schedule.mem_latency() > 0);
//!
//! // The event-driven DMA replay agrees with the closed form when
//! // nothing contends (see `interface::dmasim`).
//! let sim = scheduling::simulate_schedule(&synth.schedule, &itfcs).unwrap();
//! assert_eq!(sim.makespan, synth.schedule.mem_latency());
//!
//! // The temporal-level program still computes the same function.
//! let mut mem = Memory::for_func(&synth.temporal);
//! mem.write_f32(synth.temporal.buffer_by_name("src").unwrap(), &[1.5; 32]);
//! run(&synth.temporal, &[], &mut mem).unwrap();
//! let result = mem.read_f32(synth.temporal.buffer_by_name("out").unwrap());
//! assert_eq!(result, vec![3.0; 32]);
//! ```

pub mod area;
pub mod bench_harness;
pub mod compiler;
pub mod coordinator;
pub mod cores;
pub mod dse;
pub mod egraph;
pub mod error;
pub mod interface;
pub mod ir;
pub mod runtime;
pub mod synthesis;
pub mod util;
pub mod workloads;

pub use error::{Error, Result};
