//! # Aquas — holistic hardware–software co-optimization for ASIPs
//!
//! Reproduction of *"Aquas: Enhancing Domain Specialization through Holistic
//! Hardware-Software Co-Optimization based on MLIR"* (CS.AR 2025) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! - **Layer 3 (this crate)** — the Aquas framework: the multi-level
//!   [`ir`](crate::ir) (Aquas-IR), the [`interface`](crate::interface)
//!   memory-interface model (§4.1), the [`synthesis`](crate::synthesis)
//!   flow (§4.3), the [`egraph`](crate::egraph)-based
//!   [`compiler`](crate::compiler) (§5), cycle-level [`cores`](crate::cores)
//!   simulators, the [`area`](crate::area) model, the four case-study
//!   [`workloads`](crate::workloads) (§6), and the LLM serving
//!   [`coordinator`](crate::coordinator) that drives AOT artifacts through
//!   the [`runtime`](crate::runtime) (a pure-Rust executor standing in for
//!   PJRT on this offline image; see `runtime/sim.rs`).
//! - **Layer 2 (build-time)** — `python/compile/model.py`: a Llama-style
//!   transformer in JAX, lowered once to HLO text.
//! - **Layer 1 (build-time)** — `python/compile/kernels/`: Pallas kernels
//!   modelling each ISAX datapath, verified against pure-jnp oracles.
//!
//! Python never runs on the request path: `make artifacts` produces
//! `artifacts/*.hlo.txt`, and the Rust binary is self-contained after that
//! — or entirely without it, via the runtime's simulated fallback.

pub mod area;
pub mod bench_harness;
pub mod compiler;
pub mod coordinator;
pub mod cores;
pub mod egraph;
pub mod error;
pub mod interface;
pub mod ir;
pub mod runtime;
pub mod synthesis;
pub mod util;
pub mod workloads;

pub use error::{Error, Result};
