//! Area and timing model (the §6.1 "commercial tool targeting a 130 nm
//! process at the RocketTile level" substitute).
//!
//! Absolute numbers are calibrated to the paper's anchors:
//! - baseline RocketTile: **4.11 mm²** at **232 MHz** (130 nm);
//! - BOOMv3: 4.24× Rocket's area, −7.3% frequency;
//! - Saturn (VLEN=128): +75% area, −35% frequency; −26% of the overhead
//!   is the FP half;
//! - Aquas ISAXs: single-digit-to-~23% area overhead with **zero**
//!   frequency degradation (the generated unit is pipelined off the
//!   core's critical path; only pathologically deep combinational
//!   datapaths would intrude).
//!
//! The per-FU/SRAM/engine coefficients below are in mm² (130 nm-ish cell
//! sizes) so that our case-study ISAXs land in the paper's overhead range.

use crate::synthesis::hwgen::PipelineDesc;

/// The baseline RocketTile (§6.1).
pub const ROCKET_AREA_MM2: f64 = 4.11;
pub const ROCKET_FREQ_MHZ: f64 = 232.0;

/// Area/timing coefficients (130 nm).
#[derive(Debug, Clone, Copy)]
pub struct AreaModel {
    pub adder_mm2: f64,
    pub multiplier_mm2: f64,
    pub divider_mm2: f64,
    pub shifter_mm2: f64,
    pub logic_mm2: f64,
    pub comparator_mm2: f64,
    pub fp_unit_mm2: f64,
    /// Per KiB of scratchpad SRAM (single bank).
    pub sram_kib_mm2: f64,
    /// Extra wiring/decoder per additional bank.
    pub bank_overhead_mm2: f64,
    /// Per memory-access engine, plus per byte of interface width.
    pub engine_base_mm2: f64,
    pub engine_per_byte_mm2: f64,
    /// Pipeline/control overhead per stage + per arbiter.
    pub stage_mm2: f64,
    pub arbiter_mm2: f64,
    /// Datapath depth (FU levels) the 232 MHz clock absorbs before the
    /// unit needs an extra pipeline register (which we add for free) —
    /// frequency only degrades past `depth_freq_limit` with unpipelineable
    /// feedback, which our generator never produces.
    pub depth_freq_limit: u64,
}

impl Default for AreaModel {
    fn default() -> Self {
        Self {
            adder_mm2: 0.0028,
            multiplier_mm2: 0.016,
            divider_mm2: 0.030,
            shifter_mm2: 0.0022,
            logic_mm2: 0.0012,
            comparator_mm2: 0.0018,
            fp_unit_mm2: 0.024,
            sram_kib_mm2: 0.062,
            bank_overhead_mm2: 0.004,
            engine_base_mm2: 0.018,
            engine_per_byte_mm2: 0.0016,
            stage_mm2: 0.003,
            arbiter_mm2: 0.0025,
            depth_freq_limit: 64,
        }
    }
}

/// Area/frequency report for one design point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AreaReport {
    pub area_mm2: f64,
    pub freq_mhz: f64,
}

impl AreaReport {
    /// Percent overhead vs the bare Rocket tile.
    pub fn area_overhead_pct(&self) -> f64 {
        (self.area_mm2 - ROCKET_AREA_MM2) / ROCKET_AREA_MM2 * 100.0
    }

    /// Percent change in minimum clock period vs baseline (positive =
    /// slower clock).
    pub fn period_delta_pct(&self) -> f64 {
        (ROCKET_FREQ_MHZ / self.freq_mhz - 1.0) * 100.0
    }
}

impl AreaModel {
    /// Area of one generated ISAX unit.
    pub fn isax_area(&self, desc: &PipelineDesc) -> f64 {
        let mut a = 0.0;
        for s in &desc.stages {
            a += self.stage_mm2;
            a += s.arbiters as f64 * self.arbiter_mm2;
            a += s.fus.adders as f64 * self.adder_mm2;
            a += s.fus.multipliers as f64 * self.multiplier_mm2;
            a += s.fus.dividers as f64 * self.divider_mm2;
            a += s.fus.shifters as f64 * self.shifter_mm2;
            a += s.fus.logic as f64 * self.logic_mm2;
            a += s.fus.comparators as f64 * self.comparator_mm2;
            a += s.fus.fp_units as f64 * self.fp_unit_mm2;
        }
        for m in &desc.srams {
            a += m.bytes as f64 / 1024.0 * self.sram_kib_mm2;
            a += m.banks.saturating_sub(1) as f64 * self.bank_overhead_mm2;
        }
        for e in &desc.engines {
            a += self.engine_base_mm2 + e.width as f64 * self.engine_per_byte_mm2;
        }
        a
    }

    /// Tile report for Rocket + a set of ISAX units.
    pub fn rocket_with_isaxes(&self, descs: &[&PipelineDesc]) -> AreaReport {
        let isax: f64 = descs.iter().map(|d| self.isax_area(d)).sum();
        let max_depth = descs.iter().map(|d| d.datapath_depth).max().unwrap_or(0);
        // Zero frequency degradation while the generated pipeline stays
        // within the re-pipelineable regime (§6: "+0.0%" columns).
        let freq = if max_depth <= self.depth_freq_limit {
            ROCKET_FREQ_MHZ
        } else {
            ROCKET_FREQ_MHZ * 0.95
        };
        AreaReport { area_mm2: ROCKET_AREA_MM2 + isax, freq_mhz: freq }
    }

    /// Bare Rocket.
    pub fn rocket(&self) -> AreaReport {
        AreaReport { area_mm2: ROCKET_AREA_MM2, freq_mhz: ROCKET_FREQ_MHZ }
    }

    /// BOOMv3 tile (§6.3: 4.24× area, −7.3% frequency).
    pub fn boom(&self) -> AreaReport {
        AreaReport { area_mm2: ROCKET_AREA_MM2 * 4.24, freq_mhz: ROCKET_FREQ_MHZ * (1.0 - 0.073) }
    }

    /// Rocket + Saturn VLEN=128 (§6.4: +75% area, −35% frequency).
    pub fn saturn(&self) -> AreaReport {
        AreaReport { area_mm2: ROCKET_AREA_MM2 * 1.75, freq_mhz: ROCKET_FREQ_MHZ * (1.0 - 0.35) }
    }

    /// Saturn with the unused FP half stripped (−26% of the tile).
    pub fn saturn_int_only(&self) -> AreaReport {
        AreaReport {
            area_mm2: ROCKET_AREA_MM2 * 1.75 * (1.0 - 0.26),
            freq_mhz: ROCKET_FREQ_MHZ * (1.0 - 0.35),
        }
    }
}

/// FPGA resource model for the §6.5 prototype (Xilinx XC7Z045).
#[derive(Debug, Clone, Copy)]
pub struct FpgaModel {
    pub total_luts: u64,
    pub total_ffs: u64,
    pub total_bram_kb: u64,
    pub total_dsps: u64,
}

impl Default for FpgaModel {
    fn default() -> Self {
        // XC7Z045: 350K logic cells (~218K LUTs), 437K FFs, 2180 KB BRAM
        // (19.1 Mb incl. parity; the paper quotes 17.6 Mb usable), 900 DSPs.
        Self { total_luts: 218_600, total_ffs: 437_200, total_bram_kb: 2_180, total_dsps: 900 }
    }
}

/// FPGA resource usage of one ISAX unit.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FpgaUsage {
    pub luts: u64,
    pub ffs: u64,
    pub bram_kb: u64,
    pub dsps: u64,
}

impl FpgaModel {
    /// Estimate usage from a pipeline description (LUT/FF per FU class,
    /// BRAM from scratchpads, DSP from multipliers).
    pub fn usage(&self, desc: &PipelineDesc) -> FpgaUsage {
        let mut u = FpgaUsage::default();
        for s in &desc.stages {
            u.luts += 150; // stage control
            u.ffs += 220;
            u.luts += s.arbiters as u64 * 90;
            u.luts += s.fus.adders as u64 * 100
                + s.fus.multipliers as u64 * 180 // int8 partial products in LUTs
                + s.fus.shifters as u64 * 60
                + s.fus.logic as u64 * 60
                + s.fus.comparators as u64 * 80
                + s.fus.dividers as u64 * 1100
                + s.fus.fp_units as u64 * 900;
            u.ffs += s.fus.total() as u64 * 160;
            u.dsps += s.fus.multipliers as u64 * 2 + s.fus.fp_units as u64 * 2;
        }
        for m in &desc.srams {
            u.bram_kb += (m.bytes as u64).div_ceil(1024).max(2); // BRAM18 granularity
            u.luts += m.banks as u64 * 60; // bank mux/decoder
        }
        for e in &desc.engines {
            u.luts += 1500 + e.width as u64 * 80;
            u.ffs += 2500 + e.width as u64 * 128;
        }
        u
    }

    /// Percentages of the device.
    pub fn utilization(&self, u: &FpgaUsage) -> (f64, f64, f64, f64) {
        (
            u.luts as f64 / self.total_luts as f64 * 100.0,
            u.ffs as f64 / self.total_ffs as f64 * 100.0,
            u.bram_kb as f64 / self.total_bram_kb as f64 * 100.0,
            u.dsps as f64 / self.total_dsps as f64 * 100.0,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anchors_match_paper() {
        let m = AreaModel::default();
        assert!((m.boom().area_mm2 / ROCKET_AREA_MM2 - 4.24).abs() < 1e-9);
        assert!((m.boom().period_delta_pct() - 7.87).abs() < 0.2); // 1/(1-0.073)-1
        assert!((m.saturn().area_mm2 / ROCKET_AREA_MM2 - 1.75).abs() < 1e-9);
        assert_eq!(m.rocket().area_overhead_pct(), 0.0);
    }

    #[test]
    fn saturn_int_only_saves_26_pct() {
        let m = AreaModel::default();
        let full = m.saturn().area_mm2;
        let int = m.saturn_int_only().area_mm2;
        assert!((1.0 - int / full - 0.26).abs() < 1e-9);
    }

    #[test]
    fn isax_area_overhead_in_paper_range() {
        // A representative ISAX: a handful of FUs + 1 KiB scratchpad + two
        // engines must land in the single-digit-% overhead band.
        use crate::synthesis::hwgen::*;
        let desc = PipelineDesc {
            name: "demo".into(),
            stages: vec![
                StageDesc { name: "decode".into(), fus: FuCount::default(), arbiters: 0 },
                StageDesc {
                    name: "compute".into(),
                    fus: FuCount { adders: 8, multipliers: 4, ..Default::default() },
                    arbiters: 1,
                },
            ],
            srams: vec![SramDesc { name: "s".into(), bytes: 1024, banks: 2 }],
            engines: vec![
                MemEngineDesc {
                    itfc_name: "@cpuitfc".into(),
                    width: 4,
                    burst: false,
                    tracker_depth: 1,
                    misalign_fallback: true,
                },
                MemEngineDesc {
                    itfc_name: "@busitfc".into(),
                    width: 8,
                    burst: true,
                    tracker_depth: 2,
                    misalign_fallback: true,
                },
            ],
            initiation_interval: 1,
            datapath_depth: 4,
        };
        let m = AreaModel::default();
        let rep = m.rocket_with_isaxes(&[&desc]);
        let ovh = rep.area_overhead_pct();
        assert!(ovh > 0.5 && ovh < 23.0, "overhead {ovh}%");
        assert_eq!(rep.period_delta_pct(), 0.0);
    }

    #[test]
    fn fpga_usage_scales_with_srams() {
        use crate::synthesis::hwgen::*;
        let mk = |kib: usize| PipelineDesc {
            name: "x".into(),
            stages: vec![],
            srams: vec![SramDesc { name: "s".into(), bytes: kib * 1024, banks: 1 }],
            engines: vec![],
            initiation_interval: 1,
            datapath_depth: 1,
        };
        let f = FpgaModel::default();
        let small = f.usage(&mk(16));
        let big = f.usage(&mk(256));
        assert!(big.bram_kb > small.bram_kb * 8);
    }
}
