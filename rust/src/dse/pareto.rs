//! Deterministic Pareto machinery over the (cycles, area) objectives.
//!
//! Cycles is an exact `u64` (dmasim replay + engine model); area is the
//! `f64` the census pricing produces. Both are pure functions of the
//! candidate, so all comparisons here — including the `total_cmp` tie
//! ordering — are bitwise reproducible run to run.

use super::cost::PointCost;

/// Strict Pareto dominance: `a` is no worse on both objectives and
/// strictly better on at least one.
pub fn dominates(a: &PointCost, b: &PointCost) -> bool {
    weakly_dominates(a, b) && (a.cycles < b.cycles || a.area_mm2 < b.area_mm2)
}

/// Weak dominance: `a` is no worse than `b` on both objectives.
pub fn weakly_dominates(a: &PointCost, b: &PointCost) -> bool {
    a.cycles <= b.cycles && a.area_mm2 <= b.area_mm2
}

/// The non-dominated subset of `points`, in (cycles asc, area asc, key)
/// order. Cost ties keep a single representative — the first by key —
/// so the frontier is both mutually non-dominated *and* duplicate-free:
/// for any two members, neither weakly dominates the other.
pub fn frontier(points: &[PointCost]) -> Vec<PointCost> {
    let mut sorted: Vec<&PointCost> = points.iter().collect();
    sorted.sort_by(|x, y| {
        x.cycles
            .cmp(&y.cycles)
            .then(x.area_mm2.total_cmp(&y.area_mm2))
            .then_with(|| x.point.key().cmp(&y.point.key()))
    });
    let mut out: Vec<PointCost> = Vec::new();
    for p in sorted {
        if !out.iter().any(|q| weakly_dominates(q, p)) {
            out.push(p.clone());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::dse::space::DesignPoint;

    fn pc(cycles: u64, area: f64, width: usize) -> PointCost {
        PointCost {
            point: DesignPoint { width, ..DesignPoint::handpicked_default() },
            cycles,
            area_mm2: area,
            freq_mhz: 200.0,
            per_workload: Vec::new(),
        }
    }

    #[test]
    fn frontier_drops_dominated_and_duplicate_points() {
        let pts = vec![
            pc(100, 5.0, 4),
            pc(100, 5.0, 8),  // duplicate cost: one representative kept
            pc(90, 6.0, 16),  // frontier (faster, bigger)
            pc(120, 7.0, 32), // dominated by everything above
            pc(150, 4.0, 64), // frontier (slowest, smallest)
        ];
        let f = frontier(&pts);
        let cycles: Vec<u64> = f.iter().map(|p| p.cycles).collect();
        assert_eq!(cycles, vec![90, 100, 150]);
        for a in &f {
            for b in &f {
                if a.point != b.point {
                    assert!(!weakly_dominates(a, b) || !weakly_dominates(b, a));
                    assert!(!dominates(a, b), "{} dominates {}", a.point.key(), b.point.key());
                }
            }
        }
    }
}
