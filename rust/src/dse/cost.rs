//! The DSE cost oracle: every candidate is priced by the *existing*
//! pipeline — there is no second timing or area model anywhere.
//!
//! Per workload family, one candidate evaluation is:
//!
//! 1. [`specialize_isax`] — apply the point's ISAX-side knobs
//!    (scratchpad banking, FU-mix unroll) and run the budgeted PR-8
//!    mid-end (`ir::passes::optimize_with_budget`) — the "mid-end
//!    inside the DSE loop" headroom item;
//! 2. [`crate::synthesis::synthesize`] under the point's interface set
//!    (elision → selection → scheduling);
//! 3. [`crate::synthesis::hwgen::generate`] — the FU/SRAM/engine census
//!    whose [`crate::area::AreaModel`] pricing *is* the area objective;
//! 4. [`crate::synthesis::scheduling::simulate_schedule`] — the
//!    event-driven dmasim replay of the synthesized transaction
//!    schedule *is* the memory-cycle objective, plus the
//!    [`IsaxEngine`] compute/overhead terms for the datapath.
//!
//! The e-graph front-end runs once per software-backed family
//! ([`prove_offload`]): loop↔ISAX matching happens at the functional
//! level, so it is invariant across the hardware axes this search
//! sweeps; re-proving it per point would re-run an identical
//! saturation. `tests/dse.rs` pins the oracle differentially against
//! `simulate_schedule` and the hwgen census.

use crate::area::AreaModel;
use crate::compiler::{
    compile, loop_passes, matcher, CompileBudget, CompileOptions, IsaxDef,
};
use crate::cores::IsaxEngine;
use crate::error::{Error, Result};
use crate::ir::func::BufferKind;
use crate::ir::passes::{optimize_with_budget, OptLevel};
use crate::ir::Func;
use crate::synthesis::{hwgen, scheduling, synthesize, SynthOptions};
use crate::workloads::{llm, pcp, pqc};

use super::space::DesignPoint;

/// One jointly-searched workload family: the ISAX description plus
/// (when the family has one) the software program the e-graph
/// front-end offloads onto it.
pub struct DseWorkload {
    /// Family name (`gf2mm` / `attention` / `pqc` / `pcp`).
    pub name: &'static str,
    /// Base ISAX description; design-point knobs are applied to a clone.
    pub isax: Func,
    /// Software counterpart for the e-graph offload proof, if any
    /// (the attention tile is ISAX-only).
    pub software: Option<Func>,
    /// Synthesis knobs inherited from the family's case study.
    pub synth_opts: SynthOptions,
}

/// The four families evaluated jointly (§6 case studies): the PQC
/// GF(2) matrix multiply, the attention tile, the PQC bit unpack, and
/// the point-cloud distance kernel. Fixed, deterministic order.
pub fn workloads() -> Result<Vec<DseWorkload>> {
    let pqc_ks = pqc::kernels();
    let pcp_ks = pcp::kernels();
    let pick = |ks: &[crate::workloads::Kernel], name: &str| -> Result<(Func, Func, SynthOptions)> {
        let k = ks
            .iter()
            .find(|k| k.name == name)
            .ok_or_else(|| Error::Synthesis(format!("explore: workload kernel `{name}` missing")))?;
        Ok((k.isax.func.clone(), k.software.clone(), k.synth_opts.clone()))
    };
    let (gf2mm_isax, gf2mm_sw, gf2mm_opts) = pick(&pqc_ks, "mgf2mm")?;
    let (pqc_isax, pqc_sw, pqc_opts) = pick(&pqc_ks, "vdecomp")?;
    let (pcp_isax, pcp_sw, pcp_opts) = pick(&pcp_ks, "vdist3.vv")?;
    Ok(vec![
        DseWorkload {
            name: "gf2mm",
            isax: gf2mm_isax,
            software: Some(gf2mm_sw),
            synth_opts: gf2mm_opts,
        },
        DseWorkload {
            name: "attention",
            isax: llm::isax_attention_tile(8, 4),
            software: None,
            synth_opts: SynthOptions::default(),
        },
        DseWorkload { name: "pqc", isax: pqc_isax, software: Some(pqc_sw), synth_opts: pqc_opts },
        DseWorkload { name: "pcp", isax: pcp_isax, software: Some(pcp_sw), synth_opts: pcp_opts },
    ])
}

/// Apply a design point's ISAX-side knobs — re-bank every scratchpad,
/// unroll the top compute loop by the FU-mix factor — then run the
/// budgeted mid-end. Returns verified IR. An unroll factor that does
/// not divide the top loop's static trip count is a diagnostic error;
/// the search records such points as infeasible and keeps going.
pub fn specialize_isax(isax: &Func, point: &DesignPoint, pass_rounds: usize) -> Result<Func> {
    let mut f = isax.clone();
    for b in &mut f.buffers {
        if let BufferKind::Scratchpad { .. } = b.kind {
            b.kind = BufferKind::Scratchpad { banks: point.banks };
        }
    }
    if point.unroll > 1 {
        if let Some(&top) = matcher::top_loops(&f).first() {
            f = loop_passes::apply(&f, top, loop_passes::LoopPass::Unroll(point.unroll))?;
        }
    }
    let (opt, _stats) = optimize_with_budget(&f, OptLevel::O2, pass_rounds)?;
    Ok(opt)
}

/// Per-family cost breakdown at one design point.
#[derive(Debug, Clone)]
pub struct WorkloadCost {
    /// Family name.
    pub name: &'static str,
    /// Makespan of the dmasim replay of the synthesized transaction
    /// schedule — the memory component, priced by the event-driven
    /// simulator, exactly (`scheduling::simulate_schedule`).
    pub sim_mem_cycles: u64,
    /// Port-conflict cycles the replay observed (diagnostics).
    pub conflict_cycles: u64,
    /// Compute-loop cycles from the [`IsaxEngine`] II model over the
    /// generated pipeline (banking stalls included).
    pub compute_cycles: u64,
    /// Fixed pipeline overhead (dispatch + writeback + stage gaps).
    pub overhead: u64,
    /// Standalone area of this family's generated unit
    /// (`AreaModel::isax_area` over the hwgen census).
    pub isax_area_mm2: f64,
}

impl WorkloadCost {
    /// Total cycles this family contributes to the joint objective.
    pub fn cycles(&self) -> u64 {
        self.sim_mem_cycles + self.compute_cycles + self.overhead
    }
}

/// Joint cost of one candidate point: cycles summed across the four
/// families, area of one SoC hosting all four generated units.
#[derive(Debug, Clone)]
pub struct PointCost {
    /// The candidate configuration.
    pub point: DesignPoint,
    /// Σ per-family cycles — the latency objective.
    pub cycles: u64,
    /// Rocket plus all four units (`AreaModel::rocket_with_isaxes`) —
    /// the area objective.
    pub area_mm2: f64,
    /// Post-ISAX clock estimate for the same SoC.
    pub freq_mhz: f64,
    /// Per-family breakdown, in `workloads()` order.
    pub per_workload: Vec<WorkloadCost>,
}

/// Evaluate one candidate through the real pipeline (see module docs).
/// Deterministic: a pure function of the point, workload set and
/// budget. Infeasible points (e.g. a non-dividing unroll factor)
/// return a diagnostic error naming the point and family.
pub fn evaluate_point(
    ws: &[DseWorkload],
    point: &DesignPoint,
    budget: &CompileBudget,
) -> Result<PointCost> {
    let itfcs = point.interfaces();
    let model = AreaModel::default();
    let mut per = Vec::with_capacity(ws.len());
    let mut descs = Vec::with_capacity(ws.len());
    for w in ws {
        let fail = |stage: &str, e: Error| {
            Error::Synthesis(format!("point {} / {} ({stage}): {e}", point.key(), w.name))
        };
        let spec = specialize_isax(&w.isax, point, budget.pass_rounds)
            .map_err(|e| fail("specialize", e))?;
        let synth = synthesize(&spec, &itfcs, &w.synth_opts).map_err(|e| fail("synthesize", e))?;
        let desc = hwgen::generate(&synth, &itfcs);
        let engine = IsaxEngine::from_synthesis(&synth, &desc, &itfcs);
        let sim = scheduling::simulate_schedule(&synth.schedule, &itfcs)
            .map_err(|e| fail("replay", e))?;
        per.push(WorkloadCost {
            name: w.name,
            sim_mem_cycles: sim.makespan,
            conflict_cycles: sim.conflict_cycles,
            compute_cycles: engine.compute_cycles,
            overhead: engine.overhead,
            isax_area_mm2: model.isax_area(&desc),
        });
        descs.push(desc);
    }
    let refs: Vec<&hwgen::PipelineDesc> = descs.iter().collect();
    let soc = model.rocket_with_isaxes(&refs);
    let cycles = per.iter().map(WorkloadCost::cycles).sum();
    Ok(PointCost {
        point: *point,
        cycles,
        area_mm2: soc.area_mm2,
        freq_mhz: soc.freq_mhz,
        per_workload: per,
    })
}

/// Run the e-graph offload proof once per software-backed family: the
/// compiler must actually offload at least one loop onto the family's
/// ISAX under `budget`. Returns `(family, offloaded loop count)` pairs.
pub fn prove_offload(
    ws: &[DseWorkload],
    budget: &CompileBudget,
) -> Result<Vec<(&'static str, usize)>> {
    let mut proofs = Vec::new();
    for w in ws {
        if let Some(sw) = &w.software {
            let isax = IsaxDef { name: w.name.to_string(), func: w.isax.clone() };
            let opts = CompileOptions { budget: budget.clone(), opt_level: 0 };
            let res = compile(sw, &[isax], &opts)
                .map_err(|e| Error::Compiler(format!("explore: offload proof for `{}`: {e}", w.name)))?;
            if res.stats.matched.is_empty() {
                return Err(Error::Compiler(format!(
                    "explore: e-graph failed to offload `{}` onto its ISAX",
                    w.name
                )));
            }
            proofs.push((w.name, res.stats.matched.len()));
        }
    }
    Ok(proofs)
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    #[test]
    fn banks_knob_reaches_the_census_and_unroll_grows_the_datapath() {
        let ws = workloads().unwrap();
        let gf2mm = &ws[0];
        let base = DesignPoint::handpicked_default();
        let rebanked = DesignPoint { banks: 4, ..base };
        let b = specialize_isax(&gf2mm.isax, &base, 4).unwrap();
        let r = specialize_isax(&gf2mm.isax, &rebanked, 4).unwrap();
        let count_banks = |f: &Func| -> Vec<usize> {
            f.buffers
                .iter()
                .filter_map(|d| match d.kind {
                    BufferKind::Scratchpad { banks } => Some(banks),
                    BufferKind::Global => None,
                })
                .collect()
        };
        assert!(count_banks(&b).iter().all(|&k| k == 2));
        assert!(count_banks(&r).iter().all(|&k| k == 4));

        let unrolled = DesignPoint { unroll: 2, ..base };
        let itfcs = base.interfaces();
        let synth_b = synthesize(&b, &itfcs, &gf2mm.synth_opts).unwrap();
        let u = specialize_isax(&gf2mm.isax, &unrolled, 4).unwrap();
        let synth_u = synthesize(&u, &itfcs, &gf2mm.synth_opts).unwrap();
        let fu = |s: &crate::synthesis::SynthResult| {
            hwgen::generate(s, &itfcs)
                .stages
                .iter()
                .map(|st| st.fus.total())
                .sum::<usize>()
        };
        assert!(
            fu(&synth_u) > fu(&synth_b),
            "unroll must duplicate datapath FUs: {} vs {}",
            fu(&synth_u),
            fu(&synth_b)
        );
    }

    #[test]
    fn non_dividing_unroll_is_a_diagnostic_error() {
        let ws = workloads().unwrap();
        let attention = ws.iter().find(|w| w.name == "attention").unwrap();
        // The attention tile's top loop has 8 static trips; 16 cannot
        // divide it.
        let p = DesignPoint { unroll: 16, ..DesignPoint::handpicked_default() };
        let e = specialize_isax(&attention.isax, &p, 4);
        assert!(e.is_err(), "unroll(16) over 8 trips must be rejected");
    }
}
