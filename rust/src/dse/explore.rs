//! The deterministic search driver: candidate enumeration (exhaustive
//! for small spaces, seeded sampling beyond `sample_limit`), §6.1
//! baseline injection, per-point evaluation through the real pipeline,
//! optional area-budget filtering, and frontier assembly.
//!
//! Determinism story: enumeration order is a pure function of the axis
//! lists; sampling is a seeded xoshiro shuffle followed by a canonical
//! re-sort; the cost oracle is a pure function of (point, workloads,
//! budget); and the frontier uses a total order for ties. Two runs with
//! the same space/seed/budget therefore produce bitwise-identical
//! results — [`ExploreResult::fingerprint`] makes that checkable, and
//! `BENCH_dse.json`'s `frontier_deterministic` gate enforces it in CI.

use crate::compiler::CompileBudget;
use crate::error::{Error, Result};
use crate::util::rng::Rng;

use super::cost::{evaluate_point, prove_offload, workloads, PointCost};
use super::pareto::{frontier, weakly_dominates};
use super::space::{DesignPoint, DesignSpace};

/// Search configuration. Build one with [`Explorer::demo`] /
/// [`Explorer::full`] and adjust fields before calling [`Explorer::run`].
#[derive(Debug, Clone)]
pub struct Explorer {
    /// The axes to sweep.
    pub space: DesignSpace,
    /// Seed for the sampling shuffle when the space exceeds
    /// `sample_limit`. Irrelevant (but recorded) for exhaustive runs.
    pub seed: u64,
    /// Maximum number of candidates to evaluate; larger spaces are
    /// sampled deterministically from this seed.
    pub sample_limit: usize,
    /// Compile-side budget: bounds the per-family e-graph offload proof
    /// and the per-point mid-end rounds, so no candidate can hang the
    /// search.
    pub budget: CompileBudget,
    /// Optional SoC area cap in mm²: points above it are excluded from
    /// the frontier (they stay in `evaluated` for inspection). Growing
    /// this cap can only grow the candidate pool, so the best-cycles
    /// point never worsens — the monotonicity property `tests/dse.rs`
    /// pins.
    pub area_budget_mm2: Option<f64>,
}

impl Explorer {
    /// Tier-1-affordable configuration: exhaustive over the 48-point
    /// demo space.
    pub fn demo() -> Self {
        Self {
            space: DesignSpace::demo(),
            seed: 0xA0A5,
            sample_limit: 64,
            budget: CompileBudget::default(),
            area_budget_mm2: None,
        }
    }

    /// The default CLI configuration: a seeded 64-point sample of the
    /// 540-point full space.
    pub fn full() -> Self {
        Self { space: DesignSpace::full(), ..Self::demo() }
    }

    /// Run the search end to end. Both hand-picked §6.1 configurations
    /// always ride along as candidates, so the frontier structurally
    /// weakly-dominates them (the `--check` gate still verifies it).
    /// Infeasible candidates (diagnostic errors from the oracle) are
    /// recorded and skipped, never fatal; a failure to evaluate a
    /// hand-picked baseline *is* fatal, since every gate compares
    /// against them.
    pub fn run(&self) -> Result<ExploreResult> {
        self.space.validate()?;
        let ws = workloads()?;
        let offload_proof = prove_offload(&ws, &self.budget)?;

        let mut pts = self.space.points();
        let sampled = pts.len() > self.sample_limit;
        if sampled {
            let mut rng = Rng::new(self.seed);
            rng.shuffle(&mut pts);
            pts.truncate(self.sample_limit);
            pts.sort(); // canonical order after the seeded draw
        }
        let handpicked = DesignPoint::handpicked();
        for b in &handpicked {
            if !pts.contains(b) {
                pts.push(*b);
            }
        }

        let mut evaluated = Vec::new();
        let mut infeasible = Vec::new();
        for p in &pts {
            match evaluate_point(&ws, p, &self.budget) {
                Ok(c) => evaluated.push(c),
                Err(e) => infeasible.push((p.key(), e.to_string())),
            }
        }

        let baselines: Vec<PointCost> = handpicked
            .iter()
            .filter_map(|b| evaluated.iter().find(|c| c.point == *b).cloned())
            .collect();
        if baselines.len() != handpicked.len() {
            return Err(Error::Synthesis(
                "explore: a hand-picked §6.1 baseline failed to evaluate".into(),
            ));
        }

        let pool: Vec<PointCost> = evaluated
            .iter()
            .filter(|c| self.area_budget_mm2.map_or(true, |cap| c.area_mm2 <= cap))
            .cloned()
            .collect();
        let front = frontier(&pool);

        Ok(ExploreResult {
            space_size: self.space.size(),
            sampled,
            seed: self.seed,
            evaluated,
            infeasible,
            frontier: front,
            baselines,
            offload_proof,
        })
    }
}

/// Everything one search run produced.
#[derive(Debug, Clone)]
pub struct ExploreResult {
    /// Cells in the requested cartesian space.
    pub space_size: usize,
    /// Whether the space exceeded `sample_limit` and was sampled.
    pub sampled: bool,
    /// The seed the run used (recorded for replay).
    pub seed: u64,
    /// Every feasible candidate's cost, in canonical candidate order.
    pub evaluated: Vec<PointCost>,
    /// `(point key, reason)` for every infeasible candidate.
    pub infeasible: Vec<(String, String)>,
    /// The cycles-vs-area Pareto frontier (within the area budget).
    pub frontier: Vec<PointCost>,
    /// The hand-picked §6.1 configurations' costs, in canonical order.
    pub baselines: Vec<PointCost>,
    /// `(family, offloaded loop count)` from the e-graph proof.
    pub offload_proof: Vec<(&'static str, usize)>,
}

impl ExploreResult {
    /// No frontier member dominates (even weakly) another.
    pub fn frontier_mutually_nondominated(&self) -> bool {
        for (i, a) in self.frontier.iter().enumerate() {
            for (j, b) in self.frontier.iter().enumerate() {
                if i != j && weakly_dominates(a, b) {
                    return false;
                }
            }
        }
        true
    }

    /// Every hand-picked §6.1 configuration is weakly dominated by some
    /// frontier member (i.e. the search found nothing worse than, and
    /// generally something better than, the hand tuning).
    pub fn frontier_covers_baselines(&self) -> bool {
        self.baselines
            .iter()
            .all(|b| self.frontier.iter().any(|f| weakly_dominates(f, b)))
    }

    /// Best (minimum) cycles over the evaluated pool within an area
    /// cap; `None` if nothing fits.
    pub fn best_cycles_within(&self, cap: Option<f64>) -> Option<u64> {
        self.evaluated
            .iter()
            .filter(|c| cap.map_or(true, |a| c.area_mm2 <= a))
            .map(|c| c.cycles)
            .min()
    }

    /// The frontier's fastest point.
    pub fn best_cycles_point(&self) -> Option<&PointCost> {
        self.frontier.iter().min_by_key(|c| c.cycles)
    }

    /// Bitwise-stable digest of the frontier: point key, exact cycles,
    /// and the raw IEEE-754 bits of the area. Two runs are "the same"
    /// iff these strings are equal.
    pub fn fingerprint(&self) -> String {
        self.frontier
            .iter()
            .map(|c| format!("{}#{}#{:016x}", c.point.key(), c.cycles, c.area_mm2.to_bits()))
            .collect::<Vec<_>>()
            .join(";")
    }
}
