//! Automated ASIP design-space exploration (`aquas explore`).
//!
//! The paper hand-picks its ASIP configuration (§6.1: a 64-bit burst-8
//! system bus, dual-banked scratchpads) and §6.3 tries one wide-bus
//! variant by hand. This module closes ROADMAP item 5 by searching that
//! space automatically — interface width × burst length × in-flight
//! window × SRAM banks × FU mix — evaluated **jointly** over four
//! workload families (gf2mm, attention, pqc, pcp), in the spirit of the
//! multi-application ASIP studies in PAPERS.md: a configuration tuned
//! for one kernel is rarely best for the suite.
//!
//! The layering:
//!
//! - [`space`] — axes, the `--space` spec parser (diagnostic errors,
//!   never panics), and deterministic enumeration;
//! - [`cost`] — the cost oracle: each candidate runs through the *real*
//!   pipeline (budgeted mid-end → synthesis → hwgen census → dmasim
//!   schedule replay); no second timing or area model anywhere;
//! - [`pareto`] — dominance and the deterministic frontier;
//! - [`explore`] — the search driver: sampling, §6.1 baseline
//!   injection, area-budget filtering, result assembly.
//!
//! Three properties are CI-gated (`BENCH_dse.json`) and property-tested
//! (`tests/dse.rs`): the frontier is bitwise deterministic for a given
//! seed/space, mutually non-dominated, and weakly dominates every
//! hand-picked §6.1 configuration.

#![warn(missing_docs)]
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod cost;
pub mod explore;
pub mod pareto;
pub mod space;

pub use cost::{
    evaluate_point, prove_offload, specialize_isax, workloads, DseWorkload, PointCost,
    WorkloadCost,
};
pub use explore::{ExploreResult, Explorer};
pub use pareto::{dominates, frontier, weakly_dominates};
pub use space::{DesignPoint, DesignSpace};
