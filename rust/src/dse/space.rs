//! Explore-space specification: the swept axes, the `--space` spec
//! parser, validation, and deterministic candidate enumeration.
//!
//! A [`DesignSpace`] is the cartesian product of five axes — system-bus
//! width × burst length × in-flight window × scratchpad banks × FU-mix
//! unroll — and a [`DesignPoint`] is one cell of that product. The
//! parser follows the repo's spec-string convention (`key=value` pairs
//! separated by commas, cf. `CompileBudget::parse` / `TraceSpec`):
//! values within one axis are separated by `|`, and `lo..hi` expands to
//! the ×2 geometric ladder from `lo` up to `hi` inclusive. Every
//! malformed input — unknown axis, zero value, empty axis, inverted or
//! absurd range, non-integer — is a diagnostic [`Error`], never a panic
//! (exercised by `tests/no_panic.rs`).

use crate::error::{Error, Result};
use crate::interface::model::{InterfaceSet, MemInterface};

/// Cap on bus width and burst length (bytes / beats). Wider than any
/// §4.1 interface the paper considers; beyond it a spec is rejected as
/// an absurd bound rather than silently swept.
pub const WIDTH_CAP: usize = 64;
/// Cap on the in-flight window, scratchpad banks and unroll factor.
pub const KNOB_CAP: usize = 16;

fn space_err(msg: String) -> Error {
    Error::Synthesis(format!("explore space: {msg}"))
}

/// One candidate ASIP configuration — a cell of the jointly-searched
/// space (§6.1 hand-picks two of these; `aquas explore` searches them).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DesignPoint {
    /// System-bus width in bytes per beat (`W_k`).
    pub width: usize,
    /// Maximum beats per bus transaction (`M_k`).
    pub burst: usize,
    /// Maximum in-flight bus transactions (`I_k`).
    pub in_flight: usize,
    /// Banking factor applied to every ISAX scratchpad (feeds both the
    /// hwgen SRAM census and the compute-II bank-conflict model).
    pub banks: usize,
    /// FU-mix knob: unroll factor applied to each ISAX's top compute
    /// loop before synthesis. `1` leaves the datapath as written; larger
    /// factors duplicate body FUs (more area) and cut trip counts.
    pub unroll: u64,
}

impl DesignPoint {
    /// The hand-picked §6.1 configuration: Rocket's 64-bit burst-8 bus
    /// with two in-flight transactions, dual-banked scratchpads, no
    /// extra unrolling (`InterfaceSet::rocket_default`).
    pub fn handpicked_default() -> Self {
        Self { width: 8, burst: 8, in_flight: 2, banks: 2, unroll: 1 }
    }

    /// The hand-picked §6.3 variant: the same ASIP on a 128-bit system
    /// bus (`InterfaceSet::rocket_wide_bus`).
    pub fn handpicked_wide_bus() -> Self {
        Self { width: 16, ..Self::handpicked_default() }
    }

    /// Both hand-picked configurations, in canonical order.
    pub fn handpicked() -> Vec<Self> {
        vec![Self::handpicked_default(), Self::handpicked_wide_bus()]
    }

    /// Stable display key (report rows, fingerprints, error messages).
    pub fn key(&self) -> String {
        format!(
            "w{}.b{}.i{}.k{}.u{}",
            self.width, self.burst, self.in_flight, self.banks, self.unroll
        )
    }

    /// The candidate interface set: the fixed RoCC-style core port plus
    /// this point's system bus. Latencies (`L_k`, `E_k`) and the cache
    /// line stay at their §6.1 values — the search sweeps the
    /// microarchitectural shape, not the physical memory technology.
    pub fn interfaces(&self) -> InterfaceSet {
        let bus = MemInterface {
            width: self.width,
            max_beats: self.burst,
            in_flight: self.in_flight,
            ..MemInterface::system_bus()
        };
        InterfaceSet::new(vec![MemInterface::cpu_port(), bus])
    }
}

/// The cartesian explore space: one sorted, deduplicated value list per
/// axis. Construct via [`DesignSpace::demo`], [`DesignSpace::full`] or
/// [`DesignSpace::parse`]; [`DesignSpace::validate`] re-checks any
/// hand-assembled instance.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DesignSpace {
    /// Bus-width candidates in bytes per beat (powers of two).
    pub widths: Vec<usize>,
    /// Burst-length candidates in beats (powers of two).
    pub bursts: Vec<usize>,
    /// In-flight window candidates.
    pub in_flights: Vec<usize>,
    /// Scratchpad banking candidates.
    pub banks: Vec<usize>,
    /// FU-mix unroll candidates.
    pub unrolls: Vec<u64>,
}

impl DesignSpace {
    /// The trimmed, tier-1-affordable space (48 points) used by
    /// `--demo`, the bench smoke mode and the property tests. Contains
    /// both hand-picked §6.1 configurations.
    pub fn demo() -> Self {
        Self {
            widths: vec![4, 8, 16],
            bursts: vec![1, 8],
            in_flights: vec![1, 2],
            banks: vec![1, 2],
            unrolls: vec![1, 2],
        }
    }

    /// The default CLI space (540 points; sampled down by the
    /// explorer's `sample_limit`).
    pub fn full() -> Self {
        Self {
            widths: vec![4, 8, 16, 32],
            bursts: vec![1, 2, 4, 8, 16],
            in_flights: vec![1, 2, 4],
            banks: vec![1, 2, 4],
            unrolls: vec![1, 2, 4],
        }
    }

    /// Parse a `--space` spec, overriding axes of [`DesignSpace::full`].
    /// Example: `width=4|8|16,burst=1..8,inflight=1|2,banks=1|2|4,unroll=1|2`.
    /// `lo..hi` is the ×2 ladder from `lo` to `hi` inclusive. Every
    /// malformed input is a diagnostic error; never panics.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut s = Self::full();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let Some((key, val)) = part.split_once('=') else {
                return Err(space_err(format!("`{part}`: expected axis=values")));
            };
            let (key, val) = (key.trim(), val.trim());
            let vals = parse_axis_values(key, val)?;
            match key {
                "width" => s.widths = vals.iter().map(|&v| v as usize).collect(),
                "burst" => s.bursts = vals.iter().map(|&v| v as usize).collect(),
                "inflight" => s.in_flights = vals.iter().map(|&v| v as usize).collect(),
                "banks" => s.banks = vals.iter().map(|&v| v as usize).collect(),
                "unroll" => s.unrolls = vals,
                other => {
                    return Err(space_err(format!(
                        "unknown axis `{other}` \
                         (expected width|burst|inflight|banks|unroll)"
                    )))
                }
            }
        }
        s.validate()?;
        Ok(s)
    }

    /// Check every axis: non-empty, no zeros, within caps, powers of
    /// two where §4.1 requires it (width, burst).
    pub fn validate(&self) -> Result<()> {
        check_axis("width", &to_u64(&self.widths), WIDTH_CAP as u64, true)?;
        check_axis("burst", &to_u64(&self.bursts), WIDTH_CAP as u64, true)?;
        check_axis("inflight", &to_u64(&self.in_flights), KNOB_CAP as u64, false)?;
        check_axis("banks", &to_u64(&self.banks), KNOB_CAP as u64, false)?;
        check_axis("unroll", &self.unrolls, KNOB_CAP as u64, false)?;
        Ok(())
    }

    /// Number of cells in the cartesian product.
    pub fn size(&self) -> usize {
        self.widths
            .len()
            .saturating_mul(self.bursts.len())
            .saturating_mul(self.in_flights.len())
            .saturating_mul(self.banks.len())
            .saturating_mul(self.unrolls.len())
    }

    /// All candidate points in canonical (axis-nested) order. The order
    /// is a pure function of the axis lists, so enumeration — and with
    /// it the whole search — is deterministic.
    pub fn points(&self) -> Vec<DesignPoint> {
        let mut out = Vec::with_capacity(self.size());
        for &width in &self.widths {
            for &burst in &self.bursts {
                for &in_flight in &self.in_flights {
                    for &banks in &self.banks {
                        for &unroll in &self.unrolls {
                            out.push(DesignPoint { width, burst, in_flight, banks, unroll });
                        }
                    }
                }
            }
        }
        out
    }
}

fn to_u64(vals: &[usize]) -> Vec<u64> {
    vals.iter().map(|&v| v as u64).collect()
}

fn check_axis(name: &str, vals: &[u64], cap: u64, pow2: bool) -> Result<()> {
    if vals.is_empty() {
        return Err(space_err(format!("axis `{name}` has no values (zero-sized axis)")));
    }
    for &v in vals {
        if v == 0 {
            return Err(space_err(format!("axis `{name}`: 0 is not a valid value")));
        }
        if v > cap {
            return Err(space_err(format!(
                "axis `{name}`: {v} exceeds the cap of {cap} (absurd bound)"
            )));
        }
        if pow2 && !v.is_power_of_two() {
            return Err(space_err(format!("axis `{name}`: {v} is not a power of two")));
        }
    }
    Ok(())
}

/// Parse one axis value list: `|`-separated integers and/or `lo..hi`
/// ×2 ladders. Sorted and deduplicated on return.
fn parse_axis_values(key: &str, val: &str) -> Result<Vec<u64>> {
    if val.is_empty() {
        return Err(space_err(format!("axis `{key}` has no values (zero-sized axis)")));
    }
    let mut out = Vec::new();
    for item in val.split('|').map(str::trim) {
        if item.is_empty() {
            return Err(space_err(format!("axis `{key}`: empty value in `{val}`")));
        }
        if let Some((lo, hi)) = item.split_once("..") {
            let (lo, hi) = (lo.trim(), hi.trim());
            let lo: u64 = lo
                .parse()
                .map_err(|_| space_err(format!("axis `{key}`: range start `{lo}` is not a positive integer")))?;
            let hi: u64 = hi
                .parse()
                .map_err(|_| space_err(format!("axis `{key}`: range end `{hi}` is not a positive integer")))?;
            if lo == 0 {
                return Err(space_err(format!("axis `{key}`: range must start at 1, not 0")));
            }
            if hi < lo {
                return Err(space_err(format!("axis `{key}`: empty range {lo}..{hi}")));
            }
            if hi > KNOB_CAP.max(WIDTH_CAP) as u64 {
                return Err(space_err(format!(
                    "axis `{key}`: range end {hi} is an absurd bound (cap {})",
                    KNOB_CAP.max(WIDTH_CAP)
                )));
            }
            let mut v = lo;
            while v <= hi {
                out.push(v);
                v *= 2;
            }
        } else {
            let n: u64 = item
                .parse()
                .map_err(|_| space_err(format!("axis `{key}`: `{item}` is not a positive integer")))?;
            out.push(n);
        }
    }
    out.sort_unstable();
    out.dedup();
    Ok(out)
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    #[test]
    fn handpicked_points_match_the_checked_in_interface_sets() {
        let d = DesignPoint::handpicked_default().interfaces();
        let r = InterfaceSet::rocket_default();
        for (id, itfc) in d.iter() {
            let other = r.get(id);
            assert_eq!(itfc.width, other.width);
            assert_eq!(itfc.max_beats, other.max_beats);
            assert_eq!(itfc.in_flight, other.in_flight);
            assert_eq!(itfc.read_lead, other.read_lead);
            assert_eq!(itfc.write_cost, other.write_cost);
        }
        let w = DesignPoint::handpicked_wide_bus().interfaces();
        let rw = InterfaceSet::rocket_wide_bus();
        for (id, itfc) in w.iter() {
            let other = rw.get(id);
            assert_eq!(itfc.width, other.width);
            assert_eq!(itfc.max_beats, other.max_beats);
            assert_eq!(itfc.in_flight, other.in_flight);
        }
    }

    #[test]
    fn parse_overrides_ranges_and_sorts() {
        let s = DesignSpace::parse("width=16|4|8,burst=1..8,unroll=2").unwrap();
        assert_eq!(s.widths, vec![4, 8, 16]);
        assert_eq!(s.bursts, vec![1, 2, 4, 8]);
        assert_eq!(s.unrolls, vec![2]);
        // Untouched axes keep the full() defaults.
        assert_eq!(s.in_flights, DesignSpace::full().in_flights);
    }

    #[test]
    fn hostile_specs_are_diagnostic_errors() {
        for spec in [
            "width=0",
            "width=",
            "width=7",
            "width=128",
            "burst=8..1",
            "burst=0..4",
            "burst=1..9999999",
            "banks=abc",
            "banks=-2",
            "unroll=1|0",
            "inflight=99",
            "frobnicate=4",
            "width",
            "width=4|",
        ] {
            let e = DesignSpace::parse(spec).expect_err(spec).to_string();
            assert!(e.contains("explore space"), "{spec}: {e}");
        }
    }

    #[test]
    fn enumeration_is_deterministic_and_sized() {
        let s = DesignSpace::demo();
        assert_eq!(s.points().len(), s.size());
        assert_eq!(s.points(), s.points());
        assert_eq!(s.size(), 48);
    }
}
