//! Cost-based extraction: pick one e-node per class minimizing a
//! user-defined cost, bottom-up to a fixpoint (handles cycles introduced
//! by unions). Used by the compiler's §5.3 heuristic cost model
//! (penalize non-affine ops, prefer ISAX markers) and by the
//! extract-to-run-MLIR-pass path of §5.2.

// Panic-free audit (robustness): extraction must degrade (return `None`)
// on unextractable classes, never abort. Test code is exempt.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::collections::HashMap;

use crate::egraph::graph::{ClassId, EGraph, ENode};

/// Cost of applying `sym` to children with the given costs. Return
/// `f64::INFINITY` to forbid a node.
pub type CostFn<'a> = &'a dyn Fn(&str, &[f64]) -> f64;

/// An extracted term (tree of symbols).
#[derive(Debug, Clone, PartialEq)]
pub struct Extracted {
    pub sym: String,
    pub children: Vec<Extracted>,
    pub cost: f64,
}

impl Extracted {
    /// Render as an s-expression (tests + debugging).
    pub fn to_sexp(&self) -> String {
        if self.children.is_empty() {
            self.sym.clone()
        } else {
            let kids: Vec<String> = self.children.iter().map(Extracted::to_sexp).collect();
            format!("({} {})", self.sym, kids.join(" "))
        }
    }
}

/// Extract the minimum-cost term for `root`.
/// Returns `None` if every node in the class is forbidden or unreachable.
/// Read-only: works over `&EGraph` (the engine's accessors borrow).
pub fn extract_best(g: &EGraph, root: ClassId, cost: CostFn<'_>) -> Option<Extracted> {
    let root = g.find(root);
    // Fixpoint: best known cost + node per class.
    let mut best: HashMap<ClassId, (f64, ENode)> = HashMap::new();
    let classes = g.class_ids();
    let mut child_costs: Vec<f64> = Vec::new();
    loop {
        let mut changed = false;
        for &c in &classes {
            for node in g.nodes(c) {
                child_costs.clear();
                let mut ok = true;
                for &ch in &node.children {
                    let ch = g.find(ch);
                    match best.get(&ch) {
                        Some(&(cc, _)) => child_costs.push(cc),
                        None => {
                            ok = false;
                            break;
                        }
                    }
                }
                if !ok {
                    continue;
                }
                let name = g.sym_name(node.sym);
                let c_total = cost(name, &child_costs);
                if !c_total.is_finite() {
                    continue;
                }
                let cur = best.get(&c).map(|&(x, _)| x).unwrap_or(f64::INFINITY);
                if c_total < cur {
                    best.insert(c, (c_total, node.clone()));
                    changed = true;
                }
            }
        }
        if !changed {
            break;
        }
    }
    build(g, root, &best)
}

fn build(
    g: &EGraph,
    c: ClassId,
    best: &HashMap<ClassId, (f64, ENode)>,
) -> Option<Extracted> {
    let c = g.find(c);
    let (cost, node) = best.get(&c)?.clone();
    let mut children = Vec::with_capacity(node.children.len());
    for &ch in &node.children {
        children.push(build(g, ch, best)?);
    }
    Some(Extracted { sym: g.sym_name(node.sym).to_string(), children, cost })
}

/// A simple additive cost: every node costs its table weight (default 1)
/// plus its children. Useful default for tests and the §5.3 model.
pub fn weighted_cost<'a>(
    weights: &'a HashMap<String, f64>,
) -> impl Fn(&str, &[f64]) -> f64 + 'a {
    move |sym, kids| {
        let own = weights.get(sym).copied().unwrap_or(1.0);
        own + kids.iter().sum::<f64>()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::egraph::rewrite::{Rewrite, Runner};

    #[test]
    fn picks_cheaper_variant() {
        let mut g = EGraph::new();
        let x = g.add_named("x", vec![]);
        let c2 = g.add_named("const:2", vec![]);
        let shl = g.add_named("shl", vec![x, c2]);
        let c4 = g.add_named("const:4", vec![]);
        let mul = g.add_named("mul", vec![x, c4]);
        g.union(shl, mul);
        g.rebuild();

        // Affine-friendly cost: shl is penalized (§5.3).
        let mut w = HashMap::new();
        w.insert("shl".to_string(), 10.0);
        w.insert("mul".to_string(), 1.0);
        let cost_fn = weighted_cost(&w);
        let out = extract_best(&g, shl, &cost_fn).unwrap();
        assert_eq!(out.sym, "mul");
    }

    #[test]
    fn handles_cycles_from_unions() {
        // x unioned with (id x): extraction must not loop forever.
        let mut g = EGraph::new();
        let x = g.add_named("x", vec![]);
        let idx = g.add_named("id", vec![x]);
        g.union(x, idx);
        g.rebuild();
        let w = HashMap::new();
        let cost_fn = weighted_cost(&w);
        let out = extract_best(&g, x, &cost_fn).unwrap();
        assert_eq!(out.sym, "x"); // the non-cyclic representative
    }

    #[test]
    fn forbidden_nodes_skipped() {
        let mut g = EGraph::new();
        let a = g.add_named("bad", vec![]);
        let b = g.add_named("good", vec![]);
        g.union(a, b);
        g.rebuild();
        let cost_fn = |sym: &str, kids: &[f64]| {
            if sym == "bad" {
                f64::INFINITY
            } else {
                1.0 + kids.iter().sum::<f64>()
            }
        };
        let out = extract_best(&g, a, &cost_fn).unwrap();
        assert_eq!(out.sym, "good");
    }

    #[test]
    fn extraction_after_saturation() {
        let mut g = EGraph::new();
        let x = g.add_named("x", vec![]);
        let zero = g.add_named("const:0", vec![]);
        let add = g.add_named("add", vec![x, zero]);
        let rules = vec![Rewrite::simple("add-zero", "(add ?x const:0)", "?x")];
        Runner::default().run(&mut g, &rules);
        let w = HashMap::new();
        let cost_fn = weighted_cost(&w);
        let out = extract_best(&g, add, &cost_fn).unwrap();
        assert_eq!(out.sym, "x");
        assert_eq!(out.cost, 1.0);
    }
}
