//! Pattern language + saturation engine (the "internal rewrites" of §5.3).
//!
//! Patterns are small s-expression trees over symbols and variables.
//! A [`Rewrite`] either instantiates a RHS pattern or runs a dynamic
//! callback (needed e.g. for constant arithmetic: `x << c → x * 2^c`).
//! The [`Runner`] applies all rules to saturation under iteration and
//! node-count limits — the paper's antidote to e-graph blowup.

use std::collections::HashMap;

use crate::egraph::graph::{ClassId, EGraph, ENode};

/// A pattern: variable or symbol application.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Pattern {
    /// Binds any e-class.
    Var(String),
    /// Symbol with sub-patterns.
    App(String, Vec<Pattern>),
}

impl Pattern {
    /// Parse a tiny s-expression: `(mul ?x (const:4))`, `?x`, `iv:0`.
    pub fn parse(text: &str) -> Pattern {
        let tokens = tokenize(text);
        let (p, rest) = parse_tokens(&tokens);
        assert!(rest.is_empty(), "trailing tokens in pattern {text:?}");
        p
    }

    /// Variables bound by this pattern.
    pub fn vars(&self) -> Vec<String> {
        let mut out = Vec::new();
        self.collect_vars(&mut out);
        out
    }

    fn collect_vars(&self, out: &mut Vec<String>) {
        match self {
            Pattern::Var(v) => {
                if !out.contains(v) {
                    out.push(v.clone());
                }
            }
            Pattern::App(_, kids) => kids.iter().for_each(|k| k.collect_vars(out)),
        }
    }
}

fn tokenize(text: &str) -> Vec<String> {
    text.replace('(', " ( ")
        .replace(')', " ) ")
        .split_whitespace()
        .map(str::to_string)
        .collect()
}

fn parse_tokens(tokens: &[String]) -> (Pattern, &[String]) {
    match tokens.first().map(String::as_str) {
        Some("(") => {
            let head = tokens[1].clone();
            let mut rest = &tokens[2..];
            let mut kids = Vec::new();
            while rest.first().map(String::as_str) != Some(")") {
                let (p, r) = parse_tokens(rest);
                kids.push(p);
                rest = r;
            }
            (Pattern::App(head, kids), &rest[1..])
        }
        Some(tok) if tok.starts_with('?') => {
            (Pattern::Var(tok[1..].to_string()), &tokens[1..])
        }
        Some(tok) => (Pattern::App(tok.to_string(), vec![]), &tokens[1..]),
        None => panic!("empty pattern"),
    }
}

/// Variable bindings from a successful match.
pub type Bindings = HashMap<String, ClassId>;

/// RHS action of a rule.
pub enum Action {
    /// Instantiate a pattern.
    Template(Pattern),
    /// Dynamic: given the e-graph + bindings, produce the replacement
    /// class (or None to skip this match).
    Dynamic(Box<dyn Fn(&mut EGraph, &Bindings) -> Option<ClassId> + Send + Sync>),
}

/// A named rewrite rule.
pub struct Rewrite {
    pub name: String,
    pub lhs: Pattern,
    pub action: Action,
}

impl Rewrite {
    /// `lhs => rhs` with both sides as pattern text.
    pub fn simple(name: &str, lhs: &str, rhs: &str) -> Self {
        Self {
            name: name.into(),
            lhs: Pattern::parse(lhs),
            action: Action::Template(Pattern::parse(rhs)),
        }
    }

    /// Dynamic rule.
    pub fn dynamic<F>(name: &str, lhs: &str, f: F) -> Self
    where
        F: Fn(&mut EGraph, &Bindings) -> Option<ClassId> + Send + Sync + 'static,
    {
        Self { name: name.into(), lhs: Pattern::parse(lhs), action: Action::Dynamic(Box::new(f)) }
    }
}

/// Match `pattern` against class `c`: extend `binds`, calling `sink` per
/// complete match.
pub fn match_pattern(
    g: &mut EGraph,
    pattern: &Pattern,
    c: ClassId,
    binds: &Bindings,
    sink: &mut Vec<Bindings>,
) {
    match pattern {
        Pattern::Var(v) => {
            let c = g.find(c);
            match binds.get(v) {
                Some(&bound) if g.find(bound) != c => {}
                _ => {
                    let mut b = binds.clone();
                    b.insert(v.clone(), c);
                    sink.push(b);
                }
            }
        }
        Pattern::App(name, kids) => {
            let Some(sym) = g.find_sym(name) else { return };
            let nodes = g.nodes_with_sym(c, sym, kids.len());
            for node in nodes {
                // Match children left-to-right, threading bindings.
                let mut states = vec![binds.clone()];
                for (kid_pat, &kid_cls) in kids.iter().zip(&node.children) {
                    let mut next = Vec::new();
                    for s in &states {
                        match_pattern(g, kid_pat, kid_cls, s, &mut next);
                    }
                    states = next;
                    if states.is_empty() {
                        break;
                    }
                }
                sink.extend(states);
            }
        }
    }
}

/// Instantiate a pattern under bindings.
pub fn instantiate(g: &mut EGraph, pattern: &Pattern, binds: &Bindings) -> ClassId {
    match pattern {
        Pattern::Var(v) => *binds.get(v).unwrap_or_else(|| panic!("unbound var ?{v}")),
        Pattern::App(name, kids) => {
            let children: Vec<ClassId> = kids.iter().map(|k| instantiate(g, k, binds)).collect();
            let sym = g.sym(name);
            g.add(ENode { sym, children })
        }
    }
}

/// Saturation report (feeds Table 3).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RunReport {
    pub iterations: usize,
    pub applied: usize,
    /// Applications per rule name.
    pub per_rule: Vec<(String, usize)>,
    pub saturated: bool,
    pub node_limit_hit: bool,
}

/// The saturation engine.
pub struct Runner {
    pub iter_limit: usize,
    pub node_limit: usize,
    /// Cap on matches applied per rule per iteration (backstop against a
    /// single combinatorial pattern flooding the graph).
    pub match_limit: usize,
}

impl Default for Runner {
    fn default() -> Self {
        Self { iter_limit: 16, node_limit: 50_000, match_limit: 10_000 }
    }
}

impl Runner {
    /// Apply `rules` to saturation (or limits). Returns the report.
    pub fn run(&self, g: &mut EGraph, rules: &[Rewrite]) -> RunReport {
        let mut report = RunReport {
            per_rule: rules.iter().map(|r| (r.name.clone(), 0)).collect(),
            ..Default::default()
        };
        for _ in 0..self.iter_limit {
            report.iterations += 1;
            if !self.run_one(g, rules, &mut report) {
                report.saturated = true;
                break;
            }
            if report.node_limit_hit {
                break;
            }
        }
        report
    }

    /// One iteration over all rules; returns true if anything changed.
    /// Exposed so callers (the matcher) can interleave match attempts with
    /// saturation rounds instead of paying for full saturation up front.
    pub fn run_one(&self, g: &mut EGraph, rules: &[Rewrite], report: &mut RunReport) -> bool {
        if report.per_rule.len() != rules.len() {
            report.per_rule = rules.iter().map(|r| (r.name.clone(), 0)).collect();
        }
        let mut any_change = false;
        for (ri, rule) in rules.iter().enumerate() {
            // Gather matches first (immutable phase), apply after.
            let classes = g.class_ids();
            let mut matches: Vec<(ClassId, Bindings)> = Vec::new();
            'collect: for c in classes {
                let mut sink = Vec::new();
                match_pattern(g, &rule.lhs, c, &HashMap::new(), &mut sink);
                for b in sink {
                    matches.push((c, b));
                    if matches.len() >= self.match_limit {
                        break 'collect;
                    }
                }
            }
            let mut rule_changed = false;
            for (c, binds) in matches {
                let replacement = match &rule.action {
                    Action::Template(rhs) => Some(instantiate(g, rhs, &binds)),
                    Action::Dynamic(f) => f(g, &binds),
                };
                if let Some(r) = replacement {
                    let before = g.find(c);
                    let after = g.find(r);
                    if before != after {
                        g.union(c, r);
                        any_change = true;
                        rule_changed = true;
                        report.applied += 1;
                        report.per_rule[ri].1 += 1;
                    }
                }
                // Node budget enforced *inside* the application loop: one
                // combinatorial rule must not flood the graph unchecked.
                if g.node_count() > self.node_limit {
                    report.node_limit_hit = true;
                    g.rebuild();
                    return any_change;
                }
            }
            if rule_changed {
                g.rebuild();
            }
        }
        any_change
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let p = Pattern::parse("(mul ?x (add ?y const:1))");
        assert_eq!(
            p,
            Pattern::App(
                "mul".into(),
                vec![
                    Pattern::Var("x".into()),
                    Pattern::App(
                        "add".into(),
                        vec![Pattern::Var("y".into()), Pattern::App("const:1".into(), vec![])]
                    )
                ]
            )
        );
    }

    #[test]
    fn commutativity_saturates() {
        let mut g = EGraph::new();
        let a = g.add_named("a", vec![]);
        let b = g.add_named("b", vec![]);
        let ab = g.add_named("mul", vec![a, b]);
        let ba = g.add_named("mul", vec![b, a]);
        assert_ne!(g.find(ab), g.find(ba));
        let rules = vec![Rewrite::simple("comm-mul", "(mul ?x ?y)", "(mul ?y ?x)")];
        let report = Runner::default().run(&mut g, &rules);
        assert!(report.saturated);
        assert_eq!(g.find(ab), g.find(ba));
    }

    #[test]
    fn shl_to_mul_dynamic() {
        let mut g = EGraph::new();
        let x = g.add_named("x", vec![]);
        let c2 = g.add_named("const:2", vec![]);
        let shl = g.add_named("shl", vec![x, c2]);
        // x << 2 => x * 4 (the §5.3 example)
        let rule = Rewrite::dynamic("shl-to-mul", "(shl ?x ?c)", |g, binds| {
            let c = binds["c"];
            let nodes = g.nodes(c);
            for n in nodes {
                let name = g.sym_name(n.sym).to_string();
                if let Some(v) = name.strip_prefix("const:") {
                    if let Ok(k) = v.parse::<i64>() {
                        if (0..=62).contains(&k) {
                            let x = binds["x"];
                            let cm = g.add_named(&format!("const:{}", 1i64 << k), vec![]);
                            return Some(g.add_named("mul", vec![x, cm]));
                        }
                    }
                }
            }
            None
        });
        let report = Runner::default().run(&mut g, &[rule]);
        assert_eq!(report.applied, 1);
        let c4 = g.add_named("const:4", vec![]);
        let mul = g.add_named("mul", vec![x, c4]);
        assert_eq!(g.find(shl), g.find(mul));
    }

    #[test]
    fn node_limit_stops_explosion() {
        let mut g = EGraph::new();
        let x = g.add_named("x", vec![]);
        g.add_named("f", vec![x]);
        // Genuinely generative rule: each application mints a fresh `g`
        // wrapper, so the graph grows without bound.
        let rule = Rewrite::simple("grow", "(f ?x)", "(f (g ?x))");
        let runner = Runner { iter_limit: 1000, node_limit: 50, ..Default::default() };
        let report = runner.run(&mut g, &[rule]);
        assert!(report.node_limit_hit);
        assert!(g.node_count() > 50);
    }

    #[test]
    fn nonlinear_pattern_requires_same_class() {
        let mut g = EGraph::new();
        let a = g.add_named("a", vec![]);
        let b = g.add_named("b", vec![]);
        let aa = g.add_named("sub", vec![a, a]);
        let ab = g.add_named("sub", vec![b, a]);
        // x - x => zero
        let rules = vec![Rewrite::simple("sub-self", "(sub ?x ?x)", "zero")];
        Runner::default().run(&mut g, &rules);
        let zero = g.add_named("zero", vec![]);
        assert_eq!(g.find(aa), g.find(zero));
        assert_ne!(g.find(ab), g.find(zero));
    }
}
