//! Pattern language + saturation engine (the "internal rewrites" of §5.3).
//!
//! Patterns are small s-expression trees over symbols and variables. Each
//! [`Rewrite`] compiles its LHS **once** into a flat instruction sequence
//! ([`CompiledPattern`]): variables become interned register slots, so a
//! match attempt runs over a fixed-size `[ClassId]` binding frame with no
//! string hashing and no `HashMap` cloning per branch. Searches seed from
//! the e-graph's symbol occurrence index — rules whose root symbol never
//! occurs cost one vector lookup, and rules never visit classes that
//! cannot match their root.
//!
//! A [`Rewrite`] either instantiates a compiled RHS template or runs a
//! dynamic callback (needed e.g. for constant arithmetic: `x << c →
//! x * 2^c`); only the dynamic path materializes a name-keyed [`Bindings`]
//! map, and only for frames that actually matched. The [`Runner`] applies
//! all rules to saturation under iteration and node-count limits — the
//! paper's antidote to e-graph blowup.

// Panic-free audit (robustness): malformed patterns must surface as
// `Error`, never abort the process. Test code is exempt.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::collections::HashMap;

use crate::egraph::graph::{ClassId, EGraph, ENode, SymId};
use crate::error::{Error, Result};

/// A pattern: variable or symbol application.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Pattern {
    /// Binds any e-class.
    Var(String),
    /// Symbol with sub-patterns.
    App(String, Vec<Pattern>),
}

impl Pattern {
    /// Parse a tiny s-expression: `(mul ?x (const:4))`, `?x`, `iv:0`.
    /// Panics on malformed text — for the compile-time rule tables in
    /// [`crate::compiler::rules`], where a bad pattern is a programming
    /// error. Anything user-controllable goes through [`Self::try_parse`].
    pub fn parse(text: &str) -> Pattern {
        match Self::try_parse(text) {
            Ok(p) => p,
            Err(e) => panic!("{e}"),
        }
    }

    /// Fallible parse: malformed text (empty input, unbalanced parens,
    /// a bare `?`, pathological nesting) is a diagnostic [`Error::Egraph`],
    /// never a panic.
    pub fn try_parse(text: &str) -> Result<Pattern> {
        let tokens = tokenize(text);
        let (p, rest) = try_parse_tokens(&tokens, text, 0)?;
        if !rest.is_empty() {
            return Err(Error::Egraph(format!("trailing tokens in pattern {text:?}")));
        }
        Ok(p)
    }

    /// Variables bound by this pattern.
    pub fn vars(&self) -> Vec<String> {
        let mut out = Vec::new();
        self.collect_vars(&mut out);
        out
    }

    fn collect_vars(&self, out: &mut Vec<String>) {
        match self {
            Pattern::Var(v) => {
                if !out.contains(v) {
                    out.push(v.clone());
                }
            }
            Pattern::App(_, kids) => kids.iter().for_each(|k| k.collect_vars(out)),
        }
    }
}

fn tokenize(text: &str) -> Vec<String> {
    text.replace('(', " ( ")
        .replace(')', " ) ")
        .split_whitespace()
        .map(str::to_string)
        .collect()
}

/// Nesting bound for [`Pattern::try_parse`]: the recursive-descent parser
/// recurses per `(`, so hostile input must not be able to blow the stack
/// (a stack overflow aborts the process and escapes `catch_unwind`).
const MAX_PATTERN_DEPTH: usize = 256;

fn try_parse_tokens<'t>(
    tokens: &'t [String],
    text: &str,
    depth: usize,
) -> Result<(Pattern, &'t [String])> {
    if depth > MAX_PATTERN_DEPTH {
        return Err(Error::Egraph(format!(
            "pattern nested deeper than {MAX_PATTERN_DEPTH}: {text:?}"
        )));
    }
    match tokens.first().map(String::as_str) {
        Some("(") => {
            let head = match tokens.get(1).map(String::as_str) {
                Some("(") | Some(")") | None => {
                    return Err(Error::Egraph(format!(
                        "expected symbol after `(` in pattern {text:?}"
                    )))
                }
                Some(h) => h.to_string(),
            };
            let mut rest = &tokens[2..];
            let mut kids = Vec::new();
            loop {
                match rest.first().map(String::as_str) {
                    Some(")") => break,
                    Some(_) => {
                        let (p, r) = try_parse_tokens(rest, text, depth + 1)?;
                        kids.push(p);
                        rest = r;
                    }
                    None => {
                        return Err(Error::Egraph(format!(
                            "unbalanced parens in pattern {text:?}"
                        )))
                    }
                }
            }
            Ok((Pattern::App(head, kids), &rest[1..]))
        }
        Some(tok) if tok.starts_with('?') => {
            if tok.len() == 1 {
                return Err(Error::Egraph(format!("bare `?` variable in pattern {text:?}")));
            }
            Ok((Pattern::Var(tok[1..].to_string()), &tokens[1..]))
        }
        Some(tok) => Ok((Pattern::App(tok.to_string(), vec![]), &tokens[1..])),
        None => Err(Error::Egraph(format!("empty pattern {text:?}"))),
    }
}

/// Variable bindings from a successful match. Only materialized for
/// dynamic rules (the template path works on raw register frames).
pub type Bindings = HashMap<String, ClassId>;

// ---------------------------------------------------------------------------
// Compiled LHS: a flat instruction sequence over a register frame.
// ---------------------------------------------------------------------------

/// One matching instruction. Registers hold e-class ids; the root class is
/// always register 0, and a `Bind` writes the matched node's children into
/// a contiguous register block (depth-first, so every register is written
/// before it is read).
#[derive(Debug, Clone, Copy)]
enum Inst {
    /// Iterate the nodes of class `regs[src]` with the given symbol and
    /// arity; for each, write its children into `regs[base..base+arity]`
    /// and continue (backtracking over node choices).
    Bind { src: usize, sym: usize, arity: usize, base: usize },
    /// Non-linear variable use: require `find(regs[a]) == find(regs[b])`.
    Compare { a: usize, b: usize },
}

/// An LHS pattern compiled to instructions. Symbols are referenced by
/// index into `sym_names` and resolved against a concrete e-graph once per
/// search (one hash lookup per distinct symbol, not per branch).
#[derive(Debug, Clone)]
pub struct CompiledPattern {
    insts: Vec<Inst>,
    n_regs: usize,
    /// (variable name, register) in first-occurrence order.
    vars: Vec<(String, usize)>,
    /// Distinct symbol names referenced by `Inst::Bind`.
    sym_names: Vec<String>,
    /// Index into `sym_names` of the root symbol (`None` = bare-var LHS,
    /// which matches every class).
    root_sym: Option<usize>,
}

impl CompiledPattern {
    pub fn compile(pattern: &Pattern) -> Self {
        let mut cp = CompiledPattern {
            insts: Vec::new(),
            n_regs: 1,
            vars: Vec::new(),
            sym_names: Vec::new(),
            root_sym: None,
        };
        match pattern {
            Pattern::Var(v) => cp.vars.push((v.clone(), 0)),
            Pattern::App(name, kids) => {
                let sym = cp.intern(name);
                cp.root_sym = Some(sym);
                let base = cp.alloc(kids.len());
                cp.insts.push(Inst::Bind { src: 0, sym, arity: kids.len(), base });
                for (i, k) in kids.iter().enumerate() {
                    cp.compile_sub(k, base + i);
                }
            }
        }
        cp
    }

    /// Registers a full match frame occupies.
    pub fn frame_len(&self) -> usize {
        self.n_regs
    }

    fn intern(&mut self, name: &str) -> usize {
        if let Some(i) = self.sym_names.iter().position(|n| n == name) {
            return i;
        }
        self.sym_names.push(name.to_string());
        self.sym_names.len() - 1
    }

    fn alloc(&mut self, n: usize) -> usize {
        let base = self.n_regs;
        self.n_regs += n;
        base
    }

    fn compile_sub(&mut self, p: &Pattern, reg: usize) {
        match p {
            Pattern::Var(v) => {
                match self.vars.iter().find(|(n, _)| n == v) {
                    Some(&(_, prev)) => self.insts.push(Inst::Compare { a: prev, b: reg }),
                    None => self.vars.push((v.clone(), reg)),
                }
            }
            Pattern::App(name, kids) => {
                let sym = self.intern(name);
                let base = self.alloc(kids.len());
                self.insts.push(Inst::Bind { src: reg, sym, arity: kids.len(), base });
                for (i, k) in kids.iter().enumerate() {
                    self.compile_sub(k, base + i);
                }
            }
        }
    }

    /// Resolve this pattern's symbol table against `g` without interning.
    fn resolve(&self, g: &EGraph) -> Vec<Option<SymId>> {
        self.sym_names.iter().map(|n| g.find_sym(n)).collect()
    }

    /// Seed classes: only classes whose node set contains the root symbol
    /// (from the occurrence index), or every class for a bare-var LHS.
    fn seeds(&self, g: &EGraph, syms: &[Option<SymId>]) -> Vec<ClassId> {
        match self.root_sym {
            Some(i) => match syms[i] {
                Some(s) => g.classes_with_sym(s),
                None => Vec::new(),
            },
            None => g.class_ids(),
        }
    }

    /// Match against every seed class, appending one frame of
    /// `frame_len()` registers per complete match (at most `limit`).
    pub fn search(&self, g: &EGraph, limit: usize) -> Vec<ClassId> {
        let syms = self.resolve(g);
        let mut frames = Vec::new();
        let mut regs = vec![ClassId(0); self.n_regs];
        for c in self.seeds(g, &syms) {
            regs[0] = c;
            if !self.exec(g, &syms, 0, &mut regs, &mut frames, limit) {
                break;
            }
        }
        frames
    }

    /// Execute from instruction `ip`; returns `false` once `limit` frames
    /// have been emitted (caller stops searching).
    fn exec(
        &self,
        g: &EGraph,
        syms: &[Option<SymId>],
        ip: usize,
        regs: &mut [ClassId],
        out: &mut Vec<ClassId>,
        limit: usize,
    ) -> bool {
        if ip == self.insts.len() {
            out.extend_from_slice(regs);
            return out.len() < limit * self.n_regs;
        }
        match self.insts[ip] {
            Inst::Compare { a, b } => {
                if g.find(regs[a]) != g.find(regs[b]) {
                    return true;
                }
                self.exec(g, syms, ip + 1, regs, out, limit)
            }
            Inst::Bind { src, sym, arity, base } => {
                let Some(sym) = syms[sym] else { return true };
                let cls = regs[src];
                for node in g.nodes(cls) {
                    if node.sym != sym || node.children.len() != arity {
                        continue;
                    }
                    regs[base..base + arity].copy_from_slice(&node.children);
                    if !self.exec(g, syms, ip + 1, regs, out, limit) {
                        return false;
                    }
                }
                true
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Compiled RHS: a post-order construction plan.
// ---------------------------------------------------------------------------

/// One step of RHS instantiation; children reference earlier steps.
#[derive(Debug, Clone)]
enum TStep {
    /// Copy an LHS register (variable reference).
    Var(usize),
    /// Add a node: symbol-table index + indices of earlier steps.
    App { sym: usize, kids: Vec<usize> },
}

/// An RHS pattern compiled against its LHS's variable registers.
#[derive(Debug, Clone)]
struct CompiledTemplate {
    steps: Vec<TStep>,
    sym_names: Vec<String>,
}

impl CompiledTemplate {
    fn compile(p: &Pattern, vars: &[(String, usize)]) -> Self {
        let mut t = CompiledTemplate { steps: Vec::new(), sym_names: Vec::new() };
        t.walk(p, vars);
        t
    }

    fn walk(&mut self, p: &Pattern, vars: &[(String, usize)]) -> usize {
        match p {
            Pattern::Var(v) => {
                let reg = vars
                    .iter()
                    .find(|(n, _)| n == v)
                    .unwrap_or_else(|| panic!("unbound var ?{v} in rhs"))
                    .1;
                self.steps.push(TStep::Var(reg));
            }
            Pattern::App(name, kids) => {
                let kid_steps: Vec<usize> = kids.iter().map(|k| self.walk(k, vars)).collect();
                let sym = match self.sym_names.iter().position(|n| n == name) {
                    Some(i) => i,
                    None => {
                        self.sym_names.push(name.to_string());
                        self.sym_names.len() - 1
                    }
                };
                self.steps.push(TStep::App { sym, kids: kid_steps });
            }
        }
        self.steps.len() - 1
    }

    /// Instantiate under a match frame. `syms` is this template's symbol
    /// table pre-interned into `g` (once per rule per iteration).
    fn apply(&self, g: &mut EGraph, syms: &[SymId], frame: &[ClassId]) -> ClassId {
        let mut vals: Vec<ClassId> = Vec::with_capacity(self.steps.len());
        for step in &self.steps {
            let v = match step {
                TStep::Var(reg) => frame[*reg],
                TStep::App { sym, kids } => {
                    let children: Vec<ClassId> = kids.iter().map(|&i| vals[i]).collect();
                    g.add(ENode { sym: syms[*sym], children })
                }
            };
            vals.push(v);
        }
        // `steps` is non-empty by construction (`compile` always walks at
        // least the root), so `last()` cannot miss.
        vals.last().copied().unwrap_or_else(|| unreachable!("non-empty template"))
    }
}

// ---------------------------------------------------------------------------
// Rules
// ---------------------------------------------------------------------------

/// Compiled RHS action of a rule.
enum Action {
    /// Instantiate a compiled template.
    Template(CompiledTemplate),
    /// Dynamic: given the e-graph + bindings, produce the replacement
    /// class (or None to skip this match).
    Dynamic(Box<dyn Fn(&mut EGraph, &Bindings) -> Option<ClassId> + Send + Sync>),
}

/// A named rewrite rule. Both sides are compiled once at construction —
/// the compiled forms are the single source of truth (no retained
/// uncompiled `Pattern` to drift out of sync with what actually runs).
pub struct Rewrite {
    pub name: String,
    prog: CompiledPattern,
    action: Action,
}

impl Rewrite {
    /// `lhs => rhs` with both sides as pattern text.
    pub fn simple(name: &str, lhs: &str, rhs: &str) -> Self {
        let lhs = Pattern::parse(lhs);
        let rhs = Pattern::parse(rhs);
        let prog = CompiledPattern::compile(&lhs);
        let template = CompiledTemplate::compile(&rhs, &prog.vars);
        Self { name: name.into(), prog, action: Action::Template(template) }
    }

    /// Dynamic rule.
    pub fn dynamic<F>(name: &str, lhs: &str, f: F) -> Self
    where
        F: Fn(&mut EGraph, &Bindings) -> Option<ClassId> + Send + Sync + 'static,
    {
        let lhs = Pattern::parse(lhs);
        let prog = CompiledPattern::compile(&lhs);
        Self { name: name.into(), prog, action: Action::Dynamic(Box::new(f)) }
    }

    /// The compiled LHS (exposed for benchmarks and diagnostics).
    pub fn compiled(&self) -> &CompiledPattern {
        &self.prog
    }

    /// Materialize name-keyed bindings from a register frame (dynamic
    /// rules only — the template path never builds a map).
    fn bindings(&self, g: &EGraph, frame: &[ClassId]) -> Bindings {
        self.prog
            .vars
            .iter()
            .map(|(name, reg)| (name.clone(), g.find(frame[*reg])))
            .collect()
    }
}

/// Instantiate a pattern under bindings (uncompiled path; kept for tests
/// and ad-hoc construction — the Runner uses compiled templates).
pub fn instantiate(g: &mut EGraph, pattern: &Pattern, binds: &Bindings) -> ClassId {
    match pattern {
        Pattern::Var(v) => *binds.get(v).unwrap_or_else(|| panic!("unbound var ?{v}")),
        Pattern::App(name, kids) => {
            let children: Vec<ClassId> = kids.iter().map(|k| instantiate(g, k, binds)).collect();
            let sym = g.sym(name);
            g.add(ENode { sym, children })
        }
    }
}

/// Saturation report (feeds Table 3).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RunReport {
    pub iterations: usize,
    pub applied: usize,
    /// Applications per rule name.
    pub per_rule: Vec<(String, usize)>,
    pub saturated: bool,
    pub node_limit_hit: bool,
    /// Some rule's search filled its per-iteration match budget
    /// ([`Runner::match_limit`]) at least once: the rule set may have
    /// more matches than were applied.
    pub match_limit_hit: bool,
}

/// The saturation engine.
pub struct Runner {
    pub iter_limit: usize,
    pub node_limit: usize,
    /// Cap on matches applied per rule per iteration (backstop against a
    /// single combinatorial pattern flooding the graph).
    pub match_limit: usize,
}

impl Default for Runner {
    fn default() -> Self {
        Self { iter_limit: 16, node_limit: 50_000, match_limit: 10_000 }
    }
}

impl Runner {
    /// Apply `rules` to saturation (or limits). Returns the report.
    pub fn run(&self, g: &mut EGraph, rules: &[Rewrite]) -> RunReport {
        let mut report = RunReport {
            per_rule: rules.iter().map(|r| (r.name.clone(), 0)).collect(),
            ..Default::default()
        };
        for _ in 0..self.iter_limit {
            report.iterations += 1;
            if !self.run_one(g, rules, &mut report) {
                report.saturated = true;
                break;
            }
            if report.node_limit_hit {
                break;
            }
        }
        report
    }

    /// One iteration over all rules; returns true if anything changed.
    /// Exposed so callers (the matcher) can interleave match attempts with
    /// saturation rounds instead of paying for full saturation up front.
    pub fn run_one(&self, g: &mut EGraph, rules: &[Rewrite], report: &mut RunReport) -> bool {
        if report.per_rule.len() != rules.len() {
            report.per_rule = rules.iter().map(|r| (r.name.clone(), 0)).collect();
        }
        let mut any_change = false;
        for (ri, rule) in rules.iter().enumerate() {
            // Search phase (shared borrow, seeded from the symbol index);
            // frames are flat [ClassId] blocks, the root class in slot 0.
            let frames = rule.prog.search(g, self.match_limit);
            if frames.is_empty() {
                continue;
            }
            let n_regs = rule.prog.frame_len();
            if frames.len() >= self.match_limit * n_regs {
                report.match_limit_hit = true;
            }
            // Intern template symbols once per rule per iteration, not per
            // applied match.
            let tsyms: Option<Vec<SymId>> = match &rule.action {
                Action::Template(t) => {
                    Some(t.sym_names.iter().map(|n| g.sym(n)).collect())
                }
                Action::Dynamic(_) => None,
            };
            let mut rule_changed = false;
            for frame in frames.chunks(n_regs) {
                let c = frame[0];
                let replacement = match (&rule.action, &tsyms) {
                    (Action::Template(t), Some(ts)) => Some(t.apply(g, ts, frame)),
                    // Unreachable pairing (tsyms is Some exactly for
                    // templates); skipping is the panic-free fallback.
                    (Action::Template(_), None) => None,
                    (Action::Dynamic(f), _) => {
                        let binds = rule.bindings(g, frame);
                        f(g, &binds)
                    }
                };
                if let Some(r) = replacement {
                    if g.find(c) != g.find(r) {
                        g.union(c, r);
                        any_change = true;
                        rule_changed = true;
                        report.applied += 1;
                        report.per_rule[ri].1 += 1;
                    }
                }
                // Node budget enforced *inside* the application loop: one
                // combinatorial rule must not flood the graph unchecked.
                if g.node_count() > self.node_limit {
                    report.node_limit_hit = true;
                    g.rebuild();
                    return any_change;
                }
            }
            if rule_changed {
                g.rebuild();
            }
        }
        any_change
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn malformed_patterns_error_instead_of_panicking() {
        // (input, expected fragment in the diagnostic)
        let table = [
            ("", "empty pattern"),
            ("   ", "empty pattern"),
            ("(", "expected symbol after `(`"),
            ("()", "expected symbol after `(`"),
            ("((", "expected symbol after `(`"),
            ("(mul ?x", "unbalanced parens"),
            ("(mul ?x ?y) extra", "trailing tokens"),
            ("(mul ?x ?y))", "trailing tokens"),
            ("?", "bare `?`"),
            ("(add ? ?y)", "bare `?`"),
        ];
        for (text, want) in table {
            let err = Pattern::try_parse(text).unwrap_err().to_string();
            assert!(err.contains(want), "{text:?}: got {err:?}, want {want:?}");
        }
        // Pathological nesting errors out instead of blowing the stack.
        let deep = "(f ".repeat(10_000) + "x" + &")".repeat(10_000);
        let err = Pattern::try_parse(&deep).unwrap_err().to_string();
        assert!(err.contains("nested deeper"), "{err}");
        // Well-formed input still round-trips through the fallible path.
        assert_eq!(Pattern::try_parse("(mul ?x const:4)").unwrap(), Pattern::parse("(mul ?x const:4)"));
    }

    #[test]
    fn match_limit_hit_is_reported() {
        let mut g = EGraph::new();
        for i in 0..20 {
            let x = g.add_named(&format!("x{i}"), vec![]);
            g.add_named("f", vec![x]);
        }
        let rules = vec![Rewrite::simple("wrap", "(f ?x)", "(g ?x)")];
        let capped = Runner { match_limit: 5, ..Default::default() };
        let report = capped.run(&mut g, &rules);
        assert!(report.match_limit_hit);
        let mut g2 = EGraph::new();
        let x = g2.add_named("x", vec![]);
        g2.add_named("f", vec![x]);
        let report = Runner::default().run(&mut g2, &rules);
        assert!(!report.match_limit_hit);
    }

    #[test]
    fn parse_roundtrip() {
        let p = Pattern::parse("(mul ?x (add ?y const:1))");
        assert_eq!(
            p,
            Pattern::App(
                "mul".into(),
                vec![
                    Pattern::Var("x".into()),
                    Pattern::App(
                        "add".into(),
                        vec![Pattern::Var("y".into()), Pattern::App("const:1".into(), vec![])]
                    )
                ]
            )
        );
    }

    #[test]
    fn compile_allocates_registers_depth_first() {
        let p = Pattern::parse("(mul ?x (add ?x const:1))");
        let cp = CompiledPattern::compile(&p);
        // root + 2 mul kids + 2 add kids = 5 registers.
        assert_eq!(cp.frame_len(), 5);
        // One var (x), bound at the first mul child.
        assert_eq!(cp.vars, vec![("x".to_string(), 1)]);
        // Three symbols: mul, add, const:1.
        assert_eq!(cp.sym_names, vec!["mul", "add", "const:1"]);
        // Instructions: Bind(mul) / Bind(add) / Compare(x) / Bind(const:1).
        assert_eq!(cp.insts.len(), 4);
    }

    #[test]
    fn search_seeds_from_symbol_index() {
        let mut g = EGraph::new();
        let a = g.add_named("a", vec![]);
        let b = g.add_named("b", vec![]);
        g.add_named("mul", vec![a, b]);
        // A rule over a symbol absent from the graph searches nothing.
        let absent = CompiledPattern::compile(&Pattern::parse("(div ?x ?y)"));
        assert!(absent.search(&g, 1000).is_empty());
        let mul = CompiledPattern::compile(&Pattern::parse("(mul ?x ?y)"));
        let frames = mul.search(&g, 1000);
        assert_eq!(frames.len(), mul.frame_len()); // exactly one match
        assert_eq!(&frames[1..], &[a, b]); // children bound in order
    }

    #[test]
    fn commutativity_saturates() {
        let mut g = EGraph::new();
        let a = g.add_named("a", vec![]);
        let b = g.add_named("b", vec![]);
        let ab = g.add_named("mul", vec![a, b]);
        let ba = g.add_named("mul", vec![b, a]);
        assert_ne!(g.find(ab), g.find(ba));
        let rules = vec![Rewrite::simple("comm-mul", "(mul ?x ?y)", "(mul ?y ?x)")];
        let report = Runner::default().run(&mut g, &rules);
        assert!(report.saturated);
        assert_eq!(g.find(ab), g.find(ba));
    }

    #[test]
    fn shl_to_mul_dynamic() {
        let mut g = EGraph::new();
        let x = g.add_named("x", vec![]);
        let c2 = g.add_named("const:2", vec![]);
        let shl = g.add_named("shl", vec![x, c2]);
        // x << 2 => x * 4 (the §5.3 example)
        let rule = Rewrite::dynamic("shl-to-mul", "(shl ?x ?c)", |g, binds| {
            let c = binds["c"];
            let mut shift = None;
            for n in g.nodes(c) {
                if let Some(v) = g.sym_name(n.sym).strip_prefix("const:") {
                    if let Ok(k) = v.parse::<i64>() {
                        if (0..=62).contains(&k) {
                            shift = Some(k);
                            break;
                        }
                    }
                }
            }
            let k = shift?;
            let x = binds["x"];
            let cm = g.add_named(&format!("const:{}", 1i64 << k), vec![]);
            Some(g.add_named("mul", vec![x, cm]))
        });
        let report = Runner::default().run(&mut g, &[rule]);
        assert_eq!(report.applied, 1);
        let c4 = g.add_named("const:4", vec![]);
        let mul = g.add_named("mul", vec![x, c4]);
        assert_eq!(g.find(shl), g.find(mul));
    }

    #[test]
    fn node_limit_stops_explosion() {
        let mut g = EGraph::new();
        let x = g.add_named("x", vec![]);
        g.add_named("f", vec![x]);
        // Genuinely generative rule: each application mints a fresh `g`
        // wrapper, so the graph grows without bound.
        let rule = Rewrite::simple("grow", "(f ?x)", "(f (g ?x))");
        let runner = Runner { iter_limit: 1000, node_limit: 50, ..Default::default() };
        let report = runner.run(&mut g, &[rule]);
        assert!(report.node_limit_hit);
        assert!(g.node_count() > 50);
    }

    #[test]
    fn nonlinear_pattern_requires_same_class() {
        let mut g = EGraph::new();
        let a = g.add_named("a", vec![]);
        let b = g.add_named("b", vec![]);
        let aa = g.add_named("sub", vec![a, a]);
        let ab = g.add_named("sub", vec![b, a]);
        // x - x => zero
        let rules = vec![Rewrite::simple("sub-self", "(sub ?x ?x)", "zero")];
        Runner::default().run(&mut g, &rules);
        let zero = g.add_named("zero", vec![]);
        assert_eq!(g.find(aa), g.find(zero));
        assert_ne!(g.find(ab), g.find(zero));
    }

    #[test]
    fn match_limit_caps_frames() {
        let mut g = EGraph::new();
        for i in 0..20 {
            let x = g.add_named(&format!("x{i}"), vec![]);
            g.add_named("f", vec![x]);
        }
        let cp = CompiledPattern::compile(&Pattern::parse("(f ?x)"));
        let frames = cp.search(&g, 5);
        assert_eq!(frames.len(), 5 * cp.frame_len());
    }

    #[test]
    fn nested_template_instantiates_via_compiled_rhs() {
        // (add (mul ?a ?b) const:0) => (mul ?b ?a): exercises var reuse,
        // nested Bind, and a multi-step template.
        let mut g = EGraph::new();
        let a = g.add_named("a", vec![]);
        let b = g.add_named("b", vec![]);
        let m = g.add_named("mul", vec![a, b]);
        let z = g.add_named("const:0", vec![]);
        let root = g.add_named("add", vec![m, z]);
        let rules =
            vec![Rewrite::simple("strip", "(add (mul ?a ?b) const:0)", "(mul ?b ?a)")];
        let report = Runner::default().run(&mut g, &rules);
        assert_eq!(report.applied, 1);
        let ba = g.add_named("mul", vec![b, a]);
        assert_eq!(g.find(root), g.find(ba));
    }
}
