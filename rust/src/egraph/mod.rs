//! §2.3 / §5.2 — an e-graph engine (egg/egglog-style, Willsey et al.).
//!
//! An *e-graph* compactly represents a large space of equivalent programs:
//! *e-classes* group equivalent *e-nodes*; an e-node is a function symbol
//! applied to child e-class ids. Rewrites match patterns over e-nodes and
//! `union` their results into the matched class, non-destructively
//! accumulating every variant. Extraction selects one representative per
//! class minimizing a user-defined cost.
//!
//! Engine notes: the core uses an egg-style **worklist rebuild** (only
//! parents of union-touched classes are re-canonicalized, never the whole
//! memo), dense class storage, and an incrementally-maintained **symbol
//! occurrence index**; rewrites are **compiled once** into flat register
//! machines so a match attempt does no string hashing and no map cloning.
//! See `README.md` § "E-graph engine internals".
//!
//! Submodules: [`graph`] (union-find + hashcons + congruence closure),
//! [`rewrite`] (pattern language + compiled matcher + saturation engine
//! with iteration/node limits), [`extract`] (cost-based extraction).

// Panic-free audit (robustness): see the per-module denies in the
// submodules; this module itself holds only re-exports.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod extract;
pub mod graph;
pub mod rewrite;

pub use extract::{extract_best, CostFn, Extracted};
pub use graph::{ClassId, EGraph, ENode, SymId};
pub use rewrite::{CompiledPattern, Pattern, Rewrite, RunReport, Runner};
