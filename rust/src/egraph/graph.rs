//! Core e-graph: interned symbols, union-find, hashcons, congruence.

use std::collections::HashMap;

/// Interned symbol id.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SymId(pub u32);

/// E-class id (canonical after `find`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ClassId(pub u32);

/// An e-node: a function symbol applied to child e-classes.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ENode {
    pub sym: SymId,
    pub children: Vec<ClassId>,
}

impl ENode {
    pub fn leaf(sym: SymId) -> Self {
        Self { sym, children: vec![] }
    }

    fn canonicalize(&self, uf: &mut UnionFind) -> ENode {
        ENode { sym: self.sym, children: self.children.iter().map(|&c| uf.find(c)).collect() }
    }
}

#[derive(Debug, Default, Clone)]
struct UnionFind {
    parent: Vec<u32>,
}

impl UnionFind {
    fn make(&mut self) -> ClassId {
        let id = self.parent.len() as u32;
        self.parent.push(id);
        ClassId(id)
    }

    fn find(&mut self, c: ClassId) -> ClassId {
        let mut root = c.0;
        while self.parent[root as usize] != root {
            root = self.parent[root as usize];
        }
        // path compression
        let mut cur = c.0;
        while self.parent[cur as usize] != root {
            let next = self.parent[cur as usize];
            self.parent[cur as usize] = root;
            cur = next;
        }
        ClassId(root)
    }

    fn union(&mut self, a: ClassId, b: ClassId) -> ClassId {
        let ra = self.find(a);
        let rb = self.find(b);
        if ra != rb {
            // Union toward the smaller id keeps canonical ids stable-ish.
            let (keep, drop) = if ra.0 < rb.0 { (ra, rb) } else { (rb, ra) };
            self.parent[drop.0 as usize] = keep.0;
            keep
        } else {
            ra
        }
    }
}

/// The e-graph.
#[derive(Debug, Default, Clone)]
pub struct EGraph {
    syms: Vec<String>,
    sym_ids: HashMap<String, SymId>,
    uf: UnionFind,
    /// Hashcons: canonical node -> class.
    memo: HashMap<ENode, ClassId>,
    /// Nodes per canonical class.
    classes: HashMap<ClassId, Vec<ENode>>,
    /// Classes touched since the last rebuild.
    dirty: Vec<ClassId>,
}

impl EGraph {
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern a symbol name.
    pub fn sym(&mut self, name: &str) -> SymId {
        if let Some(&id) = self.sym_ids.get(name) {
            return id;
        }
        let id = SymId(self.syms.len() as u32);
        self.syms.push(name.to_string());
        self.sym_ids.insert(name.to_string(), id);
        id
    }

    /// Look up a symbol without interning.
    pub fn find_sym(&self, name: &str) -> Option<SymId> {
        self.sym_ids.get(name).copied()
    }

    /// Symbol name.
    pub fn sym_name(&self, s: SymId) -> &str {
        &self.syms[s.0 as usize]
    }

    /// Canonical class id.
    pub fn find(&mut self, c: ClassId) -> ClassId {
        self.uf.find(c)
    }

    /// Add an e-node, returning its class (hashconsed).
    pub fn add(&mut self, node: ENode) -> ClassId {
        let node = node.canonicalize(&mut self.uf);
        if let Some(&c) = self.memo.get(&node) {
            return self.uf.find(c);
        }
        let id = self.uf.make();
        self.memo.insert(node.clone(), id);
        self.classes.entry(id).or_default().push(node);
        id
    }

    /// Convenience: add by symbol name + children.
    pub fn add_named(&mut self, name: &str, children: Vec<ClassId>) -> ClassId {
        let sym = self.sym(name);
        self.add(ENode { sym, children })
    }

    /// Merge two classes; returns the canonical survivor.
    pub fn union(&mut self, a: ClassId, b: ClassId) -> ClassId {
        let ra = self.uf.find(a);
        let rb = self.uf.find(b);
        if ra == rb {
            return ra;
        }
        let keep = self.uf.union(ra, rb);
        let drop = if keep == ra { rb } else { ra };
        let moved = self.classes.remove(&drop).unwrap_or_default();
        self.classes.entry(keep).or_default().extend(moved);
        self.dirty.push(keep);
        keep
    }

    /// Restore congruence: nodes whose children were unioned may now be
    /// duplicates; re-canonicalize until fixpoint.
    pub fn rebuild(&mut self) {
        while !self.dirty.is_empty() {
            self.dirty.clear();
            let old_memo = std::mem::take(&mut self.memo);
            let mut new_memo: HashMap<ENode, ClassId> = HashMap::with_capacity(old_memo.len());
            let mut unions: Vec<(ClassId, ClassId)> = Vec::new();
            for (node, cls) in old_memo {
                let canon = node.canonicalize(&mut self.uf);
                let ccls = self.uf.find(cls);
                match new_memo.get(&canon) {
                    Some(&existing) if existing != ccls => unions.push((existing, ccls)),
                    Some(_) => {}
                    None => {
                        new_memo.insert(canon, ccls);
                    }
                }
            }
            self.memo = new_memo;
            for (a, b) in unions {
                self.union(a, b);
            }
            // Re-bucket class nodes canonically (hash-set dedup per bucket).
            let mut new_classes: HashMap<ClassId, Vec<ENode>> = HashMap::new();
            let mut seen: std::collections::HashSet<(ClassId, ENode)> =
                std::collections::HashSet::new();
            let old = std::mem::take(&mut self.classes);
            for (cls, nodes) in old {
                let ccls = self.uf.find(cls);
                for n in nodes {
                    let canon = n.canonicalize(&mut self.uf);
                    if seen.insert((ccls, canon.clone())) {
                        new_classes.entry(ccls).or_default().push(canon);
                    }
                }
            }
            self.classes = new_classes;
        }
    }

    /// Nodes of a class (canonical).
    pub fn nodes(&mut self, c: ClassId) -> Vec<ENode> {
        let c = self.uf.find(c);
        self.classes.get(&c).cloned().unwrap_or_default()
    }

    /// Nodes of a class restricted to one symbol + arity — the e-matching
    /// hot path (avoids cloning whole classes that can't match anyway).
    pub fn nodes_with_sym(&mut self, c: ClassId, sym: SymId, arity: usize) -> Vec<ENode> {
        let c = self.uf.find(c);
        match self.classes.get(&c) {
            Some(ns) => ns
                .iter()
                .filter(|n| n.sym == sym && n.children.len() == arity)
                .cloned()
                .collect(),
            None => Vec::new(),
        }
    }

    /// All canonical class ids.
    pub fn class_ids(&mut self) -> Vec<ClassId> {
        let ids: Vec<ClassId> = self.classes.keys().copied().collect();
        ids.into_iter().map(|c| self.uf.find(c)).collect()
    }

    /// Total e-node count (Table 3's "e-nodes" statistic).
    pub fn node_count(&self) -> usize {
        self.classes.values().map(|v| v.len()).sum()
    }

    /// Class count.
    pub fn class_count(&self) -> usize {
        self.classes.len()
    }

    /// Does class `c` contain a node with symbol `sym` (marker test)?
    pub fn class_has_sym(&mut self, c: ClassId, sym: SymId) -> bool {
        let c = self.uf.find(c);
        self.classes.get(&c).map(|ns| ns.iter().any(|n| n.sym == sym)).unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hashcons_dedupes() {
        let mut g = EGraph::new();
        let a = g.add_named("x", vec![]);
        let b = g.add_named("x", vec![]);
        assert_eq!(a, b);
        assert_eq!(g.node_count(), 1);
    }

    #[test]
    fn union_merges_classes() {
        let mut g = EGraph::new();
        let a = g.add_named("a", vec![]);
        let b = g.add_named("b", vec![]);
        assert_ne!(g.find(a), g.find(b));
        g.union(a, b);
        assert_eq!(g.find(a), g.find(b));
    }

    #[test]
    fn congruence_closure() {
        // f(a), f(b): union(a, b) must make f(a) == f(b) after rebuild.
        let mut g = EGraph::new();
        let a = g.add_named("a", vec![]);
        let b = g.add_named("b", vec![]);
        let fa = g.add_named("f", vec![a]);
        let fb = g.add_named("f", vec![b]);
        assert_ne!(g.find(fa), g.find(fb));
        g.union(a, b);
        g.rebuild();
        assert_eq!(g.find(fa), g.find(fb));
    }

    #[test]
    fn nested_congruence() {
        let mut g = EGraph::new();
        let a = g.add_named("a", vec![]);
        let b = g.add_named("b", vec![]);
        let fa = g.add_named("f", vec![a]);
        let fb = g.add_named("f", vec![b]);
        let gfa = g.add_named("g", vec![fa]);
        let gfb = g.add_named("g", vec![fb]);
        g.union(a, b);
        g.rebuild();
        assert_eq!(g.find(gfa), g.find(gfb));
    }

    #[test]
    fn class_has_marker() {
        let mut g = EGraph::new();
        let a = g.add_named("expr", vec![]);
        let m = g.add_named("marker", vec![]);
        g.union(a, m);
        g.rebuild();
        let ms = g.sym("marker");
        assert!(g.class_has_sym(a, ms));
    }
}
