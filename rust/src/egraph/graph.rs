//! Core e-graph: interned symbols, union-find, hashcons, congruence.
//!
//! Engine layout (egg-style worklist design, see Willsey et al. 2021):
//!
//! - **Dense class storage.** Classes live in a `Vec<Option<EClass>>`
//!   indexed by `ClassId`, so the hot read paths (`nodes`, `class_has_sym`,
//!   seeding) never hash. `Some` exactly for union-find-canonical ids.
//! - **Parent lists.** Every class records the e-nodes that reference it
//!   (and the class each such node belongs to). `union` merely concatenates
//!   node + parent lists and pushes the survivor onto a worklist.
//! - **Worklist `rebuild`.** Congruence is restored by repairing only the
//!   parents of classes touched by unions instead of re-hashing the whole
//!   memo to a fixpoint. A finishing pass canonicalizes + dedups the
//!   stored nodes of exactly the classes this rebuild touched — rebuild
//!   cost stays proportional to the dirty region, never the whole graph.
//! - **Symbol occurrence index.** `sym_index[sym]` lists the classes
//!   containing a node with that symbol, so e-matching seeds directly from
//!   the index and never iterates classes that cannot match. The index is
//!   append-only (one entry per class per symbol at `add` time; a class's
//!   symbol set never shrinks, and merged ids resolve via the query's
//!   canonicalize + dedup), so no rebuild pass regenerates it.
//! - **Split read/write paths.** `find` is `&self` and non-compressing;
//!   `find_mut` compresses. Accessors (`nodes`, `class_ids`, `node_count`,
//!   `classes_with_sym`) take `&self` and return borrowed slices where
//!   possible, so matching holds no `&mut` borrow and allocates nothing
//!   per candidate node.

// Panic-free audit (robustness): internal invariants use `unreachable!`,
// never `unwrap`/`expect` on values user input could influence.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::collections::hash_map::Entry;
use std::collections::HashMap;

/// Interned symbol id.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SymId(pub u32);

/// E-class id (canonical after `find`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ClassId(pub u32);

/// An e-node: a function symbol applied to child e-classes.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ENode {
    pub sym: SymId,
    pub children: Vec<ClassId>,
}

impl ENode {
    pub fn leaf(sym: SymId) -> Self {
        Self { sym, children: vec![] }
    }
}

#[derive(Debug, Default, Clone)]
struct UnionFind {
    parent: Vec<u32>,
}

impl UnionFind {
    fn make(&mut self) -> ClassId {
        let id = self.parent.len() as u32;
        self.parent.push(id);
        ClassId(id)
    }

    /// Non-compressing find: usable from `&self` read paths. Cheap in
    /// practice because every `&mut` operation compresses as it goes.
    fn find(&self, c: ClassId) -> ClassId {
        let mut root = c.0;
        while self.parent[root as usize] != root {
            root = self.parent[root as usize];
        }
        ClassId(root)
    }

    /// Path-compressing find for mutating paths.
    fn find_mut(&mut self, c: ClassId) -> ClassId {
        let mut root = c.0;
        while self.parent[root as usize] != root {
            root = self.parent[root as usize];
        }
        let mut cur = c.0;
        while self.parent[cur as usize] != root {
            let next = self.parent[cur as usize];
            self.parent[cur as usize] = root;
            cur = next;
        }
        ClassId(root)
    }

    fn union(&mut self, a: ClassId, b: ClassId) -> ClassId {
        let ra = self.find_mut(a);
        let rb = self.find_mut(b);
        if ra != rb {
            // Union toward the smaller id keeps canonical ids stable-ish.
            let (keep, drop) = if ra.0 < rb.0 { (ra, rb) } else { (rb, ra) };
            self.parent[drop.0 as usize] = keep.0;
            keep
        } else {
            ra
        }
    }

}

/// One e-class: its nodes plus the e-nodes that reference it.
#[derive(Debug, Default, Clone)]
struct EClass {
    nodes: Vec<ENode>,
    /// Parent e-nodes (as shaped when recorded) and the class each belongs
    /// to. Repair re-canonicalizes these lazily — only for dirty classes.
    parents: Vec<(ENode, ClassId)>,
}

/// The e-graph.
#[derive(Debug, Default, Clone)]
pub struct EGraph {
    syms: Vec<String>,
    sym_ids: HashMap<String, SymId>,
    uf: UnionFind,
    /// Hashcons: canonical node -> class (values canonicalized lazily).
    memo: HashMap<ENode, ClassId>,
    /// Dense class storage; `Some` exactly for canonical live ids.
    classes: Vec<Option<EClass>>,
    /// sym -> classes containing a node with that symbol. Append-only:
    /// one entry per class per symbol at `add` time. Entries for merged
    /// classes go stale but stay correct — a class's symbol set never
    /// shrinks, and queries canonicalize + dedup.
    sym_index: Vec<Vec<ClassId>>,
    /// Classes whose parents must be repaired before congruence holds.
    worklist: Vec<ClassId>,
    /// Classes whose *stored nodes* may be stale (merged into, or holding
    /// a node whose child merged) — the finishing pass canonicalizes and
    /// dedups exactly these.
    touched: Vec<ClassId>,
    /// Total stored nodes (exact after `rebuild`, monotone between).
    live_nodes: usize,
    /// Number of live (canonical) classes.
    live_classes: usize,
}

impl EGraph {
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern a symbol name.
    pub fn sym(&mut self, name: &str) -> SymId {
        if let Some(&id) = self.sym_ids.get(name) {
            return id;
        }
        let id = SymId(self.syms.len() as u32);
        self.syms.push(name.to_string());
        self.sym_ids.insert(name.to_string(), id);
        self.sym_index.push(Vec::new());
        id
    }

    /// Look up a symbol without interning.
    pub fn find_sym(&self, name: &str) -> Option<SymId> {
        self.sym_ids.get(name).copied()
    }

    /// Symbol name.
    pub fn sym_name(&self, s: SymId) -> &str {
        &self.syms[s.0 as usize]
    }

    /// Canonical class id (read-only, non-compressing).
    pub fn find(&self, c: ClassId) -> ClassId {
        self.uf.find(c)
    }

    /// Canonical class id with path compression (mutating hot paths).
    pub fn find_mut(&mut self, c: ClassId) -> ClassId {
        self.uf.find_mut(c)
    }

    /// Add an e-node, returning its class (hashconsed).
    pub fn add(&mut self, mut node: ENode) -> ClassId {
        for c in &mut node.children {
            *c = self.uf.find_mut(*c);
        }
        if let Some(&c) = self.memo.get(&node) {
            return self.uf.find_mut(c);
        }
        let id = self.uf.make();
        for &ch in &node.children {
            self.classes[ch.0 as usize]
                .as_mut()
                .unwrap_or_else(|| unreachable!("canonical child class is live"))
                .parents
                .push((node.clone(), id));
        }
        let sym = node.sym.0 as usize;
        if self.sym_index.len() <= sym {
            self.sym_index.resize_with(sym + 1, Vec::new);
        }
        self.sym_index[sym].push(id);
        self.memo.insert(node.clone(), id);
        self.classes.push(Some(EClass { nodes: vec![node], parents: Vec::new() }));
        self.live_nodes += 1;
        self.live_classes += 1;
        id
    }

    /// Convenience: add by symbol name + children.
    pub fn add_named(&mut self, name: &str, children: Vec<ClassId>) -> ClassId {
        let sym = self.sym(name);
        self.add(ENode { sym, children })
    }

    /// Merge two classes; returns the canonical survivor.
    pub fn union(&mut self, a: ClassId, b: ClassId) -> ClassId {
        let ra = self.uf.find_mut(a);
        let rb = self.uf.find_mut(b);
        if ra == rb {
            return ra;
        }
        let keep = self.uf.union(ra, rb);
        let drop = if keep == ra { rb } else { ra };
        let dropped = self.classes[drop.0 as usize]
            .take()
            .unwrap_or_else(|| unreachable!("canonical class is live"));
        let kept = self.classes[keep.0 as usize]
            .as_mut()
            .unwrap_or_else(|| unreachable!("canonical class is live"));
        kept.nodes.extend(dropped.nodes);
        kept.parents.extend(dropped.parents);
        self.live_classes -= 1;
        self.worklist.push(keep);
        self.touched.push(keep);
        keep
    }

    /// Restore congruence: repair only the parents of classes touched by
    /// unions (worklist algorithm) instead of rehashing the whole memo.
    pub fn rebuild(&mut self) {
        if self.worklist.is_empty() {
            return;
        }
        while !self.worklist.is_empty() {
            let mut todo = std::mem::take(&mut self.worklist);
            todo.sort_unstable();
            todo.dedup();
            for id in todo {
                self.repair(id);
            }
        }
        self.rebuild_touched();
    }

    /// Re-canonicalize the parents of one dirty class, unioning classes
    /// whose nodes have become congruent.
    fn repair(&mut self, id: ClassId) {
        let id = self.uf.find_mut(id);
        let parents = {
            let cls = self.classes[id.0 as usize]
                .as_mut()
                .unwrap_or_else(|| unreachable!("repair target is live"));
            std::mem::take(&mut cls.parents)
        };
        if parents.is_empty() {
            return;
        }
        let mut seen: HashMap<ENode, ClassId> = HashMap::with_capacity(parents.len());
        for (mut pnode, pclass) in parents {
            // Remove by the as-recorded shape. If a sibling child's repair
            // already re-keyed this node, the remove misses and that older
            // re-keyed entry goes stale — harmless (lookups always
            // canonicalize children first, so stale keys are unreachable)
            // and bounded by union churn, the same trade egg makes.
            self.memo.remove(&pnode);
            for ch in &mut pnode.children {
                *ch = self.uf.find_mut(*ch);
            }
            let pclass = self.uf.find_mut(pclass);
            // This parent class's stored copy of `pnode` is now stale:
            // queue it for the finishing canonicalize+dedup pass.
            self.touched.push(pclass);
            match seen.entry(pnode) {
                Entry::Occupied(mut e) => {
                    // Two parents canonicalized to the same node: their
                    // classes are congruent. Union (pushes more work).
                    let merged = self.union(*e.get(), pclass);
                    e.insert(merged);
                }
                Entry::Vacant(e) => {
                    e.insert(pclass);
                }
            }
        }
        // Write back the deduped, canonical parent set + memo entries. The
        // repaired class may itself have been merged by the unions above.
        let id = self.uf.find_mut(id);
        for (pnode, pclass) in seen {
            let pclass = self.uf.find_mut(pclass);
            self.memo.insert(pnode.clone(), pclass);
            self.classes[id.0 as usize]
                .as_mut()
                .unwrap_or_else(|| unreachable!("repair target is live"))
                .parents
                .push((pnode, pclass));
        }
    }

    /// Canonicalize + dedup the stored nodes of exactly the classes this
    /// rebuild touched (merge targets + owners of re-canonicalized parent
    /// nodes). Untouched classes are already canonical — no child of
    /// theirs merged, or they would appear in that child's parents and be
    /// queued here. Runs once per `rebuild`, after the worklist drains.
    fn rebuild_touched(&mut self) {
        let mut touched = std::mem::take(&mut self.touched);
        for c in &mut touched {
            *c = self.uf.find_mut(*c);
        }
        touched.sort_unstable();
        touched.dedup();
        let uf = &mut self.uf;
        for id in touched {
            let Some(cls) = self.classes[id.0 as usize].as_mut() else { continue };
            let before = cls.nodes.len();
            for n in &mut cls.nodes {
                for c in &mut n.children {
                    *c = uf.find_mut(*c);
                }
            }
            cls.nodes.sort_unstable();
            cls.nodes.dedup();
            self.live_nodes -= before - cls.nodes.len();
        }
    }

    /// Nodes of a class, as stored (canonical after `rebuild`). Borrowed —
    /// the e-matching hot path clones nothing.
    pub fn nodes(&self, c: ClassId) -> &[ENode] {
        let c = self.uf.find(c);
        match self.classes.get(c.0 as usize) {
            Some(Some(cls)) => &cls.nodes,
            _ => &[],
        }
    }

    /// All canonical class ids, ascending (live slots are canonical by
    /// construction — no per-id `find` needed).
    pub fn class_ids(&self) -> Vec<ClassId> {
        self.classes
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|_| ClassId(i as u32)))
            .collect()
    }

    /// Classes containing at least one node with symbol `sym` — the
    /// e-matching seed set. Canonicalized, sorted, deduped (entries for
    /// merged-away classes are stale but resolve through `find`).
    pub fn classes_with_sym(&self, sym: SymId) -> Vec<ClassId> {
        let Some(bucket) = self.sym_index.get(sym.0 as usize) else {
            return Vec::new();
        };
        let mut out: Vec<ClassId> = bucket.iter().map(|&c| self.uf.find(c)).collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Total e-node count (Table 3's "e-nodes" statistic). O(1).
    pub fn node_count(&self) -> usize {
        self.live_nodes
    }

    /// Class count. O(1).
    pub fn class_count(&self) -> usize {
        self.live_classes
    }

    /// Does class `c` contain a node with symbol `sym` (marker test)?
    pub fn class_has_sym(&self, c: ClassId, sym: SymId) -> bool {
        self.nodes(c).iter().any(|n| n.sym == sym)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn hashcons_dedupes() {
        let mut g = EGraph::new();
        let a = g.add_named("x", vec![]);
        let b = g.add_named("x", vec![]);
        assert_eq!(a, b);
        assert_eq!(g.node_count(), 1);
    }

    #[test]
    fn union_merges_classes() {
        let mut g = EGraph::new();
        let a = g.add_named("a", vec![]);
        let b = g.add_named("b", vec![]);
        assert_ne!(g.find(a), g.find(b));
        g.union(a, b);
        assert_eq!(g.find(a), g.find(b));
    }

    #[test]
    fn congruence_closure() {
        // f(a), f(b): union(a, b) must make f(a) == f(b) after rebuild.
        let mut g = EGraph::new();
        let a = g.add_named("a", vec![]);
        let b = g.add_named("b", vec![]);
        let fa = g.add_named("f", vec![a]);
        let fb = g.add_named("f", vec![b]);
        assert_ne!(g.find(fa), g.find(fb));
        g.union(a, b);
        g.rebuild();
        assert_eq!(g.find(fa), g.find(fb));
    }

    #[test]
    fn nested_congruence() {
        let mut g = EGraph::new();
        let a = g.add_named("a", vec![]);
        let b = g.add_named("b", vec![]);
        let fa = g.add_named("f", vec![a]);
        let fb = g.add_named("f", vec![b]);
        let gfa = g.add_named("g", vec![fa]);
        let gfb = g.add_named("g", vec![fb]);
        g.union(a, b);
        g.rebuild();
        assert_eq!(g.find(gfa), g.find(gfb));
    }

    #[test]
    fn class_has_marker() {
        let mut g = EGraph::new();
        let a = g.add_named("expr", vec![]);
        let m = g.add_named("marker", vec![]);
        g.union(a, m);
        g.rebuild();
        let ms = g.sym("marker");
        assert!(g.class_has_sym(a, ms));
    }

    #[test]
    fn rebuild_dedupes_congruent_nodes() {
        // After union(a, b) + rebuild, f(a) and f(b) are the same node:
        // the merged class stores it once and node_count reflects that.
        let mut g = EGraph::new();
        let a = g.add_named("a", vec![]);
        let b = g.add_named("b", vec![]);
        let fa = g.add_named("f", vec![a]);
        g.add_named("f", vec![b]);
        assert_eq!(g.node_count(), 4);
        g.union(a, b);
        g.rebuild();
        // a|b holds {a, b}; f-class holds one canonical f node.
        assert_eq!(g.node_count(), 3);
        assert_eq!(g.nodes(fa).len(), 1);
    }

    #[test]
    fn sym_index_seeds_matching() {
        let mut g = EGraph::new();
        let a = g.add_named("a", vec![]);
        let b = g.add_named("b", vec![]);
        let ma = g.add_named("mul", vec![a, b]);
        let mb = g.add_named("mul", vec![b, a]);
        let mul = g.sym("mul");
        assert_eq!(g.classes_with_sym(mul), vec![g.find(ma), g.find(mb)]);
        // Merging the two mul classes collapses the seed set too.
        g.union(ma, mb);
        g.rebuild();
        assert_eq!(g.classes_with_sym(mul), vec![g.find(ma)]);
        // Leaf symbols index their own classes.
        let asym = g.sym("a");
        assert_eq!(g.classes_with_sym(asym), vec![g.find(a)]);
    }

    #[test]
    fn read_accessors_take_shared_borrows() {
        let mut g = EGraph::new();
        let a = g.add_named("a", vec![]);
        let f = g.add_named("f", vec![a]);
        // All of these coexist on &g — no &mut needed for reads.
        let r = &g;
        assert_eq!(r.find(f), f);
        assert_eq!(r.nodes(f).len(), 1);
        assert_eq!(r.nodes(f)[0].children, vec![a]);
        assert_eq!(r.class_ids(), vec![a, f]);
        assert_eq!(r.node_count(), 2);
        assert_eq!(r.class_count(), 2);
    }

    #[test]
    fn deep_union_chain_rebuilds_transitively() {
        // A chain of unions across separately-built towers must fully
        // collapse: g^k(a) == g^k(b) for all k once a == b.
        let mut g = EGraph::new();
        let a = g.add_named("a", vec![]);
        let b = g.add_named("b", vec![]);
        let mut ta = a;
        let mut tb = b;
        let mut pairs = Vec::new();
        for _ in 0..12 {
            ta = g.add_named("g", vec![ta]);
            tb = g.add_named("g", vec![tb]);
            pairs.push((ta, tb));
        }
        g.union(a, b);
        g.rebuild();
        for (x, y) in pairs {
            assert_eq!(g.find(x), g.find(y));
        }
        // Each tower level deduped to a single node.
        assert_eq!(g.nodes(ta).len(), 1);
    }
}
