//! Serving-engine benchmark: deterministic trace replay at several batch
//! widths (`cargo bench --bench serve`, `aquas bench serve`).
//!
//! Replays one [`TraceSpec`] through the paged-KV continuous-batching
//! engine with `max_active` ∈ {1, 4, 8}. The batch-1 run *is* the
//! single-stream coordinator baseline (see
//! [`crate::workloads::llm::IsaxLlmModel::batch_tick_cycles`]), so the
//! recorded `batch4_throughput_x` / `batch8_throughput_x` metrics are the
//! serving-layer speedups this subsystem exists to deliver. All latency
//! numbers are on the modelled SoC clock — byte-identical across replays.
//!
//! Also recorded: TTFT/ITL percentiles per width, KV-pool accounting
//! (peak blocks, preemptions, leak check), cross-width token equality
//! (scheduling must never perturb greedy numerics) and a replay
//! determinism check. The bench target gates on these in CI.
//!
//! The second half of the report is the **multi-core SoC scaling
//! section**: the heavy-tailed [`soc_spec`] trace replayed on 1/2/4/8
//! cores through [`crate::coordinator::SocCoordinator`] (sharded KV,
//! async admission, migration + stealing, measured shared-DDR
//! contention). Recorded per core count: throughput and speedup vs the
//! 1-core SoC, latency percentiles, migration/steal/preemption
//! counters, the contention delta in DMA cycles, and per-shard leak
//! checks — plus a bitwise check that the 1-core SoC reproduces the
//! plain engine and a 4-core replay-determinism check.
//!
//! The final section is the **chaos degradation curve**: the 4-core SoC
//! re-run with 1 and 2 cores killed mid-trace via [`FaultPlan`].
//! Recorded per point: surviving throughput as a fraction of the
//! healthy 4-core run, evacuation/shed counters, leak checks, survivor
//! token preservation and seeded-replay determinism — and a bitwise
//! check that the *empty* fault plan changes nothing at all.

use crate::coordinator::{
    Coordinator, CoordinatorConfig, FaultPlan, KvStats, RequestMetrics, SchedulePolicy,
    SocConfig, SocCoordinator, SocStats, TraceSpec,
};
use crate::error::Result;
use crate::runtime::Runtime;
use crate::util::stats::summarize;

use super::Report;

/// The checked-in benchmark workload: a *saturating* arrival process
/// (offered load well above the single-stream service rate), so the
/// throughput comparison measures the engine, not idle gaps between
/// arrivals.
pub fn default_spec(quick: bool) -> TraceSpec {
    TraceSpec {
        n: if quick { 12 } else { 32 },
        seed: 7,
        rate: 16.0,
        plen: (4, 12),
        gen: (8, 16),
        ..Default::default()
    }
}

/// The SoC core-scaling workload: bursty arrivals (geometric bursts of
/// mean 4), a heavy generation-length tail (25% of requests draw from
/// the stretched range) and a mixed interactive/batch SLO population —
/// the churn the multi-core scheduler exists to absorb. Offered load
/// saturates even the 8-core SoC, so the curves measure service
/// capacity, not arrival gaps.
pub fn soc_spec(quick: bool) -> TraceSpec {
    TraceSpec {
        n: if quick { 32 } else { 64 },
        seed: 11,
        rate: 24.0,
        plen: (4, 12),
        gen: (6, 16),
        burst: 4.0,
        tail: 0.25,
        mix: 0.5,
    }
}

/// Outcome of one trace replay.
pub struct TraceRun {
    pub metrics: Vec<RequestMetrics>,
    /// Simulated end-to-end time, ms.
    pub elapsed_ms: f64,
    pub kv: KvStats,
    pub preemptions: u64,
}

impl TraceRun {
    pub fn total_tokens(&self) -> usize {
        self.metrics.iter().map(|m| m.generated.len()).sum()
    }

    /// Aggregate generated-token throughput on the simulated clock.
    pub fn throughput_tok_s(&self) -> f64 {
        self.total_tokens() as f64 / (self.elapsed_ms / 1e3).max(1e-12)
    }

    fn ttft_ms(&self) -> Vec<f64> {
        self.metrics.iter().map(|m| m.ttft_us as f64 / 1e3).collect()
    }

    fn itl_ms(&self) -> Vec<f64> {
        self.metrics
            .iter()
            .flat_map(|m| m.itl_us.iter().map(|&x| x as f64 / 1e3))
            .collect()
    }
}

/// Replay `spec` at the given batch width/policy.
pub fn run_trace(
    rt: &Runtime,
    spec: &TraceSpec,
    policy: SchedulePolicy,
    batch: usize,
) -> Result<TraceRun> {
    let model = rt.manifest().model.clone();
    let reqs = spec.generate(model.vocab, model.prefill_len);
    let mut coord = Coordinator::new(
        rt,
        CoordinatorConfig { policy, max_active: batch, ..Default::default() },
    );
    coord.submit_trace(&reqs)?;
    let metrics = coord.run_to_completion()?;
    Ok(TraceRun {
        metrics,
        elapsed_ms: coord.sim_now_ms(),
        kv: coord.kv_stats(),
        preemptions: coord.preemptions(),
    })
}

/// Outcome of one N-core SoC trace replay.
pub struct SocTraceRun {
    /// Per-request metrics, merged across cores and sorted by SoC id.
    pub metrics: Vec<RequestMetrics>,
    /// Simulated end-to-end time on the slowest core's clock, ms.
    pub elapsed_ms: f64,
    /// SoC counters + per-shard allocator accounting.
    pub stats: SocStats,
}

impl SocTraceRun {
    /// Total generated tokens across the trace.
    pub fn total_tokens(&self) -> usize {
        self.metrics.iter().map(|m| m.generated.len()).sum()
    }

    /// Aggregate generated-token throughput on the simulated clock.
    pub fn throughput_tok_s(&self) -> f64 {
        self.total_tokens() as f64 / (self.elapsed_ms / 1e3).max(1e-12)
    }

    fn ttft_ms(&self) -> Vec<f64> {
        self.metrics.iter().map(|m| m.ttft_us as f64 / 1e3).collect()
    }

    fn itl_ms(&self) -> Vec<f64> {
        self.metrics
            .iter()
            .flat_map(|m| m.itl_us.iter().map(|&x| x as f64 / 1e3))
            .collect()
    }
}

/// Replay `spec` on an N-core SoC with the default shard geometry,
/// dispatch policy and DDR port group (see
/// [`crate::coordinator::SocConfig`]). Generation lengths are capped to
/// the serving window so heavy-tail draws stay admissible.
pub fn run_soc_trace(rt: &Runtime, spec: &TraceSpec, cores: usize) -> Result<SocTraceRun> {
    run_soc_trace_with_faults(rt, spec, cores, &FaultPlan::default())
}

/// [`run_soc_trace`] under a deterministic fault plan (core deaths,
/// stall windows, DMA error injection, load surges). The empty plan is
/// bitwise the plain run — the report gates on that below.
pub fn run_soc_trace_with_faults(
    rt: &Runtime,
    spec: &TraceSpec,
    cores: usize,
    faults: &FaultPlan,
) -> Result<SocTraceRun> {
    let model = rt.manifest().model.clone();
    let reqs = spec.generate_capped(model.vocab, model.prefill_len, model.max_seq);
    let mut soc = SocCoordinator::new(
        rt,
        SocConfig { cores, faults: faults.clone(), ..Default::default() },
    );
    soc.submit_trace(&reqs)?;
    let metrics = soc.run_to_completion()?;
    let elapsed_ms = soc.sim_elapsed_ms();
    let stats = soc.stats();
    Ok(SocTraceRun { metrics, elapsed_ms, stats })
}

/// Build the serving report (the `BENCH_serve.json` source of truth).
pub fn report(quick: bool) -> Report {
    let rt = Runtime::load("artifacts").expect("runtime load (simulated fallback)");
    let spec = default_spec(quick);
    let mut r = Report::new(
        "Serving engine — paged-KV continuous batching vs single-stream (simulated SoC clock)",
        vec![
            "config", "tokens", "sim s", "tok/s", "x vs single", "ttft p50/p95 ms",
            "itl p50/p95 ms", "peak blk", "preempt",
        ],
    );
    r.metric("trace_requests", spec.n as f64);

    let mut single_tok_s = 0.0;
    let mut single_tokens: Vec<(u64, Vec<i32>)> = Vec::new();
    for (label, batch) in [("single", 1usize), ("batch4", 4), ("batch8", 8)] {
        let run = run_trace(&rt, &spec, SchedulePolicy::DecodeFirst, batch)
            .unwrap_or_else(|e| panic!("{label} replay failed: {e}"));
        let tok_s = run.throughput_tok_s();
        if batch == 1 {
            single_tok_s = tok_s;
            single_tokens =
                run.metrics.iter().map(|m| (m.id, m.generated.clone())).collect();
        } else {
            // Scheduling width must never perturb greedy numerics.
            let tokens: Vec<(u64, Vec<i32>)> =
                run.metrics.iter().map(|m| (m.id, m.generated.clone())).collect();
            let matches = tokens == single_tokens;
            r.metric(
                &format!("{label}_tokens_match_single"),
                if matches { 1.0 } else { 0.0 },
            );
        }
        let speedup = tok_s / single_tok_s.max(1e-12);
        let ttft = summarize(run.ttft_ms());
        let itl = summarize(run.itl_ms());
        r.row(vec![
            label.into(),
            run.total_tokens().to_string(),
            format!("{:.1}", run.elapsed_ms / 1e3),
            format!("{tok_s:.2}"),
            format!("{speedup:.2}x"),
            format!("{:.0}/{:.0}", ttft.p50, ttft.p95),
            format!("{:.0}/{:.0}", itl.p50, itl.p95),
            run.kv.peak_in_use.to_string(),
            run.preemptions.to_string(),
        ]);
        r.metric(&format!("{label}_throughput_tok_s"), tok_s);
        r.metric(&format!("{label}_throughput_x"), speedup);
        r.metric(&format!("{label}_ttft_p50_ms"), ttft.p50);
        r.metric(&format!("{label}_ttft_p95_ms"), ttft.p95);
        r.metric(&format!("{label}_itl_p50_ms"), itl.p50);
        r.metric(&format!("{label}_itl_p95_ms"), itl.p95);
        r.metric(&format!("{label}_peak_blocks"), run.kv.peak_in_use as f64);
        r.metric(&format!("{label}_preemptions"), run.preemptions as f64);
        r.metric(
            &format!("{label}_kv_leak_free"),
            if run.kv.leak_free() { 1.0 } else { 0.0 },
        );
    }

    // Fair (EDF) policy ablation at batch 4: tail TTFT should not be
    // worse than DecodeFirst on the same trace.
    let fair = run_trace(&rt, &spec, SchedulePolicy::Fair, 4).expect("fair replay");
    let fair_ttft = summarize(fair.ttft_ms());
    r.metric("fair4_ttft_p95_ms", fair_ttft.p95);
    r.metric("fair4_throughput_tok_s", fair.throughput_tok_s());
    r.metric("fair4_kv_leak_free", if fair.kv.leak_free() { 1.0 } else { 0.0 });

    // Replay determinism: identical trace spec → identical simulated
    // clock and token streams.
    let a = run_trace(&rt, &spec, SchedulePolicy::DecodeFirst, 4).expect("replay a");
    let b = run_trace(&rt, &spec, SchedulePolicy::DecodeFirst, 4).expect("replay b");
    let tok_a: Vec<(u64, Vec<i32>)> = a.metrics.iter().map(|m| (m.id, m.generated.clone())).collect();
    let tok_b: Vec<(u64, Vec<i32>)> = b.metrics.iter().map(|m| (m.id, m.generated.clone())).collect();
    let deterministic = tok_a == tok_b && a.elapsed_ms == b.elapsed_ms;
    r.metric("replay_deterministic", if deterministic { 1.0 } else { 0.0 });

    // ----- multi-core SoC: core-scaling curves (1/2/4/8 cores) ----------
    let sspec = soc_spec(quick);
    r.metric("soc_trace_requests", sspec.n as f64);
    let mut core1_tok_s = 0.0;
    let mut core1_tokens: Vec<(u64, Vec<i32>)> = Vec::new();
    for cores in [1usize, 2, 4, 8] {
        let label = format!("cores{cores}");
        let run = run_soc_trace(&rt, &sspec, cores)
            .unwrap_or_else(|e| panic!("{label} replay failed: {e}"));
        let tok_s = run.throughput_tok_s();
        let tokens: Vec<(u64, Vec<i32>)> =
            run.metrics.iter().map(|m| (m.id, m.generated.clone())).collect();
        if cores == 1 {
            core1_tok_s = tok_s;
            core1_tokens = tokens;
        } else {
            // Sharding, migration and stealing move *where* a sequence
            // runs, never *what* it generates.
            r.metric(
                &format!("{label}_tokens_match_1core"),
                if tokens == core1_tokens { 1.0 } else { 0.0 },
            );
        }
        let speedup = tok_s / core1_tok_s.max(1e-12);
        let ttft = summarize(run.ttft_ms());
        let itl = summarize(run.itl_ms());
        let peak =
            run.stats.per_core_kv.iter().map(|k| k.peak_in_use).max().unwrap_or(0);
        let leak_free = run.stats.per_core_kv.iter().all(|k| k.leak_free());
        r.row(vec![
            label.clone(),
            run.total_tokens().to_string(),
            format!("{:.1}", run.elapsed_ms / 1e3),
            format!("{tok_s:.2}"),
            format!("{speedup:.2}x"),
            format!("{:.0}/{:.0}", ttft.p50, ttft.p95),
            format!("{:.0}/{:.0}", itl.p50, itl.p95),
            peak.to_string(),
            run.stats.preemptions.to_string(),
        ]);
        r.metric(&format!("{label}_throughput_tok_s"), tok_s);
        r.metric(&format!("{label}_throughput_x"), speedup);
        r.metric(&format!("{label}_ttft_p50_ms"), ttft.p50);
        r.metric(&format!("{label}_ttft_p95_ms"), ttft.p95);
        r.metric(&format!("{label}_itl_p50_ms"), itl.p50);
        r.metric(&format!("{label}_itl_p95_ms"), itl.p95);
        r.metric(&format!("{label}_peak_blocks"), peak as f64);
        r.metric(&format!("{label}_preemptions"), run.stats.preemptions as f64);
        r.metric(
            &format!("{label}_contention_dma_cycles"),
            run.stats.contention_dma_cycles,
        );
        r.metric(&format!("{label}_migrations"), run.stats.migrations as f64);
        r.metric(&format!("{label}_steals"), run.stats.steals as f64);
        r.metric(&format!("{label}_kv_leak_free"), if leak_free { 1.0 } else { 0.0 });
    }

    // A 1-core SoC is the PR 3 engine, bitwise: same trace through
    // `SocCoordinator { cores: 1 }` must reproduce run `a` exactly —
    // ids, token streams, TTFT/ITL on the clock, and elapsed time.
    let soc1 = run_soc_trace(&rt, &spec, 1).expect("1-core SoC replay");
    let bitwise = soc1.elapsed_ms == a.elapsed_ms
        && soc1.metrics.len() == a.metrics.len()
        && soc1.metrics.iter().zip(&a.metrics).all(|(x, y)| {
            x.id == y.id
                && x.generated == y.generated
                && x.ttft_us == y.ttft_us
                && x.itl_us == y.itl_us
        });
    r.metric("soc1_bitwise_match_engine", if bitwise { 1.0 } else { 0.0 });

    // SoC replay determinism at 4 cores: identical trace spec →
    // identical tokens, clock and contention accounting.
    let sa = run_soc_trace(&rt, &sspec, 4).expect("soc replay a");
    let sb = run_soc_trace(&rt, &sspec, 4).expect("soc replay b");
    let stok_a: Vec<(u64, Vec<i32>)> =
        sa.metrics.iter().map(|m| (m.id, m.generated.clone())).collect();
    let stok_b: Vec<(u64, Vec<i32>)> =
        sb.metrics.iter().map(|m| (m.id, m.generated.clone())).collect();
    let soc_det = stok_a == stok_b
        && sa.elapsed_ms == sb.elapsed_ms
        && sa.stats.contention_dma_cycles == sb.stats.contention_dma_cycles;
    r.metric("soc_replay_deterministic", if soc_det { 1.0 } else { 0.0 });

    // ----- chaos: degradation curves under dead cores -------------------
    // The 4-core SoC with 0/1/2 cores killed mid-trace. Gate inputs: the
    // empty fault plan is bitwise the plain 4-core run, survivors keep
    // throughput above a proportional-minus-margin floor, every shard
    // stays leak-free, no request is lost (completed + shed == offered),
    // completed streams match the 1-core ground truth, and a seeded
    // fault schedule replays deterministically.
    let empty = run_soc_trace_with_faults(&rt, &sspec, 4, &FaultPlan::default())
        .expect("empty-plan replay");
    let etok: Vec<(u64, Vec<i32>)> =
        empty.metrics.iter().map(|m| (m.id, m.generated.clone())).collect();
    let empty_bitwise = etok == stok_a
        && empty.elapsed_ms == sa.elapsed_ms
        && empty.stats.contention_dma_cycles == sa.stats.contention_dma_cycles;
    r.metric("faults_empty_bitwise", if empty_bitwise { 1.0 } else { 0.0 });

    for (dead, plan_text) in [(1usize, "coredown=1@40"), (2, "coredown=1@40,coredown=3@60")] {
        let plan = FaultPlan::parse(plan_text).expect("degradation plan parses");
        let label = format!("deg_dead{dead}");
        let run = run_soc_trace_with_faults(&rt, &sspec, 4, &plan)
            .unwrap_or_else(|e| panic!("{label} replay failed: {e}"));
        let frac = run.throughput_tok_s() / sa.throughput_tok_s().max(1e-12);
        let leak_free = run.stats.per_core_kv.iter().all(|k| k.leak_free());
        let accounted =
            run.metrics.len() as u64 + run.stats.shed_requests == sspec.n as u64;
        // Survivor streams must be the 1-core streams bitwise, id by id
        // (shed requests simply have no stream to compare).
        let preserved = run.metrics.iter().all(|m| {
            core1_tokens.iter().any(|(id, toks)| *id == m.id && *toks == m.generated)
        });
        let rerun = run_soc_trace_with_faults(&rt, &sspec, 4, &plan)
            .unwrap_or_else(|e| panic!("{label} rerun failed: {e}"));
        let det = run.elapsed_ms == rerun.elapsed_ms
            && run.metrics.len() == rerun.metrics.len()
            && run
                .metrics
                .iter()
                .zip(&rerun.metrics)
                .all(|(x, y)| x.id == y.id && x.generated == y.generated);
        r.row(vec![
            format!("4cores-{dead}dead"),
            run.total_tokens().to_string(),
            format!("{:.1}", run.elapsed_ms / 1e3),
            format!("{:.2}", run.throughput_tok_s()),
            format!("{frac:.2}x of 4c"),
            String::new(),
            String::new(),
            run.stats.evacuated_seqs.to_string(),
            run.stats.preemptions.to_string(),
        ]);
        r.metric(&format!("{label}_throughput_frac"), frac);
        r.metric(&format!("{label}_kv_leak_free"), if leak_free { 1.0 } else { 0.0 });
        r.metric(&format!("{label}_accounted"), if accounted { 1.0 } else { 0.0 });
        r.metric(&format!("{label}_tokens_preserved"), if preserved { 1.0 } else { 0.0 });
        r.metric(&format!("{label}_evacuated"), run.stats.evacuated_seqs as f64);
        r.metric(&format!("{label}_faults_injected"), run.stats.faults_injected as f64);
        r.metric(&format!("{label}_shed"), run.stats.shed_requests as f64);
        r.metric(&format!("{label}_replay_deterministic"), if det { 1.0 } else { 0.0 });
    }

    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_report_passes_its_own_gates() {
        let r = report(true);
        assert_eq!(r.metrics["replay_deterministic"], 1.0);
        assert_eq!(r.metrics["batch4_tokens_match_single"], 1.0);
        assert_eq!(r.metrics["batch8_tokens_match_single"], 1.0);
        for label in ["single", "batch4", "batch8"] {
            assert_eq!(r.metrics[&format!("{label}_kv_leak_free")], 1.0, "{label} leaked");
        }
        // The acceptance bar: batched (N>=4) aggregate throughput >= 2x
        // the single-stream coordinator on the same trace.
        let x4 = r.metrics["batch4_throughput_x"];
        assert!(x4 >= 2.0, "batch-4 throughput only {x4:.2}x the single-stream baseline");
        assert!(r.metrics["batch8_throughput_x"] >= x4 * 0.9, "batch-8 collapsed");

        // ----- multi-core SoC scaling gates ----------------------------
        assert_eq!(r.metrics["soc1_bitwise_match_engine"], 1.0, "1-core SoC diverged");
        assert_eq!(r.metrics["soc_replay_deterministic"], 1.0);
        for cores in [2, 4, 8] {
            assert_eq!(
                r.metrics[&format!("cores{cores}_tokens_match_1core")],
                1.0,
                "sharding perturbed tokens at {cores} cores"
            );
        }
        for cores in [1, 2, 4, 8] {
            assert_eq!(
                r.metrics[&format!("cores{cores}_kv_leak_free")],
                1.0,
                "shard leaked at {cores} cores"
            );
        }
        // Scaling is real but strictly sublinear: per-shard queue tails
        // bound 2/4 cores below linear, and the shared-DDR port group
        // walls the 8-core point (nonzero contention delta).
        let sx2 = r.metrics["cores2_throughput_x"];
        let sx4 = r.metrics["cores4_throughput_x"];
        let sx8 = r.metrics["cores8_throughput_x"];
        assert!(sx2 > 1.0 && sx2 < 2.0, "2-core speedup {sx2:.2}x out of range");
        assert!(sx4 >= 2.0 && sx4 < 4.0, "4-core speedup {sx4:.2}x out of range");
        assert!(sx8 > 1.5 && sx8 < 8.0, "8-core speedup {sx8:.2}x out of range");
        assert!(
            r.metrics["cores8_contention_dma_cycles"] > 0.0,
            "8-core run saw no shared-DDR contention"
        );

        // ----- chaos degradation gates ---------------------------------
        assert_eq!(r.metrics["faults_empty_bitwise"], 1.0, "empty plan not bitwise");
        for (dead, floor) in [(1, 0.5), (2, 0.25)] {
            let label = format!("deg_dead{dead}");
            let frac = r.metrics[&format!("{label}_throughput_frac")];
            assert!(
                frac >= floor,
                "{dead} dead of 4: throughput {frac:.2}x of healthy, floor {floor}"
            );
            assert!(frac <= 1.05, "{dead} dead of 4 sped the SoC up?! {frac:.2}x");
            assert_eq!(r.metrics[&format!("{label}_kv_leak_free")], 1.0, "{label} leaked");
            assert_eq!(r.metrics[&format!("{label}_accounted")], 1.0, "{label} lost requests");
            assert_eq!(
                r.metrics[&format!("{label}_tokens_preserved")],
                1.0,
                "{label} perturbed surviving token streams"
            );
            assert!(
                r.metrics[&format!("{label}_evacuated")] > 0.0,
                "{label}: dead cores held no work?"
            );
            assert_eq!(
                r.metrics[&format!("{label}_replay_deterministic")],
                1.0,
                "{label} chaos replay diverged"
            );
        }
    }
}
