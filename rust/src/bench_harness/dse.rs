//! Design-space-explorer benchmark (`cargo bench --bench dse`,
//! `aquas bench dse`).
//!
//! Runs the Pareto search of [`crate::dse`] — quick mode exhausts the
//! 48-point demo space, full mode draws the seeded 64-point sample of
//! the 540-point space — and turns the ISSUE's three frontier
//! properties plus the area-budget monotonicity law into `--check`
//! gates over `BENCH_dse.json`:
//!
//! - `frontier_deterministic` — two back-to-back runs with the same
//!   seed/space produce bitwise-identical evaluations and frontiers
//!   (compared down to the IEEE-754 bits of the area objective);
//! - `frontier_mutually_nondominated` — no frontier member weakly
//!   dominates another;
//! - `frontier_covers_handpicked` — every hand-picked §6.1
//!   configuration is weakly dominated by some frontier member;
//! - `monotone_area_budget` — sweeping the area cap upward through
//!   every evaluated area, the best-cycles point never worsens.
//!
//! The raw frontier (one row per point, plus the §6.1 baselines) and
//! the per-point objective values are recorded so the report is a
//! usable artifact, not just a gate vector.

use crate::dse::{weakly_dominates, ExploreResult, Explorer, PointCost};

use super::Report;

fn identical(a: &ExploreResult, b: &ExploreResult) -> bool {
    let cost_eq = |x: &PointCost, y: &PointCost| {
        x.point == y.point
            && x.cycles == y.cycles
            && x.area_mm2.to_bits() == y.area_mm2.to_bits()
            && x.freq_mhz.to_bits() == y.freq_mhz.to_bits()
    };
    a.fingerprint() == b.fingerprint()
        && a.evaluated.len() == b.evaluated.len()
        && a.evaluated.iter().zip(&b.evaluated).all(|(x, y)| cost_eq(x, y))
        && a.infeasible == b.infeasible
}

fn monotone_over_area_budgets(r: &ExploreResult) -> bool {
    let mut areas: Vec<f64> = r.evaluated.iter().map(|c| c.area_mm2).collect();
    areas.sort_by(f64::total_cmp);
    let mut prev_best: Option<u64> = None;
    for cap in areas {
        let best = r.best_cycles_within(Some(cap));
        if let (Some(p), Some(b)) = (prev_best, best) {
            if b > p {
                return false;
            }
        }
        if best.is_some() {
            prev_best = best;
        }
    }
    true
}

/// Build the report; `quick` is the CI smoke mode (demo space).
pub fn report(quick: bool) -> Report {
    let ex = if quick { Explorer::demo() } else { Explorer::full() };
    let a = ex.run().expect("explore run");
    let b = ex.run().expect("explore replay");

    let mut rep = Report::new(
        "aquas explore — cycles × area Pareto frontier (gf2mm + attention + pqc + pcp)",
        vec!["config", "width", "burst", "inflight", "banks", "unroll", "cycles", "area mm2", "freq MHz", "kind"],
    );
    let mut row = |c: &PointCost, kind: &str| {
        rep.row(vec![
            c.point.key(),
            c.point.width.to_string(),
            c.point.burst.to_string(),
            c.point.in_flight.to_string(),
            c.point.banks.to_string(),
            c.point.unroll.to_string(),
            c.cycles.to_string(),
            format!("{:.4}", c.area_mm2),
            format!("{:.1}", c.freq_mhz),
            kind.to_string(),
        ]);
    };
    for c in &a.frontier {
        row(c, "frontier");
    }
    for c in &a.baselines {
        let on_frontier = a.frontier.iter().any(|f| f.point == c.point);
        row(c, if on_frontier { "handpicked+frontier" } else { "handpicked" });
    }

    rep.metric("space_size", a.space_size as f64);
    rep.metric("sampled", if a.sampled { 1.0 } else { 0.0 });
    rep.metric("evaluated_points", a.evaluated.len() as f64);
    rep.metric("infeasible_points", a.infeasible.len() as f64);
    rep.metric("frontier_size", a.frontier.len() as f64);
    rep.metric(
        "offload_matches",
        a.offload_proof.iter().map(|(_, n)| *n as f64).sum(),
    );
    if let Some(best) = a.best_cycles_point() {
        rep.metric("frontier_best_cycles", best.cycles as f64);
        rep.metric("frontier_best_cycles_area_mm2", best.area_mm2);
    }
    if let Some(default) = a.baselines.first() {
        rep.metric("handpicked_default_cycles", default.cycles as f64);
        rep.metric("handpicked_default_area_mm2", default.area_mm2);
        if let Some(best) = a.best_cycles_point() {
            rep.metric(
                "best_speedup_vs_handpicked",
                default.cycles as f64 / best.cycles as f64,
            );
        }
    }
    if let Some(wide) = a.baselines.get(1) {
        rep.metric("handpicked_wide_cycles", wide.cycles as f64);
        rep.metric("handpicked_wide_area_mm2", wide.area_mm2);
    }

    // The four gates.
    rep.metric("frontier_deterministic", if identical(&a, &b) { 1.0 } else { 0.0 });
    rep.metric(
        "frontier_mutually_nondominated",
        if a.frontier_mutually_nondominated() { 1.0 } else { 0.0 },
    );
    rep.metric(
        "frontier_covers_handpicked",
        if a.frontier_covers_baselines() { 1.0 } else { 0.0 },
    );
    rep.metric(
        "monotone_area_budget",
        if monotone_over_area_budgets(&a) { 1.0 } else { 0.0 },
    );
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::dominates;

    #[test]
    fn quick_report_passes_its_own_gates() {
        let rep = report(true);
        for gate in [
            "frontier_deterministic",
            "frontier_mutually_nondominated",
            "frontier_covers_handpicked",
            "monotone_area_budget",
        ] {
            assert_eq!(rep.metrics.get(gate), Some(&1.0), "gate {gate} failed");
        }
        assert!(rep.metrics["frontier_size"] >= 1.0);
        assert!(rep.metrics["best_speedup_vs_handpicked"] >= 1.0);
    }

    #[test]
    fn frontier_beats_or_matches_both_baselines_pointwise() {
        let r = Explorer::demo().run().expect("demo run");
        for b in &r.baselines {
            assert!(
                r.frontier.iter().any(|f| weakly_dominates(f, b)),
                "baseline {} escaped the frontier",
                b.point.key()
            );
        }
        // And the frontier strictly improves on at least one objective
        // somewhere, or hand-tuning was already Pareto-optimal — both
        // acceptable, but the demo space is built to expose a win.
        let default = &r.baselines[0];
        assert!(
            r.frontier.iter().any(|f| dominates(f, default) || f.point == default.point),
            "default baseline neither dominated nor on the frontier"
        );
    }
}
