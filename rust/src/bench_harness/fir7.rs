//! Figures 3 + 4: the fir7 running example — suboptimal (manual/naive)
//! lowering vs the optimized synthesis pipeline, with the IR shown after
//! each refinement step.

use crate::bench_harness::report::Report;
use crate::interface::cache::CacheHint;
use crate::interface::model::InterfaceSet;
use crate::ir::builder::FuncBuilder;
use crate::ir::Func;
use crate::runtime::DType;
use crate::synthesis::{hwgen, naive, synthesize, SynthOptions, SynthResult};

/// The fir7 kernel exactly as §4.3 describes it: a 108-byte `src` stream,
/// a 7-tap coefficient vector, a 21-element `bias` vector (the elision
/// candidate), 21 outputs.
pub fn fir7() -> Func {
    let mut b = FuncBuilder::new("fir7");
    let src = b.global("src", DType::F32, 27, CacheHint::Cold);
    let coef = b.global("coef", DType::F32, 7, CacheHint::Warm);
    let bias = b.global("bias", DType::F32, 21, CacheHint::Warm);
    let out = b.global("out", DType::F32, 21, CacheHint::Warm);
    let s_src = b.scratchpad("s_src", DType::F32, 27, 2);
    let s_coef = b.scratchpad("s_coef", DType::F32, 7, 1);
    let s_bias = b.scratchpad("s_bias", DType::F32, 21, 1);
    let s_out = b.scratchpad("s_out", DType::F32, 21, 1);
    let zero = b.const_i(0);
    b.transfer(s_src, zero, src, zero, 108);
    b.transfer(s_coef, zero, coef, zero, 28);
    b.transfer(s_bias, zero, bias, zero, 84);
    b.for_range(0, 21, 1, |b, i| {
        let init = b.const_f(0.0);
        let lb = b.const_i(0);
        let ub = b.const_i(7);
        let one = b.const_i(1);
        let acc = b.for_loop(lb, ub, one, &[init], |b, j, c| {
            let idx = b.add(i, j);
            let x = b.read_smem(s_src, idx);
            let w = b.read_smem(s_coef, j);
            let m = b.mul(x, w);
            vec![b.add(c[0], m)]
        });
        let bb = b.read_smem(s_bias, i);
        let y = b.add(acc[0], bb);
        b.write_smem(s_out, i, y);
    });
    let zero2 = b.const_i(0);
    b.transfer(out, zero2, s_out, zero2, 84);
    b.finish(&[])
}

/// Synthesis options for fir7. The elision profitability analysis measures
/// the 7-tap MAC stream directly from the loop nest (147 innermost
/// iterations hide per-element `bias` fetches; 7 reads per output keep
/// `src` staged), so the defaults suffice.
pub fn fir7_opts() -> SynthOptions {
    SynthOptions::default()
}

/// Run both flows on fir7.
pub fn run() -> (SynthResult, SynthResult, InterfaceSet) {
    let itfcs = InterfaceSet::rocket_default();
    let f = fir7();
    let smart = synthesize(&f, &itfcs, &fir7_opts()).expect("aquas fir7");
    let nai = naive::synthesize_naive(&f, &itfcs).expect("naive fir7");
    (smart, nai, itfcs)
}

/// Figure 3: the timing comparison.
pub fn fig3() -> Report {
    let (smart, nai, itfcs) = run();
    let mut r = Report::new(
        "Figure 3 — fir7 stage-in schedule: suboptimal lowering vs Aquas",
        vec!["design", "elided", "schedule (itfc: sizes)", "mem cycles"],
    );
    let fmt_sched = |s: &crate::synthesis::Schedule| {
        let mut parts = Vec::new();
        for item in &s.items {
            parts.push(format!("{}:{}B", itfcs.get(item.itfc).name, item.size));
        }
        parts.join(" ")
    };
    r.row(vec![
        "naive (manual first-glance)".into(),
        nai.elided.join(","),
        fmt_sched(&nai.schedule),
        nai.schedule.mem_latency().to_string(),
    ]);
    r.row(vec![
        "aquas (interface-aware)".into(),
        smart.elided.join(","),
        fmt_sched(&smart.schedule),
        smart.schedule.mem_latency().to_string(),
    ]);
    r.metric("naive_mem_cycles", nai.schedule.mem_latency() as f64);
    r.metric("aquas_mem_cycles", smart.schedule.mem_latency() as f64);
    r.metric(
        "speedup",
        nai.schedule.mem_latency() as f64 / smart.schedule.mem_latency().max(1) as f64,
    );
    r
}

/// Figure 4: the IR after each synthesis stage (rendered text).
pub fn fig4() -> String {
    let f = fir7();
    let itfcs = InterfaceSet::rocket_default();
    let smart = synthesize(&f, &itfcs, &fir7_opts()).expect("synt fir7");
    let mut out = String::new();
    out.push_str("=== (input) functional level ===\n");
    out.push_str(&crate::ir::printer::print_func(&f));
    out.push_str("\n=== (a) after scratchpad elision ===\n");
    out.push_str(&crate::ir::printer::print_func(&smart.functional));
    out.push_str("\n=== (b) after interface selection + canonicalization ===\n");
    out.push_str(&crate::ir::printer::print_func(&smart.architectural));
    out.push_str("\n=== (c) after transaction scheduling (temporal) ===\n");
    out.push_str(&crate::ir::printer::print_func(&smart.temporal));
    out.push_str("\n=== generated hardware (structural Verilog) ===\n");
    let desc = hwgen::generate(&smart, &itfcs);
    out.push_str(&hwgen::to_verilog(&desc));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aquas_elides_bias_but_not_src() {
        let (smart, _, _) = run();
        assert!(smart.elided.contains(&"s_bias".to_string()), "elided: {:?}", smart.elided);
        assert!(!smart.elided.contains(&"s_src".to_string()));
    }

    #[test]
    fn aquas_schedule_faster_than_naive() {
        let (smart, nai, _) = run();
        assert!(
            smart.schedule.mem_latency() < nai.schedule.mem_latency(),
            "aquas {} !< naive {}",
            smart.schedule.mem_latency(),
            nai.schedule.mem_latency()
        );
    }

    #[test]
    fn src_canonicalized_into_paper_segments() {
        let (smart, _, itfcs) = run();
        // The 108B src transfer must route over the bus as 64+32+8+4.
        let probe = crate::synthesis::memprobe::extract(&smart.functional).unwrap();
        let src_op = probe
            .ops
            .iter()
            .find(|o| smart.functional.buffer(o.buf).name == "src")
            .expect("src op");
        let a = &smart.assignments[src_op.id];
        assert_eq!(itfcs.get(a.itfc).name, "@busitfc");
        assert_eq!(a.segments, vec![64, 32, 8, 4]);
    }

    #[test]
    fn fig4_shows_all_levels() {
        let text = fig4();
        assert!(text.contains("transfer"));
        assert!(text.contains("copy_issue"));
        assert!(text.contains("module isax_fir7"));
    }
}
