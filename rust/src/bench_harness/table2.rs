//! Table 2: cycle counts, speedups, clock and area overheads for the PQC
//! and PCP case studies under three designs: base Rocket, the APS-like
//! naive flow ("ICCAD'25"), and Aquas.

use crate::area::{AreaModel, AreaReport};
use crate::bench_harness::report::Report;
use crate::compiler::{compile, CompileOptions};
use crate::cores::rocket::{CoreConfig, RocketModel};
use crate::cores::IsaxEngine;
use crate::ir::interp::Memory;
use crate::synthesis::{hwgen, naive, synthesize};
use crate::workloads::{pcp, pqc, Kernel};

/// Per-kernel measurements.
pub struct KernelRow {
    pub kernel: Kernel,
    pub base_cycles: u64,
    pub aps_cycles: u64,
    pub aquas_cycles: u64,
    /// Rocket + the Aquas-generated unit.
    pub area: AreaReport,
    pub aps_area: AreaReport,
    /// Engine cycles per invocation (diagnostics).
    pub aquas_engine: u64,
    pub aps_engine: u64,
}

impl KernelRow {
    pub fn aps_speedup(&self) -> f64 {
        self.base_cycles as f64 / self.aps_cycles as f64
    }

    pub fn aquas_speedup(&self) -> f64 {
        self.base_cycles as f64 / self.aquas_cycles as f64
    }
}

/// Whole-table result.
pub struct Table2 {
    pub pqc_rows: Vec<KernelRow>,
    pub pcp_rows: Vec<KernelRow>,
    pub pqc_e2e: E2eRow,
    pub pcp_e2e: E2eRow,
}

/// End-to-end measurements.
pub struct E2eRow {
    pub name: &'static str,
    pub base_cycles: u64,
    pub aps_cycles: u64,
    pub aquas_cycles: u64,
    pub area: AreaReport,
    pub aps_area: AreaReport,
}

impl E2eRow {
    pub fn aps_speedup(&self) -> f64 {
        self.base_cycles as f64 / self.aps_cycles as f64
    }

    pub fn aquas_speedup(&self) -> f64 {
        self.base_cycles as f64 / self.aquas_cycles as f64
    }
}

/// Measure one kernel under all three designs.
pub fn measure(k: &Kernel) -> KernelRow {
    let area_model = AreaModel::default();
    let base_model = RocketModel::new(CoreConfig::default());

    // Base: the plain software on the scalar core.
    let mut mem = Memory::for_func(&k.software);
    (k.init)(&k.software, &mut mem);
    let base = base_model.simulate(&k.software, &[], &mut mem).expect("base sim");

    // Synthesize the ISAX under both flows.
    let smart = synthesize(&k.isax.func, &k.itfcs, &k.synth_opts).expect("aquas synth");
    let naive_r = naive::synthesize_naive(&k.isax.func, &k.itfcs).expect("naive synth");
    let smart_desc = hwgen::generate(&smart, &k.itfcs);
    let naive_desc = hwgen::generate(&naive_r, &k.itfcs);
    let smart_engine = IsaxEngine::from_synthesis(&smart, &smart_desc, &k.itfcs);
    let naive_engine = IsaxEngine::from_synthesis_naive(&naive_r, &naive_desc, &k.itfcs);

    // Offload via the compiler, then re-time the lowered program.
    let lowered = compile(&k.software, &[k.isax.clone()], &CompileOptions::default())
        .expect("compile")
        .func;
    let mut mem2 = Memory::for_func(&lowered);
    (k.init)(&lowered, &mut mem2);
    let aquas_model = RocketModel::new(CoreConfig::default())
        .with_isax(&k.isax.name, smart_engine.cycles_per_invocation());
    let aquas = aquas_model.simulate(&lowered, &[], &mut mem2).expect("aquas sim");

    let mut mem3 = Memory::for_func(&lowered);
    (k.init)(&lowered, &mut mem3);
    let aps_model = RocketModel::new(CoreConfig::default())
        .with_isax(&k.isax.name, naive_engine.cycles_per_invocation());
    let aps = aps_model.simulate(&lowered, &[], &mut mem3).expect("aps sim");

    KernelRow {
        base_cycles: base.cycles,
        aps_cycles: aps.cycles,
        aquas_cycles: aquas.cycles,
        area: area_model.rocket_with_isaxes(&[&smart_desc]),
        aps_area: area_model.rocket_with_isaxes(&[&naive_desc]),
        aquas_engine: smart_engine.cycles_per_invocation(),
        aps_engine: naive_engine.cycles_per_invocation(),
        kernel: clone_kernel(k),
    }
}

// Kernel holds fn pointers + IR, all cloneable by hand.
fn clone_kernel(k: &Kernel) -> Kernel {
    Kernel {
        name: k.name,
        software: k.software.clone(),
        variants: k.variants.clone(),
        isax: k.isax.clone(),
        init: k.init,
        outputs: k.outputs.clone(),
        vector_profile: k.vector_profile,
        synth_opts: k.synth_opts.clone(),
        itfcs: k.itfcs.clone(),
    }
}

/// Measure a list of kernels.
pub fn run_kernels(ks: Vec<Kernel>) -> Vec<KernelRow> {
    ks.iter().map(measure).collect()
}

fn measure_e2e(
    name: &'static str,
    software: &crate::ir::Func,
    init: fn(&crate::ir::Func, &mut Memory),
    kernels: &[Kernel],
) -> E2eRow {
    let area_model = AreaModel::default();
    let base_model = RocketModel::new(CoreConfig::default());
    let mut mem = Memory::for_func(software);
    init(software, &mut mem);
    let base = base_model.simulate(software, &[], &mut mem).expect("base e2e");

    let isaxes: Vec<_> = kernels.iter().map(|k| k.isax.clone()).collect();
    let lowered = compile(software, &isaxes, &CompileOptions::default()).expect("compile e2e").func;

    let mut aquas_model = RocketModel::new(CoreConfig::default());
    let mut aps_model = RocketModel::new(CoreConfig::default());
    let mut smart_descs = Vec::new();
    let mut naive_descs = Vec::new();
    for k in kernels {
        let smart = synthesize(&k.isax.func, &k.itfcs, &k.synth_opts).expect("synth");
        let nai = naive::synthesize_naive(&k.isax.func, &k.itfcs).expect("naive");
        let sd = hwgen::generate(&smart, &k.itfcs);
        let nd = hwgen::generate(&nai, &k.itfcs);
        let se = IsaxEngine::from_synthesis(&smart, &sd, &k.itfcs);
        let ne = IsaxEngine::from_synthesis_naive(&nai, &nd, &k.itfcs);
        aquas_model = aquas_model.with_isax(&k.isax.name, se.cycles_per_invocation());
        aps_model = aps_model.with_isax(&k.isax.name, ne.cycles_per_invocation());
        smart_descs.push(sd);
        naive_descs.push(nd);
    }
    let mut m2 = Memory::for_func(&lowered);
    init(&lowered, &mut m2);
    let aquas = aquas_model.simulate(&lowered, &[], &mut m2).expect("aquas e2e");
    let mut m3 = Memory::for_func(&lowered);
    init(&lowered, &mut m3);
    let aps = aps_model.simulate(&lowered, &[], &mut m3).expect("aps e2e");

    E2eRow {
        name,
        base_cycles: base.cycles,
        aps_cycles: aps.cycles,
        aquas_cycles: aquas.cycles,
        area: area_model.rocket_with_isaxes(&smart_descs.iter().collect::<Vec<_>>()),
        aps_area: area_model.rocket_with_isaxes(&naive_descs.iter().collect::<Vec<_>>()),
    }
}

/// Run the full Table 2.
pub fn run() -> Table2 {
    let pqc_kernels = pqc::kernels();
    let pcp_kernels = pcp::kernels();
    let pqc_rows = run_kernels(pqc::kernels());
    let pcp_rows = run_kernels(pcp::kernels());
    let pqc_e2e = measure_e2e(
        "PQC end-to-end",
        &pqc::end_to_end_software(),
        pqc::init_end_to_end,
        &pqc_kernels,
    );
    let pcp_e2e = measure_e2e(
        "PCP end-to-end",
        &pcp::end_to_end_software(),
        pcp::init_end_to_end,
        &pcp_kernels,
    );
    Table2 { pqc_rows, pcp_rows, pqc_e2e, pcp_e2e }
}

/// Format as the paper's table.
pub fn report() -> Report {
    let t = run();
    let mut r = Report::new(
        "Table 2 — PQC + PCP cycle counts / speedups / overheads (Base | APS-like | Aquas)",
        vec![
            "case", "base cyc", "aps cyc", "aquas cyc", "aps x", "aquas x", "aps clk",
            "aquas clk", "aps area", "aquas area",
        ],
    );
    let push = |name: String,
                    base: u64,
                    aps: u64,
                    aquas: u64,
                    aps_area: &AreaReport,
                    area: &AreaReport,
                    r: &mut Report| {
        r.row(vec![
            name.clone(),
            base.to_string(),
            aps.to_string(),
            aquas.to_string(),
            format!("{:.2}x", base as f64 / aps as f64),
            format!("{:.2}x", base as f64 / aquas as f64),
            format!("{:+.1}%", aps_area.period_delta_pct()),
            format!("{:+.1}%", area.period_delta_pct()),
            format!("+{:.1}%", aps_area.area_overhead_pct()),
            format!("+{:.1}%", area.area_overhead_pct()),
        ]);
        r.metric(&format!("{name}_aquas_speedup"), base as f64 / aquas as f64);
        r.metric(&format!("{name}_aps_speedup"), base as f64 / aps as f64);
        r.metric(&format!("{name}_area_pct"), area.area_overhead_pct());
    };
    for row in t.pqc_rows.iter().chain(&t.pcp_rows) {
        push(
            row.kernel.name.to_string(),
            row.base_cycles,
            row.aps_cycles,
            row.aquas_cycles,
            &row.aps_area,
            &row.area,
            &mut r,
        );
    }
    for e in [&t.pqc_e2e, &t.pcp_e2e] {
        push(
            e.name.to_string(),
            e.base_cycles,
            e.aps_cycles,
            e.aquas_cycles,
            &e.aps_area,
            &e.area,
            &mut r,
        );
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aquas_beats_base_on_every_kernel() {
        let t = run();
        for row in t.pqc_rows.iter().chain(&t.pcp_rows) {
            assert!(
                row.aquas_speedup() > 1.0,
                "{}: aquas {} !< base {}",
                row.kernel.name,
                row.aquas_cycles,
                row.base_cycles
            );
        }
    }

    #[test]
    fn aquas_beats_aps_everywhere() {
        let t = run();
        for row in t.pqc_rows.iter().chain(&t.pcp_rows) {
            assert!(
                row.aquas_cycles < row.aps_cycles,
                "{}: aquas {} !< aps {}",
                row.kernel.name,
                row.aquas_cycles,
                row.aps_cycles
            );
        }
        assert!(t.pqc_e2e.aquas_cycles < t.pqc_e2e.aps_cycles);
        assert!(t.pcp_e2e.aquas_cycles < t.pcp_e2e.aps_cycles);
    }

    #[test]
    fn e2e_speedups_have_paper_shape() {
        // Paper: Aquas 1.42×/1.96× on e2e; APS < 1× on both e2e cases.
        let t = run();
        assert!(t.pqc_e2e.aquas_speedup() > 1.1, "pqc {}", t.pqc_e2e.aquas_speedup());
        assert!(t.pcp_e2e.aquas_speedup() > 1.1, "pcp {}", t.pcp_e2e.aquas_speedup());
    }

    #[test]
    fn aps_shows_paper_slowdowns() {
        // Paper Table 2: the APS-like flow *loses to the base core* on
        // mgf2mm (0.21×), vfsmax (0.79×) and vmadot (0.63×) — the blind-
        // elision / narrow-port failure mode.
        let t = run();
        for name in ["mgf2mm", "vfsmax"] {
            let row = t
                .pqc_rows
                .iter()
                .chain(&t.pcp_rows)
                .find(|r| r.kernel.name == name)
                .unwrap();
            assert!(
                row.aps_speedup() < 1.0,
                "{name}: aps speedup {:.2} should be < 1",
                row.aps_speedup()
            );
        }
        // vmadot lands near break-even in our model (paper: 0.63×; see
        // EXPERIMENTS.md for the delta discussion).
        let vmadot =
            t.pcp_rows.iter().find(|r| r.kernel.name == "vmadot").unwrap();
        assert!(vmadot.aps_speedup() < 1.5, "vmadot aps {:.2}", vmadot.aps_speedup());
        // And the PQC end-to-end APS result is a slowdown (paper: 0.48×;
        // our model: ~0.5×). PCP e2e lands near break-even (paper 0.82×).
        assert!(t.pqc_e2e.aps_speedup() < 1.0, "pqc e2e {:.2}", t.pqc_e2e.aps_speedup());
        assert!(t.pcp_e2e.aps_speedup() < 1.3, "pcp e2e {:.2}", t.pcp_e2e.aps_speedup());
    }

    #[test]
    fn area_overheads_modest_and_clock_clean() {
        let t = run();
        for row in t.pqc_rows.iter().chain(&t.pcp_rows) {
            let pct = row.area.area_overhead_pct();
            assert!(pct > 0.0 && pct < 25.0, "{}: {pct}%", row.kernel.name);
            assert_eq!(row.area.period_delta_pct(), 0.0, "{}", row.kernel.name);
        }
        assert!(t.pcp_e2e.area.area_overhead_pct() < 35.0);
    }
}
