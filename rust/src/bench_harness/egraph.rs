//! E-graph engine benchmarks (`cargo bench --bench egraph`).
//!
//! Measures, per workload (gf2mm / attention / mcov), the three numbers
//! that track the matching engine's throughput from this PR onward:
//!
//! - **saturation wall time** of the internal rule set over the encoded
//!   software + aligned-ISAX pair;
//! - **e-nodes/sec** processed at saturation;
//! - **match-round latency** — the full `compile()` path (encode, hybrid
//!   rewriting, skeleton match, lower).
//!
//! The bench target additionally replays the same encoded term graphs
//! into a copy of the pre-PR engine (full-memo-rehash rebuild, string-
//! keyed matcher) to record an old-vs-new speedup. The [`TermGraph`]
//! export below makes that replay engine-agnostic: encoding is add-only,
//! so class ids are dense and topologically ordered, and any e-graph
//! implementation can rebuild the exact same workload from the term list.

use std::time::Instant;

use crate::compiler::rules::internal_rules;
use crate::compiler::{self, encode::encode_func, CompileOptions, IsaxDef};
use crate::egraph::{ClassId, EGraph, Runner};
use crate::interface::cache::CacheHint;
use crate::ir::builder::FuncBuilder;
use crate::ir::Func;
use crate::runtime::DType;
use crate::util::stats::summarize;
use crate::workloads::pqc;

use super::Report;

/// Attention-score dimensions (one head): `SEQ` keys of width `D`.
pub const ATTN_SEQ: i64 = 16;
pub const ATTN_D: i64 = 8;

/// Software spelling of the attention score kernel: `s[i] += q[j] *
/// k[i<<3 + j]` — the shift-indexed form idiomatic C produces for a
/// power-of-two head width.
pub fn attention_software() -> Func {
    let mut b = FuncBuilder::new("attn_scores_sw");
    let q = b.global("q", DType::I32, ATTN_D as usize, CacheHint::Warm);
    let k = b.global("k", DType::I32, (ATTN_SEQ * ATTN_D) as usize, CacheHint::Warm);
    let s = b.global("s", DType::I32, ATTN_SEQ as usize, CacheHint::Warm);
    b.for_range(0, ATTN_SEQ, 1, |b, i| {
        b.for_range(0, ATTN_D, 1, |b, j| {
            let qv = b.load(q, j);
            let three = b.const_i(3);
            let row = b.shl(i, three);
            let kidx = b.add(row, j);
            let kv = b.load(k, kidx);
            let prod = b.mul(qv, kv);
            let sv = b.load(s, i);
            let acc = b.add(sv, prod);
            b.store(s, i, acc);
        });
    });
    b.finish(&[])
}

/// ISAX description of the same kernel with multiply indexing (`i * 8 +
/// j`) — the `shl-to-mul` internal rule must bridge the two spellings.
pub fn attention_isax() -> Func {
    let mut b = FuncBuilder::new("attn_scores");
    let q = b.global("q", DType::I32, ATTN_D as usize, CacheHint::Warm);
    let k = b.global("k", DType::I32, (ATTN_SEQ * ATTN_D) as usize, CacheHint::Warm);
    let s = b.global("s", DType::I32, ATTN_SEQ as usize, CacheHint::Warm);
    b.for_range(0, ATTN_SEQ, 1, |b, i| {
        b.for_range(0, ATTN_D, 1, |b, j| {
            let qv = b.load(q, j);
            let eight = b.const_i(8);
            let row = b.mul(i, eight);
            let kidx = b.add(row, j);
            let kv = b.load(k, kidx);
            let prod = b.mul(qv, kv);
            let sv = b.load(s, i);
            let acc = b.add(sv, prod);
            b.store(s, i, acc);
        });
    });
    b.finish(&[])
}

/// RF-divergent gf2mm software: the same xor/and datapath as
/// `pqc::software_mgf2mm`, but every row index spelled with shifts
/// (`r << 5`, `k << 3`, `r << 3` — K = 32, C = 8 are powers of two).
/// The canonical software and ISAX hashcons to the same class with zero
/// rewrites; this spelling forces the `shl-to-mul` bridge, making gf2mm a
/// genuine saturation workload (the paper's Table 3 "RF" divergence).
pub fn gf2mm_software_shifted() -> Func {
    use crate::workloads::pqc::{C, K, R};
    let mut b = FuncBuilder::new("mgf2mm_sw_shifted");
    let h = b.global("h", DType::I32, (R * K) as usize, CacheHint::Warm);
    let e = b.global("em", DType::I32, (K * C) as usize, CacheHint::Warm);
    let s = b.global("s", DType::I32, (R * C) as usize, CacheHint::Warm);
    let logk = K.trailing_zeros() as i64;
    let logc = C.trailing_zeros() as i64;
    b.for_range(0, R, 1, |b, r| {
        b.for_range(0, C, 1, |b, c| {
            b.for_range(0, K, 1, |b, k| {
                let lk = b.const_i(logk);
                let rk = b.shl(r, lk);
                let hidx = b.add(rk, k);
                let hv = b.load(h, hidx);
                let lc = b.const_i(logc);
                let kcidx = b.shl(k, lc);
                let eidx = b.add(kcidx, c);
                let ev = b.load(e, eidx);
                let prod = b.and(hv, ev);
                let rc = b.shl(r, lc);
                let sidx = b.add(rc, c);
                let sv = b.load(s, sidx);
                let acc = b.xor(sv, prod);
                b.store(s, sidx, acc);
            });
        });
    });
    b.finish(&[])
}

/// An engine-agnostic snapshot of an encoded software + ISAX pair.
///
/// Encoding is add-only (no unions), so every class holds exactly one
/// node, class ids are dense, and children always reference smaller ids —
/// `terms[i]` can be replayed in order into any e-graph implementation.
pub struct TermGraph {
    /// `(symbol, children-as-term-indices)`, index == original class id.
    pub terms: Vec<(String, Vec<u32>)>,
    /// Term index of the software top-level loop class.
    pub sw_root: u32,
    /// Term index of the aligned-ISAX top-level loop class.
    pub isax_root: u32,
}

/// Encode `software` (canonicalized) + `isax` (aligned) into a fresh
/// e-graph and export the term list.
pub fn term_graph(software: &Func, isax: &Func) -> TermGraph {
    let sw = compiler::align::canonicalize_software(software);
    let aligned = compiler::align::align_isax(isax).expect("isax aligns");
    let mut g = EGraph::new();
    let m_sw = encode_func(&mut g, &sw);
    let m_isax = encode_func(&mut g, &aligned);
    let root_of = |m: &compiler::encode::EncodeMap| -> u32 {
        m.loops
            .iter()
            .find(|&&(_, _, d)| d == 0)
            .map(|&(_, c, _)| c.0)
            .expect("workload has a top-level loop")
    };
    let sw_root = root_of(&m_sw);
    let isax_root = root_of(&m_isax);
    let terms = g
        .class_ids()
        .into_iter()
        .map(|c| {
            let nodes = g.nodes(c);
            assert_eq!(nodes.len(), 1, "encode is add-only: one node per class");
            let n = &nodes[0];
            assert!(
                n.children.iter().all(|k| k.0 < c.0),
                "encode is topological: children precede parents"
            );
            (g.sym_name(n.sym).to_string(), n.children.iter().map(|k| k.0).collect())
        })
        .collect();
    TermGraph { terms, sw_root, isax_root }
}

/// The gf2mm (PQC syndrome matmul) pair: shift-spelled software against
/// the bundled ISAX description.
pub fn gf2mm_term_graph() -> TermGraph {
    term_graph(&gf2mm_software_shifted(), &pqc::isax_mgf2mm())
}

/// The synthetic attention pair defined above.
pub fn attention_term_graph() -> TermGraph {
    term_graph(&attention_software(), &attention_isax())
}

/// Replay a [`TermGraph`] into a fresh engine instance.
pub fn replay(tg: &TermGraph) -> (EGraph, ClassId, ClassId) {
    let mut g = EGraph::new();
    let mut ids: Vec<ClassId> = Vec::with_capacity(tg.terms.len());
    for (sym, kids) in &tg.terms {
        let children: Vec<ClassId> = kids.iter().map(|&k| ids[k as usize]).collect();
        ids.push(g.add_named(sym, children));
    }
    (g, ids[tg.sw_root as usize], ids[tg.isax_root as usize])
}

/// Saturation limits used by every e-graph bench (old and new engines),
/// mirroring `CompileOptions::default()`.
pub fn bench_runner() -> Runner {
    Runner { iter_limit: 12, node_limit: 100_000, match_limit: 10_000 }
}

/// One workload's measurements.
struct WorkloadNumbers {
    initial_enodes: usize,
    saturated_enodes: usize,
    iterations: usize,
    saturate_ms: f64,
    enodes_per_sec: f64,
    match_ms: f64,
    matched: bool,
}

fn measure(tg: &TermGraph, software: &Func, isax: IsaxDef, samples: usize) -> WorkloadNumbers {
    // Saturation: replay the encoded pair, run the internal rules. Rule
    // construction (parse + pattern compilation) stays outside the timed
    // region, matching how the bench target times the legacy comparison.
    let rules = internal_rules();
    let mut initial = 0;
    let mut saturated = 0;
    let mut iterations = 0;
    let sat: Vec<f64> = (0..samples)
        .map(|_| {
            let (mut g, sw_root, isax_root) = replay(tg);
            initial = g.node_count();
            let t0 = Instant::now();
            let report = bench_runner().run(&mut g, &rules);
            // The "match" of the saturation benchmark: class equality of
            // the two top-level loops (kept inside the timed region — it
            // is what the compiler's skeleton engine does per round).
            let _equal = g.find(sw_root) == g.find(isax_root);
            let dt = t0.elapsed().as_secs_f64();
            saturated = g.node_count();
            iterations = report.iterations;
            dt * 1e3
        })
        .collect();
    let sat = summarize(sat);

    // Match-round latency: the full compile pipeline.
    let mut matched = false;
    let mat: Vec<f64> = (0..samples)
        .map(|_| {
            let t0 = Instant::now();
            let r = compiler::compile(software, &[isax.clone()], &CompileOptions::default())
                .expect("compile");
            matched = !r.stats.matched.is_empty();
            t0.elapsed().as_secs_f64() * 1e3
        })
        .collect();
    let mat = summarize(mat);

    WorkloadNumbers {
        initial_enodes: initial,
        saturated_enodes: saturated,
        iterations,
        saturate_ms: sat.mean,
        enodes_per_sec: if sat.mean > 0.0 { saturated as f64 / (sat.mean / 1e3) } else { 0.0 },
        match_ms: mat.mean,
        matched,
    }
}

/// The e-graph engine report (new engine only; the bench target adds the
/// legacy comparison). `quick` runs one sample per section (CI smoke).
pub fn report(quick: bool) -> Report {
    let samples = if quick { 1 } else { 5 };
    let mut r = Report::new(
        "E-graph engine — saturation + match throughput (worklist rebuild, \
         symbol-indexed, compiled patterns)",
        vec![
            "workload",
            "initial e-nodes",
            "saturated e-nodes",
            "iters",
            "saturate ms",
            "e-nodes/s",
            "match ms",
            "matched",
        ],
    );
    let mcov = crate::workloads::pcp::kernels()
        .into_iter()
        .find(|k| k.name == "mcov.vs")
        .expect("mcov kernel");
    let cases: Vec<(&str, TermGraph, Func, IsaxDef)> = vec![
        (
            "gf2mm",
            gf2mm_term_graph(),
            gf2mm_software_shifted(),
            IsaxDef { name: "mgf2mm".into(), func: pqc::isax_mgf2mm() },
        ),
        (
            "attention",
            attention_term_graph(),
            attention_software(),
            IsaxDef { name: "attn_scores".into(), func: attention_isax() },
        ),
        (
            "mcov",
            term_graph(&mcov.software, &mcov.isax.func),
            mcov.software.clone(),
            mcov.isax.clone(),
        ),
    ];
    for (name, tg, software, isax) in cases {
        let n = measure(&tg, &software, isax, samples);
        r.row(vec![
            name.into(),
            n.initial_enodes.to_string(),
            n.saturated_enodes.to_string(),
            n.iterations.to_string(),
            format!("{:.3}", n.saturate_ms),
            format!("{:.0}", n.enodes_per_sec),
            format!("{:.3}", n.match_ms),
            if n.matched { "yes".into() } else { "no".into() },
        ]);
        r.metric(&format!("{name}_initial_enodes"), n.initial_enodes as f64);
        r.metric(&format!("{name}_saturated_enodes"), n.saturated_enodes as f64);
        r.metric(&format!("{name}_saturate_ms"), n.saturate_ms);
        r.metric(&format!("{name}_enodes_per_sec"), n.enodes_per_sec);
        r.metric(&format!("{name}_match_ms"), n.match_ms);
        r.metric(&format!("{name}_matched"), if n.matched { 1.0 } else { 0.0 });
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attention_software_matches_isax() {
        let r = compiler::compile(
            &attention_software(),
            &[IsaxDef { name: "attn_scores".into(), func: attention_isax() }],
            &CompileOptions::default(),
        )
        .unwrap();
        assert_eq!(r.stats.matched, vec!["attn_scores".to_string()], "{:?}", r.stats);
        assert!(r.stats.internal_rewrites > 0, "shl↔mul bridging required");
    }

    #[test]
    fn shifted_gf2mm_matches_through_internal_rewrites() {
        let r = compiler::compile(
            &gf2mm_software_shifted(),
            &[IsaxDef { name: "mgf2mm".into(), func: pqc::isax_mgf2mm() }],
            &CompileOptions::default(),
        )
        .unwrap();
        assert_eq!(r.stats.matched, vec!["mgf2mm".to_string()], "{:?}", r.stats);
        assert!(r.stats.internal_rewrites > 0, "shift spelling needs the RF bridge");
    }

    #[test]
    fn term_graph_replays_loss_free() {
        let tg = gf2mm_term_graph();
        assert!(tg.terms.len() > 100, "gf2mm encodes to a non-trivial graph");
        let (g, sw, isax) = replay(&tg);
        assert_eq!(g.node_count(), tg.terms.len());
        assert_ne!(g.find(sw), g.find(isax), "distinct spellings before saturation");
        // Saturating the replayed pair matches the two top loops — the
        // same verdict the real compiler reaches on mgf2mm.
        let (mut g, sw, isax) = replay(&tg);
        bench_runner().run(&mut g, &internal_rules());
        assert_eq!(g.find(sw), g.find(isax), "gf2mm saturation unifies sw and isax");
    }
}
