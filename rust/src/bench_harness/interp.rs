//! IR-interpreter benchmarks (`cargo bench --bench interp`).
//!
//! Measures, per AOT kernel at *manifest* shapes, the tree-walking
//! reference interpreter ([`crate::ir::interp`]) against the compiled
//! register-bytecode VM ([`crate::ir::vm`]): wall time per execution,
//! one-off compile cost, and `speedup_vs_legacy`. The tree-walker *is*
//! the legacy engine — it stays in-tree as the differential oracle, so
//! the comparison needs no embedded copy (unlike `benches/egraph.rs`).
//!
//! The module also hosts the building blocks the differential tests
//! share:
//!
//! - the IR spellings of every AOT kernel entry (`ir_gf2mm`, `ir_vmvar`,
//!   …) used by `tests/golden_diff.rs` for the interp/vm/runtime triple
//!   check;
//! - [`random_program`], a seeded random Aquas-IR generator (nested
//!   loops with carried values, if/else, loads/stores, bulk copies, irf
//!   traffic, mixed int/float dataflow) used by `tests/vm_diff.rs` and
//!   the bench's `--check` fuzz gate;
//! - [`check_equivalent`], which runs one function through both engines
//!   on identically seeded memories and compares return values, the full
//!   memory image (bit-exact, via the typed arena views), the irf, and
//!   [`ExecStats`] — or, for failing programs, that both engines fail
//!   identically;
//! - [`check_opt_equivalent`] and [`dynamic_ops`], the mid-end
//!   (`ir::passes`) observational-equivalence check and the dynamic
//!   op-count metric the `--check` optimization gates ride on;
//! - [`check_fuel_equivalent`], the fuel-metering determinism check
//!   (unlimited fuel bitwise-identical; budget exhaustion stops both
//!   engines at the identical op), and [`no_panic_smoke`], the
//!   hostile-input gate — both feed `_agree` metrics that the bench's
//!   `--check` mode fails on.

use std::time::Instant;

use crate::interface::cache::CacheHint;
use crate::interface::model::InterfaceId;
use crate::interface::TransactionKind;
use crate::ir::builder::FuncBuilder;
use crate::ir::func::BufferId;
use crate::ir::interp::{self, ExecStats, Fuel, Memory, Val};
use crate::ir::ops::CmpPred;
use crate::ir::passes::{self, OptLevel, Pass};
use crate::ir::types::Type;
use crate::ir::{vm, Func, Value};
use crate::runtime::DType;
use crate::util::rng::Rng;
use crate::util::stats::geomean;
use crate::workloads::graphics::{KA, KD, KS, RGB2YUV, SHININESS};

use super::Report;

// ---------------------------------------------------------------------------
// IR spellings of the AOT kernel entries (manifest shapes)
// ---------------------------------------------------------------------------

/// gf2mm — `[n,n] x [n,n]` over GF(2) (and/xor datapath).
pub fn ir_gf2mm(n: i64) -> Func {
    let mut b = FuncBuilder::new("gf2mm_ir");
    let a = b.global("a", DType::I32, (n * n) as usize, CacheHint::Warm);
    let bm = b.global("b", DType::I32, (n * n) as usize, CacheHint::Warm);
    let s = b.global("s", DType::I32, (n * n) as usize, CacheHint::Warm);
    b.for_range(0, n, 1, |b, r| {
        b.for_range(0, n, 1, |b, c| {
            b.for_range(0, n, 1, |b, k| {
                let nn = b.const_i(n);
                let rk = b.mul(r, nn);
                let aidx = b.add(rk, k);
                let av = b.load(a, aidx);
                let kn = b.mul(k, nn);
                let bidx = b.add(kn, c);
                let bv = b.load(bm, bidx);
                let prod = b.and(av, bv);
                let rc = b.mul(r, nn);
                let sidx = b.add(rc, c);
                let sv = b.load(s, sidx);
                let acc = b.xor(sv, prod);
                b.store(s, sidx, acc);
            });
        });
    });
    b.finish(&[])
}

/// vdecomp — `[nwords]` packed words -> `[nwords*32]` bits (shift/mask).
pub fn ir_vdecomp(nwords: i64) -> Func {
    let nbits = nwords * 32;
    let mut b = FuncBuilder::new("vdecomp_ir");
    let e = b.global("e", DType::I32, nwords as usize, CacheHint::Warm);
    let out = b.global("out", DType::I32, nbits as usize, CacheHint::Warm);
    b.for_range(0, nbits, 1, |b, i| {
        let five = b.const_i(5);
        let word_idx = b.shr(i, five);
        let w = b.load(e, word_idx);
        let mask31 = b.const_i(31);
        let sh = b.and(i, mask31);
        let shifted = b.shr(w, sh);
        let one = b.const_i(1);
        let bit = b.and(shifted, one);
        b.store(out, i, bit);
    });
    b.finish(&[])
}

/// vdist3 — `[n,3]`² -> `[n]` squared distances.
pub fn ir_vdist3(n: i64) -> Func {
    let mut b = FuncBuilder::new("vdist3_ir");
    let p = b.global("p", DType::F32, (n * 3) as usize, CacheHint::Warm);
    let q = b.global("q", DType::F32, (n * 3) as usize, CacheHint::Warm);
    let d = b.global("d", DType::F32, n as usize, CacheHint::Warm);
    b.for_range(0, n, 1, |b, i| {
        let three = b.const_i(3);
        let base = b.mul(i, three);
        let mut acc = b.const_f(0.0);
        for dim in 0..3 {
            let off = b.const_i(dim);
            let idx = b.add(base, off);
            let pv = b.load(p, idx);
            let qv = b.load(q, idx);
            let diff = b.sub(pv, qv);
            let sq = b.mul(diff, diff);
            acc = b.add(acc, sq);
        }
        b.store(d, i, acc);
    });
    b.finish(&[])
}

/// mcov — `[n,3]`² -> `[3,3]` cross-covariance of *centered* points.
/// Assumes the `pm`/`qm` mean buffers start zeroed (they are outputs of
/// the first two stages).
pub fn ir_mcov_centered(n: i64) -> Func {
    let mut b = FuncBuilder::new("mcov_ir");
    let p = b.global("p", DType::F32, (n * 3) as usize, CacheHint::Warm);
    let q = b.global("q", DType::F32, (n * 3) as usize, CacheHint::Warm);
    let pm = b.global("pm", DType::F32, 3, CacheHint::Warm);
    let qm = b.global("qm", DType::F32, 3, CacheHint::Warm);
    let cov = b.global("cov", DType::F32, 9, CacheHint::Warm);
    // Column sums.
    b.for_range(0, n, 1, |b, i| {
        let three = b.const_i(3);
        let base = b.mul(i, three);
        for d in 0..3 {
            let off = b.const_i(d);
            let idx = b.add(base, off);
            let pv = b.load(p, idx);
            let ps = b.load(pm, off);
            let ps2 = b.add(ps, pv);
            b.store(pm, off, ps2);
            let qv = b.load(q, idx);
            let qs = b.load(qm, off);
            let qs2 = b.add(qs, qv);
            b.store(qm, off, qs2);
        }
    });
    // Sums -> means.
    b.for_range(0, 3, 1, |b, d| {
        let nf = b.const_f(n as f64);
        let ps = b.load(pm, d);
        let pmean = b.div(ps, nf);
        b.store(pm, d, pmean);
        let qs = b.load(qm, d);
        let qmean = b.div(qs, nf);
        b.store(qm, d, qmean);
    });
    // Centered cross-covariance.
    b.for_range(0, n, 1, |b, i| {
        let three = b.const_i(3);
        let base = b.mul(i, three);
        b.for_range(0, 3, 1, |b, r| {
            b.for_range(0, 3, 1, |b, c| {
                let pr = b.add(base, r);
                let pv = b.load(p, pr);
                let pmv = b.load(pm, r);
                let pc = b.sub(pv, pmv);
                let qc_idx = b.add(base, c);
                let qv = b.load(q, qc_idx);
                let qmv = b.load(qm, c);
                let qc = b.sub(qv, qmv);
                let prod = b.mul(pc, qc);
                let three2 = b.const_i(3);
                let rr = b.mul(r, three2);
                let cidx = b.add(rr, c);
                let old = b.load(cov, cidx);
                let acc = b.add(old, prod);
                b.store(cov, cidx, acc);
            });
        });
    });
    b.finish(&[])
}

/// vfsmax — `[n]` -> max + argmax. Refines from `mx[0]` (seed it to
/// `x[0]` when comparing against the runtime entry).
pub fn ir_vfsmax(n: i64) -> Func {
    let mut b = FuncBuilder::new("vfsmax_ir");
    let x = b.global("x", DType::F32, n as usize, CacheHint::Warm);
    let mx = b.global("mx", DType::F32, 1, CacheHint::Warm);
    let am = b.global("am", DType::I32, 1, CacheHint::Warm);
    b.for_range(0, n, 1, |b, i| {
        let v = b.load(x, i);
        let zero = b.const_i(0);
        let cur = b.load(mx, zero);
        let better = b.cmp(CmpPred::Gt, v, cur);
        let newmax = b.select(better, v, cur);
        b.store(mx, zero, newmax);
        let curi = b.load(am, zero);
        let newi = b.select(better, i, curi);
        b.store(am, zero, newi);
    });
    b.finish(&[])
}

/// vmadot — `[rows,cols] · [cols]` -> `[rows]`.
pub fn ir_vmadot(rows: i64, cols: i64) -> Func {
    let mut b = FuncBuilder::new("vmadot_ir");
    let m = b.global("m", DType::F32, (rows * cols) as usize, CacheHint::Warm);
    let v = b.global("v", DType::F32, cols as usize, CacheHint::Warm);
    let y = b.global("y", DType::F32, rows as usize, CacheHint::Warm);
    b.for_range(0, rows, 1, |b, r| {
        b.for_range(0, cols, 1, |b, c| {
            let cc = b.const_i(cols);
            let rb = b.mul(r, cc);
            let midx = b.add(rb, c);
            let mv = b.load(m, midx);
            let vv = b.load(v, c);
            let prod = b.mul(mv, vv);
            let old = b.load(y, r);
            let acc = b.add(old, prod);
            b.store(y, r, acc);
        });
    });
    b.finish(&[])
}

/// phong — `[n,3]`³ unit vectors -> `[n]` intensities.
pub fn ir_phong(n: i64) -> Func {
    let mut b = FuncBuilder::new("phong_ir");
    let nrm = b.global("nrm", DType::F32, (n * 3) as usize, CacheHint::Warm);
    let lgt = b.global("lgt", DType::F32, (n * 3) as usize, CacheHint::Warm);
    let view = b.global("view", DType::F32, (n * 3) as usize, CacheHint::Warm);
    let out = b.global("inten", DType::F32, n as usize, CacheHint::Warm);
    b.for_range(0, n, 1, |b, i| {
        let three = b.const_i(3);
        let base = b.mul(i, three);
        let mut nv = [None; 3];
        let mut lv = [None; 3];
        let mut vv = [None; 3];
        for d in 0..3usize {
            let off = b.const_i(d as i64);
            let idx = b.add(base, off);
            nv[d] = Some(b.load(nrm, idx));
            lv[d] = Some(b.load(lgt, idx));
            vv[d] = Some(b.load(view, idx));
        }
        let mut ndotl = b.const_f(0.0);
        for d in 0..3 {
            let p = b.mul(nv[d].unwrap(), lv[d].unwrap());
            ndotl = b.add(ndotl, p);
        }
        let zero_f = b.const_f(0.0);
        let ndotl = b.max(ndotl, zero_f);
        let two = b.const_f(2.0);
        let scale = b.mul(two, ndotl);
        let mut rdotv = b.const_f(0.0);
        for d in 0..3 {
            let rn = b.mul(scale, nv[d].unwrap());
            let refl = b.sub(rn, lv[d].unwrap());
            let p = b.mul(refl, vv[d].unwrap());
            rdotv = b.add(rdotv, p);
        }
        let zero_f2 = b.const_f(0.0);
        let rdotv = b.max(rdotv, zero_f2);
        let spec_pow = b.powi(rdotv, SHININESS);
        let gate = b.cmp(CmpPred::Gt, ndotl, zero_f2);
        let zero_f3 = b.const_f(0.0);
        let spec = b.select(gate, spec_pow, zero_f3);
        let ka = b.const_f(KA);
        let kd = b.const_f(KD);
        let ks = b.const_f(KS);
        let diff = b.mul(kd, ndotl);
        let sp = b.mul(ks, spec);
        let partial = b.add(ka, diff);
        let inten = b.add(partial, sp);
        b.store(out, i, inten);
    });
    b.finish(&[])
}

/// vrgb2yuv — `[n,3]` -> `[n,3]` colorspace matrix.
pub fn ir_vrgb2yuv(n: i64) -> Func {
    let mut b = FuncBuilder::new("vrgb2yuv_ir");
    let rgb = b.global("rgb", DType::F32, (n * 3) as usize, CacheHint::Warm);
    let yuv = b.global("yuv", DType::F32, (n * 3) as usize, CacheHint::Warm);
    b.for_range(0, n, 1, |b, i| {
        let three = b.const_i(3);
        let base = b.mul(i, three);
        for (row, coeffs) in RGB2YUV.iter().enumerate() {
            let mut acc = b.const_f(0.0);
            for (c, &coeff) in coeffs.iter().enumerate() {
                let off = b.const_i(c as i64);
                let idx = b.add(base, off);
                let v = b.load(rgb, idx);
                let k = b.const_f(coeff);
                let p = b.mul(v, k);
                acc = b.add(acc, p);
            }
            let roff = b.const_i(row as i64);
            let oidx = b.add(base, roff);
            b.store(yuv, oidx, acc);
        }
    });
    b.finish(&[])
}

/// vmvar — `[rows,w]` -> (`[rows]` mean, `[rows]` var).
pub fn ir_vmvar(rows: i64, w: i64) -> Func {
    let mut b = FuncBuilder::new("vmvar_ir");
    let x = b.global("x", DType::F32, (rows * w) as usize, CacheHint::Warm);
    let mean = b.global("mean", DType::F32, rows as usize, CacheHint::Warm);
    let var = b.global("var", DType::F32, rows as usize, CacheHint::Warm);
    b.for_range(0, rows, 1, |b, r| {
        let wc = b.const_i(w);
        let base = b.mul(r, wc);
        b.for_range(0, w, 1, |b, i| {
            let idx = b.add(base, i);
            let v = b.load(x, idx);
            let s = b.load(mean, r);
            let s2 = b.add(s, v);
            b.store(mean, r, s2);
            let sq = b.mul(v, v);
            let m2 = b.load(var, r);
            let m22 = b.add(m2, sq);
            b.store(var, r, m22);
        });
        let wf = b.const_f(w as f64);
        let s = b.load(mean, r);
        let m = b.div(s, wf);
        b.store(mean, r, m);
        let m2 = b.load(var, r);
        let ex2 = b.div(m2, wf);
        let msq = b.mul(m, m);
        let v = b.sub(ex2, msq);
        b.store(var, r, v);
    });
    b.finish(&[])
}

/// Every AOT kernel entry as an IR function at its manifest shape
/// (serving entries excluded: the transformer runs in `runtime::sim`).
pub fn aot_cases() -> Vec<(&'static str, Func)> {
    vec![
        ("gf2mm", ir_gf2mm(64)),
        ("vdecomp", ir_vdecomp(16)),
        ("vdist3", ir_vdist3(256)),
        ("mcov", ir_mcov_centered(256)),
        ("vfsmax", ir_vfsmax(256)),
        ("vmadot", ir_vmadot(64, 64)),
        ("phong", ir_phong(256)),
        ("vrgb2yuv", ir_vrgb2yuv(256)),
        ("vmvar", ir_vmvar(64, 16)),
        ("attention", crate::workloads::llm::ir_causal_attention(4, 64, 16)),
    ]
}

// ---------------------------------------------------------------------------
// Differential checking
// ---------------------------------------------------------------------------

/// Fill every buffer and the irf deterministically from `seed`.
pub fn seed_memory(func: &Func, mem: &mut Memory, seed: u64) {
    let mut rng = Rng::new(seed ^ 0x5EED_F00D);
    for (i, decl) in func.buffers.iter().enumerate() {
        let id = BufferId(i as u32);
        match decl.elem {
            DType::F32 => {
                let data: Vec<f32> =
                    (0..decl.len).map(|_| (rng.f32() - 0.5) * 4.0).collect();
                mem.write_f32(id, &data);
            }
            DType::I32 => {
                let data: Vec<i32> =
                    (0..decl.len).map(|_| rng.below(256) as i32 - 128).collect();
                mem.write_i32(id, &data);
            }
        }
    }
    for r in mem.irf.iter_mut() {
        *r = rng.below(64) as i64 - 32;
    }
}

fn vals_equal(a: &Val, b: &Val) -> bool {
    match (a, b) {
        (Val::I(x), Val::I(y)) => x == y,
        (Val::F(x), Val::F(y)) => x.to_bits() == y.to_bits(),
        _ => false,
    }
}

/// Bit-exact comparison of two memory images: every buffer through the
/// typed arena views (float equality by `to_bits`, so NaNs compare), plus
/// the integer register file. Shared by [`check_equivalent`] and the
/// golden-diff triple check.
pub fn memories_equal(
    func: &Func,
    m1: &Memory,
    m2: &Memory,
) -> std::result::Result<(), String> {
    for (i, decl) in func.buffers.iter().enumerate() {
        let id = BufferId(i as u32);
        let same = match (m1.f64s(id), m2.f64s(id)) {
            (Some(a), Some(b)) => {
                a.len() == b.len()
                    && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
            }
            (None, None) => m1.i64s(id) == m2.i64s(id),
            _ => false,
        };
        if !same {
            return Err(format!("{}: buffer `{}` image diverges", func.name, decl.name));
        }
    }
    if m1.irf != m2.irf {
        return Err(format!("{}: irf diverges", func.name));
    }
    Ok(())
}

/// Run `func` through the tree-walker and the bytecode VM on identically
/// seeded memories; `Err(diagnosis)` on any divergence in return values,
/// memory image (bit-exact), irf, [`ExecStats`], or error verdict.
pub fn check_equivalent(func: &Func, seed: u64) -> std::result::Result<(), String> {
    let args = default_args(func);
    let mut m1 = Memory::for_func(func);
    seed_memory(func, &mut m1, seed);
    let mut m2 = m1.clone();
    let mut s1 = ExecStats::default();
    let mut s2 = ExecStats::default();
    let r1 = interp::run_with_stats(func, &args, &mut m1, &mut s1);
    let compiled =
        vm::compile(func).map_err(|e| format!("{}: vm compile failed: {e}", func.name))?;
    let r2 = compiled.run_with_stats(&args, &mut m2, &mut s2);
    match (&r1, &r2) {
        (Ok(a), Ok(b)) => {
            if a.len() != b.len() || !a.iter().zip(b.iter()).all(|(x, y)| vals_equal(x, y)) {
                return Err(format!("{}: outputs diverge: {a:?} vs {b:?}", func.name));
            }
        }
        (Err(e1), Err(e2)) => {
            if e1.to_string() != e2.to_string() {
                return Err(format!("{}: errors diverge: `{e1}` vs `{e2}`", func.name));
            }
        }
        _ => {
            return Err(format!(
                "{}: verdicts diverge: walker {r1:?} vs vm {r2:?}",
                func.name
            ))
        }
    }
    if s1 != s2 {
        return Err(format!("{}: stats diverge: {s1:?} vs {s2:?}", func.name));
    }
    memories_equal(func, &m1, &m2)
}

/// Deterministic argument vector shared by every differential check:
/// float params get `0.25 + i`, int params get `2 + i`.
pub fn default_args(func: &Func) -> Vec<Val> {
    func.params
        .iter()
        .enumerate()
        .map(|(i, &p)| match func.value_type(p) {
            Type::Float => Val::F(0.25 + i as f64),
            _ => Val::I(2 + i as i64),
        })
        .collect()
}

/// Prove an optimized function observationally equivalent to its
/// unoptimized original: `opt` must agree with itself across both
/// engines (including [`ExecStats`], via [`check_equivalent`]), and the
/// tree-walker must produce identical return values, memory image, irf,
/// and error verdict for `unopt` and `opt`. Stats between `unopt` and
/// `opt` are deliberately *not* compared — changing them is the mid-end's
/// entire job.
pub fn check_opt_equivalent(
    unopt: &Func,
    opt: &Func,
    seed: u64,
) -> std::result::Result<(), String> {
    check_equivalent(opt, seed)?;
    let args = default_args(unopt);
    let mut m1 = Memory::for_func(unopt);
    seed_memory(unopt, &mut m1, seed);
    let mut m2 = m1.clone();
    let r1 = interp::run(unopt, &args, &mut m1);
    let r2 = interp::run(opt, &args, &mut m2);
    match (&r1, &r2) {
        (Ok(a), Ok(b)) => {
            if a.len() != b.len() || !a.iter().zip(b.iter()).all(|(x, y)| vals_equal(x, y)) {
                return Err(format!(
                    "{}: unopt vs opt outputs diverge: {a:?} vs {b:?}",
                    unopt.name
                ));
            }
        }
        (Err(e1), Err(e2)) => {
            if e1.to_string() != e2.to_string() {
                return Err(format!(
                    "{}: unopt vs opt errors diverge: `{e1}` vs `{e2}`",
                    unopt.name
                ));
            }
        }
        _ => {
            return Err(format!(
                "{}: unopt vs opt verdicts diverge: {r1:?} vs {r2:?}",
                unopt.name
            ))
        }
    }
    memories_equal(unopt, &m1, &m2)
}

/// Fuel determinism: both engines must bill execution identically.
///
/// Checks, for one function and seed:
/// - unlimited fuel is bitwise identical to the unfueled run on both
///   engines (verdict, memory image, stats) and both record the same
///   total spend;
/// - for every budget in `{0, 1, spent/2, spent-1, spent}` the walker
///   and the VM agree exactly — same verdict (including the error
///   string of a fuel abort), same partial [`ExecStats`], same final
///   [`Fuel`] state, same memory image;
/// - a budget of exactly `spent` succeeds bitwise-identical to the
///   unfueled baseline, and any smaller budget aborts (when the
///   baseline itself succeeds).
pub fn check_fuel_equivalent(func: &Func, seed: u64) -> std::result::Result<(), String> {
    let name = &func.name;
    let args = default_args(func);
    let mut base = Memory::for_func(func);
    seed_memory(func, &mut base, seed);

    let same_verdict = |what: &str,
                        a: &crate::error::Result<Vec<Val>>,
                        b: &crate::error::Result<Vec<Val>>|
     -> std::result::Result<(), String> {
        match (a, b) {
            (Ok(x), Ok(y))
                if x.len() == y.len()
                    && x.iter().zip(y.iter()).all(|(p, q)| vals_equal(p, q)) =>
            {
                Ok(())
            }
            (Err(e1), Err(e2)) if e1.to_string() == e2.to_string() => Ok(()),
            _ => Err(format!("{name}: {what}: verdicts diverge: {a:?} vs {b:?}")),
        }
    };

    // Unfueled walker baseline.
    let mut m_ref = base.clone();
    let mut s_ref = ExecStats::default();
    let r_ref = interp::run_with_stats(func, &args, &mut m_ref, &mut s_ref);

    // Unlimited fuel on both engines: bitwise identical to the baseline.
    let mut spent_per_engine = Vec::new();
    for (engine, is_vm) in [("walker", false), ("vm", true)] {
        let mut m = base.clone();
        let mut s = ExecStats::default();
        let mut fuel = Fuel::unlimited();
        let r = if is_vm {
            vm::run_fueled(func, &args, &mut m, &mut s, &mut fuel)
        } else {
            interp::run_fueled(func, &args, &mut m, &mut s, &mut fuel)
        };
        same_verdict(&format!("{engine} unlimited-fuel"), &r_ref, &r)?;
        if s != s_ref {
            return Err(format!(
                "{name}: {engine} unlimited-fuel stats diverge: {s:?} vs {s_ref:?}"
            ));
        }
        memories_equal(func, &m_ref, &m)
            .map_err(|e| format!("{e} ({engine} unlimited fuel)"))?;
        spent_per_engine.push(fuel.spent());
    }
    let spent = spent_per_engine[0];
    if spent_per_engine[1] != spent {
        return Err(format!(
            "{name}: engines bill different fuel: walker {spent} vs vm {}",
            spent_per_engine[1]
        ));
    }

    // Budget sweep: both engines must stop at the identical op with
    // identical partial state, and exactly-enough fuel must succeed.
    for budget in [0, 1, spent / 2, spent.saturating_sub(1), spent] {
        let mut mw = base.clone();
        let mut sw = ExecStats::default();
        let mut fw = Fuel::new(budget);
        let rw = interp::run_fueled(func, &args, &mut mw, &mut sw, &mut fw);

        let mut mv = base.clone();
        let mut sv = ExecStats::default();
        let mut fv = Fuel::new(budget);
        let rv = vm::run_fueled(func, &args, &mut mv, &mut sv, &mut fv);

        same_verdict(&format!("budget {budget}"), &rw, &rv)?;
        if sw != sv {
            return Err(format!(
                "{name}: budget {budget}: partial stats diverge: {sw:?} vs {sv:?}"
            ));
        }
        if fw != fv {
            return Err(format!(
                "{name}: budget {budget}: fuel state diverges: {fw:?} vs {fv:?}"
            ));
        }
        memories_equal(func, &mw, &mv)
            .map_err(|e| format!("{e} (budget {budget})"))?;
        if r_ref.is_ok() {
            if budget >= spent {
                same_verdict(&format!("exact budget {budget}"), &r_ref, &rw)?;
                if sw != s_ref {
                    return Err(format!(
                        "{name}: exact budget {budget}: stats diverge from baseline"
                    ));
                }
                memories_equal(func, &m_ref, &mw)
                    .map_err(|e| format!("{e} (exact budget {budget})"))?;
            } else {
                let msg = match &rw {
                    Err(e) => e.to_string(),
                    Ok(v) => {
                        return Err(format!(
                            "{name}: budget {budget} < spent {spent} but run succeeded: {v:?}"
                        ))
                    }
                };
                if !msg.contains("fuel exhausted") {
                    return Err(format!(
                        "{name}: budget {budget}: expected a fuel abort, got `{msg}`"
                    ));
                }
            }
        }
    }
    Ok(())
}

/// Quick adversarial no-panic smoke for the bench gate: hostile strings
/// through every parser and seeded random programs through verify →
/// optimize → both engines, all under `catch_unwind`. Returns `false`
/// if anything panicked (the full harness is `tests/no_panic.rs`).
pub fn no_panic_smoke(cases: u64) -> bool {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    let garbage = |rng: &mut Rng| -> String {
        let atoms = [
            "(", ")", "?", "?x", "f", "add", "const:0", "{", "}", "[", "]", ":",
            ",", "\"", "\\", "=", "iters", "1e309", "-", "nul", "\u{0}", " ",
        ];
        (0..rng.range(0, 12)).map(|_| *rng.choose(&atoms)).collect()
    };
    for seed in 0..cases {
        let ok = catch_unwind(AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed ^ 0x0BAD_CAFE);
            let s = garbage(&mut rng);
            let _ = crate::egraph::Pattern::try_parse(&s);
            let _ = crate::util::json::Json::parse(&s);
            let _ = crate::compiler::CompileBudget::parse(&s);
            let f = random_program(seed);
            let _ = crate::ir::verifier::verify(&f);
            if let Ok((opt, _)) = passes::optimize(&f, OptLevel::O2) {
                let args = default_args(&opt);
                let mut m = Memory::for_func(&opt);
                seed_memory(&opt, &mut m, seed);
                let _ = interp::run(&opt, &args, &mut m);
                if let Ok(c) = vm::compile(&opt) {
                    let _ = c.run(&args, &mut m);
                }
            }
        }))
        .is_ok();
        if !ok {
            eprintln!("no-panic smoke: seed {seed} panicked");
            return false;
        }
    }
    true
}

/// Dynamic op count of one seeded execution: arithmetic + loads + stores
/// + branches + transfers (the work the mid-end can actually remove;
/// consts, casts and yields are free in both engines).
pub fn dynamic_ops(func: &Func, seed: u64) -> std::result::Result<u64, String> {
    let args = default_args(func);
    let mut m = Memory::for_func(func);
    seed_memory(func, &mut m, seed);
    let mut st = ExecStats::default();
    interp::run_with_stats(func, &args, &mut m, &mut st)
        .map_err(|e| format!("{}: {e}", func.name))?;
    Ok(st.arith_ops + st.loads + st.stores + st.branches + st.transfers)
}

// ---------------------------------------------------------------------------
// Seeded random-program generator
// ---------------------------------------------------------------------------

/// Generate a deterministic random Aquas-IR function: nested `for`s with
/// loop-carried values, `if`/`else`, in-bounds loads/stores, bulk
/// transfers/copies (including overlapping same-buffer moves), irf
/// traffic, and mixed int/float dataflow (`exp` included, clamped).
/// Indices are wrapped in-bounds and divisors are non-zero constants, so
/// generated programs execute cleanly; NaN-producing float chains are
/// possible and must fail identically in both engines.
pub fn random_program(seed: u64) -> Func {
    let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0xF0_22);
    let mut b = FuncBuilder::new(format!("fuzz_{seed}"));
    let mut ints: Vec<Value> = (0..rng.range(0, 3)).map(|_| b.param(Type::Int)).collect();
    let mut bufs: Vec<(BufferId, DType, i64)> = Vec::new();
    for bi in 0..rng.range(2, 5) {
        let len = *rng.choose(&[4i64, 8, 12, 16, 32]);
        let dt = if rng.bool(0.5) { DType::F32 } else { DType::I32 };
        bufs.push((b.global(&format!("b{bi}"), dt, len as usize, CacheHint::Warm), dt, len));
    }
    let mut floats: Vec<Value> = Vec::new();
    ints.push(b.const_i(1));
    ints.push(b.const_i(3));
    ints.push(b.const_i(-2));
    floats.push(b.const_f(0.5));
    floats.push(b.const_f(-1.25));
    gen_block(&mut b, &mut rng, &bufs, &mut ints, &mut floats, 0, 60);
    let mut rets: Vec<Value> = Vec::new();
    for _ in 0..rng.range(0, 4) {
        rets.push(if rng.bool(0.5) { *rng.choose(&ints) } else { *rng.choose(&floats) });
    }
    b.finish(&rets)
}

/// An always-in-bounds index: `((x % len) + len) % len` over a pool int.
fn inbounds_index(b: &mut FuncBuilder, rng: &mut Rng, ints: &[Value], len: i64) -> Value {
    let x = *rng.choose(ints);
    let lc = b.const_i(len);
    let r1 = b.rem(x, lc);
    let r2 = b.add(r1, lc);
    b.rem(r2, lc)
}

#[allow(clippy::too_many_arguments)]
fn gen_block(
    b: &mut FuncBuilder,
    rng: &mut Rng,
    bufs: &[(BufferId, DType, i64)],
    ints: &mut Vec<Value>,
    floats: &mut Vec<Value>,
    depth: usize,
    budget: usize,
) {
    let n_stmts = rng.range(2, 8).min(budget.max(1));
    for _ in 0..n_stmts {
        match rng.below(14) {
            0 | 1 => {
                // Int arithmetic / bitwise.
                let x = *rng.choose(ints);
                let y = *rng.choose(ints);
                let v = match rng.below(8) {
                    0 => b.add(x, y),
                    1 => b.sub(x, y),
                    2 => b.mul(x, y),
                    3 => b.and(x, y),
                    4 => b.or(x, y),
                    5 => b.xor(x, y),
                    6 => b.min(x, y),
                    _ => b.max(x, y),
                };
                ints.push(v);
            }
            2 => {
                // Shifts with masked amounts; div/rem by non-zero consts.
                let x = *rng.choose(ints);
                let v = match rng.below(4) {
                    0 => {
                        let seven = b.const_i(7);
                        let amt = b.and(x, seven);
                        let y = *rng.choose(ints);
                        b.shl(y, amt)
                    }
                    1 => {
                        let seven = b.const_i(7);
                        let amt = b.and(x, seven);
                        let y = *rng.choose(ints);
                        b.shr(y, amt)
                    }
                    2 => {
                        let c = b.const_i(*rng.choose(&[2i64, 3, 5, 8]));
                        b.div(x, c)
                    }
                    _ => {
                        let c = b.const_i(*rng.choose(&[2i64, 3, 5, 8]));
                        b.rem(x, c)
                    }
                };
                ints.push(v);
            }
            3 | 4 => {
                // Float arithmetic.
                let x = *rng.choose(floats);
                let y = *rng.choose(floats);
                let v = match rng.below(5) {
                    0 => b.add(x, y),
                    1 => b.sub(x, y),
                    2 => b.mul(x, y),
                    3 => b.min(x, y),
                    _ => b.max(x, y),
                };
                floats.push(v);
            }
            5 => {
                // Unary float: clamped exp, sqrt of a square, neg, powi.
                let x = *rng.choose(floats);
                let v = match rng.below(4) {
                    0 => {
                        let hi = b.const_f(4.0);
                        let lo = b.const_f(-30.0);
                        let x1 = b.min(x, hi);
                        let x2 = b.max(x1, lo);
                        b.exp(x2)
                    }
                    1 => {
                        let sq = b.mul(x, x);
                        b.sqrt(sq)
                    }
                    2 => b.neg(x),
                    _ => b.powi(x, rng.below(4) as u32),
                };
                floats.push(v);
            }
            6 => {
                // Conversions.
                if rng.bool(0.5) {
                    let x = *rng.choose(ints);
                    let v = b.to_float(x);
                    floats.push(v);
                } else {
                    let x = *rng.choose(floats);
                    let v = b.to_int(x);
                    ints.push(v);
                }
            }
            7 => {
                // Compare + select (same-typed arms).
                let preds =
                    [CmpPred::Eq, CmpPred::Ne, CmpPred::Lt, CmpPred::Le, CmpPred::Gt, CmpPred::Ge];
                let pred = *rng.choose(&preds);
                let c = if rng.bool(0.7) {
                    let x = *rng.choose(ints);
                    let y = *rng.choose(ints);
                    b.cmp(pred, x, y)
                } else {
                    let x = *rng.choose(floats);
                    let y = *rng.choose(floats);
                    b.cmp(pred, x, y)
                };
                ints.push(c);
                if rng.bool(0.5) {
                    let x = *rng.choose(ints);
                    let y = *rng.choose(ints);
                    let v = b.select(c, x, y);
                    ints.push(v);
                } else {
                    let x = *rng.choose(floats);
                    let y = *rng.choose(floats);
                    let v = b.select(c, x, y);
                    floats.push(v);
                }
            }
            8 | 9 => {
                // Load (typed by the buffer) / store (occasionally
                // cross-typed to exercise the arena's store coercion).
                let (buf, dt, len) = *rng.choose(bufs);
                let idx = inbounds_index(b, rng, ints, len);
                if rng.bool(0.55) {
                    let v = b.load(buf, idx);
                    match dt {
                        DType::F32 => floats.push(v),
                        DType::I32 => ints.push(v),
                    }
                } else {
                    let cross = rng.bool(0.2);
                    let v = match (dt, cross) {
                        (DType::F32, false) | (DType::I32, true) => *rng.choose(floats),
                        _ => *rng.choose(ints),
                    };
                    b.store(buf, idx, v);
                }
            }
            10 => {
                // Integer register file traffic.
                let reg = rng.below(32) as u8;
                let v = *rng.choose(ints);
                b.write_irf(reg, v);
                let r = b.read_irf(reg);
                ints.push(r);
            }
            11 => {
                // Bulk transfer/copy with constant in-bounds offsets
                // (same-buffer overlap included on purpose).
                let (dst, _, dlen) = *rng.choose(bufs);
                let (src, _, slen) = *rng.choose(bufs);
                let n = rng.range(1, dlen.min(slen) as usize + 1) as i64;
                let d_off = rng.range(0, (dlen - n + 1) as usize) as i64;
                let s_off = rng.range(0, (slen - n + 1) as usize) as i64;
                let dv = b.const_i(d_off * 4);
                let sv = b.const_i(s_off * 4);
                if rng.bool(0.7) {
                    b.transfer(dst, dv, src, sv, (n * 4) as usize);
                } else {
                    b.copy(
                        InterfaceId(0),
                        dst,
                        dv,
                        src,
                        sv,
                        (n * 4) as usize,
                        TransactionKind::Load,
                    );
                }
            }
            12 => {
                // Nested for with carried values.
                if depth >= 3 || budget < 8 {
                    ints.push(b.const_i(7));
                    continue;
                }
                let trip = rng.range(1, 6) as i64;
                let lb = b.const_i(0);
                let ub = b.const_i(trip);
                let step = b.const_i(if rng.bool(0.3) { 2 } else { 1 });
                let mut init = Vec::new();
                let mut carried_is_float = Vec::new();
                for _ in 0..rng.range(0, 3) {
                    if rng.bool(0.5) {
                        init.push(*rng.choose(ints));
                        carried_is_float.push(false);
                    } else {
                        init.push(*rng.choose(floats));
                        carried_is_float.push(true);
                    }
                }
                let mut crng = Rng::new(rng.next_u64());
                let mut ints_c = ints.clone();
                let mut floats_c = floats.clone();
                let cif = carried_is_float.clone();
                let inner_budget = budget / 2;
                let results = b.for_loop(lb, ub, step, &init, move |b, iv, carried| {
                    ints_c.push(iv);
                    for (k, &cv) in carried.iter().enumerate() {
                        if cif[k] {
                            floats_c.push(cv);
                        } else {
                            ints_c.push(cv);
                        }
                    }
                    gen_block(b, &mut crng, bufs, &mut ints_c, &mut floats_c, depth + 1, inner_budget);
                    cif.iter()
                        .map(|&isf| {
                            if isf {
                                *crng.choose(&floats_c)
                            } else {
                                *crng.choose(&ints_c)
                            }
                        })
                        .collect()
                });
                for (k, &r) in results.iter().enumerate() {
                    if carried_is_float[k] {
                        floats.push(r);
                    } else {
                        ints.push(r);
                    }
                }
            }
            _ => {
                // If/else with matching-typed yields.
                if depth >= 3 || budget < 8 {
                    floats.push(b.const_f(0.75));
                    continue;
                }
                let x = *rng.choose(ints);
                let y = *rng.choose(ints);
                let cond = b.cmp(*rng.choose(&[CmpPred::Lt, CmpPred::Ge, CmpPred::Ne]), x, y);
                let res_is_float: Vec<bool> = (0..rng.range(0, 3)).map(|_| rng.bool(0.5)).collect();
                let mut r1 = Rng::new(rng.next_u64());
                let mut r2 = Rng::new(rng.next_u64());
                let mut i1 = ints.clone();
                let mut f1 = floats.clone();
                let mut i2 = ints.clone();
                let mut f2 = floats.clone();
                let rif1 = res_is_float.clone();
                let rif2 = res_is_float.clone();
                let inner_budget = budget / 3;
                let results = b.if_else(
                    cond,
                    move |b| {
                        gen_block(b, &mut r1, bufs, &mut i1, &mut f1, depth + 1, inner_budget);
                        rif1.iter()
                            .map(|&isf| if isf { *r1.choose(&f1) } else { *r1.choose(&i1) })
                            .collect()
                    },
                    move |b| {
                        gen_block(b, &mut r2, bufs, &mut i2, &mut f2, depth + 1, inner_budget);
                        rif2.iter()
                            .map(|&isf| if isf { *r2.choose(&f2) } else { *r2.choose(&i2) })
                            .collect()
                    },
                );
                for (k, &r) in results.iter().enumerate() {
                    if res_is_float[k] {
                        floats.push(r);
                    } else {
                        ints.push(r);
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The bench report
// ---------------------------------------------------------------------------

fn name_seed(name: &str) -> u64 {
    name.bytes().fold(0xD1F0u64, |h, c| h.wrapping_mul(31).wrapping_add(c as u64))
}

/// Mean wall ms per execution against a clone of `template` (cloning
/// stays outside the timed region so both engines pay identical setup).
fn time_ms<F: FnMut(&mut Memory)>(template: &Memory, quick: bool, mut run: F) -> f64 {
    let (min_reps, max_reps, target_s) = if quick { (1, 3, 0.005) } else { (3, 40, 0.06) };
    let mut total = 0.0;
    let mut reps = 0usize;
    loop {
        let mut m = template.clone();
        let t0 = Instant::now();
        run(&mut m);
        total += t0.elapsed().as_secs_f64();
        reps += 1;
        if reps >= max_reps || (reps >= min_reps && total >= target_s) {
            break;
        }
    }
    total / reps as f64 * 1e3
}

/// The interpreter engine report: per AOT kernel, tree-walker vs
/// compiled-bytecode wall time, the one-off compile cost, the speedup,
/// and the differential verdict. `quick` is the CI smoke mode.
pub fn report(quick: bool) -> Report {
    let mut r = Report::new(
        "IR interpreter — register-bytecode VM vs tree-walking oracle \
         (every AOT kernel at manifest shapes)",
        vec!["kernel", "walker ms", "vm ms", "compile ms", "speedup", "insns", "agree"],
    );
    let mut speedups = Vec::new();
    let mut all_agree = true;
    let mut opt_all_agree = true;
    let mut fuel_all_agree = true;
    for (name, func) in aot_cases() {
        let agree = match check_equivalent(&func, name_seed(name)) {
            Ok(()) => true,
            Err(e) => {
                eprintln!("DIVERGENCE: {e}");
                false
            }
        };
        all_agree &= agree;

        // Fuel gate: metering must not perturb semantics (unlimited fuel
        // bitwise-identical) and must exhaust identically on both engines.
        let fuel_agree = match check_fuel_equivalent(&func, name_seed(name) ^ 0xF0E1) {
            Ok(()) => true,
            Err(e) => {
                eprintln!("FUEL DIVERGENCE: {e}");
                false
            }
        };
        fuel_all_agree &= fuel_agree;
        r.metric(&format!("{name}_fuel_agree"), if fuel_agree { 1.0 } else { 0.0 });

        let t0 = Instant::now();
        let compiled = vm::compile(&func).expect("AOT kernel compiles to bytecode");
        let compile_ms = t0.elapsed().as_secs_f64() * 1e3;

        let mut template = Memory::for_func(&func);
        seed_memory(&func, &mut template, name_seed(name) ^ 0xBEEF);
        let walker_ms = time_ms(&template, quick, |m| {
            interp::run(&func, &[], m).expect("tree-walker run");
        });
        let vm_ms = time_ms(&template, quick, |m| {
            compiled.run(&[], m).expect("vm run");
        });
        let speedup = walker_ms / vm_ms.max(1e-9);
        speedups.push(speedup);

        r.row(vec![
            name.into(),
            format!("{walker_ms:.3}"),
            format!("{vm_ms:.3}"),
            format!("{compile_ms:.3}"),
            format!("{speedup:.1}x"),
            compiled.num_insns().to_string(),
            if agree { "yes".into() } else { "NO".into() },
        ]);
        r.metric(&format!("{name}_legacy_ms"), walker_ms);
        r.metric(&format!("{name}_vm_ms"), vm_ms);
        r.metric(&format!("{name}_vm_compile_ms"), compile_ms);
        r.metric(&format!("{name}_speedup_vs_legacy"), speedup);
        r.metric(&format!("{name}_agree"), if agree { 1.0 } else { 0.0 });

        // Mid-end: full pipeline equivalence + dynamic-op deltas, plus the
        // per-pass breakdown (each pass alone on a fresh clone).
        let (opt, _) = passes::optimize(&func, OptLevel::O2)
            .expect("pass pipeline on AOT kernel");
        let opt_agree = match check_opt_equivalent(&func, &opt, name_seed(name)) {
            Ok(()) => true,
            Err(e) => {
                eprintln!("OPT DIVERGENCE: {e}");
                false
            }
        };
        opt_all_agree &= opt_agree;
        let seed = name_seed(name) ^ 0xD1F0;
        let d0 = dynamic_ops(&func, seed).expect("unopt kernel runs") as f64;
        let d1 = dynamic_ops(&opt, seed).expect("opt kernel runs") as f64;
        r.metric(&format!("{name}_dynops_unopt"), d0);
        r.metric(&format!("{name}_dynops_opt"), d1);
        r.metric(&format!("{name}_dynop_reduction"), 1.0 - d1 / d0.max(1.0));
        r.metric(&format!("{name}_opt_agree"), if opt_agree { 1.0 } else { 0.0 });
        for pass in Pass::ALL {
            let mut fp = func.clone();
            passes::run_pass(&mut fp, pass).expect("single pass on AOT kernel");
            let dp = dynamic_ops(&fp, seed).expect("single-pass kernel runs") as f64;
            r.metric(&format!("{name}_dynops_{}", pass.name()), dp);
        }
    }
    r.metric("kernels", speedups.len() as f64);
    r.metric("geomean_speedup_vs_legacy", geomean(&speedups));
    r.metric("all_agree", if all_agree { 1.0 } else { 0.0 });
    r.metric("opt_all_agree", if opt_all_agree { 1.0 } else { 0.0 });
    r.metric("fuel_all_agree", if fuel_all_agree { 1.0 } else { 0.0 });
    // Hostile-input smoke: parsers and engines must error, never abort.
    let smoke = no_panic_smoke(if quick { 20 } else { 60 });
    r.metric("no_panic_agree", if smoke { 1.0 } else { 0.0 });
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_aot_case_compiles_and_agrees() {
        for (name, func) in aot_cases() {
            check_equivalent(&func, name_seed(name))
                .unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn random_programs_are_deterministic_per_seed() {
        let a = random_program(42);
        let c = random_program(42);
        assert_eq!(a.num_ops(), c.num_ops());
        assert_eq!(a.buffers.len(), c.buffers.len());
        assert_eq!(
            crate::ir::printer::print_func(&a),
            crate::ir::printer::print_func(&c),
            "generator must be deterministic"
        );
        let d = random_program(43);
        assert_ne!(
            crate::ir::printer::print_func(&a),
            crate::ir::printer::print_func(&d),
            "different seeds must differ"
        );
    }

    #[test]
    fn a_few_fuzz_seeds_agree_in_unit_tests() {
        for seed in 0..12 {
            let f = random_program(seed);
            check_equivalent(&f, seed).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        }
    }

    #[test]
    fn optimized_fuzz_programs_agree_in_unit_tests() {
        for seed in 0..12 {
            let f = random_program(seed);
            let (opt, _) = passes::optimize(&f, OptLevel::O2)
                .unwrap_or_else(|e| panic!("seed {seed}: pipeline failed: {e}"));
            check_opt_equivalent(&f, &opt, seed)
                .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        }
    }

    #[test]
    fn pipeline_cuts_dynamic_ops_on_gf2mm() {
        let f = ir_gf2mm(8);
        let (opt, _) = passes::optimize(&f, OptLevel::O2).unwrap();
        check_opt_equivalent(&f, &opt, 7).unwrap();
        let d0 = dynamic_ops(&f, 7).unwrap();
        let d1 = dynamic_ops(&opt, 7).unwrap();
        assert!(d1 < d0, "pipeline left gf2mm's dynamic ops flat: {d0} -> {d1}");
    }
}
