//! Regenerates every table and figure of the paper's evaluation (§6).
//!
//! Each `table*`/`fig*` function returns the formatted rows *and* the raw
//! numbers, so `cargo bench` targets, the `aquas bench` CLI, and
//! EXPERIMENTS.md all draw from one source of truth.

pub mod dma;
pub mod dse;
pub mod egraph;
pub mod fir7;
pub mod interp;
pub mod report;
pub mod serve;
pub mod table2;
pub mod table3;

pub use report::Report;

use crate::area::AreaModel;
use crate::cores::boom::{BoomConfig, BoomModel};
use crate::cores::saturn::{SaturnConfig, SaturnModel};
use crate::interface::latency::{sequence_latency, TransactionKind};
use crate::interface::model::MemInterface;

/// Figure 2(b): the cost of suboptimal interface selection/ordering on the
/// two-interface example.
pub fn fig2() -> Report {
    let itfc1 = MemInterface::cpu_port();
    let itfc2 = MemInterface::system_bus();
    let mut r = Report::new(
        "Figure 2(b) — suboptimal interface choices on the @itfc1/@itfc2 example",
        vec!["design choice", "cycles", "penalty"],
    );
    // A 32-byte load + an 8-byte load, as in the figure.
    let big = 32usize;
    let small = 8usize;

    // Optimal: big burst over itfc2, small word(s) over itfc1 in parallel.
    let opt = sequence_latency(&itfc2, TransactionKind::Load, &[big]).max(sequence_latency(
        &itfc1,
        TransactionKind::Load,
        &itfc1.decompose(0, small),
    ));
    // Suboptimal A: everything word-by-word over itfc1.
    let sub_a = sequence_latency(
        &itfc1,
        TransactionKind::Load,
        &itfc1.decompose(0, big + small),
    );
    // Suboptimal B: both over itfc2 but issuing the small transfer first
    // (serializes the burst behind the lead-off of the small one).
    let sub_b = sequence_latency(&itfc2, TransactionKind::Load, &[small, big]);

    r.row(vec!["optimal (burst on @itfc2, word on @itfc1)".into(), opt.to_string(), "—".into()]);
    r.row(vec![
        "all word-by-word on @itfc1".into(),
        sub_a.to_string(),
        format!("+{}", sub_a - opt),
    ]);
    r.row(vec![
        "small-first ordering on @itfc2".into(),
        sub_b.to_string(),
        format!("+{}", sub_b.saturating_sub(opt)),
    ]);
    r.metric("penalty_word_by_word", (sub_a - opt) as f64);
    r.metric("penalty_bad_order", sub_b.saturating_sub(opt) as f64);
    r
}

/// Figure 6: BOOMv3 vs Aquas on the PCP workloads (performance + area).
pub fn fig6() -> Report {
    let mut r = Report::new(
        "Figure 6 — BOOMv3 vs Aquas on point-cloud workloads",
        vec![
            "case", "boom cyc", "aquas cyc", "boom t(µs)", "aquas t(µs)", "aquas/boom speed",
            "area ratio",
        ],
    );
    let area = AreaModel::default();
    let boom_rep = area.boom();
    let t2 = table2::run();
    let boom = BoomModel::new(BoomConfig::default());

    for row in &t2.pcp_rows {
        let k = &row.kernel;
        // BOOM runs the plain software.
        let mut mem = crate::ir::interp::Memory::for_func(&k.software);
        (k.init)(&k.software, &mut mem);
        let br = boom.simulate(&k.software, &[], &mut mem).expect("boom sim");
        // Times at each design's achievable frequency.
        let boom_us = br.cycles as f64 / boom_rep.freq_mhz;
        let aquas_rep = row.area;
        let aquas_us = row.aquas_cycles as f64 / aquas_rep.freq_mhz;
        let ratio = boom_us / aquas_us;
        r.row(vec![
            k.name.into(),
            br.cycles.to_string(),
            row.aquas_cycles.to_string(),
            format!("{boom_us:.2}"),
            format!("{aquas_us:.2}"),
            format!("{ratio:.2}x"),
            format!("{:.2}x", boom_rep.area_mm2 / aquas_rep.area_mm2),
        ]);
        r.metric(&format!("{}_aquas_vs_boom", k.name), ratio);
    }
    r.metric("boom_area_mm2", boom_rep.area_mm2);
    r
}

/// Figure 7: Saturn (VLEN=128) vs Aquas on the graphics workloads.
pub fn fig7() -> Report {
    let mut r = Report::new(
        "Figure 7 — Saturn (RVV, VLEN=128) vs Aquas on graphics workloads",
        vec![
            "case", "base cyc", "saturn cyc", "aquas cyc", "saturn speed*", "aquas speed*",
            "saturn area", "aquas area",
        ],
    );
    let area = AreaModel::default();
    let saturn_rep = area.saturn();
    let saturn = SaturnModel::new(SaturnConfig::default());
    let rows = table2::run_kernels(crate::workloads::graphics_kernels());

    for row in &rows {
        let k = &row.kernel;
        let profile = k.vector_profile.as_ref().expect("graphics kernels have profiles");
        let sat = saturn.simulate(profile);
        // Speedups vs the base core *in time*, accounting for frequency:
        // Saturn's integration costs 35% clock, Aquas costs none.
        let base_t = row.base_cycles as f64 / crate::area::ROCKET_FREQ_MHZ;
        let sat_t = sat.cycles as f64 / saturn_rep.freq_mhz;
        let aquas_t = row.aquas_cycles as f64 / row.area.freq_mhz;
        let sat_x = base_t / sat_t;
        let aquas_x = base_t / aquas_t;
        r.row(vec![
            k.name.into(),
            row.base_cycles.to_string(),
            sat.cycles.to_string(),
            row.aquas_cycles.to_string(),
            format!("{sat_x:.2}x"),
            format!("{aquas_x:.2}x"),
            format!("+{:.0}%", saturn_rep.area_overhead_pct()),
            format!("+{:.1}%", row.area.area_overhead_pct()),
        ]);
        r.metric(&format!("{}_saturn_x", k.name), sat_x);
        r.metric(&format!("{}_aquas_x", k.name), aquas_x);
    }
    r
}

/// Figure 8: the FPGA LLM-inference study.
pub fn fig8() -> Report {
    use crate::workloads::llm;
    let mut r = Report::new(
        "Figure 8 — CPU LLM inference (Llama-2-110M-class, int8, 80 MHz SoC)",
        vec!["metric", "base", "aquas", "speedup"],
    );
    let cfg = llm::LlmConfig::default();
    let (base, aquas, ttft_x, itl_x) = llm::figure8_latency(&cfg);
    r.row(vec![
        "TTFT (ms)".into(),
        format!("{:.0}", base.ttft_ms),
        format!("{:.0}", aquas.ttft_ms),
        format!("{ttft_x:.2}x"),
    ]);
    r.row(vec![
        "ITL (ms)".into(),
        format!("{:.0}", base.itl_ms),
        format!("{:.0}", aquas.itl_ms),
        format!("{itl_x:.2}x"),
    ]);
    let (usage, (lut, ff, bram, dsp)) = llm::figure8_resources();
    r.row(vec![
        "resources".into(),
        "—".into(),
        format!(
            "LUT {:.0}% ({}) | FF {:.0}% ({}) | BRAM {:.0}% ({} KB) | DSP {:.0}% ({})",
            lut, usage.luts, ff, usage.ffs, bram, usage.bram_kb, dsp, usage.dsps
        ),
        "—".into(),
    ]);
    r.metric("ttft_speedup", ttft_x);
    r.metric("itl_speedup", itl_x);
    r.metric("lut_pct", lut);
    r.metric("ff_pct", ff);
    r.metric("bram_pct", bram);
    r
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig2_reports_meaningful_penalties() {
        let r = super::fig2();
        // Paper: "a notable 7- to 9-cycle latency penalty".
        let p = r.metrics["penalty_word_by_word"];
        assert!(p >= 7.0, "penalty {p}");
    }

    #[test]
    fn fig8_reproduces_headline_speedups() {
        let r = super::fig8();
        let ttft = r.metrics["ttft_speedup"];
        let itl = r.metrics["itl_speedup"];
        assert!(ttft > 6.0 && ttft < 14.0);
        assert!(itl > 6.0 && itl < 14.0);
        assert!(r.metrics["bram_pct"] > r.metrics["lut_pct"]);
    }
}
