//! Burst-DMA memory-subsystem benchmark (`cargo bench --bench dma`,
//! `aquas bench dma`).
//!
//! Sweeps Figure-2-style interface configurations — width × burst length
//! × in-flight depth — over three transaction traces and prices each
//! through *both* timing models:
//!
//! - **gf2mm**: the bulk staging transfers of the `mgf2mm` ISAX (via
//!   [`memprobe`]);
//! - **attention**: the §6.5 attention unit's double-buffered weight/KV
//!   tile stream ([`IsaxLlmModel::tile_bytes`]);
//! - **kvgather**: one paged KV block — `2 × n_layers` slabs of
//!   `block_slots × dim` bytes, the unit the serving coordinator stages
//!   per sequence per tick.
//!
//! Per `(trace, config, direction)` the report records the event-driven
//! simulator's cycles ([`crate::interface::dmasim`]), the exact
//! closed-form recurrence, the §4.3 `T_k` estimate, and achieved
//! bytes/cycle. The `--check` gates make the §4.1/§4.3 agreement story
//! executable:
//!
//! - `uncontended_sim_matches_recurrence` — single-stream replays must
//!   equal [`sequence_latency`] *exactly*, for loads and stores alike;
//! - `tk_store_exact` / `tk_load_within_bound` — the closed-form `T_k`
//!   must reproduce the simulator exactly for stores and stay within the
//!   documented 50% bound for loads;
//! - `bank_conflicts_resolve` — a two-interface stream into a
//!   single-banked scratchpad must lose cycles to port conflicts, and
//!   the same trace into a dual-banked scratchpad must not (the
//!   contention regime where the simulator *disagrees* with every closed
//!   form — the reason it exists).

use crate::interface::dmasim::{self, SimTxn, SramSpec};
use crate::interface::latency::{sequence_latency, tk_estimate, TransactionKind};
use crate::interface::model::{InterfaceId, InterfaceSet, MemInterface};
use crate::interface::HierarchyLevel;
use crate::synthesis::memprobe;
use crate::workloads::llm::{IsaxLlmModel, LlmConfig};
use crate::workloads::pqc;

use super::Report;

/// One benchmark trace: per-op request sizes in bytes, per direction
/// (requests are decomposed per swept interface, as §4.3 would).
pub struct DmaTrace {
    /// Trace name (report rows + metric prefixes).
    pub name: &'static str,
    /// Load request sizes in bytes (one entry per memory op).
    pub loads: Vec<usize>,
    /// Store request sizes in bytes.
    pub stores: Vec<usize>,
}

/// The three checked-in traces (see module docs).
pub fn traces() -> Vec<DmaTrace> {
    // gf2mm: bulk staging ops of the real ISAX description.
    let kernels = pqc::kernels();
    let k = kernels.iter().find(|k| k.name == "mgf2mm").expect("mgf2mm kernel exists");
    let probe = memprobe::extract(&k.isax.func).expect("mgf2mm probe");
    let mut gf2mm_loads = Vec::new();
    let mut gf2mm_stores = Vec::new();
    for op in probe.ops.iter().filter(|o| o.bulk) {
        match op.kind {
            TransactionKind::Load => gf2mm_loads.push(op.bytes),
            TransactionKind::Store => gf2mm_stores.push(op.bytes),
        }
    }
    assert!(!gf2mm_loads.is_empty(), "mgf2mm stages data in bulk");

    // attention: 8 staged weight/KV tiles in, 2 result tiles out.
    let isax = IsaxLlmModel::default();
    let attention_loads = vec![isax.tile_bytes; 8];
    let attention_stores = vec![isax.tile_bytes / 4; 2];

    // kvgather: one paged KV block = 2*n_layers slabs of block_slots*dim.
    let cfg = LlmConfig::default();
    let block_slots = 8usize;
    let slab = block_slots * cfg.dim * cfg.weight_bytes;
    let kv_loads = vec![slab; 2 * cfg.n_layers];

    vec![
        DmaTrace { name: "gf2mm", loads: gf2mm_loads, stores: gf2mm_stores },
        DmaTrace { name: "attention", loads: attention_loads, stores: attention_stores },
        DmaTrace { name: "kvgather", loads: kv_loads, stores: Vec::new() },
    ]
}

/// The swept Figure-2-style interface configurations.
pub fn sweep_configs(quick: bool) -> Vec<MemInterface> {
    let widths: &[usize] = if quick { &[4, 8] } else { &[4, 8, 16] };
    let bursts: &[usize] = &[1, 8];
    let in_flights: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };
    let mut out = Vec::new();
    for &width in widths {
        for &max_beats in bursts {
            for &in_flight in in_flights {
                out.push(MemInterface {
                    name: format!("w{width}b{max_beats}i{in_flight}"),
                    width,
                    max_beats,
                    in_flight,
                    read_lead: 6,
                    write_cost: 2,
                    line: 64,
                    level: HierarchyLevel::L2,
                });
            }
        }
    }
    out
}

fn kind_str(kind: TransactionKind) -> &'static str {
    match kind {
        TransactionKind::Load => "ld",
        TransactionKind::Store => "st",
    }
}

/// Build the DMA report (the `BENCH_dma.json` source of truth).
pub fn report(quick: bool) -> Report {
    let mut r = Report::new(
        "Burst-DMA engine — event-driven simulator vs closed form (width × burst × in-flight)",
        vec!["trace", "config", "dir", "txns", "bytes", "sim cyc", "closed cyc", "T_k", "B/cyc"],
    );
    let mut sim_exact = true;
    let mut tk_store_ok = true;
    let mut tk_load_ok = true;
    let mut best_rate: std::collections::BTreeMap<&'static str, f64> = Default::default();

    for trace in traces() {
        for itfc in sweep_configs(quick) {
            for (kind, reqs) in
                [(TransactionKind::Load, &trace.loads), (TransactionKind::Store, &trace.stores)]
            {
                if reqs.is_empty() {
                    continue;
                }
                let segments: Vec<Vec<usize>> =
                    reqs.iter().map(|&bytes| itfc.decompose(0, bytes)).collect();
                let sizes: Vec<usize> = segments.iter().flatten().copied().collect();
                let sim = dmasim::simulate_sizes(&itfc, kind, &sizes);
                let closed = sequence_latency(&itfc, kind, &sizes);
                let tk = tk_estimate(&itfc, kind, &segments);
                let bytes: usize = reqs.iter().sum();
                let rate = bytes as f64 / sim.max(1) as f64;
                if sim != closed {
                    sim_exact = false;
                }
                match kind {
                    TransactionKind::Store => {
                        // Exact for integral-beat sizes; a runt tail may
                        // open at most a sub-beat gap per runt segment
                        // (all checked-in traces are runt-free today).
                        let runts =
                            sizes.iter().filter(|&&m| m % itfc.width != 0).count() as f64;
                        let gap = sim as f64 - tk;
                        if gap < -1e-6 || gap > runts + 1e-6 {
                            tk_store_ok = false;
                        }
                    }
                    TransactionKind::Load => {
                        let rel = (tk - sim as f64).abs() / (sim as f64).max(1.0);
                        if rel > 0.5 {
                            tk_load_ok = false;
                        }
                    }
                }
                if kind == TransactionKind::Load {
                    let e = best_rate.entry(trace.name).or_insert(0.0);
                    if rate > *e {
                        *e = rate;
                    }
                }
                r.row(vec![
                    trace.name.into(),
                    itfc.name.clone(),
                    kind_str(kind).into(),
                    sizes.len().to_string(),
                    bytes.to_string(),
                    sim.to_string(),
                    closed.to_string(),
                    format!("{tk:.1}"),
                    format!("{rate:.2}"),
                ]);
                r.metric(
                    &format!("{}_{}_{}_sim_cycles", trace.name, itfc.name, kind_str(kind)),
                    sim as f64,
                );
                r.metric(
                    &format!("{}_{}_{}_bytes_per_cycle", trace.name, itfc.name, kind_str(kind)),
                    rate,
                );
            }
        }
    }
    for (name, rate) in best_rate {
        r.metric(&format!("{name}_best_bytes_per_cycle"), rate);
    }
    r.metric("uncontended_sim_matches_recurrence", if sim_exact { 1.0 } else { 0.0 });
    r.metric("tk_store_exact", if tk_store_ok { 1.0 } else { 0.0 });
    r.metric("tk_load_within_bound", if tk_load_ok { 1.0 } else { 0.0 });

    // Contention scenario: the core port streams words while the bus
    // streams bursts, both draining into one scratchpad. One bank ⇒ the
    // beat windows collide; two banks (hwgen's census for double-buffered
    // tiles) ⇒ conflict-free.
    let set = InterfaceSet::rocket_default();
    let mut txns = Vec::new();
    for i in 0..32usize {
        txns.push(SimTxn {
            op: i,
            itfc: InterfaceId(0),
            kind: TransactionKind::Load,
            addr: (i * 4) as u64,
            size: 4,
            sram: Some(0),
        });
    }
    for i in 0..8usize {
        txns.push(SimTxn {
            op: 100 + i,
            itfc: InterfaceId(1),
            kind: TransactionKind::Load,
            addr: (i * 64) as u64,
            size: 64,
            sram: Some(0),
        });
    }
    let run_banked = |banks: usize| {
        let srams = [SramSpec { name: "tile".into(), banks }];
        dmasim::simulate_txns(&set, &srams, &txns).expect("contention scenario")
    };
    let contended = run_banked(1);
    let banked = run_banked(2);
    r.metric("contended_conflict_cycles", contended.conflict_cycles as f64);
    r.metric("contended_makespan", contended.makespan as f64);
    r.metric("dual_bank_conflict_cycles", banked.conflict_cycles as f64);
    r.metric("dual_bank_makespan", banked.makespan as f64);
    r.metric(
        "bank_conflicts_resolve",
        if contended.conflict_cycles > 0 && banked.conflict_cycles == 0 { 1.0 } else { 0.0 },
    );

    // Coalescing demo: the same contiguous bytes word-by-word vs merged
    // back into maximal bursts on the bus.
    let bus = MemInterface::system_bus();
    let words: Vec<SimTxn> = (0..64usize)
        .map(|i| SimTxn {
            op: 0,
            itfc: InterfaceId(0),
            kind: TransactionKind::Load,
            addr: (i * 8) as u64,
            size: 8,
            sram: None,
        })
        .collect();
    let merged = dmasim::coalesce(&bus, &words);
    let one = InterfaceSet::new(vec![bus.clone()]);
    let split_cycles =
        dmasim::simulate_txns(&one, &[], &words).expect("word stream").makespan;
    let merged_cycles =
        dmasim::simulate_txns(&one, &[], &merged).expect("burst stream").makespan;
    r.metric("coalesce_split_cycles", split_cycles as f64);
    r.metric("coalesce_merged_cycles", merged_cycles as f64);
    r.metric(
        "coalescing_wins",
        if merged_cycles < split_cycles { 1.0 } else { 0.0 },
    );

    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_report_passes_its_own_gates() {
        let r = report(true);
        assert_eq!(r.metrics["uncontended_sim_matches_recurrence"], 1.0);
        assert_eq!(r.metrics["tk_store_exact"], 1.0);
        assert_eq!(r.metrics["tk_load_within_bound"], 1.0);
        assert_eq!(r.metrics["bank_conflicts_resolve"], 1.0);
        assert_eq!(r.metrics["coalescing_wins"], 1.0);
        assert!(r.metrics["contended_makespan"] >= r.metrics["dual_bank_makespan"]);
    }

    #[test]
    fn traces_are_nonempty_and_stable() {
        let ts = traces();
        assert_eq!(ts.len(), 3);
        for t in &ts {
            assert!(!t.loads.is_empty(), "{} has no load ops", t.name);
        }
        // kvgather covers every (layer, direction) slab of one block.
        let kv = ts.iter().find(|t| t.name == "kvgather").unwrap();
        assert_eq!(kv.loads.len(), 2 * LlmConfig::default().n_layers);
    }

    #[test]
    fn wider_faster_config_never_slower_on_bulk_loads() {
        // Sanity on the sweep: strictly better hardware (wider beat,
        // longer burst, deeper window) must not lose on a bulk stream.
        let weak = MemInterface {
            name: "w4b1i1".into(),
            width: 4,
            max_beats: 1,
            in_flight: 1,
            read_lead: 6,
            write_cost: 2,
            line: 64,
            level: HierarchyLevel::L2,
        };
        let strong = MemInterface {
            name: "w16b8i4".into(),
            width: 16,
            max_beats: 8,
            in_flight: 4,
            ..weak.clone()
        };
        let bytes = 4096usize;
        let weak_cycles =
            dmasim::simulate_sizes(&weak, TransactionKind::Load, &weak.decompose(0, bytes));
        let strong_cycles =
            dmasim::simulate_sizes(&strong, TransactionKind::Load, &strong.decompose(0, bytes));
        assert!(strong_cycles < weak_cycles, "{strong_cycles} !< {weak_cycles}");
    }
}
