//! Table 3: compilation statistics — which control-flow / dataflow
//! divergences each case exhibits, how many internal/external rewrites the
//! hybrid engine spent, and e-node counts before/after.

use crate::bench_harness::report::Report;
use crate::compiler::{compile, CompileOptions, CompileStats};
use crate::workloads::{pcp, pqc, Kernel};

/// One Table-3 row.
pub struct StatsRow {
    pub case: String,
    pub control_flow: String,
    pub dataflow: String,
    pub stats: CompileStats,
}

/// Dataflow-divergence labels per kernel (what the canonical software
/// spelling differs in, vs the ISAX description).
fn dataflow_label(name: &str) -> &'static str {
    match name {
        "vdecomp" => "RF (shift/mask vs div/rem)",
        "mgf2mm" => "RF, RE",
        "vdist3.vv" => "AF, RE",
        "mcov.vs" => "AF, RF, RE",
        "vfsmax" => "RF (select), RE",
        "vmadot" => "RF, RE",
        "vmvar" => "RF, RE",
        "mphong" => "RE (redundant loads)",
        "vrgb2yuv" => "AF (reassociation)",
        _ => "—",
    }
}

/// Compile each kernel's most divergent variant and collect stats.
pub fn run_kernels(kernels: &[Kernel]) -> Vec<StatsRow> {
    let mut rows = Vec::new();
    for k in kernels {
        // Use the variant (the robustness attack), not the canonical form.
        let (cf_label, func) = k
            .variants
            .first()
            .map(|(d, f)| (d.clone(), f.clone()))
            .unwrap_or(("—".into(), k.software.clone()));
        let r = compile(&func, &[k.isax.clone()], &CompileOptions::default())
            .unwrap_or_else(|e| panic!("{}: {e}", k.name));
        assert!(
            r.stats.matched.contains(&k.isax.name),
            "{} variant failed to match",
            k.name
        );
        rows.push(StatsRow {
            case: k.name.to_string(),
            control_flow: cf_label,
            dataflow: dataflow_label(k.name).to_string(),
            stats: r.stats,
        });
    }
    rows
}

/// End-to-end rows (multiple ISAXs against one program).
pub fn run_e2e() -> Vec<StatsRow> {
    let mut rows = Vec::new();
    {
        let ks = pqc::kernels();
        let isaxes: Vec<_> = ks.iter().map(|k| k.isax.clone()).collect();
        let r = compile(&pqc::end_to_end_software(), &isaxes, &CompileOptions::default())
            .expect("pqc e2e");
        rows.push(StatsRow {
            case: "PQC end-to-end".into(),
            control_flow: "RF spellings + glue".into(),
            dataflow: "RF, RE".into(),
            stats: r.stats,
        });
    }
    {
        let ks = pcp::kernels();
        let isaxes: Vec<_> = ks.iter().map(|k| k.isax.clone()).collect();
        let r = compile(&pcp::end_to_end_software(), &isaxes, &CompileOptions::default())
            .expect("pcp e2e");
        rows.push(StatsRow {
            case: "PCP end-to-end".into(),
            control_flow: "4 kernels fused".into(),
            dataflow: "AF, RF, RE".into(),
            stats: r.stats,
        });
    }
    rows
}

/// The full Table 3.
pub fn report() -> Report {
    let mut r = Report::new(
        "Table 3 — compilation statistics",
        vec![
            "case", "control-flow diff", "dataflow diff", "int/ext rewrites",
            "initial/saturated e-nodes", "matched",
        ],
    );
    let mut all = run_kernels(&pqc::kernels());
    all.extend(run_kernels(&pcp::kernels()));
    all.extend(run_kernels(&crate::workloads::graphics_kernels()));
    all.extend(run_e2e());
    for row in &all {
        r.row(vec![
            row.case.clone(),
            row.control_flow.clone(),
            row.dataflow.clone(),
            format!("{}/{}", row.stats.internal_rewrites, row.stats.external_rewrites),
            format!("{}/{}", row.stats.initial_enodes, row.stats.saturated_enodes),
            row.stats.matched.join("+"),
        ]);
        r.metric(&format!("{}_internal", row.case), row.stats.internal_rewrites as f64);
        r.metric(&format!("{}_external", row.case), row.stats.external_rewrites as f64);
        r.metric(&format!("{}_saturated", row.case), row.stats.saturated_enodes as f64);
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_variant_rows_match_and_stay_bounded() {
        let rows = run_kernels(&pqc::kernels());
        for row in &rows {
            assert!(!row.stats.matched.is_empty(), "{}", row.case);
            // The §5.3 claim: guided rewriting keeps the e-graph manageable.
            assert!(
                row.stats.saturated_enodes < 100_000,
                "{}: {} nodes",
                row.case,
                row.stats.saturated_enodes
            );
        }
    }

    #[test]
    fn variants_need_external_rewrites() {
        // Tiled/unrolled variants cannot match on internal rules alone.
        let rows = run_kernels(&pqc::kernels());
        let vd = rows.iter().find(|r| r.case == "vdecomp").unwrap();
        assert!(vd.stats.external_rewrites >= 1, "{:?}", vd.stats);
    }

    #[test]
    fn e2e_offloads_everything() {
        for row in run_e2e() {
            assert!(row.stats.matched.len() >= 2, "{}: {:?}", row.case, row.stats.matched);
        }
    }
}
