//! Tabular report container shared by all bench targets.
//!
//! A [`Report`] couples the human-facing table (`render`) with the raw
//! numeric metrics (`metrics_json`) so each bench's terminal output and
//! its `BENCH_*.json` artifact cannot drift apart: the CLI, the
//! `cargo bench` mains, and the CI gates all read the same
//! `BTreeMap<String, f64>`. Metric names and units are documented in
//! `docs/bench-schemas.md`; booleans are encoded as `1.0` / `0.0`.

use std::collections::BTreeMap;

/// A titled table plus named raw metrics.
#[derive(Debug, Clone)]
pub struct Report {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub metrics: BTreeMap<String, f64>,
}

impl Report {
    pub fn new(title: &str, headers: Vec<&str>) -> Self {
        Self {
            title: title.into(),
            headers: headers.into_iter().map(String::from).collect(),
            rows: Vec::new(),
            metrics: BTreeMap::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn metric(&mut self, name: &str, value: f64) {
        self.metrics.insert(name.into(), value);
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Metrics as JSON (for EXPERIMENTS.md tooling).
    pub fn metrics_json(&self) -> String {
        use crate::util::json::Json;
        let obj: BTreeMap<String, Json> =
            self.metrics.iter().map(|(k, &v)| (k.clone(), Json::Num(v))).collect();
        Json::Obj(obj).to_string_pretty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut r = Report::new("T", vec!["a", "long-header"]);
        r.row(vec!["1".into(), "2".into()]);
        let text = r.render();
        assert!(text.contains("== T =="));
        assert!(text.contains("long-header"));
        assert!(text.contains('1'));
    }

    #[test]
    fn metrics_json_roundtrips() {
        let mut r = Report::new("T", vec!["a"]);
        r.metric("x", 1.5);
        let j = crate::util::json::Json::parse(&r.metrics_json()).unwrap();
        assert_eq!(j.get("x").unwrap().as_f64().unwrap(), 1.5);
    }
}
