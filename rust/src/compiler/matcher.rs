//! §5.3 hybrid rewriting + §5.4 skeleton-components matching.
//!
//! The matching engine works on one shared e-graph holding the software
//! program *and* the aligned ISAX description (plus every variant produced
//! by external rewrites). Because the encoder canonicalizes symbols and
//! saturation unions equivalent dataflow, "the software loop implements
//! the ISAX" reduces to *e-class equality* of the two `for` nodes — the
//! "direct equivalence with the target ISAX" of Figure 5(3).
//!
//! Skeleton-components mechanics: the ISAX's loop nest (trip counts,
//! nesting, anchor counts) is the *skeleton*; the dataflow subtrees under
//! its anchors are the *components*. Component matches tag the software
//! e-classes with `comp:` markers; the skeleton engine checks structure,
//! ordering (tuple child order), loop-carried dependencies (carry symbol
//! equality), and effects (anchor counts), then tags the loop class with
//! an `isax:` marker used by extraction and lowering.
//!
//! External rewrites are *ISAX-guided* (§5.3): loop characteristics of the
//! target decide which of unroll/tile/coalesce to attempt, on which side,
//! with which factor — blind saturation of structural rewrites would blow
//! the e-graph up.

use crate::compiler::encode::{encode_func, EncodeMap};
use crate::compiler::loop_passes::{apply, LoopPass};
use crate::compiler::rules::internal_rules;
use crate::compiler::{CompileOptions, CompileStats};
use crate::egraph::{ClassId, EGraph, Runner};
use crate::error::Result;
use crate::ir::func::{Func, OpRef};
use crate::ir::ops::OpKind;
use crate::synthesis::memprobe::static_trips;

/// Outcome of matching one ISAX against one software function.
#[derive(Debug, Clone)]
pub struct MatchRound {
    /// The matched loop in the *original* software function, if any.
    pub matched_loop: Option<OpRef>,
    pub stats: CompileStats,
}

/// The loop-nest skeleton of a function or loop.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LoopShape {
    pub trips: u64,
    pub stores: usize,
    pub inner: Vec<LoopShape>,
}

impl LoopShape {
    pub fn depth(&self) -> usize {
        1 + self.inner.iter().map(LoopShape::depth).max().unwrap_or(0)
    }

    /// Total elements processed (product of trips down the first spine).
    pub fn total_trips(&self) -> u64 {
        self.trips * self.inner.first().map(LoopShape::total_trips).unwrap_or(1)
    }
}

/// Shape of the loop at `opref`.
pub fn loop_shape(func: &Func, opref: OpRef) -> Option<LoopShape> {
    let op = func.op(opref);
    if !matches!(op.kind, OpKind::For) {
        return None;
    }
    let trips = static_trips(func, opref)?;
    let region = &op.regions[0];
    let mut inner = Vec::new();
    let mut stores = 0;
    for &child in &region.ops {
        match &func.op(child).kind {
            OpKind::For => inner.extend(loop_shape(func, child)),
            OpKind::Store(_) | OpKind::WriteSmem(_) => stores += 1,
            _ => {}
        }
    }
    Some(LoopShape { trips, stores, inner })
}

/// Top-level loops of a function.
pub fn top_loops(func: &Func) -> Vec<OpRef> {
    func.entry
        .ops
        .iter()
        .copied()
        .filter(|&o| matches!(func.op(o).kind, OpKind::For))
        .collect()
}

/// One software variant under consideration (the transformed function
/// itself is not retained: matching works on the shared e-graph via the
/// encode map, and lowering targets the *origin* loop in the original).
struct Variant {
    /// The loop in the *original* function this variant's transformed
    /// loop descends from.
    origin: OpRef,
    map: EncodeMap,
}

/// Match one ISAX against the software function, applying hybrid rewrites.
pub fn match_isax(
    software: &Func,
    isax_aligned: &Func,
    name: &str,
    opts: &CompileOptions,
) -> Result<MatchRound> {
    // Vacuously complete until a saturation run actually hits a budget
    // (covers the early returns where nothing saturates at all).
    let mut stats = CompileStats { saturation_complete: true, ..Default::default() };
    let mut g = EGraph::new();
    let sw_map = encode_func(&mut g, software);
    let isax_map = encode_func(&mut g, isax_aligned);
    stats.initial_enodes = g.node_count();

    // The ISAX skeleton: its unique top-level loop.
    let isax_tops = top_loops(isax_aligned);
    let [isax_top] = isax_tops.as_slice() else {
        return Ok(MatchRound { matched_loop: None, stats });
    };
    let isax_shape = loop_shape(isax_aligned, *isax_top)
        .ok_or_else(|| crate::error::Error::Compiler("isax loop has dynamic bounds".into()))?;
    let mut isax_classes: Vec<ClassId> = isax_map
        .loops
        .iter()
        .filter(|&&(_, _, d)| d == 0)
        .map(|&(_, c, _)| c)
        .collect();

    // Component tagging (§5.4): mark every store-anchor class of the ISAX
    // body so skeleton matching can report component hits.
    tag_components(&mut g, isax_aligned, &isax_map, name);

    let runner = Runner {
        iter_limit: opts.budget.iter_limit,
        node_limit: opts.budget.node_limit,
        match_limit: opts.budget.match_limit,
    };
    let rules = internal_rules();

    // Variant pool: the original + everything external rewrites produce.
    let mut variants: Vec<Variant> = top_loops(software)
        .into_iter()
        .map(|origin| Variant { origin, map: sw_map.clone() })
        .collect();
    if variants.is_empty() {
        return Ok(MatchRound { matched_loop: None, stats });
    }
    // All variants of the same func share one encode map; dedupe.
    variants.truncate(1);
    let origins = top_loops(software);

    // Skeleton matching closure: any software depth-0 loop class equal to
    // any ISAX class? Read-only — class equality needs no `&mut`.
    let try_match = |g: &EGraph,
                     variants: &[Variant],
                     isax_classes: &[ClassId]|
     -> Option<(OpRef, bool)> {
        for (vi, v) in variants.iter().enumerate() {
            for &(opref, cls, depth) in &v.map.loops {
                if depth != 0 {
                    continue;
                }
                for &ic in isax_classes {
                    if g.find(cls) == g.find(ic) {
                        let matched = if vi == 0 { opref } else { v.origin };
                        return Some((matched, vi == 0));
                    }
                }
            }
        }
        None
    };

    for round in 0..=opts.budget.external_budget {
        // Interleave: match first (canonical programs need zero rewrites),
        // then saturate one iteration at a time, re-checking after each.
        let mut report = crate::egraph::RunReport::default();
        let mut saturated = false;
        loop {
            if let Some((matched, _)) = try_match(&g, &variants, &isax_classes) {
                // Tag the matched class with the ISAX marker (§5.4).
                let marker = g.add_named(&format!("isax:{name}"), vec![]);
                let cls = variants
                    .iter()
                    .flat_map(|v| v.map.loops.iter())
                    .find(|&&(o, _, d)| d == 0 && o == matched)
                    .map(|&(_, c, _)| c);
                if let Some(cls) = cls {
                    g.union(cls, marker);
                    g.rebuild();
                }
                stats.internal_rewrites += report.applied;
                stats.iterations += report.iterations;
                stats.saturated_enodes = g.node_count();
                stats.node_budget_hit |= report.node_limit_hit;
                stats.match_budget_hit |= report.match_limit_hit;
                // A found match means the budget sufficed for this run.
                stats.saturation_complete = true;
                stats.matched.push(name.to_string());
                return Ok(MatchRound { matched_loop: Some(matched), stats });
            }
            if report.iterations >= opts.budget.iter_limit || report.node_limit_hit {
                break;
            }
            report.iterations += 1;
            let changed = runner.run_one(&mut g, &rules, &mut report);
            if !changed {
                saturated = true;
                break;
            }
        }
        stats.internal_rewrites += report.applied;
        stats.iterations += report.iterations;
        stats.saturated_enodes = g.node_count();
        stats.node_budget_hit |= report.node_limit_hit;
        stats.match_budget_hit |= report.match_limit_hit;
        // Complete iff this round's saturation reached a true fixpoint
        // rather than an iteration/node budget.
        stats.saturation_complete = saturated;

        if round == opts.budget.external_budget {
            break;
        }

        // ISAX-guided external rewrites (§5.3): pick transformations from
        // the shape difference. Returns false when no transformation
        // applies — then we're done failing. Variant encodes + unions are
        // batched: one congruence rebuild covers the whole round.
        let mut progressed = false;
        for &origin in &origins {
            let Some(sw_shape) = loop_shape(software, origin) else { continue };
            for pass in guided_passes(&sw_shape, &isax_shape) {
                let side_isax = matches!(pass, GuidedPass::UnrollIsax(_));
                match pass {
                    GuidedPass::Sw(p) => {
                        if let Ok(newf) = apply(software, origin, p) {
                            let map = encode_func(&mut g, &newf);
                            // Union the transformed loop with its origin:
                            // they are equivalent programs.
                            if let (Some(&(_, nc, _)), Some(&oc)) = (
                                map.loops.iter().find(|&&(_, _, d)| d == 0),
                                sw_map.op_class.get(&origin),
                            ) {
                                g.union(nc, oc);
                            }
                            variants.push(Variant { origin, map });
                            stats.external_rewrites += 1;
                            progressed = true;
                        }
                    }
                    GuidedPass::UnrollIsax(f) => {
                        if let Ok(newf) = apply(isax_aligned, *isax_top, LoopPass::Unroll(f)) {
                            let map = encode_func(&mut g, &newf);
                            if let Some(&(_, nc, _)) =
                                map.loops.iter().find(|&&(_, _, d)| d == 0)
                            {
                                if let Some(&ic) = isax_classes.first() {
                                    g.union(nc, ic);
                                }
                                isax_classes.push(nc);
                            }
                            stats.external_rewrites += 1;
                            progressed = true;
                        }
                    }
                }
                let _ = side_isax;
            }
        }
        if !progressed {
            break;
        }
        g.rebuild();
    }
    Ok(MatchRound { matched_loop: None, stats })
}

/// A guided transformation: on the software loop or the ISAX pattern.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum GuidedPass {
    Sw(LoopPass),
    UnrollIsax(u64),
}

/// §5.3: decide which external rewrites the shape difference justifies.
/// "The decision here only depends on the loop structure, not the
/// specific operations within the loop body."
fn guided_passes(sw: &LoopShape, isax: &LoopShape) -> Vec<GuidedPass> {
    let mut out = Vec::new();
    let sd = sw.depth();
    let id = isax.depth();
    if sd > id {
        // Software is tiled relative to the ISAX: flatten.
        out.push(GuidedPass::Sw(LoopPass::Coalesce));
    } else if sd < id {
        // ISAX has a deeper nest: tile software by the ISAX's inner trips.
        if let Some(inner) = isax.inner.first() {
            if inner.trips > 0 && sw.trips % inner.trips == 0 {
                out.push(GuidedPass::Sw(LoopPass::Tile(inner.trips)));
            }
        }
    } else {
        // Same depth: align trip counts by unrolling whichever side
        // iterates more.
        if sw.trips > isax.trips && isax.trips > 0 && sw.trips % isax.trips == 0 {
            out.push(GuidedPass::Sw(LoopPass::Unroll(sw.trips / isax.trips)));
        } else if isax.trips > sw.trips && sw.trips > 0 && isax.trips % sw.trips == 0 {
            out.push(GuidedPass::UnrollIsax(isax.trips / sw.trips));
        }
    }
    out
}

/// Insert `comp:<isax>:<i>` markers on every store-anchor class of the
/// ISAX body (§5.4 component tagging).
fn tag_components(g: &mut EGraph, isax: &Func, map: &EncodeMap, name: &str) {
    let mut i = 0;
    isax.walk(|opref, op| {
        if matches!(op.kind, OpKind::Store(_) | OpKind::WriteSmem(_)) {
            if let Some(&cls) = map.op_class.get(&opref) {
                let marker = g.add_named(&format!("comp:{name}:{i}"), vec![]);
                g.union(cls, marker);
                i += 1;
            }
        }
    });
    g.rebuild();
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::interface::cache::CacheHint;
    use crate::ir::builder::FuncBuilder;
    use crate::runtime::DType;

    /// ISAX: out[i] = a[i] * 4 for 16 elements (written with mul).
    fn isax_scale() -> Func {
        let mut b = FuncBuilder::new("vscale");
        let a = b.global("a", DType::I32, 16, CacheHint::Unknown);
        let o = b.global("o", DType::I32, 16, CacheHint::Unknown);
        b.for_range(0, 16, 1, |b, iv| {
            let v = b.load(a, iv);
            let four = b.const_i(4);
            let w = b.mul(v, four);
            b.store(o, iv, w);
        });
        b.finish(&[])
    }

    /// Software spelled with a shift instead of the multiply.
    fn software_shift() -> Func {
        let mut b = FuncBuilder::new("app");
        let x = b.global("x", DType::I32, 16, CacheHint::Unknown);
        let y = b.global("y", DType::I32, 16, CacheHint::Unknown);
        b.for_range(0, 16, 1, |b, iv| {
            let v = b.load(x, iv);
            let two = b.const_i(2);
            let w = b.shl(v, two); // v << 2 == v * 4
            b.store(y, iv, w);
        });
        b.finish(&[])
    }

    #[test]
    fn matches_through_internal_rewrites() {
        let r = match_isax(
            &software_shift(),
            &isax_scale(),
            "vscale",
            &CompileOptions::default(),
        )
        .unwrap();
        assert!(r.matched_loop.is_some(), "stats: {:?}", r.stats);
        assert!(r.stats.internal_rewrites > 0);
        assert_eq!(r.stats.external_rewrites, 0);
        // Note: saturation can *shrink* the node count when classes merge,
        // so only positivity is guaranteed here.
        assert!(r.stats.saturated_enodes > 0 && r.stats.initial_enodes > 0);
    }

    #[test]
    fn matches_tiled_software_via_coalesce() {
        // Software tiled by 4 (depth 2) against the flat ISAX.
        let f = software_shift();
        let target = top_loops(&f)[0];
        let tiled = apply(&f, target, LoopPass::Tile(4)).unwrap();
        let r =
            match_isax(&tiled, &isax_scale(), "vscale", &CompileOptions::default()).unwrap();
        assert!(r.matched_loop.is_some(), "stats: {:?}", r.stats);
        assert!(r.stats.external_rewrites >= 1);
    }

    #[test]
    fn matches_unrolled_software() {
        // Software unrolled by 2 (8 trips, 2 stores/iter) against the
        // rolled ISAX: the engine unrolls the ISAX pattern by 2.
        let f = software_shift();
        let target = top_loops(&f)[0];
        let unrolled = apply(&f, target, LoopPass::Unroll(2)).unwrap();
        let r =
            match_isax(&unrolled, &isax_scale(), "vscale", &CompileOptions::default()).unwrap();
        assert!(r.matched_loop.is_some(), "stats: {:?}", r.stats);
        assert!(r.stats.external_rewrites >= 1);
    }

    #[test]
    fn rejects_semantically_different_loop() {
        // Software adds instead of multiplying: must NOT match.
        let mut b = FuncBuilder::new("app");
        let x = b.global("x", DType::I32, 16, CacheHint::Unknown);
        let y = b.global("y", DType::I32, 16, CacheHint::Unknown);
        b.for_range(0, 16, 1, |b, iv| {
            let v = b.load(x, iv);
            let four = b.const_i(4);
            let w = b.add(v, four);
            b.store(y, iv, w);
        });
        let f = b.finish(&[]);
        let r = match_isax(&f, &isax_scale(), "vscale", &CompileOptions::default()).unwrap();
        assert!(r.matched_loop.is_none());
    }

    #[test]
    fn rejects_extra_side_effects() {
        // Same compute but an extra store the ISAX does not perform.
        let mut b = FuncBuilder::new("app");
        let x = b.global("x", DType::I32, 16, CacheHint::Unknown);
        let y = b.global("y", DType::I32, 16, CacheHint::Unknown);
        let z = b.global("z", DType::I32, 16, CacheHint::Unknown);
        b.for_range(0, 16, 1, |b, iv| {
            let v = b.load(x, iv);
            let two = b.const_i(2);
            let w = b.shl(v, two);
            b.store(y, iv, w);
            b.store(z, iv, v); // extra effect
        });
        let f = b.finish(&[]);
        let r = match_isax(&f, &isax_scale(), "vscale", &CompileOptions::default()).unwrap();
        assert!(r.matched_loop.is_none());
    }

    #[test]
    fn loop_shape_reports_structure() {
        let f = software_shift();
        let target = top_loops(&f)[0];
        let s = loop_shape(&f, target).unwrap();
        assert_eq!(s.trips, 16);
        assert_eq!(s.depth(), 1);
        assert_eq!(s.stores, 1);
        let tiled = apply(&f, target, LoopPass::Tile(4)).unwrap();
        let t = loop_shape(&tiled, top_loops(&tiled)[0]).unwrap();
        assert_eq!(t.depth(), 2);
        assert_eq!(t.total_trips(), 16);
    }
}
