//! §5.1 — semantic alignment.
//!
//! ISAX hardware descriptions carry microarchitectural detail (scratchpad
//! staging, register-file plumbing) that application code never shows. To
//! make the two comparable, the ISAX side is normalized down to the
//! software level:
//!
//! - `read_irf x<n>` → the n-th scalar parameter (explicit register
//!   references become data dependencies);
//! - `transfer`s disappear and `read_smem`/`write_smem` on staged
//!   scratchpads retarget to the global buffers they staged — only
//!   software-visible control flow and memory effects remain.
//!
//! The software side is canonicalized the way MLIR's canonicalizer would:
//! dead code and dead stores are removed (this also neutralizes the
//! "redundant statements" robustness attack of §6.2).

use std::collections::{HashMap, HashSet};

use crate::error::{Error, Result};
use crate::ir::func::{BufferId, BufferKind, Func, OpRef, Region, Value};
use crate::ir::ops::OpKind;
use crate::ir::types::Type;

/// Normalize an ISAX functional description to the software level.
pub fn align_isax(isax: &Func) -> Result<Func> {
    let mut out = isax.clone();

    // Map each scratchpad to the global it stages (single zero-offset
    // top-level transfer), then erase the transfer.
    let defs = out.def_map();
    let mut stage_of: HashMap<BufferId, BufferId> = HashMap::new();
    let mut kill: Vec<OpRef> = Vec::new();
    for &opref in &out.entry.ops {
        let op = out.op(opref);
        if let OpKind::Transfer { dst, src, .. } = op.kind {
            let zero = |v: Value| {
                defs[v.0 as usize]
                    .map(|d| matches!(out.op(d).kind, OpKind::ConstI(0)))
                    .unwrap_or(false)
            };
            if !(zero(op.operands[0]) && zero(op.operands[1])) {
                return Err(Error::Compiler(
                    "align: non-zero-offset transfer staging is not supported".into(),
                ));
            }
            let dst_smem = matches!(out.buffer(dst).kind, BufferKind::Scratchpad { .. });
            let src_smem = matches!(out.buffer(src).kind, BufferKind::Scratchpad { .. });
            match (dst_smem, src_smem) {
                (true, false) => {
                    stage_of.insert(dst, src);
                }
                (false, true) => {
                    stage_of.insert(src, dst);
                }
                _ => {}
            }
            kill.push(opref);
        }
    }
    out.entry.ops.retain(|o| !kill.contains(o));

    // Retarget scratchpad accesses to their staged globals; fetch → load.
    for i in 0..out.num_ops() {
        let opref = OpRef(i as u32);
        let op = out.op_mut(opref);
        match op.kind.clone() {
            OpKind::ReadSmem(b) => {
                let g = *stage_of.get(&b).ok_or_else(|| {
                    Error::Compiler(format!("align: scratchpad {} never staged", b.0))
                })?;
                op.kind = OpKind::Load(g);
            }
            OpKind::WriteSmem(b) => {
                if let Some(&g) = stage_of.get(&b) {
                    op.kind = OpKind::Store(g);
                }
                // Un-staged written scratchpads are ISAX-private temps; they
                // stay (the software equivalent is a local array).
            }
            OpKind::Fetch(b) => op.kind = OpKind::Load(b),
            OpKind::ReadIrf(_) | OpKind::WriteIrf(_) => {
                return Err(Error::Compiler(
                    "align: register plumbing should be converted by the builder \
                     (model rs1/rs2 as function params)"
                        .into(),
                ));
            }
            _ => {}
        }
    }
    Ok(out)
}

/// Canonicalize application code: dead-code + dead-store elimination.
pub fn canonicalize_software(func: &Func) -> Func {
    let mut out = func.clone();
    dse(&mut out);
    dce(&mut out);
    out
}

/// Dead-store elimination: a store overwritten by a later store to the
/// same buffer+index class within the same region, with no intervening
/// read of that buffer, is dead. (Conservative same-value-id check.)
fn dse(func: &mut Func) {
    let mut kill: Vec<OpRef> = Vec::new();
    collect_dead_stores(func, &func.entry.clone(), &mut kill);
    if kill.is_empty() {
        return;
    }
    retain_ops(func, &kill);
}

fn collect_dead_stores(func: &Func, region: &Region, kill: &mut Vec<OpRef>) {
    // last_store[(buf, index value)] -> opref of previous store
    let mut last: HashMap<(u32, Value), OpRef> = HashMap::new();
    for &opref in &region.ops {
        let op = func.op(opref);
        match &op.kind {
            OpKind::Store(b) | OpKind::WriteSmem(b) => {
                let key = (b.0, op.operands[0]);
                if let Some(prev) = last.insert(key, opref) {
                    kill.push(prev);
                }
            }
            OpKind::Load(b) | OpKind::ReadSmem(b) | OpKind::Fetch(b) => {
                // Any read kills tracking for that buffer.
                last.retain(|(bb, _), _| *bb != b.0);
            }
            OpKind::For | OpKind::If => {
                // Control flow may read anything: reset, then recurse.
                last.clear();
                for r in &op.regions {
                    collect_dead_stores(func, r, kill);
                }
            }
            OpKind::Transfer { .. } | OpKind::Copy { .. } | OpKind::CopyIssue { .. } => {
                last.clear();
            }
            _ => {}
        }
    }
}

/// Dead-code elimination: drop pure ops whose results are never used.
fn dce(func: &mut Func) {
    loop {
        let mut used: HashSet<Value> = HashSet::new();
        func.walk(|_, op| {
            for &v in &op.operands {
                used.insert(v);
            }
        });
        let mut kill: Vec<OpRef> = Vec::new();
        func.walk(|opref, op| {
            let pure = !op.kind.is_anchor() && !op.kind.touches_memory();
            let read_only_mem = matches!(
                op.kind,
                OpKind::Load(_) | OpKind::ReadSmem(_) | OpKind::Fetch(_) | OpKind::LoadItfc { .. }
            );
            if (pure || read_only_mem)
                && !op.results.is_empty()
                && op.results.iter().all(|r| !used.contains(r))
            {
                kill.push(opref);
            }
        });
        if kill.is_empty() {
            break;
        }
        retain_ops(func, &kill);
    }
}

/// Remove the given oprefs from every region.
fn retain_ops(func: &mut Func, kill: &[OpRef]) {
    func.entry.ops.retain(|o| !kill.contains(o));
    for i in 0..func.num_ops() {
        let opref = OpRef(i as u32);
        let op = func.op_mut(opref);
        for region in op.regions.iter_mut() {
            region.ops.retain(|o| !kill.contains(o));
        }
    }
}

/// A scalar ISAX parameter helper for descriptions that would use
/// `read_irf`: model rs1/rs2 as function params of Int type.
pub fn param_like_irf(builder: &mut crate::ir::FuncBuilder) -> Value {
    builder.param(Type::Int)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::interface::cache::CacheHint;
    use crate::ir::builder::FuncBuilder;
    use crate::runtime::DType;

    #[test]
    fn align_retargets_staged_scratchpad() {
        let mut b = FuncBuilder::new("isax");
        let g = b.global("h", DType::I32, 64, CacheHint::Cold);
        let o = b.global("s", DType::I32, 8, CacheHint::Warm);
        let sp = b.scratchpad("sp", DType::I32, 64, 1);
        let zero = b.const_i(0);
        b.transfer(sp, zero, g, zero, 256);
        b.for_range(0, 8, 1, |b, iv| {
            let v = b.read_smem(sp, iv);
            b.store(o, iv, v);
        });
        let f = b.finish(&[]);
        let a = align_isax(&f).unwrap();
        assert_eq!(a.count_ops(|k| matches!(k, OpKind::Transfer { .. })), 0);
        assert_eq!(a.count_ops(|k| matches!(k, OpKind::ReadSmem(_))), 0);
        assert_eq!(a.count_ops(|k| matches!(k, OpKind::Load(b) if *b == BufferId(0))), 1);
    }

    #[test]
    fn dse_removes_overwritten_store() {
        let mut b = FuncBuilder::new("sw");
        let g = b.global("x", DType::I32, 8, CacheHint::Unknown);
        let zero = b.const_i(0);
        let a = b.const_i(1);
        let c = b.const_i(2);
        b.store(g, zero, a); // dead: overwritten below, no read between
        b.store(g, zero, c);
        let f = b.finish(&[]);
        let canon = canonicalize_software(&f);
        assert_eq!(canon.count_ops(|k| matches!(k, OpKind::Store(_))), 1);
    }

    #[test]
    fn dse_keeps_store_with_intervening_read() {
        let mut b = FuncBuilder::new("sw");
        let g = b.global("x", DType::I32, 8, CacheHint::Unknown);
        let zero = b.const_i(0);
        let a = b.const_i(1);
        b.store(g, zero, a);
        let v = b.load(g, zero);
        b.store(g, zero, v);
        let f = b.finish(&[]);
        let canon = canonicalize_software(&f);
        assert_eq!(canon.count_ops(|k| matches!(k, OpKind::Store(_))), 2);
    }

    #[test]
    fn dce_removes_unused_chains() {
        let mut b = FuncBuilder::new("sw");
        let g = b.global("x", DType::I32, 8, CacheHint::Unknown);
        let zero = b.const_i(0);
        let v = b.load(g, zero);
        let two = b.const_i(2);
        let dead = b.mul(v, two); // never used
        let _ = dead;
        b.store(g, zero, v);
        let f = b.finish(&[]);
        let canon = canonicalize_software(&f);
        assert_eq!(canon.count_ops(|k| matches!(k, OpKind::Mul)), 0);
    }

    #[test]
    fn dce_preserves_semantics() {
        use crate::ir::interp::{run as interp, Memory};
        let mut b = FuncBuilder::new("sw");
        let g = b.global("x", DType::I32, 8, CacheHint::Unknown);
        b.for_range(0, 8, 1, |b, iv| {
            let v = b.load(g, iv);
            let one = b.const_i(1);
            let w = b.add(v, one);
            let dead = b.mul(w, w);
            let _ = dead;
            b.store(g, iv, w);
        });
        let f = b.finish(&[]);
        let canon = canonicalize_software(&f);
        let mut m1 = Memory::for_func(&f);
        m1.write_i32(BufferId(0), &[5; 8]);
        interp(&f, &[], &mut m1).unwrap();
        let mut m2 = Memory::for_func(&canon);
        m2.write_i32(BufferId(0), &[5; 8]);
        interp(&canon, &[], &mut m2).unwrap();
        assert_eq!(m1.read_i32(BufferId(0)), m2.read_i32(BufferId(0)));
    }
}
