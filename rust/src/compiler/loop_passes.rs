//! §5.2/§5.3 — external rewrites: loop transformations as IR passes.
//!
//! External rewrites restructure control flow (tiling, unrolling,
//! coalescing). They are hard to express as fixed e-graph rules — they
//! need dependence/dominance reasoning — so, like the paper, we run them
//! as ordinary IR passes on an extracted program variant and union the
//! result back into the e-graph ([`crate::compiler::matcher`]).
//!
//! All passes take the *target loop* by [`OpRef`] and return a fresh
//! transformed function (the input is never mutated — non-destructive
//! accumulation is the whole point).

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::ir::func::{Func, OpRef, Region, Value};
use crate::ir::ops::{Op, OpKind};
use crate::synthesis::memprobe::static_trips;

/// Which transformation to apply (with its factor).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoopPass {
    /// Replicate the body `factor` times, multiplying the step.
    Unroll(u64),
    /// Split into an outer loop stepping `factor` and an inner 0..factor.
    Tile(u64),
    /// Collapse a perfect 2-deep nest into one loop (inverse of tile).
    Coalesce,
}

impl std::fmt::Display for LoopPass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LoopPass::Unroll(k) => write!(f, "unroll({k})"),
            LoopPass::Tile(k) => write!(f, "tile({k})"),
            LoopPass::Coalesce => write!(f, "coalesce"),
        }
    }
}

/// Apply `pass` to the loop at `target` in `func`.
pub fn apply(func: &Func, target: OpRef, pass: LoopPass) -> Result<Func> {
    let op = func.op(target);
    if !matches!(op.kind, OpKind::For) {
        return Err(Error::Compiler(format!("loop pass target {target:?} is not a for")));
    }
    let mut rb = Rebuilder::new(func);
    let entry = func.entry.clone();
    let mut out = Func::new(func.name.clone());
    out.buffers = func.buffers.clone();
    rb.out = out;
    for &p in &func.params {
        let ty = func.value_type(p);
        let np = rb.out.new_value(ty);
        rb.out.params.push(np);
        rb.map.insert(p, np);
    }
    let new_entry = rb.rebuild_region(&entry, Some((target, pass)))?;
    rb.out.entry = new_entry;
    Ok(rb.out)
}

/// Recursive IR cloner with one loop interception.
struct Rebuilder<'f> {
    src: &'f Func,
    out: Func,
    map: HashMap<Value, Value>,
}

impl<'f> Rebuilder<'f> {
    fn new(src: &'f Func) -> Self {
        Self { src, out: Func::new(src.name.clone()), map: HashMap::new() }
    }

    fn v(&self, old: Value) -> Result<Value> {
        self.map
            .get(&old)
            .copied()
            .ok_or_else(|| Error::Compiler(format!("rebuild: unmapped value {old}")))
    }

    fn fresh_like(&mut self, old: Value) -> Value {
        let ty = self.src.value_type(old);
        let nv = self.out.new_value(ty);
        self.map.insert(old, nv);
        nv
    }

    /// Clone a region, transforming `intercept` if encountered.
    fn rebuild_region(
        &mut self,
        region: &Region,
        intercept: Option<(OpRef, LoopPass)>,
    ) -> Result<Region> {
        let mut out = Region::default();
        for &p in &region.params {
            out.params.push(self.fresh_like(p));
        }
        for &opref in &region.ops {
            let refs = match intercept {
                Some((target, pass)) if opref == target => self.transform_loop(opref, pass)?,
                _ => self.clone_op(opref, intercept)?,
            };
            out.ops.extend(refs);
        }
        Ok(out)
    }

    fn clone_op(
        &mut self,
        opref: OpRef,
        intercept: Option<(OpRef, LoopPass)>,
    ) -> Result<Vec<OpRef>> {
        let op = self.src.op(opref).clone();
        let operands: Vec<Value> = op.operands.iter().map(|&v| self.v(v)).collect::<Result<_>>()?;
        let mut regions = Vec::new();
        for r in &op.regions {
            regions.push(self.rebuild_region(r, intercept)?);
        }
        let results: Vec<Value> = op.results.iter().map(|&r| self.fresh_like(r)).collect();
        let mut new_op = Op::new(op.kind.clone(), operands, results);
        new_op.regions = regions;
        Ok(vec![self.out.add_op(new_op)])
    }

    /// Emit the body of `loop_op`'s region with `iv` bound to `iv_val` and
    /// carried params bound to `carried`; returns yielded values.
    fn inline_body(
        &mut self,
        region: &Region,
        iv_val: Value,
        carried: &[Value],
        into: &mut Vec<OpRef>,
    ) -> Result<Vec<Value>> {
        // Bind region params.
        let saved: Vec<(Value, Option<Value>)> = region
            .params
            .iter()
            .map(|&p| (p, self.map.get(&p).copied()))
            .collect();
        self.map.insert(region.params[0], iv_val);
        for (&p, &c) in region.params[1..].iter().zip(carried) {
            self.map.insert(p, c);
        }
        let mut yielded = Vec::new();
        for &opref in &region.ops {
            let op = self.src.op(opref).clone();
            if matches!(op.kind, OpKind::Yield) {
                yielded = op.operands.iter().map(|&v| self.v(v)).collect::<Result<_>>()?;
                continue;
            }
            let refs = self.clone_op(opref, None)?;
            into.extend(refs);
        }
        // Restore shadowed bindings.
        for (p, old) in saved {
            match old {
                Some(v) => {
                    self.map.insert(p, v);
                }
                None => {
                    self.map.remove(&p);
                }
            }
        }
        Ok(yielded)
    }

    fn transform_loop(&mut self, opref: OpRef, pass: LoopPass) -> Result<Vec<OpRef>> {
        match pass {
            LoopPass::Unroll(f) => self.unroll(opref, f),
            LoopPass::Tile(t) => self.tile(opref, t),
            LoopPass::Coalesce => self.coalesce(opref),
        }
    }

    fn loop_parts(&self, opref: OpRef) -> (Op, Region, i64, i64, i64) {
        let op = self.src.op(opref).clone();
        let region = op.regions[0].clone();
        let cval = |v: Value| {
            let defs = self.src.def_map();
            defs[v.0 as usize]
                .and_then(|d| match self.src.op(d).kind {
                    OpKind::ConstI(c) => Some(c),
                    _ => None,
                })
                .unwrap_or(i64::MIN)
        };
        let lb = cval(op.operands[0]);
        let ub = cval(op.operands[1]);
        let step = cval(op.operands[2]);
        (op, region, lb, ub, step)
    }

    fn unroll(&mut self, opref: OpRef, f: u64) -> Result<Vec<OpRef>> {
        let (op, region, lb, ub, step) = self.loop_parts(opref);
        let trips = static_trips(self.src, opref)
            .ok_or_else(|| Error::Compiler("unroll: non-static loop bounds".into()))?;
        if f == 0 || trips % f != 0 || step == i64::MIN {
            return Err(Error::Compiler(format!("unroll: factor {f} does not divide {trips}")));
        }
        let mut ops = Vec::new();
        // New bounds: same lb/ub, step * f.
        let lbv = self.push_const(lb, &mut ops);
        let ubv = self.push_const(ub, &mut ops);
        let stepv = self.push_const(step * f as i64, &mut ops);
        let inits: Vec<Value> =
            op.operands[3..].iter().map(|&v| self.v(v)).collect::<Result<_>>()?;

        // Build the unrolled body region.
        let mut body = Region::default();
        let iv = self.out.new_value(crate::ir::types::Type::Int);
        body.params.push(iv);
        let mut carried: Vec<Value> = Vec::new();
        for &init in &inits {
            let ty = self.out.value_type(init);
            let p = self.out.new_value(ty);
            body.params.push(p);
            carried.push(p);
        }
        let mut body_ops: Vec<OpRef> = Vec::new();
        let mut cur: Vec<Value> = carried.clone();
        for k in 0..f {
            let iv_k = if k == 0 {
                iv
            } else {
                let c = self.push_const(step * k as i64, &mut body_ops);
                let nv = self.out.new_value(crate::ir::types::Type::Int);
                let add = self.out.add_op(Op::new(OpKind::Add, vec![iv, c], vec![nv]));
                body_ops.push(add);
                nv
            };
            cur = self.inline_body(&region, iv_k, &cur, &mut body_ops)?;
        }
        let yld = self.out.add_op(Op::new(OpKind::Yield, cur, vec![]));
        body_ops.push(yld);
        body.ops = body_ops;

        let results: Vec<Value> = op.results.iter().map(|&r| self.fresh_like(r)).collect();
        let mut operands = vec![lbv, ubv, stepv];
        operands.extend(&inits);
        let mut for_op = Op::new(OpKind::For, operands, results);
        for_op.regions.push(body);
        ops.push(self.out.add_op(for_op));
        Ok(ops)
    }

    fn tile(&mut self, opref: OpRef, t: u64) -> Result<Vec<OpRef>> {
        let (op, region, lb, ub, step) = self.loop_parts(opref);
        let trips = static_trips(self.src, opref)
            .ok_or_else(|| Error::Compiler("tile: non-static loop bounds".into()))?;
        if t == 0 || trips % t != 0 || step == i64::MIN {
            return Err(Error::Compiler(format!("tile: factor {t} does not divide {trips}")));
        }
        let mut ops = Vec::new();
        let lbv = self.push_const(lb, &mut ops);
        let ubv = self.push_const(ub, &mut ops);
        let ostepv = self.push_const(step * t as i64, &mut ops);
        let inits: Vec<Value> =
            op.operands[3..].iter().map(|&v| self.v(v)).collect::<Result<_>>()?;

        // outer region
        let mut outer = Region::default();
        let ii = self.out.new_value(crate::ir::types::Type::Int);
        outer.params.push(ii);
        let mut outer_carried = Vec::new();
        for &init in &inits {
            let ty = self.out.value_type(init);
            let p = self.out.new_value(ty);
            outer.params.push(p);
            outer_carried.push(p);
        }
        let mut outer_ops: Vec<OpRef> = Vec::new();
        let ilb = self.push_const(0, &mut outer_ops);
        let iub = self.push_const(t as i64, &mut outer_ops);
        let istep = self.push_const(1, &mut outer_ops);

        // inner region
        let mut inner = Region::default();
        let i2 = self.out.new_value(crate::ir::types::Type::Int);
        inner.params.push(i2);
        let mut inner_carried = Vec::new();
        for &init in &inits {
            let ty = self.out.value_type(init);
            let p = self.out.new_value(ty);
            inner.params.push(p);
            inner_carried.push(p);
        }
        let mut inner_ops: Vec<OpRef> = Vec::new();
        // iv = ii + i2 * step
        let iv_val = if step == 1 {
            let nv = self.out.new_value(crate::ir::types::Type::Int);
            let add = self.out.add_op(Op::new(OpKind::Add, vec![ii, i2], vec![nv]));
            inner_ops.push(add);
            nv
        } else {
            let sc = self.push_const(step, &mut inner_ops);
            let mv = self.out.new_value(crate::ir::types::Type::Int);
            let mul = self.out.add_op(Op::new(OpKind::Mul, vec![i2, sc], vec![mv]));
            inner_ops.push(mul);
            let nv = self.out.new_value(crate::ir::types::Type::Int);
            let add = self.out.add_op(Op::new(OpKind::Add, vec![ii, mv], vec![nv]));
            inner_ops.push(add);
            nv
        };
        let yielded = self.inline_body(&region, iv_val, &inner_carried, &mut inner_ops)?;
        let yld = self.out.add_op(Op::new(OpKind::Yield, yielded, vec![]));
        inner_ops.push(yld);
        inner.ops = inner_ops;

        let inner_results: Vec<Value> = inits
            .iter()
            .map(|&v| {
                let ty = self.out.value_type(v);
                self.out.new_value(ty)
            })
            .collect();
        let mut inner_operands = vec![ilb, iub, istep];
        inner_operands.extend(&outer_carried);
        let mut inner_for = Op::new(OpKind::For, inner_operands, inner_results.clone());
        inner_for.regions.push(inner);
        outer_ops.push(self.out.add_op(inner_for));
        let oyld = self.out.add_op(Op::new(OpKind::Yield, inner_results, vec![]));
        outer_ops.push(oyld);
        outer.ops = outer_ops;

        let results: Vec<Value> = op.results.iter().map(|&r| self.fresh_like(r)).collect();
        let mut operands = vec![lbv, ubv, ostepv];
        operands.extend(&inits);
        let mut for_op = Op::new(OpKind::For, operands, results);
        for_op.regions.push(outer);
        ops.push(self.out.add_op(for_op));
        Ok(ops)
    }

    /// Collapse `for ii in 0..A·s step s { for j in 0..B { body(ii, j) } }`
    /// into `for k in 0..A*B { body((k / B)·s, k % B) }`. Requires lb=0 on
    /// both loops, inner step 1, and a perfect nest (outer body = inner
    /// loop + yield). With `s == B` (a tiled nest) the reconstructed index
    /// `(k/B)·B + k%B` collapses to `k` under the `div-mul-rem` rule.
    fn coalesce(&mut self, opref: OpRef) -> Result<Vec<OpRef>> {
        let (op, outer_region, olb, _oub, ostep) = self.loop_parts(opref);
        let a = static_trips(self.src, opref)
            .ok_or_else(|| Error::Compiler("coalesce: non-static outer bounds".into()))?;
        if olb != 0 || ostep < 1 {
            return Err(Error::Compiler("coalesce: outer loop must be 0..N with step >= 1".into()));
        }
        // Find the single inner for (perfect nest).
        let inner_refs: Vec<OpRef> = outer_region
            .ops
            .iter()
            .copied()
            .filter(|&o| matches!(self.src.op(o).kind, OpKind::For))
            .collect();
        let non_yield_anchors = outer_region
            .ops
            .iter()
            .filter(|&&o| {
                let k = &self.src.op(o).kind;
                k.is_anchor() && !matches!(k, OpKind::Yield)
            })
            .count();
        if inner_refs.len() != 1 || non_yield_anchors != 1 {
            return Err(Error::Compiler("coalesce: not a perfect 2-deep nest".into()));
        }
        let inner_ref = inner_refs[0];
        let (inner_op, inner_region, ilb, _iub, istep) = self.loop_parts(inner_ref);
        let b_trips = static_trips(self.src, inner_ref)
            .ok_or_else(|| Error::Compiler("coalesce: non-static inner bounds".into()))?;
        if ilb != 0 || istep != 1 {
            return Err(Error::Compiler("coalesce: inner loop must be 0..B step 1".into()));
        }
        // Carried chain check: inner inits must be exactly the outer's
        // carried params (in order) and outer yields the inner results.
        let outer_carried = &outer_region.params[1..];
        let inner_inits = &inner_op.operands[3..];
        if inner_inits.len() != outer_carried.len()
            || inner_inits.iter().zip(outer_carried).any(|(a, b)| a != b)
        {
            return Err(Error::Compiler("coalesce: carried-value chain mismatch".into()));
        }

        let mut ops = Vec::new();
        let lbv = self.push_const(0, &mut ops);
        let ubv = self.push_const((a * b_trips) as i64, &mut ops);
        let stepv = self.push_const(1, &mut ops);
        let inits: Vec<Value> =
            op.operands[3..].iter().map(|&v| self.v(v)).collect::<Result<_>>()?;

        let mut body = Region::default();
        let k = self.out.new_value(crate::ir::types::Type::Int);
        body.params.push(k);
        let mut carried = Vec::new();
        for &init in &inits {
            let ty = self.out.value_type(init);
            let p = self.out.new_value(ty);
            body.params.push(p);
            carried.push(p);
        }
        let mut body_ops: Vec<OpRef> = Vec::new();
        let bconst = self.push_const(b_trips as i64, &mut body_ops);
        let iv_outer = {
            let nv = self.out.new_value(crate::ir::types::Type::Int);
            let d = self.out.add_op(Op::new(OpKind::Div, vec![k, bconst], vec![nv]));
            body_ops.push(d);
            if ostep == 1 {
                nv
            } else {
                // outer iv advances by `ostep` per outer trip.
                let sc = self.push_const(ostep, &mut body_ops);
                let mv = self.out.new_value(crate::ir::types::Type::Int);
                let m = self.out.add_op(Op::new(OpKind::Mul, vec![nv, sc], vec![mv]));
                body_ops.push(m);
                mv
            }
        };
        let iv_inner = {
            let nv = self.out.new_value(crate::ir::types::Type::Int);
            let r = self.out.add_op(Op::new(OpKind::Rem, vec![k, bconst], vec![nv]));
            body_ops.push(r);
            nv
        };
        // Bind outer iv, then inline the inner body with inner iv.
        self.map.insert(outer_region.params[0], iv_outer);
        let yielded = self.inline_body(&inner_region, iv_inner, &carried, &mut body_ops)?;
        let yld = self.out.add_op(Op::new(OpKind::Yield, yielded, vec![]));
        body_ops.push(yld);
        body.ops = body_ops;

        let results: Vec<Value> = op.results.iter().map(|&r| self.fresh_like(r)).collect();
        let mut operands = vec![lbv, ubv, stepv];
        operands.extend(&inits);
        let mut for_op = Op::new(OpKind::For, operands, results);
        for_op.regions.push(body);
        ops.push(self.out.add_op(for_op));
        Ok(ops)
    }

    fn push_const(&mut self, c: i64, into: &mut Vec<OpRef>) -> Value {
        let v = self.out.new_value(crate::ir::types::Type::Int);
        let op = self.out.add_op(Op::new(OpKind::ConstI(c), vec![], vec![v]));
        into.push(op);
        v
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::interface::cache::CacheHint;
    use crate::ir::builder::FuncBuilder;
    use crate::ir::interp::{run as interp, Memory, Val};
    use crate::runtime::DType;

    fn sum_loop() -> (Func, OpRef) {
        let mut b = FuncBuilder::new("sum");
        let x = b.global("x", DType::I32, 16, CacheHint::Unknown);
        let zero = b.const_i(0);
        let lb = b.const_i(0);
        let ub = b.const_i(16);
        let one = b.const_i(1);
        let s = b.for_loop(lb, ub, one, &[zero], |b, iv, c| {
            let v = b.load(x, iv);
            vec![b.add(c[0], v)]
        });
        let f = b.finish(&s);
        let mut target = None;
        f.walk(|r, op| {
            if matches!(op.kind, OpKind::For) {
                target = Some(r);
            }
        });
        (f, target.unwrap())
    }

    fn run_sum(f: &Func) -> i64 {
        let mut mem = Memory::for_func(f);
        let data: Vec<i32> = (1..=16).collect();
        mem.write_i32(crate::ir::func::BufferId(0), &data);
        match interp(f, &[], &mut mem).unwrap()[0] {
            Val::I(v) => v,
            _ => panic!(),
        }
    }

    #[test]
    fn unroll_preserves_reduction() {
        let (f, target) = sum_loop();
        for factor in [2u64, 4, 8] {
            let g = apply(&f, target, LoopPass::Unroll(factor)).unwrap();
            crate::ir::verifier::verify(&g).unwrap();
            assert_eq!(run_sum(&g), 136, "factor {factor}");
            // body got replicated
            assert_eq!(
                g.count_ops(|k| matches!(k, OpKind::Load(_))) as u64,
                factor,
                "factor {factor}"
            );
        }
    }

    #[test]
    fn tile_preserves_reduction() {
        let (f, target) = sum_loop();
        for factor in [2u64, 4] {
            let g = apply(&f, target, LoopPass::Tile(factor)).unwrap();
            crate::ir::verifier::verify(&g).unwrap();
            assert_eq!(run_sum(&g), 136, "factor {factor}");
            assert_eq!(g.count_ops(|k| matches!(k, OpKind::For)), 2);
        }
    }

    #[test]
    fn coalesce_inverts_tile() {
        let (f, target) = sum_loop();
        let tiled = apply(&f, target, LoopPass::Tile(4)).unwrap();
        // Find outer loop of the tiled version.
        let mut depth0 = Vec::new();
        for &o in &tiled.entry.ops {
            if matches!(tiled.op(o).kind, OpKind::For) {
                depth0.push(o);
            }
        }
        let outer = depth0.first().copied();
        let coalesced = apply(&tiled, outer.unwrap(), LoopPass::Coalesce).unwrap();
        crate::ir::verifier::verify(&coalesced).unwrap();
        assert_eq!(run_sum(&coalesced), 136);
        assert_eq!(coalesced.count_ops(|k| matches!(k, OpKind::For)), 1);
    }

    #[test]
    fn unroll_rejects_non_dividing_factor() {
        let (f, target) = sum_loop();
        assert!(apply(&f, target, LoopPass::Unroll(3)).is_err());
    }

    #[test]
    fn unroll_without_carried_values() {
        let mut b = FuncBuilder::new("scale");
        let x = b.global("x", DType::I32, 8, CacheHint::Unknown);
        b.for_range(0, 8, 1, |b, iv| {
            let v = b.load(x, iv);
            let two = b.const_i(2);
            let w = b.mul(v, two);
            b.store(x, iv, w);
        });
        let f = b.finish(&[]);
        let mut target = None;
        f.walk(|r, op| {
            if matches!(op.kind, OpKind::For) {
                target = Some(r);
            }
        });
        let g = apply(&f, target.unwrap(), LoopPass::Unroll(2)).unwrap();
        crate::ir::verifier::verify(&g).unwrap();
        let mut mem = Memory::for_func(&g);
        mem.write_i32(crate::ir::func::BufferId(0), &[1, 2, 3, 4, 5, 6, 7, 8]);
        interp(&g, &[], &mut mem).unwrap();
        assert_eq!(
            mem.read_i32(crate::ir::func::BufferId(0)),
            vec![2, 4, 6, 8, 10, 12, 14, 16]
        );
    }
}
