//! §5.3 — the internal (algebraic / dataflow) rewrite rule set.
//!
//! These are the fixed egglog-style rules: they rewrite dataflow subtrees
//! beneath anchors without touching control flow, so program order and
//! side effects are preserved by construction. The set covers the variant
//! classes the paper's case studies inject (Table 3):
//!
//! - **AF** (algebraic form): commutativity/associativity/identities;
//! - **RF** (representation form): shift↔multiply, overflow-safe average,
//!   masking idioms;
//! - **RE** (common-subexpression split/reuse): handled structurally by
//!   hashconsing — two syntactically different spellings of the same
//!   subterm collapse into one e-class once rules align them.

use crate::egraph::rewrite::Rewrite;
use crate::egraph::EGraph;

/// Parse a `const:<v>` symbol on any node of a class. Read-only — the
/// engine's borrowed accessors mean no clone and no `&mut` here.
fn const_of(g: &EGraph, c: crate::egraph::ClassId) -> Option<i64> {
    for n in g.nodes(c) {
        let name = g.sym_name(n.sym);
        if let Some(v) = name.strip_prefix("const:") {
            if let Ok(k) = v.parse::<i64>() {
                return Some(k);
            }
        }
    }
    None
}

/// The pattern→pattern internal rules as data: `(name, lhs, rhs)`.
/// Shared with the bench target's embedded pre-PR engine
/// (`benches/egraph.rs`) so old-vs-new comparisons always saturate the
/// same rule set — edit here, both engines follow.
pub const SIMPLE_RULES: &[(&str, &str, &str)] = &[
    // -- AF: commutativity --------------------------------------------------
    ("comm-add", "(add ?a ?b)", "(add ?b ?a)"),
    ("comm-mul", "(mul ?a ?b)", "(mul ?b ?a)"),
    ("comm-and", "(and ?a ?b)", "(and ?b ?a)"),
    ("comm-or", "(or ?a ?b)", "(or ?b ?a)"),
    ("comm-xor", "(xor ?a ?b)", "(xor ?b ?a)"),
    ("comm-min", "(min ?a ?b)", "(min ?b ?a)"),
    ("comm-max", "(max ?a ?b)", "(max ?b ?a)"),
    // -- AF: associativity (one direction; comm gives the rest).
    //    NOTE: assoc-mul and distributivity are deliberately absent from
    //    the default set — on loop-index polynomials they explode the
    //    graph combinatorially, which is exactly the §5.3 "blindly
    //    saturating would cause the e-graph to grow explosively" failure.
    //    The ISAX-guided strategy keeps the rule set lean and lets loop
    //    passes handle structural change.
    ("assoc-add", "(add (add ?a ?b) ?c)", "(add ?a (add ?b ?c))"),
    // -- AF: identities -----------------------------------------------------
    ("add-zero", "(add ?x const:0)", "?x"),
    ("mul-one", "(mul ?x const:1)", "?x"),
    ("mul-zero", "(mul ?x const:0)", "const:0"),
    ("sub-zero", "(sub ?x const:0)", "?x"),
    ("sub-self", "(sub ?x ?x)", "const:0"),
    ("and-self", "(and ?x ?x)", "?x"),
    ("or-self", "(or ?x ?x)", "?x"),
    ("xor-self", "(xor ?x ?x)", "const:0"),
    ("shl-zero", "(shl ?x const:0)", "?x"),
    // -- RF: overflow-safe average (the §6.2 robustness attack):
    //    (a + b) / 2  ==  (a & b) + ((a ^ b) >> 1)
    (
        "avg-overflow-safe",
        "(div (add ?a ?b) const:2)",
        "(add (and ?a ?b) (shr (xor ?a ?b) const:1))",
    ),
    (
        "avg-plain",
        "(add (and ?a ?b) (shr (xor ?a ?b) const:1))",
        "(div (add ?a ?b) const:2)",
    ),
    // -- Index reconstruction after coalescing:
    //    (k / B) * B + (k % B)  ==  k   (B constant, non-negative k)
    ("div-mul-rem", "(add (mul (div ?x ?c) ?c) (rem ?x ?c))", "?x"),
    // -- RF: select(cmp) as min/max -----------------------------------------
    ("select-max", "(select (cmp:gt ?a ?b) ?a ?b)", "(max ?a ?b)"),
    ("select-min", "(select (cmp:lt ?a ?b) ?a ?b)", "(min ?a ?b)"),
    ("max-select", "(max ?a ?b)", "(select (cmp:gt ?a ?b) ?a ?b)"),
];

/// The standard internal rule set.
pub fn internal_rules() -> Vec<Rewrite> {
    let mut rules: Vec<Rewrite> =
        SIMPLE_RULES.iter().map(|&(n, l, r)| Rewrite::simple(n, l, r)).collect();

    // -- RF: shift <-> multiply/divide with constant folding (dynamic) -----
    rules.push(Rewrite::dynamic("shl-to-mul", "(shl ?x ?c)", |g, binds| {
        let k = const_of(g, binds["c"])?;
        if !(0..=32).contains(&k) {
            return None;
        }
        let x = binds["x"];
        let cm = g.add_named(&format!("const:{}", 1i64 << k), vec![]);
        Some(g.add_named("mul", vec![x, cm]))
    }));
    rules.push(Rewrite::dynamic("shr-to-div", "(shr ?x ?c)", |g, binds| {
        let k = const_of(g, binds["c"])?;
        if !(1..=32).contains(&k) {
            return None;
        }
        let x = binds["x"];
        let cm = g.add_named(&format!("const:{}", 1i64 << k), vec![]);
        Some(g.add_named("div", vec![x, cm]))
    }));
    // Constant folding for add/mul of two consts (keeps index math tidy).
    rules.push(Rewrite::dynamic("fold-add", "(add ?a ?b)", |g, binds| {
        let x = const_of(g, binds["a"])?;
        let y = const_of(g, binds["b"])?;
        Some(g.add_named(&format!("const:{}", x.wrapping_add(y)), vec![]))
    }));
    rules.push(Rewrite::dynamic("fold-mul", "(mul ?a ?b)", |g, binds| {
        let x = const_of(g, binds["a"])?;
        let y = const_of(g, binds["b"])?;
        Some(g.add_named(&format!("const:{}", x.wrapping_mul(y)), vec![]))
    }));
    // -- RF: and-mask as rem for powers of two: x & (2^k - 1) == x % 2^k
    rules.push(Rewrite::dynamic("mask-to-rem", "(and ?x ?c)", |g, binds| {
        let k = const_of(g, binds["c"])?;
        if k <= 0 || (k + 1) & k != 0 {
            return None; // not 2^t - 1
        }
        let x = binds["x"];
        let cm = g.add_named(&format!("const:{}", k + 1), vec![]);
        Some(g.add_named("rem", vec![x, cm]))
    }));
    rules.push(Rewrite::dynamic("rem-to-mask", "(rem ?x ?c)", |g, binds| {
        let k = const_of(g, binds["c"])?;
        if k <= 1 || k & (k - 1) != 0 {
            return None; // not a power of two
        }
        let x = binds["x"];
        let cm = g.add_named(&format!("const:{}", k - 1), vec![]);
        Some(g.add_named("and", vec![x, cm]))
    }));
    rules
}

/// The §5.3 heuristic extraction cost: penalize non-affine operations so
/// the extracted program orients toward affine-friendly forms (`i*4`
/// preferred over `i<<2`), and reward ISAX markers strongly so matched
/// loops extract as intrinsics.
pub fn affine_cost(sym: &str, kids: &[f64]) -> f64 {
    let own = if sym.starts_with("isax:") {
        // Strongly prefer offloaded forms.
        0.1
    } else {
        match sym {
            "shl" | "shr" => 10.0, // non-affine index forms
            "div" | "rem" => 8.0,
            // Transcendentals are expensive scalar FUs but never index
            // math; keep them extractable without distorting index forms.
            "exp" | "sqrt" => 6.0,
            "mul" => 1.0,
            "for" => 2.0,
            _ => 1.0,
        }
    };
    own + kids.iter().sum::<f64>()
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::egraph::{extract_best, EGraph, Runner};

    #[test]
    fn shift_rewrites_to_affine_mul() {
        let mut g = EGraph::new();
        let iv = g.add_named("iv:0", vec![]);
        let c2 = g.add_named("const:2", vec![]);
        let shl = g.add_named("shl", vec![iv, c2]);
        Runner::default().run(&mut g, &internal_rules());
        let out = extract_best(&g, shl, &affine_cost).unwrap();
        assert_eq!(out.to_sexp(), "(mul iv:0 const:4)");
    }

    #[test]
    fn overflow_safe_average_recognized() {
        // (a & b) + ((a ^ b) >> 1) must collapse with (a + b) / 2.
        let mut g = EGraph::new();
        let a = g.add_named("param:0", vec![]);
        let b = g.add_named("param:1", vec![]);
        let c1 = g.add_named("const:1", vec![]);
        let c2 = g.add_named("const:2", vec![]);
        let and = g.add_named("and", vec![a, b]);
        let xor = g.add_named("xor", vec![a, b]);
        let shr = g.add_named("shr", vec![xor, c1]);
        let safe = g.add_named("add", vec![and, shr]);
        let sum = g.add_named("add", vec![a, b]);
        let plain = g.add_named("div", vec![sum, c2]);
        Runner::default().run(&mut g, &internal_rules());
        assert_eq!(g.find(safe), g.find(plain));
    }

    #[test]
    fn assoc_comm_collapse_reassociated_sums() {
        // (a + b) + c == a + (c + b)
        let mut g = EGraph::new();
        let a = g.add_named("param:0", vec![]);
        let b = g.add_named("param:1", vec![]);
        let c = g.add_named("param:2", vec![]);
        let ab = g.add_named("add", vec![a, b]);
        let abc = g.add_named("add", vec![ab, c]);
        let cb = g.add_named("add", vec![c, b]);
        let acb = g.add_named("add", vec![a, cb]);
        Runner::default().run(&mut g, &internal_rules());
        assert_eq!(g.find(abc), g.find(acb));
    }

    #[test]
    fn mask_and_rem_collapse() {
        let mut g = EGraph::new();
        let x = g.add_named("param:0", vec![]);
        let c31 = g.add_named("const:31", vec![]);
        let c32 = g.add_named("const:32", vec![]);
        let mask = g.add_named("and", vec![x, c31]);
        let rem = g.add_named("rem", vec![x, c32]);
        Runner::default().run(&mut g, &internal_rules());
        assert_eq!(g.find(mask), g.find(rem));
    }
}
