//! §5.4 final step — lowering matched loops to ISAX intrinsics.
//!
//! After extraction selects the ISAX-marked variant, the marker becomes an
//! intrinsic call to the custom instruction: the matched `for` is replaced
//! by an `isax.<name>` op. Everything else is untouched, and the result
//! feeds the standard backend (here: the cycle-level core models, which
//! execute intrinsics on the synthesized ISAX engine).

use crate::error::{Error, Result};
use crate::ir::func::{Func, OpRef};
use crate::ir::ops::{Op, OpKind};

/// Replace the loop at `target` with an `Intrinsic(name)` op.
///
/// The intrinsic's operands are the loop bounds' defining values are not
/// needed — ISAX invocations carry their configuration in the instruction
/// encoding (rs1/rs2 hold base pointers, set up by the surrounding code) —
/// so the op takes no SSA operands and produces no results. Loops whose
/// results feed later code cannot be offloaded wholesale and are rejected.
pub fn replace_loop_with_intrinsic(func: &Func, target: OpRef, name: &str) -> Result<Func> {
    let mut out = func.clone();
    let op = out.op(target).clone();
    if !matches!(op.kind, OpKind::For) {
        return Err(Error::Compiler(format!("lower: {target:?} is not a loop")));
    }
    if !op.results.is_empty() {
        // Loop-carried results that escape: the ISAX writes its outputs
        // through memory, so a value-producing loop needs a store-based
        // rewrite first. Our ISAX definitions are all memory-to-memory.
        return Err(Error::Compiler(
            "lower: cannot offload a loop whose results are used as SSA values".into(),
        ));
    }
    let intr = out.add_op(Op::new(OpKind::Intrinsic(name.to_string()), vec![], vec![]));
    let pos = out
        .entry
        .ops
        .iter()
        .position(|&o| o == target)
        .ok_or_else(|| Error::Compiler("lower: loop is not at the top level".into()))?;
    out.entry.ops[pos] = intr;
    Ok(out)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::compiler::matcher::top_loops;
    use crate::interface::cache::CacheHint;
    use crate::ir::builder::FuncBuilder;
    use crate::runtime::DType;

    #[test]
    fn replaces_loop_with_intrinsic() {
        let mut b = FuncBuilder::new("app");
        let x = b.global("x", DType::I32, 8, CacheHint::Unknown);
        b.for_range(0, 8, 1, |b, iv| {
            let v = b.load(x, iv);
            b.store(x, iv, v);
        });
        let f = b.finish(&[]);
        let target = top_loops(&f)[0];
        let g = replace_loop_with_intrinsic(&f, target, "vcopy").unwrap();
        assert_eq!(g.count_ops(|k| matches!(k, OpKind::For)), 0);
        assert_eq!(
            g.count_ops(|k| matches!(k, OpKind::Intrinsic(n) if n == "vcopy")),
            1
        );
        crate::ir::verifier::verify(&g).unwrap();
    }

    #[test]
    fn rejects_value_producing_loop() {
        let mut b = FuncBuilder::new("app");
        let x = b.global("x", DType::I32, 8, CacheHint::Unknown);
        let zero = b.const_i(0);
        let lb = b.const_i(0);
        let ub = b.const_i(8);
        let one = b.const_i(1);
        let s = b.for_loop(lb, ub, one, &[zero], |b, iv, c| {
            let v = b.load(x, iv);
            vec![b.add(c[0], v)]
        });
        let f = b.finish(&s);
        let target = top_loops(&f)[0];
        assert!(replace_loop_with_intrinsic(&f, target, "vsum").is_err());
    }
}
