//! §5 — the retargetable compiler.
//!
//! Pipeline (Figure 5):
//! 1. **Semantic alignment** ([`align`]): ISAX descriptions are normalized
//!    from functional Aquas-IR down to the software abstraction level —
//!    register-file reads become parameters, transfers/scratchpads become
//!    direct global accesses. Software code is canonicalized (DCE/DSE)
//!    the way Polygeist + MLIR canonicalization would.
//! 2. **Fusing IR and e-graph** ([`encode`]): blocks become `tuple`
//!    e-nodes whose children are the *anchors* (side-effecting ops,
//!    terminators, control flow) in program order; pure dataflow forms
//!    subtrees beneath. Identical structures hashcons to identical
//!    classes, so ISAX and software fragments that become equivalent
//!    *collapse into the same e-class*.
//! 3. **Hybrid rewriting** ([`rules`] internal / [`loop_passes`] external):
//!    algebraic egglog-style rules saturate the dataflow space, while
//!    loop transformations (unroll/tile/coalesce) run as IR passes on
//!    extracted variants whose results are unioned back — triggered
//!    selectively by ISAX loop analysis to suppress blowup.
//! 4. **Skeleton-components matching** ([`matcher`]): each ISAX splits
//!    into a loop-nest skeleton + dataflow components; components tag
//!    matching e-classes with marker e-nodes, then the skeleton engine
//!    validates structure/order/effects and tags the loop class with an
//!    ISAX marker.
//! 5. **Lowering** ([`lower`]): tagged loops are replaced by `isax.<name>`
//!    intrinsics; the rest of the program is untouched.

// Panic-free audit (robustness): the compiler must reject hostile input
// with `Error`, never abort. The deny propagates to every submodule;
// test code opts back out per-module.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod align;
pub mod encode;
pub mod loop_passes;
pub mod lower;
pub mod matcher;
pub mod rules;

use crate::egraph::{EGraph, Runner};
use crate::error::{Error, Result};
use crate::ir::Func;

/// An ISAX available for offloading: its name plus the *functional-level*
/// description (the same IR the synthesis flow consumes).
#[derive(Debug, Clone)]
pub struct IsaxDef {
    pub name: String,
    pub func: Func,
}

/// Compilation statistics (Table 3), plus the budget outcome flags of
/// the robustness contract: exhaustion is *observable*, never an error.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CompileStats {
    pub internal_rewrites: usize,
    pub external_rewrites: usize,
    pub initial_enodes: usize,
    pub saturated_enodes: usize,
    pub iterations: usize,
    pub matched: Vec<String>,
    /// Every saturation run either found its match or reached a true
    /// fixpoint — no iteration/node/match budget cut it short.
    pub saturation_complete: bool,
    /// Some saturation run stopped at the e-graph node budget.
    pub node_budget_hit: bool,
    /// Some rule filled its per-iteration match budget at least once.
    pub match_budget_hit: bool,
    /// Mid-end pipeline rounds actually executed (0 when `opt_level < 2`).
    pub pass_rounds_used: usize,
    /// The mid-end stopped at its round budget before proving a fixpoint.
    pub pass_budget_hit: bool,
}

impl CompileStats {
    /// Any budget cut the pipeline short (the `aquas compile` /
    /// `aquas opt` "budget exhausted" line).
    pub fn budget_exhausted(&self) -> bool {
        !self.saturation_complete
            || self.node_budget_hit
            || self.match_budget_hit
            || self.pass_budget_hit
    }
}

/// Result of compiling one software function against an ISAX library.
#[derive(Debug, Clone)]
pub struct CompileResult {
    /// The lowered program (matched loops replaced by intrinsics).
    pub func: Func,
    pub stats: CompileStats,
}

/// Resource budgets for one compile. Exhausting any of these is **not an
/// error**: saturation stops where it stands, extraction and the mid-end
/// still run, and the result is verified, runnable IR — the outcome is
/// recorded in [`CompileStats`] instead of failing the compile.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CompileBudget {
    /// Saturation iteration limit per round.
    pub iter_limit: usize,
    /// E-graph node budget (§5.3: "suppressing e-graph blowup").
    pub node_limit: usize,
    /// Matches applied per rule per iteration (anti-flood backstop).
    pub match_limit: usize,
    /// Maximum external (loop-pass) rewrites to attempt per ISAX.
    pub external_budget: usize,
    /// Mid-end pipeline fixpoint round cap.
    pub pass_rounds: usize,
}

impl Default for CompileBudget {
    fn default() -> Self {
        Self {
            iter_limit: 12,
            node_limit: 100_000,
            match_limit: 10_000,
            external_budget: 6,
            pass_rounds: crate::ir::passes::MAX_ROUNDS,
        }
    }
}

impl CompileBudget {
    /// Parse a `key=value,key=value` budget spec (the `--budget` CLI
    /// flag), e.g. `iters=4,nodes=20000,matches=500,external=2,rounds=8`.
    /// Unknown keys and malformed values are diagnostic errors; omitted
    /// keys keep their defaults. Never panics.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut b = Self::default();
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let Some((key, val)) = part.split_once('=') else {
                return Err(Error::Compiler(format!(
                    "budget spec `{part}`: expected key=value"
                )));
            };
            let (key, val) = (key.trim(), val.trim());
            let bad = |what: &str| Error::Compiler(format!("budget spec {key}={val}: {what}"));
            let n: usize = val.parse().map_err(|_| bad("not a non-negative integer"))?;
            match key {
                "iters" => b.iter_limit = n,
                "nodes" => b.node_limit = n,
                "matches" => {
                    if n == 0 {
                        return Err(bad("must be at least 1"));
                    }
                    b.match_limit = n;
                }
                "external" => b.external_budget = n,
                "rounds" => b.pass_rounds = n,
                _ => {
                    return Err(Error::Compiler(format!(
                        "budget spec: unknown key `{key}` \
                         (expected iters|nodes|matches|external|rounds)"
                    )))
                }
            }
        }
        Ok(b)
    }
}

/// Compiler configuration.
#[derive(Debug, Clone, Default)]
pub struct CompileOptions {
    /// Resource budgets (saturation, matching, mid-end rounds).
    pub budget: CompileBudget,
    /// Mid-end effort applied to the lowered program after matching:
    /// `0` leaves the extracted IR untouched, `2` runs the full
    /// `ir::passes` pipeline (SCCP/CSE/LICM/sink/DCE) to a fixpoint.
    pub opt_level: u8,
}

/// Compile: offload every matching loop of `software` onto the ISAXs.
/// Budget exhaustion (see [`CompileBudget`]) never fails this function:
/// a starved compile still returns verified, runnable IR, with the
/// truncation recorded in [`CompileStats`].
pub fn compile(
    software: &Func,
    isaxes: &[IsaxDef],
    opts: &CompileOptions,
) -> Result<CompileResult> {
    let mut stats = CompileStats { saturation_complete: true, ..Default::default() };
    let mut current = align::canonicalize_software(software);

    for isax in isaxes {
        let aligned = align::align_isax(&isax.func)?;
        let round = matcher::match_isax(&current, &aligned, &isax.name, opts)?;
        stats.internal_rewrites += round.stats.internal_rewrites;
        stats.external_rewrites += round.stats.external_rewrites;
        stats.iterations += round.stats.iterations;
        stats.saturation_complete &= round.stats.saturation_complete;
        stats.node_budget_hit |= round.stats.node_budget_hit;
        stats.match_budget_hit |= round.stats.match_budget_hit;
        if stats.initial_enodes == 0 {
            stats.initial_enodes = round.stats.initial_enodes;
        }
        stats.saturated_enodes = stats.saturated_enodes.max(round.stats.saturated_enodes);
        if let Some(loop_ref) = round.matched_loop {
            current = lower::replace_loop_with_intrinsic(&current, loop_ref, &isax.name)?;
            stats.matched.push(isax.name.clone());
        }
    }
    // Mid-end: the extracted program reaches the VM through the pass
    // pipeline when requested. Matching already happened, so this only
    // cleans the residual software portions around the intrinsics.
    if opts.opt_level >= 2 {
        let (optimized, pstats) = crate::ir::passes::optimize_with_budget(
            &current,
            crate::ir::passes::OptLevel::O2,
            opts.budget.pass_rounds,
        )?;
        current = optimized;
        stats.pass_rounds_used = pstats.rounds;
        stats.pass_budget_hit = pstats.budget_hit;
    }
    Ok(CompileResult { func: current, stats })
}

/// Convenience used by tests/benches: a fresh e-graph with the standard
/// internal rule set pre-saturated over one function.
pub fn saturate_func(func: &Func, opts: &CompileOptions) -> (EGraph, encode::EncodeMap) {
    let mut g = EGraph::new();
    let map = encode::encode_func(&mut g, func);
    let runner = Runner {
        iter_limit: opts.budget.iter_limit,
        node_limit: opts.budget.node_limit,
        match_limit: opts.budget.match_limit,
    };
    let rs = rules::internal_rules();
    runner.run(&mut g, &rs);
    (g, map)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn budget_spec_parses_and_rejects_malformed_input() {
        let b = CompileBudget::parse("iters=4, nodes=20000 ,matches=500,external=2,rounds=8")
            .unwrap();
        assert_eq!(b.iter_limit, 4);
        assert_eq!(b.node_limit, 20_000);
        assert_eq!(b.match_limit, 500);
        assert_eq!(b.external_budget, 2);
        assert_eq!(b.pass_rounds, 8);
        // Empty spec and stray commas keep the defaults.
        assert_eq!(CompileBudget::parse("").unwrap(), CompileBudget::default());
        assert_eq!(CompileBudget::parse(" , ,").unwrap(), CompileBudget::default());
        // (input, expected fragment in the diagnostic)
        let table = [
            ("iters", "expected key=value"),
            ("iters=", "not a non-negative integer"),
            ("iters=abc", "not a non-negative integer"),
            ("iters=-1", "not a non-negative integer"),
            ("matches=0", "must be at least 1"),
            ("warp=9", "unknown key"),
        ];
        for (spec, want) in table {
            let err = CompileBudget::parse(spec).unwrap_err().to_string();
            assert!(err.contains(want), "{spec:?}: got {err:?}, want {want:?}");
        }
    }

    #[test]
    fn starved_budget_still_compiles_and_reports_exhaustion() {
        use crate::interface::cache::CacheHint;
        use crate::ir::builder::FuncBuilder;
        use crate::runtime::DType;
        // Software spelled with a shift; the ISAX multiplies. Matching
        // needs internal rewrites, which a zero-iteration budget forbids.
        let mk = |name: &str, shl: bool| {
            let mut b = FuncBuilder::new(name);
            let x = b.global("x", DType::I32, 16, CacheHint::Unknown);
            let y = b.global("y", DType::I32, 16, CacheHint::Unknown);
            b.for_range(0, 16, 1, |b, iv| {
                let v = b.load(x, iv);
                let w = if shl {
                    let two = b.const_i(2);
                    b.shl(v, two)
                } else {
                    let four = b.const_i(4);
                    b.mul(v, four)
                };
                b.store(y, iv, w);
            });
            b.finish(&[])
        };
        let software = mk("app", true);
        let isaxes = [IsaxDef { name: "vscale".into(), func: mk("vscale", false) }];
        let starved = CompileOptions {
            budget: CompileBudget { iter_limit: 0, external_budget: 0, ..Default::default() },
            opt_level: 2,
        };
        let r = compile(&software, &isaxes, &starved).unwrap();
        // No match under starvation, but the output is verified IR that
        // still runs — degradation, not failure.
        assert!(r.stats.matched.is_empty());
        assert!(!r.stats.saturation_complete);
        assert!(r.stats.budget_exhausted());
        crate::ir::verifier::verify(&r.func).unwrap();
        let mut mem = crate::ir::interp::Memory::for_func(&r.func);
        crate::ir::interp::run(&r.func, &[], &mut mem).unwrap();

        // A default budget on the same pair matches and is complete.
        let r = compile(&software, &isaxes, &CompileOptions::default()).unwrap();
        assert_eq!(r.stats.matched, vec!["vscale".to_string()]);
        assert!(r.stats.saturation_complete);
        assert!(!r.stats.budget_exhausted());
    }
}
