//! §5 — the retargetable compiler.
//!
//! Pipeline (Figure 5):
//! 1. **Semantic alignment** ([`align`]): ISAX descriptions are normalized
//!    from functional Aquas-IR down to the software abstraction level —
//!    register-file reads become parameters, transfers/scratchpads become
//!    direct global accesses. Software code is canonicalized (DCE/DSE)
//!    the way Polygeist + MLIR canonicalization would.
//! 2. **Fusing IR and e-graph** ([`encode`]): blocks become `tuple`
//!    e-nodes whose children are the *anchors* (side-effecting ops,
//!    terminators, control flow) in program order; pure dataflow forms
//!    subtrees beneath. Identical structures hashcons to identical
//!    classes, so ISAX and software fragments that become equivalent
//!    *collapse into the same e-class*.
//! 3. **Hybrid rewriting** ([`rules`] internal / [`loop_passes`] external):
//!    algebraic egglog-style rules saturate the dataflow space, while
//!    loop transformations (unroll/tile/coalesce) run as IR passes on
//!    extracted variants whose results are unioned back — triggered
//!    selectively by ISAX loop analysis to suppress blowup.
//! 4. **Skeleton-components matching** ([`matcher`]): each ISAX splits
//!    into a loop-nest skeleton + dataflow components; components tag
//!    matching e-classes with marker e-nodes, then the skeleton engine
//!    validates structure/order/effects and tags the loop class with an
//!    ISAX marker.
//! 5. **Lowering** ([`lower`]): tagged loops are replaced by `isax.<name>`
//!    intrinsics; the rest of the program is untouched.

pub mod align;
pub mod encode;
pub mod loop_passes;
pub mod lower;
pub mod matcher;
pub mod rules;

use crate::egraph::{EGraph, Runner};
use crate::error::Result;
use crate::ir::Func;

/// An ISAX available for offloading: its name plus the *functional-level*
/// description (the same IR the synthesis flow consumes).
#[derive(Debug, Clone)]
pub struct IsaxDef {
    pub name: String,
    pub func: Func,
}

/// Compilation statistics (Table 3).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CompileStats {
    pub internal_rewrites: usize,
    pub external_rewrites: usize,
    pub initial_enodes: usize,
    pub saturated_enodes: usize,
    pub iterations: usize,
    pub matched: Vec<String>,
}

/// Result of compiling one software function against an ISAX library.
#[derive(Debug, Clone)]
pub struct CompileResult {
    /// The lowered program (matched loops replaced by intrinsics).
    pub func: Func,
    pub stats: CompileStats,
}

/// Compiler configuration.
#[derive(Debug, Clone)]
pub struct CompileOptions {
    /// Saturation iteration limit per round.
    pub iter_limit: usize,
    /// E-graph node budget (§5.3: "suppressing e-graph blowup").
    pub node_limit: usize,
    /// Maximum external (loop-pass) rewrites to attempt per ISAX.
    pub external_budget: usize,
    /// Mid-end effort applied to the lowered program after matching:
    /// `0` leaves the extracted IR untouched, `2` runs the full
    /// `ir::passes` pipeline (SCCP/CSE/LICM/sink/DCE) to a fixpoint.
    pub opt_level: u8,
}

impl Default for CompileOptions {
    fn default() -> Self {
        Self { iter_limit: 12, node_limit: 100_000, external_budget: 6, opt_level: 0 }
    }
}

/// Compile: offload every matching loop of `software` onto the ISAXs.
pub fn compile(
    software: &Func,
    isaxes: &[IsaxDef],
    opts: &CompileOptions,
) -> Result<CompileResult> {
    let mut stats = CompileStats::default();
    let mut current = align::canonicalize_software(software);

    for isax in isaxes {
        let aligned = align::align_isax(&isax.func)?;
        let round = matcher::match_isax(&current, &aligned, &isax.name, opts)?;
        stats.internal_rewrites += round.stats.internal_rewrites;
        stats.external_rewrites += round.stats.external_rewrites;
        stats.iterations += round.stats.iterations;
        if stats.initial_enodes == 0 {
            stats.initial_enodes = round.stats.initial_enodes;
        }
        stats.saturated_enodes = stats.saturated_enodes.max(round.stats.saturated_enodes);
        if let Some(loop_ref) = round.matched_loop {
            current = lower::replace_loop_with_intrinsic(&current, loop_ref, &isax.name)?;
            stats.matched.push(isax.name.clone());
        }
    }
    // Mid-end: the extracted program reaches the VM through the pass
    // pipeline when requested. Matching already happened, so this only
    // cleans the residual software portions around the intrinsics.
    if opts.opt_level >= 2 {
        let (optimized, _) = crate::ir::passes::optimize(&current, crate::ir::passes::OptLevel::O2)?;
        current = optimized;
    }
    Ok(CompileResult { func: current, stats })
}

/// Convenience used by tests/benches: a fresh e-graph with the standard
/// internal rule set pre-saturated over one function.
pub fn saturate_func(func: &Func, opts: &CompileOptions) -> (EGraph, encode::EncodeMap) {
    let mut g = EGraph::new();
    let map = encode::encode_func(&mut g, func);
    let runner =
        Runner { iter_limit: opts.iter_limit, node_limit: opts.node_limit, ..Default::default() };
    let rs = rules::internal_rules();
    runner.run(&mut g, &rs);
    (g, map)
}
