//! §5.2 — encoding IR programs into the e-graph.
//!
//! Each operation maps to an e-node whose children are the e-classes of
//! its operands. Block structure is preserved by *anchors*: side-effecting
//! ops, terminators and structured control flow are collected — in exact
//! program order — as the children of a `tuple` e-node, while pure
//! dataflow hangs beneath the anchors as order-independent subtrees.
//!
//! Symbol canonicalization is what makes cross-program matching work:
//! - induction variables encode by loop depth (`iv:0`, `iv:1`, …);
//! - loop-carried values by depth + position (`carry:0:0`);
//! - function parameters positionally (`param:0`);
//! - buffers by order of first memory access (`m0`, `m1`, …), so an ISAX
//!   reading `H` then `e` aligns with software reading `mat` then `vec`.
//!
//! Two structurally identical fragments therefore hashcons to the *same
//! e-class*, and fragments that become equal under rewriting are unioned
//! by saturation — the matcher then just compares class ids.

use std::collections::HashMap;

use crate::egraph::{ClassId, EGraph, ENode};
use crate::ir::func::{BufferId, Func, OpRef, Region, Value};
use crate::ir::ops::{CmpPred, OpKind};

/// Artifacts of encoding one function.
#[derive(Debug, Clone, Default)]
pub struct EncodeMap {
    /// Class of each encoded op that produces one (anchors + dataflow).
    pub op_class: HashMap<OpRef, ClassId>,
    /// Class of each SSA value.
    pub value_class: HashMap<Value, ClassId>,
    /// Root class of the entry region's tuple.
    pub root: Option<ClassId>,
    /// Buffer slot numbering used (buffer -> m<slot>).
    pub buf_slot: HashMap<BufferId, usize>,
    /// Classes of every `for` op, with nesting depth.
    pub loops: Vec<(OpRef, ClassId, usize)>,
}

/// Encode a function into `g`. Repeated calls share symbols and classes.
pub fn encode_func(g: &mut EGraph, func: &Func) -> EncodeMap {
    let mut ctx = Ctx {
        g,
        func,
        map: EncodeMap::default(),
        depth: 0,
        scratch: String::with_capacity(32),
    };
    for (i, &p) in func.params.iter().enumerate() {
        let c = ctx.named(format_args!("param:{i}"), vec![]);
        ctx.map.value_class.insert(p, c);
    }
    // Buffer slots are scoped per *top-level anchor*: each top-level loop
    // numbers the buffers it touches from zero. This lets one ISAX match
    // any loop of a multi-kernel program regardless of how many buffers
    // earlier kernels used. (Dataflow classes still flow across loops via
    // value_class; only the load/store symbol naming is scoped.)
    let mut anchors = Vec::new();
    let entry = func.entry.clone();
    for &opref in &entry.ops {
        ctx.map.buf_slot.clear();
        if let Some(c) = ctx.op(opref) {
            if func.op(opref).kind.is_anchor() {
                anchors.push(c);
            }
        }
    }
    let root = ctx.g.add_named("tuple", anchors);
    let mut map = ctx.map;
    map.root = Some(root);
    map
}

struct Ctx<'a> {
    g: &'a mut EGraph,
    func: &'a Func,
    map: EncodeMap,
    depth: usize,
    /// Reused buffer for formatted symbol names — encoding allocates no
    /// fresh `String` per op.
    scratch: String,
}

impl<'a> Ctx<'a> {
    /// Add a node whose symbol is a formatted name, via the scratch
    /// buffer (no per-op `format!` allocation).
    fn named(&mut self, args: std::fmt::Arguments<'_>, children: Vec<ClassId>) -> ClassId {
        use std::fmt::Write;
        self.scratch.clear();
        // Writing into a String cannot fail; swallow the Result to stay
        // panic-free under the module-wide unwrap/expect deny.
        let _ = self.scratch.write_fmt(args);
        let sym = self.g.sym(&self.scratch);
        self.g.add(ENode { sym, children })
    }

    fn slot(&mut self, b: BufferId) -> usize {
        let next = self.map.buf_slot.len();
        *self.map.buf_slot.entry(b).or_insert(next)
    }

    fn value(&self, v: Value) -> ClassId {
        *self
            .map
            .value_class
            .get(&v)
            .unwrap_or_else(|| panic!("value {v} encoded out of order"))
    }

    /// Encode a region; returns its tuple class (anchors in order).
    fn region(&mut self, region: &Region) -> ClassId {
        let mut anchors = Vec::new();
        for &opref in &region.ops {
            if let Some(c) = self.op(opref) {
                let op = self.func.op(opref);
                if op.kind.is_anchor() {
                    anchors.push(c);
                }
            }
        }
        self.g.add_named("tuple", anchors)
    }

    /// Encode one op; returns its class if it has a representation.
    fn op(&mut self, opref: OpRef) -> Option<ClassId> {
        let op = self.func.op(opref).clone();
        let class = match &op.kind {
            OpKind::ConstI(v) => self.named(format_args!("const:{v}"), vec![]),
            OpKind::ConstF(v) => self.named(format_args!("constf:{v}"), vec![]),
            OpKind::Add
            | OpKind::Sub
            | OpKind::Mul
            | OpKind::Div
            | OpKind::Rem
            | OpKind::Shl
            | OpKind::Shr
            | OpKind::And
            | OpKind::Or
            | OpKind::Xor
            | OpKind::Min
            | OpKind::Max
            | OpKind::Neg
            | OpKind::Select
            | OpKind::Sqrt
            | OpKind::Exp
            | OpKind::ToFloat
            | OpKind::ToInt => {
                let kids: Vec<ClassId> = op.operands.iter().map(|&v| self.value(v)).collect();
                self.g.add_named(op.kind.mnemonic(), kids)
            }
            OpKind::Powi(e) => {
                let kids: Vec<ClassId> = op.operands.iter().map(|&v| self.value(v)).collect();
                self.named(format_args!("powi:{e}"), kids)
            }
            OpKind::Cmp(pred) => {
                let kids: Vec<ClassId> = op.operands.iter().map(|&v| self.value(v)).collect();
                let name = match pred {
                    CmpPred::Eq => "cmp:eq",
                    CmpPred::Ne => "cmp:ne",
                    CmpPred::Lt => "cmp:lt",
                    CmpPred::Le => "cmp:le",
                    CmpPred::Gt => "cmp:gt",
                    CmpPred::Ge => "cmp:ge",
                };
                self.g.add_named(name, kids)
            }
            OpKind::Load(b) | OpKind::ReadSmem(b) | OpKind::Fetch(b) => {
                let slot = self.slot(*b);
                let idx = self.value(op.operands[0]);
                self.named(format_args!("load:m{slot}"), vec![idx])
            }
            OpKind::LoadItfc { buf, .. } => {
                let slot = self.slot(*buf);
                let idx = self.value(op.operands[0]);
                self.named(format_args!("load:m{slot}"), vec![idx])
            }
            OpKind::Store(b) | OpKind::WriteSmem(b) => {
                let slot = self.slot(*b);
                let idx = self.value(op.operands[0]);
                let val = self.value(op.operands[1]);
                self.named(format_args!("store:m{slot}"), vec![idx, val])
            }
            OpKind::StoreItfc { buf, .. } => {
                let slot = self.slot(*buf);
                let idx = self.value(op.operands[0]);
                let val = self.value(op.operands[1]);
                self.named(format_args!("store:m{slot}"), vec![idx, val])
            }
            OpKind::ReadIrf(r) => self.named(format_args!("irf:{r}"), vec![]),
            OpKind::WriteIrf(r) => {
                let val = self.value(op.operands[0]);
                self.named(format_args!("wirf:{r}"), vec![val])
            }
            OpKind::Transfer { dst, src, size } => {
                let ds = self.slot(*dst);
                let ss = self.slot(*src);
                let kids: Vec<ClassId> = op.operands.iter().map(|&v| self.value(v)).collect();
                self.named(format_args!("transfer:m{ds}:m{ss}:{size}"), kids)
            }
            OpKind::Copy { .. } | OpKind::CopyIssue { .. } | OpKind::CopyWait { .. } => {
                // Post-binding ops never reach the compiler path.
                self.g.add_named("hw-op", vec![])
            }
            OpKind::For => {
                // children: [lb, ub, step, init..., body-tuple]
                let mut kids: Vec<ClassId> =
                    op.operands.iter().map(|&v| self.value(v)).collect();
                let region = &op.regions[0];
                let iv = region.params[0];
                let depth = self.depth;
                let ivc = self.named(format_args!("iv:{depth}"), vec![]);
                self.map.value_class.insert(iv, ivc);
                for (i, &c) in region.params[1..].iter().enumerate() {
                    let cc = self.named(format_args!("carry:{depth}:{i}"), vec![]);
                    self.map.value_class.insert(c, cc);
                }
                self.depth += 1;
                let body = self.region(region);
                self.depth -= 1;
                kids.push(body);
                let c = self.g.add_named("for", kids);
                // Loop results: represent as projections of the loop.
                for (i, &r) in op.results.iter().enumerate() {
                    let proj = self.named(format_args!("for-out:{i}"), vec![c]);
                    self.map.value_class.insert(r, proj);
                }
                self.map.loops.push((opref, c, self.depth));
                c
            }
            OpKind::If => {
                let cond = self.value(op.operands[0]);
                let then_t = self.region(&op.regions[0]);
                let else_t = self.region(&op.regions[1]);
                let c = self.g.add_named("if", vec![cond, then_t, else_t]);
                for (i, &r) in op.results.iter().enumerate() {
                    let proj = self.named(format_args!("if-out:{i}"), vec![c]);
                    self.map.value_class.insert(r, proj);
                }
                c
            }
            OpKind::Yield => {
                let kids: Vec<ClassId> = op.operands.iter().map(|&v| self.value(v)).collect();
                self.g.add_named("yield", kids)
            }
            OpKind::Return => {
                let kids: Vec<ClassId> = op.operands.iter().map(|&v| self.value(v)).collect();
                self.g.add_named("return", kids)
            }
            OpKind::Intrinsic(name) => {
                let kids: Vec<ClassId> = op.operands.iter().map(|&v| self.value(v)).collect();
                self.named(format_args!("isax:{name}"), kids)
            }
        };
        for &r in &op.results {
            self.map.value_class.entry(r).or_insert(class);
        }
        self.map.op_class.insert(opref, class);
        Some(class)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::interface::cache::CacheHint;
    use crate::ir::builder::FuncBuilder;
    use crate::runtime::DType;

    fn simple_loop(name: &str, mul_by: i64) -> Func {
        let mut b = FuncBuilder::new(name);
        let x = b.global("x", DType::I32, 16, CacheHint::Unknown);
        let y = b.global("y", DType::I32, 16, CacheHint::Unknown);
        b.for_range(0, 16, 1, |b, iv| {
            let v = b.load(x, iv);
            let k = b.const_i(mul_by);
            let w = b.mul(v, k);
            b.store(y, iv, w);
        });
        b.finish(&[])
    }

    #[test]
    fn identical_programs_share_classes() {
        let f1 = simple_loop("a", 3);
        let f2 = simple_loop("b", 3);
        let mut g = EGraph::new();
        let m1 = encode_func(&mut g, &f1);
        let m2 = encode_func(&mut g, &f2);
        // Hashcons: structurally identical functions collapse entirely.
        assert_eq!(g.find(m1.root.unwrap()), g.find(m2.root.unwrap()));
    }

    #[test]
    fn different_constants_differ() {
        let f1 = simple_loop("a", 3);
        let f2 = simple_loop("b", 5);
        let mut g = EGraph::new();
        let m1 = encode_func(&mut g, &f1);
        let m2 = encode_func(&mut g, &f2);
        assert_ne!(g.find(m1.root.unwrap()), g.find(m2.root.unwrap()));
    }

    #[test]
    fn anchors_keep_program_order() {
        // store A then store B != store B then store A
        let build = |flip: bool| {
            let mut b = FuncBuilder::new("o");
            let x = b.global("x", DType::I32, 4, CacheHint::Unknown);
            let i0 = b.const_i(0);
            let i1 = b.const_i(1);
            let va = b.const_i(10);
            let vb = b.const_i(20);
            if flip {
                b.store(x, i1, vb);
                b.store(x, i0, va);
            } else {
                b.store(x, i0, va);
                b.store(x, i1, vb);
            }
            b.finish(&[])
        };
        let mut g = EGraph::new();
        let m1 = encode_func(&mut g, &build(false));
        let m2 = encode_func(&mut g, &build(true));
        assert_ne!(g.find(m1.root.unwrap()), g.find(m2.root.unwrap()));
    }

    #[test]
    fn buffer_slots_align_by_first_use() {
        // Same structure, different buffer declaration order: slots are
        // assigned by first *use*, so the programs still collapse.
        let f1 = simple_loop("a", 3);
        let f2 = {
            let mut b = FuncBuilder::new("b");
            let y = b.global("unrelated_name", DType::I32, 16, CacheHint::Cold);
            let x = b.global("other", DType::I32, 16, CacheHint::Warm);
            let _ = (x, y);
            // use y first in the load position like f1 uses x
            b.for_range(0, 16, 1, |b, iv| {
                let v = b.load(y, iv);
                let k = b.const_i(3);
                let w = b.mul(v, k);
                b.store(x, iv, w);
            });
            b.finish(&[])
        };
        let mut g = EGraph::new();
        let m1 = encode_func(&mut g, &f1);
        let m2 = encode_func(&mut g, &f2);
        assert_eq!(g.find(m1.root.unwrap()), g.find(m2.root.unwrap()));
    }

    #[test]
    fn loops_recorded_with_depth() {
        let mut b = FuncBuilder::new("nest");
        let x = b.global("x", DType::I32, 64, CacheHint::Unknown);
        b.for_range(0, 4, 1, |b, i| {
            b.for_range(0, 16, 1, |b, j| {
                let v = b.load(x, j);
                b.store(x, i, v);
            });
        });
        let f = b.finish(&[]);
        let mut g = EGraph::new();
        let m = encode_func(&mut g, &f);
        assert_eq!(m.loops.len(), 2);
        let depths: Vec<usize> = m.loops.iter().map(|&(_, _, d)| d).collect();
        assert!(depths.contains(&0) && depths.contains(&1));
    }
}
