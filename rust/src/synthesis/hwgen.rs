//! §4.3 step 4 — hardware generation.
//!
//! After scheduling is fixed, each ISAX becomes a dynamic pipeline
//! following transactional semantics (Hoe & Arvind [10]): one stage per
//! phase (decode → stage-in → compute loop → stage-out → writeback), with
//! arbitration inserted wherever two transactions contend for a resource,
//! backend adapters for the instruction-extension interface, memory-access
//! engines per memory interface (protocol conversion, burst handling,
//! misaligned-request fallback), and multi-banked SRAM for explicit
//! scratchpads.
//!
//! The paper lowers to FIRRTL/SystemVerilog through CIRCT; this module
//! produces the same *structural* information — a [`PipelineDesc`]
//! consumed by the area/timing model ([`crate::area`]) and the ISAX cycle
//! engine — plus a structural Verilog-subset rendering for inspection.

use std::fmt::Write as _;

use crate::interface::model::InterfaceSet;
use crate::ir::func::{BufferKind, Func};
use crate::ir::ops::OpKind;
use crate::synthesis::SynthResult;

/// One pipeline stage of the generated execution unit.
#[derive(Debug, Clone, PartialEq)]
pub struct StageDesc {
    /// Stage name (decode / stage_in / compute / stage_out / writeback).
    pub name: String,
    /// Functional units instantiated in this stage.
    pub fus: FuCount,
    /// Arbitration points (shared-resource muxes) inserted in this stage.
    pub arbiters: usize,
}

/// Functional-unit census of a stage (drives the area model).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FuCount {
    /// Integer/float adders (also used for subtraction).
    pub adders: usize,
    /// Multiplier instances.
    pub multipliers: usize,
    /// Divider/remainder units.
    pub dividers: usize,
    /// Barrel shifters.
    pub shifters: usize,
    /// Bitwise-logic / select muxes.
    pub logic: usize,
    /// Comparators (min/max/cmp).
    pub comparators: usize,
    /// Transcendental FP helpers (sqrt/exp/powi).
    pub fp_units: usize,
}

impl FuCount {
    /// Total functional units across all classes.
    pub fn total(&self) -> usize {
        self.adders + self.multipliers + self.dividers + self.shifters + self.logic
            + self.comparators
            + self.fp_units
    }
}

/// A synthesized scratchpad memory.
#[derive(Debug, Clone, PartialEq)]
pub struct SramDesc {
    /// Scratchpad name from the IR buffer declaration.
    pub name: String,
    /// Capacity in bytes.
    pub bytes: usize,
    /// Bank count (= beats accepted per cycle; see
    /// [`crate::interface::dmasim`] for the conflict model it feeds).
    pub banks: usize,
}

/// A memory-access engine for one interface.
#[derive(Debug, Clone, PartialEq)]
pub struct MemEngineDesc {
    /// Name of the interface this engine drives.
    pub itfc_name: String,
    /// Beat width in bytes.
    pub width: usize,
    /// Whether the engine issues multi-beat bursts.
    pub burst: bool,
    /// Outstanding-transaction tracker depth.
    pub tracker_depth: usize,
    /// Has the misaligned-request runtime fallback path.
    pub misalign_fallback: bool,
}

/// The generated execution unit, structurally.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineDesc {
    /// Unit name (derived from the ISAX function).
    pub name: String,
    /// Pipeline stages in execution order.
    pub stages: Vec<StageDesc>,
    /// Synthesized scratchpad memories.
    pub srams: Vec<SramDesc>,
    /// Per-interface memory-access engines.
    pub engines: Vec<MemEngineDesc>,
    /// Pipeline initiation interval of the compute loop (II).
    pub initiation_interval: u64,
    /// Compute datapath depth (critical path in FU levels).
    pub datapath_depth: u64,
}

/// Generate the pipeline description from a synthesis result.
pub fn generate(synth: &SynthResult, itfcs: &InterfaceSet) -> PipelineDesc {
    let func = &synth.temporal;
    // Dead ops must not cost silicon: the FU census and the datapath
    // depth are taken on a DCE-swept clone of the temporal IR, so
    // whatever dead index math survived scheduling never instantiates an
    // FU or stretches the reported critical path. Everything else
    // (schedule items, scratchpad liveness, interface usage) is computed
    // from symbolic/anchor ops DCE never touches, so the original
    // function serves those paths unchanged.
    let mut swept = func.clone();
    let mut an = crate::ir::passes::analysis::Analyses::new();
    crate::ir::passes::dce::run(&mut swept, &mut an);
    let fus = census(&swept);
    let depth = datapath_depth(&swept);

    // Stage-in/out arbitration: one arbiter per interface with >1
    // transactions contending (issue slots are a shared resource).
    let mut per_itfc_txns = vec![0usize; itfcs.len()];
    for item in &synth.schedule.items {
        per_itfc_txns[item.itfc.0] += 1;
    }
    let arbiters = per_itfc_txns.iter().filter(|&&n| n > 1).count();

    let stages = vec![
        StageDesc { name: "decode".into(), fus: FuCount::default(), arbiters: 0 },
        StageDesc { name: "stage_in".into(), fus: FuCount::default(), arbiters },
        StageDesc { name: "compute".into(), fus, arbiters: 0 },
        StageDesc {
            name: "stage_out".into(),
            fus: FuCount::default(),
            arbiters: arbiters.min(1),
        },
        StageDesc { name: "writeback".into(), fus: FuCount::default(), arbiters: 0 },
    ];

    // Scratchpads that survived elision and are still referenced.
    let srams = func
        .buffers
        .iter()
        .enumerate()
        .filter_map(|(i, b)| match b.kind {
            BufferKind::Scratchpad { banks } => {
                let bid = crate::ir::func::BufferId(i as u32);
                let used = func.count_ops(|k| match k {
                    OpKind::ReadSmem(x) | OpKind::WriteSmem(x) => *x == bid,
                    OpKind::Copy { dst, src, .. } | OpKind::CopyIssue { dst, src, .. } => {
                        *dst == bid || *src == bid
                    }
                    OpKind::Transfer { dst, src, .. } => *dst == bid || *src == bid,
                    _ => false,
                }) > 0;
                used.then(|| SramDesc { name: b.name.clone(), bytes: b.size_bytes(), banks })
            }
            _ => None,
        })
        .collect();

    // One memory engine per interface actually used by the schedule (plus
    // scalar load/store paths).
    let mut used = vec![false; itfcs.len()];
    for item in &synth.schedule.items {
        used[item.itfc.0] = true;
    }
    func.walk(|_, op| match op.kind {
        OpKind::LoadItfc { itfc, .. } | OpKind::StoreItfc { itfc, .. } => used[itfc.0] = true,
        _ => {}
    });
    let engines = itfcs
        .iter()
        .filter(|(k, _)| used[k.0])
        .map(|(_, m)| MemEngineDesc {
            itfc_name: m.name.clone(),
            width: m.width,
            burst: m.max_beats > 1,
            tracker_depth: m.in_flight,
            misalign_fallback: true,
        })
        .collect();

    PipelineDesc {
        name: func.name.clone(),
        stages,
        srams,
        engines,
        initiation_interval: 1,
        datapath_depth: depth,
    }
}

/// Count functional units: hardware instantiates one FU per op occurrence
/// inside the compute loops (the datapath is fully spatial; arbitration
/// resolves scratchpad port conflicts).
fn census(func: &Func) -> FuCount {
    let mut fus = FuCount::default();
    func.walk(|_, op| match &op.kind {
        OpKind::Add | OpKind::Sub => fus.adders += 1,
        OpKind::Mul => fus.multipliers += 1,
        OpKind::Div | OpKind::Rem => fus.dividers += 1,
        OpKind::Shl | OpKind::Shr => fus.shifters += 1,
        OpKind::And | OpKind::Or | OpKind::Xor | OpKind::Select => fus.logic += 1,
        OpKind::Min | OpKind::Max | OpKind::Cmp(_) => fus.comparators += 1,
        OpKind::Sqrt | OpKind::Exp | OpKind::Powi(_) => fus.fp_units += 1,
        _ => {}
    });
    fus
}

/// Critical-path depth of the compute dataflow (longest def-use chain
/// through non-memory ops), in FU levels.
fn datapath_depth(func: &Func) -> u64 {
    use std::collections::HashMap;
    let mut depth: HashMap<crate::ir::func::Value, u64> = HashMap::new();
    let mut max_depth = 0u64;
    // Structured IR: one forward pass suffices (defs precede uses
    // lexically); loop-carried deps add one level via region params.
    func.walk(|_, op| {
        let in_depth =
            op.operands.iter().map(|v| depth.get(v).copied().unwrap_or(0)).max().unwrap_or(0);
        let cost: u64 = match &op.kind {
            OpKind::Add | OpKind::Sub | OpKind::Min | OpKind::Max | OpKind::Cmp(_)
            | OpKind::And | OpKind::Or | OpKind::Xor | OpKind::Select | OpKind::Shl
            | OpKind::Shr => 1,
            OpKind::Mul => 2,
            OpKind::Div | OpKind::Rem | OpKind::Sqrt | OpKind::Exp => 8,
            OpKind::Powi(e) => 2 * (*e as u64).max(1),
            _ => 0,
        };
        for &r in &op.results {
            depth.insert(r, in_depth + cost);
            max_depth = max_depth.max(in_depth + cost);
        }
    });
    max_depth
}

/// Render the pipeline as a structural Verilog subset (inspection only).
pub fn to_verilog(desc: &PipelineDesc) -> String {
    let mut v = String::new();
    let _ = writeln!(v, "// Generated by aquas hwgen — structural skeleton");
    let _ = writeln!(v, "module isax_{} (", sanitize(&desc.name));
    let _ = writeln!(v, "  input  wire        clk,");
    let _ = writeln!(v, "  input  wire        rst_n,");
    let _ = writeln!(v, "  input  wire [31:0] cmd_inst,");
    let _ = writeln!(v, "  input  wire [63:0] cmd_rs1,");
    let _ = writeln!(v, "  input  wire [63:0] cmd_rs2,");
    let _ = writeln!(v, "  output wire [63:0] resp_data,");
    let _ = writeln!(v, "  output wire        resp_valid");
    for e in &desc.engines {
        let w = e.width * 8;
        let n = sanitize(&e.itfc_name);
        let _ = writeln!(v, "  , output wire [{:>2}:0] {n}_req_addr", 39);
        let _ = writeln!(v, "  , output wire [{:>2}:0] {n}_req_data", w - 1);
        let _ = writeln!(v, "  , input  wire [{:>2}:0] {n}_resp_data", w - 1);
        let _ = writeln!(v, "  , output wire        {n}_req_valid");
        let _ = writeln!(v, "  , input  wire        {n}_req_ready");
    }
    let _ = writeln!(v, ");");
    for s in &desc.srams {
        let _ = writeln!(
            v,
            "  // scratchpad {}: {} bytes, {} bank(s)",
            s.name, s.bytes, s.banks
        );
        for bank in 0..s.banks {
            let words = s.bytes / 4 / s.banks.max(1);
            let _ = writeln!(
                v,
                "  reg [31:0] {}_bank{} [0:{}];",
                sanitize(&s.name),
                bank,
                words.saturating_sub(1)
            );
        }
    }
    for (i, st) in desc.stages.iter().enumerate() {
        let _ = writeln!(
            v,
            "  // stage {i} `{}`: {} FUs, {} arbiter(s)",
            st.name,
            st.fus.total(),
            st.arbiters
        );
        let _ = writeln!(v, "  reg stage{i}_valid;");
    }
    let _ = writeln!(
        v,
        "  // compute: II={} depth={}",
        desc.initiation_interval, desc.datapath_depth
    );
    let _ = writeln!(v, "endmodule");
    v
}

fn sanitize(name: &str) -> String {
    name.chars().map(|c| if c.is_alphanumeric() { c } else { '_' }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interface::cache::CacheHint;
    use crate::interface::model::InterfaceSet;
    use crate::ir::builder::FuncBuilder;
    use crate::runtime::DType;
    use crate::synthesis::{synthesize, SynthOptions};

    fn demo_synth() -> (SynthResult, InterfaceSet) {
        let mut b = FuncBuilder::new("fir7");
        let src = b.global("src", DType::F32, 27, CacheHint::Cold);
        let coef = b.global("coef", DType::F32, 7, CacheHint::Warm);
        let out = b.global("out", DType::F32, 21, CacheHint::Warm);
        let s_src = b.scratchpad("s_src", DType::F32, 27, 2);
        let s_coef = b.scratchpad("s_coef", DType::F32, 7, 1);
        let zero = b.const_i(0);
        b.transfer(s_src, zero, src, zero, 108);
        b.transfer(s_coef, zero, coef, zero, 28);
        b.for_range(0, 21, 1, |b, i| {
            let init = b.const_f(0.0);
            let lb = b.const_i(0);
            let ub = b.const_i(7);
            let one = b.const_i(1);
            let acc = b.for_loop(lb, ub, one, &[init], |b, j, c| {
                let idx = b.add(i, j);
                let x = b.read_smem(s_src, idx);
                let w = b.read_smem(s_coef, j);
                let m = b.mul(x, w);
                vec![b.add(c[0], m)]
            });
            b.store(out, i, acc[0]);
        });
        let f = b.finish(&[]);
        let itfcs = InterfaceSet::rocket_default();
        let r = synthesize(&f, &itfcs, &SynthOptions::default()).unwrap();
        (r, itfcs)
    }

    #[test]
    fn census_ignores_dead_ops() {
        use crate::ir::ops::Op;
        use crate::ir::types::Type;
        let (r, itfcs) = demo_synth();
        let clean = generate(&r, &itfcs);
        // Lard the temporal IR with a dead const/mul/div chain: none of
        // it may instantiate an FU or stretch the datapath depth.
        let mut dirty = r.clone();
        let f = &mut dirty.temporal;
        let c = f.new_value(Type::Int);
        let cop = f.add_op(Op::new(OpKind::ConstI(6), vec![], vec![c]));
        let m = f.new_value(Type::Int);
        let mop = f.add_op(Op::new(OpKind::Mul, vec![c, c], vec![m]));
        let d = f.new_value(Type::Int);
        let dop = f.add_op(Op::new(OpKind::Div, vec![m, c], vec![d]));
        f.entry.ops.splice(0..0, [cop, mop, dop]);
        crate::ir::verifier::verify(&dirty.temporal).unwrap();
        let desc = generate(&dirty, &itfcs);
        assert_eq!(desc.stages, clean.stages, "dead ops leaked into the FU census");
        assert_eq!(
            desc.datapath_depth, clean.datapath_depth,
            "dead ops stretched the reported critical path"
        );
    }

    #[test]
    fn generates_five_stage_pipeline() {
        let (r, itfcs) = demo_synth();
        let desc = generate(&r, &itfcs);
        assert_eq!(desc.stages.len(), 5);
        assert!(desc.datapath_depth >= 3, "mul+add chain, got {}", desc.datapath_depth);
        assert!(!desc.engines.is_empty());
    }

    #[test]
    fn srams_only_for_surviving_scratchpads() {
        let (r, itfcs) = demo_synth();
        let desc = generate(&r, &itfcs);
        for name in &r.elided {
            assert!(!desc.srams.iter().any(|s| &s.name == name), "{name} elided but has SRAM");
        }
    }

    #[test]
    fn verilog_contains_module_and_engines() {
        let (r, itfcs) = demo_synth();
        let desc = generate(&r, &itfcs);
        let v = to_verilog(&desc);
        assert!(v.contains("module isax_fir7"));
        assert!(v.contains("endmodule"));
        for e in &desc.engines {
            assert!(v.contains(&sanitize(&e.itfc_name)));
        }
    }

    #[test]
    fn banked_srams_render_per_bank() {
        let (r, itfcs) = demo_synth();
        let desc = generate(&r, &itfcs);
        if let Some(s) = desc.srams.iter().find(|s| s.banks == 2) {
            let v = to_verilog(&desc);
            assert!(v.contains(&format!("{}_bank0", sanitize(&s.name))));
            assert!(v.contains(&format!("{}_bank1", sanitize(&s.name))));
        }
    }
}
