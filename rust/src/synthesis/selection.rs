//! §4.3 step 2 — interface selection and canonicalization.
//!
//! Assigns each memory operation `q` to exactly one visible interface `k`
//! (binary `X(q,k)`), greedily splitting each request into legal transfer
//! sizes in decreasing order, minimizing
//!
//! ```text
//! min Σ_k T_k + Σ_{q,k} X(q,k) · ⌈m_q / C_k⌉ · C_k / W_k
//! ```
//!
//! where `T_k` is the closed-form latency estimate
//! ([`crate::interface::latency::tk_estimate`]) and the second term
//! penalizes cache-hierarchy mismatch (scaled by the `cache_hint` /
//! hierarchy-level agreement). Loads and stores are optimized separately
//! within a region, as in the paper.
//!
//! Below [`crate::synthesis::SynthOptions::exhaustive_limit`] ops the
//! assignment is solved exactly by enumeration; above it a greedy
//! marginal-cost heuristic is used.

use crate::error::{Error, Result};
use crate::interface::cache::cache_penalty;
use crate::interface::latency::TransactionKind;
use crate::interface::model::{InterfaceId, InterfaceSet, MemInterface};
use crate::ir::func::Func;
use crate::ir::ops::{Op, OpKind};
use crate::synthesis::memprobe::{MemOp, MemProbe};
use crate::synthesis::SynthOptions;

/// The chosen interface + canonicalized segment sizes for one memory op.
#[derive(Debug, Clone, PartialEq)]
pub struct Assignment {
    /// Memory-op id (index into [`MemProbe::ops`]).
    pub op: usize,
    /// The chosen interface.
    pub itfc: InterfaceId,
    /// Legal transfer sizes in issue order (decreasing, §4.3) for one
    /// execution of the op.
    pub segments: Vec<usize>,
}

/// Per-execution transfer cost of one op on one interface (the summand of
/// `T_k` without the per-interface lead constant), times trip count, plus
/// the cache-synchronization penalty.
fn op_cost(itfc: &MemInterface, op: &MemOp, segments: &[usize]) -> f64 {
    let w = itfc.width as f64;
    let per_exec: f64 = match op.kind {
        TransactionKind::Load => {
            let bubble = itfc.read_lead as f64 / itfc.in_flight.max(1) as f64;
            segments.iter().map(|&m| (m as f64 / w).max(bubble)).sum()
        }
        TransactionKind::Store => {
            segments.iter().map(|&m| m as f64 / w + itfc.write_cost as f64).sum()
        }
    };
    let total_bytes = op.bytes.saturating_mul(op.trips as usize);
    per_exec * op.trips as f64
        + cache_penalty(total_bytes, itfc.line, itfc.width, op.hint, itfc.level)
}

/// Full objective for a complete assignment of one direction's ops.
fn total_cost(
    ops: &[&MemOp],
    choice: &[usize],
    itfcs: &InterfaceSet,
    segments: &[Vec<Vec<usize>>],
) -> f64 {
    let mut cost = 0.0;
    for (kid, itfc) in itfcs.iter() {
        let assigned: Vec<usize> = (0..ops.len()).filter(|&q| choice[q] == kid.0).collect();
        if assigned.is_empty() {
            continue;
        }
        // Lead constant of T_k (applies once per direction per interface).
        let kind = ops[assigned[0]].kind;
        cost += match kind {
            TransactionKind::Load => itfc.read_lead as f64 - 1.0,
            TransactionKind::Store => -1.0,
        };
        for q in assigned {
            cost += op_cost(itfc, ops[q], &segments[q][kid.0]);
        }
    }
    cost
}

/// Solve the selection problem for every op in the probe.
pub fn select(
    probe: &MemProbe,
    itfcs: &InterfaceSet,
    opts: &SynthOptions,
) -> Result<Vec<Assignment>> {
    if itfcs.is_empty() {
        return Err(Error::Synthesis("no interfaces declared".into()));
    }
    let mut result: Vec<Option<Assignment>> = vec![None; probe.ops.len()];
    for kind in [TransactionKind::Load, TransactionKind::Store] {
        let ops: Vec<&MemOp> = probe.ops.iter().filter(|o| o.kind == kind).collect();
        if ops.is_empty() {
            continue;
        }
        // Precompute canonical decomposition of each op on each interface.
        let segments: Vec<Vec<Vec<usize>>> = ops
            .iter()
            .map(|o| {
                itfcs
                    .iter()
                    .map(|(_, itfc)| itfc.decompose(o.base_addr, o.bytes))
                    .collect()
            })
            .collect();

        let choice = if ops.len() <= opts.exhaustive_limit {
            exhaustive(&ops, itfcs, &segments)
        } else {
            greedy(&ops, itfcs, &segments)
        };
        for (q, op) in ops.iter().enumerate() {
            let k = choice[q];
            result[op.id] = Some(Assignment {
                op: op.id,
                itfc: InterfaceId(k),
                segments: segments[q][k].clone(),
            });
        }
    }
    result
        .into_iter()
        .enumerate()
        .map(|(i, a)| a.ok_or_else(|| Error::Synthesis(format!("op {i} unassigned"))))
        .collect()
}

fn exhaustive(ops: &[&MemOp], itfcs: &InterfaceSet, segments: &[Vec<Vec<usize>>]) -> Vec<usize> {
    let k = itfcs.len();
    let n = ops.len();
    let mut best: Vec<usize> = vec![0; n];
    let mut best_cost = f64::INFINITY;
    let mut choice = vec![0usize; n];
    // Odometer enumeration of k^n assignments.
    loop {
        let cost = total_cost(ops, &choice, itfcs, segments);
        if cost < best_cost {
            best_cost = cost;
            best = choice.clone();
        }
        // increment
        let mut i = 0;
        loop {
            if i == n {
                return best;
            }
            choice[i] += 1;
            if choice[i] < k {
                break;
            }
            choice[i] = 0;
            i += 1;
        }
    }
}

fn greedy(ops: &[&MemOp], itfcs: &InterfaceSet, segments: &[Vec<Vec<usize>>]) -> Vec<usize> {
    // Assign each op to its marginally-cheapest interface, processing big
    // movers first so they claim the wide port.
    let mut order: Vec<usize> = (0..ops.len()).collect();
    order.sort_by_key(|&q| std::cmp::Reverse(ops[q].bytes.saturating_mul(ops[q].trips as usize)));
    let mut choice = vec![usize::MAX; ops.len()];
    for q in order {
        let mut best_k = 0;
        let mut best_cost = f64::INFINITY;
        for (kid, itfc) in itfcs.iter() {
            let lead = match ops[q].kind {
                TransactionKind::Load => itfc.read_lead as f64 - 1.0,
                TransactionKind::Store => -1.0,
            };
            // Marginal: op cost plus the lead if this interface is unused.
            let unused = !choice.iter().any(|&c| c == kid.0);
            let cost =
                op_cost(itfc, ops[q], &segments[q][kid.0]) + if unused { lead } else { 0.0 };
            if cost < best_cost {
                best_cost = cost;
                best_k = kid.0;
            }
        }
        choice[q] = best_k;
    }
    choice
}

/// Lower functional memory ops to the architectural level using the
/// computed assignments: `transfer` becomes a run of interface-bound
/// `copy` ops (one per canonical segment, §4.3 Figure 4(b)); per-element
/// `fetch`/global `load`/`store` become `load_itfc`/`store_itfc`.
pub fn lower_to_architectural(
    func: &Func,
    probe: &MemProbe,
    assignments: &[Assignment],
) -> Result<Func> {
    let mut out = func.clone();

    for a in assignments {
        let mop = &probe.ops[a.op];
        let op = out.op(mop.opref).clone();
        match op.kind {
            OpKind::Transfer { dst, src, .. } => {
                // Build the copy run. Segment offsets accumulate.
                let mut new_refs = Vec::new();
                let mut delta = 0usize;
                for &m in &a.segments {
                    // offset values: original offset + delta
                    let (dst_off, src_off) = if delta == 0 {
                        (op.operands[0], op.operands[1])
                    } else {
                        let c = out.new_value(crate::ir::types::Type::Int);
                        let cref = out.add_op(Op::new(
                            OpKind::ConstI(delta as i64),
                            vec![],
                            vec![c],
                        ));
                        new_refs.push(cref);
                        let d = out.new_value(crate::ir::types::Type::Int);
                        let dref =
                            out.add_op(Op::new(OpKind::Add, vec![op.operands[0], c], vec![d]));
                        new_refs.push(dref);
                        let s = out.new_value(crate::ir::types::Type::Int);
                        let sref =
                            out.add_op(Op::new(OpKind::Add, vec![op.operands[1], c], vec![s]));
                        new_refs.push(sref);
                        (d, s)
                    };
                    let cp = out.add_op(Op::new(
                        OpKind::Copy { itfc: a.itfc, dst, src, size: m, kind: mop.kind },
                        vec![dst_off, src_off],
                        vec![],
                    ));
                    new_refs.push(cp);
                    delta += m;
                }
                replace_in_regions(&mut out, mop.opref, &new_refs)?;
            }
            OpKind::Fetch(b) | OpKind::Load(b) => {
                let o = out.op_mut(mop.opref);
                o.kind = OpKind::LoadItfc { itfc: a.itfc, buf: b };
            }
            OpKind::Store(b) => {
                let o = out.op_mut(mop.opref);
                o.kind = OpKind::StoreItfc { itfc: a.itfc, buf: b };
            }
            other => {
                return Err(Error::Synthesis(format!(
                    "cannot lower {} at op {}",
                    other.mnemonic(),
                    a.op
                )))
            }
        }
    }
    Ok(out)
}

/// Replace one opref with a run of oprefs wherever it appears.
fn replace_in_regions(
    func: &mut Func,
    target: crate::ir::func::OpRef,
    replacement: &[crate::ir::func::OpRef],
) -> Result<()> {
    // entry region
    if let Some(pos) = func.entry.ops.iter().position(|&o| o == target) {
        func.entry.ops.splice(pos..=pos, replacement.iter().copied());
        return Ok(());
    }
    // nested regions: find the op holding the region
    for i in 0..func.num_ops() {
        let opref = crate::ir::func::OpRef(i as u32);
        let op = func.op(opref);
        let mut found: Option<(usize, usize)> = None;
        for (ri, region) in op.regions.iter().enumerate() {
            if let Some(pos) = region.ops.iter().position(|&o| o == target) {
                found = Some((ri, pos));
                break;
            }
        }
        if let Some((ri, pos)) = found {
            let op = func.op_mut(opref);
            op.regions[ri].ops.splice(pos..=pos, replacement.iter().copied());
            return Ok(());
        }
    }
    Err(Error::Synthesis("op to replace not found in any region".into()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interface::cache::CacheHint;
    use crate::ir::builder::FuncBuilder;
    use crate::runtime::DType;
    use crate::synthesis::memprobe;

    /// Build the fir7 stage-in: a 108B src transfer plus an output store
    /// loop — the paper's running example.
    fn fir7_src() -> Func {
        let mut b = FuncBuilder::new("fir7");
        let src = b.global("src", DType::F32, 27, CacheHint::Cold);
        let out = b.global("out", DType::F32, 21, CacheHint::Warm);
        let s_src = b.scratchpad("s_src", DType::F32, 27, 1);
        let zero = b.const_i(0);
        b.transfer(s_src, zero, src, zero, 108);
        b.for_range(0, 21, 1, |b, iv| {
            let v = b.read_smem(s_src, iv);
            b.store(out, iv, v);
        });
        b.finish(&[])
    }

    #[test]
    fn large_cold_transfer_goes_to_bus() {
        let f = fir7_src();
        let itfcs = InterfaceSet::rocket_default();
        let probe = memprobe::extract(&f).unwrap();
        let assigns = select(&probe, &itfcs, &SynthOptions::default()).unwrap();
        // op 0 is the 108B src transfer: must pick the system bus and
        // canonicalize into 64/32/8/4 (paper Figure 4(b)).
        let a = &assigns[0];
        assert_eq!(itfcs.get(a.itfc).name, "@busitfc");
        assert_eq!(a.segments, vec![64, 32, 8, 4]);
    }

    #[test]
    fn small_warm_stores_stay_on_cpu_port() {
        let f = fir7_src();
        let itfcs = InterfaceSet::rocket_default();
        let probe = memprobe::extract(&f).unwrap();
        let assigns = select(&probe, &itfcs, &SynthOptions::default()).unwrap();
        // op 1: per-element warm stores — the L1-coupled core port is free
        // of cache penalty there.
        let a = &assigns[1];
        assert_eq!(itfcs.get(a.itfc).name, "@cpuitfc");
    }

    #[test]
    fn lowering_emits_copy_run() {
        let f = fir7_src();
        let itfcs = InterfaceSet::rocket_default();
        let probe = memprobe::extract(&f).unwrap();
        let assigns = select(&probe, &itfcs, &SynthOptions::default()).unwrap();
        let arch = lower_to_architectural(&f, &probe, &assigns).unwrap();
        assert_eq!(arch.count_ops(|k| matches!(k, OpKind::Transfer { .. })), 0);
        assert_eq!(arch.count_ops(|k| matches!(k, OpKind::Copy { .. })), 4);
        assert_eq!(arch.count_ops(|k| matches!(k, OpKind::StoreItfc { .. })), 1);
        crate::ir::verifier::verify(&arch).unwrap();
    }

    #[test]
    fn lowering_preserves_semantics() {
        use crate::ir::interp::{run as interp, Memory};
        let f = fir7_src();
        let itfcs = InterfaceSet::rocket_default();
        let probe = memprobe::extract(&f).unwrap();
        let assigns = select(&probe, &itfcs, &SynthOptions::default()).unwrap();
        let arch = lower_to_architectural(&f, &probe, &assigns).unwrap();

        let data: Vec<f32> = (0..27).map(|i| (i * 3) as f32).collect();
        let mut m1 = Memory::for_func(&f);
        m1.write_f32(crate::ir::func::BufferId(0), &data);
        interp(&f, &[], &mut m1).unwrap();
        let mut m2 = Memory::for_func(&arch);
        m2.write_f32(crate::ir::func::BufferId(0), &data);
        interp(&arch, &[], &mut m2).unwrap();
        assert_eq!(
            m1.read_f32(crate::ir::func::BufferId(1)),
            m2.read_f32(crate::ir::func::BufferId(1))
        );
    }

    #[test]
    fn greedy_matches_exhaustive_on_small_problems() {
        let f = fir7_src();
        let itfcs = InterfaceSet::rocket_default();
        let probe = memprobe::extract(&f).unwrap();
        let ex = select(&probe, &itfcs, &SynthOptions::default()).unwrap();
        let gr = select(
            &probe,
            &itfcs,
            &SynthOptions { exhaustive_limit: 0, ..Default::default() },
        )
        .unwrap();
        let ex_itfcs: Vec<_> = ex.iter().map(|a| a.itfc).collect();
        let gr_itfcs: Vec<_> = gr.iter().map(|a| a.itfc).collect();
        assert_eq!(ex_itfcs, gr_itfcs);
    }
}
