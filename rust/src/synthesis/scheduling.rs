//! §4.3 step 3 — transaction scheduling and ordering.
//!
//! Lowers architectural-level transfers to the temporal level by choosing
//! the transaction order that minimizes completion time under the
//! in-flight limit `I_k` and cache-hierarchy constraints:
//!
//! - transfers are grouped by hierarchy level: **reads** closer to the top
//!   of the hierarchy issue earlier (cold data must not evict hot data);
//!   **writes** closer to the bottom issue earlier (hot data stays cached
//!   longer);
//! - decomposed segments of one memory operation remain contiguous;
//! - within those constraints, a memoized search finds the minimal-latency
//!   order per interface. The memo key compresses the exploration state
//!   into a *relative timing window* (the last `I_k` completion cycles
//!   minus the last issue cycle), exploiting the §4.1 recurrences'
//!   insensitivity to global time translation.

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::interface::cache::CacheHint;
use crate::interface::dmasim::{self, SimOutcome, SimTxn};
use crate::interface::latency::TransactionKind;
use crate::interface::model::{InterfaceId, InterfaceSet, MemInterface};
use crate::ir::func::Func;
use crate::ir::ops::{Op, OpKind};
use crate::synthesis::memprobe::MemProbe;
use crate::synthesis::selection::Assignment;

/// One scheduled (issued) transaction.
#[derive(Debug, Clone, PartialEq)]
pub struct SchedItem {
    /// Memory-op id this segment belongs to.
    pub op: usize,
    /// Interface the transaction issues on.
    pub itfc: InterfaceId,
    /// Transfer direction.
    pub kind: TransactionKind,
    /// Segment size in bytes.
    pub size: usize,
    /// Byte offset of this segment within its op.
    pub offset: usize,
    /// Unique transaction tag.
    pub tag: u32,
    /// Tags that must issue before this one (same-interface order).
    pub after: Vec<u32>,
}

/// The complete transaction schedule plus its modelled latency.
#[derive(Debug, Clone, Default)]
pub struct Schedule {
    /// Issue-ordered transactions.
    pub items: Vec<SchedItem>,
    /// Modelled cycles until every *load* transaction completes.
    pub load_latency: u64,
    /// Modelled cycles until every *store* transaction completes.
    pub store_latency: u64,
    /// Per-interface completion cycles.
    pub per_itfc: Vec<(InterfaceId, u64)>,
}

impl Schedule {
    /// Total memory latency (interfaces run in parallel).
    pub fn mem_latency(&self) -> u64 {
        self.per_itfc.iter().map(|&(_, c)| c).max().unwrap_or(0)
    }
}

/// Hierarchy phase of a transfer, derived from its data's `cache_hint`.
/// Reads: lower phase issues earlier. (Warm=top of hierarchy.)
fn read_phase(hint: CacheHint) -> u8 {
    match hint {
        CacheHint::Warm => 0,
        CacheHint::Unknown => 1,
        CacheHint::Cold => 2,
    }
}

/// Writes: bottom of the hierarchy first.
fn write_phase(hint: CacheHint) -> u8 {
    match hint {
        CacheHint::Cold => 0,
        CacheHint::Unknown => 1,
        CacheHint::Warm => 2,
    }
}

/// A schedulable unit: one bulk op's contiguous segment run on one
/// interface.
#[derive(Debug, Clone)]
struct Unit {
    op: usize,
    kind: TransactionKind,
    phase: u8,
    segments: Vec<usize>,
}

/// Simulate a mixed load/store transaction sequence on one interface
/// (the §4.1 recurrences generalized to per-transaction kind).
pub fn mixed_sequence_latency(itfc: &MemInterface, items: &[(TransactionKind, usize)]) -> u64 {
    let n = items.len();
    if n == 0 {
        return 0;
    }
    let i_k = itfc.in_flight.max(1);
    let mut a = vec![-1i64; n + 1];
    let mut b = vec![-1i64; n + 1];
    for j in 1..=n {
        let (kind, size) = items[j - 1];
        let beats = size.div_ceil(itfc.width) as i64;
        let blocked = if j > i_k { b[j - i_k] } else { -1 };
        a[j] = 1 + a[j - 1].max(blocked);
        b[j] = match kind {
            TransactionKind::Load => beats + b[j - 1].max(a[j] + itfc.read_lead as i64 - 1),
            TransactionKind::Store => beats + itfc.write_cost as i64 + b[j - 1].max(a[j] - 1),
        };
    }
    b[n].max(0) as u64
}

/// Find the minimal-latency order of units on one interface via memoized
/// search. Constraints: phase order is strict across different phases;
/// within a phase all permutations are explored. Returns unit order.
fn best_unit_order(itfc: &MemInterface, units: &[Unit]) -> Vec<usize> {
    // Sort indices by phase, search within phases.
    let n = units.len();
    if n <= 1 {
        return (0..n).collect();
    }
    // State: bitmask of scheduled units -> (best latency, order). The
    // relative-window compression: latency of the remainder depends on the
    // completed prefix only through the final (a, b-window) state, which
    // for a fixed prefix *set* varies with order — we keep the best.
    #[derive(Clone)]
    struct Entry {
        latency: u64,
        order: Vec<usize>,
    }
    let mut memo: HashMap<u32, Entry> = HashMap::new();
    memo.insert(0, Entry { latency: 0, order: vec![] });

    let full: u32 = (1u32 << n) - 1;
    // Breadth-first over popcount layers keeps the memo small.
    for layer in 0..n {
        let keys: Vec<u32> =
            memo.keys().copied().filter(|k| k.count_ones() as usize == layer).collect();
        for mask in keys {
            let entry = memo[&mask].clone();
            let min_phase = (0..n)
                .filter(|&u| mask & (1 << u) == 0)
                .map(|u| units[u].phase)
                .min()
                .unwrap_or(u8::MAX);
            for u in 0..n {
                if mask & (1 << u) != 0 || units[u].phase != min_phase {
                    continue;
                }
                let mut order = entry.order.clone();
                order.push(u);
                let seq: Vec<(TransactionKind, usize)> = order
                    .iter()
                    .flat_map(|&i| units[i].segments.iter().map(move |&s| (units[i].kind, s)))
                    .collect();
                let lat = mixed_sequence_latency(itfc, &seq);
                let next = mask | (1 << u);
                let better = memo.get(&next).map(|e| lat < e.latency).unwrap_or(true);
                if better {
                    memo.insert(next, Entry { latency: lat, order });
                }
            }
        }
    }
    memo.remove(&full).map(|e| e.order).unwrap_or_else(|| (0..n).collect())
}

/// Build the optimal schedule for all *bulk* memory operations.
/// (Per-element streaming ops are modelled by the ISAX engine's loop
/// pipeline, not the prologue/epilogue schedule.)
pub fn schedule(
    probe: &MemProbe,
    assignments: &[Assignment],
    itfcs: &InterfaceSet,
) -> Result<Schedule> {
    if assignments.len() != probe.ops.len() {
        return Err(Error::Synthesis("assignment/op count mismatch".into()));
    }
    // Group bulk units per interface.
    let mut per_itfc_units: Vec<Vec<Unit>> = vec![Vec::new(); itfcs.len()];
    for a in assignments {
        let mop = &probe.ops[a.op];
        if !mop.bulk {
            continue;
        }
        let phase = match mop.kind {
            TransactionKind::Load => read_phase(mop.hint),
            TransactionKind::Store => write_phase(mop.hint),
        };
        per_itfc_units[a.itfc.0].push(Unit {
            op: a.op,
            kind: mop.kind,
            phase,
            segments: a.segments.clone(),
        });
    }

    let mut items = Vec::new();
    let mut per_itfc = Vec::new();
    let mut tag = 0u32;
    let mut load_latency = 0u64;
    let mut store_latency = 0u64;
    for (kid, itfc) in itfcs.iter() {
        let units = &per_itfc_units[kid.0];
        if units.is_empty() {
            continue;
        }
        let order = best_unit_order(itfc, units);
        let mut seq: Vec<(TransactionKind, usize)> = Vec::new();
        let mut last_tag: Option<u32> = None;
        for &ui in &order {
            let unit = &units[ui];
            let mut offset = 0usize;
            for &size in &unit.segments {
                items.push(SchedItem {
                    op: unit.op,
                    itfc: kid,
                    kind: unit.kind,
                    size,
                    offset,
                    tag,
                    after: last_tag.map(|t| vec![t]).unwrap_or_default(),
                });
                seq.push((unit.kind, size));
                last_tag = Some(tag);
                tag += 1;
                offset += size;
            }
        }
        let lat = mixed_sequence_latency(itfc, &seq);
        per_itfc.push((kid, lat));
        // Split per direction for reporting: simulate prefix ending at the
        // last transaction of each kind.
        for (j, &(kind, _)) in seq.iter().enumerate() {
            let l = mixed_sequence_latency(itfc, &seq[..=j]);
            match kind {
                TransactionKind::Load => load_latency = load_latency.max(l),
                TransactionKind::Store => store_latency = store_latency.max(l),
            }
        }
    }
    Ok(Schedule { items, load_latency, store_latency, per_itfc })
}

/// Replay a chosen schedule through the event-driven burst-DMA engine
/// ([`crate::interface::dmasim`]): every scheduled transaction becomes a
/// simulator transaction in issue order on its interface. Without SRAM
/// contention the per-interface results provably equal the closed-form
/// [`mixed_sequence_latency`] the scheduler optimized against — this is
/// the `--timing sim` cross-check, and any disagreement beyond that
/// uncontended regime is exactly the effect the closed form cannot see.
pub fn simulate_schedule(schedule: &Schedule, itfcs: &InterfaceSet) -> Result<SimOutcome> {
    let txns: Vec<SimTxn> = schedule
        .items
        .iter()
        .map(|item| SimTxn {
            op: item.op,
            itfc: item.itfc,
            kind: item.kind,
            addr: item.offset as u64,
            size: item.size,
            sram: None,
        })
        .collect();
    dmasim::simulate_txns(itfcs, &[], &txns)
}

/// Closed-form vs event-simulated completion cycles per interface:
/// `(interface, closed_form, simulated)` rows for the CLI's
/// `synth --timing sim` report.
pub fn timing_deltas(
    schedule: &Schedule,
    itfcs: &InterfaceSet,
) -> Result<Vec<(InterfaceId, u64, u64)>> {
    let sim = simulate_schedule(schedule, itfcs)?;
    Ok(schedule
        .per_itfc
        .iter()
        .map(|&(id, closed)| (id, closed, sim.itfc_cycles(id)))
        .collect())
}

/// Lower the architectural function to the temporal level: each
/// interface-bound `copy` becomes a `copy_issue` carrying the schedule's
/// tag + `after` dependencies, and a `copy_wait` on an op's final segment
/// lands right after its issue run (Figure 4(c); the cycle model takes
/// overlap from [`Schedule`], the IR keeps conservative data ordering for
/// the interpreter).
pub fn lower_to_temporal(arch: &Func, schedule: &Schedule) -> Result<Func> {
    let mut out = arch.clone();
    // Index schedule items by (op, offset).
    let mut by_key: HashMap<(usize, usize), &SchedItem> = HashMap::new();
    for item in &schedule.items {
        by_key.insert((item.op, item.offset), item);
    }
    // Walk all Copy ops; identify (op, offset) by matching sizes in
    // order per (itfc, dst, src) triple.
    // Copies were emitted in canonical order, so offsets accumulate.
    let mut seen_offsets: HashMap<(u32, u32, u32), usize> = HashMap::new();
    let mut last_tag_of_op: HashMap<usize, u32> = HashMap::new();
    let mut copy_refs = Vec::new();
    for i in 0..out.num_ops() {
        let opref = crate::ir::func::OpRef(i as u32);
        if let OpKind::Copy { itfc, dst, src, size, kind } = out.op(opref).kind {
            let key = (itfc.0 as u32, dst.0, src.0);
            let off = *seen_offsets.get(&key).unwrap_or(&0);
            // Find schedule item by matching any op with this offset+size.
            let item = schedule
                .items
                .iter()
                .find(|it| {
                    it.offset == off && it.size == size && it.itfc == itfc && it.kind == kind
                })
                .ok_or_else(|| {
                    Error::Synthesis(format!("no schedule item for copy #{off} size {size}"))
                })?;
            seen_offsets.insert(key, off + size);
            last_tag_of_op.insert(item.op, item.tag);
            copy_refs.push((opref, item.tag, item.after.clone(), itfc, dst, src, size, kind));
        }
    }
    // Rewrite each Copy into CopyIssue.
    for &(opref, tag, ref after, itfc, dst, src, size, kind) in &copy_refs {
        let op = out.op_mut(opref);
        op.kind = OpKind::CopyIssue { itfc, dst, src, size, kind, tag, after: after.clone() };
    }
    // Insert a CopyWait after every issue (the *model* overlaps them via
    // the schedule's `after` graph; the IR keeps conservative data order so
    // the reference interpreter sees completed transfers before use).
    // Bulk copies are top-level by construction (stage-in/stage-out);
    // nested bulk copies are rejected here.
    let mut issues: Vec<(usize, u32)> = Vec::new();
    for (pos, &opref) in out.entry.ops.iter().enumerate() {
        if let OpKind::CopyIssue { tag, .. } = out.op(opref).kind {
            issues.push((pos, tag));
        }
    }
    let n_issue_total = copy_refs.len();
    if issues.len() != n_issue_total {
        return Err(Error::Synthesis(
            "bulk copy inside nested region is unsupported by temporal lowering".into(),
        ));
    }
    let _ = last_tag_of_op;
    for &(pos, tag) in issues.iter().rev() {
        let wait = out.add_op(Op::new(OpKind::CopyWait { tag }, vec![], vec![]));
        out.entry.ops.insert(pos + 1, wait);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interface::cache::CacheHint;
    use crate::ir::builder::FuncBuilder;
    use crate::runtime::DType;
    use crate::synthesis::{memprobe, selection, SynthOptions};

    fn two_transfer_func() -> Func {
        let mut b = FuncBuilder::new("two");
        let cold = b.global("coeffs", DType::F32, 32, CacheHint::Cold);
        let warm = b.global("cfg", DType::F32, 16, CacheHint::Warm);
        let s1 = b.scratchpad("s1", DType::F32, 32, 1);
        let s2 = b.scratchpad("s2", DType::F32, 16, 1);
        let zero = b.const_i(0);
        b.transfer(s1, zero, cold, zero, 128);
        b.transfer(s2, zero, warm, zero, 64);
        // keep both scratchpads "used as temporaries" so elision is moot
        b.for_range(0, 4, 1, |b, iv| {
            let a = b.read_smem(s1, iv);
            let c = b.read_smem(s2, iv);
            let d = b.add(a, c);
            b.write_smem(s1, iv, d);
        });
        b.finish(&[])
    }

    fn build_schedule(f: &Func) -> (MemProbe, Vec<Assignment>, Schedule) {
        let itfcs = InterfaceSet::rocket_default();
        let probe = memprobe::extract(f).unwrap();
        let assigns = selection::select(&probe, &itfcs, &SynthOptions::default()).unwrap();
        let sched = schedule(&probe, &assigns, &itfcs).unwrap();
        (probe, assigns, sched)
    }

    #[test]
    fn warm_reads_issue_before_cold() {
        let f = two_transfer_func();
        let (probe, _, sched) = build_schedule(&f);
        // Among items on the same interface, warm (op with Warm hint)
        // must come first.
        let mut phase_seen: HashMap<usize, usize> = HashMap::new(); // itfc -> last phase
        for (i, item) in sched.items.iter().enumerate() {
            let hint = probe.ops[item.op].hint;
            let phase = read_phase(hint) as usize;
            let e = phase_seen.entry(item.itfc.0).or_insert(0);
            assert!(phase >= *e, "item {i} phase regressed");
            *e = phase;
        }
    }

    #[test]
    fn segments_of_one_op_stay_contiguous() {
        let f = two_transfer_func();
        let (_, _, sched) = build_schedule(&f);
        // group by (itfc); check op ids form contiguous runs
        let mut per_itfc: HashMap<usize, Vec<usize>> = HashMap::new();
        for item in &sched.items {
            per_itfc.entry(item.itfc.0).or_default().push(item.op);
        }
        for ops in per_itfc.values() {
            let mut seen = std::collections::HashSet::new();
            let mut prev = usize::MAX;
            for &op in ops {
                if op != prev {
                    assert!(seen.insert(op), "op {op} segments not contiguous");
                    prev = op;
                }
            }
        }
    }

    #[test]
    fn after_edges_form_a_chain_per_interface() {
        let f = two_transfer_func();
        let (_, _, sched) = build_schedule(&f);
        let mut last: HashMap<usize, u32> = HashMap::new();
        for item in &sched.items {
            match last.get(&item.itfc.0) {
                None => assert!(item.after.is_empty()),
                Some(&t) => assert_eq!(item.after, vec![t]),
            }
            last.insert(item.itfc.0, item.tag);
        }
    }

    #[test]
    fn schedule_latency_bounded_by_sum() {
        let f = two_transfer_func();
        let (_, _, sched) = build_schedule(&f);
        assert!(sched.mem_latency() > 0);
        let naive_sum: u64 = sched.per_itfc.iter().map(|&(_, l)| l).sum();
        assert!(sched.mem_latency() <= naive_sum);
    }

    #[test]
    fn temporal_lowering_preserves_semantics() {
        use crate::ir::interp::{run as interp, Memory};
        let f = two_transfer_func();
        let itfcs = InterfaceSet::rocket_default();
        let probe = memprobe::extract(&f).unwrap();
        let assigns = selection::select(&probe, &itfcs, &SynthOptions::default()).unwrap();
        let arch = selection::lower_to_architectural(&f, &probe, &assigns).unwrap();
        let sched = schedule(&probe, &assigns, &itfcs).unwrap();
        let temporal = lower_to_temporal(&arch, &sched).unwrap();

        crate::ir::verifier::verify(&temporal).unwrap();
        assert_eq!(temporal.count_ops(|k| matches!(k, OpKind::Copy { .. })), 0);
        assert!(temporal.count_ops(|k| matches!(k, OpKind::CopyIssue { .. })) > 0);

        let coeffs: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let cfg: Vec<f32> = (0..16).map(|i| (100 + i) as f32).collect();
        let mut m1 = Memory::for_func(&f);
        m1.write_f32(crate::ir::func::BufferId(0), &coeffs);
        m1.write_f32(crate::ir::func::BufferId(1), &cfg);
        interp(&f, &[], &mut m1).unwrap();
        let mut m2 = Memory::for_func(&temporal);
        m2.write_f32(crate::ir::func::BufferId(0), &coeffs);
        m2.write_f32(crate::ir::func::BufferId(1), &cfg);
        interp(&temporal, &[], &mut m2).unwrap();
        assert_eq!(
            m1.read_f32(crate::ir::func::BufferId(2)),
            m2.read_f32(crate::ir::func::BufferId(2))
        );
    }

    #[test]
    fn simulated_schedule_replay_equals_closed_form() {
        // Uncontended replay through the event engine must land on the
        // same per-interface cycle counts the scheduler computed.
        let f = two_transfer_func();
        let itfcs = InterfaceSet::rocket_default();
        let (_, _, sched) = build_schedule(&f);
        let sim = simulate_schedule(&sched, &itfcs).unwrap();
        assert_eq!(sim.conflict_cycles, 0);
        for &(id, closed) in &sched.per_itfc {
            assert_eq!(sim.itfc_cycles(id), closed, "{id} diverged");
        }
        assert_eq!(sim.makespan, sched.mem_latency());
        let deltas = timing_deltas(&sched, &itfcs).unwrap();
        assert!(deltas.iter().all(|&(_, closed, sim)| closed == sim));
    }

    #[test]
    fn mixed_sequence_matches_pure() {
        use crate::interface::latency::sequence_latency;
        let itfc = crate::interface::model::MemInterface::system_bus();
        let sizes = [64usize, 32, 8];
        let mixed: Vec<_> = sizes.iter().map(|&s| (TransactionKind::Load, s)).collect();
        assert_eq!(
            mixed_sequence_latency(&itfc, &mixed),
            sequence_latency(&itfc, TransactionKind::Load, &sizes)
        );
    }
}
