//! The APS-like baseline flow ([24] in the paper) used for Table 2's
//! "ICCAD'25" columns.
//!
//! It reproduces the failure modes §6.2/§6.3 attribute to prior frameworks:
//!
//! - **no block-level memory operations**: every transfer is word-by-word
//!   over the instruction-extension (core) port — the only interface those
//!   frameworks abstract;
//! - **intuitive scratchpad elision**: designers "intuitively apply
//!   scratchpad buffer elision" without interface/access-pattern analysis,
//!   so staged buffers are *always* elided — per-element global accesses
//!   replace bulk staging even when the latency cannot be hidden;
//! - **FIFO transaction order**: no hierarchy-aware grouping, no in-flight
//!   aware reordering.

use crate::error::Result;
use crate::interface::model::{InterfaceId, InterfaceSet};
use crate::interface::TransactionKind;
use crate::ir::func::{BufferKind, Func, OpRef};
use crate::ir::ops::OpKind;
use crate::synthesis::memprobe::{self};
use crate::synthesis::scheduling::{mixed_sequence_latency, SchedItem, Schedule};
use crate::synthesis::selection::Assignment;
use crate::synthesis::SynthResult;

/// Run the naive flow. The result mirrors [`crate::synthesis::synthesize`]
/// so downstream consumers (cycle models, hwgen, benches) are agnostic.
pub fn synthesize_naive(func: &Func, itfcs: &InterfaceSet) -> Result<SynthResult> {
    // "Intuitive" elision: elide every stageable scratchpad regardless of
    // whether the per-element latency can be hidden.
    let (functional, elided) = blind_elide(func);

    let probe = memprobe::extract(&functional)?;
    // Everything goes through the core port (interface 0), word by word.
    let cpu = InterfaceId(0);
    let width = itfcs.get(cpu).width;
    let assignments: Vec<Assignment> = probe
        .ops
        .iter()
        .map(|op| {
            let n_words = op.bytes.div_ceil(width);
            Assignment { op: op.id, itfc: cpu, segments: vec![width; n_words] }
        })
        .collect();

    let architectural =
        crate::synthesis::selection::lower_to_architectural(&functional, &probe, &assignments)?;

    // FIFO schedule: program order, no reordering, single chain.
    let mut items = Vec::new();
    let mut seq: Vec<(TransactionKind, usize)> = Vec::new();
    let mut tag = 0u32;
    let mut last: Option<u32> = None;
    for a in &assignments {
        let mop = &probe.ops[a.op];
        if !mop.bulk {
            continue;
        }
        let mut offset = 0usize;
        for &size in &a.segments {
            items.push(SchedItem {
                op: a.op,
                itfc: cpu,
                kind: mop.kind,
                size,
                offset,
                tag,
                after: last.map(|t| vec![t]).unwrap_or_default(),
            });
            seq.push((mop.kind, size));
            last = Some(tag);
            tag += 1;
            offset += size;
        }
    }
    let lat = mixed_sequence_latency(itfcs.get(cpu), &seq);
    let mut load_latency = 0;
    let mut store_latency = 0;
    for (j, &(kind, _)) in seq.iter().enumerate() {
        let l = mixed_sequence_latency(itfcs.get(cpu), &seq[..=j]);
        match kind {
            TransactionKind::Load => load_latency = load_latency.max(l),
            TransactionKind::Store => store_latency = store_latency.max(l),
        }
    }
    let schedule = Schedule {
        items,
        load_latency,
        store_latency,
        per_itfc: if seq.is_empty() { vec![] } else { vec![(cpu, lat)] },
    };
    let temporal = crate::synthesis::scheduling::lower_to_temporal(&architectural, &schedule)?;

    Ok(SynthResult { functional, architectural, temporal, assignments, schedule, elided })
}

/// Elide every scratchpad that is filled by exactly one zero-offset
/// top-level transfer — no legality or profitability analysis.
fn blind_elide(func: &Func) -> (Func, Vec<String>) {
    let mut out = func.clone();
    let mut elided = Vec::new();
    let defs = func.def_map();
    let transfers: Vec<OpRef> = func
        .entry
        .ops
        .iter()
        .copied()
        .filter(|&o| matches!(func.op(o).kind, OpKind::Transfer { .. }))
        .collect();
    for opref in transfers {
        let op = func.op(opref);
        if let OpKind::Transfer { dst, src, .. } = op.kind {
            let dst_smem = matches!(func.buffer(dst).kind, BufferKind::Scratchpad { .. });
            let src_global = matches!(func.buffer(src).kind, BufferKind::Global);
            let zero_offsets = op.operands.iter().all(|&v| {
                defs[v.0 as usize]
                    .map(|d| matches!(func.op(d).kind, OpKind::ConstI(0)))
                    .unwrap_or(false)
            });
            // Never elide a buffer that compute writes (that would change
            // semantics, which even a naive designer notices).
            let written =
                func.count_ops(|k| matches!(k, OpKind::WriteSmem(b) if *b == dst));
            if dst_smem && src_global && zero_offsets && written == 0 {
                out.entry.ops.retain(|&o| o != opref);
                for i in 0..out.num_ops() {
                    let r = OpRef(i as u32);
                    let o = out.op_mut(r);
                    if matches!(o.kind, OpKind::ReadSmem(b) if b == dst) {
                        o.kind = OpKind::Fetch(src);
                    }
                }
                elided.push(func.buffer(dst).name.clone());
            }
        }
    }
    (out, elided)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interface::cache::CacheHint;
    use crate::ir::builder::FuncBuilder;
    use crate::runtime::DType;

    fn staged_func() -> Func {
        let mut b = FuncBuilder::new("staged");
        let g = b.global("coeffs", DType::F32, 64, CacheHint::Cold);
        let out = b.global("out", DType::F32, 16, CacheHint::Warm);
        let s = b.scratchpad("s", DType::F32, 64, 1);
        let zero = b.const_i(0);
        b.transfer(s, zero, g, zero, 256);
        b.for_range(0, 16, 1, |b, iv| {
            let four = b.const_i(4);
            let idx = b.mul(iv, four);
            let v = b.read_smem(s, idx);
            b.store(out, iv, v);
        });
        b.finish(&[])
    }

    #[test]
    fn naive_elides_blindly() {
        let f = staged_func();
        let itfcs = InterfaceSet::rocket_default();
        let r = synthesize_naive(&f, &itfcs).unwrap();
        // stride-4 cold data: the smart flow keeps the stage; naive elides.
        assert_eq!(r.elided, vec!["s".to_string()]);
    }

    #[test]
    fn naive_uses_core_port_only() {
        let f = staged_func();
        let itfcs = InterfaceSet::rocket_default();
        let r = synthesize_naive(&f, &itfcs).unwrap();
        assert!(r.assignments.iter().all(|a| a.itfc == InterfaceId(0)));
        assert!(r.assignments.iter().all(|a| a.segments.iter().all(|&s| s <= 4)));
    }

    #[test]
    fn naive_slower_than_aquas_on_bulk_moves() {
        // The headline mechanism of Table 2: Aquas's interface-aware flow
        // must beat the naive core-port flow on memory-bound ISAXs.
        let mut b = FuncBuilder::new("bulk");
        let g = b.global("src", DType::F32, 64, CacheHint::Cold);
        let s = b.scratchpad("s", DType::F32, 64, 1);
        let zero = b.const_i(0);
        b.transfer(s, zero, g, zero, 256);
        b.for_range(0, 64, 1, |b, iv| {
            let v = b.read_smem(s, iv);
            let w = b.mul(v, v);
            b.write_smem(s, iv, w);
        });
        let f = b.finish(&[]);
        let itfcs = InterfaceSet::rocket_default();
        let smart = crate::synthesis::synthesize(&f, &itfcs, &Default::default()).unwrap();
        let naive = synthesize_naive(&f, &itfcs).unwrap();
        assert!(
            smart.schedule.mem_latency() < naive.schedule.mem_latency(),
            "aquas {} !< naive {}",
            smart.schedule.mem_latency(),
            naive.schedule.mem_latency()
        );
    }

    #[test]
    fn naive_semantics_still_correct() {
        use crate::ir::interp::{run as interp, Memory};
        let f = staged_func();
        let itfcs = InterfaceSet::rocket_default();
        let r = synthesize_naive(&f, &itfcs).unwrap();
        let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let mut m1 = Memory::for_func(&f);
        m1.write_f32(crate::ir::func::BufferId(0), &data);
        interp(&f, &[], &mut m1).unwrap();
        let mut m2 = Memory::for_func(&r.temporal);
        m2.write_f32(crate::ir::func::BufferId(0), &data);
        interp(&r.temporal, &[], &mut m2).unwrap();
        assert_eq!(
            m1.read_f32(crate::ir::func::BufferId(1)),
            m2.read_f32(crate::ir::func::BufferId(1))
        );
    }
}
