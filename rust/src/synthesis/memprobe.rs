//! Memory-operation extraction: the shared "what does this ISAX move"
//! view consumed by selection, scheduling, and both synthesis flows.
//!
//! An ISAX description at the functional level stages data with `transfer`
//! ops (bulk) and touches globals with `fetch`/per-element ops inside its
//! compute loops. The probe flattens these into a list of [`MemOp`]s with
//! direction, size, base address, cache hint, and loop-trip multiplicity.

use crate::error::{Error, Result};
use crate::interface::cache::CacheHint;
use crate::interface::TransactionKind;
use crate::ir::func::{BufferId, BufferKind, Func, OpRef, Region};
use crate::ir::ops::OpKind;

/// One memory operation visible to interface selection.
#[derive(Debug, Clone, PartialEq)]
pub struct MemOp {
    /// Dense id used by [`crate::synthesis::selection::Assignment`].
    pub id: usize,
    /// Load = global → ISAX, Store = ISAX → global.
    pub kind: TransactionKind,
    /// Total bytes moved by one execution of this op.
    pub bytes: usize,
    /// Base byte address in the global address space.
    pub base_addr: u64,
    /// cache_hint of the global buffer touched.
    pub hint: CacheHint,
    /// The global buffer.
    pub buf: BufferId,
    /// Where the op lives in the IR.
    pub opref: OpRef,
    /// How many times the op executes per ISAX invocation (loop trip
    /// product for per-element ops; 1 for top-level bulk transfers).
    pub trips: u64,
    /// True for bulk `transfer`, false for per-element `fetch`-style ops.
    pub bulk: bool,
}

/// Extraction result: ops plus loop statistics used by elision and the
/// compute model.
#[derive(Debug, Clone, Default)]
pub struct MemProbe {
    /// Every memory operation found, in walk order (dense ids).
    pub ops: Vec<MemOp>,
    /// Total loop iterations across the (possibly nested) compute loops.
    pub total_iterations: u64,
    /// Arithmetic op count inside loop bodies (single iteration).
    pub body_arith_ops: u64,
}

/// Static trip count of a `for` op when lb/ub/step are constants.
pub fn static_trips(func: &Func, opref: OpRef) -> Option<u64> {
    let op = func.op(opref);
    if !matches!(op.kind, OpKind::For) {
        return None;
    }
    let cval = |v| {
        let defs = func.def_map();
        defs[v as usize].and_then(|d| match func.op(d).kind {
            OpKind::ConstI(c) => Some(c),
            _ => None,
        })
    };
    let lb = cval(op.operands[0].0)?;
    let ub = cval(op.operands[1].0)?;
    let step = cval(op.operands[2].0)?;
    if step <= 0 || ub <= lb {
        return Some(0);
    }
    Some(((ub - lb + step - 1) / step) as u64)
}

/// Extract all memory operations from a functional-level ISAX description.
pub fn extract(func: &Func) -> Result<MemProbe> {
    let mut probe = MemProbe::default();
    walk(func, &func.entry, 1, &mut probe)?;
    Ok(probe)
}

fn walk(func: &Func, region: &Region, trips: u64, probe: &mut MemProbe) -> Result<()> {
    for &opref in &region.ops {
        let op = func.op(opref);
        match &op.kind {
            OpKind::Transfer { dst, src, size } => {
                // Direction: global -> scratchpad is a load; scratchpad ->
                // global (or global -> global writes) is a store.
                let (global, kind) = classify_transfer(func, *dst, *src)?;
                let decl = func.buffer(global);
                probe.ops.push(MemOp {
                    id: probe.ops.len(),
                    kind,
                    bytes: *size,
                    base_addr: decl.base_addr,
                    hint: decl.hint,
                    buf: global,
                    opref,
                    trips,
                    bulk: true,
                });
            }
            OpKind::Fetch(b) => {
                let decl = func.buffer(*b);
                probe.ops.push(MemOp {
                    id: probe.ops.len(),
                    kind: TransactionKind::Load,
                    bytes: 4,
                    base_addr: decl.base_addr,
                    hint: decl.hint,
                    buf: *b,
                    opref,
                    trips,
                    bulk: false,
                });
            }
            OpKind::Load(b) | OpKind::Store(b)
                if matches!(func.buffer(*b).kind, BufferKind::Global) =>
            {
                let decl = func.buffer(*b);
                let kind = if matches!(op.kind, OpKind::Load(_)) {
                    TransactionKind::Load
                } else {
                    TransactionKind::Store
                };
                probe.ops.push(MemOp {
                    id: probe.ops.len(),
                    kind,
                    bytes: 4,
                    base_addr: decl.base_addr,
                    hint: decl.hint,
                    buf: *b,
                    opref,
                    trips,
                    bulk: false,
                });
            }
            OpKind::For => {
                let t = static_trips(func, opref).unwrap_or(1);
                if trips == 1 {
                    probe.total_iterations += t;
                }
                // Count body arith once.
                let mut arith = 0u64;
                func.walk_region(&op.regions[0], &mut |_, o| {
                    if !o.kind.is_anchor() && !o.kind.touches_memory() {
                        arith += 1;
                    }
                });
                probe.body_arith_ops = probe.body_arith_ops.max(arith);
                walk(func, &op.regions[0], trips.saturating_mul(t.max(1)), probe)?;
            }
            OpKind::If => {
                walk(func, &op.regions[0], trips, probe)?;
                walk(func, &op.regions[1], trips, probe)?;
            }
            _ => {}
        }
    }
    Ok(())
}

fn classify_transfer(
    func: &Func,
    dst: BufferId,
    src: BufferId,
) -> Result<(BufferId, TransactionKind)> {
    let dst_global = matches!(func.buffer(dst).kind, BufferKind::Global);
    let src_global = matches!(func.buffer(src).kind, BufferKind::Global);
    match (dst_global, src_global) {
        (false, true) => Ok((src, TransactionKind::Load)),
        (true, false) => Ok((dst, TransactionKind::Store)),
        (true, true) => Ok((dst, TransactionKind::Store)), // mem-to-mem: count the write side
        (false, false) => Err(Error::Synthesis(
            "transfer between two scratchpads needs no interface".into(),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::FuncBuilder;
    use crate::runtime::DType;

    #[test]
    fn extracts_bulk_and_element_ops() {
        let mut b = FuncBuilder::new("fir7");
        let src = b.global("src", DType::F32, 27, CacheHint::Cold);
        let out = b.global("out", DType::F32, 21, CacheHint::Warm);
        let s_src = b.scratchpad("s_src", DType::F32, 27, 1);
        let zero = b.const_i(0);
        b.transfer(s_src, zero, src, zero, 108);
        b.for_range(0, 21, 1, |b, iv| {
            let v = b.read_smem(s_src, iv);
            b.store(out, iv, v);
        });
        let f = b.finish(&[]);
        let probe = extract(&f).unwrap();
        assert_eq!(probe.ops.len(), 2);
        assert_eq!(probe.ops[0].kind, TransactionKind::Load);
        assert_eq!(probe.ops[0].bytes, 108);
        assert!(probe.ops[0].bulk);
        assert_eq!(probe.ops[1].kind, TransactionKind::Store);
        assert_eq!(probe.ops[1].trips, 21);
        assert!(!probe.ops[1].bulk);
        assert_eq!(probe.total_iterations, 21);
    }

    #[test]
    fn trip_counts_multiply_in_nests() {
        let mut b = FuncBuilder::new("nest");
        let g = b.global("g", DType::F32, 64, CacheHint::Unknown);
        b.for_range(0, 4, 1, |b, _| {
            b.for_range(0, 8, 1, |b, j| {
                let v = b.fetch(g, j);
                let _ = v;
            });
        });
        let f = b.finish(&[]);
        let probe = extract(&f).unwrap();
        assert_eq!(probe.ops.len(), 1);
        assert_eq!(probe.ops[0].trips, 32);
    }
}
