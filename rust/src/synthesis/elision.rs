//! §4.3 step 1 — scratchpad buffer elision.
//!
//! ISAXs often explicitly stage data in local scratchpads. This pass
//! evaluates whether those intermediate buffers can be safely elided to
//! allow direct main-memory access, reducing latency and SRAM usage.
//!
//! Elision of scratchpad `S` (filled from global `G`) is *disabled* when:
//! - `S` is written by compute (it is a real temporary, not a stage);
//! - `S` is read outside any loop (non-pipelined region: per-element
//!   latency cannot be hidden);
//! - `S` is accessed with a non-affine index (unpredictable stride ⇒
//!   cache-thrash risk, per affine analysis);
//! - the stride is so large that per-element fetches touch a new cache
//!   line each iteration while the data is `Cold` (thrashing);
//!
//! and *accepted* only if tentative rescheduling confirms no latency
//! increase: the per-element access latency must hide behind the loop's
//! compute (the paper's fir7 `bias` example).

use crate::error::Result;
use crate::interface::cache::CacheHint;
use crate::interface::latency::{sequence_latency, TransactionKind};
use crate::interface::model::InterfaceSet;
use crate::ir::affine::access_pattern;
use crate::ir::func::{BufferId, BufferKind, Func, OpRef};
use crate::ir::ops::OpKind;
use crate::synthesis::SynthOptions;

/// One elision candidate: scratchpad filled by exactly one top-level
/// transfer from a global, with zero offsets.
#[derive(Debug, Clone)]
struct Candidate {
    smem: BufferId,
    global: BufferId,
    transfer: OpRef,
    bytes: usize,
}

/// Run elision; returns the rewritten function and the elided buffer names.
pub fn run(func: &Func, itfcs: &InterfaceSet, opts: &SynthOptions) -> Result<(Func, Vec<String>)> {
    let mut out = func.clone();
    let mut elided = Vec::new();

    for cand in find_candidates(func) {
        if !legal(func, &cand) {
            continue;
        }
        if !profitable(func, &cand, itfcs, opts) {
            continue;
        }
        apply(&mut out, &cand);
        elided.push(func.buffer(cand.smem).name.clone());
    }
    Ok((out, elided))
}

fn find_candidates(func: &Func) -> Vec<Candidate> {
    let mut cands = Vec::new();
    // Top-level transfers only: a staged buffer filled inside a loop has
    // iteration-dependent contents and is not a pure stage.
    for &opref in &func.entry.ops {
        let op = func.op(opref);
        if let OpKind::Transfer { dst, src, size } = op.kind {
            let dst_is_smem = matches!(func.buffer(dst).kind, BufferKind::Scratchpad { .. });
            let src_is_global = matches!(func.buffer(src).kind, BufferKind::Global);
            if !(dst_is_smem && src_is_global) {
                continue;
            }
            // Offsets must be constant zero so read_smem indices map 1:1
            // onto the global buffer.
            let defs = func.def_map();
            let is_zero = |v: crate::ir::func::Value| {
                defs[v.0 as usize]
                    .map(|d| matches!(func.op(d).kind, OpKind::ConstI(0)))
                    .unwrap_or(false)
            };
            if !is_zero(op.operands[0]) || !is_zero(op.operands[1]) {
                continue;
            }
            // Exactly one filling transfer per scratchpad.
            let fills =
                func.count_ops(|k| matches!(k, OpKind::Transfer { dst: d, .. } if *d == dst));
            if fills != 1 {
                continue;
            }
            cands.push(Candidate { smem: dst, global: src, transfer: opref, bytes: size });
        }
    }
    cands
}

fn legal(func: &Func, cand: &Candidate) -> bool {
    // Written by compute => real temporary.
    let written = func.count_ops(|k| matches!(k, OpKind::WriteSmem(b) if *b == cand.smem));
    if written > 0 {
        return false;
    }
    // Read outside any loop => latency cannot be hidden by pipelining.
    for &opref in &func.entry.ops {
        let op = func.op(opref);
        if matches!(op.kind, OpKind::ReadSmem(b) if b == cand.smem) {
            return false;
        }
        let _ = op;
    }
    // Affine accesses only (cache-thrash risk otherwise).
    let pat = access_pattern(func, cand.smem);
    if !pat.all_affine || pat.reads == 0 {
        return false;
    }
    // Cold data with a stride that leaves the current line every access
    // would thrash the hierarchy when fetched per element.
    let hint = func.buffer(cand.global).hint;
    if hint == CacheHint::Cold && pat.max_stride >= 16 {
        return false;
    }
    true
}

/// Trip-weighted dynamic read count of a scratchpad (how many times the
/// elided form would hit the interface). fir7's `src` is read 7× per
/// output — this is what makes its elision unprofitable while `bias`
/// (read once per output) elides.
fn dynamic_reads(func: &Func, smem: BufferId) -> u64 {
    fn walk(func: &Func, region: &crate::ir::func::Region, mult: u64, smem: BufferId) -> u64 {
        let mut total = 0;
        for &opref in &region.ops {
            let op = func.op(opref);
            match &op.kind {
                OpKind::ReadSmem(b) if *b == smem => total += mult,
                OpKind::For => {
                    let trips =
                        crate::synthesis::memprobe::static_trips(func, opref).unwrap_or(1).max(1);
                    total += walk(func, &op.regions[0], mult * trips, smem);
                }
                OpKind::If => {
                    // worst arm
                    let t = walk(func, &op.regions[0], mult, smem);
                    let e = walk(func, &op.regions[1], mult, smem);
                    total += t.max(e);
                }
                _ => {}
            }
        }
        total
    }
    walk(func, &func.entry, 1, smem)
}

/// Innermost-iteration count along the deepest loop spine (the pipelined
/// stream length the compute occupies).
fn deepest_iterations(func: &Func) -> u64 {
    fn deepest(func: &Func, region: &crate::ir::func::Region) -> u64 {
        let mut best = 1;
        for &opref in &region.ops {
            let op = func.op(opref);
            if matches!(op.kind, OpKind::For) {
                let trips =
                    crate::synthesis::memprobe::static_trips(func, opref).unwrap_or(1).max(1);
                best = best.max(trips * deepest(func, &op.regions[0]));
            }
        }
        best
    }
    deepest(func, &func.entry)
}

/// Tentative rescheduling: accept only if the elided form's estimated
/// latency does not exceed the staged form's.
fn profitable(func: &Func, cand: &Candidate, itfcs: &InterfaceSet, opts: &SynthOptions) -> bool {
    let total_reads = dynamic_reads(func, cand.smem).max(1);
    let compute = deepest_iterations(func) * opts.body_cycles_per_iter.max(1);

    // Staged: best-interface bulk transfer up front, then compute.
    let staged_mem: u64 = itfcs
        .iter()
        .map(|(_, itfc)| {
            let segs = itfc.decompose(func.buffer(cand.global).base_addr, cand.bytes);
            sequence_latency(itfc, TransactionKind::Load, &segs)
        })
        .min()
        .unwrap_or(u64::MAX);
    let staged_total = staged_mem + compute;

    // Elided: per-read fetches pipelined against compute. With I_k
    // in-flight slots a scalar load completes every
    // max(beats, (beats + L)/I) cycles (recurrence steady state).
    let elided_total = itfcs
        .iter()
        .map(|(_, itfc)| {
            let beats = 4u64.div_ceil(itfc.width as u64);
            let per_load =
                beats.max((beats + itfc.read_lead).div_ceil(itfc.in_flight.max(1) as u64));
            let mem_stream = total_reads * per_load + itfc.read_lead;
            mem_stream.max(compute) + itfc.read_lead
        })
        .min()
        .unwrap_or(u64::MAX);

    elided_total <= staged_total
}

fn apply(out: &mut Func, cand: &Candidate) {
    // Remove the filling transfer from the entry region.
    out.entry.ops.retain(|&o| o != cand.transfer);
    // Retarget every read_smem(S) to fetch(G).
    for i in 0..out.num_ops() {
        let opref = OpRef(i as u32);
        let op = out.op_mut(opref);
        if matches!(op.kind, OpKind::ReadSmem(b) if b == cand.smem) {
            op.kind = OpKind::Fetch(cand.global);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::FuncBuilder;
    use crate::ir::interp::{run as interp, Memory};
    use crate::runtime::DType;

    /// fir7-like: bias staged into a scratchpad, read once per iteration
    /// with unit stride -> elided (the paper's Figure 4(a)).
    fn fir_bias_func() -> Func {
        let mut b = FuncBuilder::new("fir_bias");
        let bias = b.global("bias", DType::F32, 21, CacheHint::Warm);
        let out = b.global("out", DType::F32, 21, CacheHint::Warm);
        let s_bias = b.scratchpad("s_bias", DType::F32, 21, 1);
        let zero = b.const_i(0);
        b.transfer(s_bias, zero, bias, zero, 84);
        b.for_range(0, 21, 1, |b, iv| {
            let v = b.read_smem(s_bias, iv);
            let two = b.const_f(2.0);
            let w = b.mul(v, two);
            b.store(out, iv, w);
        });
        b.finish(&[])
    }

    /// In fir7 the bias read shares its loop with a 7-tap MAC, so the
    /// per-element fetch hides behind ~7 cycles of accumulation — model
    /// that compute weight explicitly (the synthesis entry point derives
    /// it from the loop body; see `workloads::fir7`).
    fn fir_opts() -> SynthOptions {
        SynthOptions { body_cycles_per_iter: 7, ..Default::default() }
    }

    #[test]
    fn elides_unit_stride_staged_buffer() {
        let f = fir_bias_func();
        let itfcs = InterfaceSet::rocket_default();
        let (g, elided) = run(&f, &itfcs, &fir_opts()).unwrap();
        assert_eq!(elided, vec!["s_bias".to_string()]);
        assert_eq!(g.count_ops(|k| matches!(k, OpKind::Transfer { .. })), 0);
        assert_eq!(g.count_ops(|k| matches!(k, OpKind::Fetch(_))), 1);
    }

    #[test]
    fn elision_preserves_semantics() {
        let f = fir_bias_func();
        let itfcs = InterfaceSet::rocket_default();
        let (g, _) = run(&f, &itfcs, &fir_opts()).unwrap();

        let bias_vals: Vec<f32> = (0..21).map(|i| i as f32).collect();
        let mut m1 = Memory::for_func(&f);
        m1.write_f32(BufferId(0), &bias_vals);
        interp(&f, &[], &mut m1).unwrap();

        let mut m2 = Memory::for_func(&g);
        m2.write_f32(BufferId(0), &bias_vals);
        interp(&g, &[], &mut m2).unwrap();

        assert_eq!(m1.read_f32(BufferId(1)), m2.read_f32(BufferId(1)));
    }

    #[test]
    fn keeps_compute_written_scratchpad() {
        let mut b = FuncBuilder::new("temp");
        let g = b.global("g", DType::F32, 16, CacheHint::Warm);
        let s = b.scratchpad("s", DType::F32, 16, 1);
        let zero = b.const_i(0);
        b.transfer(s, zero, g, zero, 64);
        b.for_range(0, 16, 1, |b, iv| {
            let v = b.read_smem(s, iv);
            let two = b.const_f(2.0);
            let w = b.mul(v, two);
            b.write_smem(s, iv, w); // compute writes back: real temporary
        });
        let f = b.finish(&[]);
        let itfcs = InterfaceSet::rocket_default();
        let (_, elided) = run(&f, &itfcs, &SynthOptions::default()).unwrap();
        assert!(elided.is_empty());
    }

    #[test]
    fn keeps_non_affine_access() {
        let mut b = FuncBuilder::new("gather");
        let g = b.global("g", DType::F32, 64, CacheHint::Warm);
        let idxbuf = b.global("idx", DType::I32, 16, CacheHint::Warm);
        let s = b.scratchpad("s", DType::F32, 64, 1);
        let zero = b.const_i(0);
        b.transfer(s, zero, g, zero, 256);
        let out = b.global("out", DType::F32, 16, CacheHint::Warm);
        b.for_range(0, 16, 1, |b, iv| {
            let j = b.load(idxbuf, iv); // data-dependent index
            let v = b.read_smem(s, j);
            b.store(out, iv, v);
        });
        let f = b.finish(&[]);
        let itfcs = InterfaceSet::rocket_default();
        let (_, elided) = run(&f, &itfcs, &SynthOptions::default()).unwrap();
        assert!(elided.is_empty());
    }

    #[test]
    fn keeps_cold_large_stride() {
        let mut b = FuncBuilder::new("strided");
        let g = b.global("coeffs", DType::F32, 512, CacheHint::Cold);
        let out = b.global("out", DType::F32, 16, CacheHint::Warm);
        let s = b.scratchpad("s", DType::F32, 512, 1);
        let zero = b.const_i(0);
        b.transfer(s, zero, g, zero, 2048);
        b.for_range(0, 16, 1, |b, iv| {
            let k = b.const_i(32);
            let idx = b.mul(iv, k); // stride 32: new line every access
            let v = b.read_smem(s, idx);
            b.store(out, iv, v);
        });
        let f = b.finish(&[]);
        let itfcs = InterfaceSet::rocket_default();
        let (_, elided) = run(&f, &itfcs, &SynthOptions::default()).unwrap();
        assert!(elided.is_empty());
    }
}
