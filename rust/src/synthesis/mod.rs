//! §4.3 — interface-aware synthesis-time optimization.
//!
//! The pipeline progressively optimizes and lowers an ISAX description
//! through the Aquas-IR levels:
//!
//! 1. [`elision`] — scratchpad buffer elision (functional level);
//! 2. [`selection`] — interface selection + transaction canonicalization
//!    (functional → architectural);
//! 3. [`scheduling`] — transaction ordering under in-flight and hierarchy
//!    constraints via a memoized search (architectural → temporal);
//! 4. [`hwgen`] — dynamic-pipeline hardware generation (temporal → RTL-ish
//!    datapath description + structural Verilog subset).
//!
//! [`naive`] implements the APS-like baseline flow the paper compares
//! against (blind elision, everything on the core port, FIFO order).
//! [`memprobe`] extracts the memory-operation view both flows share.

#![warn(missing_docs)]

pub mod elision;
pub mod hwgen;
pub mod memprobe;
pub mod naive;
pub mod scheduling;
pub mod selection;

use crate::error::Result;
use crate::interface::model::InterfaceSet;
use crate::ir::Func;

pub use memprobe::{MemOp, MemProbe};
pub use scheduling::{SchedItem, Schedule};
pub use selection::Assignment;

/// Knobs for the synthesis pipeline.
#[derive(Debug, Clone)]
pub struct SynthOptions {
    /// Enable scratchpad elision analysis (§4.3 step 1).
    pub elide_scratchpads: bool,
    /// Exhaustive interface assignment below this op count, greedy above.
    pub exhaustive_limit: usize,
    /// Body-cycle estimate per loop iteration used in elision's tentative
    /// rescheduling (the compute that hides per-element fetch latency).
    pub body_cycles_per_iter: u64,
}

impl Default for SynthOptions {
    fn default() -> Self {
        Self { elide_scratchpads: true, exhaustive_limit: 10, body_cycles_per_iter: 1 }
    }
}

/// Everything the pipeline produces: the IR after each stage plus the
/// final schedule (consumed by the ISAX cycle engine and hwgen).
#[derive(Debug, Clone)]
pub struct SynthResult {
    /// Functional level after elision.
    pub functional: Func,
    /// Architectural level (interface-bound, canonicalized copies).
    pub architectural: Func,
    /// Temporal level (ordered issue/wait pairs).
    pub temporal: Func,
    /// Interface assignment per memory op.
    pub assignments: Vec<Assignment>,
    /// The final transaction schedule with its modelled latency.
    pub schedule: Schedule,
    /// Buffers elided by step 1 (by name).
    pub elided: Vec<String>,
}

/// Run the full interface-aware pipeline on an ISAX description.
pub fn synthesize(func: &Func, itfcs: &InterfaceSet, opts: &SynthOptions) -> Result<SynthResult> {
    // Step 1: scratchpad buffer elision (functional level).
    let (functional, elided) = if opts.elide_scratchpads {
        elision::run(func, itfcs, opts)?
    } else {
        (func.clone(), Vec::new())
    };

    // Step 2: interface selection + canonicalization.
    let probe = memprobe::extract(&functional)?;
    let assignments = selection::select(&probe, itfcs, opts)?;
    let architectural = selection::lower_to_architectural(&functional, &probe, &assignments)?;

    // Step 3: transaction scheduling + ordering.
    let schedule = scheduling::schedule(&probe, &assignments, itfcs)?;
    let temporal = scheduling::lower_to_temporal(&architectural, &schedule)?;

    Ok(SynthResult { functional, architectural, temporal, assignments, schedule, elided })
}
