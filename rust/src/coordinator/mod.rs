//! L3 serving coordinator for the LLM case study (§6.5).
//!
//! A request router + batcher + KV-cache manager in the style of a
//! (single-node) vLLM router, driving the AOT artifacts through the PJRT
//! [`crate::runtime::Runtime`]. Python never appears here: prefill and
//! decode are compiled HLO executables.
//!
//! Scheduling: a continuous-batching-style loop over single-sequence
//! executables (the artifact batch is 1, matching the paper's single-core
//! edge SoC): each [`Coordinator::step`] either admits a waiting request
//! (prefill) or advances an active one (decode), under a configurable
//! decode-first / prefill-first policy. Every step also advances the
//! *modelled* SoC clock (base core vs Aquas ISAX cycle models from
//! [`crate::workloads::llm`]), so the example can report TTFT/ITL both in
//! host wall-clock and in simulated-silicon milliseconds.

mod kv;

pub use kv::KvState;

use std::collections::VecDeque;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::runtime::{Runtime, Tensor};
use crate::workloads::llm::{BaseCpuModel, IsaxLlmModel, LlmConfig};

/// Scheduling policy for mixed prefill/decode load.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulePolicy {
    /// Favor inter-token latency of running requests.
    DecodeFirst,
    /// Favor time-to-first-token of queued requests.
    PrefillFirst,
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub policy: SchedulePolicy,
    /// Hard cap on concurrently active sequences (KV memory budget).
    pub max_active: usize,
    /// Cycle models for the simulated-SoC clock.
    pub llm: LlmConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self { policy: SchedulePolicy::DecodeFirst, max_active: 4, llm: LlmConfig::default() }
    }
}

/// A generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// Per-request lifecycle metrics.
#[derive(Debug, Clone)]
pub struct RequestMetrics {
    pub id: u64,
    pub prompt_len: usize,
    pub generated: Vec<i32>,
    /// Host wall-clock µs from submit to first generated token.
    pub ttft_us: u128,
    /// Host wall-clock µs between subsequent tokens.
    pub itl_us: Vec<u128>,
    /// Simulated base-core cycles attributable to this request.
    pub sim_base_cycles: f64,
    /// Simulated Aquas-ISAX cycles attributable to this request.
    pub sim_isax_cycles: f64,
}

struct Active {
    req: Request,
    kv: KvState,
    generated: Vec<i32>,
    submitted: Instant,
    first_token: Option<Instant>,
    last_token: Option<Instant>,
    itl_us: Vec<u128>,
    sim_base_cycles: f64,
    sim_isax_cycles: f64,
}

/// The serving coordinator.
pub struct Coordinator<'rt> {
    rt: &'rt Runtime,
    cfg: CoordinatorConfig,
    next_id: u64,
    waiting: VecDeque<(Request, Instant)>,
    active: Vec<Active>,
    done: Vec<RequestMetrics>,
    base_model: BaseCpuModel,
    isax_model: IsaxLlmModel,
    bus: crate::interface::model::MemInterface,
}

impl<'rt> Coordinator<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: CoordinatorConfig) -> Self {
        Self {
            rt,
            cfg,
            next_id: 0,
            waiting: VecDeque::new(),
            active: Vec::new(),
            done: Vec::new(),
            base_model: BaseCpuModel::default(),
            isax_model: IsaxLlmModel::default(),
            bus: crate::interface::model::MemInterface::system_bus(),
        }
    }

    /// Enqueue a prompt; returns the request id.
    pub fn submit(&mut self, prompt: Vec<i32>, max_new_tokens: usize) -> Result<u64> {
        let m = &self.rt.manifest().model;
        if prompt.is_empty() {
            return Err(Error::Coordinator("empty prompt".into()));
        }
        if prompt.len() > m.prefill_len {
            return Err(Error::Coordinator(format!(
                "prompt len {} exceeds compiled prefill window {}",
                prompt.len(),
                m.prefill_len
            )));
        }
        if prompt.len() + max_new_tokens > m.max_seq {
            return Err(Error::Coordinator(format!(
                "prompt {} + new {} exceeds KV capacity {}",
                prompt.len(),
                max_new_tokens,
                m.max_seq
            )));
        }
        let id = self.next_id;
        self.next_id += 1;
        self.waiting.push_back((Request { id, prompt, max_new_tokens }, Instant::now()));
        Ok(id)
    }

    /// Is there outstanding work?
    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.active.is_empty()
    }

    /// One scheduling step per policy (continuous batching). Returns
    /// whether anything ran.
    ///
    /// - `PrefillFirst`: admit a waiting request whenever capacity allows
    ///   (minimizes TTFT at the cost of ITL jitter for running requests);
    /// - `DecodeFirst`: advance all running requests, then backfill one
    ///   admission with leftover capacity (steadier ITL).
    pub fn step(&mut self) -> Result<bool> {
        let can_admit = !self.waiting.is_empty() && self.active.len() < self.cfg.max_active;
        let can_decode = !self.active.is_empty();
        match self.cfg.policy {
            SchedulePolicy::PrefillFirst => {
                if can_admit {
                    self.do_prefill()?;
                    return Ok(true);
                }
                if can_decode {
                    self.do_decode_round()?;
                    return Ok(true);
                }
                Ok(false)
            }
            SchedulePolicy::DecodeFirst => {
                let mut ran = false;
                if can_decode {
                    self.do_decode_round()?;
                    ran = true;
                }
                if !self.waiting.is_empty() && self.active.len() < self.cfg.max_active {
                    self.do_prefill()?;
                    ran = true;
                }
                Ok(ran)
            }
        }
    }

    /// Drive to completion; returns all request metrics.
    pub fn run_to_completion(&mut self) -> Result<Vec<RequestMetrics>> {
        while self.has_work() {
            self.step()?;
        }
        let mut out = std::mem::take(&mut self.done);
        out.sort_by_key(|m| m.id);
        Ok(out)
    }

    fn do_prefill(&mut self) -> Result<()> {
        let (req, submitted) = self.waiting.pop_front().expect("checked non-empty");
        let m = self.rt.manifest().model.clone();
        // Right-pad the prompt to the compiled prefill window; the KV
        // cursor only advances by the true prompt length, so padded
        // positions are never attended.
        let mut ids = req.prompt.clone();
        ids.resize(m.prefill_len, 0);
        let t = Tensor::i32(ids, &[1, m.prefill_len])?;
        let outs = self.rt.execute("llm_prefill", &[t])?;
        let logits = &outs[0];
        // Next token = argmax over the last *real* prompt position.
        let next = argmax_at(logits, req.prompt.len() - 1, m.vocab)?;
        let kv = KvState::new(outs[1].clone(), outs[2].clone(), req.prompt.len());

        let now = Instant::now();
        let mut act = Active {
            sim_base_cycles: 0.0,
            sim_isax_cycles: 0.0,
            kv,
            generated: vec![next],
            submitted,
            first_token: Some(now),
            last_token: Some(now),
            itl_us: Vec::new(),
            req,
        };
        // Simulated cycles for the whole prefill.
        for t in 0..act.req.prompt.len() {
            act.sim_base_cycles += self.base_model.token_cycles(&self.cfg.llm, t + 1);
            act.sim_isax_cycles += self.isax_model.token_cycles(&self.cfg.llm, t + 1, &self.bus);
        }
        self.active.push(act);
        Ok(())
    }

    fn do_decode_round(&mut self) -> Result<()> {
        let m = self.rt.manifest().model.clone();
        let mut finished = Vec::new();
        for (i, act) in self.active.iter_mut().enumerate() {
            let last = *act.generated.last().expect("at least the prefill token");
            let ids = Tensor::i32(vec![last], &[1, 1])?;
            let pos = Tensor::i32(vec![act.kv.len() as i32], &[1])?;
            let outs =
                self.rt.execute("llm_decode", &[ids, act.kv.k.clone(), act.kv.v.clone(), pos])?;
            let next = argmax_flat(&outs[0])? as i32;
            act.kv = KvState::new(outs[1].clone(), outs[2].clone(), act.kv.len() + 1);
            act.generated.push(next);
            let now = Instant::now();
            if let Some(prev) = act.last_token.replace(now) {
                act.itl_us.push(now.duration_since(prev).as_micros());
            }
            act.sim_base_cycles += self.base_model.token_cycles(&self.cfg.llm, act.kv.len());
            act.sim_isax_cycles +=
                self.isax_model.token_cycles(&self.cfg.llm, act.kv.len(), &self.bus);

            let full = act.kv.len() >= m.max_seq;
            if act.generated.len() >= act.req.max_new_tokens || full {
                finished.push(i);
            }
        }
        // Retire back-to-front so indices stay valid.
        for i in finished.into_iter().rev() {
            let act = self.active.remove(i);
            let first = act.first_token.expect("prefill produced a token");
            self.done.push(RequestMetrics {
                id: act.req.id,
                prompt_len: act.req.prompt.len(),
                generated: act.generated,
                ttft_us: first.duration_since(act.submitted).as_micros(),
                itl_us: act.itl_us,
                sim_base_cycles: act.sim_base_cycles,
                sim_isax_cycles: act.sim_isax_cycles,
            });
        }
        Ok(())
    }
}

/// Argmax over logits[0, pos, :] of a [1, T, V] tensor.
fn argmax_at(logits: &Tensor, pos: usize, vocab: usize) -> Result<i32> {
    let data = logits.as_f32()?;
    let row = &data[pos * vocab..(pos + 1) * vocab];
    let mut best = 0;
    for (i, &x) in row.iter().enumerate() {
        if x > row[best] {
            best = i;
        }
    }
    Ok(best as i32)
}

/// Argmax over a flat [1, V] tensor.
fn argmax_flat(logits: &Tensor) -> Result<usize> {
    logits.argmax_f32()
}
