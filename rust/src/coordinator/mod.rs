//! L3 serving engine for the LLM case study (§6.5).
//!
//! A continuous-batching scheduler over a **paged KV cache** in the style
//! of a (single-node) vLLM router, driving the AOT artifacts through the
//! [`crate::runtime::Runtime`]. Python never appears here: prefill and
//! decode are compiled executables (or their simulated golden models).
//!
//! Architecture per tick ([`Coordinator::step`]):
//!
//! 1. **Arrivals** — trace requests whose simulated arrival time has
//!    passed move into the waiting queue.
//! 2. **Admission** — waiting requests are admitted when a batch slot and
//!    enough KV *blocks* (see [`kv::KvPool`]) are available; the policy
//!    decides whether admission outranks running decodes.
//! 3. **Decode batch** — every active sequence advances one token in a
//!    single batched tick. Sequences crossing a block boundary grab a
//!    fresh block first, *preempting* the most recently admitted sequence
//!    (recompute-style, as in vLLM) when the pool is dry.
//!
//! The engine runs entirely on the *modelled SoC clock*: every tick is
//! charged batch-aware cycle costs from [`crate::workloads::llm`], and
//! the batch's paged-KV block gathers are staged through the
//! event-driven burst-DMA engine ([`crate::interface::dmasim`]) — one
//! §4.1 queue per interface, so concurrent gathers observe real
//! queueing rather than a per-block closed form — so TTFT /
//! ITL / throughput metrics are deterministic across replays (no host
//! wall-clock anywhere). A batched tick streams the weight tiles once for
//! the whole batch — that amortization is what turns the single-stream
//! coordinator of the original study into a servable system.
//!
//! # Multi-core SoC serving
//!
//! [`SocCoordinator`] scales this engine to N ASIP cores on one SoC:
//! each core runs its own pipeline over its own
//! paged-KV *shard*, requests are dispatched to the least-loaded run
//! queue, idle cores steal queued work, sequences migrate off dry
//! shards, and every core's weight/KV streams contend for the shared
//! DDR controller through the same event-driven burst engine (the
//! slowdown is *measured* by replaying concurrent streams through
//! [`crate::interface::dmasim`], not modelled by a second formula). A
//! 1-core SoC is bitwise-identical to driving [`Coordinator`] directly.
//!
//! ```
//! use aquas::coordinator::{SocConfig, SocCoordinator, TraceSpec};
//! use aquas::runtime::Runtime;
//!
//! // Build a deterministic trace and serve it on a 2-core SoC (the
//! // runtime falls back to its simulated model without artifacts).
//! let rt = Runtime::load("artifacts").unwrap();
//! let model = rt.manifest().model.clone();
//! let spec = TraceSpec::parse("n=4,seed=7,rate=8,plen=2..6,gen=2..4").unwrap();
//! let mut soc = SocCoordinator::new(&rt, SocConfig { cores: 2, ..Default::default() });
//! soc.submit_trace(&spec.generate(model.vocab, model.prefill_len)).unwrap();
//! let done = soc.run_to_completion().unwrap();
//! assert_eq!(done.len(), 4);
//! let stats = soc.stats();
//! assert_eq!(stats.cores, 2);
//! assert!(stats.per_core_kv.iter().all(|kv| kv.leak_free()));
//! ```

#![warn(missing_docs)]

mod cores;
mod faults;
mod kv;
mod trace;

pub use cores::{DispatchPolicy, SocConfig, SocCoordinator, SocStats};
pub use faults::FaultPlan;
pub use kv::{BlockTable, KvPool, KvStats, PagedKvConfig};
pub use trace::{TraceRequest, TraceSpec};

use std::collections::{HashMap, VecDeque};

use crate::error::{Error, Result};
use crate::interface::dmasim::DmaFaultInjector;
use crate::interface::model::MemInterface;
use crate::runtime::{DecodeSlot, Runtime, Tensor};
use crate::workloads::llm::{BaseCpuModel, IsaxLlmModel, LlmConfig};

/// Scheduling policy for mixed prefill/decode load.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulePolicy {
    /// Favor inter-token latency of running requests; admissions backfill
    /// after the decode batch.
    DecodeFirst,
    /// Favor time-to-first-token of queued requests: admit whenever
    /// capacity allows, decode otherwise.
    PrefillFirst,
    /// Earliest-deadline-first fairness: requests whose TTFT deadline
    /// (arrival + [`CoordinatorConfig::slo_ttft_ms`]) has expired are
    /// admitted ahead of the decode batch; otherwise behaves like
    /// `DecodeFirst` with EDF-ordered backfill.
    Fair,
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Scheduling policy for mixed prefill/decode load.
    pub policy: SchedulePolicy,
    /// Max concurrently active sequences == decode batch width.
    pub max_active: usize,
    /// Cycle models for the simulated-SoC clock.
    pub llm: LlmConfig,
    /// Paged KV allocator geometry.
    pub kv: PagedKvConfig,
    /// TTFT service-level objective (simulated ms) used by
    /// [`SchedulePolicy::Fair`] deadlines.
    pub slo_ttft_ms: f64,
    /// Per-request decode fuel ceiling: simulated ISAX cycles allowed per
    /// token of the request's generation budget (`max_new_tokens`). A
    /// sequence whose accumulated `sim_isax_cycles` exceeds
    /// `ceiling * max_new_tokens` is retired early and counted as shed —
    /// a runaway kernel becomes a shed request, not a hung SoC. `None`
    /// (the default) disables the check and is bitwise-invisible.
    pub decode_fuel_per_token: Option<f64>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            policy: SchedulePolicy::DecodeFirst,
            max_active: 4,
            llm: LlmConfig::default(),
            kv: PagedKvConfig::default(),
            slo_ttft_ms: 2000.0,
            decode_fuel_per_token: None,
        }
    }
}

/// A generation request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Engine-assigned request id (submission order).
    pub id: u64,
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Generation budget.
    pub max_new_tokens: usize,
}

/// Per-request lifecycle metrics, all on the simulated SoC clock.
#[derive(Debug, Clone)]
pub struct RequestMetrics {
    /// Request id (matches the submit-time id).
    pub id: u64,
    /// Prompt length, tokens.
    pub prompt_len: usize,
    /// Greedily generated token ids.
    pub generated: Vec<i32>,
    /// Simulated µs from arrival to first generated token.
    pub ttft_us: u128,
    /// Simulated µs between subsequent tokens.
    pub itl_us: Vec<u128>,
    /// Simulated base-core cycles attributable to this request.
    pub sim_base_cycles: f64,
    /// Simulated Aquas-ISAX cycles attributable to this request
    /// (batched ticks are shared equally across the batch).
    pub sim_isax_cycles: f64,
    /// Times this request was preempted (blocks reclaimed + recompute).
    pub preemptions: u32,
}

/// An active sequence: request + paged-KV table + progress.
struct Active {
    req: Request,
    admitted_order: u64,
    table: BlockTable,
    /// Valid KV slots (context length).
    len: usize,
    generated: Vec<i32>,
    arrive_ms: f64,
    deadline_ms: f64,
    first_token_ms: Option<f64>,
    last_token_ms: f64,
    itl_us: Vec<u128>,
    sim_base_cycles: f64,
    sim_isax_cycles: f64,
    preemptions: u32,
}

enum WaitItem {
    Fresh { req: Request, arrive_ms: f64, deadline_ms: f64 },
    /// A preempted sequence awaiting re-admission (recompute on return).
    Resume(Box<Active>),
}

impl WaitItem {
    fn deadline_ms(&self) -> f64 {
        match self {
            WaitItem::Fresh { deadline_ms, .. } => *deadline_ms,
            WaitItem::Resume(a) => a.deadline_ms,
        }
    }

    /// KV slots the item needs at admission.
    fn needed_slots(&self) -> usize {
        match self {
            WaitItem::Fresh { req, .. } => req.prompt.len(),
            WaitItem::Resume(a) => a.req.prompt.len() + a.generated.len(),
        }
    }
}

/// One modelled execution burst: the `(compute, mem)` cycle demands of a
/// prefill pass, replay step, or batched decode tick *before* the
/// double-buffering max and pipeline-fill factor — what the multi-core
/// SoC layer needs to re-price the memory leg under shared-DDR
/// contention (see `cores.rs`).
#[derive(Debug, Clone, Copy)]
struct TickDemand {
    compute: f64,
    mem: f64,
}

/// Graceful-degradation ladder state (armed only by the SoC layer when a
/// fault plan is active; `None` on the plain engine keeps the zero-fault
/// path bitwise identical). Levels: 0 = normal, 1 = admission
/// backpressure (fresh admissions must leave one spare KV block),
/// 2 = + deadline-based load shedding of hopelessly-late waiting
/// requests, 3 = + batch-width halving.
#[derive(Debug, Clone, Copy, Default)]
struct DegradeState {
    level: u8,
    /// Consecutive overloaded ticks; escalates the ladder at 3.
    hot_rounds: u32,
    /// Consecutive calm ticks; de-escalates the ladder at 6.
    calm_rounds: u32,
}

/// The serving engine.
pub struct Coordinator<'rt> {
    rt: &'rt Runtime,
    cfg: CoordinatorConfig,
    next_id: u64,
    next_admit: u64,
    /// Trace requests not yet arrived, as `(arrive_ms, deadline_ms,
    /// request)` sorted by arrival time. The TTFT deadline is fixed at
    /// submit so per-request SLO classes survive queueing.
    pending: VecDeque<(f64, f64, Request)>,
    waiting: VecDeque<WaitItem>,
    active: Vec<Active>,
    done: Vec<RequestMetrics>,
    pool: KvPool,
    base_model: BaseCpuModel,
    isax_model: IsaxLlmModel,
    bus: MemInterface,
    /// Simulated SoC clock, in Aquas-core cycles.
    clock_cycles: f64,
    /// Memoized event-simulated gather makespans: total KV blocks staged
    /// in one tick → cycles through the burst engine
    /// ([`crate::interface::dmasim`]). Deterministic, so memoization
    /// cannot perturb replay-identical metrics.
    gather_cycles_memo: HashMap<usize, f64>,
    /// Ideal (un-paged) KV stream rate, bytes/cycle.
    kv_stream_rate: f64,
    /// Persistent gather/scatter working sets (batch × kv_elems each),
    /// reused across ticks so the decode hot path never heap-allocates.
    scratch_k: Vec<f32>,
    scratch_v: Vec<f32>,
    preemptions: u64,
    /// When set (by the SoC layer), every charged execution burst also
    /// pushes its [`TickDemand`] onto `step_demand` for contention
    /// re-pricing. Off by default: the single-core engine never pays for
    /// the recording.
    record_demand: bool,
    /// Demands accumulated since the SoC layer last drained them.
    step_demand: Vec<TickDemand>,
    /// Seeded per-transaction DMA error model, armed by a fault plan
    /// with `dmaerr > 0`. `None` (the default) leaves every gather on
    /// the clean memoized path.
    dma_faults: Option<DmaFaultInjector>,
    /// Compute-demand multiplier from active `surge` fault windows; 1.0
    /// (the default) is guarded out of every charge site, so unfaulted
    /// runs never even multiply by it.
    load_factor: f64,
    /// Degradation-ladder state; `None` (the default) disables the
    /// ladder entirely.
    degrade: Option<DegradeState>,
    /// Waiting requests shed by the degradation ladder.
    shed: u64,
    /// Retired requests whose first token missed its TTFT deadline.
    slo_violations: u64,
}

impl<'rt> Coordinator<'rt> {
    /// Build an engine over `rt`'s AOT artifacts (or their simulated
    /// fallback) with its own paged-KV pool per `cfg`.
    pub fn new(rt: &'rt Runtime, cfg: CoordinatorConfig) -> Self {
        assert!(cfg.max_active >= 1, "max_active must be positive");
        let bus = MemInterface::system_bus();
        let isax_model = IsaxLlmModel::default();
        let kv_stream_rate = isax_model.mem_bytes_per_cycle(&bus);
        let pool = KvPool::new(&rt.manifest().model, cfg.kv);
        Self {
            rt,
            cfg,
            next_id: 0,
            next_admit: 0,
            pending: VecDeque::new(),
            waiting: VecDeque::new(),
            active: Vec::new(),
            done: Vec::new(),
            pool,
            base_model: BaseCpuModel::default(),
            isax_model,
            bus,
            clock_cycles: 0.0,
            gather_cycles_memo: HashMap::new(),
            kv_stream_rate,
            scratch_k: Vec::new(),
            scratch_v: Vec::new(),
            preemptions: 0,
            record_demand: false,
            step_demand: Vec::new(),
            dma_faults: None,
            load_factor: 1.0,
            degrade: None,
            shed: 0,
            slo_violations: 0,
        }
    }

    /// Record one execution burst for the SoC contention layer (no-op
    /// unless recording was enabled by `SocCoordinator`).
    fn note_demand(&mut self, compute: f64, mem: f64) {
        if self.record_demand {
            self.step_demand.push(TickDemand { compute, mem });
        }
    }

    /// Current simulated time in milliseconds.
    pub fn sim_now_ms(&self) -> f64 {
        self.clock_cycles / self.cfg.llm.clock_hz * 1e3
    }

    /// KV pool accounting (leak check: `stats().leak_free()` once idle).
    pub fn kv_stats(&self) -> KvStats {
        self.pool.stats()
    }

    /// Total preemption events so far.
    pub fn preemptions(&self) -> u64 {
        self.preemptions
    }

    /// Requests shed: by the graceful-degradation ladder (only when the
    /// SoC layer armed it via a fault plan) or by the per-request decode
    /// fuel ceiling ([`CoordinatorConfig::decode_fuel_per_token`]).
    pub fn shed_requests(&self) -> u64 {
        self.shed
    }

    /// Retired requests whose first token landed past its TTFT deadline.
    pub fn slo_violations(&self) -> u64 {
        self.slo_violations
    }

    /// DMA fault accounting as `(retried_bursts, total_retries)`;
    /// `(0, 0)` when no injector is armed.
    pub fn dma_fault_counts(&self) -> (u64, u64) {
        match &self.dma_faults {
            Some(inj) => (inj.retried_bursts(), inj.retries()),
            None => (0, 0),
        }
    }

    fn validate(&self, prompt: &[i32], max_new_tokens: usize) -> Result<()> {
        let m = &self.rt.manifest().model;
        if prompt.is_empty() {
            return Err(Error::Coordinator("empty prompt".into()));
        }
        if max_new_tokens == 0 {
            return Err(Error::Coordinator("max_new_tokens must be positive".into()));
        }
        if prompt.len() > m.prefill_len {
            return Err(Error::Coordinator(format!(
                "prompt len {} exceeds compiled prefill window {}",
                prompt.len(),
                m.prefill_len
            )));
        }
        if prompt.len() + max_new_tokens > m.max_seq {
            return Err(Error::Coordinator(format!(
                "prompt {} + new {} exceeds KV capacity {}",
                prompt.len(),
                max_new_tokens,
                m.max_seq
            )));
        }
        // High-water KV demand: the final token is emitted without a
        // decode step writing its slot (requests satisfied by the prefill
        // token alone retire at admission), so the mark is
        // prompt + max_new - 1 slots.
        let worst = self.pool.blocks_for(prompt.len() + max_new_tokens - 1);
        if worst > self.pool.total_blocks() {
            return Err(Error::Coordinator(format!(
                "request needs up to {worst} KV blocks but the pool only has {}",
                self.pool.total_blocks()
            )));
        }
        Ok(())
    }

    /// Enqueue a prompt arriving *now*; returns the request id.
    pub fn submit(&mut self, prompt: Vec<i32>, max_new_tokens: usize) -> Result<u64> {
        let now = self.sim_now_ms();
        self.submit_at(prompt, max_new_tokens, now)
    }

    /// Enqueue a prompt with an explicit simulated arrival time (trace
    /// replay). Arrivals must be submitted in non-decreasing time order.
    pub fn submit_at(
        &mut self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        arrive_ms: f64,
    ) -> Result<u64> {
        let slo = self.cfg.slo_ttft_ms;
        self.submit_at_with_slo(prompt, max_new_tokens, arrive_ms, slo)
    }

    /// Enqueue a prompt with an explicit arrival time *and* TTFT SLO
    /// (simulated ms) — trace replay with per-request SLO classes (see
    /// [`TraceRequest::slo_factor`]).
    pub fn submit_at_with_slo(
        &mut self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        arrive_ms: f64,
        slo_ttft_ms: f64,
    ) -> Result<u64> {
        self.validate(&prompt, max_new_tokens)?;
        if let Some((last, _, _)) = self.pending.back() {
            if arrive_ms < *last {
                return Err(Error::Coordinator("trace arrivals must be sorted".into()));
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        let req = Request { id, prompt, max_new_tokens };
        self.pending.push_back((arrive_ms, arrive_ms + slo_ttft_ms, req));
        Ok(id)
    }

    /// Enqueue a whole trace; returns the request ids.
    pub fn submit_trace(&mut self, reqs: &[TraceRequest]) -> Result<Vec<u64>> {
        reqs.iter()
            .map(|r| {
                let slo = self.cfg.slo_ttft_ms * r.slo_factor;
                self.submit_at_with_slo(r.prompt.clone(), r.max_new_tokens, r.arrive_ms, slo)
            })
            .collect()
    }

    /// Is there outstanding work?
    pub fn has_work(&self) -> bool {
        !self.pending.is_empty() || !self.waiting.is_empty() || !self.active.is_empty()
    }

    /// One scheduling tick; returns whether anything ran.
    pub fn step(&mut self) -> Result<bool> {
        self.release_arrivals()?;
        // Idle with only future arrivals: fast-forward the clock.
        if self.active.is_empty() && self.waiting.is_empty() {
            match self.pending.front().map(|(t, _, _)| *t) {
                Some(t) => {
                    self.fast_forward_to(t);
                    self.release_arrivals()?;
                }
                None => return Ok(false),
            }
        }
        if self.degrade.is_some() {
            self.degrade_tick();
        }
        let mut ran = false;
        match self.cfg.policy {
            SchedulePolicy::PrefillFirst => {
                while self.try_admit(AdmitOrder::Fifo, false)? {
                    ran = true;
                }
                if !ran && !self.active.is_empty() {
                    self.do_decode_round()?;
                    ran = true;
                }
            }
            SchedulePolicy::DecodeFirst => {
                if !self.active.is_empty() {
                    self.do_decode_round()?;
                    ran = true;
                }
                while self.try_admit(AdmitOrder::Fifo, false)? {
                    ran = true;
                }
            }
            SchedulePolicy::Fair => {
                // Overdue requests jump the decode batch (EDF).
                while self.try_admit(AdmitOrder::Edf, true)? {
                    ran = true;
                }
                if !self.active.is_empty() {
                    self.do_decode_round()?;
                    ran = true;
                }
                while self.try_admit(AdmitOrder::Edf, false)? {
                    ran = true;
                }
            }
        }
        if !ran && self.active.is_empty() {
            // Waiting requests exist but nothing ran — only possible when
            // admission is gated on future arrivals (waiting empty) — or a
            // scheduler bug. Fast-forward if we can; run_to_completion
            // turns a persistent stall into an error.
            if let Some(t) = self.pending.front().map(|(t, _, _)| *t) {
                self.fast_forward_to(t);
                self.release_arrivals()?;
                ran = true;
            }
        }
        Ok(ran)
    }

    /// Drive to completion; returns all request metrics sorted by id.
    pub fn run_to_completion(&mut self) -> Result<Vec<RequestMetrics>> {
        while self.has_work() {
            if !self.step()? && self.has_work() {
                return Err(Error::Coordinator(format!(
                    "scheduler stalled: {} waiting / {} active / {} pending",
                    self.waiting.len(),
                    self.active.len(),
                    self.pending.len()
                )));
            }
        }
        debug_assert!(self.pool.stats().leak_free(), "KV blocks leaked: {:?}", self.pool.stats());
        let mut out = std::mem::take(&mut self.done);
        out.sort_by_key(|m| m.id);
        Ok(out)
    }

    // ----- internals -------------------------------------------------------

    /// Event-simulated DMA cycles to stage `total_blocks` whole KV blocks
    /// through the bus in one tick (memoized per distinct count — the
    /// replay itself is deterministic). A *batched* tick stages every
    /// sequence's blocks back-to-back through one burst queue, so this is
    /// where gathers observe real §4.1 queueing instead of a per-block
    /// closed form.
    fn gather_cycles(&mut self, total_blocks: usize) -> f64 {
        // An active DMA fault injector consumes PRNG state per priced
        // transaction, so gather costs are call-order-dependent (still
        // seeded-deterministic across replays) and must bypass the memo;
        // the clean path below stays untouched so zero-fault runs remain
        // bitwise identical.
        if let Some(inj) = self.dma_faults.as_mut().filter(|i| i.is_active()) {
            return self.isax_model.kv_gather_dma_cycles_faulty(
                &self.cfg.llm,
                &self.bus,
                self.pool.block_slots(),
                total_blocks,
                inj,
            );
        }
        if let Some(&c) = self.gather_cycles_memo.get(&total_blocks) {
            return c;
        }
        let c = self.isax_model.kv_gather_dma_cycles(
            &self.cfg.llm,
            &self.bus,
            self.pool.block_slots(),
            total_blocks,
        );
        self.gather_cycles_memo.insert(total_blocks, c);
        c
    }

    /// Block-granular KV paging cost beyond the ideal contiguous stream
    /// (already charged inside the batched tick) for one sequence at
    /// context length `ctx`: whole blocks are DMA-staged per tick, so the
    /// partially-filled tail block costs real burst cycles.
    fn paging_overhead_cycles(&mut self, ctx: usize) -> f64 {
        let blocks = self.pool.blocks_for(ctx);
        let ideal = self.cfg.llm.kv_bytes(ctx) as f64 / self.kv_stream_rate;
        (self.gather_cycles(blocks) - ideal).max(0.0)
    }

    fn fast_forward_to(&mut self, t_ms: f64) {
        // One extra cycle past the target: the ms -> cycles -> ms round
        // trip can land an ulp *below* `t_ms`, which would leave the
        // arrival unreleased and the scheduler spinning on fast-forwards.
        let cycles = t_ms / 1e3 * self.cfg.llm.clock_hz + 1.0;
        if cycles > self.clock_cycles {
            self.clock_cycles = cycles;
        }
    }

    fn release_arrivals(&mut self) -> Result<()> {
        let now = self.sim_now_ms();
        while self.pending.front().is_some_and(|&(t, _, _)| t <= now) {
            let Some((arrive_ms, deadline_ms, req)) = self.pending.pop_front() else {
                return Err(Error::Coordinator("arrival queue drained mid-release".into()));
            };
            self.waiting.push_back(WaitItem::Fresh { req, arrive_ms, deadline_ms });
        }
        Ok(())
    }

    /// Advance the graceful-degradation ladder one tick: sustained
    /// overload (full batch plus already-overdue waiters) escalates,
    /// sustained calm de-escalates, and at level ≥ 2 hopelessly-late
    /// fresh waiters are shed. Only ever called when the SoC layer armed
    /// the ladder.
    fn degrade_tick(&mut self) {
        let now = self.sim_now_ms();
        let overloaded = self.active.len() >= self.effective_max_active()
            && self.waiting.iter().any(|w| w.deadline_ms() < now);
        let level = match &mut self.degrade {
            Some(d) => {
                if overloaded {
                    d.hot_rounds += 1;
                    d.calm_rounds = 0;
                    if d.hot_rounds >= 3 && d.level < 3 {
                        d.level += 1;
                        d.hot_rounds = 0;
                    }
                } else {
                    d.calm_rounds += 1;
                    d.hot_rounds = 0;
                    if d.calm_rounds >= 6 && d.level > 0 {
                        d.level -= 1;
                        d.calm_rounds = 0;
                    }
                }
                d.level
            }
            None => return,
        };
        if level >= 2 {
            // Shed fresh waiters that are hopelessly late: past their
            // deadline by more than 3x their whole SLO window. Preempted
            // sequences are never shed — their tokens are already owed.
            let mut k = 0;
            while k < self.waiting.len() {
                let hopeless = match &self.waiting[k] {
                    WaitItem::Fresh { arrive_ms, deadline_ms, .. } => {
                        now > *deadline_ms + 3.0 * (deadline_ms - arrive_ms).max(0.0)
                    }
                    WaitItem::Resume(_) => false,
                };
                if hopeless {
                    self.waiting.remove(k);
                    self.shed += 1;
                } else {
                    k += 1;
                }
            }
        }
    }

    /// Batch width after degradation: level 3 halves it (min 1).
    fn effective_max_active(&self) -> usize {
        match &self.degrade {
            Some(d) if d.level >= 3 => (self.cfg.max_active / 2).max(1),
            _ => self.cfg.max_active,
        }
    }

    /// Pick and admit one waiting item. With `overdue_only`, admits only
    /// items whose deadline has already passed. Returns whether one ran.
    fn try_admit(&mut self, order: AdmitOrder, overdue_only: bool) -> Result<bool> {
        if self.waiting.is_empty() || self.active.len() >= self.effective_max_active() {
            return Ok(false);
        }
        let idx = match order {
            AdmitOrder::Fifo => 0,
            AdmitOrder::Edf => {
                let mut best = 0;
                for (i, item) in self.waiting.iter().enumerate() {
                    if item.deadline_ms() < self.waiting[best].deadline_ms() {
                        best = i;
                    }
                }
                best
            }
        };
        if overdue_only && self.waiting[idx].deadline_ms() > self.sim_now_ms() {
            return Ok(false);
        }
        let needed = self.pool.blocks_for(self.waiting[idx].needed_slots());
        if needed > self.pool.free_blocks() {
            return Ok(false);
        }
        // Degradation level >= 1: admission backpressure. Fresh work must
        // leave one spare KV block for the sequences already running (a
        // lone engine with nothing active still admits, or it would
        // deadlock an evacuated shard).
        if let Some(d) = &self.degrade {
            if d.level >= 1
                && !self.active.is_empty()
                && matches!(&self.waiting[idx], WaitItem::Fresh { .. })
                && needed + 1 > self.pool.free_blocks()
            {
                return Ok(false);
            }
        }
        let Some(item) = self.waiting.remove(idx) else {
            return Err(Error::Coordinator("admission picked an out-of-range queue index".into()));
        };
        match item {
            WaitItem::Fresh { req, arrive_ms, deadline_ms } => {
                self.admit_fresh(req, arrive_ms, deadline_ms)?;
            }
            WaitItem::Resume(act) => self.admit_resume(*act)?,
        }
        Ok(true)
    }

    /// Run `llm_prefill` for `prompt`, scatter the caches into `table`,
    /// and return the first generated token.
    fn run_prefill(&mut self, prompt: &[i32], table: &BlockTable) -> Result<i32> {
        let m = self.rt.manifest().model.clone();
        // Right-pad to the compiled prefill window; only the true prompt
        // positions are scattered into blocks, so pad K/V never survives.
        let mut ids = prompt.to_vec();
        ids.resize(m.prefill_len, 0);
        let t = Tensor::i32(ids, &[1, m.prefill_len])?;
        let outs = self.rt.execute("llm_prefill", &[t])?;
        let next = argmax_at(&outs[0], prompt.len() - 1, m.vocab)?;
        self.pool.scatter_prefill(table, prompt.len(), outs[1].as_f32()?, outs[2].as_f32()?);
        Ok(next)
    }

    fn admit_fresh(&mut self, req: Request, arrive_ms: f64, deadline_ms: f64) -> Result<()> {
        let plen = req.prompt.len();
        let mut table = BlockTable::default();
        if !self.pool.ensure_capacity(&mut table, plen) {
            // try_admit checked free capacity; getting here is a bug.
            self.pool.release(&mut table);
            return Err(Error::Coordinator("admission raced the KV pool".into()));
        }
        let next = match self.run_prefill(&req.prompt, &table) {
            Ok(n) => n,
            Err(e) => {
                self.pool.release(&mut table);
                return Err(e);
            }
        };
        // Charge the modelled clock: the ISAX tiles the whole prompt
        // through one weight stream; the scalar baseline walks it
        // token-by-token (weights re-streamed each time).
        let (pc, pm) = self.isax_model.prefill_parts(&self.cfg.llm, plen, &self.bus);
        self.note_demand(pc, pm);
        let mut isax = pc.max(pm) * 1.05;
        // Surge fault windows inflate demand; guarded so unfaulted runs
        // never multiply (bitwise-identity, not just value-identity).
        if self.load_factor != 1.0 {
            isax *= self.load_factor;
        }
        let mut base = 0.0;
        for t in 0..plen {
            base += self.base_model.token_cycles(&self.cfg.llm, t + 1);
        }
        self.clock_cycles += isax;
        let now = self.sim_now_ms();
        let id = req.id;
        let satisfied = req.max_new_tokens <= 1;
        self.active.push(Active {
            req,
            admitted_order: self.next_admit,
            table,
            len: plen,
            generated: vec![next],
            arrive_ms,
            deadline_ms,
            first_token_ms: Some(now),
            last_token_ms: now,
            itl_us: Vec::new(),
            sim_base_cycles: base,
            sim_isax_cycles: isax,
            preemptions: 0,
        });
        self.next_admit += 1;
        // A max_new_tokens == 1 request is satisfied by the prefill token
        // alone — retire it now rather than overshoot by a decode round.
        if satisfied {
            self.retire(id)?;
        }
        Ok(())
    }

    /// Re-admit a preempted sequence: re-prefill the prompt, then replay
    /// its already-emitted tokens to rebuild the KV state (recompute
    /// preemption). Replayed tokens are not re-emitted — metrics keep
    /// their original timestamps; the recompute cost lands on the clock.
    fn admit_resume(&mut self, mut act: Active) -> Result<()> {
        let plen = act.req.prompt.len();
        let total = plen + act.generated.len();
        if !self.pool.ensure_capacity(&mut act.table, total) {
            self.pool.release(&mut act.table);
            return Err(Error::Coordinator("resume admission raced the KV pool".into()));
        }
        let prompt = act.req.prompt.clone();
        let refirst = self.run_prefill(&prompt, &act.table);
        if let Err(e) = refirst {
            self.pool.release(&mut act.table);
            return Err(e);
        }
        act.len = plen;
        let (pc, pm) = self.isax_model.prefill_parts(&self.cfg.llm, plen, &self.bus);
        self.note_demand(pc, pm);
        let mut isax = pc.max(pm) * 1.05;

        // Replay all but the last generated token through single decode
        // steps (the last one is the pending input of the next tick).
        let kvn = self.pool.gathered_elems();
        if self.scratch_k.len() < kvn {
            self.scratch_k.resize(kvn, 0.0);
            self.scratch_v.resize(kvn, 0.0);
        }
        // Gather once: each decode step writes its new slot into the
        // scratch working set in place, so the scratch stays current
        // through the whole replay (scatter_slot only mirrors the new
        // slot back to its block).
        self.pool.gather(
            &act.table,
            act.len,
            &mut self.scratch_k[..kvn],
            &mut self.scratch_v[..kvn],
        );
        let replay: Vec<i32> = act.generated[..act.generated.len() - 1].to_vec();
        for (i, tok) in replay.into_iter().enumerate() {
            let pos = plen + i;
            let step = {
                let mut slots = [DecodeSlot {
                    token: tok,
                    pos,
                    kc: &mut self.scratch_k[..kvn],
                    vc: &mut self.scratch_v[..kvn],
                }];
                self.rt.decode_batch(&mut slots)
            };
            let logits = match step {
                Ok(l) => l,
                Err(e) => {
                    self.pool.release(&mut act.table);
                    return Err(e);
                }
            };
            self.pool.scatter_slot(&act.table, pos, &self.scratch_k[..kvn], &self.scratch_v[..kvn]);
            act.len += 1;
            debug_assert_eq!(
                argmax_row(&logits[0]),
                act.generated[i + 1],
                "replay diverged from the original decode"
            );
            // Same pricing as the regular decode path: batched tick plus
            // the block-granular paging DMA overhead.
            let (tc, tm) = self.isax_model.batch_tick_parts(&self.cfg.llm, &[act.len], &self.bus);
            self.note_demand(tc, tm);
            isax += tc.max(tm) * 1.05;
            isax += self.paging_overhead_cycles(act.len);
        }
        if self.load_factor != 1.0 {
            isax *= self.load_factor;
        }
        self.clock_cycles += isax;
        act.sim_isax_cycles += isax;
        act.admitted_order = self.next_admit;
        self.next_admit += 1;
        self.active.push(act);
        Ok(())
    }

    /// Reclaim the blocks of `active[idx]` and push it back to the head
    /// of the waiting queue for recompute re-admission.
    fn preempt(&mut self, idx: usize) {
        let mut act = self.active.remove(idx);
        self.pool.release(&mut act.table);
        act.len = 0;
        act.preemptions += 1;
        self.preemptions += 1;
        self.waiting.push_front(WaitItem::Resume(Box::new(act)));
    }

    /// Make sure sequence `id` owns blocks for one more slot, preempting
    /// the most recently admitted *other* sequence while the pool is dry.
    fn grow_kv(&mut self, id: u64) -> Result<()> {
        loop {
            let Some(idx) = self.active.iter().position(|a| a.req.id == id) else {
                return Ok(()); // preempted by an earlier grow this round
            };
            let need = self.active[idx].len + 1;
            if self.pool.ensure_capacity(&mut self.active[idx].table, need) {
                return Ok(());
            }
            let victim = self
                .active
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != idx)
                .max_by_key(|(_, a)| a.admitted_order)
                .map(|(i, _)| i);
            match victim {
                Some(vi) => self.preempt(vi),
                None => {
                    return Err(Error::Coordinator(
                        "KV pool exhausted by a single sequence".into(),
                    ))
                }
            }
        }
    }

    /// Advance every active sequence one token in a single batched tick.
    fn do_decode_round(&mut self) -> Result<()> {
        let ids: Vec<u64> = self.active.iter().map(|a| a.req.id).collect();
        // Phase A: secure the next slot per sequence (may preempt).
        for &id in &ids {
            self.grow_kv(id)?;
        }
        let batch: Vec<u64> = ids
            .into_iter()
            .filter(|id| self.active.iter().any(|a| a.req.id == *id))
            .collect();
        if batch.is_empty() {
            return Ok(());
        }

        // Phase B: gather each sequence's blocks into the persistent
        // scratch working sets and run the batched decode path (no
        // per-token heap churn on the hot path).
        let kvn = self.pool.gathered_elems();
        let n = batch.len();
        if self.scratch_k.len() < n * kvn {
            self.scratch_k.resize(n * kvn, 0.0);
            self.scratch_v.resize(n * kvn, 0.0);
        }
        let mut feeds: Vec<(i32, usize)> = Vec::with_capacity(n);
        for (bi, id) in batch.iter().enumerate() {
            let Some(act) = self.active.iter().find(|a| a.req.id == *id) else {
                return Err(Error::Coordinator(format!(
                    "batch member {id} vanished before gather"
                )));
            };
            self.pool.gather(
                &act.table,
                act.len,
                &mut self.scratch_k[bi * kvn..(bi + 1) * kvn],
                &mut self.scratch_v[bi * kvn..(bi + 1) * kvn],
            );
            let Some(&last_tok) = act.generated.last() else {
                return Err(Error::Coordinator(format!(
                    "sequence {id} has no pending token"
                )));
            };
            feeds.push((last_tok, act.len));
        }
        let logits = {
            let mut slots: Vec<DecodeSlot<'_>> = self
                .scratch_k
                .chunks_mut(kvn)
                .zip(self.scratch_v.chunks_mut(kvn))
                .zip(&feeds)
                .map(|((kc, vc), &(token, pos))| DecodeSlot { token, pos, kc, vc })
                .collect();
            self.rt.decode_batch(&mut slots)?
        };

        // Charge the modelled clock: one batched tick (weights streamed
        // once across the batch) + the paged-KV DMA-burst overhead of
        // staging every sequence's whole blocks through one event-
        // simulated burst queue instead of an ideal contiguous stream —
        // the batch's gathers contend for the same bus, and the §4.1
        // in-flight window pipelines across block boundaries.
        let ctxs: Vec<usize> = feeds.iter().map(|&(_, pos)| pos + 1).collect();
        let (tc, tm) = self.isax_model.batch_tick_parts(&self.cfg.llm, &ctxs, &self.bus);
        self.note_demand(tc, tm);
        let mut tick = tc.max(tm) * 1.05;
        let total_blocks: usize = ctxs.iter().map(|&c| self.pool.blocks_for(c)).sum();
        let ideal: f64 =
            ctxs.iter().map(|&c| self.cfg.llm.kv_bytes(c) as f64 / self.kv_stream_rate).sum();
        tick += (self.gather_cycles(total_blocks) - ideal).max(0.0);
        if self.load_factor != 1.0 {
            tick *= self.load_factor;
        }
        self.clock_cycles += tick;
        let share = tick / batch.len() as f64;
        let now = self.sim_now_ms();
        let max_seq = self.rt.manifest().model.max_seq;

        // Phase C: commit tokens, timestamps and retirements.
        let mut retired = Vec::new();
        for (i, id) in batch.iter().enumerate() {
            let next = argmax_row(&logits[i]);
            let Some(idx) = self.active.iter().position(|a| a.req.id == *id) else {
                return Err(Error::Coordinator(format!(
                    "batch member {id} vanished before commit"
                )));
            };
            self.pool.scatter_slot(
                &self.active[idx].table,
                self.active[idx].len,
                &self.scratch_k[i * kvn..(i + 1) * kvn],
                &self.scratch_v[i * kvn..(i + 1) * kvn],
            );
            let act = &mut self.active[idx];
            act.len += 1;
            act.generated.push(next);
            act.itl_us.push(ms_delta_us(act.last_token_ms, now));
            act.last_token_ms = now;
            act.sim_isax_cycles += share;
            act.sim_base_cycles += self.base_model.token_cycles(&self.cfg.llm, act.len);
            // Fuel ceiling: a sequence whose simulated decode spend blows
            // past its per-token allowance is cut off and counted as shed
            // (PR 7 degradation ladder semantics) — the already-generated
            // prefix is still delivered through normal retirement.
            let over_fuel = self.cfg.decode_fuel_per_token.is_some_and(|per_tok| {
                act.sim_isax_cycles > per_tok * act.req.max_new_tokens as f64
            });
            if act.generated.len() >= act.req.max_new_tokens || act.len >= max_seq {
                retired.push(*id);
            } else if over_fuel {
                retired.push(*id);
                self.shed += 1;
            }
        }
        for id in retired {
            self.retire(id)?;
        }
        Ok(())
    }

    fn retire(&mut self, id: u64) -> Result<()> {
        let Some(idx) = self.active.iter().position(|a| a.req.id == id) else {
            return Err(Error::Coordinator(format!("retiring unknown sequence {id}")));
        };
        let mut act = self.active.remove(idx);
        self.pool.release(&mut act.table);
        let Some(first) = act.first_token_ms else {
            return Err(Error::Coordinator(format!(
                "sequence {id} retired before its first token"
            )));
        };
        // Observational SLO accounting — never changes scheduling.
        if first > act.deadline_ms {
            self.slo_violations += 1;
        }
        self.done.push(RequestMetrics {
            id: act.req.id,
            prompt_len: act.req.prompt.len(),
            generated: act.generated,
            ttft_us: ms_delta_us(act.arrive_ms, first),
            itl_us: act.itl_us,
            sim_base_cycles: act.sim_base_cycles,
            sim_isax_cycles: act.sim_isax_cycles,
            preemptions: act.preemptions,
        });
        Ok(())
    }
}

#[derive(Clone, Copy)]
enum AdmitOrder {
    Fifo,
    Edf,
}

/// Simulated-ms interval as non-negative µs.
fn ms_delta_us(from_ms: f64, to_ms: f64) -> u128 {
    ((to_ms - from_ms).max(0.0) * 1e3).round() as u128
}

/// Argmax over logits[0, pos, :] of a [1, T, V] tensor.
fn argmax_at(logits: &Tensor, pos: usize, vocab: usize) -> Result<i32> {
    let data = logits.as_f32()?;
    let row = &data[pos * vocab..(pos + 1) * vocab];
    let mut best = 0;
    for (i, &x) in row.iter().enumerate() {
        if x > row[best] {
            best = i;
        }
    }
    Ok(best as i32)
}

/// Argmax over one logits row (strict `>`, first-wins — matches the
/// tensor argmax the monolithic path used).
fn argmax_row(row: &[f32]) -> i32 {
    let mut best = 0;
    for (i, &x) in row.iter().enumerate() {
        if x > row[best] {
            best = i;
        }
    }
    best as i32
}
