//! Deterministic fault plans for chaos-testing the serving stack.
//!
//! A [`FaultPlan`] is parsed from a compact spec string using the same
//! `key=value,key=value` grammar as [`super::TraceSpec`]:
//!
//! | key | form | meaning |
//! |-----|------|---------|
//! | `coredown` | `coredown=k@t` | core `k` dies permanently at `t` ms |
//! | `corestall` | `corestall=k@t0..t1` | core `k` freezes over `[t0, t1)` ms |
//! | `dmaerr` | `dmaerr=p` | each DMA transaction fails with probability `p` |
//! | `seed` | `seed=s` | PRNG seed for the DMA error draws |
//! | `surge` | `surge=x@t0..t1` | compute demand multiplied by `x` over `[t0, t1)` ms |
//!
//! Repeated `coredown`/`corestall`/`surge` keys append additional events.
//! All faults are deterministic: the same plan (including `seed`) replayed
//! against the same trace produces bitwise-identical serving output, which
//! is what makes chaos schedules assertable in tests and CI.

use crate::error::{Error, Result};

/// A deterministic, seeded schedule of faults to inject into an SoC
/// serving run.
///
/// The default (empty) plan injects nothing and is guaranteed not to
/// perturb any serving output: every fault hook early-returns when the
/// plan is empty, so zero-fault runs stay bitwise identical to a build
/// without fault injection at all.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Permanent core deaths as `(core, t_ms)` pairs.
    pub core_down: Vec<(usize, f64)>,
    /// Transient core stalls as `(core, t0_ms, t1_ms)` windows.
    pub core_stall: Vec<(usize, f64, f64)>,
    /// Per-transaction DMA error probability in `[0, 1]`.
    pub dma_err: f64,
    /// Seed for the deterministic DMA error draws.
    pub seed: u64,
    /// Compute surges as `(factor, t0_ms, t1_ms)` windows; overlapping
    /// windows multiply.
    pub surge: Vec<(f64, f64, f64)>,
}

impl FaultPlan {
    /// True when the plan injects nothing (a bare `seed=` does not count
    /// as a fault).
    pub fn is_empty(&self) -> bool {
        self.core_down.is_empty()
            && self.core_stall.is_empty()
            && self.surge.is_empty()
            && self.dma_err == 0.0
    }

    /// Parse a fault spec string such as
    /// `coredown=1@40,corestall=2@30..120,dmaerr=0.05,seed=9,surge=2@0..50`.
    ///
    /// Every malformed part — unknown key, missing `@`, non-numeric
    /// field, reversed time range, out-of-range probability, or an empty
    /// spec — yields a diagnostic [`Error::Coordinator`]; parsing never
    /// panics and never silently falls back to a default.
    pub fn parse(text: &str) -> Result<Self> {
        let mut plan = FaultPlan::default();
        let mut any = false;
        for part in text.split(',').filter(|p| !p.is_empty()) {
            let (key, val) = part.split_once('=').ok_or_else(|| {
                Error::Coordinator(format!("fault spec `{part}`: expected key=value"))
            })?;
            let bad =
                |what: &str| Error::Coordinator(format!("fault spec {key}={val}: {what}"));
            match key {
                "coredown" => {
                    let (core, at) = val
                        .split_once('@')
                        .ok_or_else(|| bad("expected core@t_ms"))?;
                    let core: usize =
                        core.parse().map_err(|_| bad("core index must be an integer"))?;
                    let t: f64 = at.parse().map_err(|_| bad("time must be a number"))?;
                    if !t.is_finite() || t < 0.0 {
                        return Err(bad("time must be finite and non-negative"));
                    }
                    plan.core_down.push((core, t));
                }
                "corestall" => {
                    let (core, window) = val
                        .split_once('@')
                        .ok_or_else(|| bad("expected core@t0..t1"))?;
                    let core: usize =
                        core.parse().map_err(|_| bad("core index must be an integer"))?;
                    let (t0, t1) = parse_ms_range(window)
                        .ok_or_else(|| bad("expected a t0..t1 millisecond range"))?;
                    if t1 < t0 {
                        return Err(bad("reversed time range"));
                    }
                    plan.core_stall.push((core, t0, t1));
                }
                "dmaerr" => {
                    let p: f64 = val.parse().map_err(|_| bad("must be a number"))?;
                    if !p.is_finite() || !(0.0..=1.0).contains(&p) {
                        return Err(bad("probability must be in 0..=1"));
                    }
                    plan.dma_err = p;
                }
                "seed" => {
                    plan.seed =
                        val.parse().map_err(|_| bad("must be an unsigned integer"))?;
                }
                "surge" => {
                    let (factor, window) = val
                        .split_once('@')
                        .ok_or_else(|| bad("expected factor@t0..t1"))?;
                    let x: f64 =
                        factor.parse().map_err(|_| bad("factor must be a number"))?;
                    if !x.is_finite() || x < 1.0 {
                        return Err(bad("surge factor must be finite and >= 1"));
                    }
                    let (t0, t1) = parse_ms_range(window)
                        .ok_or_else(|| bad("expected a t0..t1 millisecond range"))?;
                    if t1 < t0 {
                        return Err(bad("reversed time range"));
                    }
                    plan.surge.push((x, t0, t1));
                }
                _ => {
                    return Err(Error::Coordinator(format!(
                        "fault spec: unknown key `{key}`"
                    )));
                }
            }
            any = true;
        }
        if !any {
            return Err(Error::Coordinator("fault spec: empty spec".into()));
        }
        Ok(plan)
    }
}

/// Parse `t0..t1` into a pair of finite non-negative milliseconds.
fn parse_ms_range(text: &str) -> Option<(f64, f64)> {
    let (lo, hi) = text.split_once("..")?;
    let lo: f64 = lo.parse().ok()?;
    let hi: f64 = hi.parse().ok()?;
    if !lo.is_finite() || !hi.is_finite() || lo < 0.0 {
        return None;
    }
    Some((lo, hi))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_key_and_appends_repeats() {
        let plan = FaultPlan::parse(
            "coredown=1@40,coredown=3@60,corestall=2@30..120,dmaerr=0.05,seed=9,surge=2@0..50",
        )
        .unwrap();
        assert_eq!(plan.core_down, vec![(1, 40.0), (3, 60.0)]);
        assert_eq!(plan.core_stall, vec![(2, 30.0, 120.0)]);
        assert_eq!(plan.dma_err, 0.05);
        assert_eq!(plan.seed, 9);
        assert_eq!(plan.surge, vec![(2.0, 0.0, 50.0)]);
        assert!(!plan.is_empty());
    }

    #[test]
    fn bare_seed_still_counts_as_empty_plan() {
        let plan = FaultPlan::parse("seed=42").unwrap();
        assert!(plan.is_empty());
        assert_eq!(plan.seed, 42);
    }

    #[test]
    fn every_malformed_spec_is_a_diagnostic_error() {
        // (spec, substring expected in the diagnostic)
        let cases = [
            ("", "empty spec"),
            (",", "empty spec"),
            ("coredown", "expected key=value"),
            ("coredown=1", "expected core@t_ms"),
            ("coredown=x@40", "core index must be an integer"),
            ("coredown=1@fast", "time must be a number"),
            ("coredown=1@-5", "finite and non-negative"),
            ("corestall=2@30", "expected core@t0..t1"),
            ("corestall=2@120..30", "reversed time range"),
            ("corestall=2@a..b", "t0..t1 millisecond range"),
            ("dmaerr=maybe", "must be a number"),
            ("dmaerr=1.5", "probability must be in 0..=1"),
            ("seed=-1", "unsigned integer"),
            ("surge=0.5@0..10", "must be finite and >= 1"),
            ("surge=2@10..5", "reversed time range"),
            ("surge=2", "expected factor@t0..t1"),
            ("warp=9", "unknown key"),
            ("=", "expected key=value"),
        ];
        for (spec, needle) in cases {
            let err = FaultPlan::parse(spec).unwrap_err();
            let msg = err.to_string();
            assert!(
                msg.contains(needle),
                "spec `{spec}` gave `{msg}`, expected it to mention `{needle}`"
            );
        }
    }
}
