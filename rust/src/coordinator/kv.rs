//! KV-cache state for one active sequence.
//!
//! The artifacts use fixed-capacity caches (`[L, B, H, max_seq, Dh]`) with
//! a scalar cursor: slots `< len` are valid; `llm_decode` writes slot
//! `len` and the attention masks everything beyond. This is the
//! paged-attention-without-paging layout appropriate for a batch-1 edge
//! SoC (one contiguous region per sequence).

use crate::runtime::Tensor;

/// KV tensors + cursor for one sequence.
#[derive(Debug, Clone)]
pub struct KvState {
    pub k: Tensor,
    pub v: Tensor,
    len: usize,
}

impl KvState {
    pub fn new(k: Tensor, v: Tensor, len: usize) -> Self {
        debug_assert_eq!(k.shape(), v.shape());
        Self { k, v, len }
    }

    /// Number of valid positions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Total capacity (max_seq dimension).
    pub fn capacity(&self) -> usize {
        // [L, B, H, max_seq, Dh]
        self.k.shape()[3]
    }

    /// Remaining slots.
    pub fn remaining(&self) -> usize {
        self.capacity().saturating_sub(self.len)
    }
}
