//! Paged KV-cache allocator.
//!
//! The serving engine no longer keeps one monolithic `[L, B, H, max_seq,
//! Dh]` tensor pair per sequence. Instead a [`KvPool`] owns a fixed pool
//! of equal-sized *blocks* (each holding `block_slots` token positions of
//! K and V for every layer/head), handed out through a free list. Each
//! active sequence maps its logical slots onto blocks through a
//! [`BlockTable`]; admission control queues or preempts when the pool
//! runs dry.
//!
//! Execution still needs the model's contiguous `[L, H, max_seq, Dh]`
//! layout (the simulated backend mirrors the AOT artifact geometry), so
//! the pool provides `gather`/`scatter` staging: blocks are DMA-staged
//! into a per-tick scratch working set, the decode step writes one new
//! slot, and that slot is scattered back to its block. This is the
//! block-structured accelerator-memory discipline of the paper's §4
//! scratchpads applied to the serving layer — storage at rest is paged,
//! execution sees a gathered tile.
//!
//! Block layout (per block, per direction): `[L, H, block_slots, Dh]`
//! row-major, so one `(layer, block)` pair is a contiguous burst for the
//! DMA cost model.

use crate::runtime::ModelSpec;

/// Paged-allocator configuration.
#[derive(Debug, Clone, Copy)]
pub struct PagedKvConfig {
    /// Token positions per block.
    pub block_slots: usize,
    /// Total blocks in the pool (shared by all sequences).
    pub num_blocks: usize,
}

impl Default for PagedKvConfig {
    fn default() -> Self {
        // For the tiny artifact model (max_seq = 64): 8-slot blocks, a
        // pool deep enough for 8 fully-grown sequences.
        Self { block_slots: 8, num_blocks: 64 }
    }
}

/// Index of a block within the pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockId(pub u32);

/// One sequence's slot → block mapping.
#[derive(Debug, Clone, Default)]
pub struct BlockTable {
    blocks: Vec<BlockId>,
}

impl BlockTable {
    /// Blocks currently held.
    pub fn len(&self) -> usize {
        self.blocks.len()
    }

    /// True when the table holds no blocks.
    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    /// Slot capacity of the held blocks.
    pub fn capacity(&self, block_slots: usize) -> usize {
        self.blocks.len() * block_slots
    }
}

/// Pool statistics (leak checking + bench reporting).
#[derive(Debug, Clone, Copy, Default)]
pub struct KvStats {
    /// Pool size, blocks.
    pub total_blocks: usize,
    /// Token positions per block (the pool's actual geometry, so
    /// reporting never has to re-derive it from a config default).
    pub block_slots: usize,
    /// Blocks currently on the free list.
    pub free_blocks: usize,
    /// High-water mark of blocks simultaneously allocated.
    pub peak_in_use: usize,
    /// Lifetime block allocations.
    pub allocs: u64,
    /// Lifetime block frees.
    pub frees: u64,
}

impl KvStats {
    /// Every allocated block has been returned.
    pub fn leak_free(&self) -> bool {
        self.free_blocks == self.total_blocks
    }
}

/// The paged block pool: backing storage + free list.
#[derive(Debug)]
pub struct KvPool {
    layers: usize,
    heads: usize,
    head_dim: usize,
    max_seq: usize,
    block_slots: usize,
    num_blocks: usize,
    /// Block storage, `num_blocks × [L, H, block_slots, Dh]` each.
    k: Vec<f32>,
    v: Vec<f32>,
    /// LIFO free list of block indices.
    free: Vec<BlockId>,
    peak_in_use: usize,
    allocs: u64,
    frees: u64,
}

impl KvPool {
    /// Build a pool sized for `model`'s cache geometry per `cfg`.
    pub fn new(model: &ModelSpec, cfg: PagedKvConfig) -> Self {
        assert!(cfg.block_slots > 0, "zero-slot blocks");
        assert!(cfg.num_blocks > 0, "empty pool");
        let block_elems = model.n_layers * model.n_heads * cfg.block_slots * model.head_dim;
        Self {
            layers: model.n_layers,
            heads: model.n_heads,
            head_dim: model.head_dim,
            max_seq: model.max_seq,
            block_slots: cfg.block_slots,
            num_blocks: cfg.num_blocks,
            k: vec![0.0; block_elems * cfg.num_blocks],
            v: vec![0.0; block_elems * cfg.num_blocks],
            // Hand out low ids first (pop from the back).
            free: (0..cfg.num_blocks as u32).rev().map(BlockId).collect(),
            peak_in_use: 0,
            allocs: 0,
            frees: 0,
        }
    }

    /// Token positions per block.
    pub fn block_slots(&self) -> usize {
        self.block_slots
    }

    /// Pool size, blocks.
    pub fn total_blocks(&self) -> usize {
        self.num_blocks
    }

    /// Blocks currently on the free list.
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Accounting snapshot (leak checking + bench reporting).
    pub fn stats(&self) -> KvStats {
        KvStats {
            total_blocks: self.total_blocks(),
            block_slots: self.block_slots,
            free_blocks: self.free.len(),
            peak_in_use: self.peak_in_use,
            allocs: self.allocs,
            frees: self.frees,
        }
    }

    /// Blocks needed to hold `slots` token positions.
    pub fn blocks_for(&self, slots: usize) -> usize {
        slots.div_ceil(self.block_slots)
    }

    fn block_elems(&self) -> usize {
        self.layers * self.heads * self.block_slots * self.head_dim
    }

    /// Elements of one gathered `[L, H, max_seq, Dh]` working set.
    pub fn gathered_elems(&self) -> usize {
        self.layers * self.heads * self.max_seq * self.head_dim
    }

    /// Grow `table` until it covers `slots` positions; returns false
    /// (table unchanged beyond partial growth kept) if the pool runs out.
    pub fn ensure_capacity(&mut self, table: &mut BlockTable, slots: usize) -> bool {
        while table.capacity(self.block_slots) < slots {
            match self.free.pop() {
                Some(b) => {
                    self.allocs += 1;
                    table.blocks.push(b);
                    let in_use = self.total_blocks() - self.free.len();
                    self.peak_in_use = self.peak_in_use.max(in_use);
                }
                None => return false,
            }
        }
        true
    }

    /// Return every block of `table` to the free list.
    pub fn release(&mut self, table: &mut BlockTable) {
        for b in table.blocks.drain(..) {
            self.frees += 1;
            debug_assert!(!self.free.contains(&b), "double free of block {b:?}");
            self.free.push(b);
        }
    }

    /// Offset of `(layer, head, offset-in-block)` within one block.
    fn in_block_index(&self, layer: usize, head: usize, off: usize) -> usize {
        ((layer * self.heads + head) * self.block_slots + off) * self.head_dim
    }

    /// Offset of `(layer, head, slot)` within a gathered working set
    /// (matches the simulated backend's cache layout).
    fn gathered_index(&self, layer: usize, head: usize, slot: usize) -> usize {
        ((layer * self.heads + head) * self.max_seq + slot) * self.head_dim
    }

    /// Stage slots `0..len` of a sequence into contiguous `[L, H, max_seq,
    /// Dh]` working sets; positions `>= len` are zeroed (the model never
    /// attends them — slot `len` is written by the decode step itself).
    pub fn gather(&self, table: &BlockTable, len: usize, kc: &mut [f32], vc: &mut [f32]) {
        debug_assert_eq!(kc.len(), self.gathered_elems());
        debug_assert_eq!(vc.len(), self.gathered_elems());
        debug_assert!(len <= table.capacity(self.block_slots), "table under-allocated");
        kc.fill(0.0);
        vc.fill(0.0);
        let dh = self.head_dim;
        let be = self.block_elems();
        for (bi, b) in table.blocks.iter().enumerate() {
            let base = b.0 as usize * be;
            let first = bi * self.block_slots;
            if first >= len {
                break;
            }
            let fill = (len - first).min(self.block_slots);
            for l in 0..self.layers {
                for h in 0..self.heads {
                    for off in 0..fill {
                        let src = base + self.in_block_index(l, h, off);
                        let dst = self.gathered_index(l, h, first + off);
                        kc[dst..dst + dh].copy_from_slice(&self.k[src..src + dh]);
                        vc[dst..dst + dh].copy_from_slice(&self.v[src..src + dh]);
                    }
                }
            }
        }
    }

    /// Write back one slot from a gathered working set into its block
    /// (the slot the decode step just produced).
    pub fn scatter_slot(&mut self, table: &BlockTable, slot: usize, kc: &[f32], vc: &[f32]) {
        debug_assert!(slot < table.capacity(self.block_slots), "slot beyond table");
        let b = table.blocks[slot / self.block_slots];
        let off = slot % self.block_slots;
        let base = b.0 as usize * self.block_elems();
        let dh = self.head_dim;
        for l in 0..self.layers {
            for h in 0..self.heads {
                let dst = base + self.in_block_index(l, h, off);
                let src = self.gathered_index(l, h, slot);
                self.k[dst..dst + dh].copy_from_slice(&kc[src..src + dh]);
                self.v[dst..dst + dh].copy_from_slice(&vc[src..src + dh]);
            }
        }
    }

    /// Scatter slots `0..len` of full `[L, B=1, H, max_seq, Dh]` prefill
    /// caches into the sequence's blocks (padded prefill positions beyond
    /// `len` are dropped — they hold pad-token K/V nothing may attend).
    pub fn scatter_prefill(&mut self, table: &BlockTable, len: usize, kc: &[f32], vc: &[f32]) {
        debug_assert_eq!(kc.len(), self.gathered_elems(), "prefill cache geometry");
        debug_assert!(len <= table.capacity(self.block_slots), "table under-allocated");
        let dh = self.head_dim;
        let be = self.block_elems();
        for (bi, b) in table.blocks.iter().enumerate() {
            let base = b.0 as usize * be;
            let first = bi * self.block_slots;
            if first >= len {
                break;
            }
            let fill = (len - first).min(self.block_slots);
            for l in 0..self.layers {
                for h in 0..self.heads {
                    for off in 0..fill {
                        let dst = base + self.in_block_index(l, h, off);
                        let src = self.gathered_index(l, h, first + off);
                        self.k[dst..dst + dh].copy_from_slice(&kc[src..src + dh]);
                        self.v[dst..dst + dh].copy_from_slice(&vc[src..src + dh]);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ModelSpec {
        ModelSpec {
            vocab: 16,
            dim: 8,
            n_layers: 2,
            n_heads: 2,
            head_dim: 4,
            hidden: 16,
            max_seq: 16,
            prefill_len: 8,
            batch: 1,
            param_count: 0,
        }
    }

    #[test]
    fn alloc_free_roundtrip_is_leak_free() {
        let mut pool = KvPool::new(&model(), PagedKvConfig { block_slots: 4, num_blocks: 6 });
        let mut t1 = BlockTable::default();
        let mut t2 = BlockTable::default();
        assert!(pool.ensure_capacity(&mut t1, 7)); // 2 blocks
        assert!(pool.ensure_capacity(&mut t2, 9)); // 3 blocks
        assert_eq!(pool.free_blocks(), 1);
        assert_eq!(pool.stats().peak_in_use, 5);
        // Pool exhaustion is reported, not panicked.
        assert!(!pool.ensure_capacity(&mut t1, 13));
        pool.release(&mut t1);
        pool.release(&mut t2);
        let s = pool.stats();
        assert!(s.leak_free(), "{s:?}");
        assert_eq!(s.allocs, s.frees);
    }

    #[test]
    fn gather_scatter_roundtrips_slots() {
        let m = model();
        let mut pool = KvPool::new(&m, PagedKvConfig { block_slots: 4, num_blocks: 8 });
        let n = pool.gathered_elems();
        let mut table = BlockTable::default();
        assert!(pool.ensure_capacity(&mut table, 6));

        // Write slots 0..6 one at a time through scatter_slot, with
        // distinct per-slot values.
        for slot in 0..6usize {
            let mut kc = vec![0.0f32; n];
            let mut vc = vec![0.0f32; n];
            for l in 0..m.n_layers {
                for h in 0..m.n_heads {
                    let at = pool.gathered_index(l, h, slot);
                    for d in 0..m.head_dim {
                        kc[at + d] = (slot * 100 + l * 10 + h) as f32 + d as f32 * 0.1;
                        vc[at + d] = -(kc[at + d]);
                    }
                }
            }
            pool.scatter_slot(&table, slot, &kc, &vc);
        }

        // Gather back and check every written slot, plus zeroed tail.
        let mut kc = vec![9.0f32; n];
        let mut vc = vec![9.0f32; n];
        pool.gather(&table, 6, &mut kc, &mut vc);
        for slot in 0..6usize {
            for l in 0..m.n_layers {
                for h in 0..m.n_heads {
                    let at = pool.gathered_index(l, h, slot);
                    for d in 0..m.head_dim {
                        let want = (slot * 100 + l * 10 + h) as f32 + d as f32 * 0.1;
                        assert_eq!(kc[at + d], want, "k slot {slot} l{l} h{h} d{d}");
                        assert_eq!(vc[at + d], -want, "v slot {slot} l{l} h{h} d{d}");
                    }
                }
            }
        }
        let tail = pool.gathered_index(0, 0, 6);
        assert!(kc[tail..tail + m.head_dim].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn prefill_scatter_matches_slotwise_writes() {
        let m = model();
        let mut pool = KvPool::new(&m, PagedKvConfig { block_slots: 4, num_blocks: 8 });
        let n = pool.gathered_elems();
        let mut full_k = vec![0.0f32; n];
        let mut full_v = vec![0.0f32; n];
        for (i, x) in full_k.iter_mut().enumerate() {
            *x = i as f32;
        }
        for (i, x) in full_v.iter_mut().enumerate() {
            *x = i as f32 * 2.0;
        }
        let mut table = BlockTable::default();
        assert!(pool.ensure_capacity(&mut table, 5));
        pool.scatter_prefill(&table, 5, &full_k, &full_v);
        let mut kc = vec![0.0f32; n];
        let mut vc = vec![0.0f32; n];
        pool.gather(&table, 5, &mut kc, &mut vc);
        for l in 0..m.n_layers {
            for h in 0..m.n_heads {
                for slot in 0..5usize {
                    let at = pool.gathered_index(l, h, slot);
                    assert_eq!(&kc[at..at + m.head_dim], &full_k[at..at + m.head_dim]);
                    assert_eq!(&vc[at..at + m.head_dim], &full_v[at..at + m.head_dim]);
                }
                // Ungathered tail slots are zero, not stale prefill pad.
                let at = pool.gathered_index(l, h, 5);
                assert!(kc[at..at + m.head_dim].iter().all(|&x| x == 0.0));
            }
        }
    }
}
