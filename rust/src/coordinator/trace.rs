//! Deterministic request-trace generation + replay specs.
//!
//! A [`TraceSpec`] describes a synthetic arrival process compactly enough
//! to put on a CLI (`aquas serve --trace n=16,seed=7,rate=4,plen=4..12,
//! gen=6..14`); [`TraceSpec::generate`] expands it into concrete
//! [`TraceRequest`]s, all drawn from the seeded in-crate PRNG so two
//! replays of the same spec are byte-identical.
//!
//! Grammar (comma-separated `key=value` over the defaults):
//!
//! | key     | meaning                                                    |
//! |---------|------------------------------------------------------------|
//! | `n`     | request count                                              |
//! | `seed`  | PRNG seed                                                  |
//! | `rate`  | mean offered load, requests per simulated second (0 = all at t0) |
//! | `plen`  | prompt-length range `lo..hi`, inclusive                    |
//! | `gen`   | generation-length range `lo..hi`, inclusive                |
//! | `burst` | mean arrival-burst size (≥ 1; 1 = plain Poisson)           |
//! | `tail`  | heavy-tail probability: gen drawn from `gen.hi+1..=4·gen.hi` |
//! | `mix`   | interactive fraction: tagged with a 4× tighter TTFT SLO    |
//!
//! `burst`/`tail`/`mix` at their defaults draw *nothing* from the PRNG,
//! so every pre-SoC spec still expands to a byte-identical trace.

use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// One request of a serving trace.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    /// Arrival time on the simulated SoC clock, in milliseconds.
    pub arrive_ms: f64,
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Generation budget (the sequence retires after this many tokens).
    pub max_new_tokens: usize,
    /// Multiplier on the engine's TTFT SLO for this request: `1.0` for
    /// batch-class traffic, `< 1` for interactive-class traffic whose
    /// deadline is tighter (see [`TraceSpec`]'s `mix` knob and
    /// [`super::SchedulePolicy::Fair`]).
    pub slo_factor: f64,
}

/// A compact, deterministic trace description.
#[derive(Debug, Clone)]
pub struct TraceSpec {
    /// Number of requests.
    pub n: usize,
    /// PRNG seed (prompts, lengths, arrivals).
    pub seed: u64,
    /// Mean arrival rate in requests per simulated second (Poisson
    /// process). `0` means all requests arrive at t = 0.
    pub rate: f64,
    /// Prompt length range (inclusive), clamped to the prefill window.
    pub plen: (usize, usize),
    /// Generation length range (inclusive).
    pub gen: (usize, usize),
    /// Mean burst size, ≥ 1. Arrivals come in geometric bursts of this
    /// mean, back-to-back within a burst, separated by exponential gaps
    /// of mean `burst/rate` — the long-run offered load stays `rate`,
    /// but queues see the heavy-tailed churn real front-ends produce.
    /// `1.0` is the plain Poisson process of the pre-SoC grammar.
    pub burst: f64,
    /// Heavy-tail probability in `[0, 1]`: with this probability a
    /// request's generation length is drawn from the stretched range
    /// `gen.1+1 ..= 4·gen.1` instead of `gen` (always clamped to the
    /// serving window by [`TraceSpec::generate_capped`]). `0` disables.
    pub tail: f64,
    /// Interactive-class probability in `[0, 1]`: with this probability
    /// a request is tagged with `slo_factor = 0.25` (a 4× tighter TTFT
    /// deadline under [`super::SchedulePolicy::Fair`]). `0` disables.
    pub mix: f64,
}

impl Default for TraceSpec {
    fn default() -> Self {
        Self {
            n: 16,
            seed: 7,
            rate: 2.0,
            plen: (4, 12),
            gen: (6, 14),
            burst: 1.0,
            tail: 0.0,
            mix: 0.0,
        }
    }
}

impl TraceSpec {
    /// Parse the CLI form: comma-separated `key=value` pairs over the
    /// defaults, e.g. `n=16,seed=7,rate=4,plen=4..12,gen=6..14,burst=4,
    /// tail=0.25,mix=0.5`.
    pub fn parse(text: &str) -> Result<Self> {
        if text.split(',').all(|p| p.is_empty()) {
            // An empty spec is almost certainly a quoting mistake on the
            // CLI; silently replaying the defaults would hide it.
            return Err(Error::Coordinator("trace spec: empty spec".into()));
        }
        let mut spec = Self::default();
        for part in text.split(',').filter(|p| !p.is_empty()) {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| Error::Coordinator(format!("trace spec `{part}`: expected key=value")))?;
            let bad = |what: &str| Error::Coordinator(format!("trace spec {key}={val}: {what}"));
            match key {
                "n" => spec.n = val.parse().map_err(|_| bad("not an integer"))?,
                "seed" => spec.seed = val.parse().map_err(|_| bad("not an integer"))?,
                "rate" => spec.rate = val.parse().map_err(|_| bad("not a number"))?,
                "plen" => spec.plen = parse_range(val).ok_or_else(|| bad("expected lo..hi"))?,
                "gen" => spec.gen = parse_range(val).ok_or_else(|| bad("expected lo..hi"))?,
                "burst" => spec.burst = val.parse().map_err(|_| bad("not a number"))?,
                "tail" => spec.tail = val.parse().map_err(|_| bad("not a number"))?,
                "mix" => spec.mix = val.parse().map_err(|_| bad("not a number"))?,
                _ => return Err(Error::Coordinator(format!("trace spec: unknown key `{key}`"))),
            }
        }
        if spec.n == 0 {
            return Err(Error::Coordinator("trace spec: n must be positive".into()));
        }
        if spec.plen.0 == 0 || spec.plen.0 > spec.plen.1 || spec.gen.0 == 0 || spec.gen.0 > spec.gen.1 {
            return Err(Error::Coordinator("trace spec: empty plen/gen range".into()));
        }
        if !spec.burst.is_finite() || spec.burst < 1.0 {
            return Err(Error::Coordinator("trace spec: burst must be >= 1".into()));
        }
        if !(0.0..=1.0).contains(&spec.tail) || !(0.0..=1.0).contains(&spec.mix) {
            return Err(Error::Coordinator("trace spec: tail/mix must be in 0..=1".into()));
        }
        Ok(spec)
    }

    /// Expand into concrete requests. `vocab`/`prefill_len` come from the
    /// serving model so generated prompts are always admissible.
    pub fn generate(&self, vocab: usize, prefill_len: usize) -> Vec<TraceRequest> {
        self.generate_capped(vocab, prefill_len, usize::MAX)
    }

    /// Like [`TraceSpec::generate`], but clamp each request's generation
    /// budget so `prompt + max_new ≤ max_total_slots` (the serving KV
    /// window) — heavy-tailed draws stay admissible instead of being
    /// rejected at submit. The PRNG draw sequence is unchanged, so a
    /// capped trace differs from the uncapped one only in the clamp.
    pub fn generate_capped(
        &self,
        vocab: usize,
        prefill_len: usize,
        max_total_slots: usize,
    ) -> Vec<TraceRequest> {
        let mut rng = Rng::new(self.seed);
        let mut t_ms = 0.0f64;
        let (plo, phi) = (self.plen.0.min(prefill_len), self.plen.1.min(prefill_len));
        let mut burst_left = 0usize;
        let mut out = Vec::with_capacity(self.n);
        for _ in 0..self.n {
            if self.rate > 0.0 {
                if self.burst > 1.0 {
                    if burst_left > 0 {
                        // Back-to-back arrival inside the current burst.
                        burst_left -= 1;
                    } else {
                        t_ms += rng.exponential(self.rate / self.burst) * 1e3;
                        // Geometric burst size with mean `burst` (capped
                        // so one pathological draw cannot outlast the
                        // trace).
                        let cont = 1.0 - 1.0 / self.burst;
                        let mut size = 1usize;
                        while size < self.n && rng.f64() < cont {
                            size += 1;
                        }
                        burst_left = size - 1;
                    }
                } else {
                    t_ms += rng.exponential(self.rate) * 1e3;
                }
            }
            let len = rng.range(plo, phi + 1);
            let prompt = (0..len).map(|_| rng.below(vocab as u64) as i32).collect();
            let drawn = if self.tail > 0.0 && rng.f64() < self.tail {
                rng.range(self.gen.1 + 1, 4 * self.gen.1 + 1)
            } else {
                rng.range(self.gen.0, self.gen.1 + 1)
            };
            let max_new = drawn.min(max_total_slots.saturating_sub(len)).max(1);
            let slo_factor = if self.mix > 0.0 && rng.f64() < self.mix { 0.25 } else { 1.0 };
            out.push(TraceRequest { arrive_ms: t_ms, prompt, max_new_tokens: max_new, slo_factor });
        }
        out
    }
}

fn parse_range(text: &str) -> Option<(usize, usize)> {
    let (lo, hi) = text.split_once("..")?;
    Some((lo.parse().ok()?, hi.parse().ok()?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_overrides_defaults() {
        let s = TraceSpec::parse("n=8,seed=3,rate=0,plen=2..4,gen=1..2").unwrap();
        assert_eq!(s.n, 8);
        assert_eq!(s.seed, 3);
        assert_eq!(s.rate, 0.0);
        assert_eq!(s.plen, (2, 4));
        assert_eq!(s.gen, (1, 2));
        assert_eq!((s.burst, s.tail, s.mix), (1.0, 0.0, 0.0));
        let h = TraceSpec::parse("burst=4,tail=0.25,mix=0.5").unwrap();
        assert_eq!((h.burst, h.tail, h.mix), (4.0, 0.25, 0.5));
        assert!(TraceSpec::parse("bogus").is_err());
        assert!(TraceSpec::parse("n=0").is_err());
        assert!(TraceSpec::parse("plen=9..4").is_err());
        assert!(TraceSpec::parse("warp=9").is_err());
        assert!(TraceSpec::parse("burst=0.5").is_err());
        assert!(TraceSpec::parse("tail=1.5").is_err());
        assert!(TraceSpec::parse("mix=-0.1").is_err());
    }

    #[test]
    fn every_malformed_spec_is_a_diagnostic_error() {
        // Every malformed spec must produce a diagnostic error carrying
        // the `trace spec` prefix — never a panic, never a silent
        // fall-back to the defaults.
        for bad in [
            "",          // empty spec (likely a CLI quoting mistake)
            ",",         // only separators — still an empty spec
            "n",         // bare key, no `=`
            "n=",        // empty value
            "n=abc",     // non-numeric integer
            "rate=fast", // non-numeric float
            "plen=4",    // range key without `..`
            "plen=a..b", // non-numeric range bounds
            "gen=0..4",  // zero-length generations are meaningless
            "=",         // empty key and value
        ] {
            let err = TraceSpec::parse(bad)
                .expect_err(&format!("spec `{bad}` must be rejected"))
                .to_string();
            assert!(err.contains("trace spec"), "spec `{bad}` -> `{err}`");
        }
    }

    #[test]
    fn generation_is_deterministic_and_admissible() {
        let spec = TraceSpec::default();
        let a = spec.generate(256, 16);
        let b = spec.generate(256, 16);
        assert_eq!(a.len(), spec.n);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrive_ms, y.arrive_ms);
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
            assert_eq!(x.slo_factor, y.slo_factor);
        }
        let mut last = 0.0;
        for r in &a {
            assert!(!r.prompt.is_empty() && r.prompt.len() <= 16);
            assert!(r.prompt.iter().all(|&t| (0..256).contains(&t)));
            assert!((spec.gen.0..=spec.gen.1).contains(&r.max_new_tokens));
            assert_eq!(r.slo_factor, 1.0, "mix=0 must not tag anything");
            assert!(r.arrive_ms >= last, "arrivals must be sorted");
            last = r.arrive_ms;
        }
    }

    #[test]
    fn default_knobs_leave_old_traces_byte_identical() {
        // A spec with burst/tail/mix at their defaults must draw exactly
        // the PRNG sequence the pre-SoC generator drew — the old CLI
        // strings replay the very same traces.
        let old = TraceSpec { n: 12, seed: 3, rate: 4.0, plen: (2, 6), gen: (2, 5), ..Default::default() };
        let a = old.generate(64, 8);
        let b = old.generate_capped(64, 8, usize::MAX);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrive_ms, y.arrive_ms);
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
        }
    }

    #[test]
    fn bursty_arrivals_cluster_but_keep_the_offered_load() {
        let plain = TraceSpec { n: 200, seed: 9, rate: 8.0, ..Default::default() };
        let bursty = TraceSpec { burst: 4.0, ..plain.clone() };
        let a = plain.generate(64, 8);
        let b = bursty.generate(64, 8);
        // Bursts: many zero gaps between consecutive arrivals.
        let zero_gaps =
            b.windows(2).filter(|w| w[1].arrive_ms == w[0].arrive_ms).count();
        assert!(zero_gaps > b.len() / 4, "only {zero_gaps} back-to-back arrivals");
        assert!(
            a.windows(2).filter(|w| w[1].arrive_ms == w[0].arrive_ms).count() == 0,
            "Poisson arrivals must not collide"
        );
        // Long-run offered load within a factor-ish of the plain process.
        let span = |t: &[TraceRequest]| t.last().unwrap().arrive_ms - t[0].arrive_ms;
        assert!(span(&b) > span(&a) * 0.3 && span(&b) < span(&a) * 3.0);
    }

    #[test]
    fn heavy_tail_and_mix_draw_as_specified() {
        let spec = TraceSpec {
            n: 300,
            seed: 5,
            rate: 0.0,
            gen: (2, 4),
            tail: 0.3,
            mix: 0.5,
            ..Default::default()
        };
        let reqs = spec.generate_capped(64, 8, 12);
        let tails = reqs.iter().filter(|r| r.max_new_tokens > spec.gen.1).count();
        assert!(tails > 30 && tails < 200, "tail draws off-distribution: {tails}");
        let interactive = reqs.iter().filter(|r| r.slo_factor < 1.0).count();
        assert!(interactive > 80 && interactive < 250, "mix draws off: {interactive}");
        for r in &reqs {
            assert!(r.prompt.len() + r.max_new_tokens <= 12, "cap violated");
            assert!(r.max_new_tokens >= 1);
            assert!(r.slo_factor == 1.0 || r.slo_factor == 0.25);
        }
    }

    #[test]
    fn zero_rate_means_simultaneous_arrival() {
        let spec = TraceSpec { rate: 0.0, ..Default::default() };
        assert!(spec.generate(256, 16).iter().all(|r| r.arrive_ms == 0.0));
    }
}
