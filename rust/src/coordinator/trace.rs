//! Deterministic request-trace generation + replay specs.
//!
//! A [`TraceSpec`] describes a synthetic arrival process compactly enough
//! to put on a CLI (`aquas serve --trace n=16,seed=7,rate=4,plen=4..12,
//! gen=6..14`); [`TraceSpec::generate`] expands it into concrete
//! [`TraceRequest`]s with exponential inter-arrival times and uniform
//! prompt/generation lengths, all drawn from the seeded in-crate PRNG so
//! two replays of the same spec are byte-identical.

use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// One request of a serving trace.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    /// Arrival time on the simulated SoC clock, in milliseconds.
    pub arrive_ms: f64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
}

/// A compact, deterministic trace description.
#[derive(Debug, Clone)]
pub struct TraceSpec {
    /// Number of requests.
    pub n: usize,
    /// PRNG seed (prompts, lengths, arrivals).
    pub seed: u64,
    /// Mean arrival rate in requests per simulated second (Poisson
    /// process). `0` means all requests arrive at t = 0.
    pub rate: f64,
    /// Prompt length range (inclusive), clamped to the prefill window.
    pub plen: (usize, usize),
    /// Generation length range (inclusive).
    pub gen: (usize, usize),
}

impl Default for TraceSpec {
    fn default() -> Self {
        Self { n: 16, seed: 7, rate: 2.0, plen: (4, 12), gen: (6, 14) }
    }
}

impl TraceSpec {
    /// Parse the CLI form: comma-separated `key=value` pairs over the
    /// defaults, e.g. `n=16,seed=7,rate=4,plen=4..12,gen=6..14`.
    pub fn parse(text: &str) -> Result<Self> {
        let mut spec = Self::default();
        for part in text.split(',').filter(|p| !p.is_empty()) {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| Error::Coordinator(format!("trace spec `{part}`: expected key=value")))?;
            let bad = |what: &str| Error::Coordinator(format!("trace spec {key}={val}: {what}"));
            match key {
                "n" => spec.n = val.parse().map_err(|_| bad("not an integer"))?,
                "seed" => spec.seed = val.parse().map_err(|_| bad("not an integer"))?,
                "rate" => spec.rate = val.parse().map_err(|_| bad("not a number"))?,
                "plen" => spec.plen = parse_range(val).ok_or_else(|| bad("expected lo..hi"))?,
                "gen" => spec.gen = parse_range(val).ok_or_else(|| bad("expected lo..hi"))?,
                _ => return Err(Error::Coordinator(format!("trace spec: unknown key `{key}`"))),
            }
        }
        if spec.n == 0 {
            return Err(Error::Coordinator("trace spec: n must be positive".into()));
        }
        if spec.plen.0 == 0 || spec.plen.0 > spec.plen.1 || spec.gen.0 == 0 || spec.gen.0 > spec.gen.1 {
            return Err(Error::Coordinator("trace spec: empty plen/gen range".into()));
        }
        Ok(spec)
    }

    /// Expand into concrete requests. `vocab`/`prefill_len` come from the
    /// serving model so generated prompts are always admissible.
    pub fn generate(&self, vocab: usize, prefill_len: usize) -> Vec<TraceRequest> {
        let mut rng = Rng::new(self.seed);
        let mut t_ms = 0.0f64;
        let (plo, phi) = (self.plen.0.min(prefill_len), self.plen.1.min(prefill_len));
        let mut out = Vec::with_capacity(self.n);
        for _ in 0..self.n {
            if self.rate > 0.0 {
                t_ms += rng.exponential(self.rate) * 1e3;
            }
            let len = rng.range(plo, phi + 1);
            let prompt = (0..len).map(|_| rng.below(vocab as u64) as i32).collect();
            let max_new = rng.range(self.gen.0, self.gen.1 + 1);
            out.push(TraceRequest { arrive_ms: t_ms, prompt, max_new_tokens: max_new });
        }
        out
    }
}

fn parse_range(text: &str) -> Option<(usize, usize)> {
    let (lo, hi) = text.split_once("..")?;
    Some((lo.parse().ok()?, hi.parse().ok()?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_overrides_defaults() {
        let s = TraceSpec::parse("n=8,seed=3,rate=0,plen=2..4,gen=1..2").unwrap();
        assert_eq!(s.n, 8);
        assert_eq!(s.seed, 3);
        assert_eq!(s.rate, 0.0);
        assert_eq!(s.plen, (2, 4));
        assert_eq!(s.gen, (1, 2));
        assert!(TraceSpec::parse("bogus").is_err());
        assert!(TraceSpec::parse("n=0").is_err());
        assert!(TraceSpec::parse("plen=9..4").is_err());
        assert!(TraceSpec::parse("warp=9").is_err());
    }

    #[test]
    fn generation_is_deterministic_and_admissible() {
        let spec = TraceSpec::default();
        let a = spec.generate(256, 16);
        let b = spec.generate(256, 16);
        assert_eq!(a.len(), spec.n);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrive_ms, y.arrive_ms);
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
        }
        let mut last = 0.0;
        for r in &a {
            assert!(!r.prompt.is_empty() && r.prompt.len() <= 16);
            assert!(r.prompt.iter().all(|&t| (0..256).contains(&t)));
            assert!((spec.gen.0..=spec.gen.1).contains(&r.max_new_tokens));
            assert!(r.arrive_ms >= last, "arrivals must be sorted");
            last = r.arrive_ms;
        }
    }

    #[test]
    fn zero_rate_means_simultaneous_arrival() {
        let spec = TraceSpec { rate: 0.0, ..Default::default() };
        assert!(spec.generate(256, 16).iter().all(|r| r.arrive_ms == 0.0));
    }
}
