//! Multi-core sharded serving: N ASIP serving cores on one SoC.
//!
//! [`SocCoordinator`] composes N single-core engines ([`super::Coordinator`])
//! into one SoC behind a shared DDR controller:
//!
//! - **Sharded paged KV** — each core owns its own [`super::KvPool`]
//!   shard (block contents never cross shards; a migrated sequence is
//!   rebuilt on the target by the existing recompute path).
//! - **Async admission** — arriving requests are dispatched to a core
//!   run queue up front ([`DispatchPolicy`]); cores then run their own
//!   admission/decode pipelines on their own timelines.
//! - **Cross-core migration** — when a core's next queued item cannot
//!   get blocks out of its dry shard but another core could admit it
//!   right now, the item moves (one per core per round, greedy).
//! - **Work stealing** — a fully drained core raids the back of the
//!   deepest waiting queue, fast-forwarding its idle clock to the
//!   victim's so time stays monotone.
//! - **Shared-memory contention** — every execution burst's
//!   `(compute, mem)` demand is re-priced under the measured per-stream
//!   slowdown of concurrent DMA streams through the shared port group
//!   ([`crate::workloads::llm::IsaxLlmModel::shared_stream_slowdown`],
//!   an event-driven [`crate::interface::dmasim`] replay — no second
//!   timing model). The slip lands on the owning core's clock and is
//!   totalled in [`SocStats::contention_dma_cycles`].
//! - **Fault injection & failover** — an optional deterministic
//!   [`FaultPlan`] ([`SocConfig::faults`]) kills or stalls cores on a
//!   seeded schedule, injects per-transaction DMA errors, and surges
//!   load; a watchdog detects frozen cores by clock non-progress and
//!   evacuates their sequences to surviving shards via the recompute
//!   path, while per-core engines degrade gracefully under sustained
//!   overload (backpressure → load shedding → batch halving). An empty
//!   plan is guaranteed bitwise-inert.
//!
//! Each core keeps its own simulated clock; the SoC's elapsed time is
//! the slowest core's clock ([`SocCoordinator::sim_elapsed_ms`]). With
//! one core no stream ever has a concurrent peer, so every factor is
//! exactly 1 and the replay is bitwise-identical to driving
//! [`super::Coordinator`] directly — the scaling curves measure
//! contention and imbalance, not a changed baseline.

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::interface::dmasim::DmaFaultInjector;
use crate::runtime::Runtime;

use super::{
    Coordinator, CoordinatorConfig, DegradeState, FaultPlan, KvStats, RequestMetrics,
    TickDemand, TraceRequest, WaitItem,
};

/// Health of one serving core as seen by the SoC watchdog.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum CoreHealth {
    /// Running normally.
    Up,
    /// Frozen by an active `corestall` fault window; recovers.
    Stalled,
    /// Killed by a `coredown` fault; never recovers.
    Down,
}

/// How arriving requests are dispatched to core run queues.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Send each request to the core with the least estimated
    /// outstanding work (prompt + generation tokens dispatched so far).
    /// An admission-time estimate only — work stealing corrects drift
    /// at runtime. Ties go to the lowest core id, so dispatch is
    /// deterministic.
    LeastLoaded,
    /// Strict round-robin by submission order. Mostly useful to provoke
    /// imbalance in tests (stealing must then rebalance).
    RoundRobin,
}

/// N-core SoC configuration.
#[derive(Debug, Clone)]
pub struct SocConfig {
    /// Number of ASIP serving cores on the SoC.
    pub cores: usize,
    /// Per-core engine configuration. Note `kv` describes the *per-core
    /// shard* geometry, so total SoC KV capacity scales with `cores`.
    pub per_core: CoordinatorConfig,
    /// Beats per cycle the shared DDR controller sustains across all
    /// cores' DMA engines (the port-group width of the contention
    /// replay). Each engine sustains at most one beat per cycle, so
    /// `cores <= ddr_banks` never contends.
    pub ddr_banks: usize,
    /// Dispatch policy for async admission into core run queues.
    pub dispatch: DispatchPolicy,
    /// Enable work stealing into fully drained cores.
    pub steal: bool,
    /// Deterministic fault schedule to inject ([`FaultPlan::parse`]).
    /// The default (empty) plan arms nothing and leaves every serving
    /// output bitwise identical to a fault-free build.
    pub faults: FaultPlan,
}

impl Default for SocConfig {
    fn default() -> Self {
        Self {
            cores: 1,
            per_core: CoordinatorConfig::default(),
            // A 4-beat DDR port group: up to 4 cores stream
            // contention-free (scaling there is bounded by queue
            // imbalance and batch-occupancy tails), while 8 cores
            // oversubscribe the port group 2x — the knee where the
            // bench's scaling curves hit the DDR wall and the
            // contention delta in dma_cycles becomes nonzero.
            ddr_banks: 4,
            dispatch: DispatchPolicy::LeastLoaded,
            steal: true,
            faults: FaultPlan::default(),
        }
    }
}

/// SoC-level counters on top of the per-core engine metrics.
#[derive(Debug, Clone, Default)]
pub struct SocStats {
    /// Configured core count.
    pub cores: usize,
    /// Cross-core sequence migrations (dry-shard relief).
    pub migrations: u64,
    /// Work-stealing transfers into drained cores.
    pub steals: u64,
    /// Recompute preemptions summed over all cores.
    pub preemptions: u64,
    /// Extra cycles shared-DDR contention added across all cores (zero
    /// when the port group covers the aggregate stream demand).
    pub contention_dma_cycles: f64,
    /// Fault events the active [`FaultPlan`] has applied so far (core
    /// deaths, stall onsets, surge onsets).
    pub faults_injected: u64,
    /// Total DMA retry attempts across all cores' fault injectors.
    pub dma_retries: u64,
    /// Sequences and queued requests evacuated off dead/stalled cores
    /// by the watchdog.
    pub evacuated_seqs: u64,
    /// Waiting requests shed by the graceful-degradation ladder.
    pub shed_requests: u64,
    /// Retired requests whose first token missed its TTFT deadline.
    pub slo_violations: u64,
    /// Per-shard allocator accounting, indexed by core.
    pub per_core_kv: Vec<KvStats>,
}

/// N single-core serving engines behind one shared memory controller.
pub struct SocCoordinator<'rt> {
    cores: Vec<Coordinator<'rt>>,
    cfg: SocConfig,
    /// Estimated tokens dispatched per core (LeastLoaded scoring).
    dispatched_load: Vec<u64>,
    /// Next core for round-robin dispatch.
    rr_next: usize,
    /// SoC-wide request id space (each core stamps the id it is handed).
    next_id: u64,
    migrations: u64,
    steals: u64,
    contention_dma_cycles: f64,
    /// Memoized calibration factors per concurrent-stream count.
    slowdown_memo: HashMap<usize, Vec<f64>>,
    /// Watchdog view of each core, indexed by core id.
    health: Vec<CoreHealth>,
    /// Last observed per-core clock (watchdog non-progress detection).
    watch_clock: Vec<f64>,
    /// Consecutive rounds each core held work without clock progress.
    watch_stuck: Vec<u32>,
    /// Which `coredown` events have fired, indexed like the plan.
    down_applied: Vec<bool>,
    /// `corestall` window state, indexed like the plan: 0 pending,
    /// 1 active, 2 done.
    stall_state: Vec<u8>,
    /// `surge` window state, same encoding as `stall_state`.
    surge_state: Vec<u8>,
    /// Fault-plan core indices checked against the core count (once).
    plan_validated: bool,
    faults_injected: u64,
    evacuated: u64,
}

impl<'rt> SocCoordinator<'rt> {
    /// Build an N-core SoC; each core gets its own engine and KV shard.
    pub fn new(rt: &'rt Runtime, cfg: SocConfig) -> Self {
        assert!(cfg.cores >= 1, "a SoC needs at least one core");
        assert!(cfg.ddr_banks >= 1, "shared memory needs at least one beat port");
        let mut cores: Vec<Coordinator<'rt>> = (0..cfg.cores)
            .map(|_| {
                let mut c = Coordinator::new(rt, cfg.per_core.clone());
                c.record_demand = true;
                c
            })
            .collect();
        // A non-empty fault plan arms the per-core degradation ladder
        // and (when requested) seeded DMA error injectors; an empty plan
        // leaves every core exactly as a fault-free build would.
        if !cfg.faults.is_empty() {
            for (k, c) in cores.iter_mut().enumerate() {
                c.degrade = Some(DegradeState::default());
                if cfg.faults.dma_err > 0.0 {
                    c.dma_faults = Some(DmaFaultInjector::new(
                        cfg.faults.dma_err,
                        cfg.faults.seed.wrapping_add(k as u64),
                    ));
                }
            }
        }
        let n = cores.len();
        Self {
            cores,
            dispatched_load: vec![0; n],
            rr_next: 0,
            next_id: 0,
            migrations: 0,
            steals: 0,
            contention_dma_cycles: 0.0,
            slowdown_memo: HashMap::new(),
            health: vec![CoreHealth::Up; n],
            watch_clock: vec![0.0; n],
            watch_stuck: vec![0; n],
            down_applied: vec![false; cfg.faults.core_down.len()],
            stall_state: vec![0; cfg.faults.core_stall.len()],
            surge_state: vec![0; cfg.faults.surge.len()],
            plan_validated: false,
            faults_injected: 0,
            evacuated: 0,
            cfg,
        }
    }

    /// Configured core count.
    pub fn core_count(&self) -> usize {
        self.cores.len()
    }

    /// Dispatch one trace request to a core run queue; returns its
    /// SoC-wide request id.
    pub fn submit(&mut self, r: &TraceRequest) -> Result<u64> {
        // Validate against shard geometry first (identical on every
        // core) so a rejected request perturbs no dispatch state.
        self.cores[0].validate(&r.prompt, r.max_new_tokens)?;
        let k = match self.cfg.dispatch {
            DispatchPolicy::RoundRobin => {
                let k = self.rr_next;
                self.rr_next = (self.rr_next + 1) % self.cores.len();
                k
            }
            DispatchPolicy::LeastLoaded => {
                let mut best = 0;
                for k in 1..self.cores.len() {
                    if self.dispatched_load[k] < self.dispatched_load[best] {
                        best = k;
                    }
                }
                best
            }
        };
        self.dispatched_load[k] += (r.prompt.len() + r.max_new_tokens) as u64;
        // The SoC owns the id space; the core engine stamps the id it
        // is handed so merged metrics keep global submission order.
        self.cores[k].next_id = self.next_id;
        let slo = self.cfg.per_core.slo_ttft_ms * r.slo_factor;
        let id = self.cores[k].submit_at_with_slo(
            r.prompt.clone(),
            r.max_new_tokens,
            r.arrive_ms,
            slo,
        )?;
        debug_assert_eq!(id, self.next_id);
        self.next_id += 1;
        Ok(id)
    }

    /// Dispatch a whole trace; returns the SoC-wide request ids.
    pub fn submit_trace(&mut self, reqs: &[TraceRequest]) -> Result<Vec<u64>> {
        reqs.iter().map(|r| self.submit(r)).collect()
    }

    /// Is there outstanding work on any core?
    pub fn has_work(&self) -> bool {
        self.cores.iter().any(|c| c.has_work())
    }

    /// SoC end-to-end simulated time: the slowest core's clock, ms.
    pub fn sim_elapsed_ms(&self) -> f64 {
        self.cores.iter().map(|c| c.sim_now_ms()).fold(0.0, f64::max)
    }

    /// SoC-level counters + per-shard accounting.
    pub fn stats(&self) -> SocStats {
        SocStats {
            cores: self.cores.len(),
            migrations: self.migrations,
            steals: self.steals,
            preemptions: self.cores.iter().map(|c| c.preemptions()).sum(),
            contention_dma_cycles: self.contention_dma_cycles,
            faults_injected: self.faults_injected,
            dma_retries: self.cores.iter().map(|c| c.dma_fault_counts().1).sum(),
            evacuated_seqs: self.evacuated,
            shed_requests: self.cores.iter().map(|c| c.shed_requests()).sum(),
            slo_violations: self.cores.iter().map(|c| c.slo_violations()).sum(),
            per_core_kv: self.cores.iter().map(|c| c.kv_stats()).collect(),
        }
    }

    /// Drive all cores to completion; returns every request's metrics
    /// sorted by SoC-wide id.
    pub fn run_to_completion(&mut self) -> Result<Vec<RequestMetrics>> {
        while self.has_work() {
            if !self.round()? && self.has_work() {
                return Err(Error::Coordinator(format!(
                    "SoC scheduler stalled with work outstanding across {} cores",
                    self.cores.len()
                )));
            }
        }
        let mut out = Vec::new();
        for c in &mut self.cores {
            debug_assert!(
                c.pool.stats().leak_free(),
                "core shard leaked blocks: {:?}",
                c.pool.stats()
            );
            out.append(&mut std::mem::take(&mut c.done));
        }
        out.sort_by_key(|m| m.id);
        Ok(out)
    }

    // ----- internals -------------------------------------------------------

    /// One SoC round: apply any due fault events, rebalance queues, step
    /// every healthy core that has work, run the watchdog, then charge
    /// shared-memory contention for the streams that ran concurrently.
    /// Returns whether any core made progress — fault applications,
    /// watchdog ticks and evacuations count as (bounded) progress, so
    /// recovery never reads as a stall.
    fn round(&mut self) -> Result<bool> {
        let mut acted = self.apply_faults()?;
        self.rebalance()?;
        let mut ran_any = false;
        let mut demands: Vec<(usize, Vec<TickDemand>)> = Vec::new();
        for k in 0..self.cores.len() {
            if self.health[k] != CoreHealth::Up || !self.cores[k].has_work() {
                continue;
            }
            ran_any |= self.cores[k].step()?;
            let d = std::mem::take(&mut self.cores[k].step_demand);
            if !d.is_empty() {
                demands.push((k, d));
            }
        }
        // Watchdog: a core holding work whose clock made no progress for
        // WATCHDOG_ROUNDS consecutive rounds is treated as failed and
        // its work evacuated to surviving shards. Healthy cores always
        // advance their clocks when they hold work (every step either
        // charges cycles or fast-forwards), so this only ever fires on
        // fault-frozen cores. Gated on the plan so fault-free runs never
        // even read the clocks.
        if !self.cfg.faults.is_empty() {
            const WATCHDOG_ROUNDS: u32 = 3;
            for k in 0..self.cores.len() {
                let clk = self.cores[k].clock_cycles;
                if self.cores[k].has_work() && clk <= self.watch_clock[k] {
                    self.watch_stuck[k] += 1;
                    acted = true;
                } else {
                    self.watch_stuck[k] = 0;
                }
                self.watch_clock[k] = clk;
                if self.watch_stuck[k] >= WATCHDOG_ROUNDS {
                    self.watch_stuck[k] = 0;
                    if self.evacuate(k)? > 0 {
                        acted = true;
                    }
                }
            }
        }
        self.charge_contention(&demands);
        Ok(ran_any || acted)
    }

    /// Apply every fault event whose simulated time has come. Returns
    /// whether any state changed (bounded: each event fires once).
    fn apply_faults(&mut self) -> Result<bool> {
        if self.cfg.faults.is_empty() {
            return Ok(false);
        }
        if !self.plan_validated {
            for &(k, _) in &self.cfg.faults.core_down {
                if k >= self.cores.len() {
                    return Err(Error::Coordinator(format!(
                        "fault plan: coredown targets core {k} but the SoC has {} cores",
                        self.cores.len()
                    )));
                }
            }
            for &(k, _, _) in &self.cfg.faults.core_stall {
                if k >= self.cores.len() {
                    return Err(Error::Coordinator(format!(
                        "fault plan: corestall targets core {k} but the SoC has {} cores",
                        self.cores.len()
                    )));
                }
            }
            self.plan_validated = true;
        }
        let now = self.sim_elapsed_ms();
        let mut acted = false;
        // Permanent core deaths.
        for i in 0..self.cfg.faults.core_down.len() {
            let (k, t) = self.cfg.faults.core_down[i];
            if !self.down_applied[i] && t <= now {
                self.down_applied[i] = true;
                self.health[k] = CoreHealth::Down;
                self.faults_injected += 1;
                acted = true;
            }
        }
        // Transient stall windows.
        for i in 0..self.cfg.faults.core_stall.len() {
            let (k, t0, t1) = self.cfg.faults.core_stall[i];
            match self.stall_state[i] {
                0 if t0 <= now => {
                    self.stall_state[i] = 1;
                    let h = self.stall_health(k);
                    self.health[k] = h;
                    self.faults_injected += 1;
                    acted = true;
                }
                1 if t1 <= now => {
                    self.stall_state[i] = 2;
                    let h = self.stall_health(k);
                    self.health[k] = h;
                    if h == CoreHealth::Up {
                        // Rejoin the SoC timeline forward-only so the
                        // recovered core's clock stays monotone.
                        self.cores[k].fast_forward_to(now);
                    }
                    acted = true;
                }
                _ => {}
            }
        }
        // Deadlock release: if every core is stalled or dead while work
        // remains, simulated time can no longer advance and no stall
        // window would ever expire. Retire the earliest-ending active
        // stall by decree, fast-forwarding its core past the window.
        if self.has_work() && !self.health.iter().any(|&h| h == CoreHealth::Up) {
            let mut pick: Option<(usize, f64)> = None;
            for i in 0..self.cfg.faults.core_stall.len() {
                if self.stall_state[i] == 1 {
                    let t1 = self.cfg.faults.core_stall[i].2;
                    if pick.map_or(true, |(_, best)| t1 < best) {
                        pick = Some((i, t1));
                    }
                }
            }
            if let Some((i, t1)) = pick {
                let k = self.cfg.faults.core_stall[i].0;
                self.stall_state[i] = 2;
                let h = self.stall_health(k);
                self.health[k] = h;
                if h == CoreHealth::Up {
                    self.cores[k].fast_forward_to(t1.max(now));
                }
                acted = true;
            }
        }
        // Surge windows: the product of all active factors lands on
        // every core's load multiplier (guarded out of the charge sites
        // when it is exactly 1.0).
        if !self.cfg.faults.surge.is_empty() {
            for i in 0..self.cfg.faults.surge.len() {
                let (_, t0, t1) = self.cfg.faults.surge[i];
                match self.surge_state[i] {
                    0 if t0 <= now => {
                        self.surge_state[i] = 1;
                        self.faults_injected += 1;
                        acted = true;
                    }
                    1 if t1 <= now => {
                        self.surge_state[i] = 2;
                        acted = true;
                    }
                    _ => {}
                }
            }
            let mut f = 1.0;
            for i in 0..self.cfg.faults.surge.len() {
                if self.surge_state[i] == 1 {
                    f *= self.cfg.faults.surge[i].0;
                }
            }
            for c in &mut self.cores {
                c.load_factor = f;
            }
        }
        Ok(acted)
    }

    /// Health core `k` should report from stall windows alone: `Down`
    /// is permanent, otherwise `Stalled` iff any stall window targeting
    /// it is still active.
    fn stall_health(&self, k: usize) -> CoreHealth {
        if self.health[k] == CoreHealth::Down {
            return CoreHealth::Down;
        }
        let stalled = self
            .cfg
            .faults
            .core_stall
            .iter()
            .enumerate()
            .any(|(i, &(c, _, _))| c == k && self.stall_state[i] == 1);
        if stalled {
            CoreHealth::Stalled
        } else {
            CoreHealth::Up
        }
    }

    /// Evacuate everything core `k` holds onto surviving (`Up`) cores:
    /// active sequences convert to recompute resumes (their emitted
    /// tokens ride along bitwise and are never re-emitted), queued items
    /// follow in order, and not-yet-arrived dispatches re-dispatch into
    /// the targets' sorted pending queues. The dead core's shard blocks
    /// are released first, so its pool stays leak-free. Returns how many
    /// items moved.
    fn evacuate(&mut self, k: usize) -> Result<usize> {
        let targets: Vec<usize> = (0..self.cores.len())
            .filter(|&j| j != k && self.health[j] == CoreHealth::Up)
            .collect();
        if targets.is_empty() {
            return Err(Error::Coordinator(format!(
                "core {k} failed with work outstanding and no surviving core to absorb it"
            )));
        }
        let mut moved = 0usize;
        let mut rr = 0usize;
        let actives: Vec<_> = self.cores[k].active.drain(..).collect();
        for mut act in actives {
            self.cores[k].pool.release(&mut act.table);
            act.len = 0;
            act.preemptions += 1;
            let j = targets[rr % targets.len()];
            rr += 1;
            self.cores[j].waiting.push_back(WaitItem::Resume(Box::new(act)));
            moved += 1;
        }
        while let Some(item) = self.cores[k].waiting.pop_front() {
            let j = targets[rr % targets.len()];
            rr += 1;
            self.cores[j].waiting.push_back(item);
            moved += 1;
        }
        while let Some((t_ms, d_ms, req)) = self.cores[k].pending.pop_front() {
            let j = targets[rr % targets.len()];
            rr += 1;
            let q = &mut self.cores[j].pending;
            let pos = q.iter().position(|&(pt, _, _)| pt > t_ms).unwrap_or(q.len());
            q.insert(pos, (t_ms, d_ms, req));
            moved += 1;
        }
        self.evacuated += moved as u64;
        Ok(moved)
    }

    /// Cross-core migration + work stealing, once per round. Dead or
    /// stalled cores take no part: not as migration source or target,
    /// not as thief, not as victim (the watchdog owns their work).
    fn rebalance(&mut self) -> Result<()> {
        let n = self.cores.len();
        if n <= 1 {
            return Ok(());
        }
        // Migration: a core whose next queued item cannot get blocks out
        // of its own dry shard hands it to the core with the most free
        // shard blocks that could admit it *right now* (a free batch
        // slot and enough blocks). Block contents never cross shards —
        // a preempted sequence is rebuilt on the target by the regular
        // recompute re-admission.
        for k in 0..n {
            if self.health[k] != CoreHealth::Up {
                continue;
            }
            let needed = {
                let Some(head) = self.cores[k].waiting.front() else { continue };
                self.cores[k].pool.blocks_for(head.needed_slots())
            };
            if needed <= self.cores[k].pool.free_blocks() {
                continue; // shard can serve it; plain admission will.
            }
            let mut target: Option<usize> = None;
            for j in 0..n {
                if j == k || self.health[j] != CoreHealth::Up {
                    continue;
                }
                let cj = &self.cores[j];
                if cj.active.len() >= cj.cfg.max_active || needed > cj.pool.free_blocks() {
                    continue;
                }
                let better = match target {
                    None => true,
                    Some(t) => cj.pool.free_blocks() > self.cores[t].pool.free_blocks(),
                };
                if better {
                    target = Some(j);
                }
            }
            if let Some(j) = target {
                let Some(item) = self.cores[k].waiting.pop_front() else {
                    return Err(Error::Coordinator(
                        "migration source queue emptied underneath the scheduler".into(),
                    ));
                };
                // The item keeps its absolute arrival/deadline; the
                // target admits on its own monotone clock (TTFT deltas
                // clamp at zero if the target's clock trails).
                self.cores[j].waiting.push_back(item);
                self.migrations += 1;
            }
        }
        // Work stealing: a fully drained core (no active, queued, or
        // future work) raids the back of the deepest waiting queue,
        // leaving the head for the victim's own next admission.
        if self.cfg.steal {
            for k in 0..n {
                if self.health[k] != CoreHealth::Up {
                    continue;
                }
                let drained = {
                    let c = &self.cores[k];
                    c.active.is_empty() && c.waiting.is_empty() && c.pending.is_empty()
                };
                if !drained {
                    continue;
                }
                let mut victim: Option<usize> = None;
                for j in 0..n {
                    if j == k
                        || self.health[j] != CoreHealth::Up
                        || self.cores[j].waiting.len() < 2
                    {
                        continue;
                    }
                    let better = match victim {
                        None => true,
                        Some(v) => self.cores[j].waiting.len() > self.cores[v].waiting.len(),
                    };
                    if better {
                        victim = Some(j);
                    }
                }
                if let Some(j) = victim {
                    let from_now = self.cores[j].sim_now_ms();
                    let Some(item) = self.cores[j].waiting.pop_back() else {
                        return Err(Error::Coordinator(
                            "steal victim queue emptied underneath the scheduler".into(),
                        ));
                    };
                    // The thief was idle: joining the victim's timeline
                    // forward-only keeps its clock monotone and the
                    // replay deterministic.
                    self.cores[k].fast_forward_to(from_now);
                    self.cores[k].waiting.push_back(item);
                    self.steals += 1;
                }
            }
        }
        Ok(())
    }

    /// Re-price the round's execution bursts under shared-DDR
    /// contention: with `m` cores streaming concurrently, each core's
    /// memory leg slows by the measured factor for m-way sharing, and
    /// only the slip beyond the uncontended tick lands on its clock.
    fn charge_contention(&mut self, demands: &[(usize, Vec<TickDemand>)]) {
        let m = demands.len();
        if m <= 1 {
            return; // a lone stream has the controller to itself.
        }
        let factors = self.slowdown_factors(m);
        for (rank, (k, ticks)) in demands.iter().enumerate() {
            let f = factors[rank];
            if f <= 1.0 {
                continue;
            }
            let mut slip = 0.0;
            for t in ticks {
                slip += (t.compute.max(t.mem * f) - t.compute.max(t.mem)) * 1.05;
            }
            if slip > 0.0 {
                self.cores[*k].clock_cycles += slip;
                self.contention_dma_cycles += slip;
            }
        }
    }

    /// Measured per-stream slowdown for `streams`-way sharing of the
    /// DDR port group, memoized per stream count (the calibration
    /// replay is deterministic, so memoization cannot perturb replays).
    fn slowdown_factors(&mut self, streams: usize) -> Vec<f64> {
        if let Some(f) = self.slowdown_memo.get(&streams) {
            return f.clone();
        }
        let model = self.cores[0].isax_model;
        let f = model.shared_stream_slowdown(&self.cores[0].bus, streams, self.cfg.ddr_banks);
        self.slowdown_memo.insert(streams, f.clone());
        f
    }
}
