//! §4.1 latency recurrences and the §4.3 closed-form `T_k` approximation.
//!
//! For a sequence of `N` same-kind transactions on interface `k`, with
//! `m_j` the size of the `j`-th transaction, the paper defines issue cycle
//! `a_j` and completion cycle `b_j` (`a_j = b_j = -1` for `j ≤ 0`):
//!
//! ```text
//! a_j      = 1 + max(a_{j-1}, b_{j-I_k})
//! b_j(ld)  = m_j/W_k + max(b_{j-1}, a_j + L_k - 1)
//! b_j(st)  = m_j/W_k + E_k + max(b_{j-1}, a_j - 1)
//! ```
//!
//! These serialize transactions waiting for structural (in-flight) slots
//! while overlapping data beats; `b_N` is the sequence latency.

use crate::interface::model::MemInterface;

/// Load or store; the paper's model treats the two directions separately.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TransactionKind {
    /// Memory → ISAX (pays the read lead-off latency `L_k`).
    Load,
    /// ISAX → memory (pays the write completion cost `E_k`).
    Store,
}

/// Exact sequence latency `b_N` (in cycles) for same-kind transactions of
/// `sizes` bytes issued back-to-back on `itfc`, per the §4.1 recurrences.
///
/// Sizes need not be legal single transactions: beat counts round up
/// (`⌈m / W_k⌉`, the hardware's padded-beat runtime fallback path), the
/// same rule the event-driven simulator
/// ([`crate::interface::dmasim`]) applies — so the two stay comparable
/// on any trace.
pub fn sequence_latency(itfc: &MemInterface, kind: TransactionKind, sizes: &[usize]) -> u64 {
    if sizes.is_empty() {
        return 0;
    }
    let n = sizes.len();
    let i_k = itfc.in_flight.max(1);
    // a/b indexed 1..=n with the -1 initial condition for j <= 0.
    let mut a = vec![-1i64; n + 1];
    let mut b = vec![-1i64; n + 1];
    for j in 1..=n {
        let beats = sizes[j - 1].div_ceil(itfc.width) as i64;
        let b_blocked = if j > i_k { b[j - i_k] } else { -1 };
        a[j] = 1 + a[j - 1].max(b_blocked);
        b[j] = match kind {
            TransactionKind::Load => beats + b[j - 1].max(a[j] + itfc.read_lead as i64 - 1),
            TransactionKind::Store => {
                beats + itfc.write_cost as i64 + b[j - 1].max(a[j] - 1)
            }
        };
    }
    b[n].max(0) as u64
}

/// Completion cycles of every transaction in the sequence (`b_1..=b_N`).
/// Used by the timing-diagram reproduction (Figure 3) and the ISAX engine.
pub fn completion_cycles(
    itfc: &MemInterface,
    kind: TransactionKind,
    sizes: &[usize],
) -> Vec<u64> {
    let n = sizes.len();
    let i_k = itfc.in_flight.max(1);
    let mut a = vec![-1i64; n + 1];
    let mut b = vec![-1i64; n + 1];
    let mut out = Vec::with_capacity(n);
    for j in 1..=n {
        let beats = sizes[j - 1].div_ceil(itfc.width) as i64;
        let b_blocked = if j > i_k { b[j - i_k] } else { -1 };
        a[j] = 1 + a[j - 1].max(b_blocked);
        b[j] = match kind {
            TransactionKind::Load => beats + b[j - 1].max(a[j] + itfc.read_lead as i64 - 1),
            TransactionKind::Store => {
                beats + itfc.write_cost as i64 + b[j - 1].max(a[j] - 1)
            }
        };
        out.push(b[j].max(0) as u64);
    }
    out
}

/// The §4.3 closed-form approximation of the transfer latency on interface
/// `k`, given the decomposed segment sizes of every operation assigned to
/// it (`segments[q][p]` = bytes of segment `p` of operation `q`):
///
/// ```text
/// T_k(ld) = L_k - 1 + Σ_q Σ_p max(L_k / I_k, m_qp / W_k)
/// T_k(st) = Σ_q Σ_p (m_qp / W_k + E_k) - 1
/// ```
///
/// The `L_k / I_k` term simulates the bubbles introduced by the limited
/// in-flight window. Returns 0 when nothing is assigned.
///
/// **Documented error bound** (checked by `tests/proptests.rs` across
/// randomized interface configs): for back-to-back same-kind sequences of
/// uniform legal sizes, the store form reproduces the exact §4.1
/// recurrence (the store path serializes on completions, which the
/// closed form models exactly), while the load form stays within **50%**
/// relative error of it. The load gap comes from dropping the
/// per-transaction issue cycle: at `I_k = 1` the exact per-transaction
/// cost is `beats + L_k` but the closed form charges `max(L_k, beats)`,
/// so the error approaches `min(L_k, beats) / (beats + L_k) < 1/2` (worst
/// near `beats ≈ L_k`) and shrinks as `I_k` grows.
pub fn tk_estimate(itfc: &MemInterface, kind: TransactionKind, segments: &[Vec<usize>]) -> f64 {
    if segments.iter().all(|s| s.is_empty()) {
        return 0.0;
    }
    let w = itfc.width as f64;
    match kind {
        TransactionKind::Load => {
            let bubble = itfc.read_lead as f64 / itfc.in_flight.max(1) as f64;
            let sum: f64 = segments
                .iter()
                .flat_map(|segs| segs.iter())
                .map(|&m| (m as f64 / w).max(bubble))
                .sum();
            itfc.read_lead as f64 - 1.0 + sum
        }
        TransactionKind::Store => {
            let sum: f64 = segments
                .iter()
                .flat_map(|segs| segs.iter())
                .map(|&m| m as f64 / w + itfc.write_cost as f64)
                .sum();
            sum - 1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interface::model::MemInterface;

    fn itfc1() -> MemInterface {
        // Figure 2(a) @itfc1: 32-bit, no burst, 1 in-flight, low latency.
        MemInterface { read_lead: 2, ..MemInterface::cpu_port() }
    }

    fn itfc2() -> MemInterface {
        // Figure 2(a) @itfc2: 64-bit, burst, 2 in-flight, higher latency.
        MemInterface { read_lead: 6, ..MemInterface::system_bus() }
    }

    #[test]
    fn empty_sequence_is_zero() {
        assert_eq!(sequence_latency(&itfc1(), TransactionKind::Load, &[]), 0);
    }

    #[test]
    fn single_load_lead_plus_beats() {
        // j=1: a=1+max(-1,-1)= 0? -> a_1 = 1 + max(a_0,b_{1-I}) = 1 + (-1) = 0
        // b_1 = m/W + max(b_0, a_1 + L - 1) = 1 + max(-1, 0+2-1=1) = 2
        assert_eq!(sequence_latency(&itfc1(), TransactionKind::Load, &[4]), 2);
    }

    #[test]
    fn single_store_cost() {
        // b_1 = m/W + E + max(b_0, a_1 - 1) = 1 + 1 + max(-1, -1) = 1
        assert_eq!(sequence_latency(&itfc1(), TransactionKind::Store, &[4]), 1);
    }

    #[test]
    fn loads_serialize_on_single_inflight() {
        // I=1: each load waits for the previous completion.
        let one = sequence_latency(&itfc1(), TransactionKind::Load, &[4]);
        let two = sequence_latency(&itfc1(), TransactionKind::Load, &[4, 4]);
        // second issues only after first completes: a_2 = 1 + b_1
        assert!(two >= one + 3, "two={two}, one={one}");
    }

    #[test]
    fn pipelining_with_two_inflight_overlaps() {
        // On itfc2 (I=2) consecutive loads overlap their lead-off latency.
        let k = itfc2();
        let solo = sequence_latency(&k, TransactionKind::Load, &[8]);
        let pair = sequence_latency(&k, TransactionKind::Load, &[8, 8]);
        assert!(pair < 2 * solo, "pair={pair} solo={solo}");
    }

    #[test]
    fn burst_beats_word_by_word() {
        // 64B over itfc2 as one burst vs 16 word loads over itfc1.
        let burst = sequence_latency(&itfc2(), TransactionKind::Load, &[64]);
        let words = sequence_latency(&itfc1(), TransactionKind::Load, &vec![4; 16]);
        assert!(burst < words, "burst={burst} words={words}");
    }

    #[test]
    fn completion_cycles_monotone() {
        let cs = completion_cycles(&itfc2(), TransactionKind::Load, &[64, 32, 8, 4]);
        assert_eq!(cs.len(), 4);
        assert!(cs.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(
            *cs.last().unwrap(),
            sequence_latency(&itfc2(), TransactionKind::Load, &[64, 32, 8, 4])
        );
    }

    #[test]
    fn figure2_suboptimal_choice_costs_cycles() {
        // Figure 2(b): moving a large transfer from the narrow port to the
        // burst-capable bus wins despite higher lead-off latency.
        let large = 32; // bytes
        let cpu = sequence_latency(&itfc1(), TransactionKind::Load, &vec![4; large / 4]);
        let bus = sequence_latency(&itfc2(), TransactionKind::Load, &[large]);
        assert!(
            cpu >= bus + 7,
            "expected ≥7-cycle penalty for the narrow port: cpu={cpu} bus={bus}"
        );
    }

    #[test]
    fn tk_load_includes_bubbles() {
        let k = itfc2(); // L=6, I=2 -> bubble = 3
        // One op, one 8B segment: T = 6 - 1 + max(3, 1) = 8
        let t = tk_estimate(&k, TransactionKind::Load, &[vec![8]]);
        assert!((t - 8.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn tk_store_linear() {
        let k = itfc1(); // W=4, E=1
        // Two 4B segments: (1+1)+(1+1) - 1 = 3
        let t = tk_estimate(&k, TransactionKind::Store, &[vec![4, 4]]);
        assert!((t - 3.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn tk_empty_is_zero() {
        assert_eq!(tk_estimate(&itfc1(), TransactionKind::Load, &[]), 0.0);
        assert_eq!(tk_estimate(&itfc1(), TransactionKind::Load, &[vec![]]), 0.0);
    }

    #[test]
    fn tk_tracks_exact_model_shape() {
        // The approximation should rank interfaces the same way the exact
        // recurrence does for bulk transfers.
        let cpu = itfc1();
        let bus = itfc2();
        let segs_cpu: Vec<Vec<usize>> = vec![vec![4; 27]]; // 108B word-by-word
        let segs_bus: Vec<Vec<usize>> = vec![vec![64, 32, 8, 4]];
        let t_cpu = tk_estimate(&cpu, TransactionKind::Load, &segs_cpu);
        let t_bus = tk_estimate(&bus, TransactionKind::Load, &segs_bus);
        let e_cpu = sequence_latency(&cpu, TransactionKind::Load, &vec![4; 27]) as f64;
        let e_bus = sequence_latency(&bus, TransactionKind::Load, &[64, 32, 8, 4]) as f64;
        assert_eq!(t_cpu > t_bus, e_cpu > e_bus);
    }
}
