//! Event-driven simulator for the §4.1 burst DMA engine.
//!
//! [`latency`](crate::interface::latency) prices transaction sequences
//! with the paper's *closed-form* recurrences; this module executes them
//! as a discrete-event simulation instead, so the timing model can
//! represent effects the closed form cannot see:
//!
//! - **per-interface request queues** honoring the in-flight limit `I_k`
//!   (a transaction issues only when a structural slot frees up);
//! - **burst splitting** at the alignment boundaries of §4.3
//!   canonicalization ([`MemInterface::decompose`]) and **burst
//!   coalescing** of address-contiguous runs back into maximal legal
//!   transactions ([`coalesce`]);
//! - **multi-banked scratchpad conflicts**: each interface delivers one
//!   beat per cycle, and an SRAM with `B` banks accepts at most `B` beats
//!   per cycle across *all* interfaces — beats that find every bank port
//!   busy slip to later cycles (the arbitration `hwgen` inserts; bank
//!   counts come from its [`SramDesc`](crate::synthesis::hwgen::SramDesc)
//!   census).
//!
//! **Uncontended equivalence.** With a single traffic stream and no
//! oversubscribed SRAM, the event engine reproduces
//! [`sequence_latency`](crate::interface::latency::sequence_latency) /
//! the mixed-kind §4.1 recurrence *exactly*, cycle for cycle — issue
//! cycles follow `a_j = 1 + max(a_{j-1}, b_{j-I_k})` and beat delivery
//! starts the cycle after `max(b_{j-1}, a_j + L_k - 1)` (loads) or
//! `max(b_{j-1}, a_j - 1)` (stores). `rust/tests/proptests.rs` and
//! `rust/tests/dmasim_diff.rs` pin this, which turns the documented §4.3
//! `T_k` error bound (store form exact, load form within 50%) into an
//! executable claim against the simulator instead of a comment.
//!
//! Determinism: transactions are dispatched strictly by tentative issue
//! cycle (ties go to the lower interface id), and bank ports are claimed
//! first-fit in time in that dispatch order, so every replay of the same
//! input is cycle-identical.

use std::collections::{HashMap, VecDeque};

use crate::error::{Error, Result};
use crate::interface::latency::TransactionKind;
use crate::interface::model::{InterfaceId, InterfaceSet, MemInterface};
use crate::util::rng::Rng;

/// One *already decomposed* (legal-size) transaction fed to the engine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimTxn {
    /// Memory-op id this transaction belongs to (caller-defined grouping).
    pub op: usize,
    /// Interface the transaction is bound to.
    pub itfc: InterfaceId,
    /// Transfer direction.
    pub kind: TransactionKind,
    /// Start byte address (used by [`coalesce`] to detect contiguity).
    pub addr: u64,
    /// Transaction size in bytes.
    pub size: usize,
    /// Index into the simulation's SRAM table when the transaction drains
    /// into (or out of) a banked scratchpad; `None` opts out of bank
    /// conflict modelling.
    pub sram: Option<usize>,
}

/// One un-split request: `bytes` starting at `addr`, decomposed into
/// legal transactions by [`simulate`] exactly as §4.3 canonicalization
/// would ([`MemInterface::decompose`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimRequest {
    /// Memory-op id (carried through to the emitted transactions).
    pub op: usize,
    /// Interface the request is bound to.
    pub itfc: InterfaceId,
    /// Transfer direction.
    pub kind: TransactionKind,
    /// Start byte address.
    pub addr: u64,
    /// Total bytes to move.
    pub bytes: usize,
    /// Target scratchpad (index into the SRAM table), if bank conflicts
    /// should be modelled for this request.
    pub sram: Option<usize>,
}

/// One banked scratchpad port group visible to the simulation (the bank
/// census `hwgen` computes per surviving scratchpad).
#[derive(Debug, Clone, PartialEq)]
pub struct SramSpec {
    /// Scratchpad name (diagnostics only).
    pub name: String,
    /// Number of banks = beats the SRAM accepts per cycle. Clamped to a
    /// minimum of 1.
    pub banks: usize,
}

/// Timing record of one simulated transaction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TxnRecord {
    /// Memory-op id from the input.
    pub op: usize,
    /// Interface the transaction ran on.
    pub itfc: InterfaceId,
    /// Transfer direction.
    pub kind: TransactionKind,
    /// Transaction size in bytes.
    pub size: usize,
    /// Issue cycle `a_j`.
    pub issue: u64,
    /// Completion cycle `b_j`.
    pub complete: u64,
    /// Cycles this transaction lost to SRAM bank-port conflicts.
    pub conflict_cycles: u64,
}

/// Everything one simulation run produced.
#[derive(Debug, Clone, Default)]
pub struct SimOutcome {
    /// Per-transaction records in dispatch order.
    pub txns: Vec<TxnRecord>,
    /// Final completion cycle per interface that saw traffic.
    pub per_itfc: Vec<(InterfaceId, u64)>,
    /// Completion cycle of the last transaction across all interfaces.
    pub makespan: u64,
    /// Total cycles lost to bank conflicts across all transactions.
    pub conflict_cycles: u64,
}

impl SimOutcome {
    /// Final completion cycle on one interface (0 when it saw no traffic).
    pub fn itfc_cycles(&self, id: InterfaceId) -> u64 {
        self.per_itfc.iter().find(|&&(k, _)| k == id).map(|&(_, c)| c).unwrap_or(0)
    }
}

/// Per-interface §4.1 recurrence state: last issue cycle, last
/// completion, and the ring of the last `I_k` completions (`b_{j-I_k}`
/// is the front of a full ring). The `-1` values are the paper's initial
/// conditions for `j ≤ 0`.
#[derive(Debug, Clone)]
struct ChanState {
    a_prev: i64,
    b_prev: i64,
    ring: VecDeque<i64>,
}

impl ChanState {
    fn new() -> Self {
        Self { a_prev: -1, b_prev: -1, ring: VecDeque::new() }
    }

    /// `a_j = 1 + max(a_{j-1}, b_{j-I_k})`.
    fn tentative_issue(&self, i_k: usize) -> i64 {
        let blocked = if self.ring.len() >= i_k { *self.ring.front().expect("non-empty") } else { -1 };
        1 + self.a_prev.max(blocked)
    }

    /// Issue cycle `a_j` and the first unobstructed data-beat cycle `s0`
    /// for the channel's next transaction — the single in-crate home of
    /// the event-side §4.1 recurrence. (The closed forms in `latency.rs`
    /// / `scheduling.rs` are deliberately *independent* implementations:
    /// the equivalence property tests compare the two, which would be
    /// tautological if they shared this code.)
    fn begin(&self, m: &MemInterface, kind: TransactionKind) -> (i64, i64) {
        let a = self.tentative_issue(m.in_flight.max(1));
        let s0 = match kind {
            TransactionKind::Load => self.b_prev.max(a + m.read_lead as i64 - 1) + 1,
            TransactionKind::Store => self.b_prev.max(a - 1) + 1,
        };
        (a, s0)
    }

    /// Unobstructed advance (no SRAM contention): beats land back to
    /// back from `s0`, stores pay `E_k` after the last beat. Returns the
    /// completion cycle.
    fn advance(&mut self, m: &MemInterface, kind: TransactionKind, size: usize) -> i64 {
        let (a, s0) = self.begin(m, kind);
        let last = s0 + beats_of(m, size) - 1;
        let b = match kind {
            TransactionKind::Load => last,
            TransactionKind::Store => last + m.write_cost as i64,
        };
        self.commit(m.in_flight.max(1), a, b);
        b
    }

    fn commit(&mut self, i_k: usize, a: i64, b: i64) {
        self.a_prev = a;
        self.b_prev = b;
        self.ring.push_back(b);
        while self.ring.len() > i_k {
            self.ring.pop_front();
        }
    }
}

/// Beat count of a transaction (runts round up to one beat — the
/// hardware's padded-beat fallback, mirroring `sequence_latency`).
fn beats_of(itfc: &MemInterface, size: usize) -> i64 {
    (size.div_ceil(itfc.width) as i64).max(1)
}

/// Claim `beats` one-per-cycle SRAM port slots at cycles `>= s0`,
/// skipping cycles where all `banks` ports are taken. Returns the cycle
/// of the last delivered beat and the conflict delay vs an unobstructed
/// run.
fn place_beats(occ: &mut HashMap<i64, u32>, banks: u32, s0: i64, beats: i64) -> (i64, u64) {
    let mut placed = 0i64;
    let mut c = s0;
    let mut last = s0;
    while placed < beats {
        let used = occ.entry(c).or_insert(0);
        if *used < banks {
            *used += 1;
            placed += 1;
            last = c;
        }
        c += 1;
    }
    (last, (last - (s0 + beats - 1)).max(0) as u64)
}

/// Run the event engine over already-decomposed transactions.
///
/// Transactions execute FIFO *per interface* (input order); interfaces
/// run in parallel and interact only through shared SRAM bank ports.
/// Zero-size transactions are skipped.
pub fn simulate_txns(
    itfcs: &InterfaceSet,
    srams: &[SramSpec],
    txns: &[SimTxn],
) -> Result<SimOutcome> {
    let n_chan = itfcs.len();
    let mut queues: Vec<VecDeque<SimTxn>> = vec![VecDeque::new(); n_chan];
    for t in txns {
        if t.itfc.0 >= n_chan {
            return Err(Error::Interface(format!(
                "dmasim: transaction bound to unknown interface {} ({} declared)",
                t.itfc, n_chan
            )));
        }
        if let Some(s) = t.sram {
            if s >= srams.len() {
                return Err(Error::Interface(format!(
                    "dmasim: transaction targets unknown sram index {s} ({} declared)",
                    srams.len()
                )));
            }
        }
        if t.size == 0 {
            continue;
        }
        queues[t.itfc.0].push_back(*t);
    }

    let mut chans: Vec<ChanState> = (0..n_chan).map(|_| ChanState::new()).collect();
    let mut occ: Vec<HashMap<i64, u32>> = vec![HashMap::new(); srams.len()];
    let mut had_traffic = vec![false; n_chan];
    let mut out = SimOutcome::default();

    loop {
        // Dispatch: the pending transaction with the earliest tentative
        // issue cycle goes next (ties: lowest interface id).
        let mut pick: Option<(usize, i64)> = None;
        for (k, q) in queues.iter().enumerate() {
            if q.is_empty() {
                continue;
            }
            let i_k = itfcs.get(InterfaceId(k)).in_flight.max(1);
            let a = chans[k].tentative_issue(i_k);
            if pick.map_or(true, |(_, best)| a < best) {
                pick = Some((k, a));
            }
        }
        let Some((k, a)) = pick else { break };
        let itfc = itfcs.get(InterfaceId(k));
        let t = queues[k].pop_front().expect("picked channel has work");
        let beats = beats_of(itfc, t.size);
        // First data beat lands the cycle after the §4.1 max() term.
        let (a, s0) = {
            let (a2, s0) = chans[k].begin(itfc, t.kind);
            debug_assert_eq!(a, a2, "dispatch used a stale issue cycle");
            (a2, s0)
        };
        let (last_beat, conflict) = match t.sram {
            Some(s) => place_beats(&mut occ[s], srams[s].banks.max(1) as u32, s0, beats),
            None => (s0 + beats - 1, 0),
        };
        let b = match t.kind {
            TransactionKind::Load => last_beat,
            TransactionKind::Store => last_beat + itfc.write_cost as i64,
        };
        let i_k = itfc.in_flight.max(1);
        chans[k].commit(i_k, a, b);
        had_traffic[k] = true;
        out.conflict_cycles += conflict;
        out.txns.push(TxnRecord {
            op: t.op,
            itfc: t.itfc,
            kind: t.kind,
            size: t.size,
            issue: a.max(0) as u64,
            complete: b.max(0) as u64,
            conflict_cycles: conflict,
        });
    }

    for k in 0..n_chan {
        if had_traffic[k] {
            let c = chans[k].b_prev.max(0) as u64;
            out.per_itfc.push((InterfaceId(k), c));
            out.makespan = out.makespan.max(c);
        }
    }
    Ok(out)
}

/// Split every request into legal transactions (§4.3 canonicalization)
/// and run the event engine.
pub fn simulate(
    itfcs: &InterfaceSet,
    srams: &[SramSpec],
    requests: &[SimRequest],
) -> Result<SimOutcome> {
    let mut txns = Vec::new();
    for r in requests {
        if r.itfc.0 >= itfcs.len() {
            return Err(Error::Interface(format!(
                "dmasim: request bound to unknown interface {} ({} declared)",
                r.itfc,
                itfcs.len()
            )));
        }
        let itfc = itfcs.get(r.itfc);
        let mut addr = r.addr;
        for m in itfc.decompose(r.addr, r.bytes) {
            txns.push(SimTxn {
                op: r.op,
                itfc: r.itfc,
                kind: r.kind,
                addr,
                size: m,
                sram: r.sram,
            });
            addr += m as u64;
        }
    }
    simulate_txns(itfcs, srams, &txns)
}

/// Single-interface, same-kind convenience replay: the event-engine
/// counterpart of [`sequence_latency`](crate::interface::latency::sequence_latency),
/// and provably equal to it on traces of non-zero sizes (no contention
/// is possible on one stream). Zero-size entries are *skipped* by every
/// dmasim entry point, whereas the closed form still spends an issue
/// slot on them — `decompose` never emits zeros, so real traces cannot
/// observe the difference.
pub fn simulate_sizes(itfc: &MemInterface, kind: TransactionKind, sizes: &[usize]) -> u64 {
    let set = InterfaceSet::new(vec![itfc.clone()]);
    let txns: Vec<SimTxn> = sizes
        .iter()
        .enumerate()
        .map(|(i, &size)| SimTxn {
            op: i,
            itfc: InterfaceId(0),
            kind,
            addr: 0,
            size,
            sram: None,
        })
        .collect();
    simulate_txns(&set, &[], &txns).expect("single-interface replay cannot fail").makespan
}

/// Allocation-free single-stream replay: advance one channel's §4.1
/// recurrence state over a same-kind size stream and return the final
/// completion cycle. Identical to [`simulate_sizes`] by construction
/// (same channel-state code path, no per-transaction records) — this is
/// the hot-path entry the serving coordinator prices per-tick KV block
/// gathers with, where materializing `SimTxn`/[`TxnRecord`]s for tens of
/// thousands of uniform transactions would be pure overhead.
pub fn stream_makespan(
    itfc: &MemInterface,
    kind: TransactionKind,
    sizes: impl Iterator<Item = usize>,
) -> u64 {
    let mut ch = ChanState::new();
    for size in sizes {
        if size == 0 {
            continue;
        }
        ch.advance(itfc, kind, size);
    }
    ch.b_prev.max(0) as u64
}

/// Deterministic per-transaction DMA error model: each transaction fails
/// independently with a seeded probability and is retried ECC-style with
/// bounded exponential backoff, billed in simulated beats.
///
/// A failed attempt costs the transaction's full beat count (the burst
/// must be replayed) plus a backoff of `2^attempt` beats; after
/// `max_retries` consecutive failures the engine gives up and lets the
/// original (clean) transfer stand — the model prices *transient* ECC
/// errors, not hard faults. With `prob == 0` the injector is inert and
/// every priced stream is bitwise identical to [`stream_makespan`].
#[derive(Debug, Clone)]
pub struct DmaFaultInjector {
    prob: f64,
    rng: Rng,
    max_retries: u32,
    retried_bursts: u64,
    retries: u64,
    penalty_beats: u64,
}

impl DmaFaultInjector {
    /// An injector failing each transaction with probability `prob`
    /// (clamped to `[0, 1]`), drawing from a PRNG seeded with `seed`.
    pub fn new(prob: f64, seed: u64) -> Self {
        Self {
            prob: prob.clamp(0.0, 1.0),
            rng: Rng::new(seed),
            max_retries: 4,
            retried_bursts: 0,
            retries: 0,
            penalty_beats: 0,
        }
    }

    /// True when the injector can actually perturb timing (`prob > 0`).
    /// Inactive injectors must not be consulted at all on hot paths, so
    /// that zero-probability plans never touch the PRNG.
    pub fn is_active(&self) -> bool {
        self.prob > 0.0
    }

    /// Number of transactions that needed at least one retry.
    pub fn retried_bursts(&self) -> u64 {
        self.retried_bursts
    }

    /// Total retry attempts across all transactions.
    pub fn retries(&self) -> u64 {
        self.retries
    }

    /// Total beats billed to retries and backoff so far.
    pub fn penalty_beats(&self) -> u64 {
        self.penalty_beats
    }

    /// Extra beats charged to one transaction: each failed attempt
    /// replays the burst (`beats`) after an exponential backoff, up to
    /// `max_retries` attempts.
    fn txn_penalty(&mut self, itfc: &MemInterface, size: usize) -> u64 {
        if self.prob <= 0.0 {
            return 0;
        }
        let beats = beats_of(itfc, size) as u64;
        let mut penalty = 0u64;
        let mut backoff = 1u64;
        let mut attempts = 0u64;
        while attempts < self.max_retries as u64 && self.rng.bool(self.prob) {
            penalty += backoff + beats;
            backoff *= 2;
            attempts += 1;
        }
        if attempts > 0 {
            self.retried_bursts += 1;
            self.retries += attempts;
            self.penalty_beats += penalty;
        }
        penalty
    }
}

/// [`stream_makespan`] with a fault injector in the datapath: every
/// transaction advances the clean §4.1 recurrence, then pays its retry
/// penalty (if any) as extra completion cycles. With an inactive
/// injector the result equals [`stream_makespan`] exactly and the PRNG
/// is never consulted.
pub fn stream_makespan_faulty(
    itfc: &MemInterface,
    kind: TransactionKind,
    sizes: impl Iterator<Item = usize>,
    faults: &mut DmaFaultInjector,
) -> u64 {
    let mut ch = ChanState::new();
    let mut penalty = 0u64;
    for size in sizes {
        if size == 0 {
            continue;
        }
        ch.advance(itfc, kind, size);
        penalty += faults.txn_penalty(itfc, size);
    }
    ch.b_prev.max(0) as u64 + penalty
}

/// Merge runs of address-contiguous, same-direction, same-target
/// transactions and re-split them into maximal legal bursts on `itfc` —
/// the coalescing a burst engine performs when small requests line up.
///
/// Models **one** engine: every transaction must be bound to the
/// interface whose geometry `itfc` describes, since merged runs are
/// re-decomposed against it (debug-asserted; a mixed-interface trace
/// would be re-split into sizes the other interfaces cannot issue).
/// Coalesce per interface before merging streams.
pub fn coalesce(itfc: &MemInterface, txns: &[SimTxn]) -> Vec<SimTxn> {
    debug_assert!(
        txns.windows(2).all(|w| w[0].itfc == w[1].itfc),
        "coalesce models a single interface's engine; split the trace per interface first"
    );
    let mut out = Vec::new();
    let mut run: Option<(SimTxn, u64, usize)> = None; // (head, end addr, bytes)
    let mut flush = |run: &mut Option<(SimTxn, u64, usize)>, out: &mut Vec<SimTxn>| {
        if let Some((head, _, bytes)) = run.take() {
            let mut addr = head.addr;
            for m in itfc.decompose(head.addr, bytes) {
                out.push(SimTxn { addr, size: m, ..head });
                addr += m as u64;
            }
        }
    };
    for t in txns {
        match &mut run {
            Some((head, end, bytes))
                if head.itfc == t.itfc
                    && head.kind == t.kind
                    && head.sram == t.sram
                    && *end == t.addr =>
            {
                *end += t.size as u64;
                *bytes += t.size;
            }
            _ => {
                flush(&mut run, &mut out);
                run = Some((*t, t.addr + t.size as u64, t.size));
            }
        }
    }
    flush(&mut run, &mut out);
    out
}

/// Incremental issue-stream pricer used by the IR engines to charge
/// temporal-level `copy_issue` ops
/// ([`ExecStats::dma_cycles`](crate::ir::interp::ExecStats)): the same
/// per-channel §4.1 recurrence as the event engine, advanced one
/// transaction at a time in program order, without SRAM modelling.
///
/// Both the tree-walking interpreter and the bytecode VM drive one of
/// these with the identical issue sequence, so the charged cycles are
/// bit-identical across engines by construction.
#[derive(Debug, Clone)]
pub struct IssueClock {
    itfcs: InterfaceSet,
    chans: Vec<ChanState>,
}

impl IssueClock {
    /// A clock over the given interface set.
    pub fn new(itfcs: InterfaceSet) -> Self {
        let chans = (0..itfcs.len().max(1)).map(|_| ChanState::new()).collect();
        Self { itfcs, chans }
    }

    /// A clock over the default §6.1 Rocket interface pair — what the IR
    /// engines use, since Aquas-IR carries only interface *ids*.
    pub fn rocket_default() -> Self {
        Self::new(InterfaceSet::rocket_default())
    }

    /// Price one issued transaction; returns its completion cycle.
    /// Interface ids beyond the configured set are a hard
    /// [`Error::Interface`] — the silent clamp this used to apply was a
    /// wrong-answer debt (a program priced against the wrong channel),
    /// closed now that the IR engines can bind a real `InterfaceSet` via
    /// `run_with_itfcs`. Zero-size issues are no-ops completing at the
    /// channel's current completion cycle — the same skip rule the event
    /// engine applies.
    pub fn issue(
        &mut self,
        itfc: InterfaceId,
        kind: TransactionKind,
        size: usize,
    ) -> Result<u64> {
        let Some(m) = self.itfcs.interfaces.get(itfc.0) else {
            return Err(Error::Interface(format!(
                "issue clock: transaction bound to unknown interface {} ({} declared)",
                itfc.0,
                self.itfcs.len()
            )));
        };
        if size == 0 {
            return Ok(self.chans[itfc.0].b_prev.max(0) as u64);
        }
        Ok(self.chans[itfc.0].advance(m, kind, size).max(0) as u64)
    }

    /// Latest completion cycle across all channels so far.
    pub fn makespan(&self) -> u64 {
        self.chans.iter().map(|c| c.b_prev.max(0) as u64).max().unwrap_or(0)
    }

    /// Beat count a transaction of `size` bytes would occupy on `itfc`,
    /// without advancing the clock. Fuel metering bills issued copies by
    /// this count *before* calling [`IssueClock::issue`]; unknown
    /// interface ids price as 0 so the subsequent `issue` raises the same
    /// hard error it always did (at the identical fuel spend in both IR
    /// engines). Zero-size issues are no-ops and price as 0.
    pub fn txn_beats(&self, itfc: InterfaceId, size: usize) -> u64 {
        match self.itfcs.interfaces.get(itfc.0) {
            Some(m) if size > 0 => beats_of(m, size).max(0) as u64,
            _ => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interface::latency::sequence_latency;

    fn itfc1() -> MemInterface {
        MemInterface { read_lead: 2, ..MemInterface::cpu_port() }
    }

    fn itfc2() -> MemInterface {
        MemInterface { read_lead: 6, ..MemInterface::system_bus() }
    }

    #[test]
    fn single_stream_matches_recurrence_exactly() {
        for itfc in [itfc1(), itfc2()] {
            for kind in [TransactionKind::Load, TransactionKind::Store] {
                for sizes in [vec![4usize], vec![64, 32, 8, 4], vec![8; 16], vec![4, 64, 4]] {
                    let sim = simulate_sizes(&itfc, kind, &sizes);
                    let closed = sequence_latency(&itfc, kind, &sizes);
                    assert_eq!(sim, closed, "{kind:?} {sizes:?} on {}", itfc.name);
                }
            }
        }
    }

    #[test]
    fn empty_trace_is_zero() {
        assert_eq!(simulate_sizes(&itfc1(), TransactionKind::Load, &[]), 0);
        assert_eq!(stream_makespan(&itfc1(), TransactionKind::Load, std::iter::empty()), 0);
    }

    #[test]
    fn stream_makespan_equals_recorded_replay() {
        for itfc in [itfc1(), itfc2()] {
            for kind in [TransactionKind::Load, TransactionKind::Store] {
                for sizes in [vec![4usize], vec![64, 32, 8, 4], vec![8; 32], vec![0, 8, 8]] {
                    assert_eq!(
                        stream_makespan(&itfc, kind, sizes.iter().copied()),
                        simulate_sizes(&itfc, kind, &sizes),
                        "{kind:?} {sizes:?} on {}",
                        itfc.name
                    );
                }
            }
        }
    }

    #[test]
    fn split_requests_equal_presplit_transactions() {
        let set = InterfaceSet::new(vec![itfc2()]);
        let req = SimRequest {
            op: 0,
            itfc: InterfaceId(0),
            kind: TransactionKind::Load,
            addr: 0,
            bytes: 108,
            sram: None,
        };
        let by_req = simulate(&set, &[], &[req]).unwrap();
        let sizes = itfc2().decompose(0, 108);
        assert_eq!(sizes, vec![64, 32, 8, 4]);
        assert_eq!(by_req.makespan, simulate_sizes(&itfc2(), TransactionKind::Load, &sizes));
        assert_eq!(by_req.txns.len(), 4);
    }

    #[test]
    fn parallel_interfaces_do_not_serialize() {
        // Two independent streams finish in max() time, not sum().
        let set = InterfaceSet::new(vec![itfc1(), itfc2()]);
        let txns = [
            SimTxn { op: 0, itfc: InterfaceId(0), kind: TransactionKind::Load, addr: 0, size: 4, sram: None },
            SimTxn { op: 1, itfc: InterfaceId(1), kind: TransactionKind::Load, addr: 0, size: 64, sram: None },
        ];
        let out = simulate_txns(&set, &[], &txns).unwrap();
        let solo0 = simulate_sizes(&itfc1(), TransactionKind::Load, &[4]);
        let solo1 = simulate_sizes(&itfc2(), TransactionKind::Load, &[64]);
        assert_eq!(out.itfc_cycles(InterfaceId(0)), solo0);
        assert_eq!(out.itfc_cycles(InterfaceId(1)), solo1);
        assert_eq!(out.makespan, solo0.max(solo1));
    }

    #[test]
    fn single_banked_sram_conflicts_and_banking_resolves_them() {
        // A word stream on the core port and a burst stream on the bus
        // drain into the same scratchpad: with one bank the beat windows
        // collide; with two banks (one port per interface) they cannot.
        let set = InterfaceSet::new(vec![itfc1(), itfc2()]);
        let mut txns = Vec::new();
        for i in 0..16usize {
            txns.push(SimTxn {
                op: i,
                itfc: InterfaceId(0),
                kind: TransactionKind::Load,
                addr: (i * 4) as u64,
                size: 4,
                sram: Some(0),
            });
        }
        for i in 0..4usize {
            txns.push(SimTxn {
                op: 100 + i,
                itfc: InterfaceId(1),
                kind: TransactionKind::Load,
                addr: (i * 64) as u64,
                size: 64,
                sram: Some(0),
            });
        }
        let run = |banks: usize| {
            let srams = [SramSpec { name: "tile".into(), banks }];
            simulate_txns(&set, &srams, &txns).unwrap()
        };
        let contended = run(1);
        let banked = run(2);
        assert!(contended.conflict_cycles > 0, "single bank must conflict");
        assert_eq!(banked.conflict_cycles, 0, "two banks fit two interfaces");
        assert!(contended.makespan >= banked.makespan);
        // The banked run is conflict-free, so it equals the closed form.
        assert_eq!(
            banked.itfc_cycles(InterfaceId(0)),
            simulate_sizes(&itfc1(), TransactionKind::Load, &vec![4; 16])
        );
        assert_eq!(
            banked.itfc_cycles(InterfaceId(1)),
            simulate_sizes(&itfc2(), TransactionKind::Load, &vec![64; 4])
        );
    }

    #[test]
    fn conflicts_never_reduce_latency() {
        let set = InterfaceSet::new(vec![itfc1(), itfc2()]);
        let txns: Vec<SimTxn> = (0..8)
            .map(|i| SimTxn {
                op: i,
                itfc: InterfaceId(i % 2),
                kind: if i % 3 == 0 { TransactionKind::Store } else { TransactionKind::Load },
                addr: (i * 64) as u64,
                size: if i % 2 == 0 { 4 } else { 64 },
                sram: Some(0),
            })
            .collect();
        let free = simulate_txns(&set, &[SramSpec { name: "s".into(), banks: 8 }], &txns).unwrap();
        let tight = simulate_txns(&set, &[SramSpec { name: "s".into(), banks: 1 }], &txns).unwrap();
        // Conflicts may reorder dispatch, so compare completions per op.
        let unobstructed: HashMap<usize, u64> =
            free.txns.iter().map(|t| (t.op, t.complete)).collect();
        for t in &tight.txns {
            assert!(t.complete >= unobstructed[&t.op], "conflict made op {} faster", t.op);
        }
        assert!(tight.makespan >= free.makespan);
    }

    #[test]
    fn coalesce_merges_contiguous_words_into_bursts() {
        let bus = itfc2();
        let words: Vec<SimTxn> = (0..16)
            .map(|i| SimTxn {
                op: 0,
                itfc: InterfaceId(0),
                kind: TransactionKind::Load,
                addr: (i * 8) as u64,
                size: 8,
                sram: None,
            })
            .collect();
        let merged = coalesce(&bus, &words);
        // 128 contiguous bytes at 0 -> two 64B bursts.
        assert_eq!(merged.iter().map(|t| t.size).collect::<Vec<_>>(), vec![64, 64]);
        let set = InterfaceSet::new(vec![bus.clone()]);
        let before = simulate_txns(&set, &[], &words).unwrap().makespan;
        let after = simulate_txns(&set, &[], &merged).unwrap().makespan;
        assert!(after < before, "coalescing must win: {after} !< {before}");
    }

    #[test]
    fn coalesce_respects_kind_and_gaps() {
        let bus = itfc2();
        let txns = [
            SimTxn { op: 0, itfc: InterfaceId(0), kind: TransactionKind::Load, addr: 0, size: 8, sram: None },
            SimTxn { op: 0, itfc: InterfaceId(0), kind: TransactionKind::Store, addr: 8, size: 8, sram: None },
            SimTxn { op: 0, itfc: InterfaceId(0), kind: TransactionKind::Load, addr: 64, size: 8, sram: None },
        ];
        let merged = coalesce(&bus, &txns);
        assert_eq!(merged.len(), 3, "direction change and address gap both break runs");
    }

    #[test]
    fn issue_clock_tracks_the_recurrence() {
        let mut clk = IssueClock::new(InterfaceSet::new(vec![itfc1(), itfc2()]));
        let sizes = [64usize, 32, 8, 4];
        let mut last = 0;
        for &s in &sizes {
            last = clk.issue(InterfaceId(1), TransactionKind::Load, s).unwrap();
        }
        assert_eq!(last, sequence_latency(&itfc2(), TransactionKind::Load, &sizes));
        assert_eq!(clk.makespan(), last);
        // Out-of-range interface ids are a hard error, not a clamp.
        let err = clk.issue(InterfaceId(9), TransactionKind::Store, 8).unwrap_err();
        assert!(err.to_string().contains("unknown interface"));
    }

    #[test]
    fn fault_injector_is_deterministic_and_bounded() {
        let itfc = itfc2();
        let sizes = vec![64usize; 200];

        // Zero probability: bitwise identical to the clean path, PRNG
        // untouched, nothing counted.
        let mut inert = DmaFaultInjector::new(0.0, 7);
        assert!(!inert.is_active());
        let clean = stream_makespan(&itfc, TransactionKind::Load, sizes.iter().copied());
        let priced =
            stream_makespan_faulty(&itfc, TransactionKind::Load, sizes.iter().copied(), &mut inert);
        assert_eq!(priced, clean);
        assert_eq!(inert.retries(), 0);
        assert_eq!(inert.retried_bursts(), 0);

        // Same seed replays identically, and faults always cost cycles.
        let run = |seed: u64| {
            let mut inj = DmaFaultInjector::new(0.25, seed);
            let t = stream_makespan_faulty(
                &itfc,
                TransactionKind::Load,
                sizes.iter().copied(),
                &mut inj,
            );
            (t, inj.retries(), inj.penalty_beats())
        };
        let a = run(9);
        let b = run(9);
        assert_eq!(a, b, "same seed must replay bitwise");
        assert!(a.0 > clean, "injected faults must cost cycles");
        assert_eq!(a.0, clean + a.2, "penalty is billed exactly once");

        // Certain failure hits the retry bound on every transaction.
        let mut always = DmaFaultInjector::new(1.0, 3);
        stream_makespan_faulty(&itfc, TransactionKind::Load, sizes.iter().copied(), &mut always);
        assert_eq!(always.retried_bursts(), sizes.len() as u64);
        assert_eq!(always.retries(), 4 * sizes.len() as u64);
    }
}
