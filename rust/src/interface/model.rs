//! The 6-tuple interface model and its microarchitectural constraints.

use crate::error::{Error, Result};
use crate::interface::cache::HierarchyLevel;

/// Index of an interface within an [`InterfaceSet`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct InterfaceId(pub usize);

impl std::fmt::Display for InterfaceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "@itfc{}", self.0)
    }
}

/// One memory interface `k = (W, M, I, L, E, C)` (§4.1) plus the cache
/// hierarchy level it attaches to (used by transaction ordering, §4.3).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MemInterface {
    /// Symbolic name (e.g. `@cpuitfc`, `@busitfc`).
    pub name: String,
    /// `W_k`: width in bytes per beat.
    pub width: usize,
    /// `M_k`: maximum beat count of one transaction.
    pub max_beats: usize,
    /// `I_k`: maximum in-flight transactions.
    pub in_flight: usize,
    /// `L_k`: read lead-off latency in cycles.
    pub read_lead: u64,
    /// `E_k`: write completion cost in cycles.
    pub write_cost: u64,
    /// `C_k`: cache-line size in bytes visible to this interface.
    pub line: usize,
    /// Which level of the memory hierarchy this interface reaches.
    pub level: HierarchyLevel,
}

impl MemInterface {
    /// The paper's Figure 2 `@itfc1`: a RoCC/CV-X-IF-style core port —
    /// 32-bit, no burst, one in-flight transaction, low latency, L1-coupled.
    pub fn cpu_port() -> Self {
        Self {
            name: "@cpuitfc".into(),
            width: 4,
            max_beats: 1,
            in_flight: 1,
            read_lead: 2,
            write_cost: 1,
            line: 64,
            level: HierarchyLevel::L1,
        }
    }

    /// The paper's Figure 2 `@itfc2`: a system-bus port — 64-bit, burst up
    /// to 8 beats, two in-flight transactions, higher lead-off latency.
    pub fn system_bus() -> Self {
        Self {
            name: "@busitfc".into(),
            width: 8,
            max_beats: 8,
            in_flight: 2,
            read_lead: 6,
            write_cost: 2,
            line: 64,
            level: HierarchyLevel::L2,
        }
    }

    /// §6.3 variant: the PCP study widens the system bus to 128 bits.
    pub fn system_bus_128() -> Self {
        Self { name: "@busitfc128".into(), width: 16, ..Self::system_bus() }
    }

    /// Maximum legal transaction size in bytes (`W · M`).
    pub fn max_transaction(&self) -> usize {
        self.width * self.max_beats
    }

    /// Is `m` bytes a legal single transaction? Legal iff the beat count
    /// `m / W = 2^t ≤ M` for some integer `t ≥ 0` (§4.1).
    pub fn is_legal_size(&self, m: usize) -> bool {
        if m == 0 || m % self.width != 0 {
            return false;
        }
        let beats = m / self.width;
        beats.is_power_of_two() && beats <= self.max_beats
    }

    /// Is a transaction of `m` bytes at `addr` legal? The start address must
    /// be aligned to `m` (§4.1).
    pub fn is_legal(&self, addr: u64, m: usize) -> bool {
        self.is_legal_size(m) && addr % (m as u64) == 0
    }

    /// Beat count of a legal transaction.
    pub fn beats(&self, m: usize) -> Result<u64> {
        if !self.is_legal_size(m) {
            return Err(Error::Interface(format!(
                "{}: {m} bytes is not a legal transaction (W={}, M={})",
                self.name, self.width, self.max_beats
            )));
        }
        Ok((m / self.width) as u64)
    }

    /// Greedily split `size` bytes starting at `addr` into legal, naturally
    /// aligned transfers in decreasing size order (§4.3 canonicalization).
    ///
    /// For a properly aligned base this yields the paper's ordered sequence
    /// `{m_{q,p}}`; misaligned prefixes are peeled off with the largest
    /// legal size the current alignment allows.
    pub fn decompose(&self, addr: u64, size: usize) -> Vec<usize> {
        let mut out = Vec::new();
        let mut a = addr;
        let mut remaining = size;
        let min = self.width;
        while remaining > 0 {
            if remaining < min {
                // Runt smaller than one beat: hardware handles it as a
                // single (padded) beat — the runtime fallback path.
                out.push(remaining);
                break;
            }
            // Largest legal size that fits the remaining bytes and the
            // current alignment.
            let mut m = self.max_transaction();
            while m > min && (m > remaining || a % (m as u64) != 0) {
                m /= 2;
            }
            out.push(m);
            a += m as u64;
            remaining -= m;
        }
        out
    }
}

/// The set of interfaces visible to one ISAX (module-level `!memitfc<>`
/// symbols in Aquas-IR terms).
#[derive(Debug, Clone, Default)]
pub struct InterfaceSet {
    /// The declared interfaces, indexed by [`InterfaceId`].
    pub interfaces: Vec<MemInterface>,
}

impl InterfaceSet {
    /// Build a set from explicit interface declarations.
    pub fn new(interfaces: Vec<MemInterface>) -> Self {
        Self { interfaces }
    }

    /// The default ASIP configuration from §6.1: one RoCC-style core port
    /// and one system-bus port.
    pub fn rocket_default() -> Self {
        Self::new(vec![MemInterface::cpu_port(), MemInterface::system_bus()])
    }

    /// §6.3 configuration with the 128-bit system bus.
    pub fn rocket_wide_bus() -> Self {
        Self::new(vec![MemInterface::cpu_port(), MemInterface::system_bus_128()])
    }

    /// Look an interface up by id (panics on out-of-range ids).
    pub fn get(&self, id: InterfaceId) -> &MemInterface {
        &self.interfaces[id.0]
    }

    /// Number of declared interfaces.
    pub fn len(&self) -> usize {
        self.interfaces.len()
    }

    /// True when no interfaces are declared.
    pub fn is_empty(&self) -> bool {
        self.interfaces.is_empty()
    }

    /// Iterate (id, interface).
    pub fn iter(&self) -> impl Iterator<Item = (InterfaceId, &MemInterface)> {
        self.interfaces.iter().enumerate().map(|(i, m)| (InterfaceId(i), m))
    }

    /// Find an interface by symbolic name.
    pub fn by_name(&self, name: &str) -> Option<InterfaceId> {
        self.interfaces.iter().position(|m| m.name == name).map(InterfaceId)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn legality_power_of_two_beats() {
        let bus = MemInterface::system_bus(); // W=8, M=8
        assert!(bus.is_legal_size(8)); // 1 beat
        assert!(bus.is_legal_size(16)); // 2 beats
        assert!(bus.is_legal_size(32)); // 4
        assert!(bus.is_legal_size(64)); // 8
        assert!(!bus.is_legal_size(24)); // 3 beats: not 2^t
        assert!(!bus.is_legal_size(128)); // 16 beats > M
        assert!(!bus.is_legal_size(4)); // below width
        assert!(!bus.is_legal_size(0));
    }

    #[test]
    fn alignment_constraint() {
        let bus = MemInterface::system_bus();
        assert!(bus.is_legal(64, 64));
        assert!(!bus.is_legal(32, 64)); // 64B transfer must be 64B-aligned
        assert!(bus.is_legal(32, 32));
    }

    #[test]
    fn decompose_108_bytes_matches_paper() {
        // §4.3: "the 108-byte transaction is canonicalized into 64-, 32-,
        // 8-, and 4-byte legal transfers" on @busitfc.
        let bus = MemInterface::system_bus();
        assert_eq!(bus.decompose(0, 108), vec![64, 32, 8, 4]);
    }

    #[test]
    fn decompose_aligned_power_of_two() {
        let bus = MemInterface::system_bus();
        assert_eq!(bus.decompose(0, 64), vec![64]);
        assert_eq!(bus.decompose(0, 128), vec![64, 64]);
    }

    #[test]
    fn decompose_respects_alignment() {
        let bus = MemInterface::system_bus();
        // Starting at 8 mod 64: cannot open with a 64B burst.
        let parts = bus.decompose(8, 72);
        assert_eq!(parts.iter().sum::<usize>(), 72);
        let mut a = 8u64;
        for &m in &parts {
            assert!(bus.is_legal(a, m), "illegal {m}B at {a}");
            a += m as u64;
        }
    }

    #[test]
    fn decompose_cpu_port_splits_to_words() {
        let cpu = MemInterface::cpu_port();
        assert_eq!(cpu.decompose(0, 16), vec![4, 4, 4, 4]);
    }

    #[test]
    fn decompose_total_always_matches() {
        let bus = MemInterface::system_bus();
        for size in 1..300 {
            for addr in [0u64, 4, 8, 12, 20, 52] {
                let parts = bus.decompose(addr, size);
                assert_eq!(parts.iter().sum::<usize>(), size, "size={size} addr={addr}");
            }
        }
    }

    #[test]
    fn interface_set_lookup() {
        let set = InterfaceSet::rocket_default();
        assert_eq!(set.by_name("@busitfc"), Some(InterfaceId(1)));
        assert_eq!(set.by_name("@nope"), None);
        assert_eq!(set.get(InterfaceId(0)).width, 4);
    }
}
