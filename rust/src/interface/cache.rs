//! Cache hierarchy effects (§4.1 "Cache Hierarchy and Locality").
//!
//! Two mechanisms from the paper:
//! 1. the cache-line size `C_k` enters the selection objective as a
//!    synchronization penalty `⌈m_q / C_k⌉ · C_k / W_k` approximating the
//!    beats needed to refill/flush the touched lines;
//! 2. `cache_hint` labels (`warm` / `cold`) on buffers steer transfers to
//!    the hierarchy level where the data actually lives, avoiding
//!    mismatches that cost synchronization cycles and ordering decisions
//!    that evict hot data.

/// Where data is expected to live (`cache_hint` in Aquas-IR).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum CacheHint {
    /// CPU-initialized, recently-touched data — favor higher-level paths.
    Warm,
    /// Streamed-from-DRAM data (e.g. large coefficient vectors) — keep it
    /// away from the L1 to avoid thrashing.
    Cold,
    /// No information; the model assumes no mismatch penalty either way.
    #[default]
    Unknown,
}

/// Levels of the memory hierarchy an interface can attach to. Ordering:
/// `L1 < L2 < Dram` (closer to the core is "higher" / hotter).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum HierarchyLevel {
    /// The core-coupled first-level cache.
    L1,
    /// The shared second-level cache (system-bus attach point).
    L2,
    /// Main memory, below every cache.
    Dram,
}

impl HierarchyLevel {
    /// Distance in levels between two hierarchy points.
    pub fn distance(self, other: HierarchyLevel) -> u32 {
        (self.rank()).abs_diff(other.rank())
    }

    fn rank(self) -> u32 {
        match self {
            HierarchyLevel::L1 => 0,
            HierarchyLevel::L2 => 1,
            HierarchyLevel::Dram => 2,
        }
    }
}

/// The cache-synchronization penalty from the §4.3 selection objective:
/// `⌈m / C_k⌉ · C_k / W_k` beats for an `m`-byte operation on an interface
/// with line `C_k` and width `W_k`, scaled by the hint/level mismatch.
///
/// A `Warm` buffer accessed through a low-level (far) interface must pull
/// its lines down; a `Cold` buffer accessed through the L1 port drags DRAM
/// data through the cache (thrashing). Matching hint and level costs the
/// base term only when the interface is not cache-coherent-free; the paper
/// folds this into a single approximation, which we reproduce with a
/// mismatch multiplier.
pub fn cache_penalty(
    m_bytes: usize,
    line: usize,
    width: usize,
    hint: CacheHint,
    level: HierarchyLevel,
) -> f64 {
    if m_bytes == 0 {
        return 0.0;
    }
    let lines = m_bytes.div_ceil(line.max(1)) as f64;
    let base = lines * line as f64 / width.max(1) as f64;
    base * mismatch_factor(hint, level)
}

/// Multiplier encoding hint/level agreement. 0 = free (data already at the
/// right level), 1 = the paper's base synchronization term, >1 = mismatch.
pub fn mismatch_factor(hint: CacheHint, level: HierarchyLevel) -> f64 {
    match (hint, level) {
        // Warm data is already in the upper cache: the L1 port reads it
        // without extra synchronization.
        (CacheHint::Warm, HierarchyLevel::L1) => 0.0,
        // Warm data over the bus bypasses the L1 — the lines it owns must
        // be synchronized down.
        (CacheHint::Warm, HierarchyLevel::L2) => 1.0,
        (CacheHint::Warm, HierarchyLevel::Dram) => 2.0,
        // Cold (DRAM-resident) data through the L1 port thrashes the cache:
        // every line is a miss + refill + likely eviction of hot data.
        (CacheHint::Cold, HierarchyLevel::L1) => 2.0,
        (CacheHint::Cold, HierarchyLevel::L2) => 1.0,
        (CacheHint::Cold, HierarchyLevel::Dram) => 0.0,
        // Unknown: base term everywhere (the paper's default objective).
        (CacheHint::Unknown, _) => 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(HierarchyLevel::L1 < HierarchyLevel::L2);
        assert!(HierarchyLevel::L2 < HierarchyLevel::Dram);
        assert_eq!(HierarchyLevel::L1.distance(HierarchyLevel::Dram), 2);
    }

    #[test]
    fn warm_on_l1_is_free() {
        assert_eq!(cache_penalty(64, 64, 4, CacheHint::Warm, HierarchyLevel::L1), 0.0);
    }

    #[test]
    fn cold_on_l1_thrashes() {
        let cold_l1 = cache_penalty(128, 64, 4, CacheHint::Cold, HierarchyLevel::L1);
        let cold_l2 = cache_penalty(128, 64, 8, CacheHint::Cold, HierarchyLevel::L2);
        assert!(cold_l1 > cold_l2);
    }

    #[test]
    fn penalty_scales_with_lines_touched() {
        let one = cache_penalty(64, 64, 8, CacheHint::Unknown, HierarchyLevel::L2);
        let two = cache_penalty(65, 64, 8, CacheHint::Unknown, HierarchyLevel::L2);
        assert!(two > one, "65 bytes touches two lines");
        assert_eq!(one, 8.0); // 1 line * 64/8
        assert_eq!(two, 16.0);
    }

    #[test]
    fn zero_bytes_zero_penalty() {
        assert_eq!(cache_penalty(0, 64, 4, CacheHint::Cold, HierarchyLevel::L1), 0.0);
    }

    #[test]
    fn degenerate_geometry_is_guarded() {
        // Zero-capacity / zero-width interfaces (no cache line, no beat
        // width) must clamp instead of dividing by zero: the penalty
        // stays finite and non-negative for every hint/level pair.
        for hint in [CacheHint::Warm, CacheHint::Cold, CacheHint::Unknown] {
            for level in [HierarchyLevel::L1, HierarchyLevel::L2, HierarchyLevel::Dram] {
                let no_line = cache_penalty(128, 0, 4, hint, level);
                let no_width = cache_penalty(128, 64, 0, hint, level);
                assert!(no_line.is_finite() && no_line >= 0.0, "{hint:?}/{level:?}: {no_line}");
                assert!(
                    no_width.is_finite() && no_width >= 0.0,
                    "{hint:?}/{level:?}: {no_width}"
                );
            }
        }
        // A zero-byte line refills no bytes: the penalty term vanishes
        // instead of exploding.
        assert_eq!(cache_penalty(128, 0, 4, CacheHint::Unknown, HierarchyLevel::L2), 0.0);
        // Zero width clamps to one byte per beat: the full line traffic.
        assert_eq!(cache_penalty(128, 64, 0, CacheHint::Unknown, HierarchyLevel::L2), 128.0);
    }
}
