//! §4.1 — the core-ISAX memory-interface model.
//!
//! Each memory interface `k` visible to an ISAX is a 6-tuple
//! `(W_k, M_k, I_k, L_k, E_k, C_k)`: width in bytes, max beats per
//! transaction, max in-flight transactions, read lead-off latency, write
//! completion cost, and the cache-line size visible to that interface.
//!
//! [`model`] defines the tuple plus the *microarchitectural constraints*
//! (legal transaction sizes are `m = W·2^t ≤ W·M`, aligned to `m`);
//! [`latency`] implements the paper's issue/completion recurrences and the
//! closed-form `T_k` approximation used by interface selection;
//! [`dmasim`] executes the same transactions through an event-driven
//! burst-DMA engine (queueing, in-flight limits, bank conflicts) that the
//! closed form can only approximate;
//! [`cache`] models hierarchy levels, `cache_hint` labels and the
//! line-synchronization penalty term.

#![warn(missing_docs)]

pub mod cache;
pub mod dmasim;
pub mod latency;
pub mod model;

pub use cache::{CacheHint, HierarchyLevel};
pub use latency::{sequence_latency, tk_estimate, TransactionKind};
pub use model::{InterfaceId, InterfaceSet, MemInterface};
