//! Deterministic PRNG (splitmix64 + xoshiro256**) used by workload
//! generators, the serving-trace generator, and the in-crate property
//! tests. Replaces the unavailable `rand` crate; seeded everywhere so all
//! experiments are reproducible.

/// xoshiro256** with splitmix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Panics if `n == 0`.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // Rejection sampling: accept only below the largest multiple of n.
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let x = self.next_u64();
            if x < zone {
                return x % n;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli(p).
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Pick one item.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.range(0, items.len())]
    }

    /// Exponential with rate `lambda` (inter-arrival times for serving traces).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(2);
        let mean = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
