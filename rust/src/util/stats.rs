//! Tiny statistics used by the bench harness (criterion replacement).

/// Summary of a sample of measurements.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

/// Compute a [`Summary`]; `samples` is consumed (sorted in place).
pub fn summarize(mut samples: Vec<f64>) -> Summary {
    assert!(!samples.is_empty(), "summarize of empty sample set");
    samples.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    Summary {
        n,
        mean,
        stddev: var.sqrt(),
        min: samples[0],
        max: samples[n - 1],
        p50: percentile(&samples, 0.50),
        p95: percentile(&samples, 0.95),
        p99: percentile(&samples, 0.99),
    }
}

/// Nearest-rank percentile of a pre-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Geometric mean of positive values.
pub fn geomean(values: &[f64]) -> f64 {
    assert!(!values.is_empty());
    let log_sum: f64 = values.iter().map(|v| v.max(1e-300).ln()).sum();
    (log_sum / values.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = summarize(vec![2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.p99, 2.0);
    }

    #[test]
    fn percentiles_ordered() {
        let s = summarize((1..=100).map(|i| i as f64).collect());
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
    }

    #[test]
    fn geomean_of_powers() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
    }
}
