//! Minimal JSON: enough to read `artifacts/manifest.json` and to emit
//! benchmark/metrics reports. Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP (not needed for our data).
//!
//! Robustness contract: `Json::parse` never panics on any input — every
//! malformed document yields an `Error::Manifest` with the byte offset
//! where parsing stopped, and nesting is capped (the recursive-descent
//! parser must not let `[[[[…` overflow the stack, which would abort the
//! process rather than unwind).

// Panic-free audit (robustness): manifests and specs come from disk and
// the CLI; a corrupt file must become a diagnostic, never an abort.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => Err(Error::Manifest(format!("expected object, got {other:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => Err(Error::Manifest(format!("expected array, got {other:?}"))),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(Error::Manifest(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(Error::Manifest(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(Error::Manifest(format!("expected non-negative integer, got {n}")));
        }
        Ok(n as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(Error::Manifest(format!("expected non-negative integer, got {n}")));
        }
        Ok(n as u64)
    }

    /// Fetch a required object field.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| Error::Manifest(format!("missing field `{key}`")))
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialization (`to_string()` comes via the `ToString`
/// blanket impl).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        f.write_str(&out)
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Deepest container nesting accepted. Stack overflow aborts the process
/// (it cannot be caught by `catch_unwind`), so hostile `[[[[…` input must
/// be rejected with an error well before the recursion gets dangerous.
const MAX_JSON_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    /// Current container nesting, checked against [`MAX_JSON_DEPTH`].
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Manifest(format!("json parse error at byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn literal(&mut self, text: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{text}`")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8 starting at pos-1.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn enter(&mut self) -> Result<()> {
        self.depth += 1;
        if self.depth > MAX_JSON_DEPTH {
            return Err(self.err(&format!("nesting deeper than {MAX_JSON_DEPTH}")));
        }
        Ok(())
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        self.enter()?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => {
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        self.enter()?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            map.insert(key, value);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => {
                    self.depth -= 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    /// Malformed-input table (mirrors `FaultPlan::parse`'s): every row
    /// must produce a diagnostic `Error` — with a byte offset — never a
    /// panic.
    #[test]
    fn malformed_documents_error_with_byte_offsets() {
        let cases: &[(&str, &str)] = &[
            ("", "unexpected character"),
            ("   ", "unexpected character"),
            ("{", "expected `\"`"),
            ("[1, 2", "expected `,` or `]`"),
            ("{\"a\" 1}", "expected `:`"),
            ("{\"a\": 1,}", "expected `\"`"),
            ("\"unterminated", "unterminated string"),
            ("\"bad \\x escape\"", "bad escape"),
            ("\"bad \\u12", "bad \\u"),
            ("nul", "expected `null`"),
            ("tru3", "expected `true`"),
            ("1.2.3", "invalid number"),
            ("-", "invalid number"),
            ("{} extra", "trailing characters"),
            ("[1] [2]", "trailing characters"),
        ];
        for (input, want) in cases {
            let err = Json::parse(input).unwrap_err().to_string();
            assert!(
                err.contains(want),
                "input {input:?}: error {err:?} missing {want:?}"
            );
            assert!(
                err.contains("at byte"),
                "input {input:?}: error {err:?} lacks a byte offset"
            );
        }
    }

    #[test]
    fn hostile_nesting_errors_instead_of_overflowing() {
        // Way past MAX_JSON_DEPTH: must error, not blow the stack (a
        // stack overflow aborts and would escape catch_unwind).
        let deep_arr = "[".repeat(100_000);
        let err = Json::parse(&deep_arr).unwrap_err().to_string();
        assert!(err.contains("nesting deeper than"), "got: {err}");

        let deep_obj = "{\"k\":".repeat(100_000);
        let err = Json::parse(&deep_obj).unwrap_err().to_string();
        assert!(err.contains("nesting deeper than"), "got: {err}");

        // At or below the cap still parses.
        let ok = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn roundtrip_object() {
        let text = r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny"}, "d": null, "e": true}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn parses_nested_manifest_shape() {
        let text = r#"{"entries": {"x": {"file": "x.hlo.txt", "args": [{"shape": [2, 3], "dtype": "float32"}]}}}"#;
        let v = Json::parse(text).unwrap();
        let args = v.get("entries").unwrap().get("x").unwrap().get("args").unwrap();
        let shape = args.as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|j| j.as_usize().unwrap())
            .collect::<Vec<_>>();
        assert_eq!(shape, vec![2, 3]);
    }

    #[test]
    fn unicode_string() {
        let v = Json::parse(r#""héllo é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo é");
    }
}
