//! Small in-crate utilities that replace unavailable external crates on
//! this offline image: a JSON parser/serializer (instead of serde_json), a
//! deterministic PRNG (instead of rand), and a tiny statistics helper used
//! by the bench harness (instead of criterion).

pub mod json;
pub mod rng;
pub mod stats;
