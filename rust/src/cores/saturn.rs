//! Saturn-like RISC-V vector unit model (Figure 7's comparison point).
//!
//! Saturn is a VLEN-configurable in-order vector unit attached to Rocket.
//! The model executes a *vector profile* of each kernel: element-wise ops
//! stream through the lanes at `elements / lanes` cycles per op, while
//! reductions pay a log-tree + pipeline-drain penalty per occurrence —
//! exactly the effect the paper blames for Saturn's poor `vmvar` showing
//! ("reduction operations … are inefficient for such instruction sets").
//!
//! Per §6.4, Saturn's integration costs a 35% frequency drop and +75%
//! RocketTile area (−26% if the FP half is stripped); those factors live
//! in [`crate::area`].

use crate::cores::CycleReport;

/// How a kernel maps onto vector hardware.
#[derive(Debug, Clone, Copy, Default)]
pub struct VectorProfile {
    /// Total elements processed.
    pub elements: u64,
    /// Element-wise vector ops per element (map-type work).
    pub vector_ops_per_element: u64,
    /// Reduction operations over the whole stream (sum/max trees).
    pub reductions: u64,
    /// Scalar (non-vectorizable) ops, run on the host core.
    pub scalar_ops: u64,
    /// Vector loads/stores per element.
    pub mem_ops_per_element: u64,
}

/// Saturn model parameters.
#[derive(Debug, Clone, Copy)]
pub struct SaturnConfig {
    /// VLEN in bits (paper configuration: 128).
    pub vlen: u64,
    /// Element width in bits (f32/i32 workloads).
    pub sew: u64,
    /// Pipeline drain + tree latency per reduction.
    pub reduction_cost: u64,
    /// Cycles per vector memory op per occupied lane-group.
    pub mem_throughput: u64,
    /// Vector instruction issue overhead (vsetvl + dispatch).
    pub issue_overhead: u64,
}

impl Default for SaturnConfig {
    fn default() -> Self {
        Self { vlen: 128, sew: 32, reduction_cost: 24, mem_throughput: 1, issue_overhead: 2 }
    }
}

/// The vector-unit model.
pub struct SaturnModel {
    pub cfg: SaturnConfig,
}

impl SaturnModel {
    pub fn new(cfg: SaturnConfig) -> Self {
        Self { cfg }
    }

    /// Lanes available for the element width.
    pub fn lanes(&self) -> u64 {
        (self.cfg.vlen / self.cfg.sew).max(1)
    }

    /// Cycles for a kernel described by `profile`.
    pub fn simulate(&self, profile: &VectorProfile) -> CycleReport {
        let lanes = self.lanes();
        let groups = profile.elements.div_ceil(lanes).max(1);
        let compute = groups
            * profile.vector_ops_per_element
            * 1
            + groups * profile.mem_ops_per_element * self.cfg.mem_throughput;
        let issue = (profile.vector_ops_per_element + profile.mem_ops_per_element)
            * self.cfg.issue_overhead;
        let reductions = profile.reductions * self.cfg.reduction_cost;
        let scalar = profile.scalar_ops;
        let cycles = compute + issue + reductions + scalar;
        CycleReport {
            cycles,
            instructions: profile.vector_ops_per_element * groups
                + profile.scalar_ops
                + profile.reductions,
            cache_misses: 0,
            isax_invocations: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_lanes_at_vlen128_sew32() {
        assert_eq!(SaturnModel::new(SaturnConfig::default()).lanes(), 4);
    }

    #[test]
    fn elementwise_work_scales_down_by_lanes() {
        let m = SaturnModel::new(SaturnConfig::default());
        let small = m.simulate(&VectorProfile {
            elements: 64,
            vector_ops_per_element: 4,
            mem_ops_per_element: 2,
            ..Default::default()
        });
        let large = m.simulate(&VectorProfile {
            elements: 256,
            vector_ops_per_element: 4,
            mem_ops_per_element: 2,
            ..Default::default()
        });
        assert!(large.cycles >= 3 * small.cycles);
    }

    #[test]
    fn reductions_dominate_small_kernels() {
        // The vmvar effect: heavy reduction content erases the lane win.
        let m = SaturnModel::new(SaturnConfig::default());
        let maponly = m.simulate(&VectorProfile {
            elements: 64,
            vector_ops_per_element: 2,
            mem_ops_per_element: 1,
            ..Default::default()
        });
        let reduction_heavy = m.simulate(&VectorProfile {
            elements: 64,
            vector_ops_per_element: 2,
            mem_ops_per_element: 1,
            reductions: 8,
            ..Default::default()
        });
        assert!(reduction_heavy.cycles > 2 * maponly.cycles);
    }
}
