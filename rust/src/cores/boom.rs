//! BOOMv3-like out-of-order core model (Figure 6's comparison point).
//!
//! A 4-wide OoO machine hides latency but is still bound by (a) issue
//! bandwidth, (b) the fixed load-store unit (the paper: "memory traffic is
//! bottlenecked by fixed LSUs"), and (c) the dependence critical path
//! through reductions. We take the max of those three lower bounds — the
//! classic analytical OoO model — over the interpreter's dynamic counts.
//!
//! Per the paper (§6.3): BOOMv3 costs 4.24× the area of Rocket and drops
//! frequency by 7.3%; those factors live in [`crate::area`].

use crate::cores::CycleReport;
use crate::error::Result;
use crate::ir::func::Func;
use crate::ir::interp::{ExecStats, Memory, Val};

/// BOOM model parameters.
#[derive(Debug, Clone, Copy)]
pub struct BoomConfig {
    /// Sustained issue width (effective, after fetch/rename losses).
    pub issue_width: f64,
    /// Loads the LSU can start per cycle.
    pub loads_per_cycle: f64,
    /// Stores per cycle.
    pub stores_per_cycle: f64,
    /// L1 miss penalty (shared with the scalar model's cache).
    pub miss_penalty: u64,
    /// Fraction of loop iterations serialized by loop-carried deps (the
    /// OoO window cannot break true dependences, e.g. reductions).
    pub serial_fraction: f64,
}

impl Default for BoomConfig {
    fn default() -> Self {
        Self {
            issue_width: 2.4, // effective IPC of BOOMv3 on kernel code
            loads_per_cycle: 2.0,
            stores_per_cycle: 1.0,
            miss_penalty: 20,
            serial_fraction: 0.35,
        }
    }
}

/// The OoO core model.
pub struct BoomModel {
    pub cfg: BoomConfig,
}

impl BoomModel {
    pub fn new(cfg: BoomConfig) -> Self {
        Self { cfg }
    }

    /// Simulate a software function (no ISAXs — BOOM runs plain RV64).
    pub fn simulate(&self, func: &Func, args: &[Val], mem: &mut Memory) -> Result<CycleReport> {
        let mut stats = ExecStats::default();
        let mut trace = Some(Vec::new());
        crate::ir::interp::run_traced(func, args, mem, &mut stats, &mut trace)?;
        let trace = trace.unwrap();
        let mut cache =
            crate::cores::memsys::Cache::new(crate::cores::memsys::CacheConfig::default());
        let miss_extra = cache.run_trace(func, &trace) as f64
            * (self.cfg.miss_penalty as f64 / 20.0)
            * 0.5; // OoO hides ~half the miss latency

        let total_ops =
            (stats.arith_ops + stats.loads + stats.stores + stats.branches) as f64;
        let issue_bound = total_ops / self.cfg.issue_width;
        let load_bound = stats.loads as f64 / self.cfg.loads_per_cycle;
        let store_bound = stats.stores as f64 / self.cfg.stores_per_cycle;
        let serial_bound = stats.loop_iterations as f64 * self.cfg.serial_fraction;

        let cycles =
            issue_bound.max(load_bound).max(store_bound).max(serial_bound) + miss_extra;
        Ok(CycleReport {
            cycles: cycles.ceil() as u64,
            instructions: total_ops as u64,
            cache_misses: cache.misses,
            isax_invocations: 0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cores::rocket::{CoreConfig, RocketModel};
    use crate::interface::cache::CacheHint;
    use crate::ir::builder::FuncBuilder;
    use crate::runtime::DType;

    fn kernel(n: i64) -> Func {
        let mut b = FuncBuilder::new("k");
        let x = b.global("x", DType::F32, n as usize, CacheHint::Unknown);
        let y = b.global("y", DType::F32, n as usize, CacheHint::Unknown);
        b.for_range(0, n, 1, |b, iv| {
            let v = b.load(x, iv);
            let w = b.load(y, iv);
            let s = b.mul(v, w);
            let t = b.add(s, v);
            b.store(y, iv, t);
        });
        b.finish(&[])
    }

    #[test]
    fn boom_faster_than_rocket() {
        let f = kernel(128);
        let rocket = RocketModel::new(CoreConfig::default());
        let boom = BoomModel::new(BoomConfig::default());
        let mut m1 = Memory::for_func(&f);
        let mut m2 = Memory::for_func(&f);
        let rr = rocket.simulate(&f, &[], &mut m1).unwrap();
        let rb = boom.simulate(&f, &[], &mut m2).unwrap();
        assert!(
            (rb.cycles as f64) < 0.7 * rr.cycles as f64,
            "boom {} vs rocket {}",
            rb.cycles,
            rr.cycles
        );
    }

    #[test]
    fn lsu_bound_kicks_in_for_memory_heavy_code() {
        // Pure copy loop: 1 load + 1 store per element, almost no arith.
        let mut b = FuncBuilder::new("copy");
        let x = b.global("x", DType::F32, 256, CacheHint::Unknown);
        let y = b.global("y", DType::F32, 256, CacheHint::Unknown);
        b.for_range(0, 256, 1, |b, iv| {
            let v = b.load(x, iv);
            b.store(y, iv, v);
        });
        let f = b.finish(&[]);
        let boom = BoomModel::new(BoomConfig::default());
        let mut mem = Memory::for_func(&f);
        let r = boom.simulate(&f, &[], &mut mem).unwrap();
        // ≥ stores / stores_per_cycle = 256 cycles.
        assert!(r.cycles >= 256, "cycles {}", r.cycles);
    }
}
