//! The in-order scalar base-core model (Rocket-like, §6.1).
//!
//! Cycles come from interpreting the software IR with per-op costs plus a
//! real cache model over the actual memory trace:
//!
//! - single-issue, in-order: every retired op costs its latency;
//! - loads: 1 cycle + miss penalty from [`crate::cores::memsys::Cache`];
//! - taken branches (loop back-edges) pay a small pipeline bubble;
//! - `isax.<name>` intrinsics dispatch to an [`crate::cores::IsaxEngine`]
//!   whose per-invocation cycles were computed by the synthesis flow.

use std::collections::HashMap;

use crate::cores::memsys::{Cache, CacheConfig};
use crate::cores::CycleReport;
use crate::error::Result;
use crate::ir::func::Func;
use crate::ir::interp::{run_traced, ExecStats, Memory, Val};
use crate::ir::ops::OpKind;

/// Scalar-core cost parameters.
#[derive(Debug, Clone, Copy)]
pub struct CoreConfig {
    pub int_op: u64,
    pub mul: u64,
    pub div: u64,
    pub fp_op: u64,
    pub load_hit: u64,
    pub store: u64,
    /// Back-edge / taken-branch bubble.
    pub branch: u64,
    /// RoCC-style ISAX dispatch overhead per invocation.
    pub isax_dispatch: u64,
    pub cache: CacheConfig,
}

impl Default for CoreConfig {
    fn default() -> Self {
        Self {
            int_op: 1,
            mul: 3,
            div: 20,
            fp_op: 4,
            load_hit: 1,
            store: 1,
            branch: 2,
            isax_dispatch: 4,
            cache: CacheConfig::default(),
        }
    }
}

/// The base-core model. `isax_cycles` maps intrinsic names to their
/// per-invocation cycle cost (empty for the pure-software baseline).
pub struct RocketModel {
    pub cfg: CoreConfig,
    pub isax_cycles: HashMap<String, u64>,
}

impl RocketModel {
    pub fn new(cfg: CoreConfig) -> Self {
        Self { cfg, isax_cycles: HashMap::new() }
    }

    /// Register an ISAX engine cost (from synthesis + the ISAX engine).
    pub fn with_isax(mut self, name: &str, cycles_per_invocation: u64) -> Self {
        self.isax_cycles.insert(name.to_string(), cycles_per_invocation);
        self
    }

    /// Execute + time a software function. `mem` carries the workload
    /// data; the function's intrinsics must all be registered.
    pub fn simulate(&self, func: &Func, args: &[Val], mem: &mut Memory) -> Result<CycleReport> {
        // Split per-op-kind costs: re-walk the IR counting op kinds at
        // execution frequency. The interpreter gives aggregate stats; we
        // weight them via a static census scaled by loop trip counts —
        // instead, simpler and exact: run with a trace and count costs by
        // replaying per-op stats.
        let mut stats = ExecStats::default();
        let mut trace = Some(Vec::new());
        let func_no_intrinsics = strip_intrinsics(func);
        run_traced(&func_no_intrinsics, args, mem, &mut stats, &mut trace)?;
        let trace = trace.unwrap();

        // Weighted arithmetic cost: approximate the mix by a static census
        // of the loop bodies (mul/div are rare enough that the mix is
        // stable across iterations).
        let (w_int, w_mul, w_div, w_fp) = arith_mix(func);
        let mix_cost = |n: u64| -> u64 {
            let total_w = (w_int + w_mul + w_div + w_fp).max(1);
            let avg = (w_int * self.cfg.int_op
                + w_mul * self.cfg.mul
                + w_div * self.cfg.div
                + w_fp * self.cfg.fp_op) as f64
                / total_w as f64;
            (n as f64 * avg).round() as u64
        };

        let mut cache = Cache::new(self.cfg.cache);
        let miss_cycles = cache.run_trace(&func_no_intrinsics, &trace);

        let mut cycles = 0u64;
        cycles += mix_cost(stats.arith_ops);
        cycles += stats.loads * self.cfg.load_hit;
        cycles += stats.stores * self.cfg.store;
        cycles += miss_cycles;
        cycles += stats.branches * self.cfg.branch;

        // ISAX invocations: count them in the *original* function (the
        // stripped copy replaced them with nothing).
        let mut isax_cycles = 0u64;
        let mut invocations = 0u64;
        func.walk(|_, op| {
            if let OpKind::Intrinsic(name) = &op.kind {
                let per = self.isax_cycles.get(name).copied().unwrap_or(0);
                isax_cycles += per + self.cfg.isax_dispatch;
                invocations += 1;
            }
        });
        cycles += isax_cycles;

        Ok(CycleReport {
            cycles,
            instructions: stats.arith_ops + stats.loads + stats.stores + stats.branches,
            cache_misses: cache.misses,
            isax_invocations: invocations,
        })
    }
}

/// Remove intrinsic ops so the interpreter can run the scalar remainder.
/// (The ISAX's semantic effect on memory is not needed for *timing* the
/// surrounding code; numeric validation runs the un-lowered function.)
fn strip_intrinsics(func: &Func) -> Func {
    let mut out = func.clone();
    let kill: Vec<_> = (0..out.num_ops())
        .map(|i| crate::ir::func::OpRef(i as u32))
        .filter(|&r| matches!(out.op(r).kind, OpKind::Intrinsic(_)))
        .collect();
    out.entry.ops.retain(|o| !kill.contains(o));
    for i in 0..out.num_ops() {
        let r = crate::ir::func::OpRef(i as u32);
        let op = out.op_mut(r);
        for region in op.regions.iter_mut() {
            region.ops.retain(|o| !kill.contains(o));
        }
    }
    out
}

/// Static census of arithmetic op kinds (used to weight the dynamic count).
fn arith_mix(func: &Func) -> (u64, u64, u64, u64) {
    let (mut i, mut m, mut d, mut f) = (0u64, 0u64, 0u64, 0u64);
    func.walk(|_, op| match op.kind {
        OpKind::Mul => m += 1,
        OpKind::Div | OpKind::Rem => d += 1,
        OpKind::Sqrt | OpKind::Exp | OpKind::Powi(_) => f += 1,
        OpKind::Add
        | OpKind::Sub
        | OpKind::Shl
        | OpKind::Shr
        | OpKind::And
        | OpKind::Or
        | OpKind::Xor
        | OpKind::Min
        | OpKind::Max
        | OpKind::Neg
        | OpKind::Cmp(_)
        | OpKind::Select
        | OpKind::ToFloat
        | OpKind::ToInt => i += 1,
        _ => {}
    });
    (i, m, d, f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interface::cache::CacheHint;
    use crate::ir::builder::FuncBuilder;
    use crate::runtime::DType;

    fn vec_scale(n: i64) -> Func {
        let mut b = FuncBuilder::new("scale");
        let x = b.global("x", DType::I32, n as usize, CacheHint::Unknown);
        b.for_range(0, n, 1, |b, iv| {
            let v = b.load(x, iv);
            let two = b.const_i(2);
            let w = b.mul(v, two);
            b.store(x, iv, w);
        });
        b.finish(&[])
    }

    #[test]
    fn cycles_scale_with_work() {
        let model = RocketModel::new(CoreConfig::default());
        let f16 = vec_scale(16);
        let f64_ = vec_scale(64);
        let mut m1 = Memory::for_func(&f16);
        let mut m2 = Memory::for_func(&f64_);
        let r1 = model.simulate(&f16, &[], &mut m1).unwrap();
        let r2 = model.simulate(&f64_, &[], &mut m2).unwrap();
        assert!(r2.cycles > 3 * r1.cycles, "{} vs {}", r2.cycles, r1.cycles);
    }

    #[test]
    fn cache_misses_counted() {
        let model = RocketModel::new(CoreConfig::default());
        let f = vec_scale(256);
        let mut mem = Memory::for_func(&f);
        let r = model.simulate(&f, &[], &mut mem).unwrap();
        // 256 words = 16 lines -> 16 cold misses (loads; stores hit after).
        assert_eq!(r.cache_misses, 16);
    }

    #[test]
    fn isax_invocation_replaces_loop_cost() {
        let f = vec_scale(64);
        let lowered = crate::compiler::lower::replace_loop_with_intrinsic(
            &f,
            crate::compiler::matcher::top_loops(&f)[0],
            "vscale",
        )
        .unwrap();
        let base = RocketModel::new(CoreConfig::default());
        let acc = RocketModel::new(CoreConfig::default()).with_isax("vscale", 40);
        let mut m1 = Memory::for_func(&f);
        let mut m2 = Memory::for_func(&lowered);
        let rb = base.simulate(&f, &[], &mut m1).unwrap();
        let ra = acc.simulate(&lowered, &[], &mut m2).unwrap();
        assert_eq!(ra.isax_invocations, 1);
        assert!(ra.cycles < rb.cycles, "isax {} !< base {}", ra.cycles, rb.cycles);
    }
}
