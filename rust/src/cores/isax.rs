//! The ISAX execution engine: turns a synthesis result into cycles per
//! invocation.
//!
//! The generated unit is a dynamic pipeline (§4.3 "Hardware Generation"):
//!
//! ```text
//! dispatch | stage-in (schedule) | compute loop | stage-out | writeback
//! ```
//!
//! - stage-in/out latency comes straight from the transaction
//!   [`crate::synthesis::Schedule`] (the §4.1 recurrences applied to the
//!   chosen interfaces/order — this is where Aquas vs naive differ);
//! - the compute loop is modelled as a pipelined datapath with initiation
//!   interval `II` and depth from hwgen; per-element *streaming* memory
//!   ops (post-elision `fetch`/`load_itfc` inside the loop) bound the
//!   steady-state II through their interface's sustainable rate;
//! - scratchpad bank conflicts add stalls when a loop body reads one
//!   scratchpad more times per iteration than it has banks.

use crate::interface::model::InterfaceSet;
use crate::ir::func::{BufferKind, Func};
use crate::ir::ops::OpKind;
use crate::synthesis::hwgen::PipelineDesc;
use crate::synthesis::SynthResult;

/// Per-invocation cycle model for one synthesized ISAX.
#[derive(Debug, Clone)]
pub struct IsaxEngine {
    pub name: String,
    /// Stage-in + stage-out cycles (the bulk-transfer schedule).
    pub mem_cycles: u64,
    /// Compute-loop cycles.
    pub compute_cycles: u64,
    /// Fixed pipeline overhead (dispatch + writeback + stage gaps).
    pub overhead: u64,
}

impl IsaxEngine {
    /// Build the engine model from synthesis output (Aquas flow: the
    /// generated dataflow register-promotes loop-invariant accesses).
    pub fn from_synthesis(synth: &SynthResult, desc: &PipelineDesc, itfcs: &InterfaceSet) -> Self {
        Self::from_synthesis_with(synth, desc, itfcs, true)
    }

    /// Naive/APS-like flow: hand-written datapaths without the dataflow
    /// analysis needed for register promotion — every per-element access
    /// really hits the interface (the paper's "suboptimal memory
    /// optimization decisions").
    pub fn from_synthesis_naive(
        synth: &SynthResult,
        desc: &PipelineDesc,
        itfcs: &InterfaceSet,
    ) -> Self {
        Self::from_synthesis_with(synth, desc, itfcs, false)
    }

    fn from_synthesis_with(
        synth: &SynthResult,
        desc: &PipelineDesc,
        itfcs: &InterfaceSet,
        promote_invariant: bool,
    ) -> Self {
        let func = &synth.temporal;
        let mem_cycles = synth.schedule.mem_latency();

        // Loop structure: total iterations and per-iteration streaming ops.
        let iters = total_iterations(func);
        let streaming = streaming_rate(func, itfcs, promote_invariant);
        let bank_stalls = bank_conflict_stalls(func);
        let ii = desc.initiation_interval.max(streaming).max(1 + bank_stalls);
        let compute_cycles = if iters > 0 {
            iters.saturating_sub(1) * ii + desc.datapath_depth.max(1)
        } else {
            desc.datapath_depth
        };

        Self {
            name: func.name.clone(),
            mem_cycles,
            compute_cycles,
            overhead: 2 + desc.stages.len() as u64 / 2,
        }
    }

    /// Cycles for one invocation.
    pub fn cycles_per_invocation(&self) -> u64 {
        // Stage-in overlaps the first compute iterations only partially in
        // the generated pipeline; we model sequential phases (conservative
        // for Aquas, identical for the naive flow — both flows share this).
        self.mem_cycles + self.compute_cycles + self.overhead
    }
}

/// Product-sum of static loop trip counts (total body executions of the
/// innermost bodies; nested loops multiply).
fn total_iterations(func: &Func) -> u64 {
    fn walk(func: &Func, region: &crate::ir::func::Region, mult: u64, acc: &mut u64) {
        for &opref in &region.ops {
            let op = func.op(opref);
            if matches!(op.kind, OpKind::For) {
                let trips =
                    crate::synthesis::memprobe::static_trips(func, opref).unwrap_or(1).max(1);
                // Count this loop's iterations at its own level…
                *acc += mult * trips;
                // …then descend: inner loops multiply.
                walk(func, &op.regions[0], mult * trips, acc);
            } else {
                for r in &op.regions {
                    walk(func, r, mult, acc);
                }
            }
        }
    }
    // The engine pipelines the *innermost* dimension; the paper's designs
    // flatten nests into one pipelined stream, so total iterations =
    // product over the deepest spine. We approximate with the max over
    // paths (sum per level is too pessimistic for pipelined nests).
    fn deepest(func: &Func, region: &crate::ir::func::Region) -> u64 {
        let mut best = 1;
        for &opref in &region.ops {
            let op = func.op(opref);
            if matches!(op.kind, OpKind::For) {
                let trips =
                    crate::synthesis::memprobe::static_trips(func, opref).unwrap_or(1).max(1);
                best = best.max(trips * deepest(func, &op.regions[0]));
            } else {
                for r in &op.regions {
                    best = best.max(deepest(func, r));
                }
            }
        }
        best
    }
    let mut _acc = 0u64;
    walk(func, &func.entry, 1, &mut _acc);
    deepest(func, &func.entry)
}

/// Per-request protocol overhead of a scalar interface access (request
/// handshake + response capture on the extension interface).
const SCALAR_PROTOCOL_CYCLES: u64 = 2;
/// L1 refill penalty seen by a streaming access that misses.
const STREAM_MISS_PENALTY: f64 = 20.0;

/// Sustainable per-iteration cycles imposed by streaming (in-loop)
/// interface accesses: Σ per-interface (accesses/iter × cycles/access).
///
/// With `promote_invariant`, scalar accesses whose index is loop-invariant
/// (e.g. a running maximum kept at `out[0]`) are register-promoted by the
/// generated dataflow — kept in a register with one writeback — so they
/// don't stream. The naive/APS flow lacks that analysis (§6.2/§6.3).
///
/// Every streamed access also pays a stride-dependent expected cache-miss
/// cost: unit strides reuse the 64-byte line, large strides touch a new
/// line each access (the mechanism behind §6.2's "severe degradation"
/// after blind elision).
fn streaming_rate(func: &Func, itfcs: &InterfaceSet, promote_invariant: bool) -> u64 {
    let mut per_itfc = vec![0f64; itfcs.len()];
    let analysis = crate::ir::affine::AffineAnalysis::run(func);
    // (invariant w.r.t. the innermost enclosing loop?, miss rate).
    // An access whose index doesn't move with the *innermost* iv (e.g. a
    // running accumulator `acc[r]` inside the k-loop) lives in a register
    // across those iterations; its amortized per-iteration cost is ~0.
    let classify = |v: crate::ir::func::Value,
                    inner_iv: Option<crate::ir::func::Value>|
     -> (bool, f64) {
        match analysis.expr(v) {
            Some(e) => {
                let inner_stride = inner_iv
                    .and_then(|iv| e.coeffs.get(&iv))
                    .map(|c| c.unsigned_abs())
                    .unwrap_or(0);
                if inner_stride == 0 {
                    (true, 0.0)
                } else {
                    (false, ((inner_stride * 4) as f64 / 64.0).min(1.0))
                }
            }
            // Non-affine (e.g. `i / 32`): slowly-varying word walks are
            // line-friendly in practice.
            None => (false, 1.0 / 16.0),
        }
    };
    // Count per-element interface ops inside loops (trips = 1 weight: the
    // rate is per innermost iteration). Track the enclosing loop's iv.
    fn in_loops(
        func: &Func,
        region: &crate::ir::func::Region,
        iv: Option<crate::ir::func::Value>,
        out: &mut Vec<(usize, bool, crate::ir::func::Value, Option<crate::ir::func::Value>)>,
    ) {
        for &opref in &region.ops {
            let op = func.op(opref);
            match &op.kind {
                OpKind::LoadItfc { itfc, .. } if iv.is_some() => {
                    out.push((itfc.0, false, op.operands[0], iv))
                }
                OpKind::StoreItfc { itfc, .. } if iv.is_some() => {
                    out.push((itfc.0, true, op.operands[0], iv))
                }
                OpKind::For => {
                    let inner_iv = op.regions[0].params.first().copied();
                    in_loops(func, &op.regions[0], inner_iv, out)
                }
                OpKind::If => {
                    in_loops(func, &op.regions[0], iv, out);
                    in_loops(func, &op.regions[1], iv, out);
                }
                _ => {}
            }
        }
    }
    let mut accesses = Vec::new();
    in_loops(func, &func.entry, None, &mut accesses);
    for (k, is_store, idx, inner_iv) in accesses {
        let (invariant, miss_rate) = classify(idx, inner_iv);
        if invariant && promote_invariant {
            continue;
        }
        let itfc = itfcs.get(crate::interface::model::InterfaceId(k));
        let beats = 4u64.div_ceil(itfc.width as u64);
        // Steady-state spacing from the §4.1 recurrences: with I in-flight
        // slots, a new scalar access completes every
        // max(beats, (beats + latency) / I) cycles — plus protocol
        // overhead and the expected refill cost.
        let base = match is_store {
            false => beats.max((beats + itfc.read_lead).div_ceil(itfc.in_flight.max(1) as u64)),
            true => beats.max((beats + itfc.write_cost).div_ceil(itfc.in_flight.max(1) as u64)),
        };
        per_itfc[k] +=
            (base + SCALAR_PROTOCOL_CYCLES) as f64 + miss_rate * STREAM_MISS_PENALTY;
    }
    per_itfc.into_iter().fold(0.0, f64::max).round() as u64
}

/// Stalls per iteration from scratchpad bank conflicts: reads of one
/// scratchpad beyond its bank count serialize.
fn bank_conflict_stalls(func: &Func) -> u64 {
    use std::collections::HashMap;
    let mut reads_per_buf: HashMap<u32, u64> = HashMap::new();
    func.walk(|_, op| {
        if let OpKind::ReadSmem(b) = op.kind {
            *reads_per_buf.entry(b.0).or_insert(0) += 1;
        }
    });
    let mut stalls = 0u64;
    for (buf, reads) in reads_per_buf {
        if let BufferKind::Scratchpad { banks } =
            func.buffer(crate::ir::func::BufferId(buf)).kind
        {
            stalls = stalls.max(reads.saturating_sub(banks as u64));
        }
    }
    stalls
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interface::cache::CacheHint;
    use crate::ir::builder::FuncBuilder;
    use crate::runtime::DType;
    use crate::synthesis::{hwgen, naive, synthesize, SynthOptions};

    fn staged_kernel() -> crate::ir::Func {
        let mut b = FuncBuilder::new("k");
        let src = b.global("src", DType::F32, 64, CacheHint::Cold);
        let out = b.global("out", DType::F32, 64, CacheHint::Warm);
        let s = b.scratchpad("s", DType::F32, 64, 2);
        let zero = b.const_i(0);
        b.transfer(s, zero, src, zero, 256);
        b.for_range(0, 64, 1, |b, iv| {
            let v = b.read_smem(s, iv);
            let w = b.mul(v, v);
            b.store(out, iv, w);
        });
        b.finish(&[])
    }

    #[test]
    fn aquas_engine_beats_naive_engine() {
        let f = staged_kernel();
        let itfcs = InterfaceSet::rocket_default();
        let smart = synthesize(&f, &itfcs, &SynthOptions::default()).unwrap();
        let base = naive::synthesize_naive(&f, &itfcs).unwrap();
        let smart_desc = hwgen::generate(&smart, &itfcs);
        let naive_desc = hwgen::generate(&base, &itfcs);
        let e_smart = IsaxEngine::from_synthesis(&smart, &smart_desc, &itfcs);
        let e_naive = IsaxEngine::from_synthesis_naive(&base, &naive_desc, &itfcs);
        assert!(
            e_smart.cycles_per_invocation() < e_naive.cycles_per_invocation(),
            "aquas {} !< naive {}",
            e_smart.cycles_per_invocation(),
            e_naive.cycles_per_invocation()
        );
    }

    #[test]
    fn iterations_dominate_compute() {
        let f = staged_kernel();
        let itfcs = InterfaceSet::rocket_default();
        let r = synthesize(&f, &itfcs, &SynthOptions::default()).unwrap();
        let desc = hwgen::generate(&r, &itfcs);
        let e = IsaxEngine::from_synthesis(&r, &desc, &itfcs);
        // 64 iterations at II>=1 plus depth.
        assert!(e.compute_cycles >= 64, "compute {}", e.compute_cycles);
    }

    #[test]
    fn streaming_loads_bound_ii() {
        // Post-elision kernel: per-element fetch through the cpu port.
        let mut b = FuncBuilder::new("stream");
        let src = b.global("src", DType::F32, 64, CacheHint::Warm);
        let out = b.global("out", DType::F32, 64, CacheHint::Warm);
        b.for_range(0, 64, 1, |b, iv| {
            let v = b.fetch(src, iv);
            b.store(out, iv, v);
        });
        let f = b.finish(&[]);
        let itfcs = InterfaceSet::rocket_default();
        let r = synthesize(&f, &itfcs, &SynthOptions::default()).unwrap();
        let desc = hwgen::generate(&r, &itfcs);
        let e = IsaxEngine::from_synthesis(&r, &desc, &itfcs);
        // cpu port sustains one 4B load every max(1, L/I)=2 cycles.
        assert!(e.compute_cycles >= 64 * 2, "compute {}", e.compute_cycles);
    }
}
