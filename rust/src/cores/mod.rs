//! Cycle-level core models (the evaluation substrate of §6).
//!
//! The paper measures cycle counts by Verilator RTL simulation of a Rocket
//! SoC; this crate substitutes calibrated analytical/cycle-approximate
//! models (see DESIGN.md's substitution ledger):
//!
//! - [`rocket`] — the in-order scalar base core: interprets software IR
//!   with per-op costs and a real cache model ([`memsys`]);
//! - [`isax`] — the Aquas/naive ISAX execution engine: consumes the
//!   synthesis [`crate::synthesis::Schedule`] + pipeline description, so
//!   interface selection and transaction ordering decisions flow straight
//!   into cycles;
//! - [`boom`] — a BOOMv3-like 4-wide out-of-order model (Figure 6);
//! - [`saturn`] — a Saturn-like VLEN=128 vector unit model (Figure 7).

pub mod boom;
pub mod isax;
pub mod memsys;
pub mod rocket;
pub mod saturn;

pub use isax::IsaxEngine;
pub use memsys::{Cache, CacheConfig};
pub use rocket::{CoreConfig, RocketModel};

/// A cycle-count result for one kernel execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CycleReport {
    pub cycles: u64,
    pub instructions: u64,
    pub cache_misses: u64,
    pub isax_invocations: u64,
}

impl CycleReport {
    /// Cycles-per-instruction (guarded).
    pub fn cpi(&self) -> f64 {
        if self.instructions == 0 {
            0.0
        } else {
            self.cycles as f64 / self.instructions as f64
        }
    }
}
