//! Cache model for the base-core memory system.
//!
//! A set-associative write-allocate cache with LRU replacement, fed by the
//! interpreter's memory trace. Hit latency is folded into the load cost;
//! misses pay the refill penalty. This is what makes the base core's
//! cycles sensitive to access *patterns* (stride, thrashing), which the
//! Aquas cache-hint machinery then avoids on the ISAX side.

use crate::ir::func::Func;
use crate::ir::interp::MemAccess;

/// Cache geometry + timing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheConfig {
    pub line_bytes: usize,
    pub sets: usize,
    pub ways: usize,
    /// Cycles per miss (refill from the next level).
    pub miss_penalty: u64,
}

impl Default for CacheConfig {
    fn default() -> Self {
        // Rocket-ish 16 KiB L1D: 64B lines, 64 sets, 4 ways.
        Self { line_bytes: 64, sets: 64, ways: 4, miss_penalty: 20 }
    }
}

/// The cache state.
#[derive(Debug, Clone)]
pub struct Cache {
    cfg: CacheConfig,
    /// tags[set][way], with per-way LRU stamps.
    tags: Vec<Vec<(u64, u64)>>,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
}

impl Cache {
    pub fn new(cfg: CacheConfig) -> Self {
        Self { cfg, tags: vec![Vec::new(); cfg.sets], clock: 0, hits: 0, misses: 0 }
    }

    /// Access a byte address; returns true on hit.
    pub fn access(&mut self, addr: u64) -> bool {
        self.clock += 1;
        let line = addr / self.cfg.line_bytes as u64;
        let set = (line % self.cfg.sets as u64) as usize;
        let tag = line / self.cfg.sets as u64;
        let ways = &mut self.tags[set];
        if let Some(slot) = ways.iter_mut().find(|(t, _)| *t == tag) {
            slot.1 = self.clock;
            self.hits += 1;
            return true;
        }
        self.misses += 1;
        if ways.len() < self.cfg.ways {
            ways.push((tag, self.clock));
        } else {
            // Evict LRU.
            let lru = ways
                .iter()
                .enumerate()
                .min_by_key(|(_, (_, stamp))| *stamp)
                .map(|(i, _)| i)
                .expect("non-empty ways");
            ways[lru] = (tag, self.clock);
        }
        false
    }

    /// Run a whole trace; returns total extra cycles from misses.
    pub fn run_trace(&mut self, func: &Func, trace: &[MemAccess]) -> u64 {
        let mut extra = 0;
        for a in trace {
            let decl = func.buffer(a.buf);
            let addr = decl.base_addr + (a.index.max(0) as u64) * 4;
            if !self.access(addr) {
                extra += self.cfg.miss_penalty;
            }
        }
        extra
    }

    pub fn miss_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.misses as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_accesses_hit_within_line() {
        let mut c = Cache::new(CacheConfig::default());
        // 16 words in one 64B line: 1 miss + 15 hits.
        for i in 0..16 {
            c.access(0x1000 + i * 4);
        }
        assert_eq!(c.misses, 1);
        assert_eq!(c.hits, 15);
    }

    #[test]
    fn strided_accesses_miss_every_line() {
        let mut c = Cache::new(CacheConfig::default());
        for i in 0..16 {
            c.access(0x1000 + i * 64);
        }
        assert_eq!(c.misses, 16);
    }

    #[test]
    fn repeated_working_set_hits_after_warmup() {
        let mut c = Cache::new(CacheConfig::default());
        for _round in 0..4 {
            for i in 0..32 {
                c.access(0x2000 + i * 64);
            }
        }
        // 32 lines fit in 16 KiB: only cold misses.
        assert_eq!(c.misses, 32);
        assert_eq!(c.hits, 3 * 32);
    }

    #[test]
    fn thrashing_set_conflict() {
        let cfg = CacheConfig { sets: 2, ways: 1, line_bytes: 64, miss_penalty: 20 };
        let mut c = Cache::new(cfg);
        // Two addresses mapping to the same set, alternating: all misses.
        for _ in 0..8 {
            c.access(0x0);
            c.access(0x100); // 256 = line 4 -> set 0 as well (4 % 2 == 0)
        }
        assert_eq!(c.hits, 0);
        assert_eq!(c.misses, 16);
    }
}
