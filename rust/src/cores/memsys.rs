//! Cache model for the base-core memory system.
//!
//! A set-associative write-allocate cache with LRU replacement, fed by the
//! interpreter's memory trace. Hit latency is folded into the load cost;
//! misses pay the refill penalty. This is what makes the base core's
//! cycles sensitive to access *patterns* (stride, thrashing), which the
//! Aquas cache-hint machinery then avoids on the ISAX side.

use crate::ir::func::Func;
use crate::ir::interp::MemAccess;

/// Cache geometry + timing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheConfig {
    pub line_bytes: usize,
    pub sets: usize,
    pub ways: usize,
    /// Cycles per miss (refill from the next level).
    pub miss_penalty: u64,
}

impl Default for CacheConfig {
    fn default() -> Self {
        // Rocket-ish 16 KiB L1D: 64B lines, 64 sets, 4 ways.
        Self { line_bytes: 64, sets: 64, ways: 4, miss_penalty: 20 }
    }
}

/// The cache state.
#[derive(Debug, Clone)]
pub struct Cache {
    cfg: CacheConfig,
    /// tags[set][way], with per-way LRU stamps.
    tags: Vec<Vec<(u64, u64)>>,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
}

impl Cache {
    pub fn new(cfg: CacheConfig) -> Self {
        Self { cfg, tags: vec![Vec::new(); cfg.sets], clock: 0, hits: 0, misses: 0 }
    }

    /// Access a byte address; returns true on hit.
    pub fn access(&mut self, addr: u64) -> bool {
        self.clock += 1;
        // Zero-capacity geometry (no ways or no sets): nothing can ever
        // be resident, so every access is a miss and nothing is filled.
        if self.cfg.ways == 0 || self.cfg.sets == 0 {
            self.misses += 1;
            return false;
        }
        let line = addr / self.cfg.line_bytes as u64;
        let set = (line % self.cfg.sets as u64) as usize;
        let tag = line / self.cfg.sets as u64;
        let ways = &mut self.tags[set];
        if let Some(slot) = ways.iter_mut().find(|(t, _)| *t == tag) {
            slot.1 = self.clock;
            self.hits += 1;
            return true;
        }
        self.misses += 1;
        if ways.len() < self.cfg.ways {
            ways.push((tag, self.clock));
        } else {
            // Evict LRU.
            let lru = ways
                .iter()
                .enumerate()
                .min_by_key(|(_, (_, stamp))| *stamp)
                .map(|(i, _)| i)
                .expect("non-empty ways");
            ways[lru] = (tag, self.clock);
        }
        false
    }

    /// Run a whole trace; returns total extra cycles from misses.
    pub fn run_trace(&mut self, func: &Func, trace: &[MemAccess]) -> u64 {
        let mut extra = 0;
        for a in trace {
            let decl = func.buffer(a.buf);
            let addr = decl.base_addr + (a.index.max(0) as u64) * 4;
            if !self.access(addr) {
                extra += self.cfg.miss_penalty;
            }
        }
        extra
    }

    pub fn miss_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.misses as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_accesses_hit_within_line() {
        let mut c = Cache::new(CacheConfig::default());
        // 16 words in one 64B line: 1 miss + 15 hits.
        for i in 0..16 {
            c.access(0x1000 + i * 4);
        }
        assert_eq!(c.misses, 1);
        assert_eq!(c.hits, 15);
    }

    #[test]
    fn strided_accesses_miss_every_line() {
        let mut c = Cache::new(CacheConfig::default());
        for i in 0..16 {
            c.access(0x1000 + i * 64);
        }
        assert_eq!(c.misses, 16);
    }

    #[test]
    fn repeated_working_set_hits_after_warmup() {
        let mut c = Cache::new(CacheConfig::default());
        for _round in 0..4 {
            for i in 0..32 {
                c.access(0x2000 + i * 64);
            }
        }
        // 32 lines fit in 16 KiB: only cold misses.
        assert_eq!(c.misses, 32);
        assert_eq!(c.hits, 3 * 32);
    }

    #[test]
    fn thrashing_set_conflict() {
        let cfg = CacheConfig { sets: 2, ways: 1, line_bytes: 64, miss_penalty: 20 };
        let mut c = Cache::new(cfg);
        // Two addresses mapping to the same set, alternating: all misses.
        for _ in 0..8 {
            c.access(0x0);
            c.access(0x100); // 256 = line 4 -> set 0 as well (4 % 2 == 0)
        }
        assert_eq!(c.hits, 0);
        assert_eq!(c.misses, 16);
    }

    #[test]
    fn lru_evicts_least_recently_touched_way() {
        // Default geometry: 64 sets, 4 ways. Lines A..E all map to set 0
        // (addresses 4096 bytes apart).
        let (a, b, bb, d, e) = (0x0u64, 0x1000u64, 0x2000u64, 0x3000u64, 0x4000u64);
        let mut c = Cache::new(CacheConfig::default());
        for addr in [a, b, bb, d] {
            assert!(!c.access(addr), "cold fill of {addr:#x}");
        }
        // Refresh A so B becomes the LRU way, then overflow the set.
        assert!(c.access(a), "A still resident");
        assert!(!c.access(e), "E is a capacity miss");
        // E must have evicted B (the LRU), not A/C/D.
        assert!(c.access(a), "A survived the eviction");
        assert!(c.access(bb), "C survived the eviction");
        assert!(c.access(d), "D survived the eviction");
        assert!(c.access(e), "E resident after its fill");
        assert!(!c.access(b), "B was the LRU victim and must miss");
        assert_eq!(c.misses, 6); // 4 cold + E + B's return
        assert_eq!(c.hits, 5);
    }

    #[test]
    fn sequential_stride_beats_set_thrashing_stride() {
        // Same access count, radically different locality: a word-stride
        // sweep of 16 lines vs 16 lines that all collide in one set.
        let sweep = |stride: u64| {
            let mut c = Cache::new(CacheConfig::default());
            for _round in 0..4 {
                for i in 0..16u64 {
                    c.access(0x8000 + i * stride);
                }
            }
            c.miss_rate()
        };
        let sequential = sweep(4); // 16 words in 1 line per 16 accesses
        let thrashing = sweep(64 * 64); // one 4-way set, 16 lines, cyclic
        assert!(sequential < 0.1, "sequential miss rate {sequential}");
        // Cyclic reuse distance 16 > 4 ways: LRU never hits.
        assert_eq!(thrashing, 1.0, "thrashing miss rate {thrashing}");
    }

    #[test]
    fn zero_capacity_cache_always_misses_without_panicking() {
        // ways = 0 (and sets = 0) are legal degenerate geometries: an
        // interface with no cache behind it. Every access misses; the
        // old code panicked trying to evict from an empty set.
        for cfg in [
            CacheConfig { ways: 0, ..CacheConfig::default() },
            CacheConfig { sets: 0, ..CacheConfig::default() },
        ] {
            let mut c = Cache::new(cfg);
            for i in 0..32u64 {
                assert!(!c.access(0x1000 + (i % 4) * 4), "nothing can be resident");
            }
            assert_eq!(c.hits, 0);
            assert_eq!(c.misses, 32);
            assert_eq!(c.miss_rate(), 1.0);
        }
    }

    #[test]
    fn exact_capacity_working_set_fits_without_eviction() {
        // Exactly sets × ways distinct lines: after the cold fill, every
        // re-reference hits — the boundary where one more line would
        // start evicting.
        let cfg = CacheConfig::default(); // 64 sets x 4 ways = 256 lines
        let lines = (cfg.sets * cfg.ways) as u64;
        let mut c = Cache::new(cfg);
        for round in 0..3 {
            for i in 0..lines {
                let hit = c.access(i * cfg.line_bytes as u64);
                assert_eq!(hit, round > 0, "line {i} round {round}");
            }
        }
        assert_eq!(c.misses, lines);
        assert_eq!(c.hits, 2 * lines);
        // One extra line past exact capacity starts the evictions.
        assert!(!c.access(lines * cfg.line_bytes as u64));
        assert!(!c.access(0), "set 0's LRU way was just evicted");
    }

    #[test]
    fn re_reference_after_miss_penalty_is_free() {
        use crate::interface::cache::CacheHint;
        use crate::ir::builder::FuncBuilder;
        use crate::runtime::DType;

        // First pass over a buffer pays one refill per line; replaying
        // the identical trace against the now-warm cache charges zero
        // extra cycles — the penalty accounting must not double-bill
        // re-references.
        let mut b = FuncBuilder::new("warm");
        let x = b.global("x", DType::I32, 64, CacheHint::Unknown);
        let f = b.finish(&[]);
        let trace: Vec<MemAccess> =
            (0..64).map(|i| MemAccess { buf: x, index: i, is_store: false }).collect();
        let cfg = CacheConfig::default();
        let mut c = Cache::new(cfg);
        let cold = c.run_trace(&f, &trace);
        // 64 i32s = 256 bytes = 4 lines.
        assert_eq!(cold, 4 * cfg.miss_penalty, "cold pass: one refill per touched line");
        let warm = c.run_trace(&f, &trace);
        assert_eq!(warm, 0, "warm replay must be penalty-free");
        assert_eq!(c.misses, 4);
        assert_eq!(c.hits, 2 * 64 - 4);
    }

    #[test]
    fn run_trace_charges_miss_penalty_per_miss_on_hand_built_trace() {
        use crate::interface::cache::CacheHint;
        use crate::ir::builder::FuncBuilder;
        use crate::runtime::DType;

        // One global at the builder's default base 0x1000; a second right
        // after it (64B-aligned) so the trace can cross buffers.
        let mut b = FuncBuilder::new("trace");
        let x = b.global("x", DType::I32, 32, CacheHint::Unknown); // 0x1000..0x1080
        let y = b.global("y", DType::I32, 16, CacheHint::Unknown); // 0x1080..
        let f = b.finish(&[]);
        assert_eq!(f.buffer(x).base_addr, 0x1000);
        assert_eq!(f.buffer(y).base_addr, 0x1080);

        // Tiny direct-mapped 2-set cache: line 64B, so x spans lines
        // {0x1000 -> set 0, 0x1040 -> set 1} and y starts at 0x1080 ->
        // set 0 again (conflict with x's first line).
        let cfg = CacheConfig { line_bytes: 64, sets: 2, ways: 1, miss_penalty: 20 };
        let mut c = Cache::new(cfg);
        let acc = |buf, index, is_store| MemAccess { buf, index, is_store };
        let trace = vec![
            acc(x, 0, false),  // 0x1000 set0: miss
            acc(x, 1, false),  // same line: hit
            acc(x, 16, true),  // 0x1040 set1: miss
            acc(x, 0, false),  // set0 line still resident: hit
            acc(y, 0, true),   // 0x1080 set0: miss, evicts x line 0
            acc(x, 0, false),  // set0 conflict: miss again
        ];
        let extra = c.run_trace(&f, &trace);
        assert_eq!(c.misses, 4, "hand trace miss count");
        assert_eq!(c.hits, 2, "hand trace hit count");
        assert_eq!(extra, 4 * cfg.miss_penalty, "penalty accounting");
    }
}
