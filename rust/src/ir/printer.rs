//! Textual form of Aquas-IR (MLIR-flavoured). Used by `aquas synth --demo`
//! to show the Figure 4 IR refinements and by debugging/tests.

use std::fmt::Write;

use crate::ir::func::{Func, Region};
use crate::ir::ops::{CmpPred, Op, OpKind};

/// Render a function to text.
pub fn print_func(f: &Func) -> String {
    let mut out = String::new();
    for b in &f.buffers {
        let kind = match b.kind {
            crate::ir::func::BufferKind::Global => "global".to_string(),
            crate::ir::func::BufferKind::Scratchpad { banks } => format!("smem<banks={banks}>"),
        };
        let _ = writeln!(
            out,
            "  {} : {} {}[{}] hint={:?} @0x{:x}",
            b.name,
            kind,
            b.elem.name(),
            b.len,
            b.hint,
            b.base_addr
        );
    }
    let params: Vec<String> = f.params.iter().map(|p| format!("{p}")).collect();
    let _ = writeln!(out, "func @{}({}) {{", f.name, params.join(", "));
    print_region(f, &f.entry, 1, &mut out);
    out.push_str("}\n");
    out
}

fn print_region(f: &Func, region: &Region, depth: usize, out: &mut String) {
    for &opref in &region.ops {
        print_op(f, f.op(opref), depth, out);
    }
}

fn print_op(f: &Func, op: &Op, depth: usize, out: &mut String) {
    let pad = "  ".repeat(depth);
    let results: Vec<String> = op.results.iter().map(|r| format!("{r}")).collect();
    let operands: Vec<String> = op.operands.iter().map(|o| format!("{o}")).collect();
    let lhs = if results.is_empty() { String::new() } else { format!("{} = ", results.join(", ")) };

    match &op.kind {
        OpKind::For => {
            let iv = op.regions[0].params[0];
            let carried: Vec<String> =
                op.regions[0].params[1..].iter().map(|p| format!("{p}")).collect();
            let _ = write!(
                out,
                "{pad}{lhs}for {iv} = {} to {} step {}",
                operands[0], operands[1], operands[2]
            );
            if !carried.is_empty() {
                let inits: Vec<String> = operands[3..].to_vec();
                let _ = write!(out, " iter_args({} = {})", carried.join(", "), inits.join(", "));
            }
            out.push_str(" {\n");
            print_region(f, &op.regions[0], depth + 1, out);
            let _ = writeln!(out, "{pad}}}");
        }
        OpKind::If => {
            let _ = writeln!(out, "{pad}{lhs}if {} {{", operands[0]);
            print_region(f, &op.regions[0], depth + 1, out);
            let _ = writeln!(out, "{pad}}} else {{");
            print_region(f, &op.regions[1], depth + 1, out);
            let _ = writeln!(out, "{pad}}}");
        }
        kind => {
            let attr = attr_string(f, kind);
            let _ = writeln!(out, "{pad}{lhs}{}{attr} {}", kind.mnemonic(), operands.join(", "));
        }
    }
}

fn attr_string(f: &Func, kind: &OpKind) -> String {
    match kind {
        OpKind::ConstI(v) => format!(" {v}"),
        OpKind::ConstF(v) => format!(" {v}"),
        OpKind::Cmp(p) => format!(
            ".{}",
            match p {
                CmpPred::Eq => "eq",
                CmpPred::Ne => "ne",
                CmpPred::Lt => "lt",
                CmpPred::Le => "le",
                CmpPred::Gt => "gt",
                CmpPred::Ge => "ge",
            }
        ),
        OpKind::Powi(e) => format!("<{e}>"),
        OpKind::Load(b) | OpKind::Store(b) | OpKind::Fetch(b) | OpKind::ReadSmem(b)
        | OpKind::WriteSmem(b) => format!(" {}", f.buffer(*b).name),
        OpKind::ReadIrf(r) | OpKind::WriteIrf(r) => format!(" x{r}"),
        OpKind::Transfer { dst, src, size } => {
            format!(" {}<-{} #{}B", f.buffer(*dst).name, f.buffer(*src).name, size)
        }
        OpKind::Copy { itfc, dst, src, size, kind } => format!(
            " {}<-{} #{}B via @itfc{} ({:?})",
            f.buffer(*dst).name,
            f.buffer(*src).name,
            size,
            itfc.0,
            kind
        ),
        OpKind::LoadItfc { itfc, buf } | OpKind::StoreItfc { itfc, buf } => {
            format!(" {} via @itfc{}", f.buffer(*buf).name, itfc.0)
        }
        OpKind::CopyIssue { itfc, dst, src, size, tag, after, .. } => format!(
            " {}<-{} #{}B via @itfc{} tag={} after={:?}",
            f.buffer(*dst).name,
            f.buffer(*src).name,
            size,
            itfc.0,
            tag,
            after
        ),
        OpKind::CopyWait { tag } => format!(" tag={tag}"),
        OpKind::Intrinsic(name) => format!(".{name}"),
        _ => String::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::FuncBuilder;
    use crate::interface::cache::CacheHint;
    use crate::runtime::DType;

    #[test]
    fn prints_loop_structure() {
        let mut b = FuncBuilder::new("demo");
        let buf = b.global("x", DType::F32, 8, CacheHint::Warm);
        b.for_range(0, 8, 1, |b, iv| {
            let v = b.load(buf, iv);
            let two = b.const_f(2.0);
            let d = b.mul(v, two);
            b.store(buf, iv, d);
        });
        let f = b.finish(&[]);
        let text = print_func(&f);
        assert!(text.contains("func @demo"));
        assert!(text.contains("for"));
        assert!(text.contains("load x"));
        assert!(text.contains("store x"));
        assert!(text.contains("hint=Warm"));
    }
}
