//! Aquas-IR (§4.2): a multi-level SSA IR with regions.
//!
//! The paper implements Aquas-IR as an MLIR dialect; this crate implements
//! the same three refinement levels as a purpose-built IR (see DESIGN.md's
//! substitution ledger):
//!
//! | Level         | Representative ops                         | exposed µ-arch features |
//! |---------------|--------------------------------------------|-------------------------|
//! | Functional    | `transfer`, `fetch`, `read_smem`, `read_irf` | `m`: transfer size    |
//! | Architectural | `copy`/`load` bound to a `!memitfc<>`      | `W, M` legality; `I, L, E` latency; `C` cache penalty |
//! | Temporal      | `copy_issue`/`copy_wait` with `after` deps | `I`-aware order; hierarchy phase order |
//!
//! The same IR also hosts *software* programs (plain loops + load/store),
//! so the retargetable compiler (§5) can align ISAX descriptions and
//! application code at one abstraction level.
//!
//! Submodules: [`types`], [`ops`], [`func`] (module/function/arena),
//! [`builder`], [`printer`], [`verifier`], [`affine`] (index analysis),
//! [`interp`] (tree-walking reference interpreter used for HW/SW
//! equivalence checks), [`vm`] (compile-once register-bytecode engine,
//! differentially pinned against [`interp`]), [`passes`] (the mid-end:
//! SCCP/CSE/LICM/sink/DCE over cached analyses, every pass
//! differentially proven semantics-preserving).

pub mod affine;
pub mod builder;
pub mod func;
pub mod interp;
pub mod ops;
pub mod passes;
pub mod printer;
pub mod types;
pub mod verifier;
pub mod vm;

pub use builder::FuncBuilder;
pub use func::{BufferDecl, BufferId, BufferKind, Func, OpRef, Region, Value};
pub use ops::{CmpPred, Op, OpKind};
pub use types::Type;
