//! Mid-end optimization passes over Aquas-IR.
//!
//! The e-graph solves instruction *matching*; this module is the
//! classical mid-end that runs between extraction and `vm::compile`:
//! SCCP (sparse conditional constant propagation), CSE (with memory
//! versioning), LICM, compute sink, and DCE, orchestrated by
//! [`optimize`] as a pipeline iterated to a fixpoint. Analyses
//! (def-use, dominance, loop forest, integer intervals) are cached in
//! [`analysis::Analyses`] and invalidated only when a pass reports
//! changes.
//!
//! The contract every pass upholds — and the differential harness in
//! `tests/vm_diff.rs` machine-checks — is *observational equivalence*:
//! identical outputs, final memory, irf state, and error strings as the
//! unoptimized program, on both execution engines. Effectful anchors
//! (`store`, `copy_issue`, `copy_wait`, `transfer`, control flow) are
//! never deleted or reordered; pure work moves only within windows
//! proven safe by the trap oracle ([`analysis::can_trap`]). Execution
//! *statistics* (dynamic op counts) are exactly what the pipeline is
//! meant to change; they are reported, not compared.

#![warn(missing_docs)]

pub mod analysis;
pub mod cse;
pub mod dce;
pub mod licm;
pub mod sccp;
pub mod sink;

use crate::error::Result;
use crate::ir::func::Func;
use crate::ir::verifier;

use analysis::Analyses;

/// How hard the mid-end works.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OptLevel {
    /// No optimization: the IR is passed through untouched.
    #[default]
    O0,
    /// The full pipeline (SCCP, CSE, LICM, sink, DCE) to a fixpoint.
    O2,
}

impl OptLevel {
    /// Parse a CLI flag value (`"0"` or `"2"`).
    pub fn from_flag(s: &str) -> Option<Self> {
        match s {
            "0" => Some(OptLevel::O0),
            "2" => Some(OptLevel::O2),
            _ => None,
        }
    }
}

/// One mid-end pass, in pipeline order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pass {
    /// Sparse conditional constant propagation.
    Sccp,
    /// Common subexpression elimination.
    Cse,
    /// Loop-invariant code motion.
    Licm,
    /// Compute sink into `if` arms.
    Sink,
    /// Dead code elimination.
    Dce,
}

impl Pass {
    /// Every pass in the order one pipeline round runs them. SCCP first
    /// (folding exposes duplicates), CSE before LICM (fewer ops to
    /// hoist), sink after LICM (they target disjoint region kinds, so
    /// neither undoes the other), DCE last to sweep what the rest
    /// orphaned.
    pub const ALL: [Pass; 5] = [Pass::Sccp, Pass::Cse, Pass::Licm, Pass::Sink, Pass::Dce];

    /// Stable lowercase name (used in error messages, benches, CLI).
    pub fn name(self) -> &'static str {
        match self {
            Pass::Sccp => "sccp",
            Pass::Cse => "cse",
            Pass::Licm => "licm",
            Pass::Sink => "sink",
            Pass::Dce => "dce",
        }
    }
}

/// What a pipeline run did, per pass kind, plus how many rounds it took
/// to reach the fixpoint.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PipelineStats {
    /// Rounds executed (the last round is the all-zero fixpoint proof).
    pub rounds: usize,
    /// Ops constant-folded / branches decided / zero-trip loops deleted.
    pub folded: usize,
    /// Ops deduplicated by CSE.
    pub deduped: usize,
    /// Ops hoisted out of loops.
    pub hoisted: usize,
    /// Ops sunk into `if` arms.
    pub sunk: usize,
    /// Dead ops removed.
    pub removed: usize,
    /// The round budget ran out before a fixpoint was proven: the IR is
    /// valid and verified, but another round might still find rewrites.
    pub budget_hit: bool,
}

impl PipelineStats {
    /// Total number of individual rewrites across all passes.
    pub fn total(&self) -> usize {
        self.folded + self.deduped + self.hoisted + self.sunk + self.removed
    }
}

impl std::fmt::Display for PipelineStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "rounds={} folded={} deduped={} hoisted={} sunk={} removed={} budget_hit={}",
            self.rounds,
            self.folded,
            self.deduped,
            self.hoisted,
            self.sunk,
            self.removed,
            self.budget_hit
        )
    }
}

/// Default pipeline round cap — a backstop; the pipeline converges long
/// before this on real programs (each pass's rewrite count is a
/// monotonically decreasing measure). [`optimize_with_budget`] accepts a
/// caller-chosen cap (`compiler::CompileBudget::pass_rounds`).
pub const MAX_ROUNDS: usize = 32;

/// Run a single pass in isolation (fresh analysis cache) and verify the
/// result. Returns the pass's change count.
pub fn run_pass(f: &mut Func, pass: Pass) -> Result<usize> {
    let mut an = Analyses::new();
    run_pass_with(f, pass, &mut an)
}

fn run_pass_with(f: &mut Func, pass: Pass, an: &mut Analyses) -> Result<usize> {
    let n = match pass {
        Pass::Sccp => sccp::run(f, an),
        Pass::Cse => cse::run(f, an),
        Pass::Licm => licm::run(f, an),
        Pass::Sink => sink::run(f, an),
        Pass::Dce => dce::run(f, an),
    };
    verifier::verify_after_pass(f, pass.name())?;
    Ok(n)
}

/// Optimize `f` at `level`, returning the optimized function and what
/// the pipeline did. The input is not modified. Every pass run is
/// followed by a verifier check, so an `Ok` result is always valid IR.
pub fn optimize(f: &Func, level: OptLevel) -> Result<(Func, PipelineStats)> {
    optimize_with_budget(f, level, MAX_ROUNDS)
}

/// [`optimize`] under a caller-chosen round budget. Running out of
/// rounds is not an error: the pipeline stops where it stands, the
/// result is still verified IR, and `budget_hit` records that a fixpoint
/// was not proven. `max_rounds == 0` returns the input untouched (with
/// `budget_hit` set at O2, since nothing was proven converged).
pub fn optimize_with_budget(
    f: &Func,
    level: OptLevel,
    max_rounds: usize,
) -> Result<(Func, PipelineStats)> {
    let mut out = f.clone();
    let mut stats = PipelineStats::default();
    if level == OptLevel::O0 {
        return Ok((out, stats));
    }
    let mut an = Analyses::new();
    let mut converged = false;
    for round in 1..=max_rounds {
        stats.rounds = round;
        let mut changed = 0;
        for pass in Pass::ALL {
            let n = run_pass_with(&mut out, pass, &mut an)?;
            changed += n;
            match pass {
                Pass::Sccp => stats.folded += n,
                Pass::Cse => stats.deduped += n,
                Pass::Licm => stats.hoisted += n,
                Pass::Sink => stats.sunk += n,
                Pass::Dce => stats.removed += n,
            }
        }
        if changed == 0 {
            converged = true;
            break;
        }
    }
    stats.budget_hit = !converged;
    Ok((out, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interface::cache::CacheHint;
    use crate::ir::builder::FuncBuilder;
    use crate::ir::interp;
    use crate::ir::types::Type;
    use crate::runtime::DType;

    /// A function packed with one opportunity per pass: a constant
    /// subtree (SCCP), duplicate address math (CSE), loop-invariant
    /// arithmetic (LICM), work used in one `if` arm (sink), and a value
    /// nobody reads (DCE).
    fn rich_func() -> Func {
        let mut b = FuncBuilder::new("rich");
        let buf = b.global("data", DType::I32, 64, CacheHint::Unknown);
        let x = b.param(Type::Int);
        let two = b.const_i(2);
        let three = b.const_i(3);
        let six = b.mul(two, three); // SCCP: folds to 6
        let dead = b.add(six, two); // DCE: never used
        let _ = dead;
        b.for_range(0, 8, 1, |b, i| {
            let base = b.mul(six, two); // LICM: invariant; SCCP: const 12
            let a1 = b.add(base, i);
            let a2 = b.add(base, i); // CSE: duplicate of a1
            let v = b.load(buf, a1);
            let w = b.load(buf, a2); // CSE: duplicate load (no store between)
            let s = b.add(v, w);
            b.store(buf, a1, s);
        });
        let zero = b.const_i(0);
        let cond = b.cmp(crate::ir::ops::CmpPred::Gt, x, zero);
        let heavy = b.mul(x, x); // sink: only used in the then-arm
        let y = b.if_else(cond, |_| vec![heavy], |b| {
            let z = b.const_i(7);
            vec![z]
        });
        b.finish(&[y[0]])
    }

    #[test]
    fn pipeline_reaches_fixpoint_and_verifies() {
        let f = rich_func();
        let (opt, stats) = optimize(&f, OptLevel::O2).unwrap();
        assert!(stats.total() > 0, "pipeline found nothing in a rich func");
        assert!(stats.folded > 0, "sccp idle: {stats}");
        assert!(stats.deduped > 0, "cse idle: {stats}");
        assert!(stats.removed > 0, "dce idle: {stats}");
        crate::ir::verifier::verify(&opt).unwrap();
        // Idempotence: a second run is a no-op fixpoint.
        let (opt2, stats2) = optimize(&opt, OptLevel::O2).unwrap();
        assert_eq!(stats2.total(), 0, "second run not a fixpoint: {stats2}");
        assert_eq!(opt2, opt, "fixpoint run still mutated the function");
    }

    #[test]
    fn round_budget_degrades_gracefully() {
        let f = rich_func();
        // One round is not enough for the rich func's fixpoint proof:
        // the budget flag is set, but the IR is still valid and verified.
        let (opt, stats) = optimize_with_budget(&f, OptLevel::O2, 1).unwrap();
        assert_eq!(stats.rounds, 1);
        assert!(stats.budget_hit, "{stats}");
        crate::ir::verifier::verify(&opt).unwrap();
        // Zero rounds: input passes through untouched, budget flagged.
        let (same, z) = optimize_with_budget(&f, OptLevel::O2, 0).unwrap();
        assert_eq!(same, f);
        assert_eq!(z.rounds, 0);
        assert!(z.budget_hit);
        // The unbudgeted entry point proves its fixpoint.
        let (_, full) = optimize(&f, OptLevel::O2).unwrap();
        assert!(!full.budget_hit, "{full}");
    }

    #[test]
    fn o0_is_identity() {
        let f = rich_func();
        let (same, stats) = optimize(&f, OptLevel::O0).unwrap();
        assert_eq!(same, f);
        assert_eq!(stats.total(), 0);
    }

    #[test]
    fn optimized_func_agrees_with_original() {
        let f = rich_func();
        let (opt, _) = optimize(&f, OptLevel::O2).unwrap();
        let buf = f.buffer_by_name("data").unwrap();
        let seed: Vec<i32> = (0..64).map(|i| (i * 7 % 23) - 5).collect();
        for arg in [-3i64, 0, 5] {
            let mut m1 = interp::Memory::for_func(&f);
            m1.write_i32(buf, &seed);
            let mut m2 = interp::Memory::for_func(&opt);
            m2.write_i32(buf, &seed);
            let r1 = interp::run(&f, &[interp::Val::I(arg)], &mut m1);
            let r2 = interp::run(&opt, &[interp::Val::I(arg)], &mut m2);
            match (r1, r2) {
                (Ok(a), Ok(b)) => {
                    assert_eq!(a, b, "return values diverge for arg {arg}");
                    assert_eq!(m1.read_i32(buf), m2.read_i32(buf));
                }
                (Err(a), Err(b)) => assert_eq!(a.to_string(), b.to_string()),
                (a, b) => panic!("engines diverge: {a:?} vs {b:?}"),
            }
        }
    }

    #[test]
    fn opt_level_flag_parses() {
        assert_eq!(OptLevel::from_flag("0"), Some(OptLevel::O0));
        assert_eq!(OptLevel::from_flag("2"), Some(OptLevel::O2));
        assert_eq!(OptLevel::from_flag("1"), None);
    }
}
