//! Compute sink: move pure work into the `if` arm that consumes it.
//!
//! A pure, provably trap-free, non-memory op whose every use sits inside
//! a single arm of a single later `if` is moved to the front of that
//! arm, so the work only runs when the branch is actually taken. Ops are
//! never sunk into `for` bodies (that would *multiply* the work), and
//! trapping ops are never sunk (skipping the untaken arm would skip the
//! trap, changing observable error behaviour). Stores and other anchors
//! stay where they are — only value computation moves.
//!
//! Each region is scanned in reverse so a chain of ops feeding one arm
//! sinks in a single round in the right order: the tail of the chain
//! moves first, which makes its producer eligible next.

use std::collections::HashMap;

use crate::ir::func::{Func, OpRef, Region, Value};
use crate::ir::ops::OpKind;
use crate::ir::passes::analysis::{can_trap, Analyses, Intervals};

/// Identity of a region: `None` is the entry, otherwise the owning op
/// and the region's index within it.
type RegionId = Option<(OpRef, usize)>;

/// Run the sink pass on `f`; returns the number of ops moved.
pub fn run(f: &mut Func, an: &mut Analyses) -> usize {
    let mut total = 0;
    loop {
        let iv = an.intervals(f).clone();
        let n = round(f, &iv);
        if n == 0 {
            break;
        }
        total += n;
        an.invalidate();
    }
    total
}

fn round(f: &mut Func, iv: &Intervals) -> usize {
    // Parent map: op -> (owning op, region index); absent = entry.
    let mut parent: HashMap<OpRef, (OpRef, usize)> = HashMap::new();
    let mut users: HashMap<Value, Vec<OpRef>> = HashMap::new();
    build_maps(f, &f.entry, None, &mut parent, &mut users);
    let mut entry = std::mem::take(&mut f.entry);
    let moved = sink_region(f, &mut entry, None, &mut parent, &users, iv);
    f.entry = entry;
    moved
}

fn build_maps(
    f: &Func,
    region: &Region,
    id: RegionId,
    parent: &mut HashMap<OpRef, (OpRef, usize)>,
    users: &mut HashMap<Value, Vec<OpRef>>,
) {
    for &opref in &region.ops {
        if let Some(p) = id {
            parent.insert(opref, p);
        }
        let op = f.op(opref);
        for &v in &op.operands {
            users.entry(v).or_default().push(opref);
        }
        for (ri, r) in op.regions.iter().enumerate() {
            build_maps(f, r, Some((opref, ri)), parent, users);
        }
    }
}

/// Where do all transitive containers of `u` place it relative to the
/// region `id`?
enum Climb {
    /// `u` itself sits directly in the region.
    Direct,
    /// `u` is nested under op `.0` (directly in the region) via its
    /// region `.1`.
    Into(OpRef, usize),
    /// `u` is outside the region's subtree (cannot happen for uses of a
    /// value defined in the region, but handled defensively).
    Lost,
}

fn climb(u: OpRef, id: RegionId, parent: &HashMap<OpRef, (OpRef, usize)>) -> Climb {
    let c = parent.get(&u).copied();
    if c == id {
        return Climb::Direct;
    }
    let (mut anc, mut arm) = match c {
        Some(x) => x,
        None => return Climb::Lost,
    };
    loop {
        let pc = parent.get(&anc).copied();
        if pc == id {
            return Climb::Into(anc, arm);
        }
        match pc {
            Some((p, ri)) => {
                anc = p;
                arm = ri;
            }
            None => return Climb::Lost,
        }
    }
}

fn sink_region(
    f: &mut Func,
    region: &mut Region,
    id: RegionId,
    parent: &mut HashMap<OpRef, (OpRef, usize)>,
    users: &HashMap<Value, Vec<OpRef>>,
    iv: &Intervals,
) -> usize {
    let mut moved = 0;
    // Inner regions first, so deep chains settle before this level moves.
    for i in 0..region.ops.len() {
        let opref = region.ops[i];
        let mut regs = std::mem::take(&mut f.op_mut(opref).regions);
        for (ri, r) in regs.iter_mut().enumerate() {
            moved += sink_region(f, r, Some((opref, ri)), parent, users, iv);
        }
        f.op_mut(opref).regions = regs;
    }
    // Reverse scan: the tail of a dependence chain sinks first.
    let mut i = region.ops.len();
    while i > 0 {
        i -= 1;
        let x = region.ops[i];
        let op = f.op(x);
        let candidate = op.regions.is_empty()
            && !op.kind.is_anchor()
            && !op.kind.touches_memory()
            && !matches!(op.kind, OpKind::ReadIrf(_))
            && op.results.len() == 1
            && !can_trap(f, op, iv);
        if !candidate {
            continue;
        }
        let res = op.results[0];
        let Some(us) = users.get(&res) else { continue };
        if us.is_empty() {
            continue; // dead: DCE's job, not ours
        }
        let mut target: Option<(OpRef, usize)> = None;
        let mut ok = true;
        for &u in us {
            match climb(u, id, parent) {
                Climb::Direct | Climb::Lost => {
                    ok = false;
                    break;
                }
                Climb::Into(t, arm) => {
                    if let Some(prev) = target {
                        if prev != (t, arm) {
                            ok = false;
                            break;
                        }
                    }
                    target = Some((t, arm));
                }
            }
        }
        let Some((t, arm)) = target else { continue };
        if !ok || !matches!(f.op(t).kind, OpKind::If) {
            continue;
        }
        region.ops.remove(i);
        let mut regs = std::mem::take(&mut f.op_mut(t).regions);
        regs[arm].ops.insert(0, x);
        f.op_mut(t).regions = regs;
        parent.insert(x, (t, arm));
        moved += 1;
    }
    moved
}
