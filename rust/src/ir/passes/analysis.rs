//! Analyses backing the mid-end passes: def-use chains, lexical
//! dominance, the loop forest, and a dense integer-interval dataflow
//! solver that powers the trap-safety oracle ([`can_trap`]).
//!
//! All results are owned (ids only, no borrows into the [`Func`]), so a
//! pass can hold an analysis while it mutates the function, and the
//! [`Analyses`] cache can keep results alive across passes until a pass
//! actually changes something.

use std::collections::HashMap;

use crate::ir::func::{Func, OpRef, Region, Value};
use crate::ir::ops::{Op, OpKind};
use crate::ir::types::Type;

// ---------------------------------------------------------------------------
// Def-use chains
// ---------------------------------------------------------------------------

/// Def-use chains over the *reachable* ops (region walk, not the raw
/// arena — ops retired by a pass drop out automatically).
#[derive(Debug, Clone, Default)]
pub struct DefUse {
    /// Number of reachable uses per value.
    uses: HashMap<Value, u32>,
    /// Defining op per value (results, plus region params mapping to the
    /// op owning the region). Function params have no entry.
    defs: HashMap<Value, OpRef>,
}

impl DefUse {
    /// Compute def-use chains for `f`.
    pub fn compute(f: &Func) -> Self {
        let mut du = DefUse::default();
        f.walk(|opref, op| {
            for &v in &op.operands {
                *du.uses.entry(v).or_insert(0) += 1;
            }
            for &v in &op.results {
                du.defs.insert(v, opref);
            }
            for region in &op.regions {
                for &p in &region.params {
                    du.defs.insert(p, opref);
                }
            }
        });
        du
    }

    /// Reachable use count of `v`.
    pub fn use_count(&self, v: Value) -> u32 {
        self.uses.get(&v).copied().unwrap_or(0)
    }

    /// The op defining `v` (region params map to the owning op); `None`
    /// for function parameters.
    pub fn def(&self, v: Value) -> Option<OpRef> {
        self.defs.get(&v).copied()
    }
}

// ---------------------------------------------------------------------------
// Lexical dominance
// ---------------------------------------------------------------------------

/// Dominance for the structured IR. Regions are single-block and nest
/// lexically, so op `A` dominates op `B` exactly when, at the deepest
/// region containing both, `A`'s subtree position is strictly before the
/// subtree containing `B` — no CFG iteration needed. Sibling `if` arms
/// never dominate each other; an op never dominates into its own body
/// (a `for`'s results are defined only after the body).
#[derive(Debug, Clone, Default)]
pub struct Dominance {
    /// Path of op indices from the entry region down to each op.
    path: HashMap<OpRef, Vec<u32>>,
}

impl Dominance {
    /// Compute positions for every reachable op of `f`.
    pub fn compute(f: &Func) -> Self {
        let mut dom = Dominance::default();
        let mut prefix = Vec::new();
        dom.index_region(f, &f.entry, &mut prefix);
        dom
    }

    fn index_region(&mut self, f: &Func, region: &Region, prefix: &mut Vec<u32>) {
        for (i, &opref) in region.ops.iter().enumerate() {
            prefix.push(i as u32);
            self.path.insert(opref, prefix.clone());
            for r in &f.op(opref).regions {
                self.index_region(f, r, prefix);
            }
            prefix.pop();
        }
    }

    /// Does `a` strictly dominate `b` (execute-before on every path that
    /// reaches `b`)?
    pub fn dominates(&self, a: OpRef, b: OpRef) -> bool {
        let (Some(pa), Some(pb)) = (self.path.get(&a), self.path.get(&b)) else {
            return false;
        };
        if pa.len() > pb.len() || pa.is_empty() {
            return false;
        }
        let k = pa.len() - 1;
        pa[..k] == pb[..k] && pa[k] < pb[k]
    }
}

// ---------------------------------------------------------------------------
// Loop forest
// ---------------------------------------------------------------------------

/// One `for` op in the loop forest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LoopInfo {
    /// The `for` op.
    pub op: OpRef,
    /// Nesting depth (1 = outermost).
    pub depth: u32,
    /// Innermost enclosing `for`, if any.
    pub parent: Option<OpRef>,
}

/// All `for` loops of a function with their nesting structure.
#[derive(Debug, Clone, Default)]
pub struct LoopForest {
    /// Loops in pre-order.
    pub loops: Vec<LoopInfo>,
}

impl LoopForest {
    /// Compute the loop forest of `f`.
    pub fn compute(f: &Func) -> Self {
        let mut forest = LoopForest::default();
        let mut stack: Vec<OpRef> = Vec::new();
        forest.visit(f, &f.entry, &mut stack);
        forest
    }

    fn visit(&mut self, f: &Func, region: &Region, stack: &mut Vec<OpRef>) {
        for &opref in &region.ops {
            let op = f.op(opref);
            let is_for = matches!(op.kind, OpKind::For);
            if is_for {
                self.loops.push(LoopInfo {
                    op: opref,
                    depth: stack.len() as u32 + 1,
                    parent: stack.last().copied(),
                });
                stack.push(opref);
            }
            for r in &op.regions {
                self.visit(f, r, stack);
            }
            if is_for {
                stack.pop();
            }
        }
    }

    /// Deepest nesting level (0 for a loop-free function).
    pub fn max_depth(&self) -> u32 {
        self.loops.iter().map(|l| l.depth).max().unwrap_or(0)
    }
}

// ---------------------------------------------------------------------------
// Integer intervals (dense forward dataflow)
// ---------------------------------------------------------------------------

/// A conservative `[lo, hi]` range for an integer SSA value, tracked in
/// `i128` so `i64` corner arithmetic cannot overflow the analysis
/// itself. Absence from [`Intervals`] means unknown (top).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Iv {
    /// Inclusive lower bound.
    pub lo: i128,
    /// Inclusive upper bound.
    pub hi: i128,
}

impl Iv {
    fn cst(c: i64) -> Self {
        Iv { lo: c as i128, hi: c as i128 }
    }

    /// Reject ranges that escape `i64` (the runtime wraps there, so any
    /// bound past the edge is unsound to keep).
    fn fit(self) -> Option<Self> {
        if self.lo > self.hi {
            return None;
        }
        if self.lo < i64::MIN as i128 || self.hi > i64::MAX as i128 {
            return None;
        }
        Some(self)
    }

    fn hull(self, other: Self) -> Self {
        Iv { lo: self.lo.min(other.lo), hi: self.hi.max(other.hi) }
    }
}

/// Dense forward interval analysis over the structured IR. Loads,
/// parameters and loop-carried values are top; induction variables get
/// `[lb.lo, max(lb.lo, ub.hi - 1)]` when the bounds are known and the
/// step is provably positive. Sound under the interpreter's wrapping
/// integer semantics because any range that could wrap is dropped to top
/// by [`Iv::fit`].
#[derive(Debug, Clone, Default)]
pub struct Intervals {
    iv: HashMap<Value, Iv>,
}

impl Intervals {
    /// Compute intervals for every reachable integer value of `f`.
    pub fn compute(f: &Func) -> Self {
        let mut s = Intervals::default();
        s.region(f, &f.entry);
        s
    }

    /// The known range of `v`, if any.
    pub fn get(&self, v: Value) -> Option<Iv> {
        self.iv.get(&v).copied()
    }

    fn set(&mut self, v: Value, iv: Option<Iv>) {
        if let Some(iv) = iv.and_then(Iv::fit) {
            self.iv.insert(v, iv);
        }
    }

    fn region(&mut self, f: &Func, region: &Region) {
        for &opref in &region.ops {
            self.op(f, f.op(opref));
        }
    }

    fn op(&mut self, f: &Func, op: &Op) {
        let g = |s: &Self, i: usize| op.operands.get(i).and_then(|&v| s.get(v));
        match &op.kind {
            OpKind::ConstI(c) => self.set(op.results[0], Some(Iv::cst(*c))),
            OpKind::Add | OpKind::Sub | OpKind::Mul => {
                if f.value_type(op.results[0]) == Type::Int {
                    let r = match (g(self, 0), g(self, 1)) {
                        (Some(a), Some(b)) => corners(&op.kind, a, b),
                        _ => None,
                    };
                    self.set(op.results[0], r);
                }
            }
            OpKind::Rem => {
                // `x % L` with a known positive divisor: result in
                // `(-L, L)`; non-negative when x provably is. This is
                // what proves the fuzzer's `((x % L) + L) % L` in-bounds
                // index pattern.
                let r = match (g(self, 0), g(self, 1)) {
                    (x, Some(l)) if l.lo >= 1 => {
                        let mut lo = -(l.hi - 1);
                        let mut hi = l.hi - 1;
                        if let Some(x) = x {
                            if x.lo >= 0 {
                                lo = 0;
                                hi = hi.min(x.hi);
                            }
                        }
                        Some(Iv { lo, hi })
                    }
                    _ => None,
                };
                self.set(op.results[0], r);
            }
            OpKind::And => {
                // Masking with a known non-negative constant bounds the
                // result to `[0, mask]` whenever x is non-negative.
                let r = match (g(self, 0), g(self, 1)) {
                    (Some(x), Some(m)) if x.lo >= 0 && m.lo >= 0 => {
                        Some(Iv { lo: 0, hi: x.hi.min(m.hi) })
                    }
                    (Some(x), Some(m)) if m.lo == m.hi && m.lo >= 0 && x.lo >= 0 => {
                        Some(Iv { lo: 0, hi: m.hi })
                    }
                    _ => None,
                };
                self.set(op.results[0], r);
            }
            OpKind::Min => {
                let r = match (g(self, 0), g(self, 1)) {
                    (Some(a), Some(b)) => {
                        Some(Iv { lo: a.lo.min(b.lo), hi: a.hi.min(b.hi) })
                    }
                    _ => None,
                };
                if f.value_type(op.results[0]) == Type::Int {
                    self.set(op.results[0], r);
                }
            }
            OpKind::Max => {
                let r = match (g(self, 0), g(self, 1)) {
                    (Some(a), Some(b)) => {
                        Some(Iv { lo: a.lo.max(b.lo), hi: a.hi.max(b.hi) })
                    }
                    _ => None,
                };
                if f.value_type(op.results[0]) == Type::Int {
                    self.set(op.results[0], r);
                }
            }
            OpKind::Neg => {
                if f.value_type(op.results[0]) == Type::Int {
                    let r = g(self, 0).map(|a| Iv { lo: -a.hi, hi: -a.lo });
                    self.set(op.results[0], r);
                }
            }
            OpKind::Cmp(_) => self.set(op.results[0], Some(Iv { lo: 0, hi: 1 })),
            OpKind::Select => {
                if f.value_type(op.results[0]) == Type::Int {
                    let r = match (g(self, 1), g(self, 2)) {
                        (Some(a), Some(b)) => Some(a.hull(b)),
                        _ => None,
                    };
                    self.set(op.results[0], r);
                }
            }
            OpKind::For => {
                // Bind the induction variable's range for the body walk
                // (valid across every iteration), carried params stay top.
                let region = &op.regions[0];
                let (lb, ub) = (g(self, 0), g(self, 1));
                let step_pos = g(self, 2).is_some_and(|s| s.lo >= 1);
                if let (Some(lb), Some(ub), true) = (lb, ub, step_pos) {
                    let iv = Iv { lo: lb.lo, hi: (ub.hi - 1).max(lb.lo) };
                    self.set(region.params[0], Some(iv));
                }
                self.region(f, region);
            }
            OpKind::If => {
                self.region(f, &op.regions[0]);
                self.region(f, &op.regions[1]);
                // Results: hull of the two arms' yield operands.
                let yields: Vec<Option<&Op>> = op
                    .regions
                    .iter()
                    .map(|r| r.ops.last().map(|&o| f.op(o)))
                    .collect();
                if let (Some(t), Some(e)) = (yields[0], yields[1]) {
                    for (i, &res) in op.results.iter().enumerate() {
                        if f.value_type(res) != Type::Int {
                            continue;
                        }
                        let r = match (
                            t.operands.get(i).and_then(|&v| self.get(v)),
                            e.operands.get(i).and_then(|&v| self.get(v)),
                        ) {
                            (Some(a), Some(b)) => Some(a.hull(b)),
                            _ => None,
                        };
                        self.set(res, r);
                    }
                }
            }
            // Loads, conversions, shifts, irf reads, everything else: top.
            _ => {}
        }
    }
}

/// Corner-product interval arithmetic for add/sub/mul in `i128`.
fn corners(kind: &OpKind, a: Iv, b: Iv) -> Option<Iv> {
    let r = match kind {
        OpKind::Add => Iv { lo: a.lo + b.lo, hi: a.hi + b.hi },
        OpKind::Sub => Iv { lo: a.lo - b.hi, hi: a.hi - b.lo },
        OpKind::Mul => {
            let cs = [a.lo * b.lo, a.lo * b.hi, a.hi * b.lo, a.hi * b.hi];
            Iv {
                lo: cs.iter().copied().min().unwrap(),
                hi: cs.iter().copied().max().unwrap(),
            }
        }
        _ => return None,
    };
    Some(r)
}

// ---------------------------------------------------------------------------
// Trap-safety oracle
// ---------------------------------------------------------------------------

/// Can executing `op` raise a runtime error (or change the error
/// behaviour of the program if executed speculatively)?
///
/// This is the single predicate every pass consults before moving or
/// deleting work: DCE only removes dead ops that provably cannot trap,
/// LICM only hoists (and sink only sinks) trap-free ops, so the
/// optimized program reports *bit-identical error strings at identical
/// memory states* — part of the differential contract in
/// `tests/vm_diff.rs`.
///
/// The analysis mirrors `ir::interp` exactly: wrapping integer
/// arithmetic never traps; int `div`/`rem` trap on a zero (or `-1` with
/// `i64::MIN`) divisor unless the divisor's interval excludes both;
/// float `cmp` traps on NaN (always assumed possible); loads trap unless
/// the index interval is provably inside `[0, len)`. Type mismatches the
/// interpreter would reject at runtime also count as traps.
pub fn can_trap(f: &Func, op: &Op, iv: &Intervals) -> bool {
    let ty = |v: Value| f.value_type(v);
    let same_ty2 = |op: &Op| ty(op.operands[0]) == ty(op.operands[1]);
    match &op.kind {
        OpKind::ConstI(_) | OpKind::ConstF(_) => false,
        OpKind::Add | OpKind::Sub | OpKind::Mul | OpKind::Min | OpKind::Max => !same_ty2(op),
        OpKind::Div => {
            if !same_ty2(op) {
                return true;
            }
            if ty(op.operands[0]) == Type::Float {
                return false; // fp division yields inf/NaN, never errors
            }
            !divisor_is_safe(op.operands[1], iv)
        }
        OpKind::Rem => {
            if ty(op.operands[0]) != Type::Int || ty(op.operands[1]) != Type::Int {
                return true;
            }
            !divisor_is_safe(op.operands[1], iv)
        }
        OpKind::Shl | OpKind::Shr | OpKind::And | OpKind::Or | OpKind::Xor => {
            ty(op.operands[0]) != Type::Int || ty(op.operands[1]) != Type::Int
        }
        OpKind::Neg => false,
        OpKind::Sqrt | OpKind::Exp => ty(op.operands[0]) != Type::Float,
        OpKind::Powi(_) => ty(op.operands[0]) != Type::Float,
        OpKind::ToFloat => ty(op.operands[0]) != Type::Int,
        OpKind::ToInt => ty(op.operands[0]) != Type::Float,
        OpKind::Cmp(_) => {
            // Float comparison errors on NaN ("cmp: unordered"); we never
            // try to prove NaN-freedom, so any float cmp may trap.
            !same_ty2(op) || ty(op.operands[0]) == Type::Float
        }
        OpKind::Select => ty(op.operands[0]) != Type::Int,
        OpKind::Load(b) | OpKind::Fetch(b) | OpKind::ReadSmem(b) => {
            !index_in_bounds(op.operands[0], f.buffer(*b).len, iv)
        }
        OpKind::LoadItfc { buf, .. } => {
            !index_in_bounds(op.operands[0], f.buffer(*buf).len, iv)
        }
        OpKind::ReadIrf(_) => false,
        // Anchors, writes, transfers, control flow, intrinsics: the
        // passes never speculate these, so report them as trapping.
        _ => true,
    }
}

/// Divisor provably excludes 0 *and* -1 (`i64::MIN / -1` overflows).
fn divisor_is_safe(v: Value, iv: &Intervals) -> bool {
    match iv.get(v) {
        Some(r) => r.lo >= 1 || r.hi <= -2,
        None => false,
    }
}

fn index_in_bounds(v: Value, len: usize, iv: &Intervals) -> bool {
    match iv.get(v) {
        Some(r) => r.lo >= 0 && r.hi < len as i128,
        None => false,
    }
}

// ---------------------------------------------------------------------------
// Analysis cache
// ---------------------------------------------------------------------------

/// Lazily-computed, invalidation-aware analysis cache shared by the pass
/// pipeline: each analysis is computed on first request and reused until
/// [`Analyses::invalidate`] is called (which the pass manager does after
/// any pass that reports changes). Passes that change nothing keep every
/// cached result warm for the next pass in the round.
#[derive(Debug, Default)]
pub struct Analyses {
    defuse: Option<DefUse>,
    dominance: Option<Dominance>,
    loops: Option<LoopForest>,
    intervals: Option<Intervals>,
}

impl Analyses {
    /// Fresh, empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Drop every cached result (the IR changed).
    pub fn invalidate(&mut self) {
        *self = Self::default();
    }

    /// Def-use chains for `f` (cached).
    pub fn defuse(&mut self, f: &Func) -> &DefUse {
        self.defuse.get_or_insert_with(|| DefUse::compute(f))
    }

    /// Lexical dominance for `f` (cached).
    pub fn dominance(&mut self, f: &Func) -> &Dominance {
        self.dominance.get_or_insert_with(|| Dominance::compute(f))
    }

    /// Loop forest for `f` (cached).
    pub fn loops(&mut self, f: &Func) -> &LoopForest {
        self.loops.get_or_insert_with(|| LoopForest::compute(f))
    }

    /// Interval analysis for `f` (cached).
    pub fn intervals(&mut self, f: &Func) -> &Intervals {
        self.intervals.get_or_insert_with(|| Intervals::compute(f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interface::cache::CacheHint;
    use crate::ir::builder::FuncBuilder;
    use crate::runtime::DType;

    fn loopy() -> Func {
        let mut b = FuncBuilder::new("loopy");
        let buf = b.global("x", DType::I32, 16, CacheHint::Unknown);
        b.for_range(0, 8, 1, |b, i| {
            b.for_range(0, 4, 1, |b, j| {
                let s = b.add(i, j);
                b.store(buf, i, s);
            });
        });
        b.finish(&[])
    }

    #[test]
    fn loop_forest_tracks_nesting() {
        let f = loopy();
        let forest = LoopForest::compute(&f);
        assert_eq!(forest.loops.len(), 2);
        assert_eq!(forest.max_depth(), 2);
        assert_eq!(forest.loops[0].depth, 1);
        assert_eq!(forest.loops[1].parent, Some(forest.loops[0].op));
    }

    #[test]
    fn dominance_is_lexical() {
        let f = loopy();
        let dom = Dominance::compute(&f);
        // The lb const (first entry op) dominates the outer for (4th).
        let first = f.entry.ops[0];
        let last = *f.entry.ops.last().unwrap();
        assert!(dom.dominates(first, last));
        assert!(!dom.dominates(last, first));
        assert!(!dom.dominates(first, first));
    }

    #[test]
    fn induction_variable_gets_a_range() {
        let f = loopy();
        let iv = Intervals::compute(&f);
        // Find the inner add op and check both operands are bounded.
        let mut checked = false;
        f.walk(|_, op| {
            if matches!(op.kind, OpKind::Add) {
                let a = iv.get(op.operands[0]).expect("outer iv bounded");
                let b = iv.get(op.operands[1]).expect("inner iv bounded");
                assert_eq!((a.lo, a.hi), (0, 7));
                assert_eq!((b.lo, b.hi), (0, 3));
                checked = true;
            }
        });
        assert!(checked);
    }

    #[test]
    fn rem_pattern_proves_in_bounds() {
        // ((x % 8) + 8) % 8 over an unknown x is within [0, 8).
        let mut b = FuncBuilder::new("idx");
        let x = b.param(Type::Int);
        let buf = b.global("m", DType::I32, 8, CacheHint::Unknown);
        let l = b.const_i(8);
        let r0 = b.rem(x, l);
        let r1 = b.add(r0, l);
        let r2 = b.rem(r1, l);
        let v = b.load(buf, r2);
        let f = b.finish(&[v]);
        let iv = Intervals::compute(&f);
        let r = iv.get(r2).expect("final rem bounded");
        assert_eq!((r.lo, r.hi), (0, 7));
        // And the load is therefore trap-free while a raw-index load isn't.
        f.walk(|_, op| {
            if matches!(op.kind, OpKind::Load(_)) {
                assert!(!can_trap(&f, op, &iv));
            }
        });
    }

    #[test]
    fn trap_oracle_flags_unprovable_divisors_and_loads() {
        let mut b = FuncBuilder::new("traps");
        let x = b.param(Type::Int);
        let y = b.param(Type::Int);
        let buf = b.global("m", DType::I32, 8, CacheHint::Unknown);
        let q = b.div(x, y); // unknown divisor: may trap
        let two = b.const_i(2);
        let q2 = b.div(x, two); // constant 2: safe
        let ld = b.load(buf, x); // unknown index: may trap
        let f = b.finish(&[q, q2, ld]);
        let iv = Intervals::compute(&f);
        let mut flags = Vec::new();
        f.walk(|_, op| {
            if matches!(op.kind, OpKind::Div | OpKind::Load(_)) {
                flags.push(can_trap(&f, op, &iv));
            }
        });
        assert_eq!(flags, vec![true, false, true]);
    }

    #[test]
    fn analyses_cache_survives_until_invalidated() {
        let f = loopy();
        let mut an = Analyses::new();
        let n = an.loops(&f).loops.len();
        assert_eq!(an.loops(&f).loops.len(), n); // cached path
        an.invalidate();
        assert_eq!(an.loops(&f).loops.len(), n); // recomputed path
    }
}
