//! Sparse conditional constant propagation over the i64/f64 lattice.
//!
//! Three-level lattice per SSA value (unknown → constant → varying),
//! with `for`-carried values solved by a meet-to-fixpoint loop. The
//! folder mirrors `ir::interp` *exactly* — wrapping integer arithmetic
//! (including `neg`), checked division/remainder (a fold that the
//! interpreter would reject at runtime is simply not performed,
//! preserving the error), IEEE float arithmetic, and NaN-aware
//! comparisons.
//!
//! Three rewrites are applied:
//! - a pure op whose value is a known constant becomes `const_i`/
//!   `const_f` in place (its result id is preserved, so no uses move);
//! - an `if` with a known condition is spliced: the taken arm's ops are
//!   inlined where the `if` stood and its results map to the arm's
//!   yield operands (the untaken arm vanishes — it was unreachable);
//! - a `for` with constant bounds proving zero trips is deleted and its
//!   results map to the carried inits. A constant *non-positive* step
//!   is left untouched: the interpreter reports an error for it, and
//!   that error is part of the program's observable behaviour.

use std::collections::{HashMap, HashSet};

use crate::ir::func::{Func, OpRef, Region, Value};
use crate::ir::interp::Val;
use crate::ir::ops::{CmpPred, OpKind};
use crate::ir::passes::analysis::Analyses;
use crate::ir::types::Type;

/// The constant lattice: `Unknown` (no evidence yet), a single known
/// runtime value, or `Varying` (shown to take multiple values).
#[derive(Debug, Clone, Copy, PartialEq)]
enum Lat {
    Unknown,
    Const(Val),
    Varying,
}

fn val_eq(a: Val, b: Val) -> bool {
    match (a, b) {
        (Val::I(x), Val::I(y)) => x == y,
        (Val::F(x), Val::F(y)) => x.to_bits() == y.to_bits(),
        _ => false,
    }
}

fn meet(a: Lat, b: Lat) -> Lat {
    match (a, b) {
        (Lat::Unknown, x) | (x, Lat::Unknown) => x,
        (Lat::Const(x), Lat::Const(y)) if val_eq(x, y) => Lat::Const(x),
        _ => Lat::Varying,
    }
}

#[derive(Default)]
struct Sccp {
    lats: HashMap<Value, Lat>,
    /// Pure ops to rewrite to constants.
    fold: HashMap<OpRef, Val>,
    /// `if` ops with a decided condition -> taken arm index.
    splice: HashMap<OpRef, usize>,
    /// Zero-trip `for` ops to delete.
    zero_trip: HashSet<OpRef>,
    /// Accumulated use replacements (if results, zero-trip for results).
    map: HashMap<Value, Value>,
    changes: usize,
}

/// Run SCCP on `f`; returns the number of rewrites (folds + splices +
/// zero-trip deletions).
pub fn run(f: &mut Func, an: &mut Analyses) -> usize {
    let mut st = Sccp::default();
    for &p in &f.params {
        st.lats.insert(p, Lat::Varying);
    }
    st.eval_region(f, &f.entry);
    st.plan(f, &f.entry);
    if st.fold.is_empty() && st.splice.is_empty() && st.zero_trip.is_empty() {
        return 0;
    }
    let mut entry = std::mem::take(&mut f.entry);
    let mut new_ops = Vec::with_capacity(entry.ops.len());
    st.transform_ops(f, std::mem::take(&mut entry.ops), &mut new_ops);
    entry.ops = new_ops;
    f.entry = entry;
    f.replace_uses(&st.map);
    an.invalidate();
    st.changes
}

impl Sccp {
    fn lat(&self, v: Value) -> Lat {
        self.lats.get(&v).copied().unwrap_or(Lat::Unknown)
    }

    fn set(&mut self, v: Value, l: Lat) {
        self.lats.insert(v, l);
    }

    /// Evaluate a region; returns the lattice values of its terminator's
    /// operands (the yield/return payload).
    fn eval_region(&mut self, f: &Func, region: &Region) -> Vec<Lat> {
        let mut out = Vec::new();
        for &opref in &region.ops {
            let op = f.op(opref);
            match &op.kind {
                OpKind::Yield | OpKind::Return => {
                    out = op.operands.iter().map(|&v| self.lat(v)).collect();
                }
                OpKind::For => self.eval_for(f, opref),
                OpKind::If => self.eval_if(f, opref),
                _ => {
                    if op.results.is_empty() {
                        continue;
                    }
                    let l = if is_opaque(&op.kind) {
                        Lat::Varying
                    } else {
                        let mut vals = Vec::with_capacity(op.operands.len());
                        let mut l = None;
                        for &o in &op.operands {
                            match self.lat(o) {
                                Lat::Const(v) => vals.push(v),
                                other => {
                                    l = Some(other);
                                    break;
                                }
                            }
                        }
                        match l {
                            Some(other) => other,
                            None => match eval_op(&op.kind, &vals) {
                                Some(v) => Lat::Const(v),
                                None => Lat::Varying,
                            },
                        }
                    };
                    for &r in &op.results {
                        self.set(r, l);
                    }
                }
            }
        }
        out
    }

    fn eval_for(&mut self, f: &Func, opref: OpRef) {
        let op = f.op(opref);
        let body = &op.regions[0];
        let inits: Vec<Lat> = op.operands[3..].iter().map(|&v| self.lat(v)).collect();
        let bounds = (
            self.lat(op.operands[0]),
            self.lat(op.operands[1]),
            self.lat(op.operands[2]),
        );
        // Trip count when all bounds are constant and the step is valid.
        let trips: Option<i128> = match bounds {
            (Lat::Const(Val::I(l)), Lat::Const(Val::I(u)), Lat::Const(Val::I(s))) if s > 0 => {
                let (l, u, s) = (l as i128, u as i128, s as i128);
                Some(if u <= l { 0 } else { (u - l + s - 1) / s })
            }
            _ => None,
        };
        // Carried fixpoints re-evaluate enclosing bodies; a verdict from
        // an earlier round may rest on lattice values that have since
        // descended to Varying, so always re-derive from scratch.
        self.zero_trip.remove(&opref);
        if trips == Some(0) {
            // Body never runs; results are the inits. Leave body values
            // at Unknown — the whole op is deleted by the transform.
            self.zero_trip.insert(opref);
            for (i, &r) in op.results.iter().enumerate() {
                self.set(r, inits[i]);
            }
            return;
        }
        let iv_lat = match (trips, bounds.0) {
            (Some(1), Lat::Const(v)) => Lat::Const(v),
            _ => Lat::Varying,
        };
        if trips == Some(1) {
            // Exactly one iteration: carried params are the inits.
            self.set(body.params[0], iv_lat);
            for (i, &p) in body.params[1..].iter().enumerate() {
                self.set(p, inits[i]);
            }
            let y = self.eval_region(f, body);
            for (i, &r) in op.results.iter().enumerate() {
                self.set(r, y[i]);
            }
            return;
        }
        // General case: meet the carried values to a fixpoint. The
        // lattice has height 2, so this converges in a few rounds.
        let mut carried = inits.clone();
        let mut y;
        loop {
            self.set(body.params[0], iv_lat);
            for (i, &p) in body.params[1..].iter().enumerate() {
                self.set(p, carried[i]);
            }
            y = self.eval_region(f, body);
            let next: Vec<Lat> = carried
                .iter()
                .zip(&y)
                .map(|(&c, &yl)| meet(c, yl))
                .collect();
            if next == carried {
                break;
            }
            carried = next;
        }
        for (i, &r) in op.results.iter().enumerate() {
            // Unknown trip count includes "zero", where the init flows
            // straight through.
            let l = if trips.is_some() { y[i] } else { meet(inits[i], y[i]) };
            self.set(r, l);
        }
    }

    fn eval_if(&mut self, f: &Func, opref: OpRef) {
        let op = f.op(opref);
        // Same staleness discipline as `eval_for`: a condition that was
        // Const in an earlier fixpoint round may now be Varying.
        self.splice.remove(&opref);
        match self.lat(op.operands[0]) {
            Lat::Const(Val::I(c)) => {
                let taken = if c != 0 { 0 } else { 1 };
                let y = self.eval_region(f, &op.regions[taken]);
                self.splice.insert(opref, taken);
                for (i, &r) in op.results.iter().enumerate() {
                    self.set(r, y[i]);
                }
            }
            _ => {
                // Unknown/varying/float condition (the latter errors at
                // runtime — keep the op): evaluate both arms and meet.
                let y0 = self.eval_region(f, &op.regions[0]);
                let y1 = self.eval_region(f, &op.regions[1]);
                for (i, &r) in op.results.iter().enumerate() {
                    self.set(r, meet(y0[i], y1[i]));
                }
            }
        }
    }

    /// Decide which pure ops get rewritten to constants.
    fn plan(&mut self, f: &Func, region: &Region) {
        for &opref in &region.ops {
            let op = f.op(opref);
            if let Some(&taken) = self.splice.get(&opref) {
                // Only the surviving arm is planned/transformed.
                self.plan(f, &op.regions[taken]);
                continue;
            }
            if self.zero_trip.contains(&opref) {
                continue;
            }
            for r in &op.regions {
                self.plan(f, r);
            }
            let foldable = op.regions.is_empty()
                && op.results.len() == 1
                && !op.kind.is_anchor()
                && !op.kind.touches_memory()
                && !matches!(
                    op.kind,
                    OpKind::ConstI(_) | OpKind::ConstF(_) | OpKind::ReadIrf(_)
                );
            if !foldable {
                continue;
            }
            if let Lat::Const(v) = self.lat(op.results[0]) {
                let ty_ok = match v {
                    Val::I(_) => f.value_type(op.results[0]) == Type::Int,
                    Val::F(_) => f.value_type(op.results[0]) == Type::Float,
                };
                if ty_ok {
                    self.fold.insert(opref, v);
                }
            }
        }
    }

    /// Rebuild an op list applying folds, splices, and zero-trip
    /// deletions; recurses into surviving regions.
    fn transform_ops(&mut self, f: &mut Func, ops: Vec<OpRef>, out: &mut Vec<OpRef>) {
        for opref in ops {
            if self.zero_trip.contains(&opref) {
                let op = f.op(opref);
                let inits: Vec<Value> = op.operands[3..].to_vec();
                for (i, &r) in op.results.iter().enumerate() {
                    self.map.insert(r, inits[i]);
                }
                self.changes += 1;
                continue; // op deleted
            }
            if let Some(&taken) = self.splice.get(&opref) {
                let mut regs = std::mem::take(&mut f.op_mut(opref).regions);
                let arm = std::mem::take(&mut regs[taken]);
                let op = f.op(opref);
                // Map the if's results to the taken arm's yield operands.
                if let Some(&last) = arm.ops.last() {
                    let yields = f.op(last).operands.clone();
                    for (i, &r) in op.results.iter().enumerate() {
                        self.map.insert(r, yields[i]);
                    }
                }
                let mut inner = arm.ops;
                inner.pop(); // drop the yield terminator
                self.transform_ops(f, inner, out);
                self.changes += 1;
                continue; // the if itself is deleted
            }
            let mut regs = std::mem::take(&mut f.op_mut(opref).regions);
            for r in &mut regs {
                let inner = std::mem::take(&mut r.ops);
                let mut rebuilt = Vec::with_capacity(inner.len());
                self.transform_ops(f, inner, &mut rebuilt);
                r.ops = rebuilt;
            }
            f.op_mut(opref).regions = regs;
            if let Some(&v) = self.fold.get(&opref) {
                let op = f.op_mut(opref);
                op.kind = match v {
                    Val::I(c) => OpKind::ConstI(c),
                    Val::F(c) => OpKind::ConstF(c),
                };
                op.operands.clear();
                self.changes += 1;
            }
            out.push(opref);
        }
    }
}

/// Ops whose results carry no compile-time information.
fn is_opaque(kind: &OpKind) -> bool {
    matches!(
        kind,
        OpKind::Load(_)
            | OpKind::Fetch(_)
            | OpKind::ReadSmem(_)
            | OpKind::LoadItfc { .. }
            | OpKind::ReadIrf(_)
            | OpKind::Intrinsic(_)
    )
}

/// Fold one pure op over constant operands, mirroring `ir::interp`
/// exactly. `None` means "the interpreter would error (or the value is
/// not representable without changing behaviour)": no fold happens and
/// the runtime error is preserved.
fn eval_op(kind: &OpKind, vals: &[Val]) -> Option<Val> {
    use Val::{F, I};
    Some(match kind {
        OpKind::ConstI(c) => I(*c),
        OpKind::ConstF(c) => F(*c),
        OpKind::Add | OpKind::Sub | OpKind::Mul | OpKind::Div | OpKind::Min | OpKind::Max => {
            match (vals[0], vals[1]) {
                (I(a), I(b)) => I(match kind {
                    OpKind::Add => a.wrapping_add(b),
                    OpKind::Sub => a.wrapping_sub(b),
                    OpKind::Mul => a.wrapping_mul(b),
                    OpKind::Div => a.checked_div(b)?,
                    OpKind::Min => a.min(b),
                    OpKind::Max => a.max(b),
                    _ => unreachable!(),
                }),
                (F(a), F(b)) => F(match kind {
                    OpKind::Add => a + b,
                    OpKind::Sub => a - b,
                    OpKind::Mul => a * b,
                    OpKind::Div => a / b,
                    OpKind::Min => a.min(b),
                    OpKind::Max => a.max(b),
                    _ => unreachable!(),
                }),
                _ => return None, // mixed types: interpreter errors
            }
        }
        OpKind::Rem | OpKind::Shl | OpKind::Shr | OpKind::And | OpKind::Or | OpKind::Xor => {
            match (vals[0], vals[1]) {
                (I(a), I(b)) => I(match kind {
                    OpKind::Rem => a.checked_rem(b)?,
                    OpKind::Shl => a.wrapping_shl(b as u32),
                    OpKind::Shr => a.wrapping_shr(b as u32),
                    OpKind::And => a & b,
                    OpKind::Or => a | b,
                    OpKind::Xor => a ^ b,
                    _ => unreachable!(),
                }),
                _ => return None,
            }
        }
        OpKind::Neg => match vals[0] {
            I(a) => I(a.wrapping_neg()),
            F(a) => F(-a),
        },
        OpKind::Sqrt => match vals[0] {
            F(a) => F(a.sqrt()),
            _ => return None,
        },
        OpKind::Exp => match vals[0] {
            F(a) => F(a.exp()),
            _ => return None,
        },
        OpKind::Powi(e) => match vals[0] {
            F(a) => F(a.powi(*e as i32)),
            _ => return None,
        },
        OpKind::ToFloat => match vals[0] {
            I(a) => F(a as f64),
            _ => return None,
        },
        OpKind::ToInt => match vals[0] {
            F(a) => I(a as i64),
            _ => return None,
        },
        OpKind::Cmp(p) => {
            let ord = match (vals[0], vals[1]) {
                (I(a), I(b)) => a.cmp(&b),
                (F(a), F(b)) => a.partial_cmp(&b)?, // NaN: interp errors
                _ => return None,
            };
            use std::cmp::Ordering::*;
            let t = match p {
                CmpPred::Eq => ord == Equal,
                CmpPred::Ne => ord != Equal,
                CmpPred::Lt => ord == Less,
                CmpPred::Le => ord != Greater,
                CmpPred::Gt => ord == Greater,
                CmpPred::Ge => ord != Less,
            };
            I(t as i64)
        }
        OpKind::Select => match vals[0] {
            I(c) => {
                if c != 0 {
                    vals[1]
                } else {
                    vals[2]
                }
            }
            _ => return None,
        },
        _ => return None,
    })
}
