//! Loop-invariant code motion.
//!
//! Hoists pure, provably trap-free, non-memory ops out of `for` bodies
//! when every operand is defined outside the body (or by an op hoisted
//! just before it). Loads are never hoisted — a store elsewhere in the
//! body could change what they observe — and trapping ops are never
//! hoisted because executing them *before* the loop would reorder an
//! error relative to the anchors the loop has already run. Only ops
//! sitting directly in a loop body move; ops inside `if` arms stay put
//! (the arm may never execute, and leaving them is what keeps LICM and
//! the sink pass from endlessly undoing each other).
//!
//! Recursion visits inner loops first, so invariants cascade outward —
//! an op hoisted out of an inner loop lands in the outer body in time
//! for the outer loop's own scan in the same call.

use std::collections::HashSet;

use crate::ir::func::{Func, OpRef, Region, Value};
use crate::ir::ops::OpKind;
use crate::ir::passes::analysis::{can_trap, Analyses, Intervals};

/// Run LICM on `f`; returns the number of ops hoisted.
pub fn run(f: &mut Func, an: &mut Analyses) -> usize {
    if an.loops(f).loops.is_empty() {
        return 0; // loop-free: keep every cached analysis warm
    }
    let mut total = 0;
    loop {
        let iv = an.intervals(f).clone();
        let mut entry = std::mem::take(&mut f.entry);
        let n = hoist_region(f, &mut entry, &iv);
        f.entry = entry;
        if n == 0 {
            break;
        }
        total += n;
        an.invalidate();
    }
    total
}

fn hoist_region(f: &mut Func, region: &mut Region, iv: &Intervals) -> usize {
    let mut moved = 0;
    let mut new_ops: Vec<OpRef> = Vec::with_capacity(region.ops.len());
    for &opref in &region.ops {
        // Inner regions first: an inner loop's invariants surface into
        // this level before we scan it.
        let mut regs = std::mem::take(&mut f.op_mut(opref).regions);
        for r in &mut regs {
            moved += hoist_region(f, r, iv);
        }
        f.op_mut(opref).regions = regs;

        if matches!(f.op(opref).kind, OpKind::For) {
            let mut regs = std::mem::take(&mut f.op_mut(opref).regions);
            {
                let body = &mut regs[0];
                // Values defined at body level: the iv, carried params,
                // and every direct op's results.
                let mut body_defs: HashSet<Value> = body.params.iter().copied().collect();
                for &o in &body.ops {
                    body_defs.extend(f.op(o).results.iter().copied());
                }
                let mut hoisted: HashSet<Value> = HashSet::new();
                let mut kept: Vec<OpRef> = Vec::with_capacity(body.ops.len());
                for &o in &body.ops {
                    let op = f.op(o);
                    let invariant = op.regions.is_empty()
                        && !op.kind.is_anchor()
                        && !op.kind.touches_memory()
                        && !matches!(op.kind, OpKind::ReadIrf(_))
                        && !op.results.is_empty()
                        && !can_trap(f, op, iv)
                        && op
                            .operands
                            .iter()
                            .all(|v| !body_defs.contains(v) || hoisted.contains(v));
                    if invariant {
                        new_ops.push(o); // lands just before the `for`
                        hoisted.extend(f.op(o).results.iter().copied());
                        moved += 1;
                    } else {
                        kept.push(o);
                    }
                }
                body.ops = kept;
            }
            f.op_mut(opref).regions = regs;
        }
        new_ops.push(opref);
    }
    region.ops = new_ops;
    moved
}
