//! Dead code elimination.
//!
//! Removes ops whose results are never used, that have no side effects
//! (not [`crate::ir::ops::OpKind::is_anchor`]), no regions, and that
//! provably cannot trap ([`analysis::can_trap`]) — deleting an op that
//! could raise a runtime error would change the program's observable
//! error behaviour, which the differential harness treats as a
//! semantics break. Runs to a fixpoint so chains of dead ops unravel
//! completely. Anchors (`store`/`copy_issue`/`copy_wait`/control flow)
//! and ops feeding terminators are structurally protected: a terminator
//! use keeps its producer's use count non-zero.
//!
//! [`analysis::can_trap`]: crate::ir::passes::analysis::can_trap

use crate::ir::func::{Func, Region};
use crate::ir::passes::analysis::{can_trap, Analyses, DefUse, Intervals};

/// Run DCE on `f`; returns the number of ops removed.
pub fn run(f: &mut Func, an: &mut Analyses) -> usize {
    // Removing ops only ever shrinks the use-graph; value ranges never
    // widen, so one interval computation stays sound across rounds.
    let iv = an.intervals(f).clone();
    let mut removed = 0;
    loop {
        let du = an.defuse(f).clone();
        let mut entry = std::mem::take(&mut f.entry);
        let n = sweep_region(f, &mut entry, &du, &iv);
        f.entry = entry;
        if n == 0 {
            break;
        }
        removed += n;
        an.invalidate();
    }
    removed
}

fn sweep_region(f: &mut Func, region: &mut Region, du: &DefUse, iv: &Intervals) -> usize {
    let mut removed = 0;
    // Inner regions first, so inner removals surface as zero use counts
    // at this level on the next fixpoint round.
    for &opref in &region.ops {
        let mut regs = std::mem::take(&mut f.op_mut(opref).regions);
        for r in &mut regs {
            removed += sweep_region(f, r, du, iv);
        }
        f.op_mut(opref).regions = regs;
    }
    region.ops.retain(|&opref| {
        let op = f.op(opref);
        let dead = op.regions.is_empty()
            && !op.kind.is_anchor()
            && !op.results.is_empty()
            && op.results.iter().all(|&v| du.use_count(v) == 0)
            && !can_trap(f, op, iv);
        if dead {
            removed += 1;
        }
        !dead
    });
    removed
}
