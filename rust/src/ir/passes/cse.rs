//! Common subexpression elimination with memory versioning.
//!
//! Pure ops are keyed on `(kind, canonical operands)` in a scoped table
//! (each region sees its ancestors' entries, never its siblings'), so a
//! replacement always lexically precedes — and therefore dominates — the
//! duplicate it retires. Deduplicating a *trapping* op is still sound
//! under that discipline: the representative executes first on every
//! path that reaches the duplicate, so the program traps at the same
//! point with the same message either way.
//!
//! Loads are deduplicated too, keyed additionally on a per-buffer
//! version counter: every write to a buffer (store, transfer, copy,
//! `copy_issue` landing via `copy_wait`, intrinsic) bumps its version,
//! `for` bodies bump every buffer their subtree writes both before and
//! after the body (iteration `n+1` observes iteration `n`'s stores), and
//! `if` arms are versioned independently then merged. `read_irf` is
//! versioned the same way against `write_irf`. Commutative *integer*
//! ops sort their operands; float operands keep source order so IEEE
//! edge cases (`NaN` payloads, signed zero in `min`/`max`) are never
//! re-associated.

use std::collections::{HashMap, HashSet};

use crate::ir::func::{Func, OpRef, Region, Value};
use crate::ir::ops::{CmpPred, OpKind};
use crate::ir::passes::analysis::{Analyses, Dominance};
use crate::ir::types::Type;

/// Hash-cons key for a candidate op. `Load` carries the buffer's memory
/// version at the point of the load; `Irf` the irf version.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum Key {
    CI(i64),
    CF(u64),
    /// (op tag, lhs, rhs) — operands pre-sorted for commutative int ops.
    Bin(u8, Value, Value),
    Cmp(CmpPred, Value, Value),
    /// (op tag, operand) for unary ops.
    Un(u8, Value),
    Powi(u32, Value),
    Sel(Value, Value, Value),
    /// (load kind tag, interface id, buffer id, index, buffer version).
    Load(u8, u32, u32, Value, u64),
    /// (irf register, irf version).
    Irf(u8, u64),
}

/// What a subtree may write: the buffers it stores/copies into, whether
/// it writes the irf, and whether it clobbers everything (`copy_wait`
/// landing a DMA, or an intrinsic).
#[derive(Debug, Default)]
struct WriteSet {
    bufs: HashSet<u32>,
    irf: bool,
    all: bool,
}

struct Cse {
    /// Retired value -> replacement.
    map: HashMap<Value, Value>,
    versions: HashMap<u32, u64>,
    irf_version: u64,
    clock: u64,
    deduped: usize,
    nbufs: u32,
    dom: Dominance,
}

/// Run CSE on `f`; returns the number of ops deduplicated.
pub fn run(f: &mut Func, an: &mut Analyses) -> usize {
    let mut st = Cse {
        map: HashMap::new(),
        versions: HashMap::new(),
        irf_version: 0,
        clock: 0,
        deduped: 0,
        nbufs: f.buffers.len() as u32,
        dom: an.dominance(f).clone(),
    };
    let mut entry = std::mem::take(&mut f.entry);
    st.region(f, &mut entry, HashMap::new());
    f.entry = entry;
    f.replace_uses(&st.map);
    if st.deduped > 0 {
        an.invalidate();
    }
    st.deduped
}

impl Cse {
    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    fn version(&self, buf: u32) -> u64 {
        self.versions.get(&buf).copied().unwrap_or(0)
    }

    fn resolve(&self, mut v: Value) -> Value {
        let mut hops = 0;
        while let Some(&n) = self.map.get(&v) {
            v = n;
            hops += 1;
            if hops > self.map.len() {
                break;
            }
        }
        v
    }

    fn bump(&mut self, buf: u32) {
        let t = self.tick();
        self.versions.insert(buf, t);
    }

    fn bump_set(&mut self, w: &WriteSet) {
        if w.all {
            let t = self.tick();
            for b in 0..self.nbufs {
                self.versions.insert(b, t);
            }
        } else {
            for &b in &w.bufs {
                self.bump(b);
            }
        }
        if w.irf || w.all {
            self.irf_version = self.tick();
        }
    }

    /// Apply the write effect of a single (region-free) op.
    fn apply_write(&mut self, kind: &OpKind) {
        match kind {
            OpKind::Store(b) | OpKind::WriteSmem(b) => self.bump(b.0),
            OpKind::StoreItfc { buf, .. } => self.bump(buf.0),
            OpKind::Transfer { dst, .. }
            | OpKind::Copy { dst, .. }
            | OpKind::CopyIssue { dst, .. } => self.bump(dst.0),
            OpKind::WriteIrf(_) => self.irf_version = self.tick(),
            OpKind::CopyWait { .. } => {
                // The pending DMA lands now; we don't track which buffer
                // it targets, so clobber all of them.
                let w = WriteSet { bufs: HashSet::new(), irf: false, all: true };
                self.bump_set(&w);
            }
            OpKind::Intrinsic(_) => {
                let w = WriteSet { bufs: HashSet::new(), irf: true, all: true };
                self.bump_set(&w);
            }
            _ => {}
        }
    }

    fn region(&mut self, f: &mut Func, region: &mut Region, mut table: HashMap<Key, (Value, OpRef)>) {
        let mut kept: Vec<OpRef> = Vec::with_capacity(region.ops.len());
        for idx in 0..region.ops.len() {
            let opref = region.ops[idx];
            // Canonicalize operands through the replacement map so keys
            // compare over representatives.
            let operands: Vec<Value> = f
                .op(opref)
                .operands
                .iter()
                .map(|&v| self.resolve(v))
                .collect();
            f.op_mut(opref).operands = operands;

            let has_regions = !f.op(opref).regions.is_empty();
            if has_regions {
                match f.op(opref).kind {
                    OpKind::For => {
                        // The body re-executes: anything its subtree
                        // writes must look clobbered to loads inside the
                        // body (iteration n+1 sees iteration n's stores)
                        // and to loads after the loop.
                        let w = subtree_writes(f, opref);
                        self.bump_set(&w);
                        let mut regs = std::mem::take(&mut f.op_mut(opref).regions);
                        self.region(f, &mut regs[0], table.clone());
                        f.op_mut(opref).regions = regs;
                        self.bump_set(&w);
                    }
                    OpKind::If => {
                        // Each arm versions memory independently from
                        // the pre-if state; afterwards the union of both
                        // arms' writes is clobbered.
                        let saved = (self.versions.clone(), self.irf_version);
                        let mut regs = std::mem::take(&mut f.op_mut(opref).regions);
                        self.region(f, &mut regs[0], table.clone());
                        self.versions = saved.0.clone();
                        self.irf_version = saved.1;
                        self.region(f, &mut regs[1], table.clone());
                        self.versions = saved.0;
                        self.irf_version = saved.1;
                        f.op_mut(opref).regions = regs;
                        let w = subtree_writes(f, opref);
                        self.bump_set(&w);
                    }
                    _ => {
                        // No other region-bearing ops exist; if one ever
                        // does, recurse conservatively and clobber all.
                        let mut regs = std::mem::take(&mut f.op_mut(opref).regions);
                        for r in &mut regs {
                            self.region(f, r, table.clone());
                        }
                        f.op_mut(opref).regions = regs;
                        let w = WriteSet { bufs: HashSet::new(), irf: true, all: true };
                        self.bump_set(&w);
                    }
                }
                kept.push(opref);
                continue;
            }

            if let Some(key) = self.key_of(f, opref) {
                if let Some(&(rep, rep_op)) = table.get(&key) {
                    let dup = f.op(opref).results[0];
                    debug_assert!(
                        self.dom.dominates(rep_op, opref),
                        "CSE representative must dominate its duplicate"
                    );
                    let _ = rep_op;
                    self.map.insert(dup, rep);
                    self.deduped += 1;
                    continue; // drop the duplicate from the region
                }
                let res = f.op(opref).results[0];
                table.insert(key, (res, opref));
            }
            let kind = f.op(opref).kind.clone();
            self.apply_write(&kind);
            kept.push(opref);
        }
        region.ops = kept;
    }

    fn key_of(&self, f: &Func, opref: OpRef) -> Option<Key> {
        let op = f.op(opref);
        if !op.regions.is_empty() || op.results.len() != 1 {
            return None;
        }
        let o = &op.operands;
        let int2 = |a: Value, b: Value| {
            f.value_type(a) == Type::Int && f.value_type(b) == Type::Int
        };
        // Sort operands only for commutative *integer* ops.
        let comm = |tag: u8, a: Value, b: Value| {
            let (a, b) = if int2(a, b) && a > b { (b, a) } else { (a, b) };
            Key::Bin(tag, a, b)
        };
        Some(match &op.kind {
            OpKind::ConstI(c) => Key::CI(*c),
            OpKind::ConstF(c) => Key::CF(c.to_bits()),
            OpKind::Add => comm(0, o[0], o[1]),
            OpKind::Mul => comm(1, o[0], o[1]),
            OpKind::And => comm(2, o[0], o[1]),
            OpKind::Or => comm(3, o[0], o[1]),
            OpKind::Xor => comm(4, o[0], o[1]),
            OpKind::Min => comm(5, o[0], o[1]),
            OpKind::Max => comm(6, o[0], o[1]),
            OpKind::Sub => Key::Bin(7, o[0], o[1]),
            OpKind::Div => Key::Bin(8, o[0], o[1]),
            OpKind::Rem => Key::Bin(9, o[0], o[1]),
            OpKind::Shl => Key::Bin(10, o[0], o[1]),
            OpKind::Shr => Key::Bin(11, o[0], o[1]),
            OpKind::Cmp(p) => Key::Cmp(*p, o[0], o[1]),
            OpKind::Neg => Key::Un(0, o[0]),
            OpKind::Sqrt => Key::Un(1, o[0]),
            OpKind::Exp => Key::Un(2, o[0]),
            OpKind::ToFloat => Key::Un(3, o[0]),
            OpKind::ToInt => Key::Un(4, o[0]),
            OpKind::Powi(e) => Key::Powi(*e, o[0]),
            OpKind::Select => Key::Sel(o[0], o[1], o[2]),
            OpKind::Load(b) => Key::Load(0, 0, b.0, o[0], self.version(b.0)),
            OpKind::Fetch(b) => Key::Load(1, 0, b.0, o[0], self.version(b.0)),
            OpKind::ReadSmem(b) => Key::Load(2, 0, b.0, o[0], self.version(b.0)),
            OpKind::LoadItfc { itfc, buf } => {
                Key::Load(3, itfc.0 as u32, buf.0, o[0], self.version(buf.0))
            }
            OpKind::ReadIrf(r) => Key::Irf(*r, self.irf_version),
            _ => return None,
        })
    }
}

/// Everything the subtree rooted at `opref` may write.
fn subtree_writes(f: &Func, opref: OpRef) -> WriteSet {
    let mut w = WriteSet::default();
    collect(f, opref, &mut w);
    return w;

    fn collect(f: &Func, opref: OpRef, w: &mut WriteSet) {
        let op = f.op(opref);
        match &op.kind {
            OpKind::Store(b) | OpKind::WriteSmem(b) => {
                w.bufs.insert(b.0);
            }
            OpKind::StoreItfc { buf, .. } => {
                w.bufs.insert(buf.0);
            }
            OpKind::Transfer { dst, .. }
            | OpKind::Copy { dst, .. }
            | OpKind::CopyIssue { dst, .. } => {
                w.bufs.insert(dst.0);
            }
            OpKind::WriteIrf(_) => w.irf = true,
            OpKind::CopyWait { .. } => w.all = true,
            OpKind::Intrinsic(_) => {
                w.all = true;
                w.irf = true;
            }
            _ => {}
        }
        for r in &op.regions {
            for &o in &r.ops {
                collect(f, o, w);
            }
        }
    }
}
