//! Register-bytecode VM for Aquas-IR: compile a [`Func`] once, execute
//! many times at near-native speed.
//!
//! The tree-walking reference interpreter ([`crate::ir::interp`])
//! re-dispatches on `OpKind` per executed op and keeps SSA values in a
//! `HashMap<Value, Val>`. That is the right shape for an oracle, but it
//! bounds every interp-backed workload (differential tests, proptests,
//! interp-driven serving validation) at tens of nanoseconds *per op per
//! iteration*. This module pays the analysis cost once per function
//! instead:
//!
//! - **Dense typed register files.** One `i64` and one `f64` slot per SSA
//!   value (value ids are already dense), indexed directly — no hashing,
//!   no enum tag. Every instruction is monomorphized to its operand type
//!   at compile time (`BinI` vs `BinF`, `LoadI` vs `LoadF`, …); the
//!   tree-walker's runtime "mixed types" dispatch becomes a compile-time
//!   check.
//! - **Constants folded at compile time.** `const.i`/`const.f` ops emit
//!   no instructions at all: they are preloaded into the register image
//!   before execution, so a constant inside a hot loop costs nothing per
//!   iteration.
//! - **Structured control flow lowered to branch targets.** `for` becomes
//!   head-check / body / increment / back-edge; `if` becomes a
//!   conditional branch over two straight-line arms. Loop-carried values
//!   are parallel-moved through scratch registers on the back edge.
//! - **Bulk memory ops.** `transfer`/`copy`/`copy_issue`+`copy_wait`
//!   lower to the same `checked_copy` slice operation the tree-walker
//!   uses (one call per transfer, not one tagged element move per word),
//!   charging identical [`ExecStats`].
//!
//! Semantics are *bit-identical* to the tree-walker by construction: both
//! engines share [`Memory`]'s typed arena and the transfer helper, float
//! math is `f64` in both, int math wraps in both, and every error string
//! and stats increment is mirrored (including order relative to the
//! failure point). `rust/tests/vm_diff.rs` fuzzes this equivalence with
//! seeded random programs and `cargo bench --bench interp -- --check`
//! gates it over every AOT kernel in CI.
//!
//! Traced execution (cache-model traces) stays on the tree-walker: the
//! VM's [`run_traced`] delegates whenever a live trace sink is passed.

// Panic-free audit (robustness): malformed IR must surface as `Error`,
// never abort the process. Test code is exempt (see the tests module).
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::interface::dmasim::IssueClock;
use crate::interface::latency::TransactionKind;
use crate::interface::model::{InterfaceId, InterfaceSet};
use crate::ir::func::{BufferId, Func, Region};
use crate::ir::interp::{checked_copy, ExecStats, Fuel, MemAccess, Memory, Val};
use crate::ir::ops::{CmpPred, OpKind};
use crate::ir::types::Type;
use crate::runtime::DType;

/// Integer binary opcodes (operate on the `i64` register file).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum IBin {
    Add,
    Sub,
    Mul,
    Div,
    Rem,
    Shl,
    Shr,
    And,
    Or,
    Xor,
    Min,
    Max,
}

/// Float binary opcodes (operate on the `f64` register file).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FBin {
    Add,
    Sub,
    Mul,
    Div,
    Min,
    Max,
}

/// One bytecode instruction. Registers are `u32` indices into the typed
/// register files; buffer ids / lengths are resolved at compile time.
#[derive(Debug, Clone)]
enum Insn {
    BinI { op: IBin, d: u32, a: u32, b: u32 },
    BinF { op: FBin, d: u32, a: u32, b: u32 },
    CmpI { pred: CmpPred, d: u32, a: u32, b: u32 },
    CmpF { pred: CmpPred, d: u32, a: u32, b: u32 },
    SelI { d: u32, c: u32, a: u32, b: u32 },
    SelF { d: u32, c: u32, a: u32, b: u32 },
    NegI { d: u32, a: u32 },
    NegF { d: u32, a: u32 },
    Sqrt { d: u32, a: u32 },
    Exp { d: u32, a: u32 },
    Powi { d: u32, a: u32, e: u32 },
    ToFloat { d: u32, a: u32 },
    ToInt { d: u32, a: u32 },
    MovI { d: u32, a: u32 },
    MovF { d: u32, a: u32 },
    LoadF { d: u32, idx: u32, buf: u32, len: u32 },
    LoadI { d: u32, idx: u32, buf: u32, len: u32 },
    StoreF { idx: u32, val: u32, buf: u32, len: u32 },
    StoreI { idx: u32, val: u32, buf: u32, len: u32 },
    ReadIrf { d: u32, r: u8 },
    WriteIrf { a: u32, r: u8 },
    Copy { dst: u32, src: u32, d_off: u32, s_off: u32, size: u32, dlen: u32, slen: u32 },
    /// Temporal-level `copy_issue`: stages the transfer under `tag` and
    /// charges its simulated §4.1 completion cycle (`itfc`/`kind` feed
    /// the DMA clock — timing only, data moves at the matching `Wait`).
    Issue {
        dst: u32,
        src: u32,
        d_off: u32,
        s_off: u32,
        size: u32,
        dlen: u32,
        slen: u32,
        tag: u32,
        itfc: u32,
        kind: TransactionKind,
    },
    Wait { tag: u32 },
    /// `for` prologue: error on non-positive step (before the first
    /// head check, matching the tree-walker's evaluation order).
    StepCheck { step: u32 },
    /// `for` head: fall through into an iteration (counting it) while
    /// `iv < ub`, else jump to `exit`.
    ForHead { iv: u32, ub: u32, exit: u32 },
    /// `iv += step` on the back edge (loop machinery: no stats).
    IvInc { iv: u32, step: u32 },
    Jump { pc: u32 },
    /// `if` dispatch: counts one branch, falls through when the condition
    /// register is non-zero, jumps to `else_pc` otherwise.
    Branch { c: u32, else_pc: u32 },
    /// An unlowered ISAX intrinsic: counts the call, then errors exactly
    /// like the tree-walker (`name` indexes the compiled name table).
    Intrinsic { name: u32 },
    Halt,
}

/// An issued-but-not-awaited bulk copy (temporal level).
#[derive(Debug, Clone, Copy)]
struct VmPending {
    dst: u32,
    src: u32,
    d_off: i64,
    s_off: i64,
    size: u32,
    dlen: u32,
    slen: u32,
}

/// A function compiled to register bytecode. Create once with
/// [`compile`], execute many times with [`CompiledFunc::run`] /
/// [`CompiledFunc::run_with_stats`] — executions are independent (fresh
/// register files each call) and `&self`, so a compiled kernel can be
/// replayed concurrently.
#[derive(Debug, Clone)]
pub struct CompiledFunc {
    name: String,
    insns: Vec<Insn>,
    /// Register-file size (SSA values + compiler temporaries).
    n_regs: u32,
    /// Constant register image, applied before execution.
    init_i: Vec<(u32, i64)>,
    init_f: Vec<(u32, f64)>,
    /// Parameter registers in declaration order.
    params: Vec<(u32, Type)>,
    /// Return-value registers, filled by the entry terminator.
    ret: Vec<(u32, Type)>,
    /// Interface set DMA issues are priced against; `None` binds the
    /// default §6.1 Rocket pair lazily (see [`CompiledFunc::with_itfcs`]).
    itfcs: Option<InterfaceSet>,
    /// Intrinsic name table (referenced by `Insn::Intrinsic`).
    intrinsics: Vec<String>,
}

/// Compile `func` into register bytecode. Fails (with a tree-walker-style
/// diagnostic) on IR the typed register machine cannot host: mixed-type
/// arithmetic, float indices, region terminators missing or with the
/// wrong arity — programs on which the tree-walker would error at
/// runtime anyway. Two deliberate tightenings over the walker: ill-typed
/// ops are rejected even when control flow would never reach them, and
/// scalar args whose `Val` variant does not match the declared param
/// type are rejected at call time (the walker inserts the mismatched
/// value and only faults if an op actually consumes it).
pub fn compile(func: &Func) -> Result<CompiledFunc> {
    let mut c = Compiler {
        func,
        insns: Vec::new(),
        n_regs: func.num_values() as u32,
        init_i: Vec::new(),
        init_f: Vec::new(),
        ret: Vec::new(),
        intrinsics: Vec::new(),
    };
    let sink = TermSink::Return;
    c.region(&func.entry, &sink)?;
    c.insns.push(Insn::Halt);
    Ok(CompiledFunc {
        name: func.name.clone(),
        insns: c.insns,
        n_regs: c.n_regs,
        init_i: c.init_i,
        init_f: c.init_f,
        params: func.params.iter().map(|&p| (p.0, func.value_type(p))).collect(),
        ret: c.ret,
        intrinsics: c.intrinsics,
        itfcs: None,
    })
}

/// Compile + execute in one call (the tree-walker-compatible surface).
pub fn run(func: &Func, args: &[Val], mem: &mut Memory) -> Result<Vec<Val>> {
    let mut stats = ExecStats::default();
    run_with_stats(func, args, mem, &mut stats)
}

/// Compile + execute, collecting [`ExecStats`].
pub fn run_with_stats(
    func: &Func,
    args: &[Val],
    mem: &mut Memory,
    stats: &mut ExecStats,
) -> Result<Vec<Val>> {
    compile(func)?.run_with_stats(args, mem, stats)
}

/// Compile + execute under a [`Fuel`] budget — the VM counterpart of
/// [`crate::ir::interp::run_fueled`], exhausting at the identical event
/// with identical partial stats and memory image. Compilation itself is
/// not metered (it is bounded by the function size, not by execution).
pub fn run_fueled(
    func: &Func,
    args: &[Val],
    mem: &mut Memory,
    stats: &mut ExecStats,
    fuel: &mut Fuel,
) -> Result<Vec<Val>> {
    compile(func)?.run_fueled(args, mem, stats, fuel)
}

/// Compile + execute with DMA issues priced against a *specific*
/// [`InterfaceSet`] instead of the default §6.1 Rocket pair — the VM
/// counterpart of [`crate::ir::interp::run_with_itfcs`], bit-identical
/// to it on the same program, inputs and set.
pub fn run_with_itfcs(
    func: &Func,
    args: &[Val],
    mem: &mut Memory,
    stats: &mut ExecStats,
    itfcs: &InterfaceSet,
) -> Result<Vec<Val>> {
    compile(func)?.with_itfcs(itfcs.clone()).run_with_stats(args, mem, stats)
}

/// Traced surface: a live trace sink needs per-access callbacks the
/// bytecode deliberately elides, so tracing falls back to the
/// tree-walking oracle; without a sink this is the compiled fast path.
pub fn run_traced(
    func: &Func,
    args: &[Val],
    mem: &mut Memory,
    stats: &mut ExecStats,
    trace: &mut Option<Vec<MemAccess>>,
) -> Result<Vec<Val>> {
    if trace.is_some() {
        crate::ir::interp::run_traced(func, args, mem, stats, trace)
    } else {
        run_with_stats(func, args, mem, stats)
    }
}

// ---------------------------------------------------------------------------
// Compilation
// ---------------------------------------------------------------------------

/// What a region terminator feeds.
enum TermSink {
    /// Entry region: operands become the function's return values.
    Return,
    /// `for` body: operands parallel-move through `temps` into the
    /// carried registers on the back edge.
    Loop { temps: Vec<u32>, carried: Vec<u32>, tys: Vec<Type> },
    /// `if` arm: operands move straight into the result registers.
    Arm { dests: Vec<u32>, tys: Vec<Type> },
}

struct Compiler<'a> {
    func: &'a Func,
    insns: Vec<Insn>,
    n_regs: u32,
    init_i: Vec<(u32, i64)>,
    init_f: Vec<(u32, f64)>,
    ret: Vec<(u32, Type)>,
    intrinsics: Vec<String>,
}

impl<'a> Compiler<'a> {
    fn temp(&mut self) -> u32 {
        let r = self.n_regs;
        self.n_regs += 1;
        r
    }

    fn ty(&self, v: crate::ir::func::Value) -> Type {
        self.func.value_type(v)
    }

    fn want(&self, v: crate::ir::func::Value, ty: Type, what: &str) -> Result<u32> {
        if self.ty(v) != ty {
            return Err(Error::Ir(format!(
                "vm compile: {what} expects {ty} operand, got {} ({v})",
                self.ty(v)
            )));
        }
        Ok(v.0)
    }

    fn mov(&mut self, ty: Type, d: u32, a: u32) -> Result<()> {
        match ty {
            Type::Int => self.insns.push(Insn::MovI { d, a }),
            Type::Float => self.insns.push(Insn::MovF { d, a }),
            Type::None => {
                return Err(Error::Ir("vm compile: cannot move a none-typed value".into()))
            }
        }
        Ok(())
    }

    /// Compile a region into the instruction stream; returns whether a
    /// terminator (Yield/Return) was reached. Ops after the terminator
    /// are unreachable in the tree-walker and are not compiled.
    fn region(&mut self, region: &Region, sink: &TermSink) -> Result<bool> {
        // Copy the `&'a Func` out of `self` so op borrows are independent
        // of the `&mut self` emission calls (no per-op cloning).
        let func = self.func;
        for &opref in &region.ops {
            let op = func.op(opref);
            match &op.kind {
                OpKind::Yield | OpKind::Return => {
                    self.terminator(&op.operands, sink)?;
                    return Ok(true);
                }
                _ => self.op(op)?,
            }
        }
        match sink {
            TermSink::Return => Ok(false),
            TermSink::Loop { .. } => Err(Error::Ir("for body missing yield".into())),
            TermSink::Arm { .. } => Err(Error::Ir("if arm missing yield".into())),
        }
    }

    fn terminator(&mut self, operands: &[crate::ir::func::Value], sink: &TermSink) -> Result<()> {
        match sink {
            TermSink::Return => {
                let mut ret = Vec::with_capacity(operands.len());
                for &v in operands {
                    let ty = self.ty(v);
                    let t = self.temp();
                    self.mov(ty, t, v.0)?;
                    ret.push((t, ty));
                }
                self.ret = ret;
                self.insns.push(Insn::Halt);
                Ok(())
            }
            TermSink::Loop { temps, carried, tys } => {
                if operands.len() != carried.len() {
                    return Err(Error::Ir("for: yield arity != iter_args arity".into()));
                }
                for i in 0..operands.len() {
                    let v = operands[i];
                    if self.ty(v) != tys[i] {
                        return Err(Error::Ir(format!(
                            "vm compile: for yield value {v} type {} != carried type {}",
                            self.ty(v),
                            tys[i]
                        )));
                    }
                    self.mov(tys[i], temps[i], v.0)?;
                }
                for i in 0..carried.len() {
                    self.mov(tys[i], carried[i], temps[i])?;
                }
                Ok(())
            }
            TermSink::Arm { dests, tys } => {
                if operands.len() != dests.len() {
                    return Err(Error::Ir("if: arm yield arity mismatch".into()));
                }
                for i in 0..operands.len() {
                    let v = operands[i];
                    if self.ty(v) != tys[i] {
                        return Err(Error::Ir(format!(
                            "vm compile: if yield value {v} type {} != result type {}",
                            self.ty(v),
                            tys[i]
                        )));
                    }
                    self.mov(tys[i], dests[i], v.0)?;
                }
                Ok(())
            }
        }
    }

    fn buf_len(&self, b: BufferId) -> u32 {
        self.func.buffer(b).len as u32
    }

    fn buf_elem(&self, b: BufferId) -> Type {
        match self.func.buffer(b).elem {
            DType::F32 => Type::Float,
            DType::I32 => Type::Int,
        }
    }

    /// Emit the instruction(s) for one non-terminator op.
    fn op(&mut self, op: &crate::ir::ops::Op) -> Result<()> {
        let kind = &op.kind;
        match kind {
            OpKind::ConstI(c) => {
                self.init_i.push((op.results[0].0, *c));
            }
            OpKind::ConstF(c) => {
                self.init_f.push((op.results[0].0, *c));
            }
            OpKind::Add | OpKind::Sub | OpKind::Mul | OpKind::Div | OpKind::Min | OpKind::Max => {
                let (a, b, d) = (op.operands[0], op.operands[1], op.results[0]);
                let ta = self.ty(a);
                if ta != self.ty(b) || ta != self.ty(d) {
                    return Err(Error::Ir(format!("{}: mixed types", kind.mnemonic())));
                }
                match ta {
                    Type::Int => {
                        let iop = match kind {
                            OpKind::Add => IBin::Add,
                            OpKind::Sub => IBin::Sub,
                            OpKind::Mul => IBin::Mul,
                            OpKind::Div => IBin::Div,
                            OpKind::Min => IBin::Min,
                            OpKind::Max => IBin::Max,
                            _ => unreachable!(),
                        };
                        self.insns.push(Insn::BinI { op: iop, d: d.0, a: a.0, b: b.0 });
                    }
                    Type::Float => {
                        let fop = match kind {
                            OpKind::Add => FBin::Add,
                            OpKind::Sub => FBin::Sub,
                            OpKind::Mul => FBin::Mul,
                            OpKind::Div => FBin::Div,
                            OpKind::Min => FBin::Min,
                            OpKind::Max => FBin::Max,
                            _ => unreachable!(),
                        };
                        self.insns.push(Insn::BinF { op: fop, d: d.0, a: a.0, b: b.0 });
                    }
                    Type::None => {
                        return Err(Error::Ir(format!("{}: none-typed operand", kind.mnemonic())))
                    }
                }
            }
            OpKind::Rem | OpKind::Shl | OpKind::Shr | OpKind::And | OpKind::Or | OpKind::Xor => {
                let a = self.want(op.operands[0], Type::Int, kind.mnemonic())?;
                let b = self.want(op.operands[1], Type::Int, kind.mnemonic())?;
                let d = self.want(op.results[0], Type::Int, kind.mnemonic())?;
                let iop = match kind {
                    OpKind::Rem => IBin::Rem,
                    OpKind::Shl => IBin::Shl,
                    OpKind::Shr => IBin::Shr,
                    OpKind::And => IBin::And,
                    OpKind::Or => IBin::Or,
                    OpKind::Xor => IBin::Xor,
                    _ => unreachable!(),
                };
                self.insns.push(Insn::BinI { op: iop, d, a, b });
            }
            OpKind::Neg => {
                let a = op.operands[0];
                let d = op.results[0];
                match self.ty(a) {
                    Type::Int => self.insns.push(Insn::NegI { d: d.0, a: a.0 }),
                    Type::Float => self.insns.push(Insn::NegF { d: d.0, a: a.0 }),
                    Type::None => return Err(Error::Ir("neg: none-typed operand".into())),
                }
            }
            OpKind::Sqrt => {
                let a = self.want(op.operands[0], Type::Float, "sqrt")?;
                self.insns.push(Insn::Sqrt { d: op.results[0].0, a });
            }
            OpKind::Exp => {
                let a = self.want(op.operands[0], Type::Float, "exp")?;
                self.insns.push(Insn::Exp { d: op.results[0].0, a });
            }
            OpKind::Powi(e) => {
                let a = self.want(op.operands[0], Type::Float, "powi")?;
                self.insns.push(Insn::Powi { d: op.results[0].0, a, e: *e });
            }
            OpKind::ToFloat => {
                let a = self.want(op.operands[0], Type::Int, "to_float")?;
                self.insns.push(Insn::ToFloat { d: op.results[0].0, a });
            }
            OpKind::ToInt => {
                let a = self.want(op.operands[0], Type::Float, "to_int")?;
                self.insns.push(Insn::ToInt { d: op.results[0].0, a });
            }
            OpKind::Cmp(pred) => {
                let (a, b, d) = (op.operands[0], op.operands[1], op.results[0]);
                if self.ty(a) != self.ty(b) {
                    return Err(Error::Ir("cmp: mixed types".into()));
                }
                match self.ty(a) {
                    Type::Int => {
                        self.insns.push(Insn::CmpI { pred: *pred, d: d.0, a: a.0, b: b.0 })
                    }
                    Type::Float => {
                        self.insns.push(Insn::CmpF { pred: *pred, d: d.0, a: a.0, b: b.0 })
                    }
                    Type::None => return Err(Error::Ir("cmp: none-typed operand".into())),
                }
            }
            OpKind::Select => {
                let c = self.want(op.operands[0], Type::Int, "select")?;
                let (a, b, d) = (op.operands[1], op.operands[2], op.results[0]);
                let ta = self.ty(a);
                if ta != self.ty(b) || ta != self.ty(d) {
                    return Err(Error::Ir("select: mixed types".into()));
                }
                match ta {
                    Type::Int => self.insns.push(Insn::SelI { d: d.0, c, a: a.0, b: b.0 }),
                    Type::Float => self.insns.push(Insn::SelF { d: d.0, c, a: a.0, b: b.0 }),
                    Type::None => return Err(Error::Ir("select: none-typed operand".into())),
                }
            }
            OpKind::Load(b) | OpKind::Fetch(b) | OpKind::ReadSmem(b) => {
                self.load(*b, op, kind.mnemonic())?;
            }
            OpKind::LoadItfc { buf, .. } => {
                self.load(*buf, op, kind.mnemonic())?;
            }
            OpKind::Store(b) | OpKind::WriteSmem(b) => {
                self.store(*b, op, kind.mnemonic())?;
            }
            OpKind::StoreItfc { buf, .. } => {
                self.store(*buf, op, kind.mnemonic())?;
            }
            OpKind::ReadIrf(r) => {
                let d = self.want(op.results[0], Type::Int, "read_irf")?;
                self.insns.push(Insn::ReadIrf { d, r: *r });
            }
            OpKind::WriteIrf(r) => {
                let a = self.want(op.operands[0], Type::Int, "write_irf")?;
                self.insns.push(Insn::WriteIrf { a, r: *r });
            }
            OpKind::Transfer { dst, src, size } | OpKind::Copy { dst, src, size, .. } => {
                let d_off = self.want(op.operands[0], Type::Int, "transfer offset")?;
                let s_off = self.want(op.operands[1], Type::Int, "transfer offset")?;
                self.insns.push(Insn::Copy {
                    dst: dst.0,
                    src: src.0,
                    d_off,
                    s_off,
                    size: *size as u32,
                    dlen: self.buf_len(*dst),
                    slen: self.buf_len(*src),
                });
            }
            OpKind::CopyIssue { dst, src, size, tag, itfc, kind, .. } => {
                let d_off = self.want(op.operands[0], Type::Int, "copy_issue offset")?;
                let s_off = self.want(op.operands[1], Type::Int, "copy_issue offset")?;
                self.insns.push(Insn::Issue {
                    dst: dst.0,
                    src: src.0,
                    d_off,
                    s_off,
                    size: *size as u32,
                    dlen: self.buf_len(*dst),
                    slen: self.buf_len(*src),
                    tag: *tag,
                    itfc: itfc.0 as u32,
                    kind: *kind,
                });
            }
            OpKind::CopyWait { tag } => {
                self.insns.push(Insn::Wait { tag: *tag });
            }
            OpKind::For => self.for_op(op)?,
            OpKind::If => self.if_op(op)?,
            OpKind::Yield | OpKind::Return => unreachable!("handled by region()"),
            OpKind::Intrinsic(name) => {
                let idx = self.intrinsics.len() as u32;
                self.intrinsics.push(name.clone());
                self.insns.push(Insn::Intrinsic { name: idx });
            }
        }
        Ok(())
    }

    fn load(&mut self, b: BufferId, op: &crate::ir::ops::Op, what: &str) -> Result<()> {
        let idx = self.want(op.operands[0], Type::Int, what)?;
        let d = op.results[0];
        let elem = self.buf_elem(b);
        if self.ty(d) != elem {
            return Err(Error::Ir(format!(
                "vm compile: {what} result {d} type {} != buffer elem {elem}",
                self.ty(d)
            )));
        }
        let len = self.buf_len(b);
        match elem {
            Type::Float => self.insns.push(Insn::LoadF { d: d.0, idx, buf: b.0, len }),
            _ => self.insns.push(Insn::LoadI { d: d.0, idx, buf: b.0, len }),
        }
        Ok(())
    }

    fn store(&mut self, b: BufferId, op: &crate::ir::ops::Op, what: &str) -> Result<()> {
        let idx = self.want(op.operands[0], Type::Int, what)?;
        let v = op.operands[1];
        let elem = self.buf_elem(b);
        let len = self.buf_len(b);
        // The arena coerces on store; mirror that with an explicit cast
        // into a temp when the value's type differs from the element.
        let val = match (elem, self.ty(v)) {
            (Type::Float, Type::Float) | (Type::Int, Type::Int) => v.0,
            (Type::Float, Type::Int) => {
                let t = self.temp();
                self.insns.push(Insn::ToFloat { d: t, a: v.0 });
                t
            }
            (Type::Int, Type::Float) => {
                let t = self.temp();
                self.insns.push(Insn::ToInt { d: t, a: v.0 });
                t
            }
            _ => return Err(Error::Ir(format!("vm compile: {what} of none-typed value"))),
        };
        match elem {
            Type::Float => self.insns.push(Insn::StoreF { idx, val, buf: b.0, len }),
            _ => self.insns.push(Insn::StoreI { idx, val, buf: b.0, len }),
        }
        Ok(())
    }

    fn for_op(&mut self, op: &crate::ir::ops::Op) -> Result<()> {
        let lb = self.want(op.operands[0], Type::Int, "for bound")?;
        let ub = self.want(op.operands[1], Type::Int, "for bound")?;
        let step = self.want(op.operands[2], Type::Int, "for step")?;
        let region = &op.regions[0];
        let iv = self.want(region.params[0], Type::Int, "for iv")?;
        let carried_vals = &region.params[1..];
        let inits = &op.operands[3..];
        if carried_vals.len() != inits.len() {
            return Err(Error::Ir("for: iter_args arity != region carried params".into()));
        }
        if op.results.len() != carried_vals.len() {
            return Err(Error::Ir("for results != carried count".into()));
        }
        self.insns.push(Insn::StepCheck { step });
        let mut tys = Vec::with_capacity(carried_vals.len());
        let mut carried = Vec::with_capacity(carried_vals.len());
        for (&cv, &init) in carried_vals.iter().zip(inits) {
            let ty = self.ty(cv);
            if ty != self.ty(init) {
                return Err(Error::Ir(format!(
                    "vm compile: for init {init} type {} != carried {cv} type {ty}",
                    self.ty(init)
                )));
            }
            self.mov(ty, cv.0, init.0)?;
            tys.push(ty);
            carried.push(cv.0);
        }
        self.insns.push(Insn::MovI { d: iv, a: lb });
        let head = self.insns.len();
        self.insns.push(Insn::ForHead { iv, ub, exit: 0 });
        let temps: Vec<u32> = (0..carried.len()).map(|_| self.temp()).collect();
        let sink = TermSink::Loop { temps, carried: carried.clone(), tys: tys.clone() };
        self.region(region, &sink)?;
        self.insns.push(Insn::IvInc { iv, step });
        self.insns.push(Insn::Jump { pc: head as u32 });
        let exit = self.insns.len() as u32;
        if let Insn::ForHead { exit: e, .. } = &mut self.insns[head] {
            *e = exit;
        }
        for (i, &r) in op.results.iter().enumerate() {
            if self.ty(r) != tys[i] {
                return Err(Error::Ir(format!(
                    "vm compile: for result {r} type {} != carried type {}",
                    self.ty(r),
                    tys[i]
                )));
            }
            self.mov(tys[i], r.0, carried[i])?;
        }
        Ok(())
    }

    fn if_op(&mut self, op: &crate::ir::ops::Op) -> Result<()> {
        let c = self.want(op.operands[0], Type::Int, "if condition")?;
        let dests: Vec<u32> = op.results.iter().map(|r| r.0).collect();
        let tys: Vec<Type> = op.results.iter().map(|&r| self.ty(r)).collect();
        let branch_at = self.insns.len();
        self.insns.push(Insn::Branch { c, else_pc: 0 });
        let sink = TermSink::Arm { dests: dests.clone(), tys: tys.clone() };
        self.region(&op.regions[0], &sink)?;
        let jump_at = self.insns.len();
        self.insns.push(Insn::Jump { pc: 0 });
        let else_pc = self.insns.len() as u32;
        if let Insn::Branch { else_pc: e, .. } = &mut self.insns[branch_at] {
            *e = else_pc;
        }
        self.region(&op.regions[1], &sink)?;
        let end = self.insns.len() as u32;
        if let Insn::Jump { pc } = &mut self.insns[jump_at] {
            *pc = end;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Execution
// ---------------------------------------------------------------------------

impl CompiledFunc {
    /// Function name (diagnostics).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Number of bytecode instructions.
    pub fn num_insns(&self) -> usize {
        self.insns.len()
    }

    /// Register-file size (SSA values + compiler temporaries).
    pub fn num_regs(&self) -> usize {
        self.n_regs as usize
    }

    /// Execute against `mem`; returns the function's `return` values.
    pub fn run(&self, args: &[Val], mem: &mut Memory) -> Result<Vec<Val>> {
        let mut stats = ExecStats::default();
        self.run_with_stats(args, mem, &mut stats)
    }

    /// Bind the interface set DMA issues are priced against (replacing
    /// the default §6.1 Rocket pair). Timing-only: functional results
    /// are unaffected; ids beyond the set become hard errors.
    pub fn with_itfcs(mut self, itfcs: InterfaceSet) -> Self {
        self.itfcs = Some(itfcs);
        self
    }

    /// Execute and collect [`ExecStats`] — identical counts to the
    /// tree-walking interpreter on the same program and inputs.
    pub fn run_with_stats(
        &self,
        args: &[Val],
        mem: &mut Memory,
        stats: &mut ExecStats,
    ) -> Result<Vec<Val>> {
        let mut fuel = Fuel::unlimited();
        self.run_fueled(args, mem, stats, &mut fuel)
    }

    /// Execute under a [`Fuel`] budget. Charges mirror the tree-walker's
    /// event-for-event ([`crate::ir::interp::run_fueled`]): arithmetic,
    /// memory, transfer and control events cost 1 (`powi` costs its
    /// exponent; `copy_issue` adds its DMA beat count), while pure VM
    /// machinery — const preloads, moves, coercion casts, jumps, the
    /// step check — is free, so both engines exhaust at the identical
    /// event with identical partial stats and memory. With
    /// [`Fuel::unlimited`] the budget check never fires and this is
    /// bitwise identical to [`run_with_stats`](Self::run_with_stats).
    pub fn run_fueled(
        &self,
        args: &[Val],
        mem: &mut Memory,
        stats: &mut ExecStats,
        fuel: &mut Fuel,
    ) -> Result<Vec<Val>> {
        if args.len() != self.params.len() {
            return Err(Error::Ir(format!(
                "expected {} args, got {}",
                self.params.len(),
                args.len()
            )));
        }
        let mut ri = vec![0i64; self.n_regs as usize];
        let mut rf = vec![0f64; self.n_regs as usize];
        for &(r, v) in &self.init_i {
            ri[r as usize] = v;
        }
        for &(r, v) in &self.init_f {
            rf[r as usize] = v;
        }
        for (&(r, ty), a) in self.params.iter().zip(args) {
            match (ty, a) {
                (Type::Int, Val::I(v)) => ri[r as usize] = *v,
                (Type::Float, Val::F(v)) => rf[r as usize] = *v,
                (_, other) => {
                    return Err(Error::Ir(format!(
                        "vm: arg {other:?} does not match declared param type {ty}"
                    )))
                }
            }
        }
        let mut pending: HashMap<u32, VmPending> = HashMap::new();
        // DMA clock: pre-bound when the compiled function carries an
        // interface set, otherwise lazily built on first issue (mirrors
        // the tree-walker bit-for-bit in both modes).
        let mut dma: Option<IssueClock> =
            self.itfcs.as_ref().map(|s| IssueClock::new(s.clone()));

        let oob = |i: i64, len: u32| {
            Error::Ir(format!("index {i} out of bounds (len {len})", len = len as usize))
        };

        let mut pc = 0usize;
        loop {
            match &self.insns[pc] {
                Insn::BinI { op, d, a, b } => {
                    fuel.charge(1)?;
                    stats.arith_ops += 1;
                    let x = ri[*a as usize];
                    let y = ri[*b as usize];
                    ri[*d as usize] = match op {
                        IBin::Add => x.wrapping_add(y),
                        IBin::Sub => x.wrapping_sub(y),
                        IBin::Mul => x.wrapping_mul(y),
                        IBin::Div => {
                            if y == 0 {
                                return Err(Error::Ir("division by zero".into()));
                            }
                            // Wrapping, mirroring the tree-walker:
                            // `i64::MIN / -1` must not overflow-panic.
                            x.wrapping_div(y)
                        }
                        IBin::Rem => {
                            if y == 0 {
                                return Err(Error::Ir("remainder by zero".into()));
                            }
                            x.wrapping_rem(y)
                        }
                        IBin::Shl => x.wrapping_shl(y as u32),
                        IBin::Shr => x.wrapping_shr(y as u32),
                        IBin::And => x & y,
                        IBin::Or => x | y,
                        IBin::Xor => x ^ y,
                        IBin::Min => x.min(y),
                        IBin::Max => x.max(y),
                    };
                }
                Insn::BinF { op, d, a, b } => {
                    fuel.charge(1)?;
                    stats.arith_ops += 1;
                    let x = rf[*a as usize];
                    let y = rf[*b as usize];
                    rf[*d as usize] = match op {
                        FBin::Add => x + y,
                        FBin::Sub => x - y,
                        FBin::Mul => x * y,
                        FBin::Div => x / y,
                        FBin::Min => x.min(y),
                        FBin::Max => x.max(y),
                    };
                }
                Insn::CmpI { pred, d, a, b } => {
                    fuel.charge(1)?;
                    stats.arith_ops += 1;
                    let ord = ri[*a as usize].cmp(&ri[*b as usize]);
                    ri[*d as usize] = cmp_result(*pred, ord) as i64;
                }
                Insn::CmpF { pred, d, a, b } => {
                    fuel.charge(1)?;
                    stats.arith_ops += 1;
                    let ord = rf[*a as usize]
                        .partial_cmp(&rf[*b as usize])
                        .ok_or_else(|| Error::Ir("cmp: unordered (NaN)".into()))?;
                    ri[*d as usize] = cmp_result(*pred, ord) as i64;
                }
                Insn::SelI { d, c, a, b } => {
                    fuel.charge(1)?;
                    stats.arith_ops += 1;
                    ri[*d as usize] =
                        if ri[*c as usize] != 0 { ri[*a as usize] } else { ri[*b as usize] };
                }
                Insn::SelF { d, c, a, b } => {
                    fuel.charge(1)?;
                    stats.arith_ops += 1;
                    rf[*d as usize] =
                        if ri[*c as usize] != 0 { rf[*a as usize] } else { rf[*b as usize] };
                }
                Insn::NegI { d, a } => {
                    fuel.charge(1)?;
                    stats.arith_ops += 1;
                    // Wrapping, mirroring the tree-walker (`-i64::MIN`).
                    ri[*d as usize] = ri[*a as usize].wrapping_neg();
                }
                Insn::NegF { d, a } => {
                    fuel.charge(1)?;
                    stats.arith_ops += 1;
                    rf[*d as usize] = -rf[*a as usize];
                }
                Insn::Sqrt { d, a } => {
                    fuel.charge(1)?;
                    stats.arith_ops += 1;
                    rf[*d as usize] = rf[*a as usize].sqrt();
                }
                Insn::Exp { d, a } => {
                    fuel.charge(1)?;
                    stats.arith_ops += 1;
                    rf[*d as usize] = rf[*a as usize].exp();
                }
                Insn::Powi { d, a, e } => {
                    fuel.charge(*e as u64)?;
                    stats.arith_ops += *e as u64;
                    rf[*d as usize] = rf[*a as usize].powi(*e as i32);
                }
                Insn::ToFloat { d, a } => {
                    rf[*d as usize] = ri[*a as usize] as f64;
                }
                Insn::ToInt { d, a } => {
                    ri[*d as usize] = rf[*a as usize] as i64;
                }
                Insn::MovI { d, a } => {
                    ri[*d as usize] = ri[*a as usize];
                }
                Insn::MovF { d, a } => {
                    rf[*d as usize] = rf[*a as usize];
                }
                Insn::LoadF { d, idx, buf, len } => {
                    fuel.charge(1)?;
                    stats.loads += 1;
                    let i = ri[*idx as usize];
                    if i < 0 || i as u64 >= *len as u64 {
                        return Err(oob(i, *len));
                    }
                    rf[*d as usize] = match &mem.bufs[*buf as usize] {
                        crate::ir::interp::BufData::F(v) => v[i as usize],
                        crate::ir::interp::BufData::I(v) => v[i as usize] as f64,
                    };
                }
                Insn::LoadI { d, idx, buf, len } => {
                    fuel.charge(1)?;
                    stats.loads += 1;
                    let i = ri[*idx as usize];
                    if i < 0 || i as u64 >= *len as u64 {
                        return Err(oob(i, *len));
                    }
                    ri[*d as usize] = match &mem.bufs[*buf as usize] {
                        crate::ir::interp::BufData::I(v) => v[i as usize],
                        crate::ir::interp::BufData::F(v) => v[i as usize] as i64,
                    };
                }
                Insn::StoreF { idx, val, buf, len } => {
                    fuel.charge(1)?;
                    stats.stores += 1;
                    let i = ri[*idx as usize];
                    if i < 0 || i as u64 >= *len as u64 {
                        return Err(oob(i, *len));
                    }
                    let x = rf[*val as usize];
                    match &mut mem.bufs[*buf as usize] {
                        crate::ir::interp::BufData::F(v) => v[i as usize] = x,
                        crate::ir::interp::BufData::I(v) => v[i as usize] = x as i64,
                    }
                }
                Insn::StoreI { idx, val, buf, len } => {
                    fuel.charge(1)?;
                    stats.stores += 1;
                    let i = ri[*idx as usize];
                    if i < 0 || i as u64 >= *len as u64 {
                        return Err(oob(i, *len));
                    }
                    let x = ri[*val as usize];
                    match &mut mem.bufs[*buf as usize] {
                        crate::ir::interp::BufData::I(v) => v[i as usize] = x,
                        crate::ir::interp::BufData::F(v) => v[i as usize] = x as f64,
                    }
                }
                Insn::ReadIrf { d, r } => {
                    fuel.charge(1)?;
                    ri[*d as usize] = mem.irf[*r as usize];
                }
                Insn::WriteIrf { a, r } => {
                    fuel.charge(1)?;
                    mem.irf[*r as usize] = ri[*a as usize];
                }
                Insn::Copy { dst, src, d_off, s_off, size, dlen, slen } => {
                    fuel.charge(1)?;
                    stats.transfers += 1;
                    stats.transfer_bytes += *size as u64;
                    let doff = ri[*d_off as usize];
                    let soff = ri[*s_off as usize];
                    checked_copy(
                        mem,
                        BufferId(*dst),
                        doff,
                        BufferId(*src),
                        soff,
                        *size as usize,
                        *dlen as usize,
                        *slen as usize,
                    )?;
                }
                Insn::Issue { dst, src, d_off, s_off, size, dlen, slen, tag, itfc, kind } => {
                    let clk = dma.get_or_insert_with(IssueClock::rocket_default);
                    fuel.charge(
                        1 + clk.txn_beats(InterfaceId(*itfc as usize), *size as usize),
                    )?;
                    stats.transfers += 1;
                    stats.transfer_bytes += *size as u64;
                    let done = clk.issue(InterfaceId(*itfc as usize), *kind, *size as usize)?;
                    stats.dma_cycles = stats.dma_cycles.max(done);
                    pending.insert(
                        *tag,
                        VmPending {
                            dst: *dst,
                            src: *src,
                            d_off: ri[*d_off as usize],
                            s_off: ri[*s_off as usize],
                            size: *size,
                            dlen: *dlen,
                            slen: *slen,
                        },
                    );
                }
                Insn::Wait { tag } => {
                    fuel.charge(1)?;
                    let p = pending
                        .remove(tag)
                        .ok_or_else(|| Error::Ir(format!("copy_wait: unknown tag {tag}")))?;
                    checked_copy(
                        mem,
                        BufferId(p.dst),
                        p.d_off,
                        BufferId(p.src),
                        p.s_off,
                        p.size as usize,
                        p.dlen as usize,
                        p.slen as usize,
                    )?;
                }
                Insn::StepCheck { step } => {
                    let s = ri[*step as usize];
                    if s <= 0 {
                        return Err(Error::Ir(format!("for: non-positive step {s}")));
                    }
                }
                Insn::ForHead { iv, ub, exit } => {
                    if ri[*iv as usize] < ri[*ub as usize] {
                        fuel.charge(1)?;
                        stats.loop_iterations += 1;
                        stats.branches += 1;
                    } else {
                        pc = *exit as usize;
                        continue;
                    }
                }
                Insn::IvInc { iv, step } => {
                    let s = ri[*step as usize];
                    ri[*iv as usize] += s;
                }
                Insn::Jump { pc: t } => {
                    pc = *t as usize;
                    continue;
                }
                Insn::Branch { c, else_pc } => {
                    fuel.charge(1)?;
                    stats.branches += 1;
                    if ri[*c as usize] == 0 {
                        pc = *else_pc as usize;
                        continue;
                    }
                }
                Insn::Intrinsic { name } => {
                    fuel.charge(1)?;
                    stats.intrinsic_calls += 1;
                    return Err(Error::Ir(format!(
                        "intrinsic `{}` reached the reference interpreter; lower it or \
                         execute through the ISAX engine",
                        self.intrinsics[*name as usize]
                    )));
                }
                Insn::Halt => break,
            }
            pc += 1;
        }

        let mut out = Vec::with_capacity(self.ret.len());
        for &(r, ty) in &self.ret {
            out.push(match ty {
                Type::Float => Val::F(rf[r as usize]),
                _ => Val::I(ri[r as usize]),
            });
        }
        Ok(out)
    }
}

fn cmp_result(pred: CmpPred, ord: std::cmp::Ordering) -> bool {
    match pred {
        CmpPred::Eq => ord.is_eq(),
        CmpPred::Ne => ord.is_ne(),
        CmpPred::Lt => ord.is_lt(),
        CmpPred::Le => ord.is_le(),
        CmpPred::Gt => ord.is_gt(),
        CmpPred::Ge => ord.is_ge(),
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::interface::cache::CacheHint;
    use crate::ir::builder::FuncBuilder;
    use crate::ir::interp;

    fn diff(f: &Func, args: &[Val]) -> (Vec<Val>, Memory) {
        let mut m1 = Memory::for_func(f);
        let mut m2 = Memory::for_func(f);
        let mut s1 = ExecStats::default();
        let mut s2 = ExecStats::default();
        let o1 = interp::run_with_stats(f, args, &mut m1, &mut s1).expect("tree-walker");
        let o2 = compile(f).expect("compile").run_with_stats(args, &mut m2, &mut s2).expect("vm");
        assert_eq!(o1, o2, "{}: outputs diverge", f.name);
        assert_eq!(s1, s2, "{}: stats diverge", f.name);
        (o2, m2)
    }

    #[test]
    fn sum_loop_matches_tree_walker() {
        let mut b = FuncBuilder::new("sum");
        let buf = b.global("x", DType::I32, 8, CacheHint::Unknown);
        let zero = b.const_i(0);
        let lb = b.const_i(0);
        let ub = b.const_i(8);
        let one = b.const_i(1);
        let sums = b.for_loop(lb, ub, one, &[zero], |b, iv, carried| {
            let x = b.load(buf, iv);
            vec![b.add(carried[0], x)]
        });
        let f = b.finish(&sums);
        let mut mem = Memory::for_func(&f);
        mem.write_i32(BufferId(0), &[1, 2, 3, 4, 5, 6, 7, 8]);
        let out = compile(&f).unwrap().run(&[], &mut mem).unwrap();
        assert_eq!(out, vec![Val::I(36)]);
        diff(&f, &[]);
    }

    #[test]
    fn carried_swap_parallel_moves() {
        // yield [b, a] — the back edge must move through temps, not
        // clobber sequentially.
        let mut b = FuncBuilder::new("swap");
        let x0 = b.const_i(1);
        let y0 = b.const_i(100);
        let lb = b.const_i(0);
        let ub = b.const_i(5);
        let one = b.const_i(1);
        let outs = b.for_loop(lb, ub, one, &[x0, y0], |b, _iv, carried| {
            let sum = b.add(carried[0], carried[1]);
            vec![carried[1], sum]
        });
        let f = b.finish(&outs);
        let (vals, _) = diff(&f, &[]);
        // Fibonacci-style recurrence seeded (1, 100).
        let (mut a, mut c) = (1i64, 100i64);
        for _ in 0..5 {
            let s = a + c;
            a = c;
            c = s;
        }
        assert_eq!(vals, vec![Val::I(a), Val::I(c)]);
    }

    #[test]
    fn if_else_and_float_math() {
        use crate::ir::types::Type;
        let mut b = FuncBuilder::new("sel");
        let p = b.param(Type::Int);
        let zero = b.const_i(0);
        let c = b.cmp(CmpPred::Gt, p, zero);
        let r = b.if_else(
            c,
            |b| {
                let x = b.const_f(2.0);
                vec![b.exp(x)]
            },
            |b| {
                let x = b.const_f(9.0);
                vec![b.sqrt(x)]
            },
        );
        let f = b.finish(&r);
        let mut mem = Memory::for_func(&f);
        let out = compile(&f).unwrap().run(&[Val::I(5)], &mut mem).unwrap();
        assert_eq!(out, vec![Val::F(2.0f64.exp())]);
        let out = compile(&f).unwrap().run(&[Val::I(-5)], &mut mem).unwrap();
        assert_eq!(out, vec![Val::F(3.0)]);
        diff(&f, &[Val::I(5)]);
        diff(&f, &[Val::I(-5)]);
    }

    #[test]
    fn transfer_and_stats_match() {
        let mut b = FuncBuilder::new("t");
        let g = b.global("g", DType::F32, 16, CacheHint::Cold);
        let s = b.scratchpad("s", DType::F32, 16, 1);
        let zero = b.const_i(0);
        b.transfer(s, zero, g, zero, 16 * 4);
        let f = b.finish(&[]);
        let mut m1 = Memory::for_func(&f);
        let mut m2 = Memory::for_func(&f);
        let data: Vec<f32> = (0..16).map(|i| i as f32).collect();
        m1.write_f32(BufferId(0), &data);
        m2.write_f32(BufferId(0), &data);
        let mut s1 = ExecStats::default();
        let mut s2 = ExecStats::default();
        interp::run_with_stats(&f, &[], &mut m1, &mut s1).unwrap();
        compile(&f).unwrap().run_with_stats(&[], &mut m2, &mut s2).unwrap();
        assert_eq!(m2.read_f32(BufferId(1)), data);
        assert_eq!(s1, s2);
        assert_eq!(s2.transfers, 1);
        assert_eq!(s2.transfer_bytes, 64);
    }

    #[test]
    fn bound_interface_set_matches_tree_walker_and_rejects_bad_ids() {
        use crate::interface::model::{InterfaceId, InterfaceSet};
        use crate::interface::TransactionKind;
        use crate::ir::func::Value;
        use crate::ir::ops::Op;
        let mut b = FuncBuilder::new("t");
        let g = b.global("g", DType::I32, 4, CacheHint::Unknown);
        let s = b.scratchpad("s", DType::I32, 4, 1);
        let zero = b.const_i(0);
        let mut f = {
            b.transfer(s, zero, g, zero, 0); // placeholder replaced below
            b.finish(&[])
        };
        let issue = f.add_op(Op::new(
            OpKind::CopyIssue {
                itfc: InterfaceId(1),
                dst: BufferId(1),
                src: BufferId(0),
                size: 16,
                kind: TransactionKind::Load,
                tag: 3,
                after: vec![],
            },
            vec![Value(0), Value(0)],
            vec![],
        ));
        let wait = f.add_op(Op::new(OpKind::CopyWait { tag: 3 }, vec![], vec![]));
        let ret = f.entry.ops.pop().unwrap();
        f.entry.ops.pop(); // placeholder transfer
        f.entry.ops.push(issue);
        f.entry.ops.push(wait);
        f.entry.ops.push(ret);

        // Both engines, same bound set: bit-identical data and stats,
        // and the wide-bus billing differs from the default pair.
        let wide = InterfaceSet::rocket_wide_bus();
        let run_one = |set: Option<&InterfaceSet>, engine_vm: bool| {
            let mut m = Memory::for_func(&f);
            m.write_i32(BufferId(0), &[9, 8, 7, 6]);
            let mut st = ExecStats::default();
            match (set, engine_vm) {
                (Some(s), true) => run_with_itfcs(&f, &[], &mut m, &mut st, s).unwrap(),
                (Some(s), false) => {
                    interp::run_with_itfcs(&f, &[], &mut m, &mut st, s).unwrap()
                }
                (None, true) => run_with_stats(&f, &[], &mut m, &mut st).unwrap(),
                (None, false) => interp::run_with_stats(&f, &[], &mut m, &mut st).unwrap(),
            };
            assert_eq!(m.read_i32(BufferId(1)), vec![9, 8, 7, 6]);
            st
        };
        let vm_wide = run_one(Some(&wide), true);
        let walker_wide = run_one(Some(&wide), false);
        assert_eq!(vm_wide, walker_wide, "engines diverge on the bound set");
        let vm_default = run_one(None, true);
        assert_eq!(vm_default, run_one(None, false));
        assert_ne!(
            vm_wide.dma_cycles, vm_default.dma_cycles,
            "the wide bus must be billed differently from the default pair"
        );

        // A one-interface set leaves the op's InterfaceId(1) unbound:
        // hard error from both engines.
        let narrow = InterfaceSet::new(vec![wide.interfaces[0].clone()]);
        let mut m = Memory::for_func(&f);
        m.write_i32(BufferId(0), &[9, 8, 7, 6]);
        let mut st = ExecStats::default();
        let err = run_with_itfcs(&f, &[], &mut m, &mut st, &narrow).unwrap_err();
        assert!(err.to_string().contains("unknown interface"), "{err}");
    }

    #[test]
    fn non_positive_step_rejected_like_tree_walker() {
        let mut b = FuncBuilder::new("bad");
        let lb = b.const_i(0);
        let ub = b.const_i(4);
        let step = b.const_i(0);
        b.for_loop(lb, ub, step, &[], |_, _, _| vec![]);
        let f = b.finish(&[]);
        let mut m1 = Memory::for_func(&f);
        let mut m2 = Memory::for_func(&f);
        let e1 = interp::run(&f, &[], &mut m1).unwrap_err().to_string();
        let e2 = compile(&f).unwrap().run(&[], &mut m2).unwrap_err().to_string();
        assert_eq!(e1, e2);
        assert!(e1.contains("non-positive step"));
    }

    #[test]
    fn out_of_bounds_error_matches() {
        let mut b = FuncBuilder::new("oob");
        let buf = b.global("x", DType::I32, 2, CacheHint::Unknown);
        let idx = b.const_i(5);
        let v = b.load(buf, idx);
        let f = b.finish(&[v]);
        let mut m1 = Memory::for_func(&f);
        let mut m2 = Memory::for_func(&f);
        let e1 = interp::run(&f, &[], &mut m1).unwrap_err().to_string();
        let e2 = compile(&f).unwrap().run(&[], &mut m2).unwrap_err().to_string();
        assert_eq!(e1, e2);
    }

    #[test]
    fn fuel_exhausts_identically_on_both_engines() {
        let mut b = FuncBuilder::new("sum");
        let buf = b.global("x", DType::I32, 8, CacheHint::Unknown);
        let zero = b.const_i(0);
        let lb = b.const_i(0);
        let ub = b.const_i(8);
        let one = b.const_i(1);
        let sums = b.for_loop(lb, ub, one, &[zero], |b, iv, carried| {
            let x = b.load(buf, iv);
            vec![b.add(carried[0], x)]
        });
        let f = b.finish(&sums);
        let data = [1, 2, 3, 4, 5, 6, 7, 8];

        // Unlimited fuel: bitwise identical to the unfueled run, and it
        // records the program's exact spend.
        let mut mem = Memory::for_func(&f);
        mem.write_i32(BufferId(0), &data);
        let mut stats = ExecStats::default();
        let mut fuel = Fuel::unlimited();
        let out = run_fueled(&f, &[], &mut mem, &mut stats, &mut fuel).unwrap();
        assert_eq!(out, vec![Val::I(36)]);
        let spent = fuel.spent();
        assert!(spent > 0);

        // Exact fuel succeeds; every smaller budget aborts both engines
        // at the identical event with identical partial stats and memory.
        for budget in [0, 1, spent / 2, spent - 1, spent] {
            let run_one = |engine_vm: bool| {
                let mut m = Memory::for_func(&f);
                m.write_i32(BufferId(0), &data);
                let mut st = ExecStats::default();
                let mut fu = Fuel::new(budget);
                let r = if engine_vm {
                    run_fueled(&f, &[], &mut m, &mut st, &mut fu)
                } else {
                    interp::run_fueled(&f, &[], &mut m, &mut st, &mut fu)
                };
                (r.map_err(|e| e.to_string()), st, fu, m.read_i32(BufferId(0)))
            };
            let (rv, sv, fv, mv) = run_one(true);
            let (rw, sw, fw, mw) = run_one(false);
            assert_eq!(rv, rw, "budget {budget}: results diverge");
            assert_eq!(sv, sw, "budget {budget}: partial stats diverge");
            assert_eq!(fv, fw, "budget {budget}: fuel state diverges");
            assert_eq!(mv, mw, "budget {budget}: memory diverges");
            assert_eq!(rv.is_ok(), budget >= spent);
            if budget < spent {
                assert!(rv.unwrap_err().contains("fuel exhausted"));
            }
        }
    }

    #[test]
    fn consts_are_preloaded_not_executed() {
        let mut b = FuncBuilder::new("c");
        let x = b.global("x", DType::I32, 64, CacheHint::Unknown);
        b.for_range(0, 64, 1, |b, iv| {
            let k = b.const_i(3);
            let v = b.load(x, iv);
            let w = b.mul(v, k);
            b.store(x, iv, w);
        });
        let f = b.finish(&[]);
        let c = compile(&f).unwrap();
        // The loop-body constant contributes zero instructions: only
        // head/load/mul/store/inc/jump remain inside the loop.
        let body_insns = c.num_insns();
        assert!(body_insns <= 12, "expected compact bytecode, got {body_insns}");
        diff(&f, &[]);
    }
}
